//! The cluster runtime end to end, in one process: a `Session` on the
//! networked `ClusterBackend` serving a request stream over the
//! loopback transport, with per-request deadline/loss/straggler/cache
//! stats and the anytime progress stream printed.
//!
//! The stream has the DNN-training shape: two weight matrices `A#0`,
//! `A#1` alternate across requests while the activation matrix `B` is
//! fresh every time — so after the first lap every request hits the
//! session's encoded-block cache and skips re-encoding `A`.
//!
//! `cargo run --release --example cluster_service`

use uepmm::cluster::{ClusterConfig, DeadlineMode, WorkerConfig};
use uepmm::config::SyntheticSpec;
use uepmm::prelude::*;
use uepmm::util::pool::available_parallelism;

fn main() -> anyhow::Result<()> {
    let spec = SyntheticSpec::fig9_rxc().scaled(10);
    let threads = available_parallelism().min(8);
    let backend = ClusterBackend::loopback(
        threads,
        ClusterConfig {
            deadline: DeadlineMode::Virtual,
            time_scale: 0.002, // pace stragglers at 2 ms per virtual unit
            cache_capacity: 0, // the session owns the cache
            ..ClusterConfig::default()
        },
        WorkerConfig {
            name: "loop".to_string(),
            time_scale: 0.002,
            ..WorkerConfig::default()
        },
        std::time::Duration::from_secs(30),
    )?;
    let mut session = Session::builder()
        .partitioning(spec.part.clone())
        .code(CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3())))
        .classes(spec.class_map())
        .workers(spec.workers)
        // seeded injected stragglers: the run is deterministic
        .latency(LatencyModel::exp(1.0))
        .deadline(0.6)
        .score(true)
        .seed(7)
        .backend(backend)
        .build()?;
    println!(
        "loopback cluster: {} coded jobs over {threads} worker threads, Ω={:.2}",
        session.workers(),
        session.omega_value()
    );

    let mut rng = Pcg64::seed_from(7);
    let weights: Vec<Matrix> = (0..2).map(|_| spec.sample_a(&mut rng)).collect();
    // deadlines cycle: the same A at a growing deadline shows the
    // paper's loss-vs-T_max trade-off live
    let deadlines = [0.6, 1.2, 2.4];
    const REQUESTS: usize = 9;
    let mut total_loss = 0.0;
    for req in 0..REQUESTS {
        let a_id = (req % weights.len()) as u64;
        let b = spec.sample_b(&mut rng);
        let t_max = deadlines[(req / weights.len()) % deadlines.len()];
        let out = session.run(
            Request::new(a_id, weights[a_id as usize].clone(), b).deadline(t_max),
        )?;
        total_loss += out.outcome.normalized_loss;
        println!(
            "req {req}: A#{a_id} T_max={t_max:<4} → {:>2} in time, {:>2} late \
             → recovered {}/9, norm-loss {:.4}, {} refinements, cache {}, wall {:?}",
            out.outcome.received,
            out.late,
            out.outcome.recovered,
            out.outcome.normalized_loss,
            out.progress.refinements(),
            if out.cache_hit == Some(true) { "hit " } else { "miss" },
            out.wall,
        );
    }
    let cache = session.cache_stats();
    println!(
        "\nmean norm-loss {:.4} over {REQUESTS} requests; encoded-block cache: \
         {} hits / {} misses — re-encoding of A was skipped on every hit.",
        total_loss / REQUESTS as f64,
        cache.hits,
        cache.misses
    );
    session.shutdown()?;
    Ok(())
}
