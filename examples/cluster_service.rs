//! The cluster runtime end to end, in one process: a coordinator serving
//! a request stream over the loopback transport, with per-request
//! deadline/loss/straggler/cache stats printed.
//!
//! The stream has the DNN-training shape: two weight matrices `A#0`,
//! `A#1` alternate across requests while the activation matrix `B` is
//! fresh every time — so after the first lap every request hits the
//! encoded-block cache and skips re-encoding `A`.
//!
//! `cargo run --release --example cluster_service`

use std::time::Duration;

use uepmm::cluster::{
    spawn_loopback_workers, ClusterConfig, ClusterServer, CodingConfig,
    DeadlineMode, LoopbackTransport, MatmulRequest, WorkerConfig,
};
use uepmm::coding::{CodeKind, CodeSpec, WindowPolynomial};
use uepmm::config::SyntheticSpec;
use uepmm::latency::LatencyModel;
use uepmm::rng::Pcg64;
use uepmm::util::pool::available_parallelism;

fn main() -> anyhow::Result<()> {
    let spec = SyntheticSpec::fig9_rxc().scaled(10);
    let threads = available_parallelism().min(8);
    let coding = CodingConfig {
        part: spec.part.clone(),
        spec: CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3())),
        cm: spec.class_map(),
        workers: spec.workers,
        // seeded injected stragglers: the run is deterministic
        latency: Some(LatencyModel::exp(1.0)),
    };
    println!(
        "loopback cluster: {} coded jobs over {threads} worker threads, Ω={:.2}",
        coding.workers,
        coding.omega()
    );

    let (mut transport, dialer) = LoopbackTransport::new();
    let handles = spawn_loopback_workers(
        &dialer,
        threads,
        &WorkerConfig {
            name: "loop".to_string(),
            omega: coding.omega(),
            time_scale: 0.002, // pace stragglers at 2 ms per virtual unit
            ..WorkerConfig::default()
        },
    );
    drop(dialer);
    let mut server = ClusterServer::new(ClusterConfig {
        deadline: DeadlineMode::Virtual,
        time_scale: 0.002,
        ..ClusterConfig::default()
    });
    let joined = server.accept_workers(&mut transport, threads, Duration::from_secs(10))?;
    anyhow::ensure!(joined == threads, "worker registration failed");

    let mut rng = Pcg64::seed_from(7);
    let weights: Vec<_> = (0..2).map(|_| spec.sample_a(&mut rng)).collect();
    // deadlines cycle: the same A at a growing deadline shows the
    // paper's loss-vs-T_max trade-off live
    let deadlines = [0.6, 1.2, 2.4];
    const REQUESTS: usize = 9;
    let mut total_loss = 0.0;
    for req in 0..REQUESTS {
        let a_id = (req % weights.len()) as u64;
        let b = spec.sample_b(&mut rng);
        let t_max = deadlines[(req / weights.len()) % deadlines.len()];
        let out = server.serve_request(
            &coding,
            &MatmulRequest {
                a_id,
                a: weights[a_id as usize].clone(),
                b,
                t_max,
                score: true,
            },
            &mut rng,
        )?;
        total_loss += out.outcome.normalized_loss;
        println!(
            "req {req}: A#{a_id} T_max={t_max:<4} → {:>2} in time, {:>2} late \
             → recovered {}/9, norm-loss {:.4}, cache {}, wall {:?}",
            out.outcome.received,
            out.late,
            out.outcome.recovered,
            out.outcome.normalized_loss,
            if out.cache_hit == Some(true) { "hit " } else { "miss" },
            out.wall,
        );
    }
    let cache = server.cache_stats();
    println!(
        "\nmean norm-loss {:.4} over {REQUESTS} requests; encoded-block cache: \
         {} hits / {} misses — re-encoding of A was skipped on every hit.",
        total_loss / REQUESTS as f64,
        cache.hits,
        cache.misses
    );
    server.shutdown();
    for h in handles {
        h.join().expect("worker thread")?;
    }
    Ok(())
}
