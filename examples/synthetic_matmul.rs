//! The paper's §VI synthetic study, end to end: sweep deadlines for
//! NOW/EW-UEP/MDS/repetition on both partitioning paradigms and print
//! loss-vs-time plots next to the Theorem 2/3 predictions.
//!
//! `cargo run --release --example synthetic_matmul [-- --full]`

use uepmm::analysis::{mds_loss_vs_time, UepStrategy};
use uepmm::coding::{CodeKind, CodeSpec, EncodeStyle};
use uepmm::config::SyntheticSpec;
use uepmm::experiments::mc_loss_vs_time;
use uepmm::util::linspace;
use uepmm::util::plot::{render, Series};

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { 1 } else { 6 };
    let ts = linspace(0.0, 2.0, 21);
    for (name, spec) in [
        ("row-times-column", SyntheticSpec::fig9_rxc().scaled(scale)),
        ("column-times-row", SyntheticSpec::fig9_cxr().scaled(scale)),
    ] {
        println!("\n=== {name} (W={}, λ=1, Ω={:.2}) ===", spec.workers, spec.omega());
        let th = spec.theorem();
        let mut series = Vec::new();
        for (label, kind) in [
            ("now-uep", CodeKind::NowUep(spec.gamma.clone())),
            ("ew-uep", CodeKind::EwUep(spec.gamma.clone())),
            ("mds", CodeKind::Mds),
            ("repetition", CodeKind::Repetition),
        ] {
            let code = CodeSpec::new(kind, EncodeStyle::Stacked);
            let losses = mc_loss_vs_time(&spec, &code, &ts, 2, 150, 7, 4);
            series.push(Series::new(label, ts.clone(), losses));
        }
        println!("{}", render("normalized loss vs deadline", &series, 64, 16));
        // analytic reference at a few points
        println!("analytic checks (Theorem 2/3 & closed forms):");
        for &t in &[0.5, 1.0, 2.0] {
            println!(
                "  t={t}: Thm NOW {:.3}  Thm EW {:.3}  MDS {:.3}",
                th.normalized_loss(UepStrategy::Now, t).min(9.0),
                th.normalized_loss(UepStrategy::Ew, t).min(9.0),
                mds_loss_vs_time(9, spec.workers, &spec.latency, spec.omega(), t),
            );
        }
    }
    Ok(())
}
