//! The deployment-shaped path: a wall-clock coordinator serving a stream
//! of approximate-multiplication requests over a thread pool of workers
//! with injected straggler delays (paper Fig. 2 as a running service).
//!
//! `cargo run --release --example coded_service`

use uepmm::coding::{CodeKind, CodeSpec, WindowPolynomial};
use uepmm::config::SyntheticSpec;
use uepmm::coordinator::{run_service, Plan, ServiceConfig};
use uepmm::latency::LatencyModel;
use uepmm::rng::Pcg64;
use uepmm::util::pool::available_parallelism;

fn main() -> anyhow::Result<()> {
    let spec = SyntheticSpec::fig9_rxc().scaled(10);
    let mut rng = Pcg64::seed_from(3);
    let cfg = ServiceConfig {
        latency: LatencyModel::exp(1.0),
        omega: spec.omega(),
        t_max: 1.0,
        time_scale: 0.01, // 1 virtual time unit = 10 ms wall
        threads: available_parallelism().min(8),
    };
    println!(
        "coded matmul service: {} workers on {} threads, virtual deadline {}, Ω={:.2}",
        spec.workers, cfg.threads, cfg.t_max, cfg.omega
    );
    let mut total_loss = 0.0;
    let mut total_recovered = 0usize;
    const REQUESTS: usize = 8;
    for req in 0..REQUESTS {
        let (a, b) = spec.sample_matrices(&mut rng);
        let code = CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3()));
        let plan = Plan::build_with_classes(
            &spec.part,
            code,
            spec.class_map(),
            spec.workers,
            &a,
            &b,
            &mut rng,
        )?;
        let out = run_service(&plan, &cfg, &mut rng)?;
        total_loss += out.outcome.normalized_loss;
        total_recovered += out.outcome.recovered;
        println!(
            "req {req}: {:>2} arrivals ({} late) → recovered {}/9, norm-loss {:.4}, wall {:?}",
            out.outcome.received,
            out.late,
            out.outcome.recovered,
            out.outcome.normalized_loss,
            out.wall,
        );
    }
    println!(
        "\nmean normalized loss {:.4}, mean recovery {:.1}/9 — the PS never \
         waited past its deadline; stragglers were simply cut off.",
        total_loss / REQUESTS as f64,
        total_recovered as f64 / REQUESTS as f64
    );
    Ok(())
}
