//! The deployment-shaped path: a coordinator serving a stream of
//! approximate-multiplication requests over a thread pool of workers
//! with injected straggler delays (paper Fig. 2 as a running service) —
//! driven through the unified client API's `PooledBackend`.
//!
//! The stream alternates two weight matrices (the DNN-training shape),
//! so after the first lap every request hits the session's
//! encoded-block cache and skips re-encoding `A`.
//!
//! `cargo run --release --example coded_service`

use uepmm::config::SyntheticSpec;
use uepmm::prelude::*;
use uepmm::util::pool::available_parallelism;

fn main() -> anyhow::Result<()> {
    let spec = SyntheticSpec::fig9_rxc().scaled(10);
    let threads = available_parallelism().min(8);
    let mut session = Session::builder()
        .partitioning(spec.part.clone())
        .code(CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3())))
        .classes(spec.class_map())
        .workers(spec.workers)
        .latency(LatencyModel::exp(1.0))
        .deadline(1.0)
        .score(true)
        .seed(3)
        .backend(PooledBackend::spawn(threads)?)
        .build()?;
    println!(
        "coded matmul service: {} workers on {threads} threads, virtual deadline 1, Ω={:.2}",
        session.workers(),
        session.omega_value()
    );

    // Two weight matrices alternate; activations are fresh per request.
    let mut rng = Pcg64::seed_from(3);
    let weights: Vec<Matrix> = (0..2).map(|_| spec.sample_a(&mut rng)).collect();
    const REQUESTS: usize = 8;

    // Batched submission: the whole stream is prepared (one encode per
    // weight matrix, cache hits for the rest) before any result is read.
    let mut reqs = Vec::new();
    for req in 0..REQUESTS {
        let a_id = (req % weights.len()) as u64;
        let b = spec.sample_b(&mut rng);
        reqs.push(Request::new(a_id, weights[a_id as usize].clone(), b));
    }
    let handles = session.submit_batch(reqs)?;

    let mut total_loss = 0.0;
    let mut total_recovered = 0usize;
    for (req, h) in handles.into_iter().enumerate() {
        let out = session.wait(h)?;
        total_loss += out.outcome.normalized_loss;
        total_recovered += out.outcome.recovered;
        println!(
            "req {req}: {:>2} arrivals ({} late) → recovered {}/9, norm-loss {:.4}, \
             cache {}, wall {:?}",
            out.outcome.received,
            out.late,
            out.outcome.recovered,
            out.outcome.normalized_loss,
            if out.cache_hit == Some(true) { "hit " } else { "miss" },
            out.wall,
        );
    }
    let cache = session.cache_stats();
    println!(
        "\nmean normalized loss {:.4}, mean recovery {:.1}/9; encoded-block cache \
         {} hits / {} misses — the PS never waited past its deadline; stragglers \
         were simply cut off.",
        total_loss / REQUESTS as f64,
        total_recovered as f64 / REQUESTS as f64,
        cache.hits,
        cache.misses
    );
    session.shutdown()?;
    Ok(())
}
