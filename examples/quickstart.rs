//! Quickstart: one UEP-coded approximate matrix multiplication through
//! the unified client API — a `Session` on the in-process backend,
//! dispatching coded worker jobs that execute the AOT-compiled
//! JAX/Pallas matmul artifacts (L2/L1) on the PJRT CPU client.
//!
//! The in-process backend is the *streaming* one: the progress stream
//! below is the paper's anytime story live — every absorbed arrival
//! refines `Ĉ(t)`, with the high-norm blocks recovered first (UEP
//! protection).
//!
//! Build artifacts first: `make artifacts`, then
//! `cargo run --release --example quickstart`.
//! (Falls back to the native engine with a notice if artifacts are
//! missing, so the example always runs.)

use uepmm::prelude::*;
use uepmm::runtime::{ExecEngine, NativeEngine, PjrtEngine};

fn main() -> anyhow::Result<()> {
    // --- the problem: C = A·B with blocks of very different magnitude --
    // r×c partitioning at the artifact geometry: N = P = 3, U = Q = 64,
    // H = 32; row/column blocks at three importance levels.
    let part = Partitioning::rxc(3, 3, 64, 32, 64);
    let mut rng = Pcg64::seed_from(42);
    let sds = [10f64.sqrt(), 1.0, 0.1f64.sqrt()];
    let a_blocks: Vec<Matrix> =
        sds.iter().map(|&s| Matrix::randn(64, 32, 0.0, s, &mut rng)).collect();
    let b_blocks: Vec<Matrix> =
        sds.iter().map(|&s| Matrix::randn(32, 64, 0.0, s, &mut rng)).collect();
    let a = Matrix::vconcat(&a_blocks.iter().collect::<Vec<_>>());
    let b = Matrix::hconcat(&b_blocks.iter().collect::<Vec<_>>());

    // --- the engine: PJRT artifacts when present, native otherwise -----
    let use_pjrt = std::path::Path::new("artifacts/manifest.json").exists();
    if !use_pjrt {
        println!("NOTE: artifacts/ missing — run `make artifacts` for the PJRT path");
    }
    let engine: Box<dyn ExecEngine> = if use_pjrt {
        Box::new(PjrtEngine::from_artifacts("artifacts")?)
    } else {
        Box::new(NativeEngine::default())
    };

    // --- the session: classify by norm, EW-UEP encode for 15 workers,
    //     exponential stragglers at Ω = 9/15 (auto) ----------------------
    let mut session = Session::builder()
        .partitioning(part)
        .code(CodeSpec::new(
            CodeKind::EwUep(WindowPolynomial::paper_table3()),
            EncodeStyle::Stacked,
        ))
        .auto_classes(3)
        .workers(15)
        .latency(LatencyModel::exp(1.0))
        .deadline(4.0)
        .score(true)
        .seed(42)
        .backend(InProcessBackend::with_engine(engine))
        .build()?;

    // --- one request, consumed as an anytime stream ---------------------
    let report = session.run(Request::new(0, a, b))?;
    println!(
        "\n{:>10} {:>9} {:>10} {:>16}",
        "arrival t", "received", "recovered", "norm. loss"
    );
    for e in report.progress.events() {
        println!(
            "{:>10.3} {:>9} {:>10} {:>16.6}",
            e.elapsed, e.received, e.recovered, e.normalized_loss
        );
    }
    println!(
        "\nfinal: received {}/15, recovered {}/9, per-class {:?}, norm-loss {:.6}",
        report.outcome.received,
        report.outcome.recovered,
        report.outcome.per_class_recovered,
        report.outcome.normalized_loss
    );
    println!(
        "engine: {} — progressive refinement: more arrivals ⇒ lower loss,\n\
         with the high-norm blocks recovered first (UEP protection).",
        if use_pjrt { "pjrt (AOT JAX/Pallas artifacts)" } else { "native" }
    );
    Ok(())
}
