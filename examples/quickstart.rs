//! Quickstart: one UEP-coded approximate matrix multiplication through
//! the full three-layer stack — Rust coordinator (L3) dispatching coded
//! worker jobs that execute the AOT-compiled JAX/Pallas matmul artifacts
//! (L2/L1) on the PJRT CPU client.
//!
//! Build artifacts first: `make artifacts`, then
//! `cargo run --release --example quickstart`.
//! (Falls back to the native engine with a notice if artifacts are
//! missing, so the example always runs.)

use uepmm::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
use uepmm::coordinator::{Coordinator, Plan};
use uepmm::latency::LatencyModel;
use uepmm::linalg::Matrix;
use uepmm::partition::Partitioning;
use uepmm::rng::Pcg64;
use uepmm::runtime::{NativeEngine, PjrtEngine};
use uepmm::sim::StragglerSim;

fn main() -> anyhow::Result<()> {
    // --- the problem: C = A·B with blocks of very different magnitude --
    // r×c partitioning at the artifact geometry: N = P = 3, U = Q = 64,
    // H = 32; row/column blocks at three importance levels.
    let part = Partitioning::rxc(3, 3, 64, 32, 64);
    let mut rng = Pcg64::seed_from(42);
    let sds = [10f64.sqrt(), 1.0, 0.1f64.sqrt()];
    let a_blocks: Vec<Matrix> =
        sds.iter().map(|&s| Matrix::randn(64, 32, 0.0, s, &mut rng)).collect();
    let b_blocks: Vec<Matrix> =
        sds.iter().map(|&s| Matrix::randn(32, 64, 0.0, s, &mut rng)).collect();
    let a = Matrix::vconcat(&a_blocks.iter().collect::<Vec<_>>());
    let b = Matrix::hconcat(&b_blocks.iter().collect::<Vec<_>>());

    // --- the plan: classify by norm, EW-UEP encode for 15 workers ------
    let spec = CodeSpec::new(
        CodeKind::EwUep(WindowPolynomial::paper_table3()),
        EncodeStyle::Stacked,
    );
    let plan = Plan::build(&part, spec, 3, 15, &a, &b, &mut rng)?;
    println!(
        "plan: 9 sub-products in {} classes (sizes {:?}), 15 coded jobs",
        plan.cm.n_classes,
        plan.cm.class_sizes()
    );

    // --- straggling workers (exponential latencies, Ω = 9/15) ----------
    let sim = StragglerSim::new(15, LatencyModel::exp(1.0), 9.0 / 15.0);
    let arrivals = sim.sample_arrivals(&mut rng);

    // --- run at a sweep of deadlines on the PJRT engine ----------------
    let use_pjrt = std::path::Path::new("artifacts/manifest.json").exists();
    if !use_pjrt {
        println!("NOTE: artifacts/ missing — run `make artifacts` for the PJRT path");
    }
    println!("\n{:>8} {:>9} {:>10} {:>16}", "T_max", "received", "recovered", "norm. loss");
    let pjrt_coord = if use_pjrt {
        Some(Coordinator::new(PjrtEngine::from_artifacts("artifacts")?))
    } else {
        None
    };
    let native_coord = Coordinator::new(NativeEngine::default());
    for t_max in [0.25, 0.5, 1.0, 2.0, 4.0] {
        let outcome = match &pjrt_coord {
            Some(c) => c.run(&plan, &arrivals, t_max)?,
            None => native_coord.run(&plan, &arrivals, t_max)?,
        };
        println!(
            "{:>8} {:>9} {:>10} {:>16.6}",
            t_max, outcome.received, outcome.recovered, outcome.normalized_loss
        );
    }
    println!(
        "\nengine: {} — progressive refinement: more arrivals ⇒ lower loss,\n\
         with the high-norm blocks recovered first (UEP protection).",
        if use_pjrt { "pjrt (AOT JAX/Pallas artifacts)" } else { "native" }
    );
    Ok(())
}
