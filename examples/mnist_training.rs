//! End-to-end driver (DESIGN.md §validation): train the paper's MNIST
//! MLP (784-100-200-10, Table VI) on the synthetic digit corpus with the
//! dense-layer back-propagation matmuls routed through the UEP-coded
//! distributed engine, logging the loss curve and test accuracy, and —
//! when `artifacts/` exists — cross-checking one training step against
//! the AOT-compiled `mlp_step` JAX artifact so all three layers are
//! exercised in one run.
//!
//! `cargo run --release --example mnist_training [-- --full]`

use uepmm::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
use uepmm::data::synthetic_digits;
use uepmm::latency::LatencyModel;
use uepmm::linalg::Matrix;
use uepmm::nn::{
    softmax_xent, train_mlp, CodedMatmulCfg, DistributedMatmul, MatmulStrategy,
    Mlp, TauSchedule, TrainConfig,
};
use uepmm::partition::Paradigm;
use uepmm::rng::Pcg64;
use uepmm::runtime::PjrtEngine;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let mut rng = Pcg64::seed_from(7);
    let (n_train, n_test, epochs, cap) =
        if full { (60_000, 2_000, 3, 0) } else { (4_096, 512, 3, 40) };
    println!("generating synthetic digit corpus ({n_train} train / {n_test} test)…");
    let train = synthetic_digits(n_train, 11, &mut rng);
    let test = synthetic_digits(n_test, 13, &mut rng);

    // --- L2/L1 cross-check: one centralized step vs the AOT artifact ---
    if std::path::Path::new("artifacts/manifest.json").exists() {
        cross_check_against_artifact(&train)?;
    } else {
        println!("NOTE: artifacts/ missing — skipping the PJRT mlp_step cross-check");
    }

    // --- the coded training run (EW-UEP, eq. 17 encoding, T_max = 1) ---
    let strategy = MatmulStrategy::Coded(CodedMatmulCfg {
        paradigm: Paradigm::RowTimesCol,
        blocks: 3,
        spec: CodeSpec::new(
            CodeKind::NowUep(WindowPolynomial::paper_table3()),
            EncodeStyle::RankOne,
        ),
        workers: 15,
        latency: LatencyModel::exp(0.5),
        auto_omega: true,
        t_max: 1.0,
        s_levels: 3,
    });
    for (label, strat) in [
        ("no-straggler (centralized)", MatmulStrategy::Exact),
        ("NOW-UEP, W=15, T_max=1", strategy),
    ] {
        let mut mlp = Mlp::mnist(&mut rng);
        let cfg = TrainConfig {
            lr: 0.05,
            epochs,
            batch: 64,
            strategy: strat,
            tau: TauSchedule::paper(3),
            seed: 99,
            eval_every: 10,
            max_iters_per_epoch: cap,
        };
        println!("\n=== {label} ===");
        let rec = train_mlp(&mut mlp, &train, &test, &cfg);
        println!("  iter   loss    test-acc");
        for p in &rec.points {
            println!("  {:>4}   {:.4}  {:.4}", p.iter, p.train_loss, p.test_acc);
        }
        println!(
            "  final accuracy {:.4}; distributed sub-product recovery {:.1}%",
            rec.final_test_acc,
            100.0 * rec.recovery_rate
        );
    }
    Ok(())
}

/// Run one batch through the rust MLP and through the compiled JAX
/// `mlp_step` artifact; loss and all gradients must agree to f32
/// tolerance — proving L3's model math is the same graph the AOT path
/// compiled from Pallas kernels.
fn cross_check_against_artifact(train: &uepmm::data::Dataset) -> anyhow::Result<()> {
    let engine = PjrtEngine::from_artifacts("artifacts")?;
    let mut rng = Pcg64::seed_from(1234);
    let mlp = Mlp::mnist(&mut rng);
    let idx: Vec<usize> = (0..64).collect();
    let (x, y) = train.batch(&idx);

    // rust side: loss + grads via the Exact engine
    let (logits, acts) = mlp.forward(&x);
    let (loss_rust, g) = softmax_xent(&logits, &y);
    let mut exact = DistributedMatmul::new(MatmulStrategy::Exact, Pcg64::seed_from(1));
    let grads = mlp.backward(&acts, g, &mut exact, &TauSchedule::off(3), 0);

    // artifact side
    let exe = engine.executable("mlp_step")?;
    let mut inputs: Vec<(Vec<f32>, Vec<usize>)> = Vec::new();
    for layer in &mlp.layers {
        inputs.push((layer.v.to_f32(), vec![layer.v.rows(), layer.v.cols()]));
        inputs.push((
            layer.b.iter().map(|&b| b as f32).collect(),
            vec![layer.b.len()],
        ));
    }
    inputs.push((x.to_f32(), vec![64, 784]));
    inputs.push((y.to_f32(), vec![64, 10]));
    let refs: Vec<(&[f32], &[usize])> =
        inputs.iter().map(|(d, s)| (d.as_slice(), s.as_slice())).collect();
    let outs = exe.run_f32(&refs)?;
    let loss_jax = outs[0][0] as f64;
    anyhow::ensure!(
        (loss_rust - loss_jax).abs() < 1e-4 * (1.0 + loss_rust.abs()),
        "loss mismatch: rust {loss_rust} vs artifact {loss_jax}"
    );
    // dV1 / dV2 / dV3 live at outputs 1, 3, 5
    for (li, out_idx) in [(0usize, 1usize), (1, 3), (2, 5)] {
        let shape = mlp.layers[li].v.shape();
        let got = Matrix::from_f32(shape.0, shape.1, &outs[out_idx]);
        anyhow::ensure!(
            got.allclose(&grads.dv[li], 1e-3),
            "dV{} mismatch: max abs diff {}",
            li + 1,
            got.sub(&grads.dv[li]).max_abs()
        );
    }
    println!(
        "PJRT cross-check OK: rust training step ≡ compiled JAX/Pallas mlp_step \
         (loss {loss_rust:.6} = {loss_jax:.6}, all weight gradients allclose)"
    );
    Ok(())
}
