//! CIFAR-like CNN training with coded dense back-propagation — the
//! paper's Fig. 1 workload as a runnable example (scaled down; pass
//! `--full` for the Table V architecture at 32×32).
//!
//! `cargo run --release --example cifar_training [-- --full]`

use uepmm::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
use uepmm::data::synthetic_cifar;
use uepmm::latency::LatencyModel;
use uepmm::nn::{
    accuracy, Cnn, CnnArch, CodedMatmulCfg, DistributedMatmul, MatmulStrategy,
    TauSchedule,
};
use uepmm::partition::Paradigm;
use uepmm::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let (arch, n_train, n_test, epochs, batch) = if full {
        (CnnArch::paper(), 10_000, 1_000, 30, 64)
    } else {
        (CnnArch::small(), 800, 200, 10, 16)
    };
    println!(
        "CNN: {}×{}×{} → conv{}×2 → dense {}-{}-10 (flat {})",
        arch.in_channels, arch.side, arch.side, arch.conv_channels,
        arch.dense1, arch.dense2, arch.flat_dim()
    );
    let mut rng = Pcg64::seed_from(5);
    let train = synthetic_cifar(n_train, arch.side, 3, &mut rng);
    let test = synthetic_cifar(n_test, arch.side, 5, &mut rng);
    let (tx, ty) = test.all();

    for (label, strategy) in [
        ("no-straggler", MatmulStrategy::Exact),
        (
            "EW-UEP (W=15, T_max=1)",
            MatmulStrategy::Coded(CodedMatmulCfg {
                paradigm: Paradigm::RowTimesCol,
                blocks: 3,
                spec: CodeSpec::new(
                    CodeKind::EwUep(WindowPolynomial::paper_table3()),
                    EncodeStyle::RankOne,
                ),
                workers: 15,
                latency: LatencyModel::exp(0.5),
                auto_omega: true,
                t_max: 1.0,
                s_levels: 3,
            }),
        ),
    ] {
        println!("\n=== {label} ===");
        let mut cnn = Cnn::init(arch, &mut rng);
        let mut engine = DistributedMatmul::new(strategy, Pcg64::seed_from(17));
        let tau = TauSchedule::paper(3);
        let iters = n_train / batch;
        for epoch in 0..epochs {
            let order = uepmm::rng::permutation(&mut rng, train.len());
            let mut loss_sum = 0.0;
            for step in 0..iters {
                let idx = &order[step * batch..(step + 1) * batch];
                let (x, y) = train.batch(idx);
                loss_sum += cnn.train_step(&x, &y, 0.1, &mut engine, &tau, epoch, false);
            }
            let acc = accuracy(&cnn.logits(&tx), &ty);
            println!(
                "  epoch {epoch:>2}: loss {:.4}  test-acc {:.4}",
                loss_sum / iters as f64,
                acc
            );
        }
        println!(
            "  distributed sub-product recovery: {:.1}%",
            100.0 * engine.recovery_rate()
        );
    }
    Ok(())
}
