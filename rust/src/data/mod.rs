//! Synthetic datasets standing in for MNIST and CIFAR-10 (no network
//! access in this environment — see DESIGN.md §5 for why the
//! substitution preserves the experiments' behaviour).
//!
//! * [`synthetic_digits`] — a deterministic parametric digit renderer:
//!   seven-segment-style glyphs on a 28×28 canvas with random affine
//!   jitter, stroke-thickness variation and Gaussian noise. Same shapes
//!   and layer dims as MNIST (Table VI), genuinely learnable, and
//!   gradients sparsify under eq.(34) thresholding just like Fig. 5.
//! * [`synthetic_cifar`] — class-conditional multi-scale textures on a
//!   32×32×3 canvas (per-class frequency/phase/color signature + noise),
//!   matching the CIFAR CNN input of Table V.

mod cifar;
mod digits;

pub use cifar::synthetic_cifar;
pub use digits::synthetic_digits;

use crate::linalg::Matrix;

/// An in-memory classification dataset: flat feature rows + labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `(num_samples, feature_dim)`.
    pub x: Matrix,
    /// Class labels.
    pub labels: Vec<usize>,
    pub num_classes: usize,
}

impl Dataset {
    pub fn new(x: Matrix, labels: Vec<usize>, num_classes: usize) -> Self {
        assert_eq!(x.rows(), labels.len());
        assert!(labels.iter().all(|&l| l < num_classes));
        Dataset { x, labels, num_classes }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn feature_dim(&self) -> usize {
        self.x.cols()
    }

    /// Gather a mini-batch `(X, Y_onehot)` by sample indices.
    pub fn batch(&self, idx: &[usize]) -> (Matrix, Matrix) {
        let mut x = Matrix::zeros(idx.len(), self.x.cols());
        let mut y = Matrix::zeros(idx.len(), self.num_classes);
        for (r, &i) in idx.iter().enumerate() {
            x.row_mut(r).copy_from_slice(self.x.row(i));
            y[(r, self.labels[i])] = 1.0;
        }
        (x, y)
    }

    /// The whole dataset as one batch.
    pub fn all(&self) -> (Matrix, Matrix) {
        let idx: Vec<usize> = (0..self.len()).collect();
        self.batch(&idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn batch_gathers_correct_rows() {
        let x = Matrix::from_fn(4, 3, |r, c| (r * 3 + c) as f64);
        let d = Dataset::new(x, vec![0, 1, 2, 1], 3);
        let (bx, by) = d.batch(&[2, 0]);
        assert_eq!(bx.row(0), &[6.0, 7.0, 8.0]);
        assert_eq!(bx.row(1), &[0.0, 1.0, 2.0]);
        assert_eq!(by[(0, 2)], 1.0);
        assert_eq!(by[(1, 0)], 1.0);
        assert_eq!(by.row(0).iter().sum::<f64>(), 1.0);
    }

    #[test]
    fn digits_dataset_properties() {
        let mut rng = Pcg64::seed_from(1);
        let d = synthetic_digits(100, 42, &mut rng);
        assert_eq!(d.len(), 100);
        assert_eq!(d.feature_dim(), 784);
        assert_eq!(d.num_classes, 10);
        // pixel range is [0, 1]
        assert!(d.x.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
        // all classes present
        let mut seen = vec![false; 10];
        for &l in &d.labels {
            seen[l] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn digits_deterministic_given_seed() {
        let mut r1 = Pcg64::seed_from(9);
        let mut r2 = Pcg64::seed_from(9);
        let a = synthetic_digits(20, 5, &mut r1);
        let b = synthetic_digits(20, 5, &mut r2);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn digit_classes_are_distinguishable() {
        // mean images of different classes must differ substantially —
        // otherwise the classification task is vacuous
        let mut rng = Pcg64::seed_from(2);
        let d = synthetic_digits(500, 3, &mut rng);
        let mut means = vec![vec![0.0; 784]; 10];
        let mut counts = vec![0usize; 10];
        for (i, &l) in d.labels.iter().enumerate() {
            for (m, &v) in means[l].iter_mut().zip(d.x.row(i)) {
                *m += v;
            }
            counts[l] += 1;
        }
        for (m, &c) in means.iter_mut().zip(counts.iter()) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f64;
            }
        }
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f64>().sqrt()
        };
        assert!(dist(&means[0], &means[1]) > 1.0);
        assert!(dist(&means[3], &means[8]) > 1.0);
    }

    #[test]
    fn cifar_dataset_properties() {
        let mut rng = Pcg64::seed_from(3);
        let d = synthetic_cifar(60, 16, 7, &mut rng);
        assert_eq!(d.len(), 60);
        assert_eq!(d.feature_dim(), 3 * 16 * 16);
        assert_eq!(d.num_classes, 10);
    }
}
