//! Parametric digit renderer: a deterministic MNIST stand-in.
//!
//! Each digit 0–9 is drawn from a 16-segment template (the classic
//! seven-segment layout plus diagonals) on a 28×28 canvas, with random
//! translation (±3 px), per-stroke thickness jitter, pixel dropout, and
//! additive Gaussian noise. Pixels are clamped to `[0, 1]` like
//! normalized MNIST.

use crate::linalg::Matrix;
use crate::rng::{Normal, Pcg64, Sample};

use super::Dataset;

const SIDE: usize = 28;

/// Segment endpoints on a 20×12 glyph box (x across, y down), chosen so
/// every digit is visually distinct: (x0, y0, x1, y1) in glyph units.
fn segments_of(digit: usize) -> &'static [(f64, f64, f64, f64)] {
    // canonical seven segments
    const TOP: (f64, f64, f64, f64) = (1.0, 0.0, 11.0, 0.0);
    const TL: (f64, f64, f64, f64) = (0.0, 1.0, 0.0, 9.0);
    const TR: (f64, f64, f64, f64) = (12.0, 1.0, 12.0, 9.0);
    const MID: (f64, f64, f64, f64) = (1.0, 10.0, 11.0, 10.0);
    const BL: (f64, f64, f64, f64) = (0.0, 11.0, 0.0, 19.0);
    const BR: (f64, f64, f64, f64) = (12.0, 11.0, 12.0, 19.0);
    const BOT: (f64, f64, f64, f64) = (1.0, 20.0, 11.0, 20.0);
    const DIAG: (f64, f64, f64, f64) = (11.0, 1.0, 1.0, 19.0); // for 7's slash

    match digit {
        0 => &[TOP, TL, TR, BL, BR, BOT],
        1 => &[TR, BR],
        2 => &[TOP, TR, MID, BL, BOT],
        3 => &[TOP, TR, MID, BR, BOT],
        4 => &[TL, TR, MID, BR],
        5 => &[TOP, TL, MID, BR, BOT],
        6 => &[TOP, TL, MID, BL, BR, BOT],
        7 => &[TOP, DIAG],
        8 => &[TOP, TL, TR, MID, BL, BR, BOT],
        9 => &[TOP, TL, TR, MID, BR, BOT],
        _ => panic!("digit out of range"),
    }
}

/// Render one digit with jitter into a 784-dim row.
fn render(digit: usize, rng: &mut Pcg64, out: &mut [f64]) {
    debug_assert_eq!(out.len(), SIDE * SIDE);
    out.fill(0.0);
    // glyph box is 13 wide × 21 tall in glyph units; scale to ~16×21 px
    let scale_x = 1.15 + 0.15 * (rng.next_f64() - 0.5);
    let scale_y = 1.0 + 0.12 * (rng.next_f64() - 0.5);
    let jitter_x = 6.0 + 3.0 * (rng.next_f64() - 0.5) * 2.0;
    let jitter_y = 3.0 + 3.0 * (rng.next_f64() - 0.5) * 2.0;
    let thickness = 1.0 + 0.5 * rng.next_f64();
    for &(x0, y0, x1, y1) in segments_of(digit) {
        let (px0, py0) = (x0 * scale_x + jitter_x, y0 * scale_y + jitter_y);
        let (px1, py1) = (x1 * scale_x + jitter_x, y1 * scale_y + jitter_y);
        draw_line(out, px0, py0, px1, py1, thickness);
    }
    // pixel dropout + noise
    let noise = Normal::new(0.0, 0.08);
    for v in out.iter_mut() {
        if *v > 0.0 && rng.next_f64() < 0.05 {
            *v = 0.0;
        }
        *v = (*v + noise.sample(rng)).clamp(0.0, 1.0);
    }
}

/// Draw an anti-aliased thick line segment onto the canvas.
fn draw_line(out: &mut [f64], x0: f64, y0: f64, x1: f64, y1: f64, thickness: f64) {
    let dx = x1 - x0;
    let dy = y1 - y0;
    let len2 = (dx * dx + dy * dy).max(1e-9);
    let min_x = (x0.min(x1) - thickness - 1.0).floor().max(0.0) as usize;
    let max_x = (x0.max(x1) + thickness + 1.0).ceil().min((SIDE - 1) as f64) as usize;
    let min_y = (y0.min(y1) - thickness - 1.0).floor().max(0.0) as usize;
    let max_y = (y0.max(y1) + thickness + 1.0).ceil().min((SIDE - 1) as f64) as usize;
    for y in min_y..=max_y {
        for x in min_x..=max_x {
            let (px, py) = (x as f64, y as f64);
            // distance from pixel to the segment
            let t = (((px - x0) * dx + (py - y0) * dy) / len2).clamp(0.0, 1.0);
            let (cx, cy) = (x0 + t * dx, y0 + t * dy);
            let d = ((px - cx).powi(2) + (py - cy).powi(2)).sqrt();
            let intensity = (1.2 * (thickness - d + 0.5)).clamp(0.0, 1.0);
            let idx = y * SIDE + x;
            out[idx] = out[idx].max(intensity);
        }
    }
}

/// Generate a synthetic digit dataset of `n` samples. `class_seed` fixes
/// the label sequence independently of the pixel jitter, so train/test
/// splits with different seeds are disjoint draws from the same
/// distribution.
pub fn synthetic_digits(n: usize, class_seed: u64, rng: &mut Pcg64) -> Dataset {
    let mut label_rng = Pcg64::seed_from(class_seed);
    let mut x = Matrix::zeros(n, SIDE * SIDE);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        // round-robin base + shuffle keeps all classes present
        let label = if i < 10 {
            i
        } else {
            label_rng.next_bounded(10) as usize
        };
        render(label, rng, x.row_mut(i));
        labels.push(label);
    }
    Dataset::new(x, labels, 10)
}
