//! Class-conditional texture images: a CIFAR-10 stand-in.
//!
//! Each class has a fixed signature — two spatial frequencies, a phase
//! field, and an RGB palette — drawn once from the class id; samples add
//! random phase shifts, amplitude jitter and Gaussian noise. The classes
//! are separable by a small CNN but not linearly trivial, which is all
//! the Fig. 1 experiment needs (relative training dynamics under coded
//! stragglers).

use crate::linalg::Matrix;
use crate::rng::{Normal, Pcg64, Sample};

use super::Dataset;

/// Generate `n` synthetic RGB texture images of size `side × side`.
pub fn synthetic_cifar(n: usize, side: usize, class_seed: u64, rng: &mut Pcg64) -> Dataset {
    let num_classes = 10;
    let dim = 3 * side * side;
    let mut x = Matrix::zeros(n, dim);
    let mut labels = Vec::with_capacity(n);
    let mut label_rng = Pcg64::seed_from(class_seed);
    let noise = Normal::new(0.0, 0.15);
    for i in 0..n {
        let label = if i < num_classes {
            i
        } else {
            label_rng.next_bounded(num_classes as u64) as usize
        };
        labels.push(label);
        // per-class deterministic signature
        let mut sig = Pcg64::seed_from(0xC1FA_0000 + label as u64);
        let fx = 1.0 + sig.next_bounded(4) as f64; // spatial frequency x
        let fy = 1.0 + sig.next_bounded(4) as f64;
        let diag = 0.5 + sig.next_f64(); // diagonal component
        let palette: [f64; 3] = [sig.next_f64(), sig.next_f64(), sig.next_f64()];
        // per-sample jitter
        let phase_x = rng.next_f64() * std::f64::consts::TAU;
        let phase_y = rng.next_f64() * std::f64::consts::TAU;
        let amp = 0.8 + 0.4 * rng.next_f64();
        let row = x.row_mut(i);
        for c in 0..3 {
            for yy in 0..side {
                for xx in 0..side {
                    let u = xx as f64 / side as f64 * std::f64::consts::TAU;
                    let v = yy as f64 / side as f64 * std::f64::consts::TAU;
                    let tex = (fx * u + phase_x).sin()
                        + (fy * v + phase_y).cos()
                        + diag * ((u + v) * (1.0 + label as f64 / 3.0)).sin();
                    let val = 0.5 + 0.25 * amp * tex * (0.4 + palette[c]);
                    row[(c * side + yy) * side + xx] =
                        (val + noise.sample(rng)).clamp(0.0, 1.0);
                }
            }
        }
    }
    Dataset::new(x, labels, num_classes)
}
