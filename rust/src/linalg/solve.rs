//! Linear solvers for RLC decoding.
//!
//! * [`lu_solve`] — LU with partial pivoting, multiple right-hand sides:
//!   decodes a class once `k` coded packets with full-rank coefficients
//!   have arrived (the Stacked encoder's per-class decode).
//! * [`Eliminator`] — *incremental* Gaussian elimination that accepts one
//!   equation at a time and reports which unknowns have become uniquely
//!   determined: the global decoder for the paper's literal rank-one
//!   encoding (eq. 17), where packets mix classes.
//! * [`rank`] — numerical rank via row echelon, used by the analysis
//!   validation tests.

use super::Matrix;

/// Relative pivot tolerance for rank decisions.
const PIVOT_TOL: f64 = 1e-9;

/// Solve `A X = B` for square `A` via LU with partial pivoting.
/// Returns `None` if `A` is (numerically) singular.
pub fn lu_solve(a: &Matrix, b: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows(), a.cols(), "lu_solve needs square A");
    assert_eq!(a.rows(), b.rows());
    let n = a.rows();
    let nrhs = b.cols();
    let mut lu = a.clone();
    let mut x = b.clone();
    let scale = a.max_abs().max(1e-300);
    for col in 0..n {
        // partial pivot
        let mut piv = col;
        let mut best = lu[(col, col)].abs();
        for r in col + 1..n {
            let v = lu[(r, col)].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best <= PIVOT_TOL * scale {
            return None;
        }
        if piv != col {
            swap_rows(&mut lu, piv, col);
            swap_rows(&mut x, piv, col);
        }
        let inv_p = 1.0 / lu[(col, col)];
        for r in col + 1..n {
            let f = lu[(r, col)] * inv_p;
            if f == 0.0 {
                continue;
            }
            lu[(r, col)] = 0.0;
            for c in col + 1..n {
                let v = lu[(col, c)];
                lu[(r, c)] -= f * v;
            }
            for c in 0..nrhs {
                let v = x[(col, c)];
                x[(r, c)] -= f * v;
            }
        }
    }
    // back substitution
    for col in (0..n).rev() {
        let inv_p = 1.0 / lu[(col, col)];
        for c in 0..nrhs {
            x[(col, c)] *= inv_p;
        }
        for r in 0..col {
            let f = lu[(r, col)];
            if f == 0.0 {
                continue;
            }
            for c in 0..nrhs {
                let v = x[(col, c)];
                x[(r, c)] -= f * v;
            }
        }
    }
    Some(x)
}

fn swap_rows(m: &mut Matrix, a: usize, b: usize) {
    if a == b {
        return;
    }
    let cols = m.cols();
    let data = m.data_mut();
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    let (first, second) = data.split_at_mut(hi * cols);
    first[lo * cols..lo * cols + cols].swap_with_slice(&mut second[..cols]);
}

/// Numerical rank of `a` via row echelon reduction (destructive copy).
pub fn rank(a: &Matrix) -> usize {
    let mut m = a.clone();
    let rows = m.rows();
    let cols = m.cols();
    let scale = m.max_abs().max(1e-300);
    let mut rank = 0;
    let mut row = 0;
    for col in 0..cols {
        if row >= rows {
            break;
        }
        // find pivot
        let mut piv = row;
        let mut best = m[(row, col)].abs();
        for r in row + 1..rows {
            let v = m[(r, col)].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best <= PIVOT_TOL * scale {
            continue;
        }
        swap_rows(&mut m, piv, row);
        let inv_p = 1.0 / m[(row, col)];
        for r in row + 1..rows {
            let f = m[(r, col)] * inv_p;
            if f == 0.0 {
                continue;
            }
            for c in col..cols {
                let v = m[(row, c)];
                m[(r, c)] -= f * v;
            }
        }
        rank += 1;
        row += 1;
    }
    rank
}

/// Least-squares solve of possibly overdetermined `A x = b` via normal
/// equations (adequate for the small well-conditioned systems the
/// decoders produce). Returns `None` when `AᵀA` is singular.
pub fn solve_least_squares(a: &Matrix, b: &Matrix) -> Option<Matrix> {
    let at = a.transpose();
    let ata = super::matmul(&at, a);
    let atb = super::matmul(&at, b);
    lu_solve(&ata, &atb)
}

/// Outcome of feeding one equation to the [`Eliminator`]. Both variants
/// hand the caller's buffers back (the eliminator copies into its flat
/// storage), so a decoder can run with zero steady-state allocation;
/// their contents are the reduced row, not the original equation.
#[derive(Debug)]
pub enum Absorption {
    /// The equation increased the rank; the listed unknown indices became
    /// uniquely determined as a result (possibly none).
    Absorbed { newly: Vec<usize>, coeff: Vec<f64>, rhs: Vec<f64> },
    /// The equation was linearly dependent on the rows already absorbed.
    Rejected { coeff: Vec<f64>, rhs: Vec<f64> },
}

/// Incremental Gauss–Jordan eliminator over `n` unknowns.
///
/// Feed equations `coeff · x = rhs` one at a time (each `rhs` is an
/// arbitrary payload vector — here, a flattened matrix sub-product). The
/// eliminator maintains the *reduced* row-echelon form of everything
/// absorbed so far, which makes determination detection **complete**:
/// `e_i` lies in the row space iff the RREF contains a row supported on
/// `{i}` alone. (A one-directional staircase is not enough — a packet
/// covering extra unknowns can take an early pivot and hide a solvable
/// subsystem; see the EW-UEP decoding tests.)
///
/// Because payloads ride through the same row operations, the reduced
/// right-hand side of a singleton row *is* the recovered value: value
/// recovery is per-pivot back-substitution, never a batch re-solve.
///
/// Storage is flat and contiguous (`rank × n` coefficients, `rank ×
/// payload_len` payloads) — one allocation each that grows amortized,
/// rather than two heap cells per absorbed row.
pub struct Eliminator {
    n: usize,
    payload_len: usize,
    /// Flat row-major RREF coefficient storage (`rank` rows × `n`).
    coeffs: Vec<f64>,
    /// Flat payload storage aligned with `coeffs` (`rank` × `payload_len`).
    payloads: Vec<f64>,
    /// pivot column of each stored row.
    pivot_of_row: Vec<usize>,
    /// row index owning pivot column c, or usize::MAX.
    row_of_pivot: Vec<usize>,
    determined: Vec<bool>,
    /// Maintained count of `true` entries in `determined`.
    n_determined: usize,
}

impl Eliminator {
    pub fn new(n_unknowns: usize, payload_len: usize) -> Self {
        Eliminator {
            n: n_unknowns,
            payload_len,
            coeffs: Vec::new(),
            payloads: Vec::new(),
            pivot_of_row: Vec::new(),
            row_of_pivot: vec![usize::MAX; n_unknowns],
            determined: vec![false; n_unknowns],
            n_determined: 0,
        }
    }

    pub fn n_unknowns(&self) -> usize {
        self.n
    }

    pub fn payload_len(&self) -> usize {
        self.payload_len
    }

    /// Current rank (number of independent equations absorbed).
    pub fn rank(&self) -> usize {
        self.pivot_of_row.len()
    }

    /// Number of determined unknowns (maintained incrementally, O(1)).
    pub fn determined_count(&self) -> usize {
        self.n_determined
    }

    /// Clear all absorbed state and re-dimension, keeping the backing
    /// allocations (scratch reuse across Monte-Carlo trials).
    pub fn reset(&mut self, n_unknowns: usize, payload_len: usize) {
        self.n = n_unknowns;
        self.payload_len = payload_len;
        self.coeffs.clear();
        self.payloads.clear();
        self.pivot_of_row.clear();
        self.row_of_pivot.clear();
        self.row_of_pivot.resize(n_unknowns, usize::MAX);
        self.determined.clear();
        self.determined.resize(n_unknowns, false);
        self.n_determined = 0;
    }

    /// Fix the payload width after construction. Only legal while no row
    /// has been absorbed (the flat payload storage is strided by it).
    pub fn set_payload_len(&mut self, len: usize) {
        assert_eq!(self.rank(), 0, "payload width is fixed after the first absorbed row");
        self.payload_len = len;
    }

    /// Insert one equation, taking ownership of its buffers. Dependent
    /// equations are rejected and the buffers handed back for reuse.
    pub fn insert(&mut self, mut coeff: Vec<f64>, mut rhs: Vec<f64>) -> Absorption {
        assert_eq!(coeff.len(), self.n);
        assert_eq!(rhs.len(), self.payload_len);
        let n = self.n;
        let pl = self.payload_len;
        // Forward-reduce the incoming row against every stored pivot.
        // (Stored rows have no support left of their own pivot — the
        // RREF invariant — so reduction from `col` onward is complete.)
        let scale0 = coeff.iter().fold(0.0f64, |m, x| m.max(x.abs())).max(1e-300);
        for col in 0..n {
            if coeff[col] == 0.0 {
                continue;
            }
            let owner = self.row_of_pivot[col];
            if owner == usize::MAX {
                continue;
            }
            let f = coeff[col];
            let rc = &self.coeffs[owner * n..(owner + 1) * n];
            for i in col..n {
                coeff[i] -= f * rc[i];
            }
            let rr = &self.payloads[owner * pl..(owner + 1) * pl];
            for (v, p) in rhs.iter_mut().zip(rr.iter()) {
                *v -= f * p;
            }
            coeff[col] = 0.0;
        }
        // Find the pivot (first entry above tolerance).
        let piv = match coeff
            .iter()
            .position(|&v| v.abs() > PIVOT_TOL * scale0)
        {
            Some(p) => p,
            None => return Absorption::Rejected { coeff, rhs },
        };
        // Normalize.
        let inv = 1.0 / coeff[piv];
        for v in coeff.iter_mut() {
            *v *= inv;
        }
        for v in rhs.iter_mut() {
            *v *= inv;
        }
        coeff[piv] = 1.0;
        // Snap sub-tolerance residue to exact zero so support tests are
        // meaningful.
        for v in coeff.iter_mut() {
            if v.abs() <= PIVOT_TOL {
                *v = 0.0;
            }
        }
        // Back-eliminate the new pivot from every existing row (this is
        // what upgrades the staircase to a full RREF). Rows whose support
        // shrinks to their pivot alone become determined — detected here,
        // in the same pass, instead of a full O(rank·n) rescan.
        let n_rows = self.pivot_of_row.len();
        let mut newly = Vec::new();
        for ri in 0..n_rows {
            let base = ri * n;
            let f = self.coeffs[base + piv];
            if f == 0.0 {
                continue;
            }
            let own_piv = self.pivot_of_row[ri];
            let rc = &mut self.coeffs[base..base + n];
            for (v, nv) in rc.iter_mut().zip(coeff.iter()) {
                *v -= f * nv;
                if v.abs() <= PIVOT_TOL {
                    *v = 0.0;
                }
            }
            rc[piv] = 0.0;
            // restore the exact pivot 1 of that row (numerical hygiene)
            rc[own_piv] = 1.0;
            let support = rc.iter().filter(|&&v| v != 0.0).count();
            let rr = &mut self.payloads[ri * pl..(ri + 1) * pl];
            for (v, nv) in rr.iter_mut().zip(rhs.iter()) {
                *v -= f * nv;
            }
            if support == 1 && !self.determined[own_piv] {
                self.determined[own_piv] = true;
                self.n_determined += 1;
                newly.push(own_piv);
            }
        }
        // Append the new row to the flat storage.
        self.coeffs.extend_from_slice(&coeff);
        self.payloads.extend_from_slice(&rhs);
        self.pivot_of_row.push(piv);
        self.row_of_pivot[piv] = n_rows;
        let support = coeff.iter().filter(|&&v| v != 0.0).count();
        if support == 1 && !self.determined[piv] {
            self.determined[piv] = true;
            self.n_determined += 1;
            newly.push(piv);
        }
        Absorption::Absorbed { newly, coeff, rhs }
    }

    /// Insert, discarding the returned buffers: returns the newly
    /// determined unknowns (empty for dependent equations).
    pub fn absorb(&mut self, coeff: Vec<f64>, rhs: Vec<f64>) -> Vec<usize> {
        match self.insert(coeff, rhs) {
            Absorption::Absorbed { newly, .. } => newly,
            Absorption::Rejected { .. } => Vec::new(),
        }
    }

    pub fn is_determined(&self, idx: usize) -> bool {
        self.determined[idx]
    }

    /// Recovered payload for a determined unknown (its singleton RREF
    /// row's reduced right-hand side).
    pub fn value_of(&self, idx: usize) -> Option<&[f64]> {
        if !self.determined[idx] {
            return None;
        }
        let row = self.row_of_pivot[idx];
        Some(&self.payloads[row * self.payload_len..(row + 1) * self.payload_len])
    }

    /// Indices of all currently determined unknowns.
    pub fn determined_set(&self) -> Vec<usize> {
        (0..self.n).filter(|&i| self.determined[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::util::prop::{gen, prop_check, PropConfig};

    #[test]
    fn lu_solves_known_system() {
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 3.0]);
        let b = Matrix::from_vec(2, 1, vec![5.0, 10.0]);
        let x = lu_solve(&a, &b).unwrap();
        assert!((x[(0, 0)] - 1.0).abs() < 1e-12);
        assert!((x[(1, 0)] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn lu_detects_singular() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        let b = Matrix::from_vec(2, 1, vec![1.0, 2.0]);
        assert!(lu_solve(&a, &b).is_none());
    }

    #[test]
    fn lu_random_roundtrip() {
        prop_check("lu roundtrip", PropConfig { cases: 30, seed: 42 }, |rng, _| {
            let n = gen::usize_in(rng, 1, 20);
            let nrhs = gen::usize_in(rng, 1, 5);
            let a = Matrix::randn(n, n, 0.0, 1.0, rng);
            let x_true = Matrix::randn(n, nrhs, 0.0, 1.0, rng);
            let b = crate::linalg::matmul(&a, &x_true);
            match lu_solve(&a, &b) {
                Some(x) => {
                    if x.allclose(&x_true, 1e-6) {
                        Ok(())
                    } else {
                        Err("solution mismatch".to_string())
                    }
                }
                None => Err("spurious singularity".to_string()),
            }
        });
    }

    #[test]
    fn rank_of_constructed_matrices() {
        assert_eq!(rank(&Matrix::eye(5)), 5);
        assert_eq!(rank(&Matrix::zeros(3, 4)), 0);
        // rank-1 outer product
        let u = Matrix::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let v = Matrix::from_vec(1, 4, vec![1.0, -1.0, 2.0, 0.5]);
        assert_eq!(rank(&crate::linalg::matmul(&u, &v)), 1);
    }

    #[test]
    fn least_squares_recovers_exact_solution() {
        let mut rng = Pcg64::seed_from(8);
        let a = Matrix::randn(10, 4, 0.0, 1.0, &mut rng);
        let x_true = Matrix::randn(4, 2, 0.0, 1.0, &mut rng);
        let b = crate::linalg::matmul(&a, &x_true);
        let x = solve_least_squares(&a, &b).unwrap();
        assert!(x.allclose(&x_true, 1e-8));
    }

    #[test]
    fn eliminator_simple_sequence() {
        // unknowns x0, x1 with payloads of length 1
        let mut e = Eliminator::new(2, 1);
        // x0 + x1 = 3
        let newly = e.absorb(vec![1.0, 1.0], vec![3.0]);
        assert!(newly.is_empty());
        // x0 - x1 = 1  → x0 = 2, x1 = 1
        let mut newly = e.absorb(vec![1.0, -1.0], vec![1.0]);
        newly.sort_unstable();
        assert_eq!(newly, vec![0, 1]);
        assert_eq!(e.determined_count(), 2);
        assert!((e.value_of(0).unwrap()[0] - 2.0).abs() < 1e-12);
        assert!((e.value_of(1).unwrap()[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eliminator_rejects_dependent_rows_with_buffers() {
        let mut e = Eliminator::new(3, 1);
        e.absorb(vec![1.0, 1.0, 0.0], vec![1.0]);
        // dependent: ownership of the buffers comes back
        match e.insert(vec![2.0, 2.0, 0.0], vec![2.0]) {
            Absorption::Rejected { coeff, rhs } => {
                assert_eq!(coeff.len(), 3);
                assert_eq!(rhs.len(), 1);
            }
            Absorption::Absorbed { .. } => panic!("dependent row absorbed"),
        }
        assert_eq!(e.rank(), 1);
        assert_eq!(e.determined_count(), 0);
    }

    #[test]
    fn eliminator_partial_decode() {
        // x2 determined alone while x0,x1 stay mixed.
        let mut e = Eliminator::new(3, 2);
        let newly = e.absorb(vec![0.0, 0.0, 2.0], vec![4.0, 6.0]);
        assert_eq!(newly, vec![2]);
        assert_eq!(e.value_of(2).unwrap(), &[2.0, 3.0]);
        assert!(!e.is_determined(0));
        assert_eq!(e.determined_count(), 1);
    }

    #[test]
    fn eliminator_reset_reuses_allocations() {
        let mut e = Eliminator::new(3, 1);
        e.absorb(vec![1.0, 0.5, 0.0], vec![1.0]);
        e.absorb(vec![0.0, 1.0, 2.0], vec![2.0]);
        assert_eq!(e.rank(), 2);
        e.reset(4, 0);
        assert_eq!(e.rank(), 0);
        assert_eq!(e.n_unknowns(), 4);
        assert_eq!(e.payload_len(), 0);
        assert_eq!(e.determined_count(), 0);
        let newly = e.absorb(vec![0.0, 0.0, 0.0, 3.0], vec![]);
        assert_eq!(newly, vec![3]);
    }

    #[test]
    fn eliminator_random_full_recovery() {
        prop_check("eliminator recovers all", PropConfig { cases: 20, seed: 77 }, |rng, _| {
            let n = gen::usize_in(rng, 1, 8);
            let payload = gen::usize_in(rng, 1, 4);
            let truth: Vec<Vec<f64>> =
                (0..n).map(|_| gen::normal_vec(rng, payload)).collect();
            let mut e = Eliminator::new(n, payload);
            // Feed 3n random dense equations; after n independent ones all
            // unknowns must be determined with correct values.
            for _ in 0..3 * n {
                let coeff = gen::normal_vec(rng, n);
                let mut rhs = vec![0.0; payload];
                for (i, c) in coeff.iter().enumerate() {
                    for (r, t) in rhs.iter_mut().zip(truth[i].iter()) {
                        *r += c * t;
                    }
                }
                e.absorb(coeff, rhs);
            }
            for i in 0..n {
                let got = e.value_of(i).ok_or("unknown undetermined")?;
                for (g, t) in got.iter().zip(truth[i].iter()) {
                    if (g - t).abs() > 1e-6 {
                        return Err(format!("unknown {i}: {g} vs {t}"));
                    }
                }
            }
            Ok(())
        });
    }
}
