//! Row-major dense matrix with the block operations the paper's
//! partitioners need (row/column block split + concat), Frobenius norms
//! (importance classification), and elementwise arithmetic.

use crate::rng::{Normal, Pcg64, Sample};
use std::fmt;
use std::ops::{Index, IndexMut};

/// A dense, row-major `f64` matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a closure `f(r, c)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Matrix::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    /// I.i.d. Gaussian entries `N(mean, sd²)` — Assumption 1 matrices.
    pub fn randn(rows: usize, cols: usize, mean: f64, sd: f64, rng: &mut Pcg64) -> Self {
        let dist = Normal::new(mean, sd);
        let data = (0..rows * cols).map(|_| dist.sample(rng)).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Squared Frobenius norm `‖A‖²_F` — the importance measure (§IV-A).
    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum()
    }

    /// Frobenius norm.
    pub fn frob(&self) -> f64 {
        self.frob_sq().sqrt()
    }

    /// `‖A - B‖²_F`, the paper's loss (2).
    pub fn frob_sq_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum()
    }

    /// Extract the sub-matrix at `rows r0..r0+h`, `cols c0..c0+w`.
    pub fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block out of range");
        let mut out = Matrix::zeros(h, w);
        for r in 0..h {
            let src = &self.data[(r0 + r) * self.cols + c0..(r0 + r) * self.cols + c0 + w];
            out.row_mut(r).copy_from_slice(src);
        }
        out
    }

    /// Write `blk` into position `(r0, c0)`.
    pub fn set_block(&mut self, r0: usize, c0: usize, blk: &Matrix) {
        assert!(r0 + blk.rows <= self.rows && c0 + blk.cols <= self.cols);
        for r in 0..blk.rows {
            let dst_off = (r0 + r) * self.cols + c0;
            self.data[dst_off..dst_off + blk.cols].copy_from_slice(blk.row(r));
        }
    }

    /// Horizontal (column-wise) concatenation `[A₁, A₂, …]`.
    pub fn hconcat(parts: &[&Matrix]) -> Matrix {
        assert!(!parts.is_empty());
        let rows = parts[0].rows;
        assert!(parts.iter().all(|p| p.rows == rows), "row mismatch in hconcat");
        let cols: usize = parts.iter().map(|p| p.cols).sum();
        let mut out = Matrix::zeros(rows, cols);
        let mut c0 = 0;
        for p in parts {
            out.set_block(0, c0, p);
            c0 += p.cols;
        }
        out
    }

    /// Vertical (row-wise) concatenation `[B₁; B₂; …]`.
    pub fn vconcat(parts: &[&Matrix]) -> Matrix {
        assert!(!parts.is_empty());
        let cols = parts[0].cols;
        assert!(parts.iter().all(|p| p.cols == cols), "col mismatch in vconcat");
        let rows: usize = parts.iter().map(|p| p.rows).sum();
        let mut out = Matrix::zeros(rows, cols);
        let mut r0 = 0;
        for p in parts {
            out.set_block(r0, 0, p);
            r0 += p.rows;
        }
        out
    }

    /// Split into `n` equal row-blocks. Panics unless `rows % n == 0`.
    pub fn split_rows(&self, n: usize) -> Vec<Matrix> {
        assert!(n > 0 && self.rows % n == 0, "rows {} not divisible by {n}", self.rows);
        let h = self.rows / n;
        (0..n).map(|i| self.block(i * h, 0, h, self.cols)).collect()
    }

    /// Split into `n` equal column-blocks. Panics unless `cols % n == 0`.
    pub fn split_cols(&self, n: usize) -> Vec<Matrix> {
        assert!(n > 0 && self.cols % n == 0, "cols {} not divisible by {n}", self.cols);
        let w = self.cols / n;
        (0..n).map(|i| self.block(0, i * w, self.rows, w)).collect()
    }

    /// `self += alpha * other` (AXPY).
    pub fn axpy(&mut self, alpha: f64, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// `alpha * self`, in place.
    pub fn scale(&mut self, alpha: f64) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Elementwise sum.
    pub fn add(&self, other: &Matrix) -> Matrix {
        let mut out = self.clone();
        out.axpy(1.0, other);
        out
    }

    /// Elementwise difference.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        let mut out = self.clone();
        out.axpy(-1.0, other);
        out
    }

    /// Copy as `f32` (the artifact I/O dtype).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Build from `f32` data.
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }

    /// True if all entries are within `tol` of `other`.
    pub fn allclose(&self, other: &Matrix, tol: f64) -> bool {
        self.shape() == other.shape()
            && self
                .data
                .iter()
                .zip(other.data.iter())
                .all(|(a, b)| (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())))
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_rows = self.rows.min(6);
        for r in 0..show_rows {
            let row: Vec<String> = self
                .row(r)
                .iter()
                .take(8)
                .map(|x| format!("{x:9.4}"))
                .collect();
            let ell = if self.cols > 8 { " …" } else { "" };
            writeln!(f, "  [{}{}]", row.join(", "), ell)?;
        }
        if self.rows > show_rows {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_roundtrip() {
        let m = Matrix::from_fn(6, 9, |r, c| (r * 9 + c) as f64);
        let b = m.block(2, 3, 2, 4);
        assert_eq!(b[(0, 0)], (2 * 9 + 3) as f64);
        let mut m2 = Matrix::zeros(6, 9);
        m2.set_block(2, 3, &b);
        assert_eq!(m2[(3, 6)], m[(3, 6)]);
        assert_eq!(m2[(0, 0)], 0.0);
    }

    #[test]
    fn split_concat_rows_roundtrip() {
        let m = Matrix::from_fn(9, 4, |r, c| (r * 4 + c) as f64);
        let parts = m.split_rows(3);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[1][(0, 0)], 12.0);
        let refs: Vec<&Matrix> = parts.iter().collect();
        assert_eq!(Matrix::vconcat(&refs), m);
    }

    #[test]
    fn split_concat_cols_roundtrip() {
        let m = Matrix::from_fn(4, 9, |r, c| (r * 9 + c) as f64);
        let parts = m.split_cols(3);
        let refs: Vec<&Matrix> = parts.iter().collect();
        assert_eq!(Matrix::hconcat(&refs), m);
    }

    #[test]
    fn frobenius() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert!((m.frob_sq() - 30.0).abs() < 1e-12);
        let z = Matrix::zeros(2, 2);
        assert_eq!(m.frob_sq_diff(&z), 30.0);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_fn(3, 5, |r, c| (r + 7 * c) as f64);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose()[(4, 2)], m[(2, 4)]);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Pcg64::seed_from(10);
        let m = Matrix::randn(200, 200, 1.0, 3.0, &mut rng);
        let n = (m.rows() * m.cols()) as f64;
        let mean = m.data().iter().sum::<f64>() / n;
        let var = m.data().iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        assert!((mean - 1.0).abs() < 0.05);
        assert!((var - 9.0).abs() < 0.3);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Matrix::eye(2);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        a.axpy(2.0, &b);
        assert_eq!(a[(0, 0)], 3.0);
        assert_eq!(a[(0, 1)], 2.0);
        a.scale(0.5);
        assert_eq!(a[(0, 0)], 1.5);
    }

    #[test]
    fn f32_roundtrip() {
        let m = Matrix::from_fn(3, 3, |r, c| (r * 3 + c) as f64);
        let f = m.to_f32();
        let back = Matrix::from_f32(3, 3, &f);
        assert!(back.allclose(&m, 1e-6));
    }

    #[test]
    #[should_panic]
    fn block_out_of_range_panics() {
        Matrix::zeros(2, 2).block(1, 1, 2, 2);
    }
}
