//! Matrix multiplication kernels for the native execution engine.
//!
//! Three tiers, all producing identical results:
//! * `matmul_naive` — reference triple loop (correctness oracle),
//! * cache-blocked micro-kernel with B packed column-major per tile,
//! * thread-parallel row-band split on top of the blocked kernel.
//!
//! The dispatcher `matmul` picks a tier from the problem size. This is the
//! CPU stand-in for the Pallas kernel (which owns the real hot path on
//! TPU); its blocking mirrors the kernel's `BlockSpec` tiling so the two
//! implementations stay structurally comparable.

use super::Matrix;
use crate::util::pool::{available_parallelism, parallel_map};

/// Tuning knobs for the blocked kernel.
#[derive(Clone, Copy, Debug)]
pub struct MatmulOpts {
    /// Row-tile (M dimension).
    pub tile_m: usize,
    /// Inner-tile (K dimension).
    pub tile_k: usize,
    /// Column-tile (N dimension).
    pub tile_n: usize,
    /// Thread count; 1 disables parallelism.
    pub threads: usize,
    /// FLOP threshold below which the naive kernel is used.
    pub naive_below: usize,
}

impl Default for MatmulOpts {
    fn default() -> Self {
        // Tuned on the bench harness (`cargo bench -- matmul`,
        // EXPERIMENTS.md §Perf): small row tiles keep the 8×8
        // micro-kernel's A rows hot; tile_k=64 bounds the packed tile to
        // L1; wide tile_n amortizes packing.
        MatmulOpts {
            tile_m: 16,
            tile_k: 64,
            tile_n: 256,
            threads: available_parallelism(),
            naive_below: 32 * 32 * 32,
        }
    }
}

/// `C = A · B` with default options.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_with(a, b, MatmulOpts::default())
}

/// `C = A · B` with explicit options.
pub fn matmul_with(a: &Matrix, b: &Matrix, opts: MatmulOpts) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {:?}x{:?}", a.shape(), b.shape());
    let mut c = Matrix::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c, opts);
    c
}

/// Reference triple-loop product (used as the oracle in tests).
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        for p in 0..k {
            let av = a[(i, p)];
            if av == 0.0 {
                continue;
            }
            let brow = b.row(p);
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// Micro-kernel row block height.
const MR: usize = 8;
/// Micro-kernel accumulator width (one AVX-512 f64 vector).
const NR: usize = 8;

/// 8×8 register-blocked micro-kernel: the C tile (8 zmm registers) lives
/// in registers for the whole contraction; each packed B row chunk is
/// loaded once per `p` and feeds eight FMA streams.
#[inline]
fn microkernel_8x8(
    a: &Matrix,
    c: &mut Matrix,
    bpack: &[f64],
    nb: usize,
    i0: usize,
    j0_in_tile: usize,
    jb: usize,
    pb: usize,
    kb: usize,
) {
    let mut acc = [[0.0f64; NR]; MR];
    let mut arows: [&[f64]; MR] = [&[]; MR];
    for (r, ar) in arows.iter_mut().enumerate() {
        *ar = &a.row(i0 + r)[pb..pb + kb];
    }
    for p in 0..kb {
        let boff = p * nb + j0_in_tile;
        let bvals: &[f64; NR] = bpack[boff..boff + NR].try_into().unwrap();
        for r in 0..MR {
            let x = arows[r][p];
            for j in 0..NR {
                acc[r][j] += x * bvals[j];
            }
        }
    }
    for (r, acc_row) in acc.iter().enumerate() {
        let crow = &mut c.row_mut(i0 + r)[jb + j0_in_tile..jb + j0_in_tile + NR];
        for j in 0..NR {
            crow[j] += acc_row[j];
        }
    }
}

/// `C = A · B`, writing into a pre-allocated output (zeroed first).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix, opts: MatmulOpts) {
    assert_eq!(a.cols(), b.rows());
    assert_eq!(c.shape(), (a.rows(), b.cols()));
    c.data_mut().fill(0.0);
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let flops = m * k * n;
    if flops <= opts.naive_below {
        // Small problems: blocked overhead dominates; reuse the naive loop.
        let res = matmul_naive(a, b);
        c.data_mut().copy_from_slice(res.data());
        return;
    }
    let threads = opts.threads.max(1);
    if threads == 1 || m < 2 * opts.tile_m {
        matmul_blocked_range(a, b, c, 0, m, opts);
        return;
    }
    // Split C into row bands; each thread computes one band independently.
    let bands = threads.min(m);
    let band_rows = (m + bands - 1) / bands;
    let parts: Vec<Matrix> = parallel_map(bands, threads, |bi| {
        let r0 = bi * band_rows;
        let r1 = ((bi + 1) * band_rows).min(m);
        if r0 >= r1 {
            return Matrix::zeros(0, n);
        }
        let sub_a = a.block(r0, 0, r1 - r0, k);
        let mut sub_c = Matrix::zeros(r1 - r0, n);
        matmul_blocked_range(&sub_a, b, &mut sub_c, 0, r1 - r0, opts);
        sub_c
    });
    let mut r0 = 0;
    for p in parts.iter().filter(|p| p.rows() > 0) {
        c.set_block(r0, 0, p);
        r0 += p.rows();
    }
}

/// Blocked kernel over rows `[row0, row1)` of C.
///
/// The micro-kernel is in *broadcast-AXPY* form — `c[i, j..] += a[i,p] ·
/// b[p, j..]` — rather than dot-product form: an `f64` dot product is a
/// serial reduction the compiler cannot vectorize under strict FP
/// semantics, while the AXPY body has independent lanes and
/// auto-vectorizes to FMA. Switching forms was a 5.9× speedup on the
/// 300×900×300 worker product (see EXPERIMENTS.md §Perf).
fn matmul_blocked_range(
    a: &Matrix,
    b: &Matrix,
    c: &mut Matrix,
    row0: usize,
    row1: usize,
    opts: MatmulOpts,
) {
    let (k, n) = (a.cols(), b.cols());
    let (tm, tk, tn) = (opts.tile_m, opts.tile_k, opts.tile_n);
    // Row-major pack of the current (tk × tn) tile of B keeps the AXPY
    // source rows contiguous and cache-resident.
    let mut bpack = vec![0.0f64; tk * tn];
    let mut jb = 0;
    while jb < n {
        let nb = tn.min(n - jb);
        let mut pb = 0;
        while pb < k {
            let kb = tk.min(k - pb);
            for p in 0..kb {
                let brow = &b.row(pb + p)[jb..jb + nb];
                bpack[p * nb..p * nb + nb].copy_from_slice(brow);
            }
            let mut ib = row0;
            while ib < row1 {
                let mb = tm.min(row1 - ib);
                // Register-blocked fast path over full 8×8 sub-tiles.
                let mut i = 0;
                while i + MR <= mb {
                    let mut j0 = 0;
                    while j0 + NR <= nb {
                        microkernel_8x8(a, c, &bpack, nb, ib + i, j0, jb, pb, kb);
                        j0 += NR;
                    }
                    // column tail handled by the generic path below for
                    // these rows
                    if j0 < nb {
                        for r in 0..MR {
                            let arow = &a.row(ib + i + r)[pb..pb + kb];
                            let crow = &mut c.row_mut(ib + i + r)[jb + j0..jb + nb];
                            for (p, &av) in arow.iter().enumerate() {
                                let brow = &bpack[p * nb + j0..p * nb + nb];
                                for (cv, &bv) in crow.iter_mut().zip(brow) {
                                    *cv += av * bv;
                                }
                            }
                        }
                    }
                    i += MR;
                }
                // generic tail: broadcast-AXPY rows
                for i in i..mb {
                    let arow = &a.row(ib + i)[pb..pb + kb];
                    let crow = &mut c.row_mut(ib + i)[jb..jb + nb];
                    let mut p = 0;
                    while p + 3 < kb {
                        let (a0, a1, a2, a3) =
                            (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
                        let b0 = &bpack[p * nb..p * nb + nb];
                        let b1 = &bpack[(p + 1) * nb..(p + 1) * nb + nb];
                        let b2 = &bpack[(p + 2) * nb..(p + 2) * nb + nb];
                        let b3 = &bpack[(p + 3) * nb..(p + 3) * nb + nb];
                        for j in 0..nb {
                            crow[j] +=
                                a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                        }
                        p += 4;
                    }
                    while p < kb {
                        let a0 = arow[p];
                        let b0 = &bpack[p * nb..p * nb + nb];
                        for j in 0..nb {
                            crow[j] += a0 * b0[j];
                        }
                        p += 1;
                    }
                }
                ib += mb;
            }
            pb += kb;
        }
        jb += nb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::util::prop::{gen, prop_check, PropConfig};

    #[test]
    fn known_product() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::seed_from(1);
        let a = Matrix::randn(17, 17, 0.0, 1.0, &mut rng);
        let c = matmul(&a, &Matrix::eye(17));
        assert!(c.allclose(&a, 1e-12));
    }

    #[test]
    fn blocked_matches_naive_odd_shapes() {
        let mut rng = Pcg64::seed_from(2);
        for &(m, k, n) in &[(1, 1, 1), (3, 70, 5), (65, 127, 33), (130, 64, 129)] {
            let a = Matrix::randn(m, k, 0.0, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 0.0, 1.0, &mut rng);
            let opts = MatmulOpts { naive_below: 0, threads: 1, ..Default::default() };
            let c1 = matmul_with(&a, &b, opts);
            let c2 = matmul_naive(&a, &b);
            assert!(c1.allclose(&c2, 1e-10), "mismatch at {m}x{k}x{n}");
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Pcg64::seed_from(3);
        let a = Matrix::randn(200, 150, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(150, 180, 0.0, 1.0, &mut rng);
        let serial = matmul_with(&a, &b, MatmulOpts { threads: 1, ..Default::default() });
        let opts = MatmulOpts { threads: 4, naive_below: 0, ..Default::default() };
        let par = matmul_with(&a, &b, opts);
        assert!(serial.allclose(&par, 1e-10));
    }

    #[test]
    fn property_random_shapes_match_naive() {
        prop_check("matmul≡naive", PropConfig { cases: 25, seed: 0xABCD }, |rng, _| {
            let m = gen::usize_in(rng, 1, 40);
            let k = gen::usize_in(rng, 1, 40);
            let n = gen::usize_in(rng, 1, 40);
            let a = Matrix::randn(m, k, 0.0, 1.0, rng);
            let b = Matrix::randn(k, n, 0.0, 1.0, rng);
            let opts = MatmulOpts {
                tile_m: gen::usize_in(rng, 1, 16),
                tile_k: gen::usize_in(rng, 1, 16),
                tile_n: gen::usize_in(rng, 1, 16),
                threads: gen::usize_in(rng, 1, 4),
                naive_below: 0,
            };
            let c1 = matmul_with(&a, &b, opts);
            let c2 = matmul_naive(&a, &b);
            if c1.allclose(&c2, 1e-9) {
                Ok(())
            } else {
                Err(format!("mismatch for {m}x{k}x{n} tiles {opts:?}"))
            }
        });
    }

    #[test]
    fn matmul_distributes_over_block_sums() {
        // Σ_m A_m B_m == A·B for the c×r partitioning — the identity the
        // whole c×r paradigm rests on (paper Fig. 4).
        let mut rng = Pcg64::seed_from(4);
        let a = Matrix::randn(12, 9, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(9, 10, 0.0, 1.0, &mut rng);
        let full = matmul(&a, &b);
        let a_parts = a.split_cols(3);
        let b_parts = b.split_rows(3);
        let mut acc = Matrix::zeros(12, 10);
        for (am, bm) in a_parts.iter().zip(b_parts.iter()) {
            acc.axpy(1.0, &matmul(am, bm));
        }
        assert!(acc.allclose(&full, 1e-10));
    }
}
