//! Dense linear algebra substrate: the `Matrix` type, blocked/parallel
//! matrix multiplication (the native execution engine's compute), block
//! concatenation/extraction used by the partitioners, Frobenius norms
//! used for importance classification, and LU-based solvers used by the
//! RLC decoders.

mod matmul;
mod matrix;
mod solve;

pub use matmul::{matmul, matmul_into, matmul_naive, matmul_with, MatmulOpts};
pub use matrix::Matrix;
pub use solve::{lu_solve, rank, solve_least_squares, Absorption, Eliminator};
