//! `uepmm` — command-line launcher for the UEP coded-matmul system.
//!
//! ```text
//! uepmm exp <name|all> [--out results] [--trials N] [--full] [--seed S]
//! uepmm list                      # available experiments
//! uepmm serve [...]               # cluster coordinator (TCP or loopback)
//! uepmm worker [...]              # cluster worker agent (TCP)
//! uepmm matmul [...]              # one coded multiplication (native/pjrt)
//! ```

use std::path::PathBuf;
use std::time::{Duration, Instant};

use uepmm::cluster::{
    spawn_loopback_workers, ClusterConfig, ClusterServer, CodingConfig,
    DeadlineMode, LoopbackTransport, MatmulRequest, TcpConn, TcpTransport,
    Transport, WorkerConfig,
};
use uepmm::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
use uepmm::config::SyntheticSpec;
use uepmm::coordinator::{Coordinator, Plan};
use uepmm::experiments::{self, ExpContext};
use uepmm::latency::LatencyModel;
use uepmm::rng::Pcg64;
use uepmm::runtime::{engine_by_name, NativeEngine, PjrtEngine};
use uepmm::sim::StragglerSim;
use uepmm::util::cli::Command;
use uepmm::util::pool::available_parallelism;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> anyhow::Result<()> {
    let Some(sub) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "list" => {
            println!("experiments:");
            for (name, desc, _) in experiments::registry() {
                println!("  {name:<18} {desc}");
            }
            Ok(())
        }
        "exp" => cmd_exp(rest),
        "serve" => cmd_serve(rest),
        "worker" => cmd_worker(rest),
        "matmul" => cmd_matmul(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try `uepmm help`)"),
    }
}

fn print_usage() {
    println!(
        "uepmm — straggler mitigation through UEP codes for distributed \
         approximate matrix multiplication\n\n\
         subcommands:\n  \
         exp <name|all>   reproduce a paper figure/table (see `uepmm list`)\n  \
         list             list available experiments\n  \
         matmul           run one coded approximate multiplication\n  \
         serve            cluster coordinator: serve a request stream over\n  \
                          TCP workers (or --loopback in-process workers)\n  \
         worker           cluster worker agent: connect to a coordinator\n  \
         help             this message"
    );
}

fn cmd_exp(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("exp", "reproduce a paper figure/table")
        .opt("out", "results", "output directory for CSVs")
        .opt("trials", "400", "Monte-Carlo trials per configuration")
        .opt("seed", "2021", "base RNG seed")
        .opt("threads", "0", "worker threads (0 = all cores)")
        .flag("full", "paper-scale sizes (slower)");
    let parsed = cmd.parse(rest)?;
    let name = parsed
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let threads = parsed.get_usize("threads")?;
    let ctx = ExpContext {
        out: PathBuf::from(parsed.get_str("out")),
        trials: parsed.get_usize("trials")?,
        full: parsed.get_bool("full"),
        seed: parsed.get_u64("seed")?,
        threads: if threads == 0 { available_parallelism() } else { threads },
    };
    experiments::run(&name, &ctx)
}

fn parse_code(kind: &str, gamma: &WindowPolynomial) -> anyhow::Result<CodeSpec> {
    Ok(match kind {
        "uncoded" => CodeSpec::stacked(CodeKind::Uncoded),
        "rep" => CodeSpec::stacked(CodeKind::Repetition),
        "mds" => CodeSpec::stacked(CodeKind::Mds),
        "now" => CodeSpec::stacked(CodeKind::NowUep(gamma.clone())),
        "ew" => CodeSpec::stacked(CodeKind::EwUep(gamma.clone())),
        "now-rank1" => {
            CodeSpec::new(CodeKind::NowUep(gamma.clone()), EncodeStyle::RankOne)
        }
        "ew-rank1" => {
            CodeSpec::new(CodeKind::EwUep(gamma.clone()), EncodeStyle::RankOne)
        }
        other => anyhow::bail!("unknown code '{other}'"),
    })
}

fn cmd_matmul(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("matmul", "run one coded approximate multiplication")
        .opt("code", "ew", "uncoded|rep|mds|now|ew|now-rank1|ew-rank1")
        .opt("paradigm", "rxc", "rxc|cxr")
        .opt("workers", "15", "number of workers W")
        .opt("tmax", "1.0", "deadline T_max")
        .opt("lambda", "1.0", "exponential latency rate")
        .opt("seed", "1", "RNG seed")
        .opt("scale", "6", "matrix size divisor vs the paper (1 = full)")
        .opt("engine", "native", "native|pjrt")
        .opt("artifacts", "artifacts", "artifact dir for the pjrt engine");
    let a = cmd.parse(rest)?;
    let mut spec = match a.get_str("paradigm") {
        "rxc" => SyntheticSpec::fig9_rxc(),
        "cxr" => SyntheticSpec::fig9_cxr(),
        other => anyhow::bail!("unknown paradigm '{other}'"),
    }
    .scaled(a.get_usize("scale")?);
    spec.workers = a.get_usize("workers")?;
    spec.latency = LatencyModel::exp(a.get_f64("lambda")?);
    spec.t_max = a.get_f64("tmax")?;
    let code = parse_code(a.get_str("code"), &spec.gamma)?;

    let mut rng = Pcg64::seed_from(a.get_u64("seed")?);
    let (ma, mb) = spec.sample_matrices(&mut rng);
    let plan = Plan::build_with_classes(
        &spec.part,
        code,
        spec.class_map(),
        spec.workers,
        &ma,
        &mb,
        &mut rng,
    )?;
    let sim = StragglerSim::new(spec.workers, spec.latency.clone(), spec.omega());
    let arrivals = sim.sample_arrivals(&mut rng);
    let outcome = match a.get_str("engine") {
        "native" => Coordinator::new(NativeEngine::default())
            .run(&plan, &arrivals, spec.t_max)?,
        "pjrt" => {
            let engine = PjrtEngine::from_artifacts(a.get_str("artifacts"))?;
            println!("pjrt platform: {}", engine.platform());
            Coordinator::new(engine).run(&plan, &arrivals, spec.t_max)?
        }
        other => anyhow::bail!("unknown engine '{other}'"),
    };
    println!(
        "received {}/{} packets by T_max={}, recovered {}/{} sub-products",
        outcome.received,
        spec.workers,
        spec.t_max,
        outcome.recovered,
        spec.part.num_products()
    );
    println!("per-class recovery: {:?}", outcome.per_class_recovered);
    println!("normalized loss ‖C−Ĉ‖²/‖C‖² = {:.6}", outcome.normalized_loss);
    Ok(())
}

fn cmd_serve(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("serve", "cluster coordinator serving a request stream")
        .opt("listen", "127.0.0.1:7077", "TCP listen address")
        .flag("loopback", "run in-process loopback workers instead of TCP")
        .opt("threads", "0", "loopback worker threads (0 = all cores)")
        .opt("min-workers", "2", "TCP: workers to wait for before serving")
        .opt("accept-timeout", "60", "seconds to wait for worker registration")
        .opt("code", "ew", "uncoded|rep|mds|now|ew|now-rank1|ew-rank1")
        .opt("workers", "15", "coded packets (jobs) per request")
        .opt("requests", "6", "number of multiplication requests")
        .opt("tmax", "1.0", "per-request deadline(s), comma list cycled")
        .opt("time-scale", "0.05", "wall seconds per virtual time unit")
        .opt(
            "latency",
            "exp:1.0",
            "injected straggle model for --loopback (exp:λ|det:t|sexp:s:λ|pareto:x:α)",
        )
        .opt("matrices", "2", "distinct A matrices cycled through the stream")
        .opt("scale", "10", "matrix size divisor vs the paper")
        .opt("seed", "1", "RNG seed");
    let a = cmd.parse(rest)?;
    let loopback = a.get_bool("loopback");
    let mut spec = SyntheticSpec::fig9_rxc().scaled(a.get_usize("scale")?);
    spec.workers = a.get_usize("workers")?;
    let code = parse_code(a.get_str("code"), &spec.gamma)?;
    let time_scale = a.get_f64("time-scale")?;
    anyhow::ensure!(time_scale > 0.0, "--time-scale must be > 0");
    let tmaxes = a.get_f64_list("tmax")?;
    anyhow::ensure!(!tmaxes.is_empty(), "--tmax needs at least one deadline");
    let requests = a.get_usize("requests")?;
    let n_matrices = a.get_usize("matrices")?.max(1);
    let mut rng = Pcg64::seed_from(a.get_u64("seed")?);

    // The loopback path injects seeded virtual delays and filters on the
    // virtual deadline (deterministic); the TCP path lets workers and the
    // transport produce real timing and cuts off at the wall deadline.
    let coding = CodingConfig {
        part: spec.part.clone(),
        spec: code,
        cm: spec.class_map(),
        workers: spec.workers,
        latency: if loopback { Some(a.get::<LatencyModel>("latency")?) } else { None },
    };
    let cluster_cfg = ClusterConfig {
        deadline: if loopback { DeadlineMode::Virtual } else { DeadlineMode::Wall },
        time_scale,
        ..ClusterConfig::default()
    };
    let mut server = ClusterServer::new(cluster_cfg);
    let accept_timeout = Duration::from_secs_f64(a.get_f64("accept-timeout")?);

    let mut loopback_handles = Vec::new();
    let expected = if loopback {
        let threads = match a.get_usize("threads")? {
            0 => available_parallelism(),
            t => t,
        };
        let (mut transport, dialer) = LoopbackTransport::new();
        loopback_handles = spawn_loopback_workers(
            &dialer,
            threads,
            &WorkerConfig {
                name: "loop".to_string(),
                omega: coding.omega(),
                time_scale,
                ..WorkerConfig::default()
            },
        );
        drop(dialer);
        let joined = server.accept_workers(&mut transport, threads, accept_timeout)?;
        anyhow::ensure!(joined == threads, "only {joined}/{threads} loopback workers");
        threads
    } else {
        let mut transport = TcpTransport::bind(a.get_str("listen"))?;
        let want = a.get_usize("min-workers")?.max(1);
        println!(
            "coordinator listening on {} — waiting for {want} workers",
            transport.local_addr()
        );
        let joined = server.accept_workers(&mut transport, want, accept_timeout)?;
        anyhow::ensure!(
            joined >= want,
            "only {joined}/{want} workers registered within the accept timeout"
        );
        want
    };
    for w in server.worker_info() {
        println!("worker {} registered: {}", w.id, w.name);
    }
    println!(
        "serving {requests} requests: {} coded jobs over {expected} workers, \
         Ω={:.3}, deadlines {:?}, {} deadline mode",
        coding.workers,
        coding.omega(),
        tmaxes,
        if loopback { "virtual" } else { "wall" },
    );

    // Pre-sample the distinct A matrices of the stream (id = index).
    let a_mats: Vec<_> = (0..n_matrices).map(|_| spec.sample_a(&mut rng)).collect();
    let (mut received, mut late, mut missing, mut recovered) = (0, 0, 0, 0);
    for req in 0..requests {
        let a_id = (req % n_matrices) as u64;
        let b = spec.sample_b(&mut rng);
        let out = server.serve_request(
            &coding,
            &MatmulRequest {
                a_id,
                a: a_mats[a_id as usize].clone(),
                b,
                t_max: tmaxes[req % tmaxes.len()],
                // demo/CI stream: score every request so the loss column
                // is meaningful (production would pass false)
                score: true,
            },
            &mut rng,
        )?;
        println!(
            "request {req} (A#{a_id}, T_max={}): {} arrivals ({} late, {} missing), \
             recovered {}/{}, loss {:.4}, cache {}, wall {:?}",
            tmaxes[req % tmaxes.len()],
            out.outcome.received,
            out.late,
            out.missing(),
            out.outcome.recovered,
            coding.part.num_products(),
            out.outcome.normalized_loss,
            if out.cache_hit == Some(true) { "hit" } else { "miss" },
            out.wall,
        );
        received += out.outcome.received;
        late += out.late;
        missing += out.missing();
        recovered += out.outcome.recovered;
        let evicted = server.heartbeat();
        for id in evicted {
            println!("worker {id} evicted (missed heartbeat)");
        }
        anyhow::ensure!(server.live_workers() > 0, "all workers gone; aborting stream");
    }
    let cache = server.cache_stats();
    println!(
        "stream done: requests={requests} received={received} late={late} \
         missing={missing} recovered_total={recovered} cache_hits={} \
         cache_misses={} cache_evictions={}",
        cache.hits, cache.misses, cache.evictions
    );
    // drain until every worker closes its side: a backlogged straggler
    // must read the queued Shutdown before this process exits
    server.shutdown_graceful(Duration::from_secs(60));
    for h in loopback_handles {
        match h.join() {
            Ok(r) => {
                r?;
            }
            Err(_) => anyhow::bail!("loopback worker panicked"),
        }
    }
    println!("shutdown complete");
    Ok(())
}

fn cmd_worker(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("worker", "cluster worker agent")
        .opt("connect", "127.0.0.1:7077", "coordinator address")
        .opt("name", "", "worker name (default worker-<pid>)")
        .opt(
            "latency",
            "",
            "self-injected straggle model (empty = real timing only)",
        )
        .opt("omega", "1.0", "capacity scaling for self-injected delays")
        .opt("time-scale", "0.05", "wall seconds per virtual time unit")
        .opt("seed", "0", "delay-sampling RNG seed")
        .opt("engine", "native", "native|pjrt")
        .opt("artifacts", "artifacts", "artifact dir for the pjrt engine")
        .opt("retry", "15", "seconds to keep retrying the initial connect");
    let a = cmd.parse(rest)?;
    let name = match a.get_str("name") {
        "" => format!("worker-{}", std::process::id()),
        n => n.to_string(),
    };
    let latency = match a.get_str("latency") {
        "" => None,
        _ => Some(a.get::<LatencyModel>("latency")?),
    };
    let cfg = WorkerConfig {
        name: name.clone(),
        latency,
        omega: a.get_f64("omega")?,
        time_scale: a.get_f64("time-scale")?,
        seed: a.get_u64("seed")?,
    };
    let engine = engine_by_name(a.get_str("engine"), a.get_str("artifacts"))?;
    let addr = a.get_str("connect");
    let deadline = Instant::now() + Duration::from_secs_f64(a.get_f64("retry")?);
    let mut conn = loop {
        match TcpConn::connect(addr) {
            Ok(c) => break c,
            Err(e) => {
                if Instant::now() >= deadline {
                    anyhow::bail!("{name}: could not reach coordinator {addr}: {e}");
                }
                std::thread::sleep(Duration::from_millis(250));
            }
        }
    };
    println!("{name}: connected to {addr} (engine {})", engine.name());
    let stats = uepmm::cluster::run_worker(&mut conn, &engine, &cfg)?;
    println!(
        "{name}: done ({}): id={} jobs={} heartbeats={}",
        if stats.clean_shutdown { "clean shutdown" } else { "connection lost" },
        stats.worker_id,
        stats.jobs,
        stats.heartbeats,
    );
    Ok(())
}
