//! `uepmm` — command-line launcher for the UEP coded-matmul system.
//!
//! ```text
//! uepmm exp <name|all> [--out results] [--trials N] [--full] [--seed S]
//! uepmm list                      # available experiments
//! uepmm serve [...]               # threaded coordinator demo
//! uepmm matmul [...]              # one coded multiplication (native/pjrt)
//! ```

use std::path::PathBuf;

use uepmm::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
use uepmm::config::SyntheticSpec;
use uepmm::coordinator::{run_service, Coordinator, Plan, ServiceConfig};
use uepmm::experiments::{self, ExpContext};
use uepmm::latency::LatencyModel;
use uepmm::rng::Pcg64;
use uepmm::runtime::{NativeEngine, PjrtEngine};
use uepmm::sim::StragglerSim;
use uepmm::util::cli::Command;
use uepmm::util::pool::available_parallelism;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> anyhow::Result<()> {
    let Some(sub) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "list" => {
            println!("experiments:");
            for (name, desc, _) in experiments::registry() {
                println!("  {name:<18} {desc}");
            }
            Ok(())
        }
        "exp" => cmd_exp(rest),
        "serve" => cmd_serve(rest),
        "matmul" => cmd_matmul(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try `uepmm help`)"),
    }
}

fn print_usage() {
    println!(
        "uepmm — straggler mitigation through UEP codes for distributed \
         approximate matrix multiplication\n\n\
         subcommands:\n  \
         exp <name|all>   reproduce a paper figure/table (see `uepmm list`)\n  \
         list             list available experiments\n  \
         matmul           run one coded approximate multiplication\n  \
         serve            threaded coordinator demo (wall-clock deadline)\n  \
         help             this message"
    );
}

fn cmd_exp(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("exp", "reproduce a paper figure/table")
        .opt("out", "results", "output directory for CSVs")
        .opt("trials", "400", "Monte-Carlo trials per configuration")
        .opt("seed", "2021", "base RNG seed")
        .opt("threads", "0", "worker threads (0 = all cores)")
        .flag("full", "paper-scale sizes (slower)");
    let parsed = cmd.parse(rest)?;
    let name = parsed
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let threads = parsed.get_usize("threads")?;
    let ctx = ExpContext {
        out: PathBuf::from(parsed.get_str("out")),
        trials: parsed.get_usize("trials")?,
        full: parsed.get_bool("full"),
        seed: parsed.get_u64("seed")?,
        threads: if threads == 0 { available_parallelism() } else { threads },
    };
    experiments::run(&name, &ctx)
}

fn parse_code(kind: &str, gamma: &WindowPolynomial) -> anyhow::Result<CodeSpec> {
    Ok(match kind {
        "uncoded" => CodeSpec::stacked(CodeKind::Uncoded),
        "rep" => CodeSpec::stacked(CodeKind::Repetition),
        "mds" => CodeSpec::stacked(CodeKind::Mds),
        "now" => CodeSpec::stacked(CodeKind::NowUep(gamma.clone())),
        "ew" => CodeSpec::stacked(CodeKind::EwUep(gamma.clone())),
        "now-rank1" => {
            CodeSpec::new(CodeKind::NowUep(gamma.clone()), EncodeStyle::RankOne)
        }
        "ew-rank1" => {
            CodeSpec::new(CodeKind::EwUep(gamma.clone()), EncodeStyle::RankOne)
        }
        other => anyhow::bail!("unknown code '{other}'"),
    })
}

fn cmd_matmul(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("matmul", "run one coded approximate multiplication")
        .opt("code", "ew", "uncoded|rep|mds|now|ew|now-rank1|ew-rank1")
        .opt("paradigm", "rxc", "rxc|cxr")
        .opt("workers", "15", "number of workers W")
        .opt("tmax", "1.0", "deadline T_max")
        .opt("lambda", "1.0", "exponential latency rate")
        .opt("seed", "1", "RNG seed")
        .opt("scale", "6", "matrix size divisor vs the paper (1 = full)")
        .opt("engine", "native", "native|pjrt")
        .opt("artifacts", "artifacts", "artifact dir for the pjrt engine");
    let a = cmd.parse(rest)?;
    let mut spec = match a.get_str("paradigm") {
        "rxc" => SyntheticSpec::fig9_rxc(),
        "cxr" => SyntheticSpec::fig9_cxr(),
        other => anyhow::bail!("unknown paradigm '{other}'"),
    }
    .scaled(a.get_usize("scale")?);
    spec.workers = a.get_usize("workers")?;
    spec.latency = LatencyModel::exp(a.get_f64("lambda")?);
    spec.t_max = a.get_f64("tmax")?;
    let code = parse_code(a.get_str("code"), &spec.gamma)?;

    let mut rng = Pcg64::seed_from(a.get_u64("seed")?);
    let (ma, mb) = spec.sample_matrices(&mut rng);
    let plan = Plan::build_with_classes(
        &spec.part,
        code,
        spec.class_map(),
        spec.workers,
        &ma,
        &mb,
        &mut rng,
    )?;
    let sim = StragglerSim::new(spec.workers, spec.latency.clone(), spec.omega());
    let arrivals = sim.sample_arrivals(&mut rng);
    let outcome = match a.get_str("engine") {
        "native" => Coordinator::new(NativeEngine::default())
            .run(&plan, &arrivals, spec.t_max)?,
        "pjrt" => {
            let engine = PjrtEngine::from_artifacts(a.get_str("artifacts"))?;
            println!("pjrt platform: {}", engine.platform());
            Coordinator::new(engine).run(&plan, &arrivals, spec.t_max)?
        }
        other => anyhow::bail!("unknown engine '{other}'"),
    };
    println!(
        "received {}/{} packets by T_max={}, recovered {}/{} sub-products",
        outcome.received,
        spec.workers,
        spec.t_max,
        outcome.recovered,
        spec.part.num_products()
    );
    println!("per-class recovery: {:?}", outcome.per_class_recovered);
    println!("normalized loss ‖C−Ĉ‖²/‖C‖² = {:.6}", outcome.normalized_loss);
    Ok(())
}

fn cmd_serve(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("serve", "threaded coordinator demo")
        .opt("code", "ew", "uncoded|rep|mds|now|ew")
        .opt("workers", "15", "worker count")
        .opt("tmax", "1.0", "virtual deadline")
        .opt("lambda", "1.0", "exponential latency rate")
        .opt("requests", "5", "number of multiplication requests")
        .opt("time-scale", "0.02", "wall seconds per virtual time unit")
        .opt("seed", "1", "RNG seed")
        .opt("scale", "10", "matrix size divisor vs the paper");
    let a = cmd.parse(rest)?;
    let mut spec = SyntheticSpec::fig9_rxc().scaled(a.get_usize("scale")?);
    spec.workers = a.get_usize("workers")?;
    let code = parse_code(a.get_str("code"), &spec.gamma)?;
    let mut rng = Pcg64::seed_from(a.get_u64("seed")?);
    let cfg = ServiceConfig {
        latency: LatencyModel::exp(a.get_f64("lambda")?),
        omega: spec.omega(),
        t_max: a.get_f64("tmax")?,
        time_scale: a.get_f64("time-scale")?,
        threads: available_parallelism(),
    };
    println!(
        "serving {} requests: {} workers, deadline {}, Ω={:.3}",
        a.get_usize("requests")?,
        spec.workers,
        cfg.t_max,
        cfg.omega
    );
    for req in 0..a.get_usize("requests")? {
        let (ma, mb) = spec.sample_matrices(&mut rng);
        let plan = Plan::build_with_classes(
            &spec.part,
            code.clone(),
            spec.class_map(),
            spec.workers,
            &ma,
            &mb,
            &mut rng,
        )?;
        let out = run_service(&plan, &cfg, &mut rng)?;
        println!(
            "request {req}: {} arrivals ({} late), recovered {}/9, loss {:.4}, wall {:?}",
            out.outcome.received,
            out.late,
            out.outcome.recovered,
            out.outcome.normalized_loss,
            out.wall
        );
    }
    Ok(())
}
