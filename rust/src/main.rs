//! `uepmm` — command-line launcher for the UEP coded-matmul system.
//!
//! ```text
//! uepmm exp <name|all> [--out results] [--trials N] [--full] [--seed S]
//! uepmm list                      # available experiments
//! uepmm serve [...]               # cluster coordinator (TCP or loopback)
//! uepmm serve --service [...]     # multi-tenant serve plane (wire v6)
//! uepmm worker [...]              # cluster worker agent (TCP)
//! uepmm client [...]              # remote client of a serve plane
//! uepmm matmul [...]              # one coded multiplication (native/pjrt)
//! ```
//!
//! Every serving subcommand drives the unified client API
//! (`uepmm::api::Session` over a `Backend`): `matmul` uses the
//! in-process backend, `serve` the cluster backend (loopback worker
//! threads or TCP worker processes), and both surface the anytime
//! progress stream alongside the final outcome.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use uepmm::api::{
    ClusterBackend, InProcessBackend, ReplanPolicy, Request, RunReport, Session,
    SessionBuilder, UepmmError,
};
use uepmm::cluster::{
    ChaosConn, ClusterConfig, ClusterServer, DeadlineMode, FaultPlan, ServePlane,
    ServiceConfig, TcpConn, TcpTransport, Transport, WorkerConfig,
};
use uepmm::coding::{CodeKind, CodeSpec, RatelessSpec, WindowPolynomial};
use uepmm::config::SyntheticSpec;
use uepmm::experiments::{self, ExpContext};
use uepmm::latency::LatencyModel;
use uepmm::rng::Pcg64;
use uepmm::runtime::{engine_by_name, ExecEngine};
use uepmm::util::cli::{Args, Command};
use uepmm::util::pool::available_parallelism;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> anyhow::Result<()> {
    let Some(sub) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "list" => {
            println!("experiments:");
            for (name, desc, _) in experiments::registry() {
                println!("  {name:<18} {desc}");
            }
            Ok(())
        }
        "exp" => cmd_exp(rest),
        "serve" => cmd_serve(rest),
        "worker" => cmd_worker(rest),
        "client" => cmd_client(rest),
        "matmul" => cmd_matmul(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try `uepmm help`)"),
    }
}

fn print_usage() {
    println!(
        "uepmm — straggler mitigation through UEP codes for distributed \
         approximate matrix multiplication\n\n\
         subcommands:\n  \
         exp <name|all>   reproduce a paper figure/table (see `uepmm list`)\n  \
         list             list available experiments\n  \
         matmul           run one coded approximate multiplication\n  \
         serve            cluster coordinator: serve a request stream over\n  \
                          TCP workers (or --loopback in-process workers);\n  \
                          --service starts the multi-tenant serve plane\n  \
         worker           cluster worker agent: connect to a coordinator\n  \
         client           remote client of a multi-tenant serve plane\n  \
         help             this message"
    );
}

// ===================================================== shared option sets
//
// Each subcommand used to hand-roll its flag list and accessors; the
// shared sets below are declared once and parsed once through the typed
// `Args::get<T>` accessor, so a flag's name, default, and type live in
// exactly one place.

/// Seeding + thread-count flags (every subcommand).
struct SharedOpts {
    seed: u64,
    threads: usize,
}

impl SharedOpts {
    fn declare(cmd: Command, seed_default: &'static str) -> Command {
        cmd.opt("seed", seed_default, "base RNG seed")
            .opt("threads", "0", "worker threads (0 = all cores)")
    }

    fn parse(a: &Args) -> anyhow::Result<SharedOpts> {
        Ok(SharedOpts { seed: a.get("seed")?, threads: a.get("threads")? })
    }

    fn threads(&self) -> usize {
        if self.threads == 0 {
            available_parallelism()
        } else {
            self.threads
        }
    }
}

/// Code/geometry/deadline flags of a coded run (`matmul`, `serve`).
struct CodedOpts {
    code: String,
    workers: usize,
    tmax: Vec<f64>,
    scale: usize,
}

impl CodedOpts {
    fn declare(cmd: Command, scale_default: &'static str) -> Command {
        cmd.opt(
            "code",
            "ew",
            "uncoded|rep|mds|now|ew|now-rank1|ew-rank1|rateless[:delta=D,c=C]",
        )
            .opt("workers", "15", "coded packets (jobs) per request")
            .opt("tmax", "1.0", "deadline(s) T_max, comma list cycled")
            .opt("scale", scale_default, "matrix size divisor vs the paper")
    }

    fn parse(a: &Args) -> anyhow::Result<CodedOpts> {
        let opts = CodedOpts {
            code: a.get_str("code").to_string(),
            workers: a.get("workers")?,
            tmax: a.get_f64_list("tmax")?,
            scale: a.get("scale")?,
        };
        anyhow::ensure!(!opts.tmax.is_empty(), "--tmax needs at least one deadline");
        Ok(opts)
    }

    /// Scale the synthetic preset and resolve the code spec against its
    /// window polynomial.
    fn apply(&self, base: SyntheticSpec) -> anyhow::Result<(SyntheticSpec, CodeSpec)> {
        let mut spec = base.scaled(self.scale);
        spec.workers = self.workers;
        let code = parse_code(&self.code, &spec.gamma)?;
        Ok((spec, code))
    }
}

/// Straggle-model + pacing flags (`matmul`, `serve`, `worker`).
struct TimingOpts {
    latency: Option<LatencyModel>,
    time_scale: f64,
}

impl TimingOpts {
    fn declare(
        cmd: Command,
        latency_default: &'static str,
        latency_help: &'static str,
    ) -> Command {
        cmd.opt("latency", latency_default, latency_help)
            .opt("time-scale", "0.05", "wall seconds per virtual time unit")
    }

    fn parse(a: &Args) -> anyhow::Result<TimingOpts> {
        let latency = match a.get_str("latency") {
            "" => None,
            _ => Some(a.get::<LatencyModel>("latency")?),
        };
        Ok(TimingOpts { latency, time_scale: a.get("time-scale")? })
    }
}

/// Straggle-adaptive planning flags (`matmul`, `serve`).
struct AdaptiveOpts {
    adaptive: bool,
    replan_every: usize,
}

impl AdaptiveOpts {
    fn declare(cmd: Command) -> Command {
        cmd.flag(
            "adaptive",
            "fit a latency model from observed timings and re-optimize Γ \
             (NOW/EW codes only)",
        )
        .opt("replan-every", "4", "completed requests between replans")
    }

    fn parse(a: &Args) -> anyhow::Result<AdaptiveOpts> {
        Ok(AdaptiveOpts {
            adaptive: a.get_bool("adaptive"),
            replan_every: a.get("replan-every")?,
        })
    }

    /// Attach the adaptive policy to a session builder when enabled.
    fn apply(&self, builder: SessionBuilder) -> SessionBuilder {
        if self.adaptive {
            builder.adaptive(ReplanPolicy::every(self.replan_every))
        } else {
            builder
        }
    }

    /// Print the replan events a request's progress stream carried.
    fn print_replans(report: &RunReport) {
        let fmt_gamma = |g: &[f64]| {
            let parts: Vec<String> = g.iter().map(|x| format!("{x:.3}")).collect();
            format!("[{}]", parts.join(", "))
        };
        for ev in report.progress.replans() {
            println!(
                "replan after {} requests ({} samples): fitted {}, \
                 Γ {} → {}, predicted norm-loss {:.4} → {:.4}{}",
                ev.after_requests,
                ev.samples,
                ev.model,
                fmt_gamma(&ev.gamma_before),
                fmt_gamma(&ev.gamma_after),
                ev.predicted_before,
                ev.predicted_after,
                if ev.classes_changed { " (classes re-banded)" } else { "" },
            );
        }
    }
}

/// Execution-engine flags (`matmul`, `worker`).
struct EngineOpts {
    engine: String,
    artifacts: String,
}

impl EngineOpts {
    fn declare(cmd: Command) -> Command {
        cmd.opt("engine", "native", "native|pjrt")
            .opt("artifacts", "artifacts", "artifact dir for the pjrt engine")
    }

    fn parse(a: &Args) -> anyhow::Result<EngineOpts> {
        Ok(EngineOpts {
            engine: a.get_str("engine").to_string(),
            artifacts: a.get_str("artifacts").to_string(),
        })
    }

    fn build(&self) -> anyhow::Result<Box<dyn ExecEngine>> {
        engine_by_name(&self.engine, &self.artifacts)
    }
}

/// Parse `--code` through [`CodeSpec`]'s `FromStr` and substitute the
/// preset's window polynomial for the parser's Table III default (the
/// rateless family keeps its parsed `δ`/`c` knobs and swaps only `Γ`).
fn parse_code(kind: &str, gamma: &WindowPolynomial) -> anyhow::Result<CodeSpec> {
    let mut spec: CodeSpec = kind.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    spec.kind = match spec.kind {
        CodeKind::NowUep(_) => CodeKind::NowUep(gamma.clone()),
        CodeKind::EwUep(_) => CodeKind::EwUep(gamma.clone()),
        CodeKind::Rateless(r) => {
            CodeKind::Rateless(RatelessSpec::new(r.delta, r.c, gamma.clone()))
        }
        k => k,
    };
    Ok(spec)
}

// ============================================================ subcommands

fn cmd_exp(rest: &[String]) -> anyhow::Result<()> {
    let cmd = SharedOpts::declare(
        Command::new("exp", "reproduce a paper figure/table")
            .opt("out", "results", "output directory for CSVs")
            .opt("trials", "400", "Monte-Carlo trials per configuration")
            .flag("full", "paper-scale sizes (slower)"),
        "2021",
    );
    let parsed = cmd.parse(rest)?;
    let shared = SharedOpts::parse(&parsed)?;
    let name = parsed
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let ctx = ExpContext {
        out: PathBuf::from(parsed.get_str("out")),
        trials: parsed.get("trials")?,
        full: parsed.get_bool("full"),
        seed: shared.seed,
        threads: shared.threads(),
    };
    experiments::run(&name, &ctx)
}

fn cmd_matmul(rest: &[String]) -> anyhow::Result<()> {
    let cmd = {
        let c = Command::new("matmul", "run one coded approximate multiplication")
            .opt("paradigm", "rxc", "rxc|cxr");
        let c = CodedOpts::declare(c, "6");
        let c = TimingOpts::declare(c, "exp:1.0", "straggle model for the virtual arrivals");
        let c = EngineOpts::declare(c);
        let c = AdaptiveOpts::declare(c);
        SharedOpts::declare(c, "1")
    };
    let a = cmd.parse(rest)?;
    let shared = SharedOpts::parse(&a)?;
    let coded = CodedOpts::parse(&a)?;
    let timing = TimingOpts::parse(&a)?;
    let engine = EngineOpts::parse(&a)?;
    let adaptive = AdaptiveOpts::parse(&a)?;
    let base = match a.get_str("paradigm") {
        "rxc" => SyntheticSpec::fig9_rxc(),
        "cxr" => SyntheticSpec::fig9_cxr(),
        other => anyhow::bail!("unknown paradigm '{other}'"),
    };
    let (spec, code) = coded.apply(base)?;
    let eng = engine.build()?;
    println!("engine: {}", eng.name());

    let builder = Session::builder()
        .partitioning(spec.part.clone())
        .code(code)
        .classes(spec.class_map())
        .workers(spec.workers)
        .latency(timing.latency.clone().unwrap_or_else(|| LatencyModel::exp(1.0)))
        .deadline(coded.tmax[0])
        .score(true)
        .seed(shared.seed)
        .backend(InProcessBackend::with_engine(eng));
    let mut session = adaptive.apply(builder).build()?;

    let mut mats = Pcg64::with_stream(shared.seed, 1);
    let (ma, mb) = spec.sample_matrices(&mut mats);
    let k = spec.part.num_products();
    // one request per deadline in the --tmax list: a served loss-vs-T_max
    // sweep (repeat requests reuse the cached encoding of A)
    for &t_max in &coded.tmax {
        let report = session
            .run(Request::new(0, ma.clone(), mb.clone()).deadline(t_max))?;
        AdaptiveOpts::print_replans(&report);
        if coded.tmax.len() == 1 {
            println!("anytime progress (one line per absorbed arrival):");
            for e in report.progress.events() {
                println!(
                    "  t={:<7.3} received {:>2}  recovered {:>2}/{k}  norm-loss {:.6}",
                    e.elapsed, e.received, e.recovered, e.normalized_loss
                );
            }
        }
        println!(
            "received {}/{} packets by T_max={}, recovered {}/{} sub-products",
            report.outcome.received,
            spec.workers,
            t_max,
            report.outcome.recovered,
            k
        );
        println!("per-class recovery: {:?}", report.outcome.per_class_recovered);
        if !report.worker_packets.is_empty() {
            let per: Vec<String> = report
                .worker_packets
                .iter()
                .map(|(id, c)| format!("w{id}:{c}"))
                .collect();
            println!("rateless packet credit: [{}]", per.join(", "));
        }
        println!(
            "normalized loss ‖C−Ĉ‖²/‖C‖² = {:.6}",
            report.outcome.normalized_loss
        );
    }
    if let Some(model) = session.fitted_latency() {
        println!(
            "fitted latency model after the sweep: {model} ({} replan(s))",
            session.replan_count()
        );
    }
    Ok(())
}

fn cmd_serve(rest: &[String]) -> anyhow::Result<()> {
    let cmd = {
        let c = Command::new("serve", "cluster coordinator serving a request stream")
            .opt("listen", "127.0.0.1:7077", "TCP listen address")
            .flag("loopback", "run in-process loopback workers instead of TCP")
            .opt("min-workers", "2", "TCP: workers to wait for before serving")
            .opt("accept-timeout", "60", "seconds to wait for worker registration")
            .opt("requests", "6", "number of multiplication requests")
            .opt("matrices", "2", "distinct A matrices cycled through the stream")
            .opt("heartbeat-secs", "2", "per-worker heartbeat ack timeout, seconds")
            .opt(
                "evict-after",
                "1",
                "consecutive missed heartbeats before a worker is evicted",
            )
            .flag("no-verify", "skip Freivalds verification of arriving results")
            .flag(
                "hetero",
                "heterogeneity-aware dispatch: plan slot assignment from \
                 per-worker scale estimates (service: weighted lane pick + \
                 DRR credit charging)",
            )
            .opt(
                "blocks",
                "3",
                "factor blocks per side (K = blocks²; raise for finer \
                 rateless packet credit)",
            )
            .flag(
                "service",
                "run the multi-tenant serve plane instead of the \
                 single-stream coordinator",
            )
            .opt("sessions", "3", "service: client sessions to serve, then exit")
            .opt("max-sessions", "8", "service: concurrent session cap")
            .opt("queue-depth", "4", "service: per-session outstanding requests")
            .opt("quota", "4", "service: per-session in-flight job quota")
            .opt("decode-shards", "2", "service: decode pool threads");
        let c = CodedOpts::declare(c, "10");
        let c = TimingOpts::declare(
            c,
            "exp:1.0",
            "injected straggle model for --loopback (exp:λ|det:t|sexp:s:λ|pareto:x:α)",
        );
        let c = AdaptiveOpts::declare(c);
        SharedOpts::declare(c, "1")
    };
    let a = cmd.parse(rest)?;
    if a.get_bool("service") {
        return run_service(&a);
    }
    let shared = SharedOpts::parse(&a)?;
    let coded = CodedOpts::parse(&a)?;
    let timing = TimingOpts::parse(&a)?;
    let adaptive = AdaptiveOpts::parse(&a)?;
    let loopback = a.get_bool("loopback");
    anyhow::ensure!(timing.time_scale > 0.0, "--time-scale must be > 0");
    let (mut spec, code) = coded.apply(SyntheticSpec::fig9_rxc())?;
    let blocks: usize = a.get("blocks")?;
    anyhow::ensure!(blocks >= 1, "--blocks must be >= 1");
    if blocks != 3 {
        spec = spec.with_blocks(blocks);
    }
    let rateless = matches!(code.kind, CodeKind::Rateless(_));
    let requests: usize = a.get("requests")?;
    let n_matrices = a.get::<usize>("matrices")?.max(1);
    let accept_timeout = Duration::from_secs_f64(a.get_f64("accept-timeout")?);
    let heartbeat_secs = a.get_f64("heartbeat-secs")?;
    anyhow::ensure!(heartbeat_secs > 0.0, "--heartbeat-secs must be > 0");
    let evict_after: u32 = a.get("evict-after")?;
    anyhow::ensure!(evict_after >= 1, "--evict-after must be >= 1");

    // The loopback path injects seeded virtual delays and filters on the
    // virtual deadline (deterministic); the TCP path lets workers and the
    // transport produce real timing and cuts off at the wall deadline.
    let cluster_cfg = ClusterConfig {
        deadline: if loopback { DeadlineMode::Virtual } else { DeadlineMode::Wall },
        time_scale: timing.time_scale,
        // the session owns the encoded-block cache
        cache_capacity: 0,
        heartbeat_timeout: Duration::from_secs_f64(heartbeat_secs),
        evict_after,
        verify: !a.get_bool("no-verify"),
        hetero_assign: a.get_bool("hetero"),
        ..ClusterConfig::default()
    };
    let (backend, expected) = if loopback {
        let threads = shared.threads();
        let backend = ClusterBackend::loopback(
            threads,
            cluster_cfg,
            WorkerConfig {
                name: "loop".to_string(),
                time_scale: timing.time_scale,
                ..WorkerConfig::default()
            },
            accept_timeout,
        )?;
        (backend, threads)
    } else {
        let mut transport = TcpTransport::bind(a.get_str("listen"))?;
        let want = a.get::<usize>("min-workers")?.max(1);
        println!(
            "coordinator listening on {} — waiting for {want} workers",
            transport.local_addr()
        );
        let mut server = ClusterServer::new(cluster_cfg);
        let joined = server.accept_workers(&mut transport, want, accept_timeout)?;
        anyhow::ensure!(
            joined >= want,
            "only {joined}/{want} workers registered within the accept timeout"
        );
        (ClusterBackend::from_server(server), want)
    };
    for w in backend.worker_info() {
        println!("worker {} registered: {}", w.id, w.name);
    }
    if rateless {
        // One rateless stream per live worker: required for the virtual
        // schedule replay, and the natural shape for wall self-pacing.
        spec.workers = expected;
    }

    let mut builder = Session::builder()
        .partitioning(spec.part.clone())
        .code(code)
        .classes(spec.class_map())
        .workers(spec.workers)
        .deadline(coded.tmax[0])
        // demo/CI stream: score every request so the loss column is
        // meaningful (production would leave scoring off)
        .score(true)
        .seed(shared.seed)
        .backend(backend);
    // Rateless pacing needs the session model even over TCP: the wall
    // server lets workers self-pace, but `prepare()` derives the stream
    // budgets from the model.
    if loopback || rateless {
        if let Some(model) = timing.latency.clone() {
            builder = builder.latency(model);
        }
    }
    builder = adaptive.apply(builder);
    let mut session = builder.build()?;
    println!(
        "serving {requests} requests: {} coded jobs over {expected} workers, \
         Ω={:.3}, deadlines {:?}, {} deadline mode",
        session.workers(),
        session.omega_value(),
        coded.tmax,
        if loopback { "virtual" } else { "wall" },
    );

    // Pre-sample the distinct A matrices of the stream (id = index).
    let mut mats = Pcg64::with_stream(shared.seed, 1);
    let a_mats: Vec<_> = (0..n_matrices).map(|_| spec.sample_a(&mut mats)).collect();
    let (mut received, mut late, mut missing, mut recovered) = (0, 0, 0, 0);
    let (mut retries, mut corrupt) = (0usize, 0usize);
    // Worst per-request partial credit: min over requests of the fewest
    // packets any contributing stream decoded (rateless runs only).
    let mut rateless_partial: Option<usize> = None;
    let (mut verify_failures, mut quarantined) = (0usize, 0usize);
    let (mut refinements, mut monotone) = (0usize, true);
    for req in 0..requests {
        let a_id = (req % n_matrices) as u64;
        let b = spec.sample_b(&mut mats);
        let t_max = coded.tmax[req % coded.tmax.len()];
        let out = session.run(
            Request::new(a_id, a_mats[a_id as usize].clone(), b).deadline(t_max),
        )?;
        AdaptiveOpts::print_replans(&out);
        println!(
            "request {req} (A#{a_id}, T_max={t_max}): {} arrivals ({} late, {} missing), \
             recovered {}/{}, {} retries, loss {:.4}, cache {}, {} refinements, wall {:?}",
            out.outcome.received,
            out.late,
            out.missing(),
            out.outcome.recovered,
            spec.part.num_products(),
            out.retries,
            out.outcome.normalized_loss,
            if out.cache_hit == Some(true) { "hit" } else { "miss" },
            out.progress.refinements(),
            out.wall,
        );
        if !out.worker_packets.is_empty() {
            let total: usize = out.worker_packets.iter().map(|(_, c)| *c).sum();
            let per: Vec<String> = out
                .worker_packets
                .iter()
                .map(|(id, c)| format!("w{id}:{c}"))
                .collect();
            let slowest =
                out.worker_packets.iter().map(|(_, c)| *c).min().unwrap_or(0);
            println!(
                "  rateless credit: {total} packets decoded [{}], \
                 slowest stream {slowest}",
                per.join(", ")
            );
            rateless_partial = Some(
                rateless_partial
                    .map_or(out.partial_packets, |p| p.min(out.partial_packets)),
            );
        }
        received += out.outcome.received;
        late += out.late;
        missing += out.missing();
        recovered += out.outcome.recovered;
        retries += out.retries;
        corrupt += out.corrupt;
        verify_failures += out.verify_failures;
        quarantined = quarantined.max(out.quarantined);
        let upkeep = session.maintain()?;
        refinements += out.progress.refinements();
        monotone &= out.progress.loss_non_increasing();
        for id in upkeep.evicted {
            println!("worker {id} evicted (missed heartbeat)");
        }
        for id in &upkeep.quarantined {
            println!("worker {id} quarantined (failed verification)");
        }
        quarantined = quarantined.max(upkeep.quarantined.len());
        if upkeep.buffered_results > 0 {
            println!(
                "heartbeat buffered {} in-flight result frame(s)",
                upkeep.buffered_results
            );
        }
        anyhow::ensure!(
            upkeep.live_workers != Some(0),
            "all workers gone; aborting stream"
        );
    }
    let cache = session.cache_stats();
    // every request fully decoded despite stragglers/failures?
    let full_recovery = recovered == requests * spec.part.num_products();
    println!(
        "stream done: requests={requests} received={received} late={late} \
         missing={missing} recovered_total={recovered} retries={retries} \
         corrupt={corrupt} verify_failures={verify_failures} \
         quarantined={quarantined} full_recovery={full_recovery} \
         partial_packets={} cache_hits={} cache_misses={} cache_evictions={}",
        rateless_partial.unwrap_or(0),
        cache.hits,
        cache.misses,
        cache.evictions
    );
    println!("progress: refinements={refinements} monotone={monotone}");
    if let Some(model) = session.fitted_latency() {
        let scales: Vec<String> = session
            .worker_scales()
            .iter()
            .map(|(id, s)| format!("w{id}:{s:.2}"))
            .collect();
        println!(
            "adaptive: fitted {model}, {} replan(s), worker scales [{}]",
            session.replan_count(),
            scales.join(", "),
        );
    }
    // drain until every worker closes its side: a backlogged straggler
    // must read the queued Shutdown before this process exits
    session.shutdown()?;
    println!("shutdown complete");
    Ok(())
}

/// `uepmm serve --service`: the multi-tenant serve plane. Workers and
/// clients both dial the listen address (`uepmm worker --connect`,
/// `uepmm client --connect`); the first frame of each connection picks
/// its role.
fn run_service(a: &Args) -> anyhow::Result<()> {
    let sessions: usize = a.get("sessions")?;
    anyhow::ensure!(sessions >= 1, "--sessions must be >= 1");
    let cfg = ServiceConfig {
        max_sessions: a.get("max-sessions")?,
        queue_depth: a.get("queue-depth")?,
        tenant_quota: a.get("quota")?,
        decode_shards: a.get("decode-shards")?,
        verify: !a.get_bool("no-verify"),
        hetero_lanes: a.get_bool("hetero"),
        ..ServiceConfig::default()
    };
    anyhow::ensure!(cfg.max_sessions >= 1, "--max-sessions must be >= 1");
    anyhow::ensure!(cfg.queue_depth >= 1, "--queue-depth must be >= 1");
    let mut transport = TcpTransport::bind(a.get_str("listen"))?;
    ServePlane::new(cfg).run(&mut transport, sessions);
    Ok(())
}

/// `uepmm client`: open a session on a serve plane, stream coded
/// requests through the unified `Session` API, and back off on rejects.
fn cmd_client(rest: &[String]) -> anyhow::Result<()> {
    let cmd = {
        let c = Command::new("client", "remote client of a multi-tenant serve plane")
            .opt("connect", "127.0.0.1:7077", "serve-plane address")
            .opt("name", "", "tenant name announced at open (default client-<pid>)")
            .opt("requests", "4", "number of multiplication requests")
            .opt(
                "open-retries",
                "40",
                "redial attempts while the plane's session table is full",
            );
        let c = CodedOpts::declare(c, "10");
        let c = TimingOpts::declare(
            c,
            "exp:1.0",
            "injected straggle model (sampled delays travel with each submit)",
        );
        SharedOpts::declare(c, "1")
    };
    let a = cmd.parse(rest)?;
    let shared = SharedOpts::parse(&a)?;
    let coded = CodedOpts::parse(&a)?;
    let timing = TimingOpts::parse(&a)?;
    let (spec, code) = coded.apply(SyntheticSpec::fig9_rxc())?;
    let requests: usize = a.get("requests")?;
    let open_retries: usize = a.get("open-retries")?;
    let name = match a.get_str("name") {
        "" => format!("client-{}", std::process::id()),
        n => n.to_string(),
    };
    let addr = a.get_str("connect");

    // dial, backing off on admission rejects (the plane's retry_after
    // hint is the wait)
    let backend = {
        let mut attempt = 0;
        loop {
            match ClusterBackend::connect(addr, &name) {
                Ok(b) => break b,
                Err(UepmmError::Rejected { retry_after_ms, reason })
                    if attempt < open_retries =>
                {
                    attempt += 1;
                    println!(
                        "rejected: {reason} retry_after={retry_after_ms}ms \
                         (redial {attempt}/{open_retries})"
                    );
                    std::thread::sleep(Duration::from_millis(retry_after_ms.max(10)));
                }
                Err(e) => anyhow::bail!("{name}: connect to {addr} failed: {e}"),
            }
        }
    };
    println!(
        "session {} open as {name} ({requests} requests to {addr})",
        backend.session_id().unwrap_or(0),
    );
    let mut builder = Session::builder()
        .partitioning(spec.part.clone())
        .code(code)
        .classes(spec.class_map())
        .workers(spec.workers)
        .deadline(coded.tmax[0])
        .score(true)
        .seed(shared.seed)
        .backend(backend);
    if let Some(model) = timing.latency.clone() {
        builder = builder.latency(model);
    }
    let mut session = builder.build()?;
    let mut mats = Pcg64::with_stream(shared.seed, 1);
    let a_mat = spec.sample_a(&mut mats);
    let (mut recovered, mut late_total) = (0usize, 0usize);
    for req in 0..requests {
        let b = spec.sample_b(&mut mats);
        let t_max = coded.tmax[req % coded.tmax.len()];
        let out = loop {
            let r = session
                .run(Request::new(0, a_mat.clone(), b.clone()).deadline(t_max));
            match r {
                Ok(out) => break out,
                Err(UepmmError::Rejected { retry_after_ms, reason }) => {
                    println!("rejected: {reason} retry_after={retry_after_ms}ms");
                    std::thread::sleep(Duration::from_millis(retry_after_ms.max(10)));
                }
                Err(e) => return Err(e.into()),
            }
        };
        println!(
            "request {req} (T_max={t_max}): {} arrivals ({} late), \
             recovered {}/{}, loss {:.4}, {} refinements, wall {:?}",
            out.outcome.received,
            out.late,
            out.outcome.recovered,
            spec.part.num_products(),
            out.outcome.normalized_loss,
            out.progress.refinements(),
            out.wall,
        );
        recovered += out.outcome.recovered;
        late_total += out.late;
    }
    let full_recovery = recovered == requests * spec.part.num_products();
    session.shutdown()?;
    println!(
        "client done: requests={requests} recovered={recovered} \
         late={late_total} full_recovery={full_recovery}"
    );
    Ok(())
}

fn cmd_worker(rest: &[String]) -> anyhow::Result<()> {
    let cmd = {
        let c = Command::new("worker", "cluster worker agent")
            .opt("connect", "127.0.0.1:7077", "coordinator address")
            .opt("name", "", "worker name (default worker-<pid>)")
            .opt("omega", "1.0", "capacity scaling for self-injected delays")
            .opt("seed", "0", "delay-sampling RNG seed")
            .opt("retry", "15", "seconds to keep retrying the initial connect")
            .opt(
                "chaos",
                "",
                "fault-injection spec: drop=P,corrupt=P,dup=P,delay=P,\
                 delay-ms=N,reorder=P,tamper=P,seed=N,hang=N (empty = off)",
            );
        let c = TimingOpts::declare(
            c,
            "",
            "self-injected straggle model (empty = real timing only)",
        );
        EngineOpts::declare(c)
    };
    let a = cmd.parse(rest)?;
    let timing = TimingOpts::parse(&a)?;
    let engine_opts = EngineOpts::parse(&a)?;
    let name = match a.get_str("name") {
        "" => format!("worker-{}", std::process::id()),
        n => n.to_string(),
    };
    let cfg = WorkerConfig {
        name: name.clone(),
        latency: timing.latency,
        omega: a.get("omega")?,
        time_scale: timing.time_scale,
        seed: a.get("seed")?,
    };
    let chaos = match a.get_str("chaos") {
        "" => None,
        _ => Some(a.get::<FaultPlan>("chaos")?),
    };
    let engine = engine_opts.build()?;
    let addr = a.get_str("connect");
    let deadline = Instant::now() + Duration::from_secs_f64(a.get_f64("retry")?);
    // Exponential backoff with deterministic jitter: a cohort of workers
    // launched together (same script, staggered names) fans out instead
    // of hammering the coordinator in lockstep every 250ms.
    let mut jitter = Pcg64::with_stream(
        cfg.seed,
        name.bytes()
            .fold(0u64, |h, b| h.wrapping_mul(31).wrapping_add(u64::from(b))),
    );
    let mut backoff = Duration::from_millis(50);
    let mut conn = loop {
        match TcpConn::connect(addr) {
            Ok(c) => break c,
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    anyhow::bail!("{name}: could not reach coordinator {addr}: {e}");
                }
                let wait = backoff.mul_f64(0.5 + 0.5 * jitter.next_f64());
                std::thread::sleep(wait.min(deadline.duration_since(now)));
                backoff = (backoff * 2).min(Duration::from_secs(2));
            }
        }
    };
    println!("{name}: connected to {addr} (engine {})", engine.name());
    let stats = match chaos {
        Some(plan) => {
            println!("{name}: chaos injection on: {plan:?}");
            let mut conn = ChaosConn::new(Box::new(conn), &plan);
            uepmm::cluster::run_worker(&mut conn, &engine, &cfg)?
        }
        None => uepmm::cluster::run_worker(&mut conn, &engine, &cfg)?,
    };
    println!(
        "{name}: done ({}): id={} jobs={} heartbeats={}",
        if stats.clean_shutdown { "clean shutdown" } else { "connection lost" },
        stats.worker_id,
        stats.jobs,
        stats.heartbeats,
    );
    Ok(())
}
