//! # uepmm — UEP-coded distributed approximate matrix multiplication
//!
//! Rust + JAX + Pallas reproduction of *"Straggler Mitigation through
//! Unequal Error Protection for Distributed Approximate Matrix
//! Multiplication"* (Tegin, Hernandez, Rini, Duman, 2021).
//!
//! The library implements a parameter server (PS) that distributes coded
//! sub-products of a matrix multiplication `C = A·B` across `W` workers
//! with stochastic completion times, protects the high-norm sub-products
//! with Unequal Error Protection (UEP) random linear codes, and assembles
//! a progressively improving approximation `Ĉ` by a deadline `T_max`.
//!
//! ## Layer map
//!
//! * **[`api`] — the public front door.** One [`api::Session`] builder
//!   and one [`api::Backend`] trait drive all three execution paths
//!   (in-process virtual time, loopback thread pool, networked cluster)
//!   with batched submission, an anytime [`api::Progress`] stream,
//!   typed [`api::UepmmError`]s, and an opt-in straggle-adaptive
//!   planning loop ([`api::SessionBuilder::adaptive`]): observed
//!   per-job timings → fitted latency model → re-optimized window
//!   polynomial. Start here; everything below is the engine room.
//! * **Coding & analysis** — [`coding`] (packet generation, incremental
//!   decode), [`partition`] (block splits, Gram-based loss),
//!   [`latency`] (straggler models + online estimators), [`analysis`]
//!   (Theorems 2/3, decoding probabilities, the Γ optimizer), [`sim`]
//!   (fast coefficient-only sweeps).
//! * **Execution** — [`coordinator`] (plans, the virtual-time reference
//!   path, the deprecated thread-pool shim), [`cluster`] (wire
//!   protocol, transports, worker agents, the coordinator server the
//!   pooled/networked backends share), [`runtime`] (native + PJRT
//!   engines), [`linalg`] (the blocked/parallel matmul kernel).
//! * **Workloads** — [`nn`] (coded DNN training through the client
//!   API), [`experiments`] (paper figures + the `api-stream` demo),
//!   [`config`] (paper presets), [`data`], [`util`].
//! * **L2/L1 (build time)** — `python/compile/` lowers the JAX model and
//!   Pallas kernels to HLO text; [`runtime`] loads and executes them via
//!   PJRT. Python never runs on the request path.

pub mod analysis;
pub mod api;
pub mod cluster;
pub mod coding;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod latency;
pub mod linalg;
pub mod nn;
pub mod partition;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod util;

/// Convenient re-exports of the most commonly used types: the unified
/// client API surface plus the handful of building blocks every caller
/// touches (matrices, partitionings, codes, latency models, RNG).
pub mod prelude {
    pub use crate::api::{
        ApiResult, Backend, Capabilities, Classes, ClusterBackend, Compute,
        InProcessBackend, OmegaMode, PollState, PooledBackend, Progress,
        ProgressEvent, ReplanEvent, ReplanPolicy, Request, RequestHandle,
        RunReport, Session, SessionBuilder, UepmmError,
    };
    pub use crate::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
    pub use crate::latency::LatencyModel;
    pub use crate::linalg::Matrix;
    pub use crate::partition::{ClassMap, Paradigm, Partitioning};
    pub use crate::rng::Pcg64;
}
