//! # uepmm — UEP-coded distributed approximate matrix multiplication
//!
//! Rust + JAX + Pallas reproduction of *"Straggler Mitigation through
//! Unequal Error Protection for Distributed Approximate Matrix
//! Multiplication"* (Tegin, Hernandez, Rini, Duman, 2021).
//!
//! The library implements a parameter server (PS) that distributes coded
//! sub-products of a matrix multiplication `C = A·B` across `W` workers
//! with stochastic completion times, protects the high-norm sub-products
//! with Unequal Error Protection (UEP) random linear codes, and assembles
//! a progressively improving approximation `Ĉ` by a deadline `T_max`.
//!
//! ## Layer map
//!
//! * **L3 (this crate)** — the coordinator: [`coding`], [`partition`],
//!   [`latency`], [`analysis`], [`sim`], [`coordinator`], [`nn`],
//!   [`experiments`], and the networked runtime [`cluster`]
//!   (coordinator/worker agents over a wire protocol).
//! * **L2/L1 (build time)** — `python/compile/` lowers the JAX model and
//!   Pallas kernels to HLO text; [`runtime`] loads and executes them via
//!   PJRT. Python never runs on the request path.

pub mod analysis;
pub mod cluster;
pub mod coding;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod latency;
pub mod linalg;
pub mod nn;
pub mod partition;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod util;

/// Convenient re-exports of the most commonly used types.
pub mod prelude {
    pub use crate::linalg::Matrix;
    pub use crate::rng::Pcg64;
}
