//! Ablations beyond the paper:
//!
//! * `ablation-encoding` — the paper's eq. (17) rank-one encoding vs the
//!   stacked exact-RLC reading (DESIGN.md §2): how much loss does the
//!   Khatri-Rao structure + cross-term contamination cost?
//! * `ablation-gamma` — sensitivity of the loss to the window selection
//!   polynomial, which the paper picks "arbitrarily" and flags as an
//!   optimization opportunity in its closing remark of §VI.

use crate::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
use crate::config::SyntheticSpec;
use crate::util::csv::CsvTable;
use crate::util::linspace;
use crate::util::plot::{render, Series};

use super::common::{mc_loss_vs_time, ExpContext};

pub fn run_encoding(ctx: &ExpContext) -> anyhow::Result<()> {
    let ts = linspace(0.0, 2.0, 21);
    let instances = 2;
    let trials = (ctx.trials / 2).max(50);
    let mut table = CsvTable::new(&[
        "t",
        "rxc_now_stacked",
        "rxc_now_rank1",
        "rxc_ew_stacked",
        "rxc_ew_rank1",
        "cxr_now_stacked",
        "cxr_now_rank1",
    ]);
    let rxc = SyntheticSpec::fig9_rxc().scaled(ctx.scale_factor());
    let cxr = SyntheticSpec::fig9_cxr().scaled(ctx.scale_factor());
    let mut cols: Vec<Vec<f64>> = Vec::new();
    let mut series = Vec::new();
    let cfgs: Vec<(&str, &SyntheticSpec, CodeKind, EncodeStyle)> = vec![
        ("rxc_now_stacked", &rxc, CodeKind::NowUep(rxc.gamma.clone()), EncodeStyle::Stacked),
        ("rxc_now_rank1", &rxc, CodeKind::NowUep(rxc.gamma.clone()), EncodeStyle::RankOne),
        ("rxc_ew_stacked", &rxc, CodeKind::EwUep(rxc.gamma.clone()), EncodeStyle::Stacked),
        ("rxc_ew_rank1", &rxc, CodeKind::EwUep(rxc.gamma.clone()), EncodeStyle::RankOne),
        ("cxr_now_stacked", &cxr, CodeKind::NowUep(cxr.gamma.clone()), EncodeStyle::Stacked),
        ("cxr_now_rank1", &cxr, CodeKind::NowUep(cxr.gamma.clone()), EncodeStyle::RankOne),
    ];
    for (name, spec, kind, style) in &cfgs {
        let code = CodeSpec::new(kind.clone(), *style);
        let losses =
            mc_loss_vs_time(spec, &code, &ts, instances, trials, ctx.seed, ctx.threads);
        series.push(Series::new(name, ts.clone(), losses.clone()));
        cols.push(losses);
    }
    for i in 0..ts.len() {
        let mut row = vec![ts[i]];
        row.extend(cols.iter().map(|c| c[i]));
        table.push_f64(&row);
    }
    println!(
        "{}",
        render("Ablation — stacked vs rank-one encodings", &series, 64, 18)
    );
    ctx.write_csv("ablation_encoding_styles.csv", &table)?;
    // summarize the gap at a mid deadline
    let mid = ts.len() / 2;
    println!(
        "  at t={:.2}: r×c NOW stacked {:.3} vs rank-one {:.3}; c×r NOW stacked {:.3} vs rank-one {:.3}",
        ts[mid], cols[0][mid], cols[1][mid], cols[4][mid], cols[5][mid]
    );
    Ok(())
}

pub fn run_gamma(ctx: &ExpContext) -> anyhow::Result<()> {
    // sweep the weight on the most-important window; split the remainder
    // between the other two windows in the paper's 0.35:0.25 ratio
    let g1s = [0.2, 0.33, 0.4, 0.5, 0.6, 0.75, 0.9];
    let spec0 = SyntheticSpec::fig9_rxc().scaled(ctx.scale_factor());
    let t_evals = [0.25, 0.5, 1.0];
    let mut table = CsvTable::new(&["gamma1", "loss_t025", "loss_t05", "loss_t1"]);
    let trials = (ctx.trials / 2).max(50);
    let mut rows = Vec::new();
    for &g1 in &g1s {
        let rest = 1.0 - g1;
        let gamma =
            WindowPolynomial::new(&[g1, rest * 0.35 / 0.60, rest * 0.25 / 0.60]);
        let mut spec = spec0.clone();
        spec.gamma = gamma.clone();
        let code = CodeSpec::new(CodeKind::EwUep(gamma), EncodeStyle::Stacked);
        let losses =
            mc_loss_vs_time(&spec, &code, &t_evals, 2, trials, ctx.seed, ctx.threads);
        table.push_f64(&[g1, losses[0], losses[1], losses[2]]);
        rows.push((g1, losses));
    }
    println!("Ablation — EW loss vs window polynomial (Γ₁ sweep, r×c):");
    for (g1, losses) in &rows {
        println!(
            "  Γ₁={g1:.2}: loss(t=0.25)={:.3} loss(0.5)={:.3} loss(1)={:.3}",
            losses[0], losses[1], losses[2]
        );
    }
    ctx.write_csv("ablation_gamma_sweep.csv", &table)?;

    // The paper's future-work item, done: optimize Γ on the Theorem 2
    // objective (analysis::optimize_gamma) at each deadline.
    let mut opt_table = CsvTable::new(&["t_star", "g1", "g2", "g3", "loss", "paper_gamma_loss"]);
    for &t_star in &t_evals {
        let th = spec0.theorem();
        let opt = crate::analysis::optimize_gamma(
            &th,
            crate::analysis::UepStrategy::Ew,
            t_star,
            6,
        );
        println!(
            "  optimized Γ at t*={t_star}: ({:.3}, {:.3}, {:.3}) → loss {:.4} (paper Γ: {:.4})",
            opt.gamma[0], opt.gamma[1], opt.gamma[2], opt.loss, opt.initial_loss
        );
        opt_table.push_f64(&[
            t_star, opt.gamma[0], opt.gamma[1], opt.gamma[2], opt.loss, opt.initial_loss,
        ]);
    }
    ctx.write_csv("ablation_gamma_optimized.csv", &opt_table)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::UnknownSpace;

    /// Rank-one NOW in c×r wastes rank on ghost unknowns ⇒ at equal
    /// packet counts it recovers no more than stacked.
    #[test]
    fn rank1_cxr_weaker_than_stacked() {
        let spec = SyntheticSpec::fig9_cxr().scaled(15);
        let ts = [0.6];
        let stacked = CodeSpec::new(
            CodeKind::NowUep(spec.gamma.clone()),
            EncodeStyle::Stacked,
        );
        let rank1 = CodeSpec::new(
            CodeKind::NowUep(spec.gamma.clone()),
            EncodeStyle::RankOne,
        );
        let ls = mc_loss_vs_time(&spec, &stacked, &ts, 1, 150, 23, 4);
        let lr = mc_loss_vs_time(&spec, &rank1, &ts, 1, 150, 23, 4);
        assert!(
            lr[0] >= ls[0] - 0.02,
            "rank-one {} unexpectedly beats stacked {}",
            lr[0],
            ls[0]
        );
        // sanity: the unknown spaces really differ
        let s1 = UnknownSpace::for_code(&spec.part, EncodeStyle::Stacked);
        let s2 = UnknownSpace::for_code(&spec.part, EncodeStyle::RankOne);
        assert_eq!(s1.n_total, 9);
        assert_eq!(s2.n_total, 81);
    }
}
