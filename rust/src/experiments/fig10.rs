//! Fig. 10: normalized loss as a function of the number of *received*
//! packets. MDS is all-or-nothing at 9 packets; the UEP codes recover
//! progressively from the first arrivals.

use crate::analysis::mds_loss_vs_packets;
use crate::coding::{CodeKind, CodeSpec, EncodeStyle};
use crate::config::SyntheticSpec;
use crate::util::csv::CsvTable;
use crate::util::plot::{render, Series};

use super::common::{mc_loss_vs_packets, ExpContext};

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    let rxc = SyntheticSpec::fig9_rxc().scaled(ctx.scale_factor());
    let cxr = SyntheticSpec::fig9_cxr().scaled(ctx.scale_factor());
    let instances = if ctx.full { 4 } else { 2 };
    let trials = ctx.trials / instances.max(1);
    let ws: Vec<f64> = (0..=rxc.workers).map(|w| w as f64).collect();

    let mut header = vec!["received".to_string()];
    let mut columns: Vec<Vec<f64>> = vec![ws.clone()];
    let mut series = Vec::new();
    for (tag, spec) in [("rxc", &rxc), ("cxr", &cxr)] {
        for (code_tag, kind) in [
            ("now", CodeKind::NowUep(spec.gamma.clone())),
            ("ew", CodeKind::EwUep(spec.gamma.clone())),
        ] {
            let code = CodeSpec::new(kind, EncodeStyle::Stacked);
            let losses = mc_loss_vs_packets(
                spec, &code, instances, trials, ctx.seed, ctx.threads,
            );
            let name = format!("{code_tag}_{tag}");
            series.push(Series::new(&name, ws.clone(), losses.clone()));
            header.push(name);
            columns.push(losses);
        }
    }
    let mds: Vec<f64> = (0..=rxc.workers)
        .map(|w| mds_loss_vs_packets(9, w))
        .collect();
    series.push(Series::new("mds", ws.clone(), mds.clone()));
    header.push("mds".into());
    columns.push(mds);

    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = CsvTable::new(&header_refs);
    for i in 0..ws.len() {
        table.push_f64(&columns.iter().map(|c| c[i]).collect::<Vec<_>>());
    }
    println!(
        "{}",
        render("Fig. 10 — normalized loss vs received packets", &series, 64, 18)
    );
    ctx.write_csv("fig10_loss_vs_packets.csv", &table)?;

    // headline: UEP recovers something after very few packets
    let ew_rxc = &columns[header.iter().position(|h| h == "ew_rxc").unwrap()];
    println!(
        "  EW r×c loss after 3 packets: {:.3} (MDS: 1.000)",
        ew_rxc[3]
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uep_progressive_vs_mds_cliff() {
        let spec = SyntheticSpec::fig9_rxc().scaled(15);
        let code = CodeSpec::new(
            CodeKind::EwUep(spec.gamma.clone()),
            EncodeStyle::Stacked,
        );
        let losses = mc_loss_vs_packets(&spec, &code, 1, 100, 3, 4);
        // progressive partial recovery: strictly below 1 after a few
        // packets, decreasing with more (MDS would still be at 1.0)
        assert!(losses[4] < 0.97, "EW@4 {}", losses[4]);
        assert!(losses[6] < losses[4], "not progressive: {losses:?}");
        // MDS at 4 packets: loss 1
        assert_eq!(mds_loss_vs_packets(9, 4), 1.0);
        // with all 30 packets EW almost always decodes everything (the
        // rare exception: too few high-index windows drawn — see the EW
        // trade-off note in experiments::mnist)
        assert!(losses[spec.workers] < 0.05, "EW@30 {}", losses[spec.workers]);
    }
}
