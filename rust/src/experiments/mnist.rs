//! Figs. 13–15: MNIST-style MLP training under straggler strategies.
//!
//! Strategies (Table VII, λ = 0.5 exponential latency, Ω = 9/W):
//! * no stragglers (centralized) — red reference curve,
//! * uncoded, W = 9,
//! * NOW-UEP / EW-UEP, W = 15,
//! * 2-block repetition, W = 18,
//! over both r×c (Fig. 13) and c×r (Fig. 14) partitionings and
//! `T_max ∈ {0.25, 0.5, 1, 2}`; Fig. 15 reads accuracy vs `T_max`.
//!
//! Default scale trains on the synthetic digit corpus with a reduced
//! iteration budget (`--full` restores paper-sized 60k×3-epoch runs).

use crate::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
use crate::config::EncodingRow;
use crate::data::synthetic_digits;
use crate::latency::LatencyModel;
use crate::nn::{
    train_mlp, CodedMatmulCfg, MatmulStrategy, Mlp, TauSchedule, TrainConfig,
    TrainRecord,
};
use crate::partition::Paradigm;
use crate::rng::Pcg64;
use crate::util::csv::CsvTable;
use crate::util::plot::{render, Series};

use super::ExpContext;

const T_MAXES: [f64; 4] = [0.25, 0.5, 1.0, 2.0];

/// One strategy row of the sweep.
fn strategies(paradigm: Paradigm) -> Vec<(&'static str, Option<(CodeKind, EncodingRow)>)> {
    let gamma = WindowPolynomial::paper_table3();
    vec![
        ("no-straggler", None),
        ("uncoded", Some((CodeKind::Uncoded, EncodingRow::Uncoded))),
        ("now-uep", Some((CodeKind::NowUep(gamma.clone()), EncodingRow::Uep))),
        ("ew-uep", Some((CodeKind::EwUep(gamma), EncodingRow::Uep))),
        ("2-rep", Some((CodeKind::Repetition, EncodingRow::TwoBlockRep))),
    ]
    .into_iter()
    .map(move |(n, k)| {
        let _ = paradigm;
        (n, k)
    })
    .collect()
}

fn make_strategy(
    kind_row: &Option<(CodeKind, EncodingRow)>,
    paradigm: Paradigm,
    t_max: f64,
) -> MatmulStrategy {
    match kind_row {
        None => MatmulStrategy::Exact,
        Some((kind, row)) => {
            let (workers, _omega) = row.params();
            MatmulStrategy::Coded(CodedMatmulCfg {
                paradigm,
                blocks: match paradigm {
                    Paradigm::RowTimesCol => 3,
                    Paradigm::ColTimesRow => 9,
                },
                // UEP uses the paper's literal eq. (17) rank-one encoding
                // for r×c (per-cell granularity: with one block per level
                // a NOW packet decodes on arrival — importance-weighted
                // replication). c×r keeps the exact stacked RLC: rank-one
                // cross terms are ghosts there (DESIGN.md §2).
                spec: CodeSpec::new(
                    kind.clone(),
                    match (paradigm, kind) {
                        (Paradigm::RowTimesCol, CodeKind::NowUep(_) | CodeKind::EwUep(_)) => {
                            EncodeStyle::RankOne
                        }
                        _ => EncodeStyle::Stacked,
                    },
                ),
                workers,
                latency: LatencyModel::exp(0.5),
                auto_omega: true,
                t_max,
                s_levels: 3,
            })
        }
    }
}

/// Train one configuration.
fn run_one(
    ctx: &ExpContext,
    strategy: MatmulStrategy,
    seed_bump: u64,
) -> TrainRecord {
    let mut rng = Pcg64::seed_from(ctx.seed);
    let (n_train, n_test, epochs, max_iters) = if ctx.full {
        (60_000, 2_000, 3, 0)
    } else {
        (1_920, 400, 3, 30)
    };
    let train = synthetic_digits(n_train, 11, &mut rng);
    let test = synthetic_digits(n_test, 13, &mut rng);
    let mut mlp = Mlp::mnist(&mut rng);
    let cfg = TrainConfig {
        lr: 0.05,
        epochs,
        batch: 64,
        strategy,
        tau: TauSchedule::paper(3),
        seed: ctx.seed ^ seed_bump,
        eval_every: 10,
        max_iters_per_epoch: max_iters,
    };
    train_mlp(&mut mlp, &train, &test, &cfg)
}

/// The shared Fig. 13/14 sweep for one paradigm; returns long-format CSV.
fn sweep(ctx: &ExpContext, paradigm: Paradigm, fig: &str) -> anyhow::Result<CsvTable> {
    let mut table = CsvTable::new(&[
        "strategy", "t_max", "iter", "train_loss", "test_acc", "recovery_rate",
    ]);
    let mut plot_series = Vec::new();
    for (name, kind_row) in strategies(paradigm) {
        let t_maxes: &[f64] = if kind_row.is_none() { &[f64::INFINITY] } else { &T_MAXES };
        for &t_max in t_maxes {
            let strategy = make_strategy(&kind_row, paradigm, t_max);
            let rec = run_one(ctx, strategy, (t_max * 100.0) as u64);
            for p in &rec.points {
                table.push_raw(vec![
                    name.into(),
                    if t_max.is_infinite() { "inf".into() } else { format!("{t_max}") },
                    p.iter.to_string(),
                    format!("{:.4}", p.train_loss),
                    format!("{:.4}", p.test_acc),
                    format!("{:.4}", rec.recovery_rate),
                ]);
            }
            // plot the T_max = 1 slice (plus the reference curve)
            if t_max.is_infinite() || (t_max - 1.0).abs() < 1e-9 {
                plot_series.push(Series::new(
                    name,
                    rec.points.iter().map(|p| p.iter as f64).collect(),
                    rec.points.iter().map(|p| p.test_acc).collect(),
                ));
            }
            println!(
                "  {name:<12} T_max={:<5} final acc {:.3} (recovered {:.0}% of sub-products)",
                if t_max.is_infinite() { "-".into() } else { format!("{t_max}") },
                rec.final_test_acc,
                100.0 * rec.recovery_rate
            );
        }
    }
    println!(
        "{}",
        render(
            &format!("{fig} — accuracy vs iteration ({}, T_max=1)", paradigm.short()),
            &plot_series,
            64,
            16
        )
    );
    Ok(table)
}

pub fn run_fig13(ctx: &ExpContext) -> anyhow::Result<()> {
    let table = sweep(ctx, Paradigm::RowTimesCol, "Fig. 13")?;
    ctx.write_csv("fig13_mnist_rxc.csv", &table)
}

pub fn run_fig14(ctx: &ExpContext) -> anyhow::Result<()> {
    let table = sweep(ctx, Paradigm::ColTimesRow, "Fig. 14")?;
    ctx.write_csv("fig14_mnist_cxr.csv", &table)
}

/// Fig. 15: final accuracy vs `T_max` per strategy and paradigm.
pub fn run_fig15(ctx: &ExpContext) -> anyhow::Result<()> {
    let mut table =
        CsvTable::new(&["strategy", "paradigm", "t_max", "final_test_acc"]);
    for paradigm in [Paradigm::RowTimesCol, Paradigm::ColTimesRow] {
        for (name, kind_row) in strategies(paradigm) {
            if kind_row.is_none() {
                let rec = run_one(ctx, MatmulStrategy::Exact, 0);
                for &t in &T_MAXES {
                    table.push_raw(vec![
                        name.into(),
                        paradigm.short().into(),
                        t.to_string(),
                        format!("{:.4}", rec.final_test_acc),
                    ]);
                }
                continue;
            }
            for &t_max in &T_MAXES {
                let strategy = make_strategy(&kind_row, paradigm, t_max);
                let rec = run_one(ctx, strategy, (t_max * 100.0) as u64 + 7);
                println!(
                    "  {name:<12} {} T_max={t_max:<5} final acc {:.3}",
                    paradigm.short(),
                    rec.final_test_acc
                );
                table.push_raw(vec![
                    name.into(),
                    paradigm.short().into(),
                    t_max.to_string(),
                    format!("{:.4}", rec.final_test_acc),
                ]);
            }
        }
    }
    ctx.write_csv("fig15_accuracy_vs_tmax.csv", &table)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny smoke over the sweep machinery: UEP with a generous deadline
    /// must recover nearly everything; with a zero-ish deadline nearly
    /// nothing — and the training loop survives both.
    #[test]
    fn coded_training_extremes() {
        let ctx = ExpContext {
            out: std::env::temp_dir().join("uepmm_mnist_test"),
            trials: 0,
            full: false,
            seed: 5,
            threads: 2,
        };
        let gamma = WindowPolynomial::paper_table3();
        // uncoded with an infinite deadline recovers everything
        let generous = make_strategy(
            &Some((CodeKind::Uncoded, EncodingRow::Uncoded)),
            Paradigm::RowTimesCol,
            1e9,
        );
        let rec = run_one_small(&ctx, generous);
        assert!((rec.recovery_rate - 1.0).abs() < 1e-12);
        // EW with all 15 packets still decodes most (class 3 can starve:
        // P[n3 < 3 | Binom(15, 0.25)] ≈ 0.29 — a real EW trade-off)
        let generous_ew = make_strategy(
            &Some((CodeKind::EwUep(gamma.clone()), EncodingRow::Uep)),
            Paradigm::RowTimesCol,
            1e9,
        );
        let rec_ew = run_one_small(&ctx, generous_ew);
        assert!(rec_ew.recovery_rate > 0.7, "EW rate {}", rec_ew.recovery_rate);
        let starved = make_strategy(
            &Some((CodeKind::EwUep(gamma), EncodingRow::Uep)),
            Paradigm::RowTimesCol,
            1e-9,
        );
        let rec2 = run_one_small(&ctx, starved);
        assert!(rec2.recovery_rate < 0.05, "rate {}", rec2.recovery_rate);
        // even with no recovered gradients the loop must not diverge to NaN
        assert!(rec2.points.iter().all(|p| p.train_loss.is_finite()));
    }

    fn run_one_small(ctx: &ExpContext, strategy: MatmulStrategy) -> TrainRecord {
        let mut rng = Pcg64::seed_from(ctx.seed);
        let train = synthetic_digits(256, 11, &mut rng);
        let test = synthetic_digits(64, 13, &mut rng);
        let mut mlp = Mlp::new(&[784, 32, 16, 10], &mut rng);
        let cfg = TrainConfig {
            lr: 0.05,
            epochs: 1,
            batch: 64,
            strategy,
            tau: TauSchedule::paper(3),
            seed: 9,
            eval_every: 2,
            max_iters_per_epoch: 4,
        };
        train_mlp(&mut mlp, &train, &test, &cfg)
    }
}
