//! Tables III and VII: the coding/encoding parameter sets, printed and
//! persisted so every other experiment can reference one source of
//! truth.

use crate::config::EncodingRow;
use crate::util::csv::CsvTable;
use crate::util::plot::text_table;

use super::ExpContext;

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    // Table III
    let t3_rows = vec![
        vec!["# of blocks".into(), "3".into(), "3".into(), "3".into()],
        vec![
            "Window selection probs.".into(),
            "0.40".into(),
            "0.35".into(),
            "0.25".into(),
        ],
    ];
    println!("Table III — UEP coding parameters");
    println!(
        "{}",
        text_table(&["", "Class 1", "Class 2", "Class 3"], &t3_rows)
    );
    let mut t3 = CsvTable::new(&["param", "class1", "class2", "class3"]);
    t3.push_raw(vec!["blocks".into(), "3".into(), "3".into(), "3".into()]);
    t3.push_raw(vec!["gamma".into(), "0.4".into(), "0.35".into(), "0.25".into()]);
    ctx.write_csv("table3_uep_parameters.csv", &t3)?;

    // Table VII
    let mut t7_rows = Vec::new();
    let mut t7 = CsvTable::new(&["encoding", "workers", "omega"]);
    for (name, row) in [
        ("Uncoded", EncodingRow::Uncoded),
        ("NOW/EW - UEP", EncodingRow::Uep),
        ("2-Block Rep", EncodingRow::TwoBlockRep),
    ] {
        let (w, omega) = row.params();
        t7_rows.push(vec![name.into(), w.to_string(), format!("9/{w} = {omega:.3}")]);
        t7.push_raw(vec![name.into(), w.to_string(), omega.to_string()]);
    }
    println!("Table VII — encoding parameters (9 sub-products)");
    println!("{}", text_table(&["Encoding Type", "W", "Ω"], &t7_rows));
    ctx.write_csv("table7_encoding_parameters.csv", &t7)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_tables_written() {
        let dir = std::env::temp_dir().join("uepmm_params_test");
        let ctx = ExpContext { out: dir.clone(), ..Default::default() };
        run(&ctx).unwrap();
        assert!(dir.join("table3_uep_parameters.csv").exists());
        let t7 = std::fs::read_to_string(dir.join("table7_encoding_parameters.csv")).unwrap();
        assert!(t7.contains("NOW/EW - UEP,15,0.6"));
    }
}
