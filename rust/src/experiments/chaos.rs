//! `chaos`: the Byzantine-tolerance soak — a deterministic request
//! stream served over fault-injected workers ([`crate::cluster::chaos`])
//! plus one always-lying worker, asserting the integrity layer end to
//! end: every request fully recovers, checksum-damaged frames and
//! dropped results cost retries (never work), the liar is struck out
//! and quarantined, and the decode is bit-identical across a full
//! rerun, with verification off, and over TCP.
//!
//! Not a paper figure: the paper assumes honest-but-slow workers. This
//! soak covers the fault classes its channel model implies (see the
//! fault-model table in [`crate::cluster`]) and is the CI gate for the
//! quarantine machinery.

use std::time::Duration;

use crate::cluster::{
    run_worker, spawn_chaos_loopback_worker, spawn_loopback_workers,
    ClusterConfig, ClusterOutcome, ClusterServer, DeadlineMode, FaultPlan,
    LoopbackTransport, ServedDecode, TcpConn, TcpTransport, Transport,
    WorkerConfig,
};
use crate::coding::{CodeKind, CodeSpec, RatelessSpec};
use crate::coordinator::{Plan, RatelessPlan};
use crate::latency::LatencyModel;
use crate::linalg::Matrix;
use crate::partition::Partitioning;
use crate::rng::Pcg64;
use crate::runtime::NativeEngine;
use crate::util::csv::CsvTable;

use super::common::ExpContext;

/// Packets per request: MDS over 9 sub-products, so any 9 of the 14
/// recover everything — 5 erasures of slack for the injected faults.
const PACKETS: usize = 14;
/// Virtual deadline far above every sampled delay: nothing is late, so
/// full recovery is the only acceptable outcome.
const T_MAX: f64 = 50.0;

fn small_plan(seed: u64) -> Plan {
    let mut rng = Pcg64::seed_from(seed);
    let part = Partitioning::rxc(3, 3, 4, 5, 4);
    let a = Matrix::randn(12, 5, 0.0, 1.0, &mut rng);
    let b = Matrix::randn(5, 12, 0.0, 1.0, &mut rng);
    let spec = CodeSpec::stacked(CodeKind::Mds);
    Plan::build(&part, spec, 3, PACKETS, &a, &b, &mut rng).unwrap()
}

fn soak_config() -> ClusterConfig {
    ClusterConfig {
        deadline: DeadlineMode::Virtual,
        // quarantine on the second failed verification
        max_verify_failures: 1,
        max_job_retries: 10,
        // dropped results recover through the stall timer; keep the
        // soak quick
        stall_timeout: Duration::from_millis(500),
        ..ClusterConfig::default()
    }
}

/// Deterministic per-job injected delays for request `req` of a stream.
fn stream_delays(seed: u64, req: u64) -> Vec<f64> {
    let mut rng = Pcg64::with_stream(seed, 7000 + req);
    let model = LatencyModel::exp(1.0);
    (0..PACKETS).map(|_| model.sample_scaled(1.0, &mut rng)).collect()
}

/// One full soak pass: a fresh coordinator, three honest-but-lossy
/// chaos workers, one Byzantine worker tampering every payload, and
/// `requests` served requests. Fresh everything per call, so two calls
/// with the same arguments replay the same seeded fault plans.
fn run_soak(seed: u64, requests: usize) -> anyhow::Result<(Vec<ClusterOutcome>, usize)> {
    let (mut transport, dialer) = LoopbackTransport::new();
    let mut server = ClusterServer::new(soak_config());
    let mut handles = Vec::new();
    // register one at a time so worker ids (and thus dispatch order)
    // never depend on thread scheduling
    for i in 0..3u64 {
        let cfg = WorkerConfig {
            name: format!("honest-{i}"),
            ..WorkerConfig::default()
        };
        let plan = FaultPlan {
            seed: seed ^ (100 + i),
            drop: 0.05,
            corrupt: 0.2,
            ..FaultPlan::default()
        };
        handles.push(spawn_chaos_loopback_worker(&dialer, &cfg, &plan));
        anyhow::ensure!(
            server.accept_workers(&mut transport, 1, Duration::from_secs(10))? == 1,
            "honest-{i} failed to register"
        );
    }
    let byz_cfg = WorkerConfig { name: "byz".to_string(), ..WorkerConfig::default() };
    let byz_plan = FaultPlan { seed: seed ^ 999, tamper: 1.0, ..FaultPlan::default() };
    handles.push(spawn_chaos_loopback_worker(&dialer, &byz_cfg, &byz_plan));
    anyhow::ensure!(
        server.accept_workers(&mut transport, 1, Duration::from_secs(10))? == 1,
        "byz failed to register"
    );

    let mut outs = Vec::new();
    for req in 0..requests {
        let plan = small_plan(seed.wrapping_add(req as u64));
        let delays = stream_delays(seed, req as u64);
        outs.push(server.serve_plan(&plan, T_MAX, Some(&delays))?);
    }
    let quarantined = server.quarantined_workers().len();
    server.shutdown();
    for h in handles {
        // the quarantined worker's connection was torn down server-side:
        // its thread exits with a connection-lost error, which is the
        // expected shape here, so ignore per-thread results
        let _ = h.join();
    }
    Ok((outs, quarantined))
}

/// Honest arm: `threads` fault-free loopback workers serving the same
/// stream, with verification on or off.
fn run_honest(seed: u64, requests: usize, verify: bool) -> anyhow::Result<Vec<ClusterOutcome>> {
    let (mut transport, dialer) = LoopbackTransport::new();
    let mut server = ClusterServer::new(ClusterConfig { verify, ..soak_config() });
    let mut handles = Vec::new();
    for i in 0..3u64 {
        let cfg = WorkerConfig {
            name: format!("honest-{i}"),
            ..WorkerConfig::default()
        };
        handles.extend(spawn_loopback_workers(&dialer, 1, &cfg));
        anyhow::ensure!(
            server.accept_workers(&mut transport, 1, Duration::from_secs(10))? == 1,
            "honest-{i} failed to register"
        );
    }
    let mut outs = Vec::new();
    for req in 0..requests {
        let plan = small_plan(seed.wrapping_add(req as u64));
        let delays = stream_delays(seed, req as u64);
        outs.push(server.serve_plan(&plan, T_MAX, Some(&delays))?);
    }
    server.shutdown();
    for h in handles {
        h.join().unwrap()?;
    }
    Ok(outs)
}

/// TCP arm: the same honest stream over real sockets, verification on.
fn run_tcp(seed: u64, requests: usize) -> anyhow::Result<Vec<ClusterOutcome>> {
    let mut transport = TcpTransport::bind("127.0.0.1:0")?;
    let addr = transport.local_addr();
    let mut server = ClusterServer::new(soak_config());
    let mut handles = Vec::new();
    for i in 0..3u64 {
        let cfg = WorkerConfig {
            name: format!("honest-{i}"),
            ..WorkerConfig::default()
        };
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let mut conn = TcpConn::connect(&addr)?;
            run_worker(&mut conn, &NativeEngine::serial(), &cfg)?;
            Ok(())
        }));
        anyhow::ensure!(
            server.accept_workers(&mut transport, 1, Duration::from_secs(10))? == 1,
            "honest-{i} failed to register over TCP"
        );
    }
    let mut outs = Vec::new();
    for req in 0..requests {
        let plan = small_plan(seed.wrapping_add(req as u64));
        let delays = stream_delays(seed, req as u64);
        outs.push(server.serve_plan(&plan, T_MAX, Some(&delays))?);
    }
    server.shutdown_graceful(Duration::from_secs(5));
    for h in handles {
        h.join().unwrap()?;
    }
    Ok(outs)
}

/// Same operands and geometry as [`small_plan`], under the rateless
/// family (paper-default robust-Soliton knobs, Table III windows).
fn small_rateless_plan(seed: u64) -> RatelessPlan {
    let mut rng = Pcg64::seed_from(seed);
    let part = Partitioning::rxc(3, 3, 4, 5, 4);
    let a = Matrix::randn(12, 5, 0.0, 1.0, &mut rng);
    let b = Matrix::randn(5, 12, 0.0, 1.0, &mut rng);
    RatelessPlan::build(&part, RatelessSpec::paper_default(), 3, &a, &b).unwrap()
}

/// Seeded per-stream cumulative packet completion times: `packets`
/// strictly increasing arrivals per stream, all well inside `T_MAX`.
fn rateless_schedules(
    seed: u64,
    req: u64,
    streams: usize,
    packets: usize,
) -> Vec<Vec<f64>> {
    let model = LatencyModel::exp(1.0);
    (0..streams as u64)
        .map(|s| {
            let mut rng = Pcg64::with_stream(seed, 8000 + req * 64 + s);
            let mut t = 0.0;
            (0..packets)
                .map(|_| {
                    t += 0.1 + 0.2 * model.sample_scaled(1.0, &mut rng);
                    t
                })
                .collect()
        })
        .collect()
}

/// Rateless arm: the same lossy channel aimed at the *per-packet*
/// result frames. Three workers stream packets through chaos layers
/// that drop and reorder; the coordinator's per-`(stream, seq)` dedup,
/// stall timer, and `Redo` regeneration must still deliver a complete,
/// deterministic decode.
fn run_rateless_soak(
    seed: u64,
    requests: usize,
    chaos: bool,
) -> anyhow::Result<Vec<ServedDecode>> {
    let (mut transport, dialer) = LoopbackTransport::new();
    let mut server = ClusterServer::new(soak_config());
    let mut handles = Vec::new();
    for i in 0..3u64 {
        let cfg = WorkerConfig {
            name: format!("lossy-{i}"),
            ..WorkerConfig::default()
        };
        if chaos {
            let plan = FaultPlan {
                seed: seed ^ (200 + i),
                drop: 0.1,
                reorder: 0.2,
                ..FaultPlan::default()
            };
            handles.push(spawn_chaos_loopback_worker(&dialer, &cfg, &plan));
        } else {
            handles.extend(spawn_loopback_workers(&dialer, 1, &cfg));
        }
        anyhow::ensure!(
            server.accept_workers(&mut transport, 1, Duration::from_secs(10))? == 1,
            "lossy-{i} failed to register"
        );
    }
    let mut outs = Vec::new();
    for req in 0..requests {
        let plan = small_rateless_plan(seed.wrapping_add(req as u64));
        let schedules = rateless_schedules(seed, req as u64, 3, 12);
        outs.push(server.serve_rateless(
            &plan,
            T_MAX,
            Some(schedules.as_slice()),
            None,
        )?);
    }
    server.shutdown();
    for h in handles {
        let _ = h.join();
    }
    Ok(outs)
}

/// Recovered unknowns of two rateless arms must agree bit for bit.
fn rateless_bits_identical(a: &[ServedDecode], b: &[ServedDecode]) -> bool {
    let values = |outs: &[ServedDecode]| -> Vec<Vec<u64>> {
        outs.iter()
            .flat_map(|o| o.st.recover_values())
            .map(|v| {
                v.map_or(Vec::new(), |m| {
                    m.data().iter().map(|x| x.to_bits()).collect()
                })
            })
            .collect()
    };
    a.len() == b.len() && values(a) == values(b)
}

/// Every request must have fully recovered: nothing late, nothing
/// missing, all sub-products decoded.
fn assert_full_recovery(outs: &[ClusterOutcome], arm: &str) -> anyhow::Result<()> {
    for (req, out) in outs.iter().enumerate() {
        anyhow::ensure!(
            out.outcome.received == PACKETS
                && out.late == 0
                && out.missing() == 0
                && out.outcome.recovered == 9,
            "{arm} request {req}: received {} late {} missing {} recovered {}",
            out.outcome.received,
            out.late,
            out.missing(),
            out.outcome.recovered,
        );
    }
    Ok(())
}

/// Decode bits of two arms must agree request by request (`received`
/// and `late` are asserted separately; retry/corrupt counts may differ
/// with fault timing, the decode may not).
fn bits_identical(a: &[ClusterOutcome], b: &[ClusterOutcome]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b.iter()).all(|(x, y)| {
            x.outcome.c_hat.data() == y.outcome.c_hat.data()
                && x.outcome.loss.to_bits() == y.outcome.loss.to_bits()
        })
}

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    let requests = 6usize;
    let seed = ctx.seed;
    println!(
        "chaos soak: {requests} requests, {PACKETS} MDS packets over 3 lossy \
         workers (drop=0.05 corrupt=0.2) + 1 Byzantine (tamper=1)"
    );

    let (outs, quarantined) = run_soak(seed, requests)?;
    let mut table = CsvTable::new(&[
        "request", "received", "late", "recovered", "retries", "corrupt",
        "verify_failures", "norm_loss",
    ]);
    let (mut retries, mut corrupt, mut verify_failures) = (0usize, 0usize, 0usize);
    for (req, out) in outs.iter().enumerate() {
        println!(
            "  req {req}: received {:>2} late {} recovered {}/9 retries {} \
             corrupt {} verify_failures {} loss {:.4}",
            out.outcome.received,
            out.late,
            out.outcome.recovered,
            out.retries,
            out.corrupt,
            out.verify_failures,
            out.outcome.normalized_loss,
        );
        retries += out.retries;
        corrupt += out.corrupt;
        verify_failures += out.verify_failures;
        table.push_raw(vec![
            req.to_string(),
            out.outcome.received.to_string(),
            out.late.to_string(),
            out.outcome.recovered.to_string(),
            out.retries.to_string(),
            out.corrupt.to_string(),
            out.verify_failures.to_string(),
            format!("{:.6}", out.outcome.normalized_loss),
        ]);
    }
    assert_full_recovery(&outs, "soak")?;
    anyhow::ensure!(
        verify_failures >= 2,
        "the Byzantine worker must be caught at least twice (saw {verify_failures})"
    );
    anyhow::ensure!(quarantined == 1, "exactly the liar quarantined, saw {quarantined}");

    // the decode must not depend on fault timing: replay the identical
    // seeded stream on a fresh cluster and compare bits
    let (rerun, requarantined) = run_soak(seed, requests)?;
    assert_full_recovery(&rerun, "rerun")?;
    anyhow::ensure!(requarantined == 1, "rerun quarantined {requarantined}");
    let rerun_identical = bits_identical(&outs, &rerun);
    anyhow::ensure!(rerun_identical, "soak rerun must decode bit-identically");

    // honest runs must not be perturbed by verification at all, and the
    // transport must not leak into the math: loopback == TCP
    let honest_on = run_honest(seed, requests, true)?;
    let honest_off = run_honest(seed, requests, false)?;
    let tcp = run_tcp(seed, requests)?;
    assert_full_recovery(&honest_on, "honest")?;
    let verify_off_identical = bits_identical(&honest_on, &honest_off);
    let tcp_identical = bits_identical(&honest_on, &tcp);
    anyhow::ensure!(verify_off_identical, "verify on/off must decode identically");
    anyhow::ensure!(tcp_identical, "TCP and loopback must decode identically");
    // chaos changes the fault path, never the answer
    anyhow::ensure!(
        bits_identical(&outs, &honest_on),
        "faulted and honest streams must decode identically at full recovery"
    );

    // rateless arm: drop/reorder the per-packet result frames and
    // demand the same complete, deterministic decode as a clean channel
    let rl_chaos = run_rateless_soak(seed, requests, true)?;
    let rl_clean = run_rateless_soak(seed, requests, false)?;
    let rl_rerun = run_rateless_soak(seed, requests, true)?;
    let mut rl_retries = 0usize;
    for (req, out) in rl_chaos.iter().enumerate() {
        anyhow::ensure!(
            out.st.is_complete(),
            "rateless request {req}: only {}/9 unknowns recovered under \
             drop/reorder",
            out.st.num_recovered()
        );
        rl_retries += out.retries;
    }
    let rl_rerun_identical = rateless_bits_identical(&rl_chaos, &rl_rerun);
    let rl_clean_identical = rateless_bits_identical(&rl_chaos, &rl_clean);
    anyhow::ensure!(rl_rerun_identical, "rateless soak rerun must decode bit-identically");
    anyhow::ensure!(
        rl_clean_identical,
        "lossy and clean rateless channels must decode identically"
    );

    let full_recovery = true; // asserted above, per request
    println!(
        "chaos soak: requests={requests} verify_failures={verify_failures} \
         corrupt={corrupt} retries={retries} quarantined={quarantined} \
         full_recovery={full_recovery} rerun_identical={rerun_identical} \
         verify_off_identical={verify_off_identical} tcp_identical={tcp_identical}"
    );
    println!(
        "rateless soak: requests={requests} redo_retries={rl_retries} \
         rerun_identical={rl_rerun_identical} clean_identical={rl_clean_identical}"
    );
    ctx.write_csv("chaos_soak.csv", &table)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reduced-scale pin of the CI soak: the liar is quarantined, every
    /// request still fully recovers, and a replay decodes identically.
    #[test]
    fn chaos_soak_quarantines_the_liar_and_recovers_fully() {
        let (outs, quarantined) = run_soak(42, 2).unwrap();
        assert_full_recovery(&outs, "test").unwrap();
        assert_eq!(quarantined, 1);
        assert!(outs.iter().map(|o| o.verify_failures).sum::<usize>() >= 2);
        let (rerun, _) = run_soak(42, 2).unwrap();
        assert!(bits_identical(&outs, &rerun));
    }

    /// Reduced pin of the rateless arm: drop/reorder on the per-packet
    /// result frames still yields a complete decode, identical to a
    /// clean channel and to its own replay.
    #[test]
    fn rateless_soak_survives_drop_and_reorder() {
        let chaos = run_rateless_soak(43, 2, true).unwrap();
        for out in &chaos {
            assert!(out.st.is_complete());
        }
        let clean = run_rateless_soak(43, 2, false).unwrap();
        let rerun = run_rateless_soak(43, 2, true).unwrap();
        assert!(rateless_bits_identical(&chaos, &clean));
        assert!(rateless_bits_identical(&chaos, &rerun));
    }
}
