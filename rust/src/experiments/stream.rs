//! `api-stream`: the anytime client API as an experiment — a
//! deterministic loopback request stream served through
//! [`crate::api::Session`] + [`crate::api::PooledBackend`], recording
//! per-request loss, cache behavior, and the progressive-refinement
//! counts that make `Ĉ(t)` an anytime result.
//!
//! The stream has the DNN-training shape (two weight matrices cycle,
//! activations fresh per request) and sweeps the deadline, so the CSV
//! shows the paper's loss-vs-`T_max` trade-off *as served* (not
//! Monte-Carlo): loss falls as the deadline grows, repeated-`A`
//! requests hit the encoded-block cache, and every request's progress
//! stream is non-increasing in loss.

use crate::api::{PooledBackend, Request, Session};
use crate::coding::{CodeKind, CodeSpec};
use crate::config::SyntheticSpec;
use crate::rng::Pcg64;
use crate::util::csv::CsvTable;

use super::common::ExpContext;

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    let spec = SyntheticSpec::fig9_rxc().scaled(2 * ctx.scale_factor());
    let code = CodeSpec::stacked(CodeKind::EwUep(spec.gamma.clone()));
    let threads = ctx.threads.clamp(1, 8);
    let mut session = Session::builder()
        .partitioning(spec.part.clone())
        .code(code)
        .classes(spec.class_map())
        .workers(spec.workers)
        .latency(spec.latency.clone())
        .deadline(spec.t_max)
        .score(true)
        .seed(ctx.seed)
        .backend(PooledBackend::spawn(threads)?)
        .build()?;

    let deadlines = [0.3, 0.6, 1.2, 2.4];
    let n_weights = 2usize;
    let requests = deadlines.len() * n_weights;
    println!(
        "api-stream: {requests} requests, {} coded jobs over {threads} pooled \
         workers, Ω={:.2}, deadlines {deadlines:?}",
        session.workers(),
        session.omega_value()
    );

    let mut mats = Pcg64::with_stream(ctx.seed, 500);
    let weights: Vec<_> = (0..n_weights).map(|_| spec.sample_a(&mut mats)).collect();
    let mut table = CsvTable::new(&[
        "request", "a_id", "t_max", "received", "late", "recovered", "norm_loss",
        "refinements", "monotone", "cache_hit",
    ]);
    for req in 0..requests {
        let a_id = req % n_weights;
        let t_max = deadlines[req / n_weights];
        let b = spec.sample_b(&mut mats);
        let out = session.run(
            Request::new(a_id as u64, weights[a_id].clone(), b).deadline(t_max),
        )?;
        let monotone = out.progress.loss_non_increasing();
        println!(
            "  req {req}: A#{a_id} T_max={t_max:<4} received {:>2} recovered {}/{} \
             norm-loss {:.4} ({} refinements, monotone {monotone}, cache {})",
            out.outcome.received,
            out.outcome.recovered,
            spec.part.num_products(),
            out.outcome.normalized_loss,
            out.progress.refinements(),
            if out.cache_hit == Some(true) { "hit" } else { "miss" },
        );
        anyhow::ensure!(monotone, "progress loss must be non-increasing (r×c)");
        table.push_raw(vec![
            req.to_string(),
            a_id.to_string(),
            t_max.to_string(),
            out.outcome.received.to_string(),
            out.late.to_string(),
            out.outcome.recovered.to_string(),
            format!("{:.6}", out.outcome.normalized_loss),
            out.progress.refinements().to_string(),
            monotone.to_string(),
            (out.cache_hit == Some(true)).to_string(),
        ]);
    }
    let cache = session.cache_stats();
    println!(
        "  cache: {} hits / {} misses over the stream (one encode per weight \
         matrix)",
        cache.hits, cache.misses
    );
    session.shutdown()?;
    ctx.write_csv("api_stream.csv", &table)?;
    Ok(())
}
