//! Fig. 5 + Table II: mid-training snapshot of the MLP's gradients,
//! weights and inputs per layer — sparsity fractions under the eq. (34)
//! thresholds (τ_grad = 1e-5, τ_weight/input = 1e-4) and Gaussian MLE
//! fits of the dense remainder. This is the empirical motivation for
//! UEP protection: per-layer norm variation.

use crate::data::synthetic_digits;
use crate::nn::{
    softmax_xent, DistributedMatmul, MatmulStrategy, Mlp, TauSchedule,
};
use crate::rng::Pcg64;
use crate::util::csv::CsvTable;
use crate::util::plot::text_table;
use crate::util::stats::gaussian_fit_dense;

use super::ExpContext;

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    let mut rng = Pcg64::seed_from(ctx.seed);
    let (n_train, snapshot_iter) = if ctx.full { (60_000 / 4, 389) } else { (3_000, 80) };
    let train = synthetic_digits(n_train, 11, &mut rng);
    let mut mlp = Mlp::mnist(&mut rng);
    let mut engine = DistributedMatmul::new(MatmulStrategy::Exact, rng.split());
    let tau = TauSchedule::paper(3);
    let batch = 64;

    // train centrally up to the snapshot iteration (paper: it. 389/937)
    let mut snapshot: Option<(Vec<crate::linalg::Matrix>, Vec<crate::linalg::Matrix>)> =
        None;
    let mut order = crate::rng::permutation(&mut rng, train.len());
    let iters = (train.len() / batch).min(snapshot_iter + 1);
    for step in 0..iters {
        if order.len() < (step + 1) * batch {
            order = crate::rng::permutation(&mut rng, train.len());
        }
        let idx = &order[step * batch..(step + 1) * batch];
        let (x, y) = train.batch(idx);
        if step == iters - 1 {
            // capture the back-propagation operands at this iteration
            let (logits, acts) = mlp.forward(&x);
            let (_, g_out) = softmax_xent(&logits, &y);
            // gradients G_{i+1} entering each layer (before sparsification)
            let mut grads = Vec::new();
            let mut g = g_out.clone();
            for i in (0..3).rev() {
                grads.push(g.clone());
                if i > 0 {
                    let mut gp = crate::linalg::matmul(&g, &mlp.layers[i].v.transpose());
                    crate::nn::relu_backward(&mut gp, &acts[i]);
                    g = gp;
                }
            }
            grads.reverse();
            snapshot = Some((grads, acts));
        }
        mlp.train_step(&x, &y, 0.05, &mut engine, &tau, 0);
    }
    let (grads, acts) = snapshot.expect("snapshot captured");

    // Table II + Fig. 5 fits
    let tau_grad = 1e-5;
    let tau_wx = 1e-4;
    let mut t2 = CsvTable::new(&["layer", "grad_sparsity", "weight_sparsity", "input_sparsity"]);
    let mut fits = CsvTable::new(&["tensor", "layer", "sparsity", "mean", "variance"]);
    let mut rows = Vec::new();
    for layer in 0..3 {
        let gfit = gaussian_fit_dense(grads[layer].data(), tau_grad);
        let wfit = gaussian_fit_dense(mlp.layers[layer].v.data(), tau_wx);
        // inputs: X_i; layer 0's input is the raw image (paper marks "-")
        let xfit = gaussian_fit_dense(acts[layer].data(), tau_wx);
        t2.push_raw(vec![
            (layer + 1).to_string(),
            format!("{:.2}%", 100.0 * gfit.sparsity),
            format!("{:.2}%", 100.0 * wfit.sparsity),
            if layer == 0 { "-".into() } else { format!("{:.2}%", 100.0 * xfit.sparsity) },
        ]);
        for (tensor, fit) in [("gradient", gfit), ("weight", wfit), ("input", xfit)] {
            fits.push_raw(vec![
                tensor.into(),
                (layer + 1).to_string(),
                format!("{:.4}", fit.sparsity),
                format!("{:.3e}", fit.mean),
                format!("{:.3e}", fit.variance),
            ]);
        }
        rows.push(vec![
            (layer + 1).to_string(),
            format!("{:.2}%", 100.0 * gfit.sparsity),
            format!("{:.2}%", 100.0 * wfit.sparsity),
            if layer == 0 { "-".into() } else { format!("{:.2}%", 100.0 * xfit.sparsity) },
        ]);
    }
    println!("Table II — sparsity at snapshot iteration {snapshot_iter}:");
    println!("{}", text_table(&["Layer", "Gradients", "Weight", "Input"], &rows));
    ctx.write_csv("table2_sparsity.csv", &t2)?;
    ctx.write_csv("fig5_gaussian_fits.csv", &fits)?;

    // headline: gradient sparsity is substantial (paper: ~50-60%) and
    // the dense remainder is near-zero-mean
    let g1 = gaussian_fit_dense(grads[0].data(), tau_grad);
    println!(
        "  layer-1 gradient: sparsity {:.1}%, dense fit N({:.2e}, {:.2e})",
        100.0 * g1.sparsity,
        g1.mean,
        g1.variance
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_produces_sparsity_tables() {
        let dir = std::env::temp_dir().join("uepmm_fig5_test");
        let ctx = ExpContext {
            out: dir.clone(),
            trials: 10,
            full: false,
            seed: 3,
            threads: 4,
        };
        run(&ctx).unwrap();
        let t2 = std::fs::read_to_string(dir.join("table2_sparsity.csv")).unwrap();
        let table = CsvTable::parse(&t2).unwrap();
        assert_eq!(table.rows.len(), 3);
        // gradient sparsity should be non-trivial (paper reports ~50%+;
        // our synthetic run should at least show tens of percent)
        let s: f64 = table.rows[0][1].trim_end_matches('%').parse().unwrap();
        assert!(s > 5.0, "layer-1 gradient sparsity only {s}%");
    }
}
