//! `rateless`: fixed-rate EW-UEP vs the rateless UEP family under
//! drifting heterogeneous straggle — the work-conservation experiment.
//!
//! Both arms serve the *identical* request stream (same `A`, same fresh
//! `B`s) over the same six-worker fleet with the same per-packet pace:
//! worker `w` finishes its `k`-th unit of work at `(k+1)·base_w`, where
//! half the fleet is `SLOW_FACTOR`× slower and the whole fleet drifts
//! 1.5× slower halfway through the stream. The fixed-rate arm
//! pre-assigns `FIXED_JOBS` EW-UEP coded packets round-robin, so the
//! fast workers idle once their slots are exhausted while the coded
//! packets assigned to stragglers trickle in (or never arrive); the
//! rateless arm streams windowed LT packets (`CodeKind::Rateless`)
//! until the decoder drains the stream, so fast workers keep producing
//! and every straggler's early packets still earn partial credit.
//!
//! Measured per request, from the anytime progress stream: the time to
//! reach normalized loss `1e-1`, `1e-3`, and an exact decode (censored
//! at `T_max`), plus the straggler share of absorbed packets. Asserted:
//! the rateless arm reaches `1e-3` no later than fixed-rate EW on
//! average, the slowest workers contribute packets to every rateless
//! decode, and the decode is bit-identical across a rerun, across
//! in-process vs loopback-cluster serving, and with Freivalds
//! verification on vs off.

use std::time::Duration;

use crate::api::{ClusterBackend, InProcessBackend, Request, RunReport, Session};
use crate::cluster::{ClusterConfig, DeadlineMode, WorkerConfig};
use crate::coding::{CodeKind, CodeSpec, RatelessSpec};
use crate::config::SyntheticSpec;
use crate::latency::LatencyModel;
use crate::rng::Pcg64;
use crate::util::csv::CsvTable;

use super::common::ExpContext;

/// Physical workers (= rateless streams).
const STREAMS: usize = 6;
/// Workers `SLOW_FROM..STREAMS` are the heterogeneous stragglers.
const SLOW_FROM: usize = 3;
/// The stragglers' per-packet pace multiplier.
const SLOW_FACTOR: f64 = 4.0;
/// Fleet-wide slowdown from the drift point on.
const DRIFT_FACTOR: f64 = 1.5;
/// Coded packets of the fixed-rate arm (Ω = 36/45 = 0.8).
const FIXED_JOBS: usize = 45;
/// Deadline in virtual time units (≈ 40 fast-worker packet periods).
const T_MAX: f64 = 40.0;

struct Scenario {
    spec: SyntheticSpec,
    requests: usize,
    seed: u64,
}

impl Scenario {
    /// `blocks = 6` per side: `K = 36` sub-products, so the decoder
    /// provably cannot finish on the fast workers' packets alone before
    /// every straggler delivers — by any straggler's first packet
    /// (≤ 4.6 fast periods for any jitter draw) the three fast streams
    /// have produced at most 15 < 36 packets.
    fn new(scale: usize, requests: usize, seed: u64) -> Scenario {
        Scenario {
            spec: SyntheticSpec::fig9_rxc().scaled(scale).with_blocks(6),
            requests,
            seed,
        }
    }

    /// Per-worker packet pace of request `r`: unit base with a seeded
    /// ±15% jitter, `SLOW_FACTOR`× on the straggler half, and the
    /// fleet-wide drift from the midpoint on.
    fn bases(&self, r: usize) -> Vec<f64> {
        let drift = if r >= self.requests / 2 { DRIFT_FACTOR } else { 1.0 };
        let mut rng = Pcg64::with_stream(self.seed, 900 + r as u64);
        (0..STREAMS)
            .map(|w| {
                let jitter = 0.85 + 0.3 * rng.next_f64();
                let het = if w >= SLOW_FROM { SLOW_FACTOR } else { 1.0 };
                drift * jitter * het
            })
            .collect()
    }

    /// Fixed-rate completion times: slot `i` is the `(i/STREAMS)`-th
    /// sequential job of worker `i % STREAMS`.
    fn fixed_delays(&self, bases: &[f64]) -> Vec<f64> {
        (0..FIXED_JOBS)
            .map(|i| bases[i % STREAMS] * ((i / STREAMS) as f64 + 1.0))
            .collect()
    }
}

/// Per-request record of one arm.
#[derive(Clone, Debug, PartialEq)]
struct Served {
    tau_coarse: f64,
    tau_fine: f64,
    tau_exact: f64,
    received: usize,
    recovered: usize,
    norm_loss: f64,
    /// Fewest packets credited to any straggler stream (0 for the
    /// fixed-rate arm's report, which carries no per-stream credit).
    slow_packets: usize,
    /// Packets credited to the straggler half in total.
    slow_total: usize,
    /// Decode bits, for identity assertions across arms and reruns.
    c_bits: Vec<u64>,
}

/// First progress-event times at which the decode crosses each target
/// (censored at `T_MAX` when never reached).
fn served(report: &RunReport, k: usize) -> Served {
    let (mut tc, mut tf, mut te) = (T_MAX, T_MAX, T_MAX);
    for e in report.progress.events() {
        if e.normalized_loss <= 1e-1 {
            tc = tc.min(e.elapsed);
        }
        if e.normalized_loss <= 1e-3 {
            tf = tf.min(e.elapsed);
        }
        if e.recovered == k {
            te = te.min(e.elapsed);
        }
    }
    let slow: Vec<usize> = report.worker_packets[SLOW_FROM.min(report.worker_packets.len())..]
        .iter()
        .map(|&(_, c)| c)
        .collect();
    Served {
        tau_coarse: tc,
        tau_fine: tf,
        tau_exact: te,
        received: report.outcome.received,
        recovered: report.outcome.recovered,
        norm_loss: report.outcome.normalized_loss,
        slow_packets: slow.iter().copied().min().unwrap_or(0),
        slow_total: slow.iter().sum(),
        c_bits: report.outcome.c_hat.data().iter().map(|x| x.to_bits()).collect(),
    }
}

/// Serve the whole stream through one in-process session arm.
fn run_arm(sc: &Scenario, rateless: bool) -> anyhow::Result<Vec<Served>> {
    let code = if rateless {
        CodeSpec::stacked(CodeKind::Rateless(RatelessSpec::new(
            0.05,
            0.1,
            sc.spec.gamma.clone(),
        )))
    } else {
        CodeSpec::stacked(CodeKind::EwUep(sc.spec.gamma.clone()))
    };
    let workers = if rateless { STREAMS } else { FIXED_JOBS };
    let mut session = Session::builder()
        .partitioning(sc.spec.part.clone())
        .code(code)
        .classes(sc.spec.class_map())
        .workers(workers)
        .latency(LatencyModel::exp(1.0))
        .deadline(T_MAX)
        .score(true)
        .seed(sc.seed)
        .backend(InProcessBackend::serial())
        .build()?;
    serve_stream(sc, &mut session)
}

/// Rateless arm over the loopback cluster (Virtual deadline mode, the
/// injected pacing replayed deterministically), with Freivalds
/// verification on or off.
fn run_cluster_arm(sc: &Scenario, verify: bool) -> anyhow::Result<Vec<Served>> {
    let backend = ClusterBackend::loopback(
        STREAMS,
        ClusterConfig {
            deadline: DeadlineMode::Virtual,
            cache_capacity: 0,
            verify,
            ..ClusterConfig::default()
        },
        WorkerConfig { name: "loop".to_string(), ..WorkerConfig::default() },
        Duration::from_secs(10),
    )?;
    let mut session = Session::builder()
        .partitioning(sc.spec.part.clone())
        .code(CodeSpec::stacked(CodeKind::Rateless(RatelessSpec::new(
            0.05,
            0.1,
            sc.spec.gamma.clone(),
        ))))
        .classes(sc.spec.class_map())
        .workers(STREAMS)
        .latency(LatencyModel::exp(1.0))
        .deadline(T_MAX)
        .score(true)
        .seed(sc.seed)
        .backend(backend)
        .build()?;
    let rows = serve_stream(sc, &mut session)?;
    session.shutdown()?;
    Ok(rows)
}

/// The shared request loop: identical operands and pacing in every arm.
/// Fixed-rate sessions take the expanded per-slot delays; rateless
/// sessions take the per-stream bases (the session expands stream `s`
/// to completions `(k+1)·base_s`).
fn serve_stream(sc: &Scenario, session: &mut Session) -> anyhow::Result<Vec<Served>> {
    let rateless = session.workers() == STREAMS;
    let k = sc.spec.part.num_products();
    let mut mats = Pcg64::with_stream(sc.seed, 800);
    let a = sc.spec.sample_a(&mut mats);
    let mut rows = Vec::with_capacity(sc.requests);
    for r in 0..sc.requests {
        let b = sc.spec.sample_b(&mut mats);
        let bases = sc.bases(r);
        let delays = if rateless { bases } else { sc.fixed_delays(&bases) };
        let out = session.run(
            Request::new(0, a.clone(), b).deadline(T_MAX).delays(delays),
        )?;
        anyhow::ensure!(
            out.progress.loss_non_increasing(),
            "anytime loss must be non-increasing"
        );
        rows.push(served(&out, k));
    }
    Ok(rows)
}

fn mean(xs: impl Iterator<Item = f64>) -> f64 {
    let v: Vec<f64> = xs.collect();
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

fn bits_identical(a: &[Served], b: &[Served]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b.iter()).all(|(x, y)| x.c_bits == y.c_bits)
}

/// Core comparison, shared by the CLI experiment and the regression
/// test: serve both arms plus the identity reruns and check every
/// acceptance property.
fn compare(sc: &Scenario) -> anyhow::Result<(Vec<Served>, Vec<Served>)> {
    let fixed = run_arm(sc, false)?;
    let rl = run_arm(sc, true)?;
    let again = run_arm(sc, true)?;
    anyhow::ensure!(
        rl == again,
        "the rateless arm must be bit-reproducible across reruns"
    );
    let on = run_cluster_arm(sc, true)?;
    let off = run_cluster_arm(sc, false)?;
    anyhow::ensure!(
        bits_identical(&on, &off),
        "Freivalds verification on/off must not change the decode"
    );
    anyhow::ensure!(
        bits_identical(&rl, &on),
        "in-process and loopback-cluster rateless serving must decode \
         identically"
    );
    for (r, row) in rl.iter().enumerate() {
        anyhow::ensure!(
            row.slow_packets > 0,
            "request {r}: a straggler stream earned no rateless packet credit"
        );
    }
    let fx_fine = mean(fixed.iter().map(|s| s.tau_fine));
    let rl_fine = mean(rl.iter().map(|s| s.tau_fine));
    anyhow::ensure!(
        rl_fine <= fx_fine + 1e-9,
        "rateless must reach 1e-3 loss no later than fixed-rate EW: \
         rateless {rl_fine:.3} vs fixed {fx_fine:.3}"
    );
    Ok((fixed, rl))
}

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    let sc = Scenario::new(2 * ctx.scale_factor(), 12, ctx.seed);
    println!(
        "rateless: {} requests, K={} sub-products, {STREAMS} workers \
         ({} stragglers at {SLOW_FACTOR}x pace, fleet {DRIFT_FACTOR}x \
         slower from request {}), fixed-rate arm {FIXED_JOBS} EW packets, \
         T_max={T_MAX}",
        sc.requests,
        sc.spec.part.num_products(),
        STREAMS - SLOW_FROM,
        sc.requests / 2,
    );
    let (fixed, rl) = compare(&sc)?;

    let mut table = CsvTable::new(&[
        "arm", "request", "drifted", "tau_1e1", "tau_1e3", "tau_exact",
        "received", "recovered", "norm_loss", "slow_min_packets",
        "slow_fraction",
    ]);
    for (arm, rows) in [("fixed-ew", &fixed), ("rateless", &rl)] {
        for (r, s) in rows.iter().enumerate() {
            table.push_raw(vec![
                arm.to_string(),
                r.to_string(),
                (r >= sc.requests / 2).to_string(),
                format!("{:.4}", s.tau_coarse),
                format!("{:.4}", s.tau_fine),
                format!("{:.4}", s.tau_exact),
                s.received.to_string(),
                s.recovered.to_string(),
                format!("{:.6}", s.norm_loss),
                s.slow_packets.to_string(),
                format!("{:.4}", s.slow_total as f64 / s.received.max(1) as f64),
            ]);
        }
    }
    let half = sc.requests / 2;
    for (label, lo, hi) in
        [("pre-drift", 0, half), ("post-drift", half, sc.requests)]
    {
        println!(
            "  {label:<10} mean time-to-loss (1e-1 / 1e-3 / exact): \
             fixed {:.2} / {:.2} / {:.2}   rateless {:.2} / {:.2} / {:.2}",
            mean(fixed[lo..hi].iter().map(|s| s.tau_coarse)),
            mean(fixed[lo..hi].iter().map(|s| s.tau_fine)),
            mean(fixed[lo..hi].iter().map(|s| s.tau_exact)),
            mean(rl[lo..hi].iter().map(|s| s.tau_coarse)),
            mean(rl[lo..hi].iter().map(|s| s.tau_fine)),
            mean(rl[lo..hi].iter().map(|s| s.tau_exact)),
        );
    }
    println!(
        "  straggler credit: {:.3} of absorbed rateless packets on average \
         (min {} per straggler per request); decode bit-identical across \
         rerun, in-process vs cluster, and verify on/off",
        mean(rl.iter().map(|s| s.slow_total as f64 / s.received.max(1) as f64)),
        rl.iter().map(|s| s.slow_packets).min().unwrap_or(0),
    );
    ctx.write_csv("rateless.csv", &table)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance properties at test scale: rateless reaches 1e-3
    /// no later than fixed-rate EW under the drifting heterogeneous
    /// scenario, every straggler earns packet credit, and the decode is
    /// bit-identical across reruns, backends, and the verify toggle
    /// (all asserted inside `compare`).
    #[test]
    fn rateless_beats_fixed_rate_and_credits_the_stragglers() {
        let sc = Scenario::new(20, 4, 2021);
        let (fixed, rl) = compare(&sc).unwrap();
        assert_eq!(fixed.len(), sc.requests);
        assert_eq!(rl.len(), sc.requests);
        // every rateless request decodes exactly within the deadline
        for row in &rl {
            assert_eq!(row.recovered, sc.spec.part.num_products());
            assert!(row.tau_exact < T_MAX);
        }
    }
}
