//! `dnn-cluster`: the headline end-to-end scenario — an MLP trained
//! with its back-prop matmuls served by a *real* cluster fleet
//! ([`crate::api::ClusterBackend`] loopback workers) under drifting
//! heterogeneous straggle, comparing wall-clock-to-accuracy of four
//! arms:
//!
//! | arm | code | dispatch |
//! |---|---|---|
//! | `uncoded`    | one worker per sub-product  | least-outstanding |
//! | `mds`        | dense MDS                   | least-outstanding |
//! | `uep`        | EW-UEP (Table III Γ)        | least-outstanding |
//! | `uep-hetero` | EW-UEP + adaptive replan    | [`Assignment`] plan |
//!
//! Half the fleet is `SLOW_FACTOR`× slower at any time, and *which*
//! half drifts every [`Scenario::rounds_per_phase`] cluster rounds (via
//! [`crate::api::Backend::inject_straggle`] — the deterministic
//! injection hook). The hetero arm's adaptive session fits per-worker
//! scale offsets from job telemetry and pushes them down on the
//! replanner cadence, where [`ClusterConfig::hetero_assign`] plans the
//! slot→worker map so the most-protected (low-window) slots land on the
//! fastest workers.
//!
//! The cost metric is *virtual* time: each training matmul costs its
//! slowest absorbed result's delay capped at `T_max`
//! ([`crate::nn::DistributedMatmul::total_virtual_time`]), so the
//! comparison is bit-reproducible across machines, thread counts, and
//! wall-clock races. Asserted: the hetero arm reaches the target train
//! loss in no more virtual time than both the uncoded and the plain UEP
//! arms, every arm's preflight generous-deadline round fully recovers
//! through the real fleet, and the hetero arm is bit-identical across a
//! rerun (fresh fleet included).
//!
//! [`Assignment`]: crate::coordinator::Assignment
//! [`ClusterConfig::hetero_assign`]: crate::cluster::ClusterConfig::hetero_assign

use std::time::Duration;

use crate::api::{ClusterBackend, ReplanPolicy, SharedBackend};
use crate::cluster::{ClusterConfig, DeadlineMode, WorkerConfig};
use crate::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
use crate::data::synthetic_digits;
use crate::latency::LatencyModel;
use crate::linalg::{matmul, Matrix};
use crate::nn::{
    train_mlp, ClusterMatmulCfg, CodedMatmulCfg, DistributedMatmul,
    MatmulStrategy, Mlp, StraggleDrift, TauSchedule, TrainConfig, TrainRecord,
};
use crate::partition::Paradigm;
use crate::rng::Pcg64;
use crate::util::csv::CsvTable;

use super::common::ExpContext;

/// Physical loopback workers (registry ids `1..=FLEET`).
const FLEET: usize = 6;
/// Injected-delay multiplier of the slow half of the fleet.
const SLOW_FACTOR: f64 = 8.0;
/// Running train loss an arm must reach (10-class softmax starts at
/// `ln 10 ≈ 2.30`).
const TARGET_LOSS: f64 = 1.8;

struct Scenario {
    n_train: usize,
    n_test: usize,
    epochs: usize,
    max_iters_per_epoch: usize,
    batch: usize,
    lr: f64,
    /// Hidden layer widths of the MLP (input 784, output 10).
    hidden: Vec<usize>,
    /// Coded jobs per request for the coded arms (uncoded always uses
    /// one job per sub-product).
    coded_jobs: usize,
    t_max: f64,
    eval_every: usize,
    /// Cluster rounds served before the slow half of the fleet drifts.
    rounds_per_phase: usize,
    seed: u64,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Arm {
    Uncoded,
    Mds,
    Uep,
    UepHetero,
}

impl Arm {
    fn name(self) -> &'static str {
        match self {
            Arm::Uncoded => "uncoded",
            Arm::Mds => "mds",
            Arm::Uep => "uep",
            Arm::UepHetero => "uep-hetero",
        }
    }
}

struct ArmResult {
    rec: TrainRecord,
    /// Preflight generous-deadline round recovered every sub-product
    /// bit-exactly through the real fleet.
    full_recovery: bool,
}

impl Scenario {
    /// The coding/deadline setup of one arm. `blocks = 3` r×c (9
    /// sub-products, 3 importance classes as in Table III).
    fn coded(&self, arm: Arm) -> CodedMatmulCfg {
        let (spec, workers) = match arm {
            Arm::Uncoded => (CodeSpec::stacked(CodeKind::Uncoded), 9),
            Arm::Mds => (CodeSpec::stacked(CodeKind::Mds), self.coded_jobs),
            Arm::Uep | Arm::UepHetero => (
                CodeSpec::new(
                    CodeKind::EwUep(WindowPolynomial::paper_table3()),
                    EncodeStyle::Stacked,
                ),
                self.coded_jobs,
            ),
        };
        CodedMatmulCfg {
            paradigm: Paradigm::RowTimesCol,
            blocks: 3,
            spec,
            workers,
            latency: LatencyModel::exp(0.5),
            auto_omega: true,
            t_max: self.t_max,
            s_levels: 3,
        }
    }

    /// The drifting 3-of-6 slow fleet: which half is slow flips every
    /// phase.
    fn drift(&self) -> StraggleDrift {
        StraggleDrift {
            rounds_per_phase: self.rounds_per_phase,
            phases: vec![
                (1..=FLEET as u64 / 2).map(|w| (w, SLOW_FACTOR)).collect(),
                (FLEET as u64 / 2 + 1..=FLEET as u64)
                    .map(|w| (w, SLOW_FACTOR))
                    .collect(),
            ],
        }
    }

    fn replan_policy(&self) -> ReplanPolicy {
        ReplanPolicy {
            every: 8,
            min_samples: 24,
            sweeps: 2,
            t_star: Some(self.t_max),
            reband: false,
        }
    }
}

/// Spin up one arm's private loopback fleet behind a shared handle.
fn make_backend(hetero: bool) -> anyhow::Result<SharedBackend> {
    let backend = ClusterBackend::loopback(
        FLEET,
        ClusterConfig {
            deadline: DeadlineMode::Virtual,
            cache_capacity: 0,
            hetero_assign: hetero,
            ..ClusterConfig::default()
        },
        WorkerConfig { name: "dnn".to_string(), ..WorkerConfig::default() },
        Duration::from_secs(10),
    )?;
    Ok(SharedBackend::new(backend))
}

/// Train one arm end to end on its own fresh fleet.
fn run_arm(sc: &Scenario, arm: Arm) -> anyhow::Result<ArmResult> {
    let hetero = arm == Arm::UepHetero;
    let backend = make_backend(hetero)?;

    // preflight: one generous-deadline, injection-free round must
    // recover the exact product through the real fleet — the smoke
    // gate's `full_recovery` column
    let full_recovery = {
        let mut probe = DistributedMatmul::new(
            MatmulStrategy::Cluster(ClusterMatmulCfg {
                coded: CodedMatmulCfg { t_max: 1e6, ..sc.coded(arm) },
                backend: backend.clone(),
                adaptive: None,
                delay_seed: sc.seed ^ 0x9e37,
                drift: None,
            }),
            Pcg64::with_stream(sc.seed, 30),
        );
        let mut rng = Pcg64::with_stream(sc.seed, 31);
        let a = Matrix::randn(12, 10, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(10, 12, 0.0, 1.0, &mut rng);
        let got = probe.multiply(&a, &b);
        got.allclose(&matmul(&a, &b), 1e-9)
            && (probe.recovery_rate() - 1.0).abs() < 1e-12
    };

    let strategy = MatmulStrategy::Cluster(ClusterMatmulCfg {
        coded: sc.coded(arm),
        backend: backend.clone(),
        adaptive: if hetero { Some(sc.replan_policy()) } else { None },
        delay_seed: sc.seed ^ 0xd1f7,
        drift: Some(sc.drift()),
    });
    // identical data, model init, and batch order in every arm
    let mut rng = Pcg64::with_stream(sc.seed, 40);
    let train = synthetic_digits(sc.n_train, 11, &mut rng);
    let test = synthetic_digits(sc.n_test, 13, &mut rng);
    let mut dims = vec![784];
    dims.extend_from_slice(&sc.hidden);
    dims.push(10);
    let mut mlp = Mlp::new(&dims, &mut rng);
    let cfg = TrainConfig {
        lr: sc.lr,
        epochs: sc.epochs,
        batch: sc.batch,
        strategy,
        tau: TauSchedule::off(dims.len() - 1),
        seed: sc.seed ^ 0xbeef,
        eval_every: sc.eval_every,
        max_iters_per_epoch: sc.max_iters_per_epoch,
    };
    let rec = train_mlp(&mut mlp, &train, &test, &cfg);
    backend.shutdown_inner()?;
    Ok(ArmResult { rec, full_recovery })
}

/// Virtual time at the first evaluation point reaching the target loss.
fn time_to_target(rec: &TrainRecord) -> Option<f64> {
    rec.points
        .iter()
        .find(|p| p.train_loss <= TARGET_LOSS)
        .map(|p| p.virtual_time)
}

/// The trajectory as bits, for exact reproducibility comparison.
fn trajectory_bits(rec: &TrainRecord) -> Vec<(u64, u64, u64)> {
    rec.points
        .iter()
        .map(|p| {
            (p.train_loss.to_bits(), p.test_acc.to_bits(), p.virtual_time.to_bits())
        })
        .collect()
}

/// Core comparison shared by the CLI experiment and the smoke gate:
/// all four arms, the hetero arm twice (fresh fleet, bit-identical
/// trajectory), headline inequalities checked.
fn compare(sc: &Scenario) -> anyhow::Result<Vec<(Arm, ArmResult)>> {
    let mut results = Vec::new();
    for arm in [Arm::Uncoded, Arm::Mds, Arm::Uep, Arm::UepHetero] {
        results.push((arm, run_arm(sc, arm)?));
    }
    let again = run_arm(sc, Arm::UepHetero)?;
    let hetero = &results.last().expect("four arms").1;
    anyhow::ensure!(
        trajectory_bits(&hetero.rec) == trajectory_bits(&again.rec),
        "hetero arm must be bit-reproducible on a fresh fleet"
    );
    for (arm, r) in &results {
        anyhow::ensure!(
            r.full_recovery,
            "{}: generous-deadline preflight did not fully recover",
            arm.name()
        );
    }
    let tt = |arm: Arm| {
        results
            .iter()
            .find(|(a, _)| *a == arm)
            .and_then(|(_, r)| time_to_target(&r.rec))
            .unwrap_or(f64::INFINITY)
    };
    let (t_unc, t_uep, t_het) = (tt(Arm::Uncoded), tt(Arm::Uep), tt(Arm::UepHetero));
    anyhow::ensure!(
        t_het.is_finite(),
        "hetero arm never reached train loss {TARGET_LOSS}"
    );
    anyhow::ensure!(
        t_het <= t_unc + 1e-9,
        "hetero must reach loss {TARGET_LOSS} no later than uncoded: \
         {t_het:.3} vs {t_unc:.3}"
    );
    anyhow::ensure!(
        t_het <= t_uep + 1e-9,
        "hetero must reach loss {TARGET_LOSS} no later than plain UEP: \
         {t_het:.3} vs {t_uep:.3}"
    );
    Ok(results)
}

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    let sc = if ctx.full {
        Scenario {
            n_train: 3_840,
            n_test: 800,
            epochs: 3,
            max_iters_per_epoch: 0,
            batch: 64,
            lr: 0.1,
            hidden: vec![64, 32],
            coded_jobs: 12,
            t_max: 3.0,
            eval_every: 10,
            rounds_per_phase: 60,
            seed: ctx.seed,
        }
    } else {
        Scenario {
            n_train: 640,
            n_test: 200,
            epochs: 2,
            max_iters_per_epoch: 10,
            batch: 32,
            lr: 0.1,
            hidden: vec![32],
            coded_jobs: 12,
            t_max: 3.0,
            eval_every: 5,
            rounds_per_phase: 25,
            seed: ctx.seed,
        }
    };
    println!(
        "dnn-cluster: {} train / {} test, {} epochs x {} iters, {}-worker \
         fleet, 3-of-{} slow x{} drifting every {} rounds, T_max={}",
        sc.n_train,
        sc.n_test,
        sc.epochs,
        if sc.max_iters_per_epoch == 0 {
            sc.n_train / sc.batch
        } else {
            sc.max_iters_per_epoch
        },
        FLEET,
        FLEET,
        SLOW_FACTOR,
        sc.rounds_per_phase,
        sc.t_max,
    );
    let results = compare(&sc)?;

    let mut table = CsvTable::new(&[
        "arm",
        "epoch",
        "iter",
        "train_loss",
        "test_acc",
        "virtual_time",
        "recovery_rate",
        "full_recovery",
        "time_to_target",
    ]);
    for (arm, r) in &results {
        let tt = time_to_target(&r.rec);
        for p in &r.rec.points {
            table.push_raw(vec![
                arm.name().to_string(),
                p.epoch.to_string(),
                p.iter.to_string(),
                format!("{:.6}", p.train_loss),
                format!("{:.4}", p.test_acc),
                format!("{:.6}", p.virtual_time),
                format!("{:.4}", r.rec.recovery_rate),
                r.full_recovery.to_string(),
                tt.map_or("inf".to_string(), |t| format!("{t:.6}")),
            ]);
        }
    }
    for (arm, r) in &results {
        println!(
            "  {:<11} time-to-loss<={TARGET_LOSS}: {:>9}  final acc {:.3}  \
             recovery {:.3}  total virtual time {:.1}",
            arm.name(),
            time_to_target(&r.rec)
                .map_or("never".to_string(), |t| format!("{t:.1}")),
            r.rec.final_test_acc,
            r.rec.recovery_rate,
            r.rec.virtual_time,
        );
    }
    ctx.write_csv("dnn_cluster.csv", &table)?;
    Ok(())
}
