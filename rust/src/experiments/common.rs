//! Shared experiment plumbing: context (output dir, scale, seeds) and
//! the Monte-Carlo loss sweeps over synthetic Assumption-1 matrices.

use std::path::PathBuf;

use crate::coding::CodeSpec;
use crate::config::SyntheticSpec;
use crate::linalg::Matrix;
use crate::rng::Pcg64;
use crate::sim::{loss_trace_packets_scratch, StragglerSim, SweepScratch};
use crate::util::csv::CsvTable;
use crate::util::pool::{available_parallelism, parallel_map_scratch};

/// Common experiment options (from the CLI).
#[derive(Clone, Debug)]
pub struct ExpContext {
    /// Output directory for CSVs.
    pub out: PathBuf,
    /// Monte-Carlo trials per configuration.
    pub trials: usize,
    /// Paper-scale run (full matrix sizes / dataset sizes / epochs).
    pub full: bool,
    pub seed: u64,
    pub threads: usize,
}

impl Default for ExpContext {
    fn default() -> Self {
        ExpContext {
            out: PathBuf::from("results"),
            trials: 400,
            full: false,
            seed: 2021,
            threads: available_parallelism(),
        }
    }
}

impl ExpContext {
    /// Matrix-size divisor: paper scale when `--full`, 6× smaller dims
    /// otherwise (same block structure, ~200× fewer flops).
    pub fn scale_factor(&self) -> usize {
        if self.full {
            1
        } else {
            6
        }
    }

    /// Write a CSV table and echo the path.
    pub fn write_csv(&self, name: &str, table: &CsvTable) -> anyhow::Result<()> {
        let path = self.out.join(name);
        table.write(&path)?;
        println!("  wrote {}", path.display());
        Ok(())
    }
}

/// Monte-Carlo estimate of the *normalized expected loss at deadline t*
/// for each t in `ts`: fresh Assumption-1 matrices every `instance`,
/// fresh packets + arrivals every trial, loss read from the Gram matrix.
pub fn mc_loss_vs_time(
    spec: &SyntheticSpec,
    code: &CodeSpec,
    ts: &[f64],
    instances: usize,
    trials_per_instance: usize,
    seed: u64,
    threads: usize,
) -> Vec<f64> {
    let sums = mc_sweep(
        spec,
        code,
        instances,
        trials_per_instance,
        seed,
        threads,
        |trace, energy| {
            ts.iter()
                .map(|&t| crate::sim::loss_at(trace, t) / energy)
                .collect::<Vec<f64>>()
        },
    );
    sums
}

/// Monte-Carlo estimate of the normalized loss after exactly `w`
/// received packets, for `w = 0..=workers`.
pub fn mc_loss_vs_packets(
    spec: &SyntheticSpec,
    code: &CodeSpec,
    instances: usize,
    trials_per_instance: usize,
    seed: u64,
    threads: usize,
) -> Vec<f64> {
    mc_sweep(
        spec,
        code,
        instances,
        trials_per_instance,
        seed,
        threads,
        |trace, energy| {
            // trace[i] is the state after i arrivals
            trace.iter().map(|p| p.loss / energy).collect::<Vec<f64>>()
        },
    )
}

/// Shared sweep skeleton: returns the per-point mean of `f(trace)`.
///
/// The whole `instances × trials_per_instance` grid fans out across the
/// pool in one flat work list with per-thread [`SweepScratch`] reuse —
/// an incoming trial only allocates its packet set, arrival vector, and
/// trace. Trial `(inst, t)` draws from stream `t+1` of
/// `seed ^ (inst << 32)` (the historical per-instance seeding) and the
/// accumulation runs in trial order, so sweep outputs are bit-identical
/// at any thread count.
fn mc_sweep<F>(
    spec: &SyntheticSpec,
    code: &CodeSpec,
    instances: usize,
    trials_per_instance: usize,
    seed: u64,
    threads: usize,
    f: F,
) -> Vec<f64>
where
    F: Fn(&[crate::sim::LossTracePoint], f64) -> Vec<f64> + Sync,
{
    let cm = spec.class_map();
    let sim = StragglerSim::new(spec.workers, spec.latency.clone(), spec.omega());
    // per-instance Assumption-1 draws (cheap next to the trial fan-out)
    let insts: Vec<(Matrix, f64)> = (0..instances)
        .map(|inst| {
            let mut rng = Pcg64::with_stream(seed, 1000 + inst as u64);
            let (a, b) = spec.sample_matrices(&mut rng);
            let gram = spec.part.gram(&spec.part.true_products(&a, &b));
            let energy = gram_energy(&spec.part, &gram);
            (gram, energy)
        })
        .collect();
    let total = instances * trials_per_instance;
    let per_trial: Vec<Vec<f64>> = parallel_map_scratch(
        total,
        threads,
        SweepScratch::new,
        |idx, scratch| {
            let inst = idx / trials_per_instance;
            let trial = idx % trials_per_instance;
            let (gram, energy) = &insts[inst];
            let mut rng =
                Pcg64::with_stream(seed ^ ((inst as u64) << 32), trial as u64 + 1);
            let packets = code.generate_packets(&spec.part, &cm, spec.workers, &mut rng);
            let arrivals = sim.sample_arrivals(&mut rng);
            let trace = loss_trace_packets_scratch(
                &spec.part, code, gram, &packets, &arrivals, scratch,
            );
            f(&trace, *energy)
        },
    );
    let mut acc: Vec<f64> = Vec::new();
    let mut count = 0usize;
    for row in per_trial {
        if acc.is_empty() {
            acc = vec![0.0; row.len()];
        }
        for (a, v) in acc.iter_mut().zip(row.iter()) {
            *a += v;
        }
        count += 1;
    }
    for a in acc.iter_mut() {
        *a /= count.max(1) as f64;
    }
    acc
}

/// `‖C‖²_F` from the Gram matrix (loss with nothing recovered).
pub fn gram_energy(part: &crate::partition::Partitioning, gram: &Matrix) -> f64 {
    part.loss_from_gram(gram, &vec![false; part.num_products()])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodeKind, EncodeStyle};

    #[test]
    fn mc_time_sweep_is_monotone_and_normalized() {
        let spec = crate::config::SyntheticSpec::fig9_rxc().scaled(15);
        let code = CodeSpec::new(
            CodeKind::EwUep(spec.gamma.clone()),
            EncodeStyle::Stacked,
        );
        let ts = crate::util::linspace(0.0, 3.0, 7);
        let losses = mc_loss_vs_time(&spec, &code, &ts, 2, 40, 9, 4);
        assert_eq!(losses.len(), 7);
        assert!((losses[0] - 1.0).abs() < 1e-9, "t=0 loss {}", losses[0]);
        for w in losses.windows(2) {
            assert!(w[1] <= w[0] + 1e-9);
        }
        assert!(losses[6] < 0.2, "loss at t=3: {}", losses[6]);
    }

    /// Determinism pin: the parallel scratch-reusing sweep must produce
    /// bit-identical results at 1 thread and N threads.
    #[test]
    fn mc_sweep_bit_identical_across_thread_counts() {
        let spec = crate::config::SyntheticSpec::fig9_rxc().scaled(15);
        let code = CodeSpec::new(
            CodeKind::EwUep(spec.gamma.clone()),
            EncodeStyle::Stacked,
        );
        let ts = [0.3, 0.9, 1.5];
        let serial = mc_loss_vs_time(&spec, &code, &ts, 2, 25, 7, 1);
        for threads in [2usize, 8] {
            let parallel = mc_loss_vs_time(&spec, &code, &ts, 2, 25, 7, threads);
            assert_eq!(serial, parallel, "threads={threads}");
        }
    }

    #[test]
    fn mc_packet_sweep_ends_at_zero_for_mds() {
        let spec = crate::config::SyntheticSpec::fig9_cxr().scaled(15);
        let code = CodeSpec::stacked(CodeKind::Mds);
        let losses = mc_loss_vs_packets(&spec, &code, 1, 30, 11, 4);
        assert_eq!(losses.len(), spec.workers + 1);
        assert!((losses[0] - 1.0).abs() < 1e-9);
        // before 9 packets nothing decodes
        for &l in &losses[..9] {
            assert!((l - 1.0).abs() < 1e-9);
        }
        assert!(losses[9].abs() < 1e-9);
    }
}
