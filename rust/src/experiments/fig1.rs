//! Fig. 1: CIFAR-style CNN training accuracy vs epoch under straggler
//! strategies (λ = 0.5, T_max = 1, Table VII encodings). Convolutions
//! are computed centrally; the dense layers' back-propagation matmuls
//! are coded — except the last layer's eq. (33), kept uncoded as in the
//! paper (§VII-C).
//!
//! Headline shape: after sparsification kicks in, the UEP curves pull
//! away from uncoded/repetition toward the no-straggler curve.

use crate::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
use crate::config::EncodingRow;
use crate::data::synthetic_cifar;
use crate::latency::LatencyModel;
use crate::nn::{
    accuracy, Cnn, CnnArch, CodedMatmulCfg, DistributedMatmul, MatmulStrategy,
    TauSchedule,
};
use crate::partition::Paradigm;
use crate::rng::Pcg64;
use crate::util::csv::CsvTable;
use crate::util::plot::{render, Series};

use super::ExpContext;

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    let (arch, n_train, n_test, epochs, batch) = if ctx.full {
        (CnnArch::paper(), 10_000, 1_000, 40, 64)
    } else {
        (CnnArch::small(), 800, 200, 14, 16)
    };
    let gamma = WindowPolynomial::paper_table3();
    let t_max = 1.0;
    let mk_coded = |kind: CodeKind, row: EncodingRow| -> MatmulStrategy {
        let (workers, _) = row.params();
        MatmulStrategy::Coded(CodedMatmulCfg {
            paradigm: Paradigm::RowTimesCol,
            blocks: 3,
            // the paper's eq. (17) rank-one encoding (see mnist.rs)
            spec: CodeSpec::new(
                kind.clone(),
                match kind {
                    CodeKind::NowUep(_) | CodeKind::EwUep(_) => EncodeStyle::RankOne,
                    _ => EncodeStyle::Stacked,
                },
            ),
            workers,
            latency: LatencyModel::exp(0.5),
            auto_omega: true,
            t_max,
            s_levels: 3,
        })
    };
    let configs: Vec<(&str, MatmulStrategy)> = vec![
        ("no-straggler", MatmulStrategy::Exact),
        ("uncoded", mk_coded(CodeKind::Uncoded, EncodingRow::Uncoded)),
        ("now-uep", mk_coded(CodeKind::NowUep(gamma.clone()), EncodingRow::Uep)),
        ("ew-uep", mk_coded(CodeKind::EwUep(gamma), EncodingRow::Uep)),
        ("2-rep", mk_coded(CodeKind::Repetition, EncodingRow::TwoBlockRep)),
    ];

    let mut table = CsvTable::new(&["strategy", "epoch", "train_loss", "test_acc"]);
    let mut series = Vec::new();
    for (name, strategy) in configs {
        let mut rng = Pcg64::seed_from(ctx.seed);
        let train = synthetic_cifar(n_train, arch.side, 3, &mut rng);
        let test = synthetic_cifar(n_test, arch.side, 5, &mut rng);
        let mut cnn = Cnn::init(arch, &mut rng);
        let mut engine = DistributedMatmul::new(strategy, rng.split());
        let tau = TauSchedule::paper(3);
        let (tx, ty) = test.all();
        let iters = n_train / batch;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for epoch in 0..epochs {
            let order = crate::rng::permutation(&mut rng, train.len());
            let mut loss_sum = 0.0;
            for step in 0..iters {
                let idx = &order[step * batch..(step + 1) * batch];
                let (x, y) = train.batch(idx);
                loss_sum +=
                    cnn.train_step(&x, &y, 0.1, &mut engine, &tau, epoch, false);
            }
            let acc = accuracy(&cnn.logits(&tx), &ty);
            table.push_raw(vec![
                name.into(),
                epoch.to_string(),
                format!("{:.4}", loss_sum / iters as f64),
                format!("{:.4}", acc),
            ]);
            xs.push(epoch as f64);
            ys.push(acc);
        }
        println!(
            "  {name:<12} final acc {:.3} (recovered {:.0}% of coded sub-products)",
            ys.last().unwrap(),
            100.0 * engine.recovery_rate()
        );
        series.push(Series::new(name, xs, ys));
    }
    println!(
        "{}",
        render("Fig. 1 — CIFAR-like accuracy vs epoch (T_max = 1)", &series, 64, 16)
    );
    ctx.write_csv("fig1_cifar_accuracy.csv", &table)?;
    Ok(())
}
