//! Fig. 8: per-class decoding probabilities of the NOW-UEP and EW-UEP
//! strategies vs the number of received packets, for three classes with
//! `k = (3,3,3)`, `Γ = (0.40, 0.35, 0.25)`, `W = 30` — pure analysis
//! (eqs. 20–21 and [19, eqs. 6–9]).

use crate::analysis::{ew_decode_prob, now_decode_prob};
use crate::util::csv::CsvTable;
use crate::util::plot::{render, Series};

use super::ExpContext;

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    let gamma = [0.40, 0.35, 0.25];
    let k = [3usize, 3, 3];
    let w = 30usize;
    let mut table = CsvTable::new(&[
        "received", "now_c1", "now_c2", "now_c3", "ew_c1", "ew_c2", "ew_c3",
    ]);
    let mut series: Vec<Series> = Vec::new();
    let mut cols: Vec<Vec<f64>> = vec![Vec::new(); 6];
    let ns: Vec<f64> = (0..=w).map(|n| n as f64).collect();
    for n in 0..=w {
        let mut row = vec![n as f64];
        for l in 0..3 {
            let p = now_decode_prob(n, &gamma, &k, l);
            row.push(p);
            cols[l].push(p);
        }
        for l in 0..3 {
            let p = ew_decode_prob(n, &gamma, &k, l);
            row.push(p);
            cols[3 + l].push(p);
        }
        table.push_f64(&row);
    }
    for (i, name) in ["NOW c1", "NOW c2", "NOW c3", "EW c1", "EW c2", "EW c3"]
        .iter()
        .enumerate()
    {
        series.push(Series::new(name, ns.clone(), cols[i].clone()));
    }
    println!("{}", render("Fig. 8 — decoding probability vs received packets", &series, 64, 16));
    ctx.write_csv("fig8_decoding_probabilities.csv", &table)?;

    // headline checks (paper: class 1 is protected hardest)
    let p1_at_10 = now_decode_prob(10, &gamma, &k, 0);
    let p3_at_10 = now_decode_prob(10, &gamma, &k, 2);
    println!(
        "  NOW @N=10: class1 {:.3} vs class3 {:.3} (stronger protection for class 1: {})",
        p1_at_10,
        p3_at_10,
        p1_at_10 > p3_at_10
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_writes_csv_with_expected_shape() {
        let dir = std::env::temp_dir().join("uepmm_fig8_test");
        let ctx = ExpContext { out: dir.clone(), ..Default::default() };
        run(&ctx).unwrap();
        let text =
            std::fs::read_to_string(dir.join("fig8_decoding_probabilities.csv")).unwrap();
        let table = CsvTable::parse(&text).unwrap();
        assert_eq!(table.rows.len(), 31);
        let now_c1 = table.col_f64("now_c1").unwrap();
        let ew_c1 = table.col_f64("ew_c1").unwrap();
        // EW dominates NOW on class 1 at every packet count
        for (e, n) in ew_c1.iter().zip(now_c1.iter()) {
            assert!(e + 1e-9 >= *n);
        }
        assert!(now_c1[30] > 0.999);
    }
}
