//! `adaptive`: static-Γ vs adaptive-Γ served loss under a drifting
//! heterogeneous straggle scenario — the closed planning loop as an
//! experiment.
//!
//! Both arms serve the *identical* request stream (same `A`, same fresh
//! `B`s, same injected per-job completion times) through an in-process
//! session that *assumes* the paper's `Exp(λ=1)` latency model. Halfway
//! through the stream the actual straggle drifts: the fleet slows to
//! `Exp(λ_drift)` and a third of the slots slow down by a further
//! constant factor. The static arm keeps the Table III window
//! polynomial; the adaptive arm ([`crate::api::SessionBuilder::adaptive`])
//! fits a latency model from the observed timings and re-optimizes Γ on
//! its cadence — replan decisions are visible in the progress stream,
//! and the post-drift served loss must not exceed the static arm's.
//!
//! Everything is seeded and the backend is serial in-process with
//! injected delays, so the whole comparison is bit-identical across
//! runs and thread counts (asserted by running the adaptive arm twice).

use crate::api::{InProcessBackend, ReplanPolicy, Request, Session};
use crate::coding::{CodeKind, CodeSpec};
use crate::config::SyntheticSpec;
use crate::latency::LatencyModel;
use crate::rng::Pcg64;
use crate::util::csv::CsvTable;

use super::common::ExpContext;

/// The drifting heterogeneous scenario.
struct Scenario {
    spec: SyntheticSpec,
    requests: usize,
    /// Target deadline (virtual time units) — also the replan `t*`.
    t_max: f64,
    /// Fleet rate after the drift point (`Exp(1)` before).
    lambda_drift: f64,
    /// Extra slowdown of the heterogeneous slow group after the drift.
    slow_factor: f64,
    seed: u64,
}

impl Scenario {
    /// Injected completion times of request `r`: `Exp(λ_r)` scaled by Ω,
    /// with the first third of the slots `slow_factor`× slower after the
    /// drift point. The scenario RNG is independent of both sessions.
    fn delays(&self, r: usize, rng: &mut Pcg64) -> Vec<f64> {
        let drifted = r >= self.requests / 2;
        let lambda = if drifted { self.lambda_drift } else { 1.0 };
        let model = LatencyModel::exp(lambda);
        let omega = self.spec.omega();
        let slow_slots = self.spec.workers / 3;
        (0..self.spec.workers)
            .map(|w| {
                let d = model.sample_scaled(omega, rng);
                if drifted && w < slow_slots {
                    d * self.slow_factor
                } else {
                    d
                }
            })
            .collect()
    }
}

/// Per-request record of one arm.
#[derive(Clone, Debug, PartialEq)]
struct Served {
    received: usize,
    late: usize,
    recovered: usize,
    norm_loss: f64,
    replans: usize,
    gamma: Vec<f64>,
    cache_hit: bool,
}

/// Serve the whole scenario stream through one session arm.
fn run_arm(sc: &Scenario, adaptive: bool) -> anyhow::Result<Vec<Served>> {
    let code = CodeSpec::stacked(CodeKind::EwUep(sc.spec.gamma.clone()));
    let mut builder = Session::builder()
        .partitioning(sc.spec.part.clone())
        .code(code)
        .classes(sc.spec.class_map())
        .workers(sc.spec.workers)
        // what the planner *assumes* — the scenario will drift away
        .latency(LatencyModel::exp(1.0))
        .deadline(sc.t_max)
        .score(true)
        .seed(sc.seed)
        .backend(InProcessBackend::serial());
    if adaptive {
        builder = builder.adaptive(ReplanPolicy {
            every: 4,
            min_samples: 16,
            sweeps: 4,
            t_star: Some(sc.t_max),
            reband: false,
        });
    }
    let mut session = builder.build()?;

    // identical matrices and injected delays in every arm: fresh RNGs
    // from the scenario seed
    let mut mats = Pcg64::with_stream(sc.seed, 700);
    let mut straggle = Pcg64::with_stream(sc.seed, 701);
    let a = sc.spec.sample_a(&mut mats);
    let mut rows = Vec::with_capacity(sc.requests);
    for r in 0..sc.requests {
        let b = sc.spec.sample_b(&mut mats);
        let d = sc.delays(r, &mut straggle);
        let out = session.run(
            Request::new(0, a.clone(), b).deadline(sc.t_max).delays(d),
        )?;
        anyhow::ensure!(
            out.progress.loss_non_increasing(),
            "anytime loss must be non-increasing (r×c)"
        );
        rows.push(Served {
            received: out.outcome.received,
            late: out.late,
            recovered: out.outcome.recovered,
            norm_loss: out.outcome.normalized_loss,
            replans: out.progress.replans().len(),
            gamma: session
                .current_gamma()
                .expect("EW codes carry a window polynomial")
                .probs()
                .to_vec(),
            cache_hit: out.cache_hit == Some(true),
        });
    }
    Ok(rows)
}

fn mean(xs: impl Iterator<Item = f64>) -> f64 {
    let v: Vec<f64> = xs.collect();
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

/// Core comparison, shared by the CLI experiment and the regression
/// test: serve both arms (the adaptive one twice, pinning bit-identical
/// replan decisions), check the headline inequality, and return
/// `(static rows, adaptive rows)`.
fn compare(sc: &Scenario) -> anyhow::Result<(Vec<Served>, Vec<Served>)> {
    let stat = run_arm(sc, false)?;
    let adap = run_arm(sc, true)?;
    let again = run_arm(sc, true)?;
    anyhow::ensure!(
        adap == again,
        "adaptive arm must be bit-reproducible (same seed, same replans)"
    );
    let total_replans: usize = adap.iter().map(|s| s.replans).sum();
    anyhow::ensure!(
        total_replans >= 1,
        "the adaptive session never replanned — cadence misconfigured?"
    );
    let half = sc.requests / 2;
    let stat_drift = mean(stat[half..].iter().map(|s| s.norm_loss));
    let adap_drift = mean(adap[half..].iter().map(|s| s.norm_loss));
    anyhow::ensure!(
        adap_drift <= stat_drift + 1e-9,
        "adaptive-Γ must not lose to static-Γ under drift: \
         adaptive {adap_drift:.4} vs static {stat_drift:.4}"
    );
    Ok((stat, adap))
}

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    let sc = Scenario {
        spec: SyntheticSpec::fig9_rxc().scaled(2 * ctx.scale_factor()),
        requests: 40,
        t_max: 2.5,
        lambda_drift: 0.2,
        slow_factor: 4.0,
        seed: ctx.seed,
    };
    println!(
        "adaptive: {} requests over {} coded jobs, T_max={}, drift to \
         exp:{} (+{}x on {} slots) at request {}",
        sc.requests,
        sc.spec.workers,
        sc.t_max,
        sc.lambda_drift,
        sc.slow_factor,
        sc.spec.workers / 3,
        sc.requests / 2,
    );
    let (stat, adap) = compare(&sc)?;

    let mut table = CsvTable::new(&[
        "arm", "request", "drifted", "received", "late", "recovered",
        "norm_loss", "replans", "gamma0", "gamma1", "gamma2", "cache_hit",
    ]);
    for (arm, rows) in [("static", &stat), ("adaptive", &adap)] {
        for (r, s) in rows.iter().enumerate() {
            table.push_raw(vec![
                arm.to_string(),
                r.to_string(),
                (r >= sc.requests / 2).to_string(),
                s.received.to_string(),
                s.late.to_string(),
                s.recovered.to_string(),
                format!("{:.6}", s.norm_loss),
                s.replans.to_string(),
                format!("{:.4}", s.gamma[0]),
                format!("{:.4}", s.gamma[1]),
                format!("{:.4}", s.gamma[2]),
                s.cache_hit.to_string(),
            ]);
        }
    }
    let half = sc.requests / 2;
    for (label, lo, hi) in
        [("pre-drift", 0, half), ("post-drift", half, sc.requests)]
    {
        let s = mean(stat[lo..hi].iter().map(|x| x.norm_loss));
        let a = mean(adap[lo..hi].iter().map(|x| x.norm_loss));
        println!("  {label:<10} mean norm-loss: static {s:.4}  adaptive {a:.4}");
    }
    let final_gamma = &adap.last().expect("non-empty stream").gamma;
    println!(
        "  replans: {}; final adaptive Γ = [{:.3}, {:.3}, {:.3}] \
         (Table III was [0.400, 0.350, 0.250])",
        adap.iter().map(|s| s.replans).sum::<usize>(),
        final_gamma[0],
        final_gamma[1],
        final_gamma[2],
    );
    ctx.write_csv("adaptive.csv", &table)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance property at test scale: under the drifting
    /// heterogeneous scenario the adaptive arm replans (visibly, through
    /// the progress stream), never loses to the static arm post-drift,
    /// and reproduces bit-identically.
    #[test]
    fn adaptive_gamma_beats_static_under_drift_and_is_deterministic() {
        let sc = Scenario {
            spec: SyntheticSpec::fig9_rxc().scaled(15),
            requests: 24,
            t_max: 2.5,
            lambda_drift: 0.2,
            slow_factor: 4.0,
            seed: 2021,
        };
        let (stat, adap) = compare(&sc).unwrap();
        assert_eq!(stat.len(), sc.requests);
        assert_eq!(adap.len(), sc.requests);
        // pre-replan prefixes are identical streams: the arms only
        // diverge once a replan swaps Γ
        let first_replan = adap
            .iter()
            .position(|s| s.replans > 0)
            .expect("at least one replan");
        for r in 0..first_replan {
            assert_eq!(
                stat[r].norm_loss.to_bits(),
                adap[r].norm_loss.to_bits(),
                "request {r} precedes the first replan"
            );
        }
        // the re-optimized polynomial shifts mass toward the heavy
        // window once arrivals become scarce
        let last = adap.last().unwrap();
        assert!(
            last.gamma[0] > 0.40,
            "post-drift Γ must favor window 0: {:?}",
            last.gamma
        );
        // a Γ swap re-keys the encode cache exactly once per swap: the
        // request after a replan misses, later ones hit again
        assert!(
            adap[first_replan..].iter().any(|s| s.cache_hit),
            "the re-keyed encoding must be reused across the stream"
        );
    }
}
