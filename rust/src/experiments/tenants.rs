//! `tenants`: multi-tenant serve-plane fairness — three clients stream
//! concurrently through one loopback [`ServePlane`] and the served
//! wall-latency distribution per tenant (p50/p99) is compared against a
//! solo baseline of the same workload on an idle plane.
//!
//! Not a paper figure: the paper serves one multiplication at a time.
//! This experiment characterizes the PR-8 deployment shape — deficit-
//! round-robin sharing of one worker fleet — and is the source of the
//! `service_request_p50/p99` entries in the benchmark snapshot. The
//! headline check: under 3-way concurrency no tenant's median latency
//! collapses relative to the others' (DRR bounds the spread), and every
//! request still fully recovers.

use std::thread;

use crate::api::{ClusterBackend, Request, RunReport, Session};
use crate::cluster::{
    spawn_loopback_workers, Connection, LoopbackDialer, LoopbackTransport,
    ServePlane, ServiceConfig, WorkerConfig,
};
use crate::coding::{CodeKind, CodeSpec, WindowPolynomial};
use crate::linalg::Matrix;
use crate::partition::{ClassMap, Partitioning};
use crate::rng::Pcg64;
use crate::util::csv::CsvTable;

use super::common::ExpContext;

const TENANTS: usize = 3;
const FLEET: usize = 3;

fn part() -> Partitioning {
    Partitioning::rxc(3, 3, 4, 5, 4)
}

fn pinned_cm() -> ClassMap {
    let pair = crate::partition::default_pair_classes(3);
    ClassMap::from_levels(&part(), vec![0, 1, 2], vec![0, 1, 2], &pair)
}

/// One tenant's stream: repeated-`A`, fresh `B` per request, a deadline
/// far above every sampled delay so full recovery is expected.
fn run_tenant(
    dialer: &LoopbackDialer,
    name: &str,
    seed: u64,
    requests: usize,
) -> Vec<RunReport> {
    let conn: Box<dyn Connection> = Box::new(dialer.dial(name).unwrap());
    let backend = ClusterBackend::connect_over(conn, name).unwrap();
    let mut session = Session::builder()
        .partitioning(part())
        .code(CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3())))
        .classes(pinned_cm())
        .workers(14)
        .latency(crate::latency::LatencyModel::exp(1.0))
        .deadline(50.0)
        .score(true)
        .seed(seed)
        .backend(backend)
        .build()
        .unwrap();
    let mut mats = Pcg64::with_stream(seed, 1);
    let a = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
    let mut reports = Vec::new();
    for _ in 0..requests {
        let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
        reports.push(session.run(Request::new(0, a.clone(), b)).unwrap());
    }
    session.shutdown().unwrap();
    reports
}

fn percentile_ms(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

fn stats(reports: &[RunReport]) -> (f64, f64, bool) {
    let mut ms: Vec<f64> =
        reports.iter().map(|r| r.wall.as_secs_f64() * 1e3).collect();
    ms.sort_by(f64::total_cmp);
    let k = part().num_products();
    let full = reports.iter().all(|r| r.outcome.recovered == k);
    (percentile_ms(&ms, 0.5), percentile_ms(&ms, 0.99), full)
}

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    let requests = if ctx.full { 16 } else { 6 };
    println!(
        "tenants: {TENANTS} concurrent clients x {requests} requests over a \
         {FLEET}-worker serve plane (+ solo baseline)"
    );

    // solo baseline: one tenant on an otherwise idle plane
    let solo = {
        let (mut transport, dialer) = LoopbackTransport::new();
        let plane = thread::spawn(move || {
            ServePlane::new(ServiceConfig::default()).run(&mut transport, 1)
        });
        let workers =
            spawn_loopback_workers(&dialer, FLEET, &WorkerConfig::default());
        let reports = run_tenant(&dialer, "solo", ctx.seed, requests);
        plane.join().unwrap();
        for h in workers {
            h.join().unwrap()?;
        }
        reports
    };

    // concurrent: TENANTS clients share the plane and fleet
    let (mut transport, dialer) = LoopbackTransport::new();
    let plane = thread::spawn(move || {
        ServePlane::new(ServiceConfig::default()).run(&mut transport, TENANTS)
    });
    let workers = spawn_loopback_workers(&dialer, FLEET, &WorkerConfig::default());
    let handles: Vec<_> = (0..TENANTS)
        .map(|i| {
            let dialer = dialer.clone();
            let seed = ctx.seed.wrapping_add(1 + i as u64);
            thread::Builder::new()
                .name(format!("tenant-{i}"))
                .spawn(move || run_tenant(&dialer, &format!("tenant-{i}"), seed, requests))
                .expect("spawn tenant")
        })
        .collect();
    let concurrent: Vec<Vec<RunReport>> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    let report = plane.join().unwrap();
    for h in workers {
        h.join().unwrap()?;
    }
    anyhow::ensure!(
        report.served == (TENANTS * requests) as u64 && report.rejected == 0,
        "plane served {}/{} with {} rejects",
        report.served,
        TENANTS * requests,
        report.rejected,
    );

    let mut table = CsvTable::new(&[
        "tenant", "mode", "requests", "p50_ms", "p99_ms", "full_recovery",
    ]);
    let (p50, p99, full) = stats(&solo);
    table.push_raw(vec![
        "solo".into(),
        "solo".into(),
        requests.to_string(),
        format!("{p50:.3}"),
        format!("{p99:.3}"),
        full.to_string(),
    ]);
    println!("  solo      p50 {p50:8.2} ms   p99 {p99:8.2} ms   full_recovery={full}");
    let mut p50s = Vec::new();
    let mut all_full = full;
    for (i, reports) in concurrent.iter().enumerate() {
        let (p50, p99, full) = stats(reports);
        table.push_raw(vec![
            format!("tenant-{i}"),
            "concurrent".into(),
            requests.to_string(),
            format!("{p50:.3}"),
            format!("{p99:.3}"),
            full.to_string(),
        ]);
        println!(
            "  tenant-{i}  p50 {p50:8.2} ms   p99 {p99:8.2} ms   full_recovery={full}"
        );
        p50s.push(p50);
        all_full &= full;
    }
    ctx.write_csv("tenants.csv", &table)?;

    let worst = p50s.iter().cloned().fold(f64::MIN, f64::max);
    let best = p50s.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "headline: fair sharing p50 spread {:.2}x across {TENANTS} tenants, \
         full_recovery={all_full}",
        worst / best.max(1e-9),
    );
    anyhow::ensure!(all_full, "a tenant failed to fully recover");
    Ok(())
}
