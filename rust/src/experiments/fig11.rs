//! Fig. 11: c×r simulated normalized loss vs the Theorem 3 upper bound.
//! The paper notes the bound is "not tight" but tracks the shape — this
//! experiment quantifies exactly that gap.

use crate::analysis::UepStrategy;
use crate::coding::{CodeKind, CodeSpec, EncodeStyle};
use crate::config::SyntheticSpec;
use crate::util::csv::CsvTable;
use crate::util::linspace;
use crate::util::plot::{render, Series};

use super::common::{mc_loss_vs_time, ExpContext};

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    let spec = SyntheticSpec::fig9_cxr().scaled(ctx.scale_factor());
    let ts = linspace(0.0, 2.0, 41);
    let instances = if ctx.full { 4 } else { 2 };
    let trials = ctx.trials / instances.max(1);
    let th = spec.theorem();

    let mut table = CsvTable::new(&["t", "now_sim", "ew_sim", "now_bound", "ew_bound"]);
    let mut cols: Vec<Vec<f64>> = Vec::new();
    for kind in [
        CodeKind::NowUep(spec.gamma.clone()),
        CodeKind::EwUep(spec.gamma.clone()),
    ] {
        let code = CodeSpec::new(kind, EncodeStyle::Stacked);
        cols.push(mc_loss_vs_time(
            &spec, &code, &ts, instances, trials, ctx.seed, ctx.threads,
        ));
    }
    // Theorem 3 upper bound, normalized by E‖C‖² (the M× factor makes it
    // exceed 1 at t=0 — it is a bound, clamped here for plotting only in
    // the ASCII view; the CSV keeps raw values)
    let bounds: Vec<Vec<f64>> = [UepStrategy::Now, UepStrategy::Ew]
        .iter()
        .map(|&s| th.normalized_loss_curve(s, &ts))
        .collect();
    for i in 0..ts.len() {
        table.push_f64(&[ts[i], cols[0][i], cols[1][i], bounds[0][i], bounds[1][i]]);
    }
    let series = vec![
        Series::new("now sim", ts.clone(), cols[0].clone()),
        Series::new("ew sim", ts.clone(), cols[1].clone()),
        Series::new(
            "now bound",
            ts.clone(),
            bounds[0].iter().map(|&b| b.min(1.5)).collect(),
        ),
        Series::new(
            "ew bound",
            ts.clone(),
            bounds[1].iter().map(|&b| b.min(1.5)).collect(),
        ),
    ];
    println!(
        "{}",
        render("Fig. 11 — c×r loss: simulation vs Theorem 3 bound", &series, 64, 18)
    );
    ctx.write_csv("fig11_bound_vs_simulation.csv", &table)?;

    // the bound must actually bound the simulation
    let mut max_violation: f64 = 0.0;
    for i in 0..ts.len() {
        for j in 0..2 {
            max_violation = max_violation.max(cols[j][i] - bounds[j][i]);
        }
    }
    println!("  max (sim − bound) = {max_violation:.4} (≤ sampling noise)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem3_bounds_simulation() {
        let spec = SyntheticSpec::fig9_cxr().scaled(15);
        let th = spec.theorem();
        let ts = [0.3, 0.8, 1.5];
        let code = CodeSpec::new(
            CodeKind::NowUep(spec.gamma.clone()),
            EncodeStyle::Stacked,
        );
        let sim = mc_loss_vs_time(&spec, &code, &ts, 1, 150, 17, 4);
        for (i, &t) in ts.iter().enumerate() {
            let bound = th.normalized_loss(UepStrategy::Now, t);
            assert!(
                sim[i] <= bound + 0.05,
                "t={t}: sim {} exceeds bound {}",
                sim[i],
                bound
            );
        }
        // and the paper's observation: the bound is loose (M× factor)
        let bound0 = th.normalized_loss(UepStrategy::Now, 0.4);
        let sim0 = sim[0];
        assert!(bound0 > 1.5 * sim0, "bound {bound0} not loose vs sim {sim0}?");
    }
}
