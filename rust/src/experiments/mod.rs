//! Reproduction harness: one module per figure/table of the paper's
//! evaluation (see DESIGN.md §4 for the experiment index). Every
//! experiment writes CSV under the output directory and prints an ASCII
//! rendition of the figure plus a summary of the headline comparisons.

mod ablation;
mod adaptive;
mod chaos;
mod common;
mod dnn_cluster;
mod fig1;
mod fig10;
mod fig11;
mod fig5;
mod fig8;
mod fig9;
mod mnist;
mod params;
mod rateless;
mod stream;
mod tenants;

pub use common::{mc_loss_vs_packets, mc_loss_vs_time, ExpContext};

/// All registered experiments: `(name, description, runner)`.
pub fn registry() -> Vec<(&'static str, &'static str, fn(&ExpContext) -> anyhow::Result<()>)>
{
    vec![
        ("fig8", "decoding probabilities of NOW/EW-UEP (paper Fig. 8)", fig8::run),
        ("fig9", "normalized loss vs time, UEP vs MDS (paper Fig. 9)", fig9::run),
        ("fig10", "normalized loss vs received packets (paper Fig. 10)", fig10::run),
        ("fig11", "c×r simulation vs Theorem 3 bound (paper Fig. 11)", fig11::run),
        ("fig5", "gradient/weight/input Gaussian fits + Table II sparsity", fig5::run),
        ("fig13", "MNIST accuracy vs iteration, r×c (paper Fig. 13)", mnist::run_fig13),
        ("fig14", "MNIST accuracy vs iteration, c×r (paper Fig. 14)", mnist::run_fig14),
        ("fig15", "MNIST accuracy vs T_max (paper Fig. 15)", mnist::run_fig15),
        ("fig1", "CIFAR-like CNN accuracy vs epoch (paper Fig. 1)", fig1::run),
        ("params", "coding parameter tables (paper Tables III & VII)", params::run),
        (
            "ablation-encoding",
            "stacked vs rank-one encodings (DESIGN.md §2 ambiguity)",
            ablation::run_encoding,
        ),
        (
            "ablation-gamma",
            "window-polynomial sensitivity (paper §VI closing remark)",
            ablation::run_gamma,
        ),
        (
            "api-stream",
            "anytime client API: served loss vs deadline over a cached stream",
            stream::run,
        ),
        (
            "adaptive",
            "static-Γ vs adaptive-Γ served loss under drifting heterogeneous straggle",
            adaptive::run,
        ),
        (
            "chaos",
            "Byzantine-tolerance soak: lossy + lying workers, quarantine, bit-identical recovery",
            chaos::run,
        ),
        (
            "rateless",
            "fixed-rate EW vs rateless UEP: time-to-loss + straggler credit under drift",
            rateless::run,
        ),
        (
            "tenants",
            "multi-tenant serve plane: per-tenant served latency (p50/p99) under 3-way concurrency",
            tenants::run,
        ),
        (
            "dnn-cluster",
            "MLP wall-clock-to-accuracy on a real fleet: uncoded/MDS/UEP/UEP+hetero-assign under drift",
            dnn_cluster::run,
        ),
    ]
}

/// Run one experiment by name ("all" runs everything).
pub fn run(name: &str, ctx: &ExpContext) -> anyhow::Result<()> {
    if name == "all" {
        for (n, _, f) in registry() {
            println!("\n=== experiment {n} ===");
            f(ctx)?;
        }
        return Ok(());
    }
    for (n, _, f) in registry() {
        if n == name {
            return f(ctx);
        }
    }
    anyhow::bail!(
        "unknown experiment '{name}'; available: {}",
        registry().iter().map(|(n, _, _)| *n).collect::<Vec<_>>().join(", ")
    )
}
