//! Fig. 9: normalized expected loss vs time for NOW/EW-UEP under both
//! partitioning paradigms, against the MDS baseline — Monte-Carlo over
//! Assumption-1 matrices plus the analytic MDS curve, W=30, Exp(λ=1).
//!
//! Headline shape to reproduce (paper §VI): NOW beats MDS until t≈0.44;
//! EW beats MDS until t≈0.825 (r×c) / 0.975 (c×r); afterwards MDS wins
//! because it fully recovers at 9 packets.

use crate::analysis::mds_loss_vs_time;
use crate::coding::{CodeKind, CodeSpec, EncodeStyle};
use crate::config::SyntheticSpec;
use crate::util::csv::CsvTable;
use crate::util::linspace;
use crate::util::plot::{render, Series};

use super::common::{mc_loss_vs_time, ExpContext};

pub fn run(ctx: &ExpContext) -> anyhow::Result<()> {
    let rxc = SyntheticSpec::fig9_rxc().scaled(ctx.scale_factor());
    let cxr = SyntheticSpec::fig9_cxr().scaled(ctx.scale_factor());
    let ts = linspace(0.0, 2.0, 41);
    let instances = if ctx.full { 4 } else { 2 };
    let trials = ctx.trials / instances.max(1);

    let mut cfgs: Vec<(String, &SyntheticSpec, CodeSpec)> = Vec::new();
    for (tag, spec) in [("rxc", &rxc), ("cxr", &cxr)] {
        cfgs.push((
            format!("now_{tag}"),
            spec,
            CodeSpec::new(CodeKind::NowUep(spec.gamma.clone()), EncodeStyle::Stacked),
        ));
        cfgs.push((
            format!("ew_{tag}"),
            spec,
            CodeSpec::new(CodeKind::EwUep(spec.gamma.clone()), EncodeStyle::Stacked),
        ));
    }
    let mut header = vec!["t".to_string()];
    let mut columns: Vec<Vec<f64>> = vec![ts.clone()];
    let mut series = Vec::new();
    for (name, spec, code) in &cfgs {
        let losses =
            mc_loss_vs_time(spec, code, &ts, instances, trials, ctx.seed, ctx.threads);
        series.push(Series::new(name, ts.clone(), losses.clone()));
        header.push(name.clone());
        columns.push(losses);
    }
    // analytic MDS (same for both paradigms under Assumption 1)
    let mds: Vec<f64> = ts
        .iter()
        .map(|&t| mds_loss_vs_time(9, rxc.workers, &rxc.latency, rxc.omega(), t))
        .collect();
    series.push(Series::new("mds", ts.clone(), mds.clone()));
    header.push("mds".to_string());
    columns.push(mds.clone());

    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = CsvTable::new(&header_refs);
    for i in 0..ts.len() {
        let row: Vec<f64> = columns.iter().map(|c| c[i]).collect();
        table.push_f64(&row);
    }
    println!("{}", render("Fig. 9 — normalized loss vs time", &series, 64, 18));
    ctx.write_csv("fig9_loss_vs_time.csv", &table)?;

    // crossover report: the last time at which UEP is meaningfully below
    // MDS (both curves sit at ≈1.0 near t=0, so require a margin)
    for name in ["now_rxc", "ew_rxc", "now_cxr", "ew_cxr"] {
        let idx = header.iter().position(|h| h == name).unwrap();
        let cross = ts
            .iter()
            .zip(columns[idx].iter().zip(mds.iter()))
            .filter(|(_, (u, m))| **u < **m - 5e-3)
            .map(|(t, _)| *t)
            .fold(None::<f64>, |acc, t| Some(acc.map_or(t, |a| a.max(t))));
        println!(
            "  {name} below MDS up to t ≈ {}",
            cross.map(|t| format!("{t:.3}")).unwrap_or_else(|| "never".into())
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's headline orderings, at reduced scale/trials.
    #[test]
    fn fig9_shape_holds() {
        let spec = SyntheticSpec::fig9_rxc().scaled(15);
        let ts = [0.2, 1.6];
        let now = CodeSpec::new(
            CodeKind::NowUep(spec.gamma.clone()),
            EncodeStyle::Stacked,
        );
        let ew = CodeSpec::new(
            CodeKind::EwUep(spec.gamma.clone()),
            EncodeStyle::Stacked,
        );
        let l_now = mc_loss_vs_time(&spec, &now, &ts, 1, 120, 5, 4);
        let l_ew = mc_loss_vs_time(&spec, &ew, &ts, 1, 120, 5, 4);
        let mds_early = mds_loss_vs_time(9, 30, &spec.latency, spec.omega(), 0.2);
        let mds_late = mds_loss_vs_time(9, 30, &spec.latency, spec.omega(), 1.6);
        // early: UEP provides partial recovery, MDS essentially nothing
        assert!(
            l_now[0] < mds_early,
            "NOW {} should beat MDS {} at t=0.2",
            l_now[0],
            mds_early
        );
        // EW protects the energy-heavy class harder than NOW early on
        assert!(l_ew[0] < l_now[0] + 0.02, "EW {} vs NOW {}", l_ew[0], l_now[0]);
        // late: both MDS and UEP approach full recovery
        assert!(mds_late < 0.3, "MDS late {mds_late}");
        assert!(l_now[1] < 0.2, "NOW late {}", l_now[1]);
    }
}
