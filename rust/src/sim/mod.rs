//! Straggler simulation: virtual-time worker arrivals and the parallel
//! Monte-Carlo harness used by the figure sweeps.
//!
//! Workers are i.i.d. draws from a [`LatencyModel`] under the paper's
//! `F(Ω·t)` capacity scaling. Simulations run in *virtual time* — no
//! actual sleeping — so a 10⁴-trial sweep over a 30-worker system takes
//! milliseconds. The honest threaded execution path (real clocks, real
//! PJRT compute) lives in [`crate::coordinator`].

pub mod sweep;

pub use sweep::{
    loss_at, loss_trace_fast, loss_trace_packets, loss_trace_packets_scratch,
    LossTracePoint, SweepScratch,
};

use crate::latency::LatencyModel;
use crate::rng::Pcg64;
use crate::util::pool::parallel_map_scratch;

/// A straggler environment: `W` workers with i.i.d. scaled latencies.
#[derive(Clone, Debug)]
pub struct StragglerSim {
    pub workers: usize,
    pub latency: LatencyModel,
    /// The paper's Ω = (#sub-products)/W scaling (Remark 1).
    pub omega: f64,
}

impl StragglerSim {
    pub fn new(workers: usize, latency: LatencyModel, omega: f64) -> Self {
        assert!(workers > 0 && omega > 0.0);
        StragglerSim { workers, latency, omega }
    }

    /// Per-worker completion times (unsorted; index = worker id).
    pub fn sample_arrivals(&self, rng: &mut Pcg64) -> Vec<f64> {
        (0..self.workers)
            .map(|_| self.latency.sample_scaled(self.omega, rng))
            .collect()
    }

    /// Completion events sorted by time: `(time, worker)`.
    pub fn sample_ordered(&self, rng: &mut Pcg64) -> Vec<(f64, usize)> {
        let mut ev: Vec<(f64, usize)> = self
            .sample_arrivals(rng)
            .into_iter()
            .enumerate()
            .map(|(w, t)| (t, w))
            .collect();
        ev.sort_by(|a, b| a.0.total_cmp(&b.0));
        ev
    }

    /// Expected fraction of workers finished by `t`.
    pub fn expected_fraction(&self, t: f64) -> f64 {
        self.latency.cdf_scaled(t, self.omega)
    }
}

/// Run `trials` independent simulations in parallel with split RNG
/// streams; results come back in trial order (deterministic for a given
/// `seed`, independent of thread count).
pub fn monte_carlo<T, F>(trials: usize, threads: usize, seed: u64, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(&mut Pcg64, usize) -> T + Sync,
{
    monte_carlo_scratch(trials, threads, seed, || (), move |rng, i, _scratch| {
        f(rng, i)
    })
}

/// [`monte_carlo`] with per-thread scratch reuse: each worker thread
/// builds one scratch value via `init` and reuses it across all its
/// trials (decode states, buffers, …). Trial `i` always draws from
/// stream `i+1` of `seed`, so results are bit-identical at any thread
/// count — scratch placement never leaks into the RNG sequence.
pub fn monte_carlo_scratch<T, S, I, F>(
    trials: usize,
    threads: usize,
    seed: u64,
    init: I,
    f: F,
) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut Pcg64, usize, &mut S) -> T + Sync,
{
    parallel_map_scratch(trials, threads, init, |i, scratch| {
        let mut rng = Pcg64::with_stream(seed, i as u64 + 1);
        f(&mut rng, i, scratch)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_match_scaled_cdf() {
        let sim = StragglerSim::new(30, LatencyModel::exp(1.0), 9.0 / 15.0);
        let mut rng = Pcg64::seed_from(1);
        let t = 1.0;
        let trials = 3_000;
        let mut finished = 0usize;
        let mut total = 0usize;
        for _ in 0..trials {
            for a in sim.sample_arrivals(&mut rng) {
                total += 1;
                if a <= t {
                    finished += 1;
                }
            }
        }
        let emp = finished as f64 / total as f64;
        assert!((emp - sim.expected_fraction(t)).abs() < 0.01);
    }

    #[test]
    fn ordered_events_sorted_and_complete() {
        let sim = StragglerSim::new(10, LatencyModel::exp(2.0), 1.0);
        let mut rng = Pcg64::seed_from(2);
        let ev = sim.sample_ordered(&mut rng);
        assert_eq!(ev.len(), 10);
        for w in ev.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
        let mut workers: Vec<usize> = ev.iter().map(|e| e.1).collect();
        workers.sort_unstable();
        assert_eq!(workers, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn monte_carlo_deterministic_across_thread_counts() {
        let a = monte_carlo(64, 1, 99, |rng, _| rng.next_f64());
        let b = monte_carlo(64, 8, 99, |rng, _| rng.next_f64());
        assert_eq!(a, b);
    }

    #[test]
    fn monte_carlo_scratch_deterministic_and_isolated() {
        // a mutated scratch must never bleed into the per-trial RNG
        // stream: results stay bit-identical to the scratch-free path
        // at every thread count
        let plain = monte_carlo(48, 1, 7, |rng, _| rng.next_f64());
        for threads in [1usize, 3, 8] {
            let with_scratch = monte_carlo_scratch(
                48,
                threads,
                7,
                Vec::<f64>::new,
                |rng, _, scratch| {
                    let x = rng.next_f64();
                    scratch.push(x); // grows across the thread's trials
                    x
                },
            );
            assert_eq!(plain, with_scratch, "threads={threads}");
        }
    }
}
