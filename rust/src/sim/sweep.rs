//! Coefficient-only fast simulation path for the Monte-Carlo figure
//! sweeps: packets are decoded symbolically (incremental elimination
//! over coefficient rows — no matrix payloads) and the exact loss is
//! read off the precomputed sub-product Gram matrix
//! (`‖C−Ĉ‖² = Σ_{i,j∉rec} G_ij`, see `Partitioning::loss_from_gram`).
//! Linearity makes this numerically identical to the honest engine path
//! (verified by an integration test).
//!
//! The per-arrival accounting is fully incremental: the residual loss is
//! a running sum updated only by newly-recovered unknowns (O(k) per
//! recovery via `Partitioning::loss_delta_on_recover`, instead of an
//! O(k²) Gram recompute per arrival), and the recovered count is
//! maintained rather than recounted. [`SweepScratch`] carries the decode
//! state and index buffers across trials so a Monte-Carlo worker thread
//! allocates only its output trace in steady state.

use crate::coding::{CodeSpec, DecodeState, Packet, UnknownSpace};
use crate::linalg::Matrix;
use crate::partition::{ClassMap, Partitioning};
use crate::rng::Pcg64;

/// Loss trace entry: after the arrival at `time`, the decoder had
/// `received` packets and the residual loss was `loss`.
#[derive(Clone, Copy, Debug)]
pub struct LossTracePoint {
    pub time: f64,
    pub received: usize,
    pub recovered: usize,
    pub loss: f64,
}

/// Reusable per-thread buffers for the trial hot loop: the decode state
/// (eliminator storage), the arrival-order permutation, and the recovery
/// mask. One per Monte-Carlo worker thread.
#[derive(Default)]
pub struct SweepScratch {
    decode: Option<DecodeState>,
    order: Vec<usize>,
    mask: Vec<bool>,
}

impl SweepScratch {
    pub fn new() -> Self {
        SweepScratch::default()
    }
}

/// Simulate one trial: generate packets, decode in arrival order, and
/// report the loss after every arrival (plus the initial state at t=0).
///
/// `gram` is the Gram matrix of the true sub-products; `arrivals` is the
/// per-worker completion time vector (same length as the packet set).
pub fn loss_trace_fast(
    part: &Partitioning,
    cm: &ClassMap,
    spec: &CodeSpec,
    gram: &Matrix,
    arrivals: &[f64],
    rng: &mut Pcg64,
) -> Vec<LossTracePoint> {
    let packets = spec.generate_packets(part, cm, arrivals.len(), rng);
    loss_trace_packets(part, spec, gram, &packets, arrivals)
}

/// Same, with a pre-generated packet set.
pub fn loss_trace_packets(
    part: &Partitioning,
    spec: &CodeSpec,
    gram: &Matrix,
    packets: &[Packet],
    arrivals: &[f64],
) -> Vec<LossTracePoint> {
    let mut scratch = SweepScratch::new();
    loss_trace_packets_scratch(part, spec, gram, packets, arrivals, &mut scratch)
}

/// Same, with caller-owned scratch (the Monte-Carlo hot path: reuse one
/// [`SweepScratch`] per worker thread across all its trials).
pub fn loss_trace_packets_scratch(
    part: &Partitioning,
    spec: &CodeSpec,
    gram: &Matrix,
    packets: &[Packet],
    arrivals: &[f64],
    scratch: &mut SweepScratch,
) -> Vec<LossTracePoint> {
    assert_eq!(packets.len(), arrivals.len());
    let space = UnknownSpace::for_code(part, spec.style);
    match &mut scratch.decode {
        Some(st) if *st.space() == space => st.reset(),
        slot => *slot = Some(DecodeState::new(space)),
    }
    let st = scratch.decode.as_mut().expect("decode state just installed");
    scratch.order.clear();
    scratch.order.extend(0..arrivals.len());
    scratch
        .order
        .sort_by(|&a, &b| arrivals[a].total_cmp(&arrivals[b]));
    let k = part.num_products();
    scratch.mask.clear();
    scratch.mask.resize(k, false);
    let mut recovered = 0usize;
    // full Gram sum once per trial; afterwards only deltas
    let mut loss = part.loss_from_gram(gram, &scratch.mask);
    let mut trace = Vec::with_capacity(arrivals.len() + 1);
    trace.push(LossTracePoint { time: 0.0, received: 0, recovered: 0, loss });
    for (i, &w) in scratch.order.iter().enumerate() {
        let newly = st.add_packet(&packets[w], None);
        for u in newly {
            scratch.mask[u] = true;
            recovered += 1;
            loss -= part.loss_delta_on_recover(gram, &scratch.mask, u);
        }
        if recovered == k {
            // pin the fully-decoded endpoint to exactly zero (the batch
            // recompute's empty sum), shedding running-sum rounding
            loss = 0.0;
        }
        trace.push(LossTracePoint {
            time: arrivals[w],
            received: i + 1,
            recovered,
            loss,
        });
    }
    trace
}

/// Loss of a trace at deadline `t` (last point with `time ≤ t`).
pub fn loss_at(trace: &[LossTracePoint], t: f64) -> f64 {
    let mut loss = trace[0].loss;
    for p in trace {
        if p.time <= t {
            loss = p.loss;
        } else {
            break;
        }
    }
    loss
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodeKind, EncodeStyle, WindowPolynomial};
    use crate::latency::LatencyModel;
    use crate::partition::default_pair_classes;
    use crate::sim::StragglerSim;

    fn setup() -> (Partitioning, ClassMap, Matrix, Matrix) {
        let mut rng = Pcg64::seed_from(10);
        let part = Partitioning::rxc(3, 3, 4, 5, 4);
        let sds = [10f64.sqrt(), 1.0, (0.1f64).sqrt()];
        let a_blocks: Vec<Matrix> =
            sds.iter().map(|&s| Matrix::randn(4, 5, 0.0, s, &mut rng)).collect();
        let b_blocks: Vec<Matrix> =
            sds.iter().map(|&s| Matrix::randn(5, 4, 0.0, s, &mut rng)).collect();
        let a = Matrix::vconcat(&a_blocks.iter().collect::<Vec<_>>());
        let b = Matrix::hconcat(&b_blocks.iter().collect::<Vec<_>>());
        let pair = default_pair_classes(3);
        let cm = ClassMap::from_levels(&part, vec![0, 1, 2], vec![0, 1, 2], &pair);
        (part, cm, a, b)
    }

    #[test]
    fn trace_is_monotone_and_reaches_zero() {
        let (part, cm, a, b) = setup();
        let gram = part.gram(&part.true_products(&a, &b));
        let spec = CodeSpec::new(
            CodeKind::EwUep(WindowPolynomial::paper_table3()),
            EncodeStyle::Stacked,
        );
        let sim = StragglerSim::new(40, LatencyModel::exp(1.0), 9.0 / 40.0);
        let mut rng = Pcg64::seed_from(11);
        let arrivals = sim.sample_arrivals(&mut rng);
        let trace = loss_trace_fast(&part, &cm, &spec, &gram, &arrivals, &mut rng);
        assert_eq!(trace.len(), 41);
        for w in trace.windows(2) {
            assert!(w[1].loss <= w[0].loss + 1e-9, "loss increased");
            assert!(w[1].recovered >= w[0].recovered);
        }
        // 40 EW packets over 9 unknowns: must fully decode
        assert_eq!(trace.last().unwrap().recovered, 9);
        assert!(trace.last().unwrap().loss < 1e-9);
    }

    #[test]
    fn loss_at_deadline_interpolates_stepwise() {
        let trace = vec![
            LossTracePoint { time: 0.0, received: 0, recovered: 0, loss: 1.0 },
            LossTracePoint { time: 0.5, received: 1, recovered: 1, loss: 0.6 },
            LossTracePoint { time: 1.5, received: 2, recovered: 2, loss: 0.2 },
        ];
        assert_eq!(loss_at(&trace, 0.0), 1.0);
        assert_eq!(loss_at(&trace, 0.4), 1.0);
        assert_eq!(loss_at(&trace, 0.5), 0.6);
        assert_eq!(loss_at(&trace, 2.0), 0.2);
    }

    /// Pre-refactor reference: recompute the recovered count and the full
    /// `Σ_{i,j∉rec} G_ij` residual from scratch after every arrival.
    fn loss_trace_bruteforce(
        part: &Partitioning,
        spec: &CodeSpec,
        gram: &Matrix,
        packets: &[crate::coding::Packet],
        arrivals: &[f64],
    ) -> Vec<LossTracePoint> {
        let space = UnknownSpace::for_code(part, spec.style);
        let mut st = DecodeState::new(space);
        let mut order: Vec<usize> = (0..arrivals.len()).collect();
        order.sort_by(|&a, &b| arrivals[a].total_cmp(&arrivals[b]));
        let mut mask = vec![false; part.num_products()];
        let mut trace = vec![LossTracePoint {
            time: 0.0,
            received: 0,
            recovered: 0,
            loss: part.loss_from_gram(gram, &mask),
        }];
        for (i, &w) in order.iter().enumerate() {
            for u in st.add_packet(&packets[w], None) {
                mask[u] = true;
            }
            trace.push(LossTracePoint {
                time: arrivals[w],
                received: i + 1,
                recovered: mask.iter().filter(|&&b| b).count(),
                loss: part.loss_from_gram(gram, &mask),
            });
        }
        trace
    }

    /// The incremental running-sum loss/recovery path must match the
    /// brute-force per-arrival recompute point-for-point, on randomized
    /// schemes, paradigms, packet streams, and a reused scratch.
    #[test]
    fn incremental_trace_matches_bruteforce() {
        use crate::coding::CodeKind;
        use crate::util::prop::{gen, prop_check, PropConfig};
        let (part_rxc, cm_rxc, a1, b1) = setup();
        let gram_rxc = part_rxc.gram(&part_rxc.true_products(&a1, &b1));
        // a c×r setup so the dense-Gram delta path is exercised too
        let part_cxr = Partitioning::cxr(9, 6, 3, 5);
        let lv = vec![0, 0, 0, 1, 1, 1, 2, 2, 2];
        let pair = crate::partition::default_pair_classes(3);
        let cm_cxr =
            crate::partition::ClassMap::from_levels(&part_cxr, lv.clone(), lv, &pair);
        let mut rng0 = Pcg64::seed_from(40);
        let a2 = Matrix::randn(part_cxr.a_shape().0, part_cxr.a_shape().1, 0.0, 1.0, &mut rng0);
        let b2 = Matrix::randn(part_cxr.b_shape().0, part_cxr.b_shape().1, 0.0, 1.0, &mut rng0);
        let gram_cxr = part_cxr.gram(&part_cxr.true_products(&a2, &b2));
        let gamma = WindowPolynomial::paper_table3();
        let mut scratch = SweepScratch::new();
        prop_check(
            "incremental trace vs brute force",
            PropConfig { cases: 16, seed: 99 },
            |rng, case| {
                let (part, cm, gram) = if case % 2 == 0 {
                    (&part_rxc, &cm_rxc, &gram_rxc)
                } else {
                    (&part_cxr, &cm_cxr, &gram_cxr)
                };
                let specs = [
                    CodeSpec::stacked(CodeKind::Mds),
                    CodeSpec::stacked(CodeKind::NowUep(gamma.clone())),
                    CodeSpec::stacked(CodeKind::EwUep(gamma.clone())),
                    CodeSpec::new(CodeKind::EwUep(gamma.clone()), EncodeStyle::RankOne),
                ];
                let spec = &specs[case % specs.len()];
                let w = gen::usize_in(rng, 3, 40);
                let packets = spec.generate_packets(part, cm, w, rng);
                let arrivals: Vec<f64> =
                    (0..w).map(|_| gen::f64_in(rng, 0.0, 3.0)).collect();
                let fast = loss_trace_packets_scratch(
                    part, spec, gram, &packets, &arrivals, &mut scratch,
                );
                let slow = loss_trace_bruteforce(part, spec, gram, &packets, &arrivals);
                if fast.len() != slow.len() {
                    return Err("trace length mismatch".into());
                }
                for (f, s) in fast.iter().zip(slow.iter()) {
                    if f.received != s.received || f.recovered != s.recovered {
                        return Err(format!(
                            "counts diverge at received {}: {} vs {}",
                            f.received, f.recovered, s.recovered
                        ));
                    }
                    if (f.loss - s.loss).abs() > 1e-9 * (1.0 + s.loss.abs()) {
                        return Err(format!(
                            "loss diverges at received {}: {} vs {}",
                            f.received, f.loss, s.loss
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn mds_trace_is_all_or_nothing() {
        let (part, cm, a, b) = setup();
        let gram = part.gram(&part.true_products(&a, &b));
        let spec = CodeSpec::stacked(CodeKind::Mds);
        let mut rng = Pcg64::seed_from(12);
        let arrivals: Vec<f64> = (0..15).map(|i| i as f64).collect();
        let trace = loss_trace_fast(&part, &cm, &spec, &gram, &arrivals, &mut rng);
        let full = trace[0].loss;
        for p in &trace {
            if p.received < 9 {
                assert!((p.loss - full).abs() < 1e-9, "MDS partial decode?");
            } else {
                assert!(p.loss < 1e-9);
            }
        }
    }
}
