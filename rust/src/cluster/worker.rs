//! The worker agent: registers with a coordinator, computes coded
//! sub-products through an [`ExecEngine`], and streams results back.
//!
//! One loop serves every transport. Straggle modelling is layered:
//!
//! * **coordinator-injected** — a job can carry a pre-sampled virtual
//!   completion time (`injected_delay`) plus a wall pacing budget
//!   (`sleep_secs`); this is how seeded deterministic runs work.
//! * **self-injected** — a worker configured with a
//!   [`LatencyModel`] samples its own completion time per job from its
//!   seeded RNG (the `uepmm worker --latency exp:1.0` path).
//! * **natural** — with neither, the reported delay is the measured
//!   wall time of the computation: straggling is whatever the host and
//!   transport actually do.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coding::{JobRecipe, RatelessCoder, StackTerm, UepWindows, WindowPolynomial};
use crate::latency::LatencyModel;
use crate::linalg::Matrix;
use crate::rng::Pcg64;
use crate::runtime::{ExecEngine, NativeEngine};

use super::transport::{Connection, LoopbackDialer};
use super::wire::{Msg, RatelessJobMsg, RatelessResultMsg, ResultMsg, WireError};

/// Configuration of one worker agent.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Name announced in the registration handshake (logs/registry).
    pub name: String,
    /// Self-injected straggle model (`None` = coordinator-injected or
    /// natural timing only).
    pub latency: Option<LatencyModel>,
    /// Capacity scaling for self-sampled delays (paper Remark 1).
    pub omega: f64,
    /// Wall seconds per virtual time unit for self-injected sleeps and
    /// for converting measured wall time back to virtual time. `0`
    /// disables sleeping.
    pub time_scale: f64,
    /// Seed of the worker's private delay-sampling RNG.
    pub seed: u64,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            name: "worker".to_string(),
            latency: None,
            omega: 1.0,
            time_scale: 0.0,
            seed: 0,
        }
    }
}

/// What a worker did over its lifetime, reported when the loop exits.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkerStats {
    pub worker_id: u64,
    pub jobs: u64,
    pub heartbeats: u64,
    /// Rateless packets computed and sent (stream + redo; protocol v5).
    pub packets: u64,
    /// `true` when the coordinator sent an explicit shutdown (clean
    /// exit), `false` when the connection dropped.
    pub clean_shutdown: bool,
}

/// Everything a worker keeps per rateless request: the deterministic
/// coder plus the raw blocks, so it can derive and compute *any*
/// `(stream, seq)` packet on demand (its own budget or a `Redo`).
struct RatelessCtx {
    request_id: u64,
    stream: u64,
    budget: u32,
    coder: RatelessCoder,
    factors: Vec<(u32, u32)>,
    delays: Vec<f64>,
    t_max: f64,
    pace: f64,
    a_blocks: Vec<Arc<Matrix>>,
    b_blocks: Vec<Arc<Matrix>>,
}

impl RatelessCtx {
    fn build(rj: RatelessJobMsg) -> Result<RatelessCtx> {
        anyhow::ensure!(!rj.gamma.is_empty(), "rateless job with empty gamma");
        anyhow::ensure!(!rj.class_of.is_empty(), "rateless job with no unknowns");
        anyhow::ensure!(
            rj.factors.len() == rj.class_of.len(),
            "rateless job factor table length mismatch"
        );
        for &(ai, bi) in &rj.factors {
            anyhow::ensure!(
                (ai as usize) < rj.a_blocks.len() && (bi as usize) < rj.b_blocks.len(),
                "rateless job factor index out of range"
            );
        }
        let coder = RatelessCoder::new(
            rj.delta,
            rj.c,
            &WindowPolynomial::new(&rj.gamma),
            UepWindows::from_class_of(&rj.class_of),
        );
        Ok(RatelessCtx {
            request_id: rj.request_id,
            stream: rj.stream,
            budget: rj.budget,
            coder,
            factors: rj.factors,
            delays: rj.delays,
            t_max: rj.t_max,
            pace: rj.pace,
            a_blocks: rj.a_blocks,
            b_blocks: rj.b_blocks,
        })
    }

    /// Derive packet `(stream, seq)` and materialize its job factors —
    /// the worker-side mirror of [`crate::coordinator::build_job_matrices`],
    /// driven by the shipped factor table instead of a `Partitioning`.
    fn job_matrices(&self, stream: u64, seq: u32) -> Result<(Matrix, Matrix)> {
        let pkt = self.coder.packet(self.request_id, stream, seq);
        let JobRecipe::Stacked { terms } = &pkt.recipe else {
            // every rateless coder emits stacked recipes today; if that
            // ever changes, fail this stream instead of the process
            anyhow::bail!("rateless packet ({stream}, {seq}) is not a stacked recipe");
        };
        Ok(stack_from_factors(
            terms,
            &self.factors,
            &self.a_blocks,
            &self.b_blocks,
        ))
    }
}

/// Build `(W_A, W_B)` for a stacked recipe from an explicit
/// unknown→(a, b) factor table.
fn stack_from_factors(
    terms: &[StackTerm],
    factors: &[(u32, u32)],
    a_blocks: &[Arc<Matrix>],
    b_blocks: &[Arc<Matrix>],
) -> (Matrix, Matrix) {
    assert!(!terms.is_empty(), "empty stacked rateless job");
    let scaled_a: Vec<Matrix> = terms
        .iter()
        .map(|t| {
            let (ai, _) = factors[t.unknown];
            let mut m = (*a_blocks[ai as usize]).clone();
            m.scale(t.coeff);
            m
        })
        .collect();
    let wa = Matrix::hconcat(&scaled_a.iter().collect::<Vec<_>>());
    let b_parts: Vec<&Matrix> = terms
        .iter()
        .map(|t| &*b_blocks[factors[t.unknown].1 as usize])
        .collect();
    let wb = Matrix::vconcat(&b_parts);
    (wa, wb)
}

/// How a rateless streaming loop ended.
enum Flow {
    /// Keep the job context (stream finished or never started).
    Continue,
    /// Coordinator drained this request — drop the context.
    Drained,
    /// Coordinator asked the whole worker to shut down.
    Shutdown,
    /// The connection died mid-stream.
    Closed,
}

/// Run the worker loop until shutdown or disconnect. Registers, then
/// serves jobs and heartbeats.
pub fn run_worker<E: ExecEngine>(
    conn: &mut dyn Connection,
    engine: &E,
    cfg: &WorkerConfig,
) -> Result<WorkerStats> {
    conn.send(&Msg::Hello { agent: cfg.name.clone() })
        .map_err(|e| anyhow::anyhow!("{}: hello failed: {e}", cfg.name))?;
    let worker_id = match conn.recv() {
        Ok(Msg::Welcome { worker_id }) => worker_id,
        Ok(other) => anyhow::bail!("{}: expected welcome, got {}", cfg.name, other.name()),
        Err(e) => anyhow::bail!("{}: registration failed: {e}", cfg.name),
    };
    let mut rng = Pcg64::seed_from(cfg.seed);
    let mut stats = WorkerStats {
        worker_id,
        jobs: 0,
        heartbeats: 0,
        packets: 0,
        clean_shutdown: false,
    };
    // Set once a send hits a closed peer: the coordinator stopped
    // listening (it may still have queued a Shutdown behind the job
    // backlog), so stop computing and drain the receive side looking for
    // the orderly goodbye.
    let mut sink_closed = false;
    // Rateless job contexts, kept past their budgeted stream so `Redo`
    // can regenerate any packet until the coordinator drains the request.
    let mut ratelesses: BTreeMap<u64, RatelessCtx> = BTreeMap::new();
    // Frames that arrived while a rateless stream was polling for
    // control messages; replayed through the main loop in order.
    let mut pending: VecDeque<Msg> = VecDeque::new();
    loop {
        let msg = if let Some(m) = pending.pop_front() {
            m
        } else {
            match conn.recv_timeout(None) {
                Ok(Some(m)) => m,
                Ok(None) => continue,
                Err(WireError::Closed) => break,
                Err(e) => return Err(anyhow::anyhow!("{}: receive failed: {e}", cfg.name)),
            }
        };
        match msg {
            Msg::Job(job) => {
                if sink_closed {
                    continue;
                }
                let t0 = Instant::now(); // lint:allow(no-wallclock-in-deterministic-paths) measured fallback + pacing; Virtual runs ship injected delays
                let payload = engine.matmul(&job.wa, &job.wb)?;
                let elapsed = t0.elapsed().as_secs_f64();
                // completion time and pacing, per the layering above
                let (delay, sleep_secs) = match (job.injected_delay, &cfg.latency) {
                    (Some(d), _) => (d, job.sleep_secs),
                    (None, Some(model)) => {
                        let d = model.sample_scaled(cfg.omega, &mut rng);
                        (d, d * cfg.time_scale)
                    }
                    (None, None) => {
                        let d = if cfg.time_scale > 0.0 {
                            elapsed / cfg.time_scale
                        } else {
                            elapsed
                        };
                        (d, 0.0)
                    }
                };
                if sleep_secs > elapsed {
                    std::thread::sleep(Duration::from_secs_f64(sleep_secs - elapsed));
                }
                let reply = Msg::Result(ResultMsg {
                    request_id: job.request_id,
                    slot: job.slot,
                    // echo the dispatch attempt so the coordinator can
                    // attribute duplicates of a re-dispatched slot
                    attempt: job.attempt,
                    delay,
                    // measured compute floor, separate from any modelled
                    // straggle above — coordinator-side telemetry
                    compute_secs: elapsed,
                    payload,
                });
                match conn.send(&reply) {
                    Ok(()) => stats.jobs += 1,
                    Err(WireError::Closed) => sink_closed = true,
                    Err(e) => {
                        return Err(anyhow::anyhow!("{}: send failed: {e}", cfg.name))
                    }
                }
            }
            Msg::Heartbeat { nonce } => {
                if sink_closed {
                    continue;
                }
                match conn.send(&Msg::HeartbeatAck { nonce }) {
                    Ok(()) => stats.heartbeats += 1,
                    Err(WireError::Closed) => sink_closed = true,
                    Err(e) => {
                        return Err(anyhow::anyhow!("{}: send failed: {e}", cfg.name))
                    }
                }
            }
            Msg::Shutdown => {
                stats.clean_shutdown = true;
                break;
            }
            Msg::RatelessJob(rj) => {
                let ctx = RatelessCtx::build(rj)
                    .map_err(|e| anyhow::anyhow!("{}: {e}", cfg.name))?;
                match stream_rateless(
                    conn, engine, cfg, &mut rng, &ctx, &mut pending, &mut stats,
                    &mut sink_closed,
                )? {
                    Flow::Continue => {
                        ratelesses.insert(ctx.request_id, ctx);
                    }
                    Flow::Drained => {} // context dropped with the request
                    Flow::Shutdown => {
                        stats.clean_shutdown = true;
                        break;
                    }
                    Flow::Closed => break,
                }
            }
            Msg::Redo { request_id, stream, seq, attempt } => {
                // a Redo for an unknown request races with nothing (the
                // connection is FIFO) but a worker that never held the
                // context simply cannot help — ignore rather than die
                if let Some(ctx) = ratelesses.get(&request_id) {
                    serve_redo(
                        conn, engine, cfg, ctx, stream, seq, attempt, &mut stats,
                        &mut sink_closed,
                    )?;
                }
            }
            Msg::Drain { request_id } => {
                ratelesses.remove(&request_id);
            }
            // coordinator-only messages arriving here are a protocol
            // violation; drop the connection rather than guessing
            other => {
                anyhow::bail!("{}: unexpected {} from coordinator", cfg.name, other.name())
            }
        }
    }
    Ok(stats)
}

/// Stream `ctx.budget` packets for a rateless job, polling for control
/// frames (`Drain`, `Redo`, heartbeats, shutdown) between packets so the
/// coordinator can stop the stream the moment its decode completes.
#[allow(clippy::too_many_arguments)]
fn stream_rateless<E: ExecEngine>(
    conn: &mut dyn Connection,
    engine: &E,
    cfg: &WorkerConfig,
    rng: &mut Pcg64,
    ctx: &RatelessCtx,
    pending: &mut VecDeque<Msg>,
    stats: &mut WorkerStats,
    sink_closed: &mut bool,
) -> Result<Flow> {
    let mut prev_virtual = 0.0f64;
    let mut cum_measured = 0.0f64;
    for seq in 0..ctx.budget {
        loop {
            match conn.recv_timeout(Some(Duration::ZERO)) {
                Ok(Some(Msg::Drain { request_id })) if request_id == ctx.request_id => {
                    return Ok(Flow::Drained)
                }
                Ok(Some(Msg::Heartbeat { nonce })) => {
                    if !*sink_closed {
                        match conn.send(&Msg::HeartbeatAck { nonce }) {
                            Ok(()) => stats.heartbeats += 1,
                            Err(WireError::Closed) => *sink_closed = true,
                            Err(e) => anyhow::bail!("{}: send failed: {e}", cfg.name),
                        }
                    }
                }
                Ok(Some(Msg::Redo { request_id, stream, seq: rseq, attempt }))
                    if request_id == ctx.request_id =>
                {
                    serve_redo(conn, engine, cfg, ctx, stream, rseq, attempt, stats, sink_closed)?;
                }
                Ok(Some(Msg::Shutdown)) => return Ok(Flow::Shutdown),
                Ok(Some(other)) => pending.push_back(other),
                Ok(None) => break,
                Err(WireError::Closed) => return Ok(Flow::Closed),
                Err(e) => anyhow::bail!("{}: receive failed: {e}", cfg.name),
            }
        }
        if *sink_closed {
            continue;
        }
        let t0 = Instant::now(); // lint:allow(no-wallclock-in-deterministic-paths) compute_secs telemetry only; decode order never reads it
        let (wa, wb) = ctx.job_matrices(ctx.stream, seq)?;
        let payload = engine.matmul(&wa, &wb)?;
        let elapsed = t0.elapsed().as_secs_f64();
        // per-packet completion time, cumulative across the stream, with
        // the same precedence as fixed-rate jobs: coordinator-injected >
        // self-modelled > measured
        let (delay, sleep_secs) = if !ctx.delays.is_empty() {
            let d = ctx.delays[(seq as usize).min(ctx.delays.len() - 1)];
            let inc = (d - prev_virtual).max(0.0);
            (d, inc.min(ctx.t_max) * ctx.pace)
        } else if let Some(model) = &cfg.latency {
            let inc = model.sample_scaled(cfg.omega, rng);
            (prev_virtual + inc, inc * cfg.time_scale)
        } else {
            cum_measured += elapsed;
            let d = if cfg.time_scale > 0.0 {
                cum_measured / cfg.time_scale
            } else {
                cum_measured
            };
            (d, 0.0)
        };
        prev_virtual = delay;
        if sleep_secs > elapsed {
            std::thread::sleep(Duration::from_secs_f64(sleep_secs - elapsed));
        }
        let reply = Msg::RatelessResult(RatelessResultMsg {
            request_id: ctx.request_id,
            stream: ctx.stream,
            seq,
            attempt: 0,
            delay,
            compute_secs: elapsed,
            more: seq + 1 < ctx.budget,
            payload,
        });
        match conn.send(&reply) {
            Ok(()) => stats.packets += 1,
            Err(WireError::Closed) => *sink_closed = true,
            Err(e) => anyhow::bail!("{}: send failed: {e}", cfg.name),
        }
    }
    Ok(Flow::Continue)
}

/// Regenerate one packet of one stream on request. Any worker holding
/// the request's context can serve any stream's packet — the coder is a
/// pure function of `(request, stream, seq)`.
#[allow(clippy::too_many_arguments)]
fn serve_redo<E: ExecEngine>(
    conn: &mut dyn Connection,
    engine: &E,
    cfg: &WorkerConfig,
    ctx: &RatelessCtx,
    stream: u64,
    seq: u32,
    attempt: u32,
    stats: &mut WorkerStats,
    sink_closed: &mut bool,
) -> Result<()> {
    if *sink_closed {
        return Ok(());
    }
    let t0 = Instant::now(); // lint:allow(no-wallclock-in-deterministic-paths) compute_secs telemetry only; decode order never reads it
    let (wa, wb) = ctx.job_matrices(stream, seq)?;
    let payload = engine.matmul(&wa, &wb)?;
    let elapsed = t0.elapsed().as_secs_f64();
    // report the original injected arrival time when this is our own
    // stream (deterministic runs order decode by the precomputed
    // schedule, not this value); otherwise report measured time
    let delay = if stream == ctx.stream && (seq as usize) < ctx.delays.len() {
        ctx.delays[seq as usize]
    } else if cfg.time_scale > 0.0 {
        elapsed / cfg.time_scale
    } else {
        elapsed
    };
    let reply = Msg::RatelessResult(RatelessResultMsg {
        request_id: ctx.request_id,
        stream,
        seq,
        attempt,
        delay,
        compute_secs: elapsed,
        more: true,
        payload,
    });
    match conn.send(&reply) {
        Ok(()) => stats.packets += 1,
        Err(WireError::Closed) => *sink_closed = true,
        Err(e) => anyhow::bail!("{}: send failed: {e}", cfg.name),
    }
    Ok(())
}

/// Spawn `n` loopback worker threads dialed into `dialer`, each with its
/// own serial [`NativeEngine`] (the threads themselves are the
/// parallelism, exactly like the thread-pool service path).
pub fn spawn_loopback_workers(
    dialer: &LoopbackDialer,
    n: usize,
    base: &WorkerConfig,
) -> Vec<JoinHandle<Result<WorkerStats>>> {
    (0..n)
        .map(|i| {
            let dialer = dialer.clone();
            let mut cfg = base.clone();
            cfg.name = format!("{}-{i}", base.name);
            cfg.seed = base.seed.wrapping_add(i as u64);
            std::thread::Builder::new()
                .name(format!("uepmm-cluster-{}", cfg.name))
                .spawn(move || {
                    let mut conn = dialer
                        .dial(&cfg.name)
                        .map_err(|e| anyhow::anyhow!("{}: dial failed: {e}", cfg.name))?;
                    run_worker(&mut conn, &NativeEngine::serial(), &cfg)
                })
                .expect("spawn cluster worker thread") // lint:allow(no-panic-in-server-loops) one-time startup spawn; thread exhaustion here is fatal by design
        })
        .collect()
}

/// Spawn one loopback worker whose *sends* pass through the seeded
/// chaos layer (see [`super::chaos`]): a deterministic lossy, lying, or
/// hanging peer for soak tests. The worker itself stays honest — the
/// faults live in the connection.
pub fn spawn_chaos_loopback_worker(
    dialer: &LoopbackDialer,
    cfg: &WorkerConfig,
    plan: &super::chaos::FaultPlan,
) -> JoinHandle<Result<WorkerStats>> {
    let dialer = dialer.clone();
    let cfg = cfg.clone();
    let plan = plan.clone();
    std::thread::Builder::new()
        .name(format!("uepmm-chaos-{}", cfg.name))
        .spawn(move || {
            let conn = dialer
                .dial(&cfg.name)
                .map_err(|e| anyhow::anyhow!("{}: dial failed: {e}", cfg.name))?;
            let mut conn = super::chaos::ChaosConn::new(Box::new(conn), &plan);
            run_worker(&mut conn, &NativeEngine::serial(), &cfg)
        })
        .expect("spawn chaos worker thread") // lint:allow(no-panic-in-server-loops) one-time startup spawn; thread exhaustion here is fatal by design
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::transport::loopback_pair;
    use crate::cluster::wire::JobMsg;
    use crate::linalg::{matmul, Matrix};

    #[test]
    fn worker_registers_computes_and_shuts_down() {
        let (mut ps, mut wk) = loopback_pair("ps", "wk");
        let handle = std::thread::spawn(move || {
            let cfg = WorkerConfig { name: "t0".to_string(), ..Default::default() };
            run_worker(&mut wk, &NativeEngine::serial(), &cfg).unwrap()
        });
        match ps.recv().unwrap() {
            Msg::Hello { agent } => assert_eq!(agent, "t0"),
            other => panic!("unexpected {other:?}"),
        }
        ps.send(&Msg::Welcome { worker_id: 4 }).unwrap();

        let mut rng = Pcg64::seed_from(1);
        let wa = Matrix::randn(3, 5, 0.0, 1.0, &mut rng);
        let wb = Matrix::randn(5, 2, 0.0, 1.0, &mut rng);
        ps.send(&Msg::Job(JobMsg {
            request_id: 9,
            slot: 2,
            attempt: 3,
            injected_delay: Some(0.75),
            sleep_secs: 0.0,
            wa: std::sync::Arc::new(wa.clone()),
            wb: std::sync::Arc::new(wb.clone()),
        }))
        .unwrap();
        match ps.recv().unwrap() {
            Msg::Result(r) => {
                assert_eq!(r.request_id, 9);
                assert_eq!(r.slot, 2);
                assert_eq!(r.attempt, 3, "the dispatch attempt must be echoed");
                assert_eq!(r.delay, 0.75);
                assert!(r.payload.allclose(&matmul(&wa, &wb), 1e-12));
            }
            other => panic!("unexpected {other:?}"),
        }

        ps.send(&Msg::Heartbeat { nonce: 6 }).unwrap();
        assert!(matches!(ps.recv().unwrap(), Msg::HeartbeatAck { nonce: 6 }));

        ps.send(&Msg::Shutdown).unwrap();
        let stats = handle.join().unwrap();
        assert_eq!(
            stats,
            WorkerStats {
                worker_id: 4,
                jobs: 1,
                heartbeats: 1,
                packets: 0,
                clean_shutdown: true
            }
        );
    }

    #[test]
    fn worker_streams_rateless_packets_serves_redo_and_drains() {
        use crate::cluster::wire::RatelessJobMsg;
        use crate::coding::{RatelessCoder, UepWindows};
        use std::sync::Arc;

        let (mut ps, mut wk) = loopback_pair("ps", "wk");
        let handle = std::thread::spawn(move || {
            let cfg = WorkerConfig { name: "rl".to_string(), ..Default::default() };
            run_worker(&mut wk, &NativeEngine::serial(), &cfg).unwrap()
        });
        assert!(matches!(ps.recv().unwrap(), Msg::Hello { .. }));
        ps.send(&Msg::Welcome { worker_id: 1 }).unwrap();

        // 2 a-blocks × 2 b-blocks, 4 unknowns in 2 classes
        let mut rng = Pcg64::seed_from(3);
        let a_blocks: Vec<Arc<Matrix>> = (0..2)
            .map(|_| Arc::new(Matrix::randn(2, 3, 0.0, 1.0, &mut rng)))
            .collect();
        let b_blocks: Vec<Arc<Matrix>> = (0..2)
            .map(|_| Arc::new(Matrix::randn(3, 2, 0.0, 1.0, &mut rng)))
            .collect();
        let class_of = vec![0u32, 0, 1, 1];
        let factors = vec![(0u32, 0u32), (0, 1), (1, 0), (1, 1)];
        let rj = RatelessJobMsg {
            request_id: 77,
            stream: 0,
            budget: 3,
            delta: 0.05,
            c: 0.1,
            gamma: vec![0.6, 0.4],
            class_of: class_of.clone(),
            factors: factors.clone(),
            delays: vec![0.5, 1.0, 1.5],
            t_max: 2.0,
            pace: 0.0,
            a_blocks: a_blocks.clone(),
            b_blocks: b_blocks.clone(),
        };
        ps.send(&Msg::RatelessJob(rj)).unwrap();

        // the reference coder must predict every payload exactly
        let coder = RatelessCoder::new(
            0.05,
            0.1,
            &crate::coding::WindowPolynomial::new(&[0.6, 0.4]),
            UepWindows::from_class_of(&class_of),
        );
        let expect_payload = |stream: u64, seq: u32| {
            let pkt = coder.packet(77, stream, seq);
            let crate::coding::JobRecipe::Stacked { terms } = &pkt.recipe else {
                panic!("not stacked");
            };
            let (wa, wb) =
                super::stack_from_factors(terms, &factors, &a_blocks, &b_blocks);
            matmul(&wa, &wb)
        };
        for seq in 0..3u32 {
            match ps.recv().unwrap() {
                Msg::RatelessResult(r) => {
                    assert_eq!((r.request_id, r.stream, r.seq), (77, 0, seq));
                    assert_eq!(r.attempt, 0);
                    assert_eq!(r.more, seq < 2, "seq {seq}");
                    assert_eq!(r.delay, 0.5 * (seq + 1) as f64);
                    assert!(r.payload.allclose(&expect_payload(0, seq), 1e-12));
                }
                other => panic!("unexpected {other:?}"),
            }
        }

        // redo: a packet of a *different* stream, from the kept context
        ps.send(&Msg::Redo { request_id: 77, stream: 5, seq: 2, attempt: 1 })
            .unwrap();
        match ps.recv().unwrap() {
            Msg::RatelessResult(r) => {
                assert_eq!((r.stream, r.seq, r.attempt), (5, 2, 1));
                assert!(r.payload.allclose(&expect_payload(5, 2), 1e-12));
            }
            other => panic!("unexpected {other:?}"),
        }

        // drain drops the context; a later redo for it is ignored and
        // the worker keeps serving (heartbeat still answered)
        ps.send(&Msg::Drain { request_id: 77 }).unwrap();
        ps.send(&Msg::Redo { request_id: 77, stream: 0, seq: 0, attempt: 2 })
            .unwrap();
        ps.send(&Msg::Heartbeat { nonce: 8 }).unwrap();
        assert!(matches!(ps.recv().unwrap(), Msg::HeartbeatAck { nonce: 8 }));

        ps.send(&Msg::Shutdown).unwrap();
        let stats = handle.join().unwrap();
        assert_eq!(stats.packets, 4);
        assert!(stats.clean_shutdown);
    }

    #[test]
    fn worker_exits_quietly_when_coordinator_vanishes() {
        let (mut ps, mut wk) = loopback_pair("ps", "wk");
        let handle = std::thread::spawn(move || {
            let cfg = WorkerConfig::default();
            run_worker(&mut wk, &NativeEngine::serial(), &cfg)
        });
        assert!(matches!(ps.recv().unwrap(), Msg::Hello { .. }));
        ps.send(&Msg::Welcome { worker_id: 1 }).unwrap();
        drop(ps);
        let stats = handle.join().unwrap().unwrap();
        assert!(!stats.clean_shutdown);
        assert_eq!(stats.jobs, 0);
    }

    #[test]
    fn self_injected_latency_reports_sampled_delays() {
        let (mut ps, mut wk) = loopback_pair("ps", "wk");
        let seed = 42;
        let handle = std::thread::spawn(move || {
            let cfg = WorkerConfig {
                latency: Some(LatencyModel::exp(1.0)),
                omega: 0.5,
                time_scale: 0.0, // no sleeping in tests
                seed,
                ..Default::default()
            };
            run_worker(&mut wk, &NativeEngine::serial(), &cfg).unwrap()
        });
        assert!(matches!(ps.recv().unwrap(), Msg::Hello { .. }));
        ps.send(&Msg::Welcome { worker_id: 0 }).unwrap();
        let mut expect_rng = Pcg64::seed_from(seed);
        let model = LatencyModel::exp(1.0);
        let m = Matrix::from_vec(1, 1, vec![2.0]);
        for slot in 0..3u32 {
            ps.send(&Msg::Job(JobMsg {
                request_id: 1,
                slot,
                attempt: 0,
                injected_delay: None,
                sleep_secs: 0.0,
                wa: std::sync::Arc::new(m.clone()),
                wb: std::sync::Arc::new(m.clone()),
            }))
            .unwrap();
            let want = model.sample_scaled(0.5, &mut expect_rng);
            match ps.recv().unwrap() {
                Msg::Result(r) => assert_eq!(r.delay, want, "slot {slot}"),
                other => panic!("unexpected {other:?}"),
            }
        }
        ps.send(&Msg::Shutdown).unwrap();
        assert_eq!(handle.join().unwrap().jobs, 3);
    }
}
