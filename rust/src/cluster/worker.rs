//! The worker agent: registers with a coordinator, computes coded
//! sub-products through an [`ExecEngine`], and streams results back.
//!
//! One loop serves every transport. Straggle modelling is layered:
//!
//! * **coordinator-injected** — a job can carry a pre-sampled virtual
//!   completion time (`injected_delay`) plus a wall pacing budget
//!   (`sleep_secs`); this is how seeded deterministic runs work.
//! * **self-injected** — a worker configured with a
//!   [`LatencyModel`] samples its own completion time per job from its
//!   seeded RNG (the `uepmm worker --latency exp:1.0` path).
//! * **natural** — with neither, the reported delay is the measured
//!   wall time of the computation: straggling is whatever the host and
//!   transport actually do.

use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::latency::LatencyModel;
use crate::rng::Pcg64;
use crate::runtime::{ExecEngine, NativeEngine};

use super::transport::{Connection, LoopbackDialer};
use super::wire::{Msg, ResultMsg, WireError};

/// Configuration of one worker agent.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Name announced in the registration handshake (logs/registry).
    pub name: String,
    /// Self-injected straggle model (`None` = coordinator-injected or
    /// natural timing only).
    pub latency: Option<LatencyModel>,
    /// Capacity scaling for self-sampled delays (paper Remark 1).
    pub omega: f64,
    /// Wall seconds per virtual time unit for self-injected sleeps and
    /// for converting measured wall time back to virtual time. `0`
    /// disables sleeping.
    pub time_scale: f64,
    /// Seed of the worker's private delay-sampling RNG.
    pub seed: u64,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            name: "worker".to_string(),
            latency: None,
            omega: 1.0,
            time_scale: 0.0,
            seed: 0,
        }
    }
}

/// What a worker did over its lifetime, reported when the loop exits.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkerStats {
    pub worker_id: u64,
    pub jobs: u64,
    pub heartbeats: u64,
    /// `true` when the coordinator sent an explicit shutdown (clean
    /// exit), `false` when the connection dropped.
    pub clean_shutdown: bool,
}

/// Run the worker loop until shutdown or disconnect. Registers, then
/// serves jobs and heartbeats.
pub fn run_worker<E: ExecEngine>(
    conn: &mut dyn Connection,
    engine: &E,
    cfg: &WorkerConfig,
) -> Result<WorkerStats> {
    conn.send(&Msg::Hello { agent: cfg.name.clone() })
        .map_err(|e| anyhow::anyhow!("{}: hello failed: {e}", cfg.name))?;
    let worker_id = match conn.recv() {
        Ok(Msg::Welcome { worker_id }) => worker_id,
        Ok(other) => anyhow::bail!("{}: expected welcome, got {}", cfg.name, other.name()),
        Err(e) => anyhow::bail!("{}: registration failed: {e}", cfg.name),
    };
    let mut rng = Pcg64::seed_from(cfg.seed);
    let mut stats = WorkerStats {
        worker_id,
        jobs: 0,
        heartbeats: 0,
        clean_shutdown: false,
    };
    // Set once a send hits a closed peer: the coordinator stopped
    // listening (it may still have queued a Shutdown behind the job
    // backlog), so stop computing and drain the receive side looking for
    // the orderly goodbye.
    let mut sink_closed = false;
    loop {
        let msg = match conn.recv_timeout(None) {
            Ok(Some(m)) => m,
            Ok(None) => continue,
            Err(WireError::Closed) => break,
            Err(e) => return Err(anyhow::anyhow!("{}: receive failed: {e}", cfg.name)),
        };
        match msg {
            Msg::Job(job) => {
                if sink_closed {
                    continue;
                }
                let t0 = Instant::now();
                let payload = engine.matmul(&job.wa, &job.wb)?;
                let elapsed = t0.elapsed().as_secs_f64();
                // completion time and pacing, per the layering above
                let (delay, sleep_secs) = match (job.injected_delay, &cfg.latency) {
                    (Some(d), _) => (d, job.sleep_secs),
                    (None, Some(model)) => {
                        let d = model.sample_scaled(cfg.omega, &mut rng);
                        (d, d * cfg.time_scale)
                    }
                    (None, None) => {
                        let d = if cfg.time_scale > 0.0 {
                            elapsed / cfg.time_scale
                        } else {
                            elapsed
                        };
                        (d, 0.0)
                    }
                };
                if sleep_secs > elapsed {
                    std::thread::sleep(Duration::from_secs_f64(sleep_secs - elapsed));
                }
                let reply = Msg::Result(ResultMsg {
                    request_id: job.request_id,
                    slot: job.slot,
                    // echo the dispatch attempt so the coordinator can
                    // attribute duplicates of a re-dispatched slot
                    attempt: job.attempt,
                    delay,
                    // measured compute floor, separate from any modelled
                    // straggle above — coordinator-side telemetry
                    compute_secs: elapsed,
                    payload,
                });
                match conn.send(&reply) {
                    Ok(()) => stats.jobs += 1,
                    Err(WireError::Closed) => sink_closed = true,
                    Err(e) => {
                        return Err(anyhow::anyhow!("{}: send failed: {e}", cfg.name))
                    }
                }
            }
            Msg::Heartbeat { nonce } => {
                if sink_closed {
                    continue;
                }
                match conn.send(&Msg::HeartbeatAck { nonce }) {
                    Ok(()) => stats.heartbeats += 1,
                    Err(WireError::Closed) => sink_closed = true,
                    Err(e) => {
                        return Err(anyhow::anyhow!("{}: send failed: {e}", cfg.name))
                    }
                }
            }
            Msg::Shutdown => {
                stats.clean_shutdown = true;
                break;
            }
            // coordinator-only messages arriving here are a protocol
            // violation; drop the connection rather than guessing
            other => {
                anyhow::bail!("{}: unexpected {} from coordinator", cfg.name, other.name())
            }
        }
    }
    Ok(stats)
}

/// Spawn `n` loopback worker threads dialed into `dialer`, each with its
/// own serial [`NativeEngine`] (the threads themselves are the
/// parallelism, exactly like the thread-pool service path).
pub fn spawn_loopback_workers(
    dialer: &LoopbackDialer,
    n: usize,
    base: &WorkerConfig,
) -> Vec<JoinHandle<Result<WorkerStats>>> {
    (0..n)
        .map(|i| {
            let dialer = dialer.clone();
            let mut cfg = base.clone();
            cfg.name = format!("{}-{i}", base.name);
            cfg.seed = base.seed.wrapping_add(i as u64);
            std::thread::Builder::new()
                .name(format!("uepmm-cluster-{}", cfg.name))
                .spawn(move || {
                    let mut conn = dialer
                        .dial(&cfg.name)
                        .map_err(|e| anyhow::anyhow!("{}: dial failed: {e}", cfg.name))?;
                    run_worker(&mut conn, &NativeEngine::serial(), &cfg)
                })
                .expect("spawn cluster worker thread")
        })
        .collect()
}

/// Spawn one loopback worker whose *sends* pass through the seeded
/// chaos layer (see [`super::chaos`]): a deterministic lossy, lying, or
/// hanging peer for soak tests. The worker itself stays honest — the
/// faults live in the connection.
pub fn spawn_chaos_loopback_worker(
    dialer: &LoopbackDialer,
    cfg: &WorkerConfig,
    plan: &super::chaos::FaultPlan,
) -> JoinHandle<Result<WorkerStats>> {
    let dialer = dialer.clone();
    let cfg = cfg.clone();
    let plan = plan.clone();
    std::thread::Builder::new()
        .name(format!("uepmm-chaos-{}", cfg.name))
        .spawn(move || {
            let conn = dialer
                .dial(&cfg.name)
                .map_err(|e| anyhow::anyhow!("{}: dial failed: {e}", cfg.name))?;
            let mut conn = super::chaos::ChaosConn::new(Box::new(conn), &plan);
            run_worker(&mut conn, &NativeEngine::serial(), &cfg)
        })
        .expect("spawn chaos worker thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::transport::loopback_pair;
    use crate::cluster::wire::JobMsg;
    use crate::linalg::{matmul, Matrix};

    #[test]
    fn worker_registers_computes_and_shuts_down() {
        let (mut ps, mut wk) = loopback_pair("ps", "wk");
        let handle = std::thread::spawn(move || {
            let cfg = WorkerConfig { name: "t0".to_string(), ..Default::default() };
            run_worker(&mut wk, &NativeEngine::serial(), &cfg).unwrap()
        });
        match ps.recv().unwrap() {
            Msg::Hello { agent } => assert_eq!(agent, "t0"),
            other => panic!("unexpected {other:?}"),
        }
        ps.send(&Msg::Welcome { worker_id: 4 }).unwrap();

        let mut rng = Pcg64::seed_from(1);
        let wa = Matrix::randn(3, 5, 0.0, 1.0, &mut rng);
        let wb = Matrix::randn(5, 2, 0.0, 1.0, &mut rng);
        ps.send(&Msg::Job(JobMsg {
            request_id: 9,
            slot: 2,
            attempt: 3,
            injected_delay: Some(0.75),
            sleep_secs: 0.0,
            wa: std::sync::Arc::new(wa.clone()),
            wb: std::sync::Arc::new(wb.clone()),
        }))
        .unwrap();
        match ps.recv().unwrap() {
            Msg::Result(r) => {
                assert_eq!(r.request_id, 9);
                assert_eq!(r.slot, 2);
                assert_eq!(r.attempt, 3, "the dispatch attempt must be echoed");
                assert_eq!(r.delay, 0.75);
                assert!(r.payload.allclose(&matmul(&wa, &wb), 1e-12));
            }
            other => panic!("unexpected {other:?}"),
        }

        ps.send(&Msg::Heartbeat { nonce: 6 }).unwrap();
        assert!(matches!(ps.recv().unwrap(), Msg::HeartbeatAck { nonce: 6 }));

        ps.send(&Msg::Shutdown).unwrap();
        let stats = handle.join().unwrap();
        assert_eq!(
            stats,
            WorkerStats { worker_id: 4, jobs: 1, heartbeats: 1, clean_shutdown: true }
        );
    }

    #[test]
    fn worker_exits_quietly_when_coordinator_vanishes() {
        let (mut ps, mut wk) = loopback_pair("ps", "wk");
        let handle = std::thread::spawn(move || {
            let cfg = WorkerConfig::default();
            run_worker(&mut wk, &NativeEngine::serial(), &cfg)
        });
        assert!(matches!(ps.recv().unwrap(), Msg::Hello { .. }));
        ps.send(&Msg::Welcome { worker_id: 1 }).unwrap();
        drop(ps);
        let stats = handle.join().unwrap().unwrap();
        assert!(!stats.clean_shutdown);
        assert_eq!(stats.jobs, 0);
    }

    #[test]
    fn self_injected_latency_reports_sampled_delays() {
        let (mut ps, mut wk) = loopback_pair("ps", "wk");
        let seed = 42;
        let handle = std::thread::spawn(move || {
            let cfg = WorkerConfig {
                latency: Some(LatencyModel::exp(1.0)),
                omega: 0.5,
                time_scale: 0.0, // no sleeping in tests
                seed,
                ..Default::default()
            };
            run_worker(&mut wk, &NativeEngine::serial(), &cfg).unwrap()
        });
        assert!(matches!(ps.recv().unwrap(), Msg::Hello { .. }));
        ps.send(&Msg::Welcome { worker_id: 0 }).unwrap();
        let mut expect_rng = Pcg64::seed_from(seed);
        let model = LatencyModel::exp(1.0);
        let m = Matrix::from_vec(1, 1, vec![2.0]);
        for slot in 0..3u32 {
            ps.send(&Msg::Job(JobMsg {
                request_id: 1,
                slot,
                attempt: 0,
                injected_delay: None,
                sleep_secs: 0.0,
                wa: std::sync::Arc::new(m.clone()),
                wb: std::sync::Arc::new(m.clone()),
            }))
            .unwrap();
            let want = model.sample_scaled(0.5, &mut expect_rng);
            match ps.recv().unwrap() {
                Msg::Result(r) => assert_eq!(r.delay, want, "slot {slot}"),
                other => panic!("unexpected {other:?}"),
            }
        }
        ps.send(&Msg::Shutdown).unwrap();
        assert_eq!(handle.join().unwrap().jobs, 3);
    }
}
