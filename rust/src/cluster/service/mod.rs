//! The multi-tenant serve plane: many concurrent client sessions
//! multiplexed onto one shared worker fleet.
//!
//! Everything below [`crate::api::Session`] so far serves **one** caller
//! at a time: `ClusterServer` owns its fleet for the duration of each
//! request. This module is the missing deployment shape — a long-lived
//! plane process (`uepmm serve --service`) that accepts *both* worker
//! and client connections on a single front door and keeps the fleet
//! busy across tenants:
//!
//! * [`plane`] — the front-door reactor ([`ServePlane`]): one listener,
//!   per-connection state machines, admission control;
//! * [`engine`] — the fleet multiplexer ([`FleetEngine`]): worker
//!   lanes, deficit-round-robin dispatch across sessions, zero-copy
//!   vectored job sends, collect-all virtual-time settlement;
//! * [`scheduler`] — the fairness core ([`DrrScheduler`]): deficit
//!   round robin with per-tenant in-flight quotas;
//! * [`decode`] — the sharded decode pool ([`DecodePool`]): settled
//!   requests decode off the reactor thread, one shard per request, so
//!   a large decode never blocks dispatch or admission.
//!
//! # Wire protocol v6 — the client plane
//!
//! Workers keep speaking the existing frames (`Hello`/`Welcome`,
//! `Job`/`Result`, heartbeats). v6 adds a client plane on the same
//! framing (CRC32 trailer, resync-past-damage contract):
//!
//! | Frame | Direction | Purpose |
//! |---|---|---|
//! | `OpenSession` | client → plane, echoed back | open a session; the echo carries the assigned session id |
//! | `Submit` | client → plane | one prepared request: coefficient rows, `W_A`/`W_B` per slot, injected delays, optional Gram matrix for plane-side loss scoring |
//! | `ProgressFrame` | plane → client | one decode refinement (received/recovered/newly, running loss) |
//! | `Result` (`ClientResult`) | plane → client | final report: `Ĉ`, per-class recovery, loss, accounting |
//! | `Reject` | plane → client | admission refusal with a `retry_after` backoff hint |
//! | `CloseSession` | client → plane, echoed back | drain in-flight requests, then part cleanly |
//!
//! # Session lifecycle
//!
//! ```text
//! dial ── OpenSession ──▶ admission ──▶ ack (assigned id)
//!                        │ (≥ max_sessions)
//!                        └─▶ Reject{retry_after} + drop
//! ack ── Submit* ──▶ queue-depth check ──▶ engine (DRR dispatch)
//!                   │ (≥ queue_depth)
//!                   └─▶ Reject{retry_after}
//! engine ──▶ settle (collect-all) ──▶ decode shard ──▶ ProgressFrame* + Result
//! CloseSession ──▶ drain ──▶ echo ──▶ close
//! ```
//!
//! # Determinism
//!
//! The engine settles every request with collect-all virtual-time
//! semantics: a request completes only when all of its slots have a
//! result (or are written off), results sort by `(delay, slot)`, and
//! the deadline splits absorbed from late — so the decoded outcome is a
//! pure function of the submitted request, independent of wall-clock
//! races, client arrival interleaving, and the DRR dispatch order.
//! `rust/tests/service_plane.rs` asserts bit-identical outcomes for
//! three concurrent clients against the same clients served one at a
//! time.
//!
//! # Design note: no async runtime
//!
//! ROADMAP item 3 sketched this subsystem over tokio behind a feature
//! gate. This build is offline-vendored (no tokio in the dependency
//! tree), so the plane is a hand-rolled readiness loop instead:
//! `std::net` nonblocking accepts plus short-deadline
//! `recv_timeout(POLL_SLICE)` ticks driving per-connection state
//! machines. The blocking-I/O surface stays in [`super::transport`];
//! swapping in an async reactor later only replaces the tick loop, not
//! the protocol or the state machines.

pub mod decode;
pub mod engine;
pub mod plane;
pub mod scheduler;

pub use decode::{DecodeEvent, DecodePool, DecodeTask, RequestCounters};
pub use engine::FleetEngine;
pub use plane::{ServePlane, ServiceReport};
pub use scheduler::DrrScheduler;

/// Serve-plane sizing and admission policy.
///
/// Distinct from the deprecated single-stream
/// [`crate::coordinator::ServiceConfig`] (the threaded-service shim):
/// this one governs the multi-tenant plane.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Concurrent client sessions admitted; the `max_sessions + 1`-th
    /// `OpenSession` gets a [`crate::cluster::wire::Msg::Reject`].
    pub max_sessions: usize,
    /// Fleet-wide cap on outstanding job frames (backpressure on
    /// dispatch, not on admission).
    pub max_inflight_jobs: usize,
    /// Per-session requests accepted before `Submit` is rejected
    /// (queued + being served).
    pub queue_depth: usize,
    /// Per-session cap on in-flight *jobs* — the DRR quota that keeps
    /// one tenant from monopolizing the fleet.
    pub tenant_quota: u32,
    /// DRR quantum: consecutive job dispatches granted per scheduler
    /// visit.
    pub quantum: u32,
    /// Decode pool threads; requests shard by request id.
    pub decode_shards: usize,
    /// Backoff hint (virtual seconds) carried in every `Reject`.
    pub retry_after: f64,
    /// Freivalds-verify every arriving result (seeded per request, so
    /// honest outcomes are unchanged by toggling this).
    pub verify: bool,
    /// Seed of the verification probe stream.
    pub verify_seed: u64,
    /// Re-dispatches per slot after worker death or a rejected result.
    pub max_job_retries: u32,
    /// Heterogeneity-aware lane weighting: pick dispatch lanes by
    /// `(inflight + 1) · scale` — an EWMA of each lane's reported
    /// result delays, normalized by the live-lane mean — instead of
    /// raw occupancy, and charge the owning tenant extra DRR credit
    /// when its job lands on a slower-than-mean lane (slow capacity is
    /// not free capacity). Identical to occupancy-order until lanes
    /// actually diverge, and never changes decoded outcomes (results
    /// are absorbed in virtual-time order regardless of lane).
    pub hetero_lanes: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_sessions: 8,
            max_inflight_jobs: 64,
            queue_depth: 4,
            tenant_quota: 4,
            quantum: 2,
            decode_shards: 2,
            retry_after: 0.25,
            verify: true,
            verify_seed: 0xf7e1_5eed,
            max_job_retries: 2,
            hetero_lanes: false,
        }
    }
}
