//! The front-door reactor: one listener, every connection, no threads.
//!
//! Workers and clients dial the same [`Transport`]; the first frame
//! disambiguates (`Hello` → worker lane handed to the [`FleetEngine`],
//! `OpenSession` → admission control). The reactor is a single-threaded
//! tick loop over short-deadline receives — each tick accepts at most
//! one connection, advances every handshake, drains every client,
//! drives the engine, and fans decode events back out. No connection
//! ever blocks the loop for more than one [`POLL_SLICE`].
//!
//! Admission happens at two gates, both answered with
//! [`Msg::Reject`] carrying the configured `retry_after` backoff hint:
//!
//! * **session table** — the `max_sessions + 1`-th concurrent
//!   `OpenSession` is refused and the connection dropped;
//! * **request queue** — a `Submit` beyond `queue_depth` outstanding
//!   requests on its session is refused (the session stays open), as is
//!   one that fails engine validation.
//!
//! Progress lines printed by the plane (`session opened:`, `served:`,
//! `reject:`, `service shutdown complete:`) are a stable grep surface —
//! the CI service-smoke job asserts on them.

use std::time::Instant;

use super::super::transport::{Connection, Transport};
use super::super::wire::Msg;
use super::decode::DecodeEvent;
use super::engine::{FleetEngine, POLL_SLICE};
use super::ServiceConfig;

/// How long a dialed-in connection may sit silent before its handshake
/// slot is reclaimed.
const HANDSHAKE_GRACE_SECS: u64 = 10;

/// One admitted client session.
struct Client {
    session: u64,
    name: String,
    conn: Box<dyn Connection>,
    alive: bool,
    /// Client asked to close; the plane drains in-flight requests first.
    closing: bool,
    /// Request ids submitted and not yet answered.
    inflight: Vec<u64>,
}

/// What the plane did over its lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServiceReport {
    /// Sessions admitted (not counting rejected dials).
    pub sessions: u64,
    /// Requests answered with a `ClientResult`.
    pub served: u64,
    /// `Reject` frames sent (admission plus queue-depth plus invalid).
    pub rejected: u64,
}

/// The serve-plane reactor. Owns the engine, the admitted clients, and
/// the handshake queue; [`ServePlane::run`] is the process main loop.
pub struct ServePlane {
    cfg: ServiceConfig,
    engine: FleetEngine,
    clients: Vec<Client>,
    handshakes: Vec<(Box<dyn Connection>, Instant)>,
    next_session: u64,
    report: ServiceReport,
    /// Sessions that ended (clean close or connection loss).
    ended: u64,
}

impl ServePlane {
    pub fn new(cfg: ServiceConfig) -> ServePlane {
        let engine = FleetEngine::new(cfg.clone());
        ServePlane {
            cfg,
            engine,
            clients: Vec::new(),
            handshakes: Vec::new(),
            next_session: 1,
            report: ServiceReport::default(),
            ended: 0,
        }
    }

    /// Serve until `expected_sessions` client sessions have come and
    /// gone (however they end), then shut the fleet down cleanly.
    ///
    /// The expected-session count is the harness's termination contract:
    /// a long-lived deployment would pass `usize::MAX` and be killed by
    /// signal instead.
    pub fn run(
        mut self,
        transport: &mut dyn Transport,
        expected_sessions: usize,
    ) -> ServiceReport {
        println!(
            "service listening on {} (max_sessions={} queue_depth={} quota={})",
            transport.local_addr(),
            self.cfg.max_sessions,
            self.cfg.queue_depth,
            self.cfg.tenant_quota,
        );
        loop {
            self.accept_one(transport);
            self.advance_handshakes();
            self.drain_clients();
            self.engine.tick();
            let events = self.engine.poll_events();
            for ev in events {
                self.deliver(ev);
            }
            self.reap();
            if self.ended >= expected_sessions as u64
                && self.clients.is_empty()
                && self.engine.active_requests() == 0
            {
                break;
            }
        }
        for (name, jobs, alive) in self.engine.lane_summary() {
            println!(
                "lane {name}: jobs={jobs} ({})",
                if alive { "alive" } else { "lost" }
            );
        }
        self.engine.shutdown();
        println!(
            "service shutdown complete: sessions={} served={} rejected={}",
            self.report.sessions, self.report.served, self.report.rejected,
        );
        self.report
    }

    fn accept_one(&mut self, transport: &mut dyn Transport) {
        if let Ok(Some(conn)) = transport.accept_timeout(POLL_SLICE) {
            self.handshakes.push((conn, Instant::now())); // lint:allow(no-wallclock-in-deterministic-paths) handshake grace timer only; decode never reads it
        }
    }

    /// First-frame disambiguation: `Hello` makes a worker lane,
    /// `OpenSession` faces admission control.
    fn advance_handshakes(&mut self) {
        let mut i = 0;
        while i < self.handshakes.len() {
            let (conn, since) = &mut self.handshakes[i];
            match conn.recv_timeout(Some(POLL_SLICE)) {
                Ok(Some(Msg::Hello { agent })) => {
                    let (conn, _) = self.handshakes.remove(i);
                    match self.engine.add_worker(conn, agent.clone()) {
                        Some(id) => println!("worker joined: {agent} (lane {id})"),
                        None => println!("worker {agent} lost during welcome"),
                    }
                }
                Ok(Some(Msg::OpenSession { client, .. })) => {
                    let (conn, _) = self.handshakes.remove(i);
                    self.admit(conn, client);
                }
                Ok(None) => {
                    if since.elapsed().as_secs() >= HANDSHAKE_GRACE_SECS {
                        self.handshakes.remove(i);
                    } else {
                        i += 1;
                    }
                }
                Ok(Some(_)) | Err(_) => {
                    // spoke out of turn or died: not a peer
                    self.handshakes.remove(i);
                }
            }
        }
    }

    fn admit(&mut self, mut conn: Box<dyn Connection>, client: String) {
        if self.clients.len() >= self.cfg.max_sessions {
            self.report.rejected += 1;
            println!(
                "reject: session table full ({}/{}), client {client}",
                self.clients.len(),
                self.cfg.max_sessions,
            );
            let _ = conn.send(&Msg::Reject {
                session: 0,
                request: 0,
                retry_after: self.cfg.retry_after,
                reason: "session table full".to_string(),
            });
            return; // dropped: the client re-dials after the backoff
        }
        let session = self.next_session;
        self.next_session += 1;
        if conn
            .send(&Msg::OpenSession { session, client: client.clone() })
            .is_err()
        {
            return;
        }
        self.engine.open_session(session);
        self.report.sessions += 1;
        println!("session opened: {session} ({client})");
        self.clients.push(Client {
            session,
            name: client,
            conn,
            alive: true,
            closing: false,
            inflight: Vec::new(),
        });
    }

    fn drain_clients(&mut self) {
        for ci in 0..self.clients.len() {
            loop {
                let client = &mut self.clients[ci];
                if !client.alive {
                    break;
                }
                match client.conn.recv_timeout(Some(POLL_SLICE)) {
                    Ok(Some(Msg::Submit(mut sub))) => {
                        let session = client.session;
                        let request = sub.request;
                        // the connection, not the frame, names the tenant
                        sub.session = session;
                        if client.inflight.len() >= self.cfg.queue_depth {
                            self.reject(ci, session, request, "request queue full");
                            continue;
                        }
                        match self.engine.add_request(sub) {
                            Ok(()) => self.clients[ci].inflight.push(request),
                            Err(reason) => {
                                self.reject(ci, session, request, &reason)
                            }
                        }
                    }
                    Ok(Some(Msg::CloseSession { .. })) => {
                        client.closing = true;
                    }
                    Ok(None) => break,
                    Ok(Some(_)) | Err(_) => {
                        // protocol violation or lost connection
                        client.alive = false;
                    }
                }
            }
        }
    }

    fn reject(&mut self, ci: usize, session: u64, request: u64, reason: &str) {
        self.report.rejected += 1;
        println!("reject: {reason} (session={session} request={request})");
        let sent = self.clients[ci].conn.send(&Msg::Reject {
            session,
            request,
            retry_after: self.cfg.retry_after,
            reason: reason.to_string(),
        });
        if sent.is_err() {
            self.clients[ci].alive = false;
        }
    }

    /// Fan one decode event back out to its session.
    fn deliver(&mut self, ev: DecodeEvent) {
        match ev {
            DecodeEvent::Step { session, msg, .. } => {
                if let Some(c) = self
                    .clients
                    .iter_mut()
                    .find(|c| c.session == session && c.alive)
                {
                    if c.conn.send(&Msg::ProgressFrame(msg)).is_err() {
                        c.alive = false;
                    }
                }
            }
            DecodeEvent::Done { session, request, result, full_recovery } => {
                self.report.served += 1;
                println!(
                    "served: session={session} request={request} received={} \
                     recovered={} loss={:.6} full_recovery={full_recovery} \
                     wall_ms={}",
                    result.received,
                    result.recovered,
                    result.normalized_loss,
                    result.wall_ms,
                );
                if let Some(c) = self
                    .clients
                    .iter_mut()
                    .find(|c| c.session == session && c.alive)
                {
                    c.inflight.retain(|&r| r != request);
                    if c.conn.send(&Msg::ClientResult(result)).is_err() {
                        c.alive = false;
                    }
                }
            }
        }
    }

    /// Retire sessions that finished closing or whose connection died.
    fn reap(&mut self) {
        let mut i = 0;
        while i < self.clients.len() {
            let c = &mut self.clients[i];
            if c.alive && !(c.closing && c.inflight.is_empty()) {
                i += 1;
                continue;
            }
            if c.alive {
                let _ = c.conn.send(&Msg::CloseSession { session: c.session });
                println!("session closed: {} ({})", c.session, c.name);
            } else {
                println!("session lost: {} ({})", c.session, c.name);
            }
            self.engine.close_session(c.session);
            self.ended += 1;
            self.clients.remove(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::transport::LoopbackTransport;
    use super::super::super::wire::SubmitMsg;
    use super::super::super::worker::spawn_loopback_workers;
    use super::super::super::worker::WorkerConfig;
    use super::*;
    use crate::linalg::{matmul, Matrix};
    use crate::partition::Partitioning;
    use crate::rng::Pcg64;
    use std::sync::Arc;

    fn identity_submit(request: u64, seed: u64) -> (SubmitMsg, Matrix) {
        let mut rng = Pcg64::seed_from(seed);
        let part = Partitioning::rxc(2, 2, 2, 3, 2);
        let a = Matrix::randn(4, 3, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(3, 4, 0.0, 1.0, &mut rng);
        let a_blocks = part.split_a(&a);
        let b_blocks = part.split_b(&b);
        let k = part.num_products();
        let (mut rows, mut wa, mut wb) = (Vec::new(), Vec::new(), Vec::new());
        for u in 0..k {
            let mut row = vec![0.0; k];
            row[u] = 1.0;
            rows.push(row);
            let (ai, bi) = part.factors_of(u);
            wa.push(Arc::new(a_blocks[ai].clone()));
            wb.push(Arc::new(b_blocks[bi].clone()));
        }
        let c_true = matmul(&a, &b);
        let sub = SubmitMsg {
            session: 0,
            request,
            t_max: 10.0,
            paradigm: 0,
            dims: [
                part.n as u32,
                part.p as u32,
                part.m as u32,
                part.u as u32,
                part.h as u32,
                part.q as u32,
            ],
            n_total: k as u32,
            n_classes: 1,
            class_of: vec![0; k],
            rows,
            wa,
            wb,
            delays: vec![0.1; k],
            gram: None,
            energy: f64::NAN,
        };
        (sub, c_true)
    }

    /// End-to-end over loopback: a worker and a client dial the same
    /// front door; the client opens, submits, gets progress and a
    /// result, closes; the plane drains and reports.
    #[test]
    fn front_door_serves_a_session_end_to_end() {
        let (mut transport, dialer) = LoopbackTransport::new();
        let worker_handles = spawn_loopback_workers(
            &dialer,
            2,
            &WorkerConfig::default(),
        );
        let client_dialer = dialer.clone();
        let client = std::thread::spawn(move || {
            let mut conn = client_dialer.dial("tenant-a").unwrap();
            conn.send(&Msg::OpenSession {
                session: 0,
                client: "tenant-a".to_string(),
            })
            .unwrap();
            let session = match conn.recv().unwrap() {
                Msg::OpenSession { session, .. } => session,
                other => panic!("expected ack, got {}", other.name()),
            };
            let (sub, c_true) = identity_submit(1, 5);
            conn.send(&Msg::Submit(sub)).unwrap();
            let mut steps = 0;
            let result = loop {
                match conn.recv().unwrap() {
                    Msg::ProgressFrame(p) => {
                        assert_eq!(p.session, session);
                        steps += 1;
                    }
                    Msg::ClientResult(r) => break r,
                    other => panic!("unexpected {}", other.name()),
                }
            };
            assert_eq!(steps, 4, "one progress frame per absorbed result");
            assert_eq!(result.received, 4);
            assert!(result.c_hat.allclose(&c_true, 1e-9));
            conn.send(&Msg::CloseSession { session }).unwrap();
            match conn.recv().unwrap() {
                Msg::CloseSession { session: s } => assert_eq!(s, session),
                other => panic!("expected close echo, got {}", other.name()),
            }
        });
        let report = ServePlane::new(ServiceConfig {
            decode_shards: 1,
            ..ServiceConfig::default()
        })
        .run(&mut transport, 1);
        client.join().unwrap();
        for h in worker_handles {
            assert!(h.join().unwrap().unwrap().clean_shutdown);
        }
        assert_eq!(
            report,
            ServiceReport { sessions: 1, served: 1, rejected: 0 }
        );
    }

    /// The session table rejects the `max_sessions + 1`-th concurrent
    /// open, and queue depth rejects the `queue_depth + 1`-th in-flight
    /// submit.
    #[test]
    fn admission_control_rejects_at_both_gates() {
        let (mut transport, dialer) = LoopbackTransport::new();
        // no workers yet: request 1 cannot complete early, so the
        // queue-depth check below is race-free
        let client_dialer = dialer.clone();
        let client = std::thread::spawn(move || {
            // gate 1: with max_sessions = 1 the second open is refused
            let mut first = client_dialer.dial("t1").unwrap();
            first
                .send(&Msg::OpenSession { session: 0, client: "t1".into() })
                .unwrap();
            let session = match first.recv().unwrap() {
                Msg::OpenSession { session, .. } => session,
                other => panic!("unexpected {}", other.name()),
            };
            let mut second = client_dialer.dial("t2").unwrap();
            second
                .send(&Msg::OpenSession { session: 0, client: "t2".into() })
                .unwrap();
            match second.recv().unwrap() {
                Msg::Reject { retry_after, reason, .. } => {
                    assert!(retry_after > 0.0);
                    assert!(reason.contains("session table"), "{reason}");
                }
                other => panic!("expected reject, got {}", other.name()),
            }
            // gate 2: queue_depth = 1 — the second un-answered submit
            // is refused, the first still completes
            let (sub1, _) = identity_submit(1, 6);
            let (sub2, _) = identity_submit(2, 7);
            first.send(&Msg::Submit(sub1)).unwrap();
            first.send(&Msg::Submit(sub2)).unwrap();
            // request 1 is parked (no workers), so the plane must
            // answer request 2 with the queue-depth reject first
            match first.recv().unwrap() {
                Msg::Reject { request, reason, .. } => {
                    assert_eq!(request, 2);
                    assert!(reason.contains("queue"), "{reason}");
                }
                other => panic!("expected reject, got {}", other.name()),
            }
            // only now does the fleet get a worker; request 1 completes
            let worker_handles = spawn_loopback_workers(
                &client_dialer,
                1,
                &WorkerConfig::default(),
            );
            let (mut rejected, mut served) = (1, 0);
            loop {
                match first.recv().unwrap() {
                    Msg::ClientResult(r) => {
                        assert_eq!(r.request, 1);
                        served += 1;
                        break;
                    }
                    Msg::ProgressFrame(_) => {}
                    other => panic!("unexpected {}", other.name()),
                }
            }
            assert_eq!((rejected, served), (1, 1));
            first.send(&Msg::CloseSession { session }).unwrap();
            let _ = first.recv();
            worker_handles
        });
        let report = ServePlane::new(ServiceConfig {
            max_sessions: 1,
            queue_depth: 1,
            decode_shards: 1,
            ..ServiceConfig::default()
        })
        .run(&mut transport, 1);
        let worker_handles = client.join().unwrap();
        for h in worker_handles {
            assert!(h.join().unwrap().unwrap().clean_shutdown);
        }
        assert_eq!(report.served, 1);
        assert_eq!(report.rejected, 2);
        assert_eq!(report.sessions, 1);
    }
}
