//! Deficit round robin across client sessions, with per-tenant
//! in-flight quotas.
//!
//! Classic DRR ([Shreedhar & Varghese '96]) schedules packets by byte
//! budget; here the unit is one job dispatch. Each session (tenant) is
//! a flow in a ring. A visit grants the flow `quantum` dispatches of
//! deficit; the flow is served while it has deficit, ready work, and
//! in-flight headroom, then the cursor moves on. Two properties the
//! serve plane leans on:
//!
//! * **Bounded unfairness.** Over any interval where two sessions both
//!   have ready work, their dispatch counts differ by at most one
//!   quantum — a session bursting 100 requests cannot starve one
//!   submitting a single request.
//! * **No banked credit.** A flow found idle at its turn forfeits its
//!   deficit. Otherwise a long-idle tenant would return with a stored
//!   burst allowance and briefly monopolize the fleet.
//!
//! The quota (`max` in-flight jobs per session) is orthogonal to the
//! quantum: the quantum shapes *ordering*, the quota caps *occupancy*.
//!
//! [Shreedhar & Varghese '96]: https://doi.org/10.1109/90.502236

/// One session's scheduling state.
#[derive(Clone, Debug)]
struct Flow {
    session: u64,
    deficit: u32,
    quota: u32,
    inflight: u32,
}

/// Deficit-round-robin job scheduler over client sessions.
#[derive(Debug)]
pub struct DrrScheduler {
    quantum: u32,
    ring: Vec<Flow>,
    cursor: usize,
}

impl DrrScheduler {
    /// `quantum` consecutive dispatches granted per visit (min 1).
    pub fn new(quantum: u32) -> DrrScheduler {
        DrrScheduler { quantum: quantum.max(1), ring: Vec::new(), cursor: 0 }
    }

    /// Register a session with an in-flight job quota (min 1). Joining
    /// is idempotent.
    pub fn add_session(&mut self, session: u64, quota: u32) {
        if self.ring.iter().any(|f| f.session == session) {
            return;
        }
        self.ring.push(Flow { session, deficit: 0, quota: quota.max(1), inflight: 0 });
    }

    /// Drop a session from the ring (its in-flight jobs settle through
    /// the engine regardless).
    pub fn remove_session(&mut self, session: u64) {
        if let Some(pos) = self.ring.iter().position(|f| f.session == session) {
            self.ring.remove(pos);
            if pos < self.cursor {
                self.cursor -= 1;
            }
            if !self.ring.is_empty() {
                self.cursor %= self.ring.len();
            } else {
                self.cursor = 0;
            }
        }
    }

    /// Sessions currently in the ring.
    pub fn sessions(&self) -> usize {
        self.ring.len()
    }

    /// Pick the session for the next job dispatch, given which sessions
    /// currently have ready (undispatched) work. Consumes one deficit
    /// from the winner and counts the job in flight; returns `None`
    /// when no session is both ready and under quota.
    pub fn next(&mut self, ready: impl Fn(u64) -> bool) -> Option<u64> {
        if self.ring.is_empty() {
            return None;
        }
        let n = self.ring.len();
        for _ in 0..n {
            let i = self.cursor;
            let flow = &mut self.ring[i];
            if !ready(flow.session) {
                // idle at its turn: forfeit banked credit, move on
                flow.deficit = 0;
                self.cursor = (self.cursor + 1) % n;
                continue;
            }
            if flow.inflight >= flow.quota {
                // quota-capped: keep the deficit (the flow *wants* to
                // run; it resumes the moment a job settles)
                self.cursor = (self.cursor + 1) % n;
                continue;
            }
            if flow.deficit == 0 {
                flow.deficit = self.quantum;
            }
            flow.deficit -= 1;
            flow.inflight += 1;
            let session = flow.session;
            if flow.deficit == 0 {
                self.cursor = (self.cursor + 1) % n;
            }
            return Some(session);
        }
        None
    }

    /// Heterogeneity credit weighting: after the engine places a
    /// granted job on a lane, it charges the flow the lane's relative
    /// cost *beyond* the one credit [`Self::next`] already consumed —
    /// `extra = ⌈scale⌉ − 1` for a lane `scale`× slower than the fleet
    /// mean. A slow lane holds fleet capacity longer, so occupying it
    /// eats into the tenant's burst allowance instead of being priced
    /// like fast capacity. Saturates at zero (the dispatch itself is
    /// never revoked); fast and mean-speed lanes cost nothing extra.
    /// Exhausting the deficit ends the flow's current visit — the
    /// cursor moves on, so the zero deficit reads as "spent" rather
    /// than "fresh visit, refill me".
    pub fn charge_extra(&mut self, session: u64, extra: u32) {
        let n = self.ring.len();
        if let Some(pos) = self.ring.iter().position(|f| f.session == session) {
            let f = &mut self.ring[pos];
            f.deficit = f.deficit.saturating_sub(extra);
            if extra > 0 && f.deficit == 0 && self.cursor == pos {
                self.cursor = (self.cursor + 1) % n;
            }
        }
    }

    /// One of `session`'s jobs settled (result absorbed, written off,
    /// or the holder died and the retry was re-counted by a fresh
    /// `next`).
    pub fn note_done(&mut self, session: u64) {
        if let Some(f) = self.ring.iter_mut().find(|f| f.session == session) {
            f.inflight = f.inflight.saturating_sub(1);
        }
    }

    /// In-flight jobs currently charged to `session`.
    pub fn inflight(&self, session: u64) -> u32 {
        self.ring
            .iter()
            .find(|f| f.session == session)
            .map(|f| f.inflight)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(s: &mut DrrScheduler, ready: &[u64], n: usize) -> Vec<u64> {
        let set: Vec<u64> = ready.to_vec();
        (0..n).filter_map(|_| s.next(|id| set.contains(&id))).collect()
    }

    #[test]
    fn quantum_shapes_round_robin_bursts() {
        let mut s = DrrScheduler::new(2);
        for id in [1, 2, 3] {
            s.add_session(id, 100);
        }
        // quantum 2 ⇒ two consecutive dispatches per session per visit
        let order = drain(&mut s, &[1, 2, 3], 8);
        assert_eq!(order, vec![1, 1, 2, 2, 3, 3, 1, 1]);
    }

    #[test]
    fn quota_caps_inflight_until_jobs_settle() {
        let mut s = DrrScheduler::new(1);
        s.add_session(1, 2);
        s.add_session(2, 100);
        // session 1 fills its quota of 2, then only session 2 dispatches
        let order = drain(&mut s, &[1, 2], 6);
        assert_eq!(order, vec![1, 2, 1, 2, 2, 2]);
        assert_eq!(s.inflight(1), 2);
        // settling one job reopens session 1's slot
        s.note_done(1);
        let order = drain(&mut s, &[1, 2], 2);
        assert!(order.contains(&1), "{order:?}");
    }

    #[test]
    fn idle_flow_forfeits_its_deficit() {
        let mut s = DrrScheduler::new(3);
        s.add_session(1, 100);
        s.add_session(2, 100);
        // session 1 uses one of its three credits, then goes idle
        assert_eq!(s.next(|id| id == 1 || id == 2), Some(1));
        // with 1 idle the ring passes it (resetting its bank) and serves 2
        let order = drain(&mut s, &[2], 3);
        assert_eq!(order, vec![2, 2, 2]);
        // back with work, session 1 starts from a fresh quantum — not
        // the two banked credits plus a refill
        let order = drain(&mut s, &[1, 2], 6);
        assert_eq!(order, vec![1, 1, 1, 2, 2, 2]);
    }

    #[test]
    fn extra_credit_charge_shortens_the_visit() {
        let mut s = DrrScheduler::new(3);
        s.add_session(1, 100);
        s.add_session(2, 100);
        // session 1's first dispatch lands on a 3× lane: +2 extra
        // credit spends its whole visit, so session 2 runs next even
        // though 1 had two credits banked
        assert_eq!(s.next(|_| true), Some(1));
        s.charge_extra(1, 2);
        let order = drain(&mut s, &[1, 2], 4);
        assert_eq!(order, vec![2, 2, 2, 1]);
        // zero extra (a fast lane) changes nothing
        s.charge_extra(1, 0);
        assert_eq!(s.next(|_| true), Some(1));
    }

    #[test]
    fn removal_keeps_the_ring_consistent() {
        let mut s = DrrScheduler::new(1);
        for id in [1, 2, 3] {
            s.add_session(id, 10);
        }
        assert_eq!(drain(&mut s, &[1, 2, 3], 2), vec![1, 2]);
        s.remove_session(1);
        assert_eq!(s.sessions(), 2);
        assert_eq!(drain(&mut s, &[2, 3], 4), vec![3, 2, 3, 2]);
        // no-one ready ⇒ None, not a spin
        assert_eq!(s.next(|_| false), None);
    }
}
