//! The fleet multiplexer: many sessions' requests interleaved onto one
//! shared set of worker lanes.
//!
//! [`super::super::server::ClusterServer`] serves one request at a time
//! — its dispatch, stall, and deadline logic all assume exclusive
//! ownership of the fleet. The engine keeps the same worker *protocol*
//! (workers are unchanged: `Welcome`, `Job`, `Result`) but replaces the
//! one-request drive loop with a tick-based multiplexer:
//!
//! * **Lanes.** Each registered worker is a lane with its own framed
//!   connection and in-flight table. Job frames go out through
//!   [`Connection::send_vectored`] as `prefix | shared body | trailer`
//!   ([`wire::job_prefix`]), so the encoded `(W_A, W_B)` body — built
//!   once per slot at submit — is never re-serialized or copied for
//!   dispatch or re-dispatch.
//! * **Fair dispatch.** Every free fleet slot is offered to the
//!   [`DrrScheduler`]; the winning session's oldest request dispatches
//!   its next pending slot onto the live lane with the fewest in-flight
//!   jobs (ties to the lowest lane id, keeping selection
//!   deterministic).
//! * **Collect-all settlement.** A request completes when every slot
//!   has a result or is written off (bounded re-dispatch, exactly the
//!   single-stream server's fault model: a dead lane's jobs requeue at
//!   most [`super::ServiceConfig::max_job_retries`] times). Results
//!   then sort by `(delay, slot)` and split into absorbed (`≤ t_max`)
//!   and late — Virtual-mode semantics, so outcomes are bit-identical
//!   across runs, lane timings, and client interleavings.
//! * **Sharded decode.** Settled requests leave the tick loop as
//!   [`DecodeTask`]s; progress and final frames come back through
//!   [`FleetEngine::poll_events`].
//!
//! Result integrity mirrors the single-stream server where the fleet is
//! shared: every arriving payload is Freivalds-verified against a probe
//! stream seeded per request (`verify_seed`, engine request id), a
//! rejected result costs a retry and a `verify_failures` count, and a
//! checksum-damaged frame requeues the sending lane's oldest in-flight
//! slot (the frames of a FIFO worker arrive in dispatch order, so the
//! oldest entry is the damaged one). Lane quarantine is out of scope
//! here — the plane process owns fleet membership policy.

use std::collections::{BTreeSet, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coding::{EncodeStyle, UnknownSpace};
use crate::coordinator::Verifier;
use crate::linalg::Matrix;
use crate::partition::{Paradigm, Partitioning};
use crate::rng::Pcg64;

use super::super::transport::Connection;
use super::super::wire::{self, Msg, ResultMsg, SubmitMsg, WireError};
use super::decode::{DecodeEvent, DecodePool, DecodeTask, RequestCounters};
use super::scheduler::DrrScheduler;
use super::ServiceConfig;

/// Per-lane receive budget per tick; also the dispatch poll cadence.
/// Short enough that a tick visits every lane and client promptly, long
/// enough that an idle plane does not spin.
pub(crate) const POLL_SLICE: Duration = Duration::from_millis(1);

/// Smoothing of the per-lane result-delay EWMA behind
/// [`ServiceConfig::hetero_lanes`] (same factor as the cluster
/// coordinator's per-worker straggle score).
const LANE_EWMA_ALPHA: f64 = 0.2;

/// One registered worker.
struct Lane {
    id: u64,
    name: String,
    conn: Box<dyn Connection>,
    alive: bool,
    /// EWMA of reported result delays (virtual units); `None` until the
    /// first result. Feeds [`ServiceConfig::hetero_lanes`] weighting.
    delay_ewma: Option<f64>,
    /// Outstanding job frames: `(engine rid, slot, attempt)`.
    inflight: Vec<(u64, u32, u32)>,
    jobs_done: u64,
}

/// One admitted request being served.
struct Active {
    session: u64,
    /// Client-chosen request id, echoed in every frame back.
    request: u64,
    /// Engine-wide wire request id (`JobMsg::request_id`).
    rid: u64,
    part: Partitioning,
    n_classes: usize,
    class_of: Vec<usize>,
    n_total: usize,
    rows: Vec<Vec<f64>>,
    t_max: f64,
    gram: Option<Matrix>,
    energy: f64,
    /// Pre-encoded split job body per slot (shared across re-dispatch).
    bodies: Vec<Arc<Vec<u8>>>,
    /// Injected per-slot delays; empty = workers time themselves.
    delays: Vec<f64>,
    /// Slots awaiting (re-)dispatch.
    pending: VecDeque<u32>,
    attempts: Vec<u32>,
    /// Slot resolved: result landed or written off.
    settled: Vec<bool>,
    results: Vec<Option<(f64, u32, Matrix)>>,
    written_off: usize,
    /// Dispatched frames on live lanes, not yet resolved.
    outstanding: usize,
    counters: RequestCounters,
    verifier: Option<Verifier>,
    start: Instant,
}

impl Active {
    fn slots(&self) -> usize {
        self.rows.len()
    }

    fn complete(&self) -> bool {
        self.pending.is_empty() && self.outstanding == 0
    }
}

/// The multiplexed fleet engine. Single-threaded: the owning reactor
/// calls [`FleetEngine::tick`]; only the decode shards run elsewhere.
pub struct FleetEngine {
    cfg: ServiceConfig,
    lanes: Vec<Lane>,
    /// Rotating start index for lane polling — the same latency-fairness
    /// rotation as `ClusterServer::poll_order`.
    rotor: usize,
    active: Vec<Active>,
    sched: DrrScheduler,
    // BTreeSet: iteration-order determinism per no-unordered-iteration
    open: BTreeSet<u64>,
    pool: DecodePool,
    next_lane_id: u64,
    next_rid: u64,
}

impl FleetEngine {
    pub fn new(cfg: ServiceConfig) -> FleetEngine {
        let pool = DecodePool::new(cfg.decode_shards);
        let sched = DrrScheduler::new(cfg.quantum);
        FleetEngine {
            cfg,
            lanes: Vec::new(),
            rotor: 0,
            active: Vec::new(),
            sched,
            open: BTreeSet::new(),
            pool,
            next_lane_id: 0,
            next_rid: 0,
        }
    }

    /// Register a worker whose `Hello` the caller already consumed;
    /// sends the `Welcome`. Returns the lane id, or `None` when the
    /// welcome could not be delivered.
    pub fn add_worker(
        &mut self,
        mut conn: Box<dyn Connection>,
        agent: String,
    ) -> Option<u64> {
        let id = self.next_lane_id;
        if conn.send(&Msg::Welcome { worker_id: id }).is_err() {
            return None;
        }
        self.next_lane_id += 1;
        self.lanes.push(Lane {
            id,
            name: agent,
            conn,
            alive: true,
            delay_ewma: None,
            inflight: Vec::new(),
            jobs_done: 0,
        });
        Some(id)
    }

    pub fn live_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.alive).count()
    }

    /// Admit a session into the scheduler ring.
    pub fn open_session(&mut self, session: u64) {
        self.open.insert(session);
        self.sched.add_session(session, self.cfg.tenant_quota);
    }

    /// Retire a session (its in-flight requests still settle and
    /// decode; the plane decides whether anyone is listening).
    pub fn close_session(&mut self, session: u64) {
        self.open.remove(&session);
        self.sched.remove_session(session);
    }

    /// Requests currently being served (not yet handed to decode).
    pub fn active_requests(&self) -> usize {
        self.active.len()
    }

    /// Per-lane `(name, jobs_done, alive)` — the shutdown log line.
    pub fn lane_summary(&self) -> Vec<(String, u64, bool)> {
        self.lanes
            .iter()
            .map(|l| (l.name.clone(), l.jobs_done, l.alive))
            .collect()
    }

    /// Validate and admit one submitted request.
    pub fn add_request(&mut self, sub: SubmitMsg) -> Result<(), String> {
        if !self.open.contains(&sub.session) {
            return Err(format!("session {} is not open", sub.session));
        }
        let n = sub.rows.len();
        if n == 0 {
            return Err("request with no job slots".to_string());
        }
        if sub.wa.len() != n || sub.wb.len() != n {
            return Err(format!(
                "{} coefficient rows but {}/{} factor pairs",
                n,
                sub.wa.len(),
                sub.wb.len()
            ));
        }
        if !sub.delays.is_empty() && sub.delays.len() != n {
            return Err(format!("{} delays for {n} jobs", sub.delays.len()));
        }
        if !sub.t_max.is_finite() || sub.t_max < 0.0 {
            return Err(format!("T_max {} is not a valid deadline", sub.t_max));
        }
        let paradigm = match sub.paradigm {
            0 => Paradigm::RowTimesCol,
            1 => Paradigm::ColTimesRow,
            other => return Err(format!("unknown paradigm tag {other}")),
        };
        let [pn, pp, pm, pu, ph, pq] = sub.dims;
        let part = Partitioning {
            paradigm,
            n: pn as usize,
            p: pp as usize,
            m: pm as usize,
            u: pu as usize,
            h: ph as usize,
            q: pq as usize,
        };
        let n_real = part.num_products();
        let n_total = sub.n_total as usize;
        let style = if n_total > n_real {
            EncodeStyle::RankOne
        } else {
            EncodeStyle::Stacked
        };
        if UnknownSpace::for_code(&part, style).n_total != n_total {
            return Err(format!(
                "{n_total} unknowns do not fit the submitted partitioning"
            ));
        }
        if sub.rows.iter().any(|r| r.len() != n_total) {
            return Err("coefficient row width mismatch".to_string());
        }
        if sub.class_of.len() != n_real {
            return Err(format!(
                "{} class entries for {n_real} sub-products",
                sub.class_of.len()
            ));
        }
        let n_classes = (sub.n_classes as usize).max(1);
        if sub.class_of.iter().any(|&c| c as usize >= n_classes) {
            return Err("class index out of range".to_string());
        }
        // encode each slot's job body once; dispatch and re-dispatch
        // share these buffers through the vectored send path
        let mut bodies = Vec::with_capacity(n);
        for (wa, wb) in sub.wa.iter().zip(&sub.wb) {
            bodies.push(Arc::new(
                wire::job_body(wa, wb).map_err(|e| format!("encode job: {e}"))?,
            ));
        }
        let rid = self.next_rid;
        self.next_rid += 1;
        let verifier = if self.cfg.verify {
            let jobs: Vec<(Arc<Matrix>, Arc<Matrix>)> =
                sub.wa.iter().cloned().zip(sub.wb.iter().cloned()).collect();
            let mut vrng = Pcg64::with_stream(self.cfg.verify_seed, rid);
            Some(Verifier::new(&jobs, &mut vrng))
        } else {
            None
        };
        self.active.push(Active {
            session: sub.session,
            request: sub.request,
            rid,
            part,
            n_classes,
            class_of: sub.class_of.iter().map(|&c| c as usize).collect(),
            n_total,
            rows: sub.rows,
            t_max: sub.t_max,
            gram: sub.gram,
            energy: sub.energy,
            bodies,
            delays: sub.delays,
            pending: (0..n as u32).collect(),
            attempts: vec![0; n],
            settled: vec![false; n],
            results: (0..n).map(|_| None).collect(),
            written_off: 0,
            outstanding: 0,
            counters: RequestCounters::default(),
            verifier,
            start: Instant::now(), // lint:allow(no-wallclock-in-deterministic-paths) wall_ms telemetry only; decode order never reads it
        });
        Ok(())
    }

    /// One reactor turn: absorb lane traffic, dispatch freed capacity,
    /// hand settled requests to the decode shards.
    pub fn tick(&mut self) {
        self.poll_lanes();
        self.dispatch();
        self.complete();
    }

    /// Decode-shard events emitted since the last call.
    pub fn poll_events(&mut self) -> Vec<DecodeEvent> {
        self.pool.poll()
    }

    /// Orderly teardown: shut the lanes down, drain the decode pool.
    pub fn shutdown(mut self) {
        for lane in &mut self.lanes {
            if lane.alive {
                let _ = lane.conn.send(&Msg::Shutdown);
            }
        }
        self.pool.shutdown();
    }

    /// Drain every lane, starting from a rotating index so the same
    /// early lane does not win the poll-order race every tick.
    fn poll_lanes(&mut self) {
        let n = self.lanes.len();
        if n == 0 {
            return;
        }
        let start = self.rotor % n;
        self.rotor = self.rotor.wrapping_add(1);
        for off in 0..n {
            let li = (start + off) % n;
            if !self.lanes[li].alive {
                continue;
            }
            loop {
                match self.lanes[li].conn.recv_timeout(Some(POLL_SLICE)) {
                    Ok(Some(Msg::Result(r))) => {
                        let lane = &mut self.lanes[li];
                        let Some(pos) = lane
                            .inflight
                            .iter()
                            .position(|&(rid, slot, _)| {
                                rid == r.request_id && slot == r.slot
                            })
                        else {
                            // a result for work this lane does not hold:
                            // a stale duplicate or a confused worker —
                            // nothing to resolve
                            continue;
                        };
                        lane.inflight.remove(pos);
                        lane.jobs_done += 1;
                        if r.delay.is_finite() && r.delay >= 0.0 {
                            lane.delay_ewma = Some(match lane.delay_ewma {
                                None => r.delay,
                                Some(e) => {
                                    LANE_EWMA_ALPHA * r.delay
                                        + (1.0 - LANE_EWMA_ALPHA) * e
                                }
                            });
                        }
                        absorb_result(
                            &mut self.active,
                            &mut self.sched,
                            self.cfg.max_job_retries,
                            r,
                        );
                    }
                    Ok(Some(Msg::HeartbeatAck { .. })) => {}
                    Ok(Some(_)) => {
                        // protocol violation: this lane speaks the worker
                        // plane only
                        kill_lane(
                            &mut self.lanes[li],
                            &mut self.active,
                            &mut self.sched,
                            self.cfg.max_job_retries,
                        );
                        break;
                    }
                    Ok(None) => break,
                    Err(WireError::BadChecksum { .. }) => {
                        // channel fault, not lane fault: requeue the
                        // oldest in-flight slot (FIFO workers answer in
                        // dispatch order) and keep the lane
                        if let Some((rid, slot, _)) =
                            self.lanes[li].inflight.first().copied()
                        {
                            self.lanes[li].inflight.remove(0);
                            requeue_slot(
                                &mut self.active,
                                &mut self.sched,
                                self.cfg.max_job_retries,
                                rid,
                                slot,
                                true,
                            );
                        }
                    }
                    Err(_) => {
                        kill_lane(
                            &mut self.lanes[li],
                            &mut self.active,
                            &mut self.sched,
                            self.cfg.max_job_retries,
                        );
                        break;
                    }
                }
            }
        }
    }

    /// The [`ServiceConfig::hetero_lanes`] scale map: `(lane index,
    /// scale)` over the live lanes, each lane's result-delay EWMA
    /// normalized by the live mean (no history yet ⇒ 1.0 = mean).
    /// `None` when the feature is off or no lane has history — the
    /// dispatch then uses plain occupancy order.
    fn lane_scales(&self) -> Option<Vec<(usize, f64)>> {
        if !self.cfg.hetero_lanes {
            return None;
        }
        let live: Vec<(usize, Option<f64>)> = self
            .lanes
            .iter()
            .enumerate()
            .filter(|(_, l)| l.alive)
            .map(|(i, l)| {
                (i, l.delay_ewma.filter(|d| d.is_finite() && *d > 0.0))
            })
            .collect();
        let known: Vec<f64> = live.iter().filter_map(|&(_, d)| d).collect();
        if known.is_empty() {
            return None;
        }
        let mean = known.iter().sum::<f64>() / known.len() as f64;
        if !(mean > 0.0) {
            return None;
        }
        Some(
            live.into_iter()
                .map(|(i, d)| (i, d.map_or(1.0, |d| d / mean)))
                .collect(),
        )
    }

    /// Offer freed fleet capacity to the scheduler, one job per offer.
    fn dispatch(&mut self) {
        loop {
            let inflight_total: usize =
                self.lanes.iter().map(|l| l.inflight.len()).sum();
            if inflight_total >= self.cfg.max_inflight_jobs {
                return;
            }
            if !self.lanes.iter().any(|l| l.alive) {
                return;
            }
            let ready: BTreeSet<u64> = self
                .active
                .iter()
                .filter(|a| !a.pending.is_empty())
                .map(|a| a.session)
                .collect();
            let Some(session) = self.sched.next(|s| ready.contains(&s)) else {
                return;
            };
            // oldest request of the winning session (FIFO per tenant).
            // `ready` was derived from the same `active` list the
            // scheduler filtered on, so these lookups succeed; if that
            // invariant ever breaks, return the scheduler credit and
            // stop offering instead of panicking the serve loop.
            let Some(ai) = self
                .active
                .iter()
                .enumerate()
                .filter(|(_, a)| a.session == session && !a.pending.is_empty())
                .min_by_key(|(_, a)| a.rid)
                .map(|(i, _)| i)
            else {
                self.sched.note_done(session);
                return;
            };
            let Some(slot) = self.active[ai].pending.pop_front() else {
                self.sched.note_done(session);
                return;
            };
            let attempt = self.active[ai].attempts[slot as usize];
            // lane pick: least-outstanding (ties to the lowest id), or
            // under `hetero_lanes` the lane minimizing
            // `(inflight + 1) · scale` — identical until the per-lane
            // delay EWMAs diverge. The fleet was non-empty above, but
            // re-check rather than panic.
            let picked = match self.lane_scales() {
                Some(scales) => scales
                    .iter()
                    .min_by(|a, b| {
                        let ka = (self.lanes[a.0].inflight.len() as f64 + 1.0)
                            * a.1;
                        let kb = (self.lanes[b.0].inflight.len() as f64 + 1.0)
                            * b.1;
                        ka.total_cmp(&kb)
                            .then(self.lanes[a.0].id.cmp(&self.lanes[b.0].id))
                    })
                    .map(|&(i, scale)| (i, scale)),
                None => self
                    .lanes
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| l.alive)
                    .min_by_key(|(_, l)| (l.inflight.len(), l.id))
                    .map(|(i, _)| (i, 1.0)),
            };
            let Some((li, lane_scale)) = picked else {
                self.active[ai].pending.push_front(slot);
                self.sched.note_done(session);
                return;
            };
            let prep = {
                let act = &self.active[ai];
                let body = Arc::clone(&act.bodies[slot as usize]);
                let injected = (!act.delays.is_empty())
                    .then(|| act.delays[slot as usize]);
                wire::job_prefix(act.rid, slot, attempt, injected, 0.0, body.len())
                    .ok()
                    .map(|prefix| {
                        let trailer = wire::job_trailer(&prefix, &body);
                        (act.rid, prefix, body, trailer)
                    })
            };
            let Some((rid, prefix, body, trailer)) = prep else {
                // an unencodable frame (oversized payload) is a
                // permanent failure of this slot, not of the lane: it
                // was never dispatched, so only the scheduler credit
                // needs returning
                self.sched.note_done(session);
                let act = &mut self.active[ai];
                act.settled[slot as usize] = true;
                act.written_off += 1;
                continue;
            };
            let sent = self.lanes[li]
                .conn
                .send_vectored(&[&prefix, &body, &trailer])
                .is_ok();
            if sent {
                self.lanes[li].inflight.push((rid, slot, attempt));
                let act = &mut self.active[ai];
                act.outstanding += 1;
                act.counters.dispatched += 1;
                // hetero credit weighting: a job parked on a
                // slower-than-mean lane holds fleet capacity longer, so
                // it costs the tenant extra DRR credit (⌈scale⌉ − 1)
                if lane_scale > 1.0 {
                    let extra = (lane_scale.ceil() as u32).saturating_sub(1);
                    self.sched.charge_extra(session, extra);
                }
            } else {
                // the lane died taking this frame: put the slot back at
                // the front (no retry charged — it never left), release
                // the scheduler credit, bury the lane
                self.active[ai].pending.push_front(slot);
                self.sched.note_done(session);
                kill_lane(
                    &mut self.lanes[li],
                    &mut self.active,
                    &mut self.sched,
                    self.cfg.max_job_retries,
                );
            }
        }
    }

    /// Move settled requests to the decode shards.
    fn complete(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            if !self.active[i].complete() {
                i += 1;
                continue;
            }
            let mut act = self.active.remove(i);
            let mut absorbed: Vec<(u32, f64, u32, Matrix)> = Vec::new();
            let mut late = 0u32;
            for slot in 0..act.slots() {
                if let Some((delay, attempt, payload)) = act.results[slot].take() {
                    if delay <= act.t_max {
                        absorbed.push((slot as u32, delay, attempt, payload));
                    } else {
                        late += 1;
                    }
                }
            }
            // the shared absorb order of every virtual-time path
            absorbed.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
            act.counters.late = late;
            act.counters.wall_ms = act.start.elapsed().as_millis() as u64;
            self.pool.submit(DecodeTask {
                session: act.session,
                request: act.request,
                shard_key: act.rid,
                part: act.part,
                n_classes: act.n_classes,
                class_of: act.class_of,
                n_total: act.n_total,
                rows: act.rows,
                absorbed,
                gram: act.gram,
                energy: act.energy,
                counters: act.counters,
            });
        }
    }
}

/// Resolve one arriving result against its request: verify, then settle
/// or requeue.
fn absorb_result(
    active: &mut [Active],
    sched: &mut DrrScheduler,
    max_retries: u32,
    r: ResultMsg,
) {
    let Some(act) = active.iter_mut().find(|a| a.rid == r.request_id) else {
        return; // stale: the request already settled and decoded
    };
    let slot = r.slot as usize;
    if slot >= act.slots() {
        return;
    }
    act.outstanding = act.outstanding.saturating_sub(1);
    sched.note_done(act.session);
    if act.settled[slot] {
        return; // duplicate of a re-dispatched slot: absorbed once
    }
    if let Some(v) = &act.verifier {
        if !v.check(slot, &r.payload) {
            act.counters.verify_failures += 1;
            retry_or_write_off(act, slot as u32, max_retries);
            return;
        }
    }
    act.settled[slot] = true;
    act.results[slot] = Some((r.delay, r.attempt, r.payload));
}

/// Charge a failed attempt against a slot's retry budget.
fn retry_or_write_off(act: &mut Active, slot: u32, max_retries: u32) {
    let s = slot as usize;
    act.attempts[s] += 1;
    if act.attempts[s] > max_retries {
        act.settled[s] = true; // resolved with no result
        act.written_off += 1;
    } else {
        act.counters.retries += 1;
        act.pending.push_back(slot);
    }
}

/// Requeue one in-flight slot after a channel fault or send failure.
fn requeue_slot(
    active: &mut [Active],
    sched: &mut DrrScheduler,
    max_retries: u32,
    rid: u64,
    slot: u32,
    corrupt: bool,
) {
    let Some(act) = active.iter_mut().find(|a| a.rid == rid) else {
        return;
    };
    act.outstanding = act.outstanding.saturating_sub(1);
    sched.note_done(act.session);
    if act.settled[slot as usize] {
        return;
    }
    if corrupt {
        act.counters.corrupt += 1;
    }
    retry_or_write_off(act, slot, max_retries);
}

/// A lane died: bury it and requeue everything it held.
fn kill_lane(
    lane: &mut Lane,
    active: &mut [Active],
    sched: &mut DrrScheduler,
    max_retries: u32,
) {
    lane.alive = false;
    let held = std::mem::take(&mut lane.inflight);
    for (rid, slot, _) in held {
        requeue_slot(active, sched, max_retries, rid, slot, false);
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::transport::loopback_pair;
    use super::super::super::worker::{run_worker, WorkerConfig};
    use super::*;
    use crate::linalg::matmul;
    use crate::runtime::NativeEngine;
    use std::thread::JoinHandle;

    fn spawn_fleet(
        engine: &mut FleetEngine,
        n: usize,
    ) -> Vec<JoinHandle<anyhow::Result<super::super::super::worker::WorkerStats>>>
    {
        (0..n)
            .map(|i| {
                let name = format!("w{i}");
                let (coord, mut wk) = loopback_pair("engine", &name);
                let cfg = WorkerConfig { name: name.clone(), ..Default::default() };
                let handle = std::thread::spawn(move || {
                    run_worker(&mut wk, &NativeEngine::serial(), &cfg)
                });
                let mut conn: Box<dyn Connection> = Box::new(coord);
                // consume the Hello the worker leads with, as the plane
                // front door does
                match conn.recv().unwrap() {
                    Msg::Hello { agent } => assert_eq!(agent, name),
                    other => panic!("unexpected {other:?}"),
                }
                engine.add_worker(conn, name).unwrap();
                handle
            })
            .collect()
    }

    /// Identity-code submit: slot `u` carries unknown `u` with the raw
    /// block pair as its job.
    fn identity_submit(
        session: u64,
        request: u64,
        t_max: f64,
        delays: Vec<f64>,
        seed: u64,
    ) -> (SubmitMsg, Matrix) {
        let mut rng = Pcg64::seed_from(seed);
        let part = Partitioning::rxc(2, 2, 2, 3, 2);
        let a = Matrix::randn(4, 3, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(3, 4, 0.0, 1.0, &mut rng);
        let a_blocks = part.split_a(&a);
        let b_blocks = part.split_b(&b);
        let k = part.num_products();
        let mut rows = Vec::new();
        let mut wa = Vec::new();
        let mut wb = Vec::new();
        for u in 0..k {
            let mut row = vec![0.0; k];
            row[u] = 1.0;
            rows.push(row);
            let (ai, bi) = part.factors_of(u);
            wa.push(Arc::new(a_blocks[ai].clone()));
            wb.push(Arc::new(b_blocks[bi].clone()));
        }
        let c_true = matmul(&a, &b);
        let sub = SubmitMsg {
            session,
            request,
            t_max,
            paradigm: 0,
            dims: [
                part.n as u32,
                part.p as u32,
                part.m as u32,
                part.u as u32,
                part.h as u32,
                part.q as u32,
            ],
            n_total: k as u32,
            n_classes: 1,
            class_of: vec![0; k],
            rows,
            wa,
            wb,
            delays,
            gram: None,
            energy: f64::NAN,
        };
        (sub, c_true)
    }

    fn drive_until_done(
        engine: &mut FleetEngine,
        want_done: usize,
    ) -> Vec<DecodeEvent> {
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut events = Vec::new();
        let mut done = 0;
        while done < want_done {
            assert!(Instant::now() < deadline, "engine stalled");
            engine.tick();
            for ev in engine.poll_events() {
                if matches!(ev, DecodeEvent::Done { .. }) {
                    done += 1;
                }
                events.push(ev);
            }
        }
        events
    }

    #[test]
    fn multiplexed_requests_settle_with_injected_deadline_accounting() {
        let mut engine = FleetEngine::new(ServiceConfig {
            decode_shards: 1,
            ..ServiceConfig::default()
        });
        let handles = spawn_fleet(&mut engine, 2);
        engine.open_session(7);
        // slot 3 misses the deadline: absorbed set is slots {0, 1, 2}
        let (sub, c_true) =
            identity_submit(7, 1, 1.0, vec![0.2, 0.4, 0.6, 5.0], 11);
        engine.add_request(sub).unwrap();
        let events = drive_until_done(&mut engine, 1);
        match events.last().unwrap() {
            DecodeEvent::Done { session, request, result, full_recovery } => {
                assert_eq!((*session, *request), (7, 1));
                assert!(!full_recovery, "the late slot must be missing");
                assert_eq!(result.received, 3);
                assert_eq!(result.recovered, 3);
                assert_eq!(result.late, 1);
                assert_eq!(result.dispatched, 4);
                assert_eq!(result.verify_failures, 0);
                assert!(!result.c_hat.allclose(&c_true, 1e-9));
            }
            other => panic!("unexpected {other:?}"),
        }
        engine.close_session(7);
        engine.shutdown();
        for h in handles {
            assert!(h.join().unwrap().unwrap().clean_shutdown);
        }
    }

    #[test]
    fn two_sessions_share_the_fleet_and_both_fully_recover() {
        let mut engine = FleetEngine::new(ServiceConfig {
            decode_shards: 2,
            quantum: 1,
            ..ServiceConfig::default()
        });
        let handles = spawn_fleet(&mut engine, 3);
        engine.open_session(1);
        engine.open_session(2);
        let (sub1, c1) = identity_submit(1, 10, 10.0, vec![0.1; 4], 21);
        let (sub2, c2) = identity_submit(2, 20, 10.0, vec![0.1; 4], 22);
        engine.add_request(sub1).unwrap();
        engine.add_request(sub2).unwrap();
        let events = drive_until_done(&mut engine, 2);
        let mut seen = 0;
        for ev in &events {
            if let DecodeEvent::Done { session, result, full_recovery, .. } = ev {
                assert!(*full_recovery, "session {session}");
                let want = if *session == 1 { &c1 } else { &c2 };
                assert!(result.c_hat.allclose(want, 1e-9));
                seen += 1;
            }
        }
        assert_eq!(seen, 2);
        engine.shutdown();
        for h in handles {
            assert!(h.join().unwrap().unwrap().clean_shutdown);
        }
    }

    #[test]
    fn add_request_validates_before_admitting() {
        let mut engine = FleetEngine::new(ServiceConfig::default());
        let (sub, _) = identity_submit(9, 1, 1.0, vec![], 3);
        // unknown session
        assert!(engine.add_request(sub.clone()).is_err());
        engine.open_session(9);
        assert!(engine.add_request(sub.clone()).is_ok());
        // delay count mismatch
        let mut bad = sub.clone();
        bad.delays = vec![0.5];
        assert!(engine.add_request(bad).unwrap_err().contains("delays"));
        // row width mismatch
        let mut bad = sub.clone();
        bad.rows[0].push(1.0);
        assert!(engine.add_request(bad).is_err());
        // class table mismatch
        let mut bad = sub;
        bad.class_of.pop();
        assert!(engine.add_request(bad).is_err());
        engine.shutdown();
    }
}
