//! The sharded decode pool: settled requests decode off the reactor
//! thread.
//!
//! Decoding a request is the one serve-plane step whose cost scales
//! with problem size (Gaussian elimination over the coefficient rows
//! plus payload back-substitution). Running it inline in the reactor
//! would stall dispatch, admission, and every other tenant's progress
//! frames behind one large decode. Instead the engine hands each fully
//! settled request to a small thread pool:
//!
//! * **One shard per request.** A request's task goes to shard
//!   `shard_key % shards` and is decoded start-to-finish on that one
//!   thread, so its progress events are emitted in absorption order —
//!   per-request streams stay ordered even though shards run
//!   concurrently.
//! * **Deterministic outcomes.** The task carries the absorbed results
//!   already sorted by `(delay, slot)`; the decode is a pure function
//!   of the task, so which shard runs it (and when) cannot change any
//!   outcome — only the interleaving of *different* requests' events,
//!   which no client observes.
//!
//! Loss scoring runs plane-side from the Gram matrix the client shipped
//! (`C_true` never crosses the wire), exactly like the API-level
//! `ProgressTracker`: running loss starts at the total energy, each
//! newly recovered unknown subtracts its
//! [`Partitioning::loss_delta_on_recover`] increment, and full recovery
//! pins the loss to exactly zero.

use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::coding::{DecodeState, EncodeStyle, UnknownSpace};
use crate::coordinator::assemble_outcome;
use crate::linalg::Matrix;
use crate::partition::{ClassMap, Partitioning};

use super::super::wire::{ClientResultMsg, ProgressMsg};

/// Per-request accounting the engine gathered while the request was in
/// flight; echoed through the pool into the final [`ClientResultMsg`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RequestCounters {
    pub late: u32,
    pub dispatched: u32,
    pub retries: u32,
    pub corrupt: u32,
    pub verify_failures: u32,
    pub wall_ms: u64,
}

/// One fully settled request, ready to decode.
#[derive(Clone, Debug)]
pub struct DecodeTask {
    pub session: u64,
    pub request: u64,
    /// Shard selector (the engine's internal request id).
    pub shard_key: u64,
    pub part: Partitioning,
    pub n_classes: usize,
    pub class_of: Vec<usize>,
    /// Unknown-space width every coefficient row spans.
    pub n_total: usize,
    /// Coefficient row per slot.
    pub rows: Vec<Vec<f64>>,
    /// In-deadline results, sorted by `(delay, slot)`:
    /// `(slot, delay, attempt, payload)`.
    pub absorbed: Vec<(u32, f64, u32, Matrix)>,
    /// Gram matrix of the true sub-products (scored requests only).
    pub gram: Option<Matrix>,
    /// Total signal energy normalizing the loss.
    pub energy: f64,
    pub counters: RequestCounters,
}

/// What a shard emits back to the reactor.
#[derive(Clone, Debug)]
pub enum DecodeEvent {
    /// One decode refinement, in absorption order.
    Step { session: u64, request: u64, msg: ProgressMsg },
    /// The request's final report.
    Done {
        session: u64,
        request: u64,
        result: ClientResultMsg,
        /// Every real sub-product recovered.
        full_recovery: bool,
    },
}

/// Decode a settled request: the pure function each shard runs.
fn run_task(task: DecodeTask) -> (Vec<ProgressMsg>, ClientResultMsg, bool) {
    let DecodeTask {
        session,
        request,
        part,
        n_classes,
        class_of,
        n_total,
        rows,
        absorbed,
        gram,
        energy,
        counters,
        ..
    } = task;
    // the unknown space rebuilds from the partitioning; a row set wider
    // than the real product count means the rank-one (ghost-unknown)
    // encoding of the c×r paradigm
    let style = if n_total > part.num_products() {
        EncodeStyle::RankOne
    } else {
        EncodeStyle::Stacked
    };
    let space = UnknownSpace::for_code(&part, style);
    let mut st = DecodeState::new(space);
    let n_real = part.num_products();
    let mut mask = vec![false; n_real];
    let mut loss = if gram.is_some() { energy } else { f64::NAN };
    let mut steps = Vec::with_capacity(absorbed.len());
    let mut received = 0u32;
    for (slot, delay, attempt, payload) in absorbed {
        let newly = st.add_equation(rows[slot as usize].clone(), Some(payload));
        received += 1;
        if let Some(g) = &gram {
            for &u in &newly {
                mask[u] = true;
                loss -= part.loss_delta_on_recover(g, &mask, u);
            }
            if st.num_recovered() == n_real {
                // pin the fully-decoded endpoint to exactly zero,
                // shedding running-sum rounding (as ProgressTracker does)
                loss = 0.0;
            }
        }
        let normalized = if energy > 0.0 { loss / energy } else { loss };
        steps.push(ProgressMsg {
            session,
            request,
            elapsed: delay,
            received,
            recovered: st.num_recovered() as u32,
            newly: newly.len() as u32,
            attempt,
            loss,
            normalized_loss: normalized,
        });
    }
    // a literal ClassMap: only n_classes/class_of/members feed the
    // assembly; factor levels stayed client-side
    let mut members = vec![Vec::new(); n_classes];
    for (u, &c) in class_of.iter().enumerate() {
        members[c].push(u);
    }
    let cm = ClassMap {
        n_classes,
        class_of,
        members,
        a_level: Vec::new(),
        b_level: Vec::new(),
        s_levels: 0,
    };
    let outcome = assemble_outcome(&part, &cm, &st, received as usize);
    let normalized = if energy > 0.0 { loss / energy } else { loss };
    let full = outcome.recovered == n_real;
    let result = ClientResultMsg {
        session,
        request,
        received,
        recovered: outcome.recovered as u32,
        per_class: outcome.per_class_recovered.iter().map(|&c| c as u32).collect(),
        c_hat: outcome.c_hat,
        loss,
        normalized_loss: normalized,
        late: counters.late,
        dispatched: counters.dispatched,
        retries: counters.retries,
        corrupt: counters.corrupt,
        verify_failures: counters.verify_failures,
        wall_ms: counters.wall_ms,
    };
    (steps, result, full)
}

/// The shard pool: `shards` decode threads plus one shared event
/// channel back to the reactor.
pub struct DecodePool {
    txs: Vec<mpsc::Sender<DecodeTask>>,
    rx: mpsc::Receiver<DecodeEvent>,
    handles: Vec<JoinHandle<()>>,
}

impl DecodePool {
    /// Spawn `shards` decode threads (min 1).
    pub fn new(shards: usize) -> DecodePool {
        let shards = shards.max(1);
        let (ev_tx, ev_rx) = mpsc::channel::<DecodeEvent>();
        let mut txs = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for i in 0..shards {
            let (tx, rx) = mpsc::channel::<DecodeTask>();
            let ev = ev_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("uepmm-decode-{i}"))
                .spawn(move || {
                    while let Ok(task) = rx.recv() {
                        let (session, request) = (task.session, task.request);
                        let (steps, result, full_recovery) = run_task(task);
                        for msg in steps {
                            // a send failure means the reactor is gone;
                            // finish quietly
                            if ev
                                .send(DecodeEvent::Step { session, request, msg })
                                .is_err()
                            {
                                return;
                            }
                        }
                        if ev
                            .send(DecodeEvent::Done {
                                session,
                                request,
                                result,
                                full_recovery,
                            })
                            .is_err()
                        {
                            return;
                        }
                    }
                })
                .expect("spawn decode shard"); // lint:allow(no-panic-in-server-loops) one-time startup spawn; thread exhaustion here is fatal by design
            txs.push(tx);
            handles.push(handle);
        }
        DecodePool { txs, rx: ev_rx, handles }
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Hand a settled request to its shard.
    pub fn submit(&self, task: DecodeTask) {
        let shard = (task.shard_key as usize) % self.txs.len();
        // a dead shard thread is unrecoverable mid-run; the reactor
        // surfaces the stall through its own accounting
        let _ = self.txs[shard].send(task);
    }

    /// Drain every event the shards have emitted so far (nonblocking).
    pub fn poll(&mut self) -> Vec<DecodeEvent> {
        let mut out = Vec::new();
        while let Ok(ev) = self.rx.try_recv() {
            out.push(ev);
        }
        out
    }

    /// Close the task channels and join the shard threads.
    pub fn shutdown(mut self) {
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::rng::Pcg64;
    use std::time::{Duration, Instant};

    /// An identity "code": slot `u` carries exactly unknown `u`, with
    /// the raw block pair as its job — every absorbed slot recovers
    /// exactly one sub-product.
    fn identity_task(session: u64, request: u64, scored: bool) -> (DecodeTask, Matrix) {
        let mut rng = Pcg64::seed_from(5);
        let part = Partitioning::rxc(2, 2, 2, 3, 2);
        let a = Matrix::randn(4, 3, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(3, 4, 0.0, 1.0, &mut rng);
        let a_blocks = part.split_a(&a);
        let b_blocks = part.split_b(&b);
        let k = part.num_products();
        let mut rows = Vec::new();
        let mut absorbed = Vec::new();
        for u in 0..k {
            let mut row = vec![0.0; k];
            row[u] = 1.0;
            rows.push(row);
            let (ai, bi) = part.factors_of(u);
            let payload = matmul(&a_blocks[ai], &b_blocks[bi]);
            absorbed.push((u as u32, 0.1 * (u + 1) as f64, 0, payload));
        }
        let (gram, energy) = if scored {
            let g = part.gram(&part.true_products(&a, &b));
            let e = part.loss_from_gram(&g, &vec![false; k]);
            (Some(g), e)
        } else {
            (None, f64::NAN)
        };
        let c_true = matmul(&a, &b);
        let task = DecodeTask {
            session,
            request,
            shard_key: request,
            part,
            n_classes: 1,
            class_of: vec![0; k],
            n_total: k,
            rows,
            absorbed,
            gram,
            energy,
            counters: RequestCounters {
                late: 1,
                dispatched: 7,
                ..Default::default()
            },
        };
        (task, c_true)
    }

    fn collect_until_done(pool: &mut DecodePool, want_done: usize) -> Vec<DecodeEvent> {
        let deadline = Instant::now() + Duration::from_secs(20);
        let mut events = Vec::new();
        let mut done = 0;
        while done < want_done {
            assert!(Instant::now() < deadline, "decode pool timed out");
            for ev in pool.poll() {
                if matches!(ev, DecodeEvent::Done { .. }) {
                    done += 1;
                }
                events.push(ev);
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        events
    }

    #[test]
    fn sharded_decode_reproduces_the_exact_product() {
        let (task, c_true) = identity_task(3, 40, true);
        let mut pool = DecodePool::new(2);
        pool.submit(task);
        let events = collect_until_done(&mut pool, 1);
        // steps arrive in absorption order, losses non-increasing, and
        // the final frame carries the exact product with zero loss
        let steps: Vec<&ProgressMsg> = events
            .iter()
            .filter_map(|e| match e {
                DecodeEvent::Step { msg, .. } => Some(msg),
                _ => None,
            })
            .collect();
        assert_eq!(steps.len(), 4);
        for (i, s) in steps.iter().enumerate() {
            assert_eq!((s.session, s.request), (3, 40));
            assert_eq!(s.received, i as u32 + 1);
            assert_eq!(s.newly, 1);
        }
        assert!(steps.windows(2).all(|w| w[1].loss <= w[0].loss + 1e-9));
        match events.last().unwrap() {
            DecodeEvent::Done { session, request, result, full_recovery } => {
                assert_eq!((*session, *request), (3, 40));
                assert!(full_recovery);
                assert_eq!(result.recovered, 4);
                assert_eq!(result.per_class, vec![4]);
                assert_eq!(result.loss, 0.0, "full recovery pins loss to zero");
                assert!(result.c_hat.allclose(&c_true, 1e-9));
                // engine counters echo through untouched
                assert_eq!((result.late, result.dispatched), (1, 7));
            }
            other => panic!("unexpected {other:?}"),
        }
        pool.shutdown();
    }

    #[test]
    fn unscored_tasks_report_nan_loss_and_requests_stay_ordered() {
        let (t1, c_true) = identity_task(1, 10, false);
        let (t2, _) = identity_task(2, 11, false);
        let mut pool = DecodePool::new(2);
        pool.submit(t1);
        pool.submit(t2);
        let events = collect_until_done(&mut pool, 2);
        // per-request step order is preserved even across shards
        for rid in [10u64, 11] {
            let recv: Vec<u32> = events
                .iter()
                .filter_map(|e| match e {
                    DecodeEvent::Step { request, msg, .. } if *request == rid => {
                        Some(msg.received)
                    }
                    _ => None,
                })
                .collect();
            assert_eq!(recv, vec![1, 2, 3, 4], "request {rid}");
        }
        let done: Vec<&ClientResultMsg> = events
            .iter()
            .filter_map(|e| match e {
                DecodeEvent::Done { result, .. } => Some(result),
                _ => None,
            })
            .collect();
        assert_eq!(done.len(), 2);
        for r in done {
            assert!(r.loss.is_nan(), "unscored ⇒ NaN loss");
            assert!(r.c_hat.allclose(&c_true, 1e-9));
        }
        pool.shutdown();
    }
}
