//! The coordinator server: the parameter server of paper Fig. 2 running
//! against remote worker agents.
//!
//! Responsibilities:
//! * **registry** — accept worker connections (any [`Transport`]),
//!   handshake, track liveness, evict workers that stop answering
//!   heartbeats or whose connections fail, and let a previously evicted
//!   agent **rejoin** by re-registering under its name;
//! * **request pipeline** — serve a stream of multiplication requests,
//!   each with its own deadline: dispatch coded jobs to the live worker
//!   with the fewest outstanding jobs (ties broken by the lowest EWMA
//!   straggle score), feed arriving results into the incremental
//!   [`DecodeState`], stop at the deadline, and score the decoded
//!   approximation;
//! * **resilient job lifecycle** — every dispatched payload is retained
//!   in a per-request job table until its result lands, so jobs
//!   stranded on a worker that dies mid-request are **re-dispatched**
//!   onto survivors (bounded by [`ClusterConfig::max_job_retries`]);
//!   result frames read out of turn (by [`ClusterServer::heartbeat`] or
//!   a stale poll) are buffered in a per-worker **inbox** instead of
//!   being dropped, and duplicate results for a slot are absorbed
//!   exactly once — a failure costs latency, never accepted work;
//! * **encoded-block cache** — reuse the `B`-independent half of plan
//!   preparation across requests that multiply the same `A`
//!   (see [`super::cache`]).
//!
//! Two deadline disciplines:
//! * [`DeadlineMode::Virtual`] — every result carries a virtual
//!   completion time (injected by the coordinator from a seeded latency
//!   model, or self-sampled by the worker); the coordinator collects all
//!   results, absorbs them in `(delay, slot)` order, and accepts those
//!   with `delay ≤ T_max`. Deterministic: same seed ⇒ bit-identical
//!   outcome, which is what the loopback test suite runs.
//! * [`DeadlineMode::Wall`] — the deadline is `T_max · time_scale` wall
//!   seconds; whatever physically arrives in time is decoded
//!   progressively and stragglers are cut off, exactly the paper's
//!   protocol. This is the TCP deployment discipline.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coding::{CodeSpec, DecodeState, JobRecipe, Packet, UnknownSpace};
use crate::coordinator::{
    assemble_outcome, build_job_matrices, score_outcome, Assignment, EncodedA,
    Outcome, Plan, RatelessPlan, RatelessVerifier, Verifier,
};
use crate::latency::LatencyModel;
use crate::linalg::{matmul, Matrix};
use crate::partition::{ClassMap, Partitioning};
use crate::rng::Pcg64;

use std::collections::{BTreeMap, VecDeque};

use super::cache::{CacheKey, CacheStats, EncodedBlockCache};
use super::transport::{Connection, Transport};
use super::wire::{
    JobMsg, Msg, RatelessJobMsg, RatelessResultMsg, ResultMsg, WireError,
};

/// Per-connection poll slice while multiplexing receives.
const POLL_SLICE: Duration = Duration::from_millis(1);
/// Workers pace a paced (injected-delay) reply by at most this factor of
/// the request deadline — sleeping much past the deadline only wastes
/// wall time on results that will be counted late anyway.
const SLEEP_CAP_FACTOR: f64 = 1.05;
/// Smoothing factor of the per-worker EWMA straggle score: each
/// accepted result's reported delay moves the score by this fraction.
const STRAGGLE_EWMA_ALPHA: f64 = 0.2;

/// How request deadlines are enforced (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeadlineMode {
    Virtual,
    Wall,
}

/// Coordinator server configuration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub deadline: DeadlineMode,
    /// Wall seconds per virtual time unit: the wall deadline in `Wall`
    /// mode (must be > 0 there), and the pacing of injected delays in
    /// `Virtual` mode (0 = no pacing, run as fast as possible).
    pub time_scale: f64,
    /// How long a worker may take to answer a heartbeat before eviction.
    pub heartbeat_timeout: Duration,
    /// Hard stop for `Virtual`-mode collection (guards against a hung
    /// worker stalling a deterministic run forever).
    pub collect_timeout: Duration,
    /// Post-deadline grace period in `Wall` mode for counting (and
    /// draining) late results.
    pub late_drain: Duration,
    /// Encoded-block cache capacity in entries (0 disables caching).
    pub cache_capacity: usize,
    /// How many times a job slot stranded on a dead worker may be
    /// re-dispatched onto a survivor before it is written off (0
    /// disables re-dispatch entirely: the pre-resilience behavior).
    pub max_job_retries: usize,
    /// How many heartbeat rounds a worker may miss consecutively before
    /// eviction (1 = evict on the first miss, the pre-PR-6 behavior).
    /// Send failures still evict immediately — a dead connection proves
    /// itself.
    pub evict_after: u32,
    /// Freivalds-verify every arriving sub-product against the request's
    /// job set (see [`crate::coordinator::Verifier`]). O(n²) per result
    /// vs the O(n³) of the product itself; catches Byzantine (tampered)
    /// payloads that pass the frame checksum.
    pub verify: bool,
    /// Verification strikes a worker may accumulate before it is
    /// **quarantined**: evicted and barred from re-[`Msg::Hello`] rejoin
    /// under the same agent name until [`ClusterServer::reset_quarantine`].
    pub max_verify_failures: u32,
    /// Seed of the Freivalds probe RNG. Probes are drawn from
    /// `(verify_seed, request_id)` on a stream disjoint from delay
    /// sampling, so toggling [`ClusterConfig::verify`] never shifts any
    /// other random draw: honest-run outcomes stay bit-identical.
    pub verify_seed: u64,
    /// `Virtual`-mode stall recovery: if no result arrives and nothing is
    /// requeued for this long while jobs are outstanding, every
    /// unresolved in-flight slot is requeued (the holder may have
    /// dropped the result frame on a lossy channel). Bounded by
    /// [`ClusterConfig::max_job_retries`] per slot, so a truly dead slot
    /// is eventually written off rather than respun forever.
    pub stall_timeout: Duration,
    /// Heterogeneity-aware dispatch: plan each request's slot→worker
    /// map up front with [`crate::coordinator::Assignment`] — slower
    /// workers get fewer and less-critical (higher-window) slots —
    /// instead of least-outstanding. The scale map comes from
    /// client-pushed fitted offsets ([`ClusterServer::set_worker_scales`],
    /// re-pushed on the session's `Replanner` cadence) with the
    /// per-worker straggle EWMA as fallback; when neither source has
    /// data the dispatch silently stays least-outstanding, and a plan
    /// naming a dead worker fails over per slot. `Virtual`-mode decode
    /// outcomes are mapping-independent (results are absorbed in
    /// `(delay, slot)` order), so flipping this only moves *which
    /// worker* computes a slot — wall-clock under real heterogeneity —
    /// never a decoded value.
    pub hetero_assign: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            deadline: DeadlineMode::Virtual,
            time_scale: 0.0,
            heartbeat_timeout: Duration::from_secs(2),
            collect_timeout: Duration::from_secs(60),
            late_drain: Duration::from_millis(50),
            cache_capacity: 16,
            max_job_retries: 2,
            evict_after: 1,
            verify: true,
            max_verify_failures: 3,
            verify_seed: 0xf7e1_5eed,
            stall_timeout: Duration::from_secs(5),
            hetero_assign: false,
        }
    }
}

/// The coding setup a request stream is served under. Classes are pinned
/// (`cm`) so the packet draw — and therefore the cache — is coherent
/// across requests.
#[derive(Clone, Debug)]
pub struct CodingConfig {
    pub part: Partitioning,
    pub spec: CodeSpec,
    pub cm: ClassMap,
    /// Coded packets (= jobs) per request.
    pub workers: usize,
    /// Coordinator-injected straggle model for `Virtual`-mode runs
    /// (sampled per job from the request stream's seeded RNG). `None`
    /// leaves timing to the workers/transport.
    pub latency: Option<LatencyModel>,
}

impl CodingConfig {
    /// The paper's Ω fairness scaling (Remark 1).
    pub fn omega(&self) -> f64 {
        crate::latency::omega(self.part.num_products(), self.workers)
    }
}

/// One multiplication request in a stream. `a_id` is the caller's stable
/// identity for `A` (e.g. "layer-3 weights"): requests sharing an
/// `a_id` share cached encodings.
#[derive(Clone, Debug)]
pub struct MatmulRequest {
    pub a_id: u64,
    pub a: Matrix,
    pub b: Matrix,
    /// Per-request deadline, in virtual time units.
    pub t_max: f64,
    /// Compute the exact product locally and score the approximation
    /// against it. Evaluation only: at scale the local `A·B` dwarfs
    /// dispatch + decode, so production streams should pass `false`
    /// (the outcome's loss fields come back NaN).
    pub score: bool,
}

/// Outcome of one served request, with cluster accounting on top of the
/// decode [`Outcome`].
#[derive(Clone, Debug)]
pub struct ClusterOutcome {
    pub outcome: Outcome,
    /// Results that arrived but missed the deadline.
    pub late: usize,
    /// Jobs successfully handed to a worker connection.
    pub dispatched: usize,
    /// Re-dispatches of jobs stranded on workers that died mid-request.
    pub retries: usize,
    /// Result frames naming a slot outside the request's packet set
    /// (a broken worker) plus frames that arrived checksum-damaged
    /// (see [`ServedDecode::corrupt`]).
    pub corrupt: usize,
    /// Arriving results that failed Freivalds verification (tampered or
    /// miscomputed payloads; see [`ServedDecode::verify_failures`]).
    pub verify_failures: usize,
    /// Wall time the request took end to end.
    pub wall: Duration,
    /// `Some(hit)` when served through the encoded-block cache.
    pub cache_hit: Option<bool>,
}

impl ClusterOutcome {
    /// Dispatched jobs whose results were never seen for this request:
    /// jobs written off after exhausting their re-dispatch budget (every
    /// holder died), but in `Wall` mode also any straggler result
    /// arriving after the post-deadline grace window (the worker may be
    /// perfectly healthy — its result is simply counted against the
    /// request it missed). Always `dispatched − received − late`;
    /// `corrupt` counts garbage *frames*, not slots, and sits outside
    /// this balance.
    pub fn missing(&self) -> usize {
        self.dispatched - self.outcome.received - self.late
    }
}

/// Registry view of one worker (for logs and stats).
#[derive(Clone, Debug)]
pub struct WorkerInfo {
    pub id: u64,
    pub name: String,
    pub alive: bool,
    pub jobs_done: u64,
    /// EWMA of the worker's reported result delays (virtual time units);
    /// `None` until its first accepted result. Low = fast, high =
    /// straggler — the dispatch tie-breaker.
    pub straggle: Option<f64>,
    /// Freivalds verification strikes accumulated by this worker.
    pub verify_failures: u32,
    /// Whether the worker is quarantined (evicted for lying, barred from
    /// rejoin until [`ClusterServer::reset_quarantine`]).
    pub quarantined: bool,
}

/// What a [`ClusterServer::heartbeat`] round did.
#[derive(Clone, Debug, Default)]
pub struct HeartbeatReport {
    /// Workers evicted this round (send failure or missed ack).
    pub evicted: Vec<u64>,
    /// In-flight [`Msg::Result`] frames read while waiting for acks and
    /// routed into the owning worker's inbox — never dropped; the next
    /// serve poll drains them with full accounting.
    pub buffered_results: usize,
}

struct WorkerSlot {
    id: u64,
    name: String,
    conn: Box<dyn Connection>,
    /// Liveness is decided actively: send/recv failures and missed
    /// heartbeat acks flip this; there is no passive staleness timer.
    alive: bool,
    jobs_done: u64,
    /// Job slots of the *current* request dispatched to this worker and
    /// not yet resolved — the requeue set if the worker dies.
    in_flight: Vec<u32>,
    /// Result frames read out of turn (by [`ClusterServer::heartbeat`]
    /// while waiting for acks): buffered here and drained by the next
    /// serve poll instead of being dropped.
    inbox: VecDeque<ResultMsg>,
    /// Same buffer for per-packet rateless result frames (protocol v5).
    rateless_inbox: VecDeque<RatelessResultMsg>,
    /// EWMA straggle score over reported result delays (see
    /// [`WorkerInfo::straggle`]).
    straggle: Option<f64>,
    /// Consecutive heartbeat rounds this worker failed to ack; reset by
    /// any ack or buffered result, evicts at
    /// [`ClusterConfig::evict_after`].
    missed_heartbeats: u32,
    /// Freivalds verification strikes (survives eviction and rejoin —
    /// the strike record belongs to the agent, not the connection).
    verify_failures: u32,
    /// Quarantined workers are dead *and* refused re-registration under
    /// their name until [`ClusterServer::reset_quarantine`].
    quarantined: bool,
}

impl WorkerSlot {
    fn note_result_delay(&mut self, delay: f64) {
        self.straggle = Some(match self.straggle {
            None => delay,
            Some(e) => STRAGGLE_EWMA_ALPHA * delay + (1.0 - STRAGGLE_EWMA_ALPHA) * e,
        });
    }
}

/// Per-request collection state shared by dispatch, polling, and the
/// requeue path: which slots have settled (result accepted, counted
/// late, or written off), which await re-dispatch, and how many are
/// still outstanding on live workers.
struct Collect {
    request_id: u64,
    n_slots: usize,
    /// A settled slot will neither be re-dispatched nor decrement
    /// `outstanding` again — the duplicate-result guard.
    settled: Vec<bool>,
    /// Slots stranded on dead workers, awaiting re-dispatch.
    requeue: Vec<u32>,
    outstanding: usize,
    corrupt: usize,
    verify_failures: usize,
}

impl Collect {
    fn new(request_id: u64, n_slots: usize) -> Collect {
        Collect {
            request_id,
            n_slots,
            settled: vec![false; n_slots],
            requeue: Vec::new(),
            outstanding: 0,
            corrupt: 0,
            verify_failures: 0,
        }
    }

    /// Write off every queued slot (no re-dispatch): used when nothing
    /// requeued could make its deadline anyway.
    fn write_off_queued(&mut self) {
        while let Some(slot) = self.requeue.pop() {
            let s = slot as usize;
            if !self.settled[s] {
                self.settled[s] = true;
                self.outstanding -= 1;
            }
        }
    }
}

/// Per-(stream, seq) collection record of one rateless request.
struct PacketSlot {
    payload: Option<Matrix>,
    absorbed: bool,
    written_off: bool,
    /// Flagged for regeneration via [`Msg::Redo`] (end-of-stream gap,
    /// verify failure, or stall).
    redo_now: bool,
    /// Redo sends so far (bounded by [`ClusterConfig::max_job_retries`]).
    redos: u32,
    /// Registry id of the delivering worker.
    src: u64,
    compute_secs: f64,
    /// Reported virtual completion time (Wall-mode absorption records
    /// it; Virtual mode absorbs on the injected schedule instead).
    delay: f64,
}

/// Rateless counterpart of [`Collect`]: dedup, end-of-stream tracking,
/// and redo flags per `(stream, seq)` packet.
struct RatelessCollect {
    request_id: u64,
    /// `slots[stream][seq]`, sized by the per-stream budgets.
    slots: Vec<Vec<PacketSlot>>,
    /// Whether each stream's final frame (`more == false`) was seen —
    /// after it, missing packets of the stream only arrive via Redo.
    eos: Vec<bool>,
    /// Packets neither delivered nor written off yet.
    outstanding: usize,
    corrupt: usize,
    verify_failures: usize,
}

impl RatelessCollect {
    fn new(request_id: u64, budgets: &[u32]) -> RatelessCollect {
        let slots: Vec<Vec<PacketSlot>> = budgets
            .iter()
            .map(|&b| {
                (0..b)
                    .map(|_| PacketSlot {
                        payload: None,
                        absorbed: false,
                        written_off: false,
                        redo_now: false,
                        redos: 0,
                        src: 0,
                        compute_secs: 0.0,
                        delay: 0.0,
                    })
                    .collect()
            })
            .collect();
        let outstanding = budgets.iter().map(|&b| b as usize).sum();
        RatelessCollect {
            request_id,
            slots,
            eos: vec![false; budgets.len()],
            outstanding,
            corrupt: 0,
            verify_failures: 0,
        }
    }

    /// Stall recovery: flag every undelivered packet for regeneration.
    fn flag_all_missing(&mut self) {
        for stream in &mut self.slots {
            for sl in stream {
                if sl.payload.is_none() && !sl.absorbed && !sl.written_off {
                    sl.redo_now = true;
                }
            }
        }
    }
}

/// One accepted decode absorption inside a served request, reported to
/// the observer of [`ClusterServer::serve_jobs`] — the hook behind the
/// client API's anytime [`crate::api::Progress`] stream.
#[derive(Clone, Debug)]
pub struct DecodeStep {
    /// Virtual completion time of the absorbed result.
    pub delay: f64,
    /// Dispatch attempt that produced the result (0 = first send, `n` =
    /// the `n`-th re-dispatch after a worker death).
    pub attempt: u32,
    /// Results absorbed so far (this one included).
    pub received: usize,
    /// Real sub-products determined so far.
    pub recovered: usize,
    /// Sub-products newly determined by this absorption.
    pub newly: Vec<usize>,
}

/// Per-job round-trip record of one served request — the raw material
/// of the latency estimators ([`crate::latency::LatencyEstimator`],
/// [`crate::latency::FleetEstimator`]). One record per classified
/// result frame, in-deadline or late, in absorption order (deterministic
/// in `Virtual` mode).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobTiming {
    /// Job slot the result settled.
    pub slot: u32,
    /// Registry id of the worker that delivered it (in-process backends
    /// use the slot index — one virtual worker per job).
    pub worker: u64,
    /// Dispatch attempt that produced the result.
    pub attempt: u32,
    /// Reported virtual completion time (same units as `T_max`).
    pub delay: f64,
    /// Worker-measured wall compute seconds (0 where not measured).
    pub compute_secs: f64,
    /// Whether the result missed the request deadline.
    pub late: bool,
}

/// Raw dispatch/collect/decode result of one served job set, before
/// assembly and scoring. The accounting invariant is
/// `received + late + written-off == dispatched` (written-off being the
/// caller's `missing`); `retries`, `corrupt`, and `attempts` are
/// diagnostics on top of that balance.
pub struct ServedDecode {
    pub st: DecodeState,
    pub received: usize,
    pub late: usize,
    /// Distinct job slots successfully handed to a worker at least once.
    pub dispatched: usize,
    /// Re-dispatch sends beyond each slot's first (bounded by
    /// [`ClusterConfig::max_job_retries`] per slot).
    pub retries: usize,
    /// Result frames naming a slot outside the request's packet set
    /// (the sender is evicted as broken and its in-flight jobs
    /// re-dispatched) plus frames that arrived checksum-damaged (a
    /// channel fault: the sender keeps its slots and the damaged
    /// deliveries are requeued).
    pub corrupt: usize,
    /// Arriving results that failed Freivalds verification. Each strikes
    /// the sender (quarantine at
    /// [`ClusterConfig::max_verify_failures`]) and requeues the slot.
    pub verify_failures: usize,
    /// Per-slot send counts: `attempts[s]` is how many times slot `s`
    /// went out (1 = first dispatch only, 0 = never sent).
    pub attempts: Vec<u32>,
    /// Per-job round-trip telemetry, in absorption order (one record per
    /// classified result, including late ones).
    pub timings: Vec<JobTiming>,
    /// Rateless partial credit: packets absorbed into the decode, by the
    /// registry id of the worker that delivered them (one entry per
    /// worker the request was dispatched to). Empty for fixed-rate
    /// requests.
    pub worker_packets: Vec<(u64, usize)>,
    /// Rateless partial credit: the minimum, over every worker that was
    /// dispatched a non-empty packet stream, of packets credited to the
    /// stream's owner. `> 0` means even the slowest worker contributed
    /// decoded work — the straggler-exploitation claim the rateless code
    /// exists to make. Always 0 for fixed-rate requests.
    pub partial_packets: usize,
    pub wall: Duration,
}

/// The coordinator server. See module docs.
pub struct ClusterServer {
    cfg: ClusterConfig,
    workers: Vec<WorkerSlot>,
    cache: EncodedBlockCache,
    next_request_id: u64,
    next_worker_id: u64,
    next_nonce: u64,
    /// Rotating start index for [`Self::poll_round`]: advanced every
    /// tick so no worker's inbox is systematically drained last.
    poll_rotor: usize,
    /// Client-pushed fitted per-worker scale offsets (1.0 = fleet mean,
    /// higher = slower) — the primary source for
    /// [`ClusterConfig::hetero_assign`] planning; see
    /// [`Self::set_worker_scales`].
    fitted_scales: BTreeMap<u64, f64>,
    /// Per-worker multipliers applied to *injected* slot delays at
    /// dispatch time (evaluation/chaos hook); see
    /// [`Self::set_straggle_injection`].
    straggle_injection: BTreeMap<u64, f64>,
}

impl ClusterServer {
    pub fn new(cfg: ClusterConfig) -> Self {
        let cache = EncodedBlockCache::new(cfg.cache_capacity);
        ClusterServer {
            cfg,
            workers: Vec::new(),
            cache,
            next_request_id: 1,
            next_worker_id: 1,
            next_nonce: 1,
            poll_rotor: 0,
            fitted_scales: BTreeMap::new(),
            straggle_injection: BTreeMap::new(),
        }
    }

    /// Install client-fitted per-worker scale offsets (1.0 = fleet
    /// mean, higher = slower), keyed by registry id. Replaces the
    /// previous map wholesale — adaptive sessions re-push on their
    /// `Replanner` cadence, so a worker dropped from the fit falls back
    /// to its straggle EWMA. Non-finite and non-positive entries are
    /// dropped. A no-op for dispatch unless
    /// [`ClusterConfig::hetero_assign`] is set.
    pub fn set_worker_scales(&mut self, scales: &[(u64, f64)]) {
        self.fitted_scales = scales
            .iter()
            .copied()
            .filter(|&(_, s)| s.is_finite() && s > 0.0)
            .collect();
    }

    /// The fitted scale map currently installed (id-ordered).
    pub fn worker_scales(&self) -> Vec<(u64, f64)> {
        self.fitted_scales.iter().map(|(&id, &s)| (id, s)).collect()
    }

    /// Install per-worker *injected-delay* multipliers, keyed by
    /// registry id (deterministic heterogeneity injection for
    /// evaluation and chaos drills). A worker holding multiplier `m`
    /// completes an injected-delay job as if it were `m`× slower: the
    /// slot's base delay is multiplied at dispatch, so worker pacing,
    /// the reported delay, virtual-time decode, and the straggle EWMA
    /// all see the scaled value. Workers absent from the map run at
    /// 1.0. Replaces the previous map wholesale; non-finite and
    /// non-positive entries are dropped. Inert for requests without
    /// injected delays.
    pub fn set_straggle_injection(&mut self, scales: &[(u64, f64)]) {
        self.straggle_injection = scales
            .iter()
            .copied()
            .filter(|&(_, s)| s.is_finite() && s > 0.0)
            .collect();
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Number of workers currently considered alive.
    pub fn live_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).count()
    }

    pub fn worker_info(&self) -> Vec<WorkerInfo> {
        self.workers
            .iter()
            .map(|w| WorkerInfo {
                id: w.id,
                name: w.name.clone(),
                alive: w.alive,
                jobs_done: w.jobs_done,
                straggle: w.straggle,
                verify_failures: w.verify_failures,
                quarantined: w.quarantined,
            })
            .collect()
    }

    /// Registry ids of every quarantined worker.
    pub fn quarantined_workers(&self) -> Vec<u64> {
        self.workers
            .iter()
            .filter(|w| w.quarantined)
            .map(|w| w.id)
            .collect()
    }

    /// Operator reset: clear a quarantined worker's strike record and
    /// make its agent name eligible for rejoin again. Returns whether
    /// the id named a quarantined worker. The agent must still
    /// re-register — this lifts the bar, it does not revive the slot.
    pub fn reset_quarantine(&mut self, id: u64) -> bool {
        match self.workers.iter_mut().find(|w| w.id == id && w.quarantined) {
            Some(w) => {
                w.quarantined = false;
                w.verify_failures = 0;
                true
            }
            None => false,
        }
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Handshake one incoming connection into the registry.
    ///
    /// A `Hello` whose agent name matches a previously evicted worker is
    /// a **rejoin**: the dead slot is revived in place (same worker id,
    /// cumulative `jobs_done`, fresh connection and straggle score) and
    /// the agent is immediately eligible for dispatch — including work
    /// requeued from other failures.
    ///
    /// Only slots already *observed* dead are matched: an agent that
    /// crashes and reconnects before the coordinator has touched its old
    /// connection registers as a new slot (names are not required to be
    /// unique, so a live slot is never displaced). The stale slot is
    /// evicted on its next contact and revived by a later rejoin.
    pub fn register(
        &mut self,
        mut conn: Box<dyn Connection>,
        timeout: Duration,
    ) -> Result<u64> {
        match conn.recv_timeout(Some(timeout)) {
            Ok(Some(Msg::Hello { agent })) => {
                if let Some(q) =
                    self.workers.iter().find(|w| w.quarantined && w.name == agent)
                {
                    anyhow::bail!(
                        "agent {agent} is quarantined (worker {}): rejoin refused \
                         until reset_quarantine",
                        q.id
                    );
                }
                if let Some(wi) = self
                    .workers
                    .iter()
                    .position(|w| !w.alive && w.name == agent)
                {
                    let id = self.workers[wi].id;
                    conn.send(&Msg::Welcome { worker_id: id }).map_err(|e| {
                        anyhow::anyhow!("welcome to rejoining {agent} failed: {e}")
                    })?;
                    let w = &mut self.workers[wi];
                    w.conn = conn;
                    w.alive = true;
                    // anything in flight or buffered belongs to the old
                    // incarnation's requests and can only be stale now
                    w.in_flight.clear();
                    w.inbox.clear();
                    w.rateless_inbox.clear();
                    w.straggle = None;
                    w.missed_heartbeats = 0;
                    return Ok(id);
                }
                let id = self.next_worker_id;
                self.next_worker_id += 1;
                conn.send(&Msg::Welcome { worker_id: id })
                    .map_err(|e| anyhow::anyhow!("welcome to {agent} failed: {e}"))?;
                self.workers.push(WorkerSlot {
                    id,
                    name: agent,
                    conn,
                    alive: true,
                    jobs_done: 0,
                    in_flight: Vec::new(),
                    inbox: VecDeque::new(),
                    rateless_inbox: VecDeque::new(),
                    straggle: None,
                    missed_heartbeats: 0,
                    verify_failures: 0,
                    quarantined: false,
                });
                Ok(id)
            }
            Ok(Some(other)) => {
                anyhow::bail!("expected hello from {}, got {}", conn.peer(), other.name())
            }
            Ok(None) => anyhow::bail!("registration from {} timed out", conn.peer()),
            Err(e) => anyhow::bail!("registration from {} failed: {e}", conn.peer()),
        }
    }

    /// Accept and register up to `n` workers within `timeout`. Returns
    /// how many joined. A connection that fails the handshake (e.g. a
    /// stray non-worker hitting the port) is dropped and accepting
    /// continues; only transport-level failures abort.
    pub fn accept_workers(
        &mut self,
        transport: &mut dyn Transport,
        n: usize,
        timeout: Duration,
    ) -> Result<usize> {
        let deadline = Instant::now() + timeout; // lint:allow(no-wallclock-in-deterministic-paths) registration hang-guard; decode order never reads it
        let mut accepted = 0;
        while accepted < n {
            let now = Instant::now(); // lint:allow(no-wallclock-in-deterministic-paths) registration hang-guard; decode order never reads it
            if now >= deadline {
                break;
            }
            let slice = (deadline - now).min(Duration::from_millis(100));
            match transport.accept_timeout(slice) {
                Ok(Some(conn)) => {
                    // the handshake may not overrun the caller's accept
                    // deadline (a silent stray connection would otherwise
                    // stall registration for its full grace period)
                    let handshake = Duration::from_secs(10)
                        .min(deadline.saturating_duration_since(Instant::now())) // lint:allow(no-wallclock-in-deterministic-paths) caps the handshake wait, not decode
                        .max(Duration::from_millis(100));
                    match self.register(conn, handshake) {
                        Ok(_) => accepted += 1,
                        Err(e) => eprintln!("rejected connection: {e}"),
                    }
                }
                Ok(None) => {}
                Err(e) => anyhow::bail!("accept failed: {e}"),
            }
        }
        Ok(accepted)
    }

    /// Ping every live worker and evict the ones that do not ack within
    /// the heartbeat timeout (or whose connection fails).
    ///
    /// Any in-flight [`Msg::Result`] frame read while waiting for acks
    /// is routed into the owning worker's inbox — never consumed and
    /// dropped. The next serve poll drains the inbox through the same
    /// classifier as a fresh receive: a frame for the request then being
    /// served absorbs with full `received`/`jobs_done` accounting, while
    /// one from an already-completed request is dropped only once it is
    /// provably stale. Either way the frame also credits liveness here,
    /// so a healthy backlogged straggler is not mis-evicted — and a run
    /// interleaved with heartbeats decodes bit-identically to one
    /// without.
    pub fn heartbeat(&mut self) -> HeartbeatReport {
        let alive_at_entry: Vec<usize> = (0..self.workers.len())
            .filter(|&wi| self.workers[wi].alive)
            .collect();
        let nonce = self.next_nonce;
        self.next_nonce += 1;
        let mut buffered = 0usize;
        let mut waiting = Vec::new();
        for &wi in &alive_at_entry {
            match self.workers[wi].conn.send(&Msg::Heartbeat { nonce }) {
                Ok(()) => waiting.push(wi),
                Err(_) => self.workers[wi].alive = false,
            }
        }
        let deadline = Instant::now() + self.cfg.heartbeat_timeout; // lint:allow(no-wallclock-in-deterministic-paths) heartbeat liveness window, not decode state
        let mut acked = vec![false; self.workers.len()];
        loop {
            let outstanding = waiting
                .iter()
                .any(|&wi| !acked[wi] && self.workers[wi].alive);
            if !outstanding || Instant::now() >= deadline { // lint:allow(no-wallclock-in-deterministic-paths) heartbeat liveness window, not decode state
                break;
            }
            for &wi in &waiting {
                if acked[wi] || !self.workers[wi].alive {
                    continue;
                }
                match self.workers[wi].conn.recv_timeout(Some(POLL_SLICE)) {
                    Ok(Some(Msg::HeartbeatAck { .. })) => {
                        // any ack (even a stale nonce) proves liveness
                        acked[wi] = true;
                    }
                    // a result frame equally proves the worker is alive
                    // and making progress — a paced straggler's ack can
                    // sit behind its whole job backlog, and evicting it
                    // for that would throw away healthy capacity. The
                    // payload is buffered, not discarded: it is accepted
                    // work the serve path still has to account for.
                    Ok(Some(Msg::Result(r))) => {
                        self.workers[wi].inbox.push_back(r);
                        buffered += 1;
                        acked[wi] = true;
                    }
                    Ok(Some(Msg::RatelessResult(r))) => {
                        self.workers[wi].rateless_inbox.push_back(r);
                        buffered += 1;
                        acked[wi] = true;
                    }
                    Ok(Some(_)) => self.workers[wi].alive = false,
                    Ok(None) => {}
                    // a checksum-damaged frame is a channel fault: it
                    // neither proves liveness (keep waiting for the ack)
                    // nor condemns the worker
                    Err(WireError::BadChecksum { .. }) => {}
                    Err(_) => self.workers[wi].alive = false,
                }
            }
        }
        let mut evicted = Vec::new();
        for &wi in &alive_at_entry {
            if acked[wi] {
                self.workers[wi].missed_heartbeats = 0;
            } else if self.workers[wi].alive && waiting.contains(&wi) {
                // missed acks evict only after `evict_after` consecutive
                // silent rounds; send/recv failures (alive already false)
                // evict immediately
                self.workers[wi].missed_heartbeats += 1;
                if self.workers[wi].missed_heartbeats >= self.cfg.evict_after {
                    self.workers[wi].alive = false;
                }
            }
            if !self.workers[wi].alive {
                evicted.push(self.workers[wi].id);
            }
        }
        HeartbeatReport { evicted, buffered_results: buffered }
    }

    /// Send every worker a shutdown (best effort, including evicted
    /// ones — a worker evicted for slowness rather than death still
    /// deserves an orderly exit) and close the registry.
    ///
    /// Connections stay open (the server object holds them); callers
    /// that exit the process right afterwards should use
    /// [`Self::shutdown_graceful`] so a backlogged straggler still gets
    /// the queued shutdown frame instead of a connection reset.
    pub fn shutdown(&mut self) {
        for w in &mut self.workers {
            let _ = w.conn.send(&Msg::Shutdown);
            w.alive = false;
        }
    }

    /// [`Self::shutdown`], then drain every connection until the peer
    /// closes it or `timeout` elapses. A worker still sleeping through
    /// its job backlog keeps writing results; if the coordinator process
    /// simply exited, those writes would trigger a TCP reset that
    /// discards the worker's unread receive buffer — including the
    /// shutdown frame — and turn a clean exit into a connection loss.
    pub fn shutdown_graceful(&mut self, timeout: Duration) {
        self.shutdown();
        let deadline = Instant::now() + timeout; // lint:allow(no-wallclock-in-deterministic-paths) shutdown drain window only
        let mut open: Vec<bool> = self.workers.iter().map(|_| true).collect();
        while open.iter().any(|&o| o) && Instant::now() < deadline { // lint:allow(no-wallclock-in-deterministic-paths) shutdown drain window only
            for (wi, w) in self.workers.iter_mut().enumerate() {
                if !open[wi] {
                    continue;
                }
                match w.conn.recv_timeout(Some(POLL_SLICE)) {
                    Ok(Some(_)) => {} // drain backlog results quietly
                    Ok(None) => {}
                    Err(_) => open[wi] = false, // peer closed: fully drained
                }
            }
        }
    }

    /// Serve one pre-built [`Plan`] (no cache involvement): dispatch its
    /// packets, collect to the deadline, decode, score. `delays` are
    /// optional coordinator-injected virtual completion times, one per
    /// packet.
    pub fn serve_plan(
        &mut self,
        plan: &Plan,
        t_max: f64,
        delays: Option<&[f64]>,
    ) -> Result<ClusterOutcome> {
        let jobs: Vec<(Arc<Matrix>, Arc<Matrix>)> = plan
            .packets
            .iter()
            .map(|p| {
                let (wa, wb) = build_job_matrices(
                    &plan.part,
                    &plan.a_blocks,
                    &plan.b_blocks,
                    &p.recipe,
                );
                (Arc::new(wa), Arc::new(wb))
            })
            .collect();
        let core =
            self.serve_jobs(&plan.space, &plan.packets, jobs, delays, t_max, None)?;
        let outcome =
            score_outcome(&plan.part, &plan.cm, &plan.c_true, &core.st, core.received);
        Ok(ClusterOutcome {
            outcome,
            late: core.late,
            dispatched: core.dispatched,
            retries: core.retries,
            corrupt: core.corrupt,
            verify_failures: core.verify_failures,
            wall: core.wall,
            cache_hit: None,
        })
    }

    /// Serve one request of a stream through the encoded-block cache:
    /// on a hit the `A`-side (split + packet draw + every `W_A`) is
    /// reused and only the `B`-side is built.
    pub fn serve_request(
        &mut self,
        coding: &CodingConfig,
        req: &MatmulRequest,
        rng: &mut Pcg64,
    ) -> Result<ClusterOutcome> {
        // single-stream server: one caller, so the tenant namespace is 0
        let key = CacheKey::new(
            0,
            req.a_id,
            &coding.part,
            &coding.spec,
            &coding.cm,
            coding.workers,
        );
        let (enc, hit) = self.cache.get_or_insert_with(key, || {
            EncodedA::encode(
                &coding.part,
                coding.spec.clone(),
                &coding.cm,
                coding.workers,
                &req.a,
                rng,
            )
        })?;
        let delays: Option<Vec<f64>> = coding.latency.as_ref().map(|m| {
            let omega = coding.omega();
            (0..enc.workers()).map(|_| m.sample_scaled(omega, rng)).collect()
        });
        let b_blocks = coding.part.split_b(&req.b);
        // cache hits hand out Arc handles: no W_A deep copy per request
        let jobs: Vec<(Arc<Matrix>, Arc<Matrix>)> = (0..enc.workers())
            .map(|w| (Arc::clone(&enc.wa[w]), Arc::new(enc.job_b(&b_blocks, w))))
            .collect();
        let core = self.serve_jobs(
            &enc.space,
            &enc.packets,
            jobs,
            delays.as_deref(),
            req.t_max,
            None,
        )?;
        let outcome = if req.score {
            let c_true = matmul(&req.a, &req.b);
            score_outcome(&coding.part, &coding.cm, &c_true, &core.st, core.received)
        } else {
            assemble_outcome(&coding.part, &coding.cm, &core.st, core.received)
        };
        Ok(ClusterOutcome {
            outcome,
            late: core.late,
            dispatched: core.dispatched,
            retries: core.retries,
            corrupt: core.corrupt,
            verify_failures: core.verify_failures,
            wall: core.wall,
            cache_hit: Some(hit),
        })
    }

    /// Dispatch + collect + decode for one prepared job set — the core
    /// every higher-level entry point ([`Self::serve_plan`],
    /// [`Self::serve_request`], and the client API's cluster backends)
    /// shares. `observe` is called once per absorbed in-deadline result
    /// in absorption order, which is what feeds the anytime progress
    /// stream.
    ///
    /// The job table (`jobs` plus per-slot attempt counters) retains
    /// every dispatched payload until its result lands: a worker death
    /// requeues its unresolved slots onto survivors (bounded by
    /// [`ClusterConfig::max_job_retries`]), so a failure costs latency
    /// instead of losing the work.
    pub fn serve_jobs(
        &mut self,
        space: &UnknownSpace,
        packets: &[Packet],
        jobs: Vec<(Arc<Matrix>, Arc<Matrix>)>,
        delays: Option<&[f64]>,
        t_max: f64,
        mut observe: Option<&mut dyn FnMut(DecodeStep)>,
    ) -> Result<ServedDecode> {
        anyhow::ensure!(
            self.live_workers() > 0,
            "no live workers registered with the coordinator"
        );
        anyhow::ensure!(jobs.len() == packets.len(), "one job per packet");
        if let Some(d) = delays {
            anyhow::ensure!(d.len() == jobs.len(), "one injected delay per job");
        }
        if self.cfg.deadline == DeadlineMode::Wall {
            anyhow::ensure!(
                self.cfg.time_scale > 0.0,
                "Wall deadline mode needs time_scale > 0"
            );
        }
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        // Freivalds verifier: probes come from a stream keyed by
        // (verify_seed, request_id), disjoint from every other draw, so
        // toggling verification never shifts an honest run
        let verifier = if self.cfg.verify {
            let mut vrng = Pcg64::with_stream(self.cfg.verify_seed, request_id);
            Some(Verifier::new(&jobs, &mut vrng))
        } else {
            None
        };
        // in-flight tracking is per request
        for w in &mut self.workers {
            w.in_flight.clear();
        }
        let start = Instant::now(); // lint:allow(no-wallclock-in-deterministic-paths) wall telemetry + Wall-mode pacing base; Virtual decode ignores it
        let pace = self.cfg.time_scale;
        let n = jobs.len();
        let mut ctx = Collect::new(request_id, n);
        let mut attempts: Vec<u32> = vec![0; n];
        let mut dispatched = 0usize;
        let mut retries = 0usize;

        // ---- dispatch ----------------------------------------------------
        // Heterogeneity-aware when configured and a scale source has
        // data (fitted offsets pushed by the client, else the straggle
        // EWMA): plan the whole slot→worker map up front, slower
        // workers getting fewer and less-critical slots. Falls back to
        // least-outstanding with failover otherwise — and per slot
        // whenever a planned worker is dead.
        let plan = if self.cfg.hetero_assign {
            self.assignment_scales().and_then(|scales| {
                let windows: Vec<usize> =
                    packets.iter().map(|p| p.window).collect();
                Assignment::plan(&windows, &scales)
            })
        } else {
            None
        };
        let order: Vec<(u32, Option<u64>)> = match &plan {
            Some(a) => {
                a.dispatch_order().iter().map(|&(s, w)| (s, Some(w))).collect()
            }
            None => (0..n as u32).map(|s| (s, None)).collect(),
        };
        for (slot, target) in order {
            let sent = self.dispatch_job(
                request_id,
                slot,
                0,
                &jobs[slot as usize],
                delays,
                t_max,
                target,
                &mut ctx,
            )?;
            if sent {
                attempts[slot as usize] = 1;
                dispatched += 1;
                ctx.outstanding += 1;
            } else {
                // every worker died mid-dispatch; whatever already went
                // out may still decode something
                break;
            }
        }

        // ---- collect -----------------------------------------------------
        // Each round first flushes the requeue (slots stranded on workers
        // that died during dispatch or the previous poll are re-sent to
        // survivors), then polls every worker with work in flight.
        let mut st = DecodeState::new(space.clone());
        let mut received = 0usize;
        let mut late = 0usize;
        let mut timings: Vec<JobTiming> = Vec::new();
        match self.cfg.deadline {
            DeadlineMode::Virtual => {
                // deterministic: gather everything, then absorb in
                // (delay, slot) order and apply the virtual deadline
                let hard = start + self.cfg.collect_timeout;
                let mut results: Vec<(u64, ResultMsg)> =
                    Vec::with_capacity(ctx.outstanding);
                let mut last_progress = Instant::now(); // lint:allow(no-wallclock-in-deterministic-paths) stall hang-guard; Virtual absorb order is (delay, slot)
                loop {
                    let flushed = self.flush_requeue(
                        &mut ctx,
                        &mut attempts,
                        &jobs,
                        delays,
                        t_max,
                    )?;
                    retries += flushed;
                    if ctx.outstanding == 0 || Instant::now() >= hard { // lint:allow(no-wallclock-in-deterministic-paths) collect hang-guard only
                        break;
                    }
                    let before = results.len();
                    let polled = self.poll_round(
                        &mut ctx,
                        verifier.as_ref(),
                        &mut |w, r| results.push((w, r)),
                    );
                    if polled == 0 && ctx.requeue.is_empty() {
                        break; // nothing left that could deliver
                    }
                    if results.len() > before || flushed > 0 || !ctx.requeue.is_empty()
                    {
                        last_progress = Instant::now(); // lint:allow(no-wallclock-in-deterministic-paths) stall clock; drives recovery, not decode order
                    } else if last_progress.elapsed() >= self.cfg.stall_timeout {
                        // nothing moved for the stall window: a result
                        // frame may have been dropped on a lossy channel,
                        // so respin every unresolved slot (bounded by the
                        // per-slot retry budget; duplicates absorb once)
                        self.requeue_stalled(&mut ctx);
                        last_progress = Instant::now(); // lint:allow(no-wallclock-in-deterministic-paths) stall clock; drives recovery, not decode order
                    }
                }
                results.sort_by(|x, y| {
                    x.1.delay.total_cmp(&y.1.delay).then(x.1.slot.cmp(&y.1.slot))
                });
                for (worker, r) in results {
                    // accept_frame guarantees in-range, deduplicated slots
                    let is_late = r.delay > t_max;
                    timings.push(JobTiming {
                        slot: r.slot,
                        worker,
                        attempt: r.attempt,
                        delay: r.delay,
                        compute_secs: r.compute_secs,
                        late: is_late,
                    });
                    if !is_late {
                        let newly =
                            st.add_packet(&packets[r.slot as usize], Some(r.payload));
                        received += 1;
                        if let Some(obs) = observe.as_mut() {
                            obs(DecodeStep {
                                delay: r.delay,
                                attempt: r.attempt,
                                received,
                                recovered: st.num_recovered(),
                                newly,
                            });
                        }
                    } else {
                        late += 1;
                    }
                }
            }
            DeadlineMode::Wall => {
                // the paper's protocol: decode whatever arrives by the
                // wall deadline, cut off the rest. The deadline gate
                // sits *before* the requeue flush: a slot stranded by a
                // death detected in the final poll is never re-sent
                // past the deadline (it could not land in time anyway).
                let deadline = start + Duration::from_secs_f64(t_max * pace);
                loop {
                    if ctx.outstanding == 0 || Instant::now() >= deadline { // lint:allow(no-wallclock-in-deterministic-paths) Wall mode is wall-clock by definition
                        break;
                    }
                    retries += self.flush_requeue(
                        &mut ctx,
                        &mut attempts,
                        &jobs,
                        delays,
                        t_max,
                    )?;
                    if ctx.outstanding == 0 {
                        break; // write-offs may have settled the rest
                    }
                    let polled = self.poll_round(
                        &mut ctx,
                        verifier.as_ref(),
                        &mut |worker, r| {
                        timings.push(JobTiming {
                            slot: r.slot,
                            worker,
                            attempt: r.attempt,
                            delay: r.delay,
                            compute_secs: r.compute_secs,
                            late: false,
                        });
                        let newly =
                            st.add_packet(&packets[r.slot as usize], Some(r.payload));
                        received += 1;
                        if let Some(obs) = observe.as_mut() {
                            obs(DecodeStep {
                                delay: r.delay,
                                attempt: r.attempt,
                                received,
                                recovered: st.num_recovered(),
                                newly,
                            });
                        }
                    });
                    if polled == 0 && ctx.requeue.is_empty() {
                        break; // nothing left that could deliver
                    }
                }
                // past the deadline a re-dispatch could never land in
                // time: write the queue off instead of resending
                ctx.write_off_queued();
                // grace drain: count (and discard) stragglers so they do
                // not pollute the next request's collection
                let grace = Instant::now() + self.cfg.late_drain; // lint:allow(no-wallclock-in-deterministic-paths) late-drain grace window only
                while ctx.outstanding > 0 && Instant::now() < grace { // lint:allow(no-wallclock-in-deterministic-paths) late-drain grace window only
                    let polled = self.poll_round(
                        &mut ctx,
                        verifier.as_ref(),
                        &mut |worker, r| {
                        timings.push(JobTiming {
                            slot: r.slot,
                            worker,
                            attempt: r.attempt,
                            delay: r.delay,
                            compute_secs: r.compute_secs,
                            late: true,
                        });
                        late += 1;
                    });
                    ctx.write_off_queued(); // deaths during the drain
                    if polled == 0 {
                        break;
                    }
                }
            }
        }
        Ok(ServedDecode {
            st,
            received,
            late,
            dispatched,
            retries,
            corrupt: ctx.corrupt,
            verify_failures: ctx.verify_failures,
            attempts,
            timings,
            worker_packets: Vec::new(),
            partial_packets: 0,
            wall: start.elapsed(),
        })
    }

    /// Serve one rateless request (protocol v5): every live worker gets
    /// an open-ended packet stream keyed by `(request_id, stream, seq)`,
    /// the coordinator derives the identical coefficient rows from the
    /// plan's [`RatelessCoder`], and decoding stops the streams with a
    /// [`Msg::Drain`] the moment the unknowns are determined.
    ///
    /// * [`DeadlineMode::Virtual`] — `delays` is required: one cumulative
    ///   (non-decreasing) per-packet arrival schedule per live worker.
    ///   The coordinator first replays the k-way merge of those schedules
    ///   through a coefficient-only decode to find the exact packet set
    ///   the deadline admits, dispatches precisely those budgets, heals
    ///   losses with [`Msg::Redo`] (any worker holding the request
    ///   context can regenerate any `(stream, seq)`), and finally absorbs
    ///   payloads in schedule order — bit-identical across reruns, worker
    ///   thread counts, chaos, and verify on/off.
    /// * [`DeadlineMode::Wall`] — workers stream under a generous budget
    ///   until the decode completes or the wall deadline passes; whatever
    ///   physically arrives in time is absorbed in arrival order.
    ///
    /// The returned [`ServedDecode`] carries rateless partial credit:
    /// [`ServedDecode::worker_packets`] and
    /// [`ServedDecode::partial_packets`]. `dispatched` counts *packets*
    /// (the virtual schedule's size; in `Wall` mode the packets actually
    /// classified), not streams, so the
    /// `received + late + missing == dispatched` balance holds per
    /// packet.
    pub fn serve_rateless(
        &mut self,
        plan: &RatelessPlan,
        t_max: f64,
        delays: Option<&[Vec<f64>]>,
        mut observe: Option<&mut dyn FnMut(DecodeStep)>,
    ) -> Result<ServedDecode> {
        anyhow::ensure!(
            self.live_workers() > 0,
            "no live workers registered with the coordinator"
        );
        if self.cfg.deadline == DeadlineMode::Wall {
            anyhow::ensure!(
                self.cfg.time_scale > 0.0,
                "Wall deadline mode needs time_scale > 0"
            );
        }
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        let verifier = if self.cfg.verify {
            let mut vrng = Pcg64::with_stream(self.cfg.verify_seed, request_id);
            Some(RatelessVerifier::new(plan, &mut vrng))
        } else {
            None
        };
        for w in &mut self.workers {
            w.in_flight.clear();
        }
        let start = Instant::now(); // lint:allow(no-wallclock-in-deterministic-paths) wall telemetry + Wall-mode pacing base; Virtual decode ignores it
        let pace = self.cfg.time_scale;
        let live: Vec<usize> = (0..self.workers.len())
            .filter(|&wi| self.workers[wi].alive)
            .collect();
        let owners: Vec<u64> = live.iter().map(|&wi| self.workers[wi].id).collect();

        // ---- budgets (+ the deterministic schedule in Virtual mode) ----
        let (budgets, schedule) = match self.cfg.deadline {
            DeadlineMode::Virtual => {
                let d = delays.ok_or_else(|| {
                    anyhow::anyhow!(
                        "Virtual-mode rateless serving needs one injected \
                         per-packet delay schedule per live worker"
                    )
                })?;
                anyhow::ensure!(
                    d.len() == live.len(),
                    "one delay schedule per live worker ({} workers, {} schedules)",
                    live.len(),
                    d.len()
                );
                for s in d {
                    anyhow::ensure!(
                        s.windows(2).all(|w| w[0] <= w[1]),
                        "per-packet delay schedules must be non-decreasing"
                    );
                }
                rateless_schedule(plan, request_id, d, t_max)
            }
            DeadlineMode::Wall => {
                // generous per-stream budget: any single worker could
                // carry the whole decode alone (robust-Soliton overhead
                // is K + O(√K·ln²) ≪ 2K), with slack for strikes
                let k = plan.num_unknowns() as u32;
                (vec![2 * k + 16; live.len()], Vec::new())
            }
        };

        // ---- dispatch one stream context to every live worker ----------
        // A worker whose schedule needs no packets still gets the context
        // (budget 0): it can then serve Redo frames for other streams.
        let mut retries = 0usize;
        for (s, &wi) in live.iter().enumerate() {
            let stream_delays = match (self.cfg.deadline, delays) {
                (DeadlineMode::Virtual, Some(d)) => {
                    d[s][..(budgets[s] as usize).min(d[s].len())].to_vec()
                }
                _ => Vec::new(),
            };
            let rj = Msg::RatelessJob(RatelessJobMsg {
                request_id,
                stream: s as u64,
                budget: budgets[s],
                delta: plan.spec.delta,
                c: plan.spec.c,
                gamma: plan.spec.gamma.probs().to_vec(),
                class_of: plan.class_of(),
                factors: plan.factors(),
                delays: stream_delays,
                t_max,
                pace,
                a_blocks: plan.a_blocks.clone(),
                b_blocks: plan.b_blocks.clone(),
            });
            match self.workers[wi].conn.send(&rj) {
                Ok(()) => {}
                Err(e @ (WireError::Oversize { .. } | WireError::Oversized { .. })) => {
                    anyhow::bail!("rateless job for stream {s} cannot be encoded: {e}")
                }
                Err(_) => self.workers[wi].alive = false,
            }
        }
        anyhow::ensure!(
            self.live_workers() > 0,
            "every worker died while dispatching the rateless job"
        );

        let mut rc = RatelessCollect::new(request_id, &budgets);
        let mut st = DecodeState::new(plan.space.clone());
        let mut received = 0usize;
        let mut late = 0usize;
        let mut timings: Vec<JobTiming> = Vec::new();
        let dispatched;
        match self.cfg.deadline {
            DeadlineMode::Virtual => {
                dispatched = schedule.len();
                let hard = start + self.cfg.collect_timeout;
                let mut last_progress = Instant::now(); // lint:allow(no-wallclock-in-deterministic-paths) stall hang-guard; Virtual absorb uses schedule order
                while rc.outstanding > 0 && Instant::now() < hard { // lint:allow(no-wallclock-in-deterministic-paths) collect hang-guard only
                    let progressed =
                        self.rateless_poll(&mut rc, plan, verifier.as_ref(), &budgets);
                    let sent = self.redo_flagged(&mut rc);
                    retries += sent;
                    if progressed || sent > 0 {
                        last_progress = Instant::now(); // lint:allow(no-wallclock-in-deterministic-paths) stall clock; drives recovery, not decode order
                    } else if self.live_workers() == 0 {
                        break; // nothing outstanding can ever arrive
                    } else if last_progress.elapsed() >= self.cfg.stall_timeout {
                        // nothing moved for the stall window: a frame may
                        // have been dropped on a lossy channel — flag every
                        // missing packet for regeneration (bounded by the
                        // per-packet retry budget; duplicates absorb once)
                        rc.flag_all_missing();
                        last_progress = Instant::now(); // lint:allow(no-wallclock-in-deterministic-paths) stall clock; drives recovery, not decode order
                    }
                }
                // stop the streams and drop the worker-side contexts
                self.drain_rateless(request_id);
                // deterministic absorb: schedule order, schedule times
                for &(t, s, k) in &schedule {
                    let sl = &mut rc.slots[s][k as usize];
                    let Some(payload) = sl.payload.take() else { continue };
                    let pkt = plan.packet(request_id, s as u64, k);
                    let newly = st.add_packet(&pkt, Some(payload));
                    sl.absorbed = true;
                    received += 1;
                    timings.push(JobTiming {
                        slot: k,
                        worker: sl.src,
                        attempt: sl.redos,
                        delay: t,
                        compute_secs: sl.compute_secs,
                        late: false,
                    });
                    if let Some(obs) = observe.as_mut() {
                        obs(DecodeStep {
                            delay: t,
                            attempt: sl.redos,
                            received,
                            recovered: st.num_recovered(),
                            newly,
                        });
                    }
                }
            }
            DeadlineMode::Wall => {
                let deadline = start + Duration::from_secs_f64(t_max * pace);
                while !st.is_complete() && Instant::now() < deadline { // lint:allow(no-wallclock-in-deterministic-paths) Wall mode is wall-clock by definition
                    let progressed =
                        self.rateless_poll(&mut rc, plan, verifier.as_ref(), &budgets);
                    // absorb whatever this round delivered, in stream order
                    for s in 0..rc.slots.len() {
                        for k in 0..rc.slots[s].len() {
                            let sl = &mut rc.slots[s][k];
                            let Some(payload) = sl.payload.take() else { continue };
                            let pkt = plan.packet(request_id, s as u64, k as u32);
                            let newly = st.add_packet(&pkt, Some(payload));
                            sl.absorbed = true;
                            received += 1;
                            timings.push(JobTiming {
                                slot: k as u32,
                                worker: sl.src,
                                attempt: sl.redos,
                                delay: sl.delay,
                                compute_secs: sl.compute_secs,
                                late: false,
                            });
                            if let Some(obs) = observe.as_mut() {
                                obs(DecodeStep {
                                    delay: sl.delay,
                                    attempt: sl.redos,
                                    received,
                                    recovered: st.num_recovered(),
                                    newly,
                                });
                            }
                        }
                    }
                    if !progressed && self.live_workers() == 0 {
                        break;
                    }
                }
                self.drain_rateless(request_id);
                // grace drain: count (and discard) in-flight stragglers so
                // they do not pollute the next request's collection
                let grace = Instant::now() + self.cfg.late_drain; // lint:allow(no-wallclock-in-deterministic-paths) late-drain grace window only
                while Instant::now() < grace { // lint:allow(no-wallclock-in-deterministic-paths) late-drain grace window only
                    let mut got = false;
                    for wi in 0..self.workers.len() {
                        if !self.workers[wi].alive {
                            continue;
                        }
                        match self.workers[wi].conn.recv_timeout(Some(POLL_SLICE)) {
                            Ok(Some(Msg::RatelessResult(r)))
                                if r.request_id == request_id =>
                            {
                                late += 1;
                                got = true;
                            }
                            Ok(Some(_)) | Ok(None) => {}
                            Err(WireError::BadChecksum { .. }) => {}
                            Err(_) => self.workers[wi].alive = false,
                        }
                    }
                    if !got {
                        break;
                    }
                }
                dispatched = received + late;
            }
        }
        // partial credit: packets absorbed into the decode, by deliverer
        let mut worker_packets: Vec<(u64, usize)> =
            owners.iter().map(|&id| (id, 0)).collect();
        for stream in &rc.slots {
            for sl in stream {
                if !sl.absorbed {
                    continue;
                }
                match worker_packets.iter_mut().find(|e| e.0 == sl.src) {
                    Some(e) => e.1 += 1,
                    None => worker_packets.push((sl.src, 1)),
                }
            }
        }
        let partial_packets = live
            .iter()
            .enumerate()
            .filter(|&(s, _)| budgets[s] > 0)
            .map(|(s, _)| {
                worker_packets
                    .iter()
                    .find(|e| e.0 == owners[s])
                    .map_or(0, |e| e.1)
            })
            .min()
            .unwrap_or(0);
        Ok(ServedDecode {
            st,
            received,
            late,
            dispatched,
            retries,
            corrupt: rc.corrupt,
            verify_failures: rc.verify_failures,
            attempts: Vec::new(),
            timings,
            worker_packets,
            partial_packets,
            wall: start.elapsed(),
        })
    }

    // ------------------------------------------------------------ internals

    /// The live worker dispatch prefers: fewest jobs in flight, then the
    /// lowest EWMA straggle score, then the lowest registry index (which
    /// keeps selection deterministic). A worker with no history scores
    /// 0 — new capacity gets work immediately.
    fn pick_worker(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (wi, w) in self.workers.iter().enumerate() {
            if !w.alive {
                continue;
            }
            best = match best {
                None => Some(wi),
                Some(b) => {
                    let cur = (
                        self.workers[b].in_flight.len(),
                        self.workers[b].straggle.unwrap_or(0.0),
                    );
                    let cand = (w.in_flight.len(), w.straggle.unwrap_or(0.0));
                    if cand.0 < cur.0 || (cand.0 == cur.0 && cand.1 < cur.1) {
                        Some(wi)
                    } else {
                        Some(b)
                    }
                }
            };
        }
        best
    }

    /// The scale map [`ClusterConfig::hetero_assign`] plans on, covering
    /// every live worker: client-pushed fitted offsets win (workers the
    /// fit does not cover run at 1.0 = fleet mean); otherwise the
    /// per-worker straggle EWMA, normalized by the live fleet's mean so
    /// it lands in the same 1.0-centered units. `None` when neither
    /// source has any data — dispatch then stays least-outstanding.
    fn assignment_scales(&self) -> Option<Vec<(u64, f64)>> {
        let live: Vec<&WorkerSlot> =
            self.workers.iter().filter(|w| w.alive).collect();
        if live.is_empty() {
            return None;
        }
        if !self.fitted_scales.is_empty() {
            return Some(
                live.iter()
                    .map(|w| {
                        (w.id, self.fitted_scales.get(&w.id).copied().unwrap_or(1.0))
                    })
                    .collect(),
            );
        }
        let scores: Vec<f64> = live
            .iter()
            .filter_map(|w| w.straggle)
            .filter(|s| s.is_finite() && *s > 0.0)
            .collect();
        let mean = scores.iter().sum::<f64>() / scores.len().max(1) as f64;
        if !(mean > 0.0) {
            return None;
        }
        Some(
            live.iter()
                .map(|w| {
                    let s = w
                        .straggle
                        .filter(|s| s.is_finite() && *s > 0.0)
                        .map_or(1.0, |s| s / mean);
                    (w.id, s)
                })
                .collect(),
        )
    }

    /// Hand one (re-)dispatch of `slot` to a worker. When `target`
    /// names a live worker (a heterogeneity plan from
    /// [`Assignment::plan`]) the job goes there; a dead, quarantined,
    /// or vanished target falls through to least-outstanding (the rest
    /// of the plan still stands — only the orphaned slots re-spread).
    /// The worker is chosen *before* the wire message is built so the
    /// holder's [`Self::set_straggle_injection`] multiplier can scale
    /// the slot's injected delay. Send errors fail over: the failed
    /// worker is marked dead, its in-flight slots requeue, and the pick
    /// repeats. Returns `false` when no live worker could take the job;
    /// `Err` only for a job no worker can ever accept (its payload does
    /// not fit the wire format).
    #[allow(clippy::too_many_arguments)]
    fn dispatch_job(
        &mut self,
        request_id: u64,
        slot: u32,
        attempt: u32,
        job: &(Arc<Matrix>, Arc<Matrix>),
        delays: Option<&[f64]>,
        t_max: f64,
        target: Option<u64>,
        ctx: &mut Collect,
    ) -> Result<bool> {
        let mut target = target;
        loop {
            let wi = match target
                .take()
                .and_then(|id| {
                    self.workers.iter().position(|w| w.alive && w.id == id)
                })
                .or_else(|| self.pick_worker())
            {
                Some(wi) => wi,
                None => return Ok(false),
            };
            let injection = self
                .straggle_injection
                .get(&self.workers[wi].id)
                .copied()
                .unwrap_or(1.0);
            let msg = job_msg(
                request_id,
                slot,
                attempt,
                job,
                delays,
                t_max,
                self.cfg.time_scale,
                injection,
            );
            match self.workers[wi].conn.send(&msg) {
                Ok(()) => {
                    self.workers[wi].in_flight.push(slot);
                    return Ok(true);
                }
                Err(e @ (WireError::Oversize { .. } | WireError::Oversized { .. })) => {
                    anyhow::bail!("job for slot {slot} cannot be encoded: {e}")
                }
                Err(_) => self.kill_worker(wi, ctx),
            }
        }
    }

    /// Re-dispatch requeued slots onto surviving workers. A slot whose
    /// retry budget is exhausted — or that no live worker can take — is
    /// written off (it surfaces as `missing`). Returns how many
    /// re-dispatch sends went out.
    fn flush_requeue(
        &mut self,
        ctx: &mut Collect,
        attempts: &mut [u32],
        jobs: &[(Arc<Matrix>, Arc<Matrix>)],
        delays: Option<&[f64]>,
        t_max: f64,
    ) -> Result<usize> {
        let mut sent = 0usize;
        while let Some(slot) = ctx.requeue.pop() {
            let s = slot as usize;
            if ctx.settled[s] {
                continue; // its result landed before the worker died
            }
            if attempts[s] as usize > self.cfg.max_job_retries {
                // retry budget exhausted: written off, counts as missing
                ctx.settled[s] = true;
                ctx.outstanding -= 1;
                continue;
            }
            if self.dispatch_job(
                ctx.request_id,
                slot,
                attempts[s],
                &jobs[s],
                delays,
                t_max,
                None,
                ctx,
            )? {
                attempts[s] += 1;
                sent += 1;
            } else {
                ctx.settled[s] = true;
                ctx.outstanding -= 1;
            }
        }
        Ok(sent)
    }

    /// One poll pass: drain every worker's inbox (frames buffered by a
    /// heartbeat are real data even if the worker has since died), then
    /// read one frame from each live worker with work in flight. Worker
    /// deaths requeue their unresolved slots into `ctx.requeue` for the
    /// caller's next [`Self::flush_requeue`]. Accepted results reach
    /// `on_result` with the delivering worker's registry id (timing
    /// attribution). Returns how many workers were pollable — 0 with an
    /// empty requeue means nothing outstanding can ever arrive.
    /// The worker indices one [`Self::poll_round`] pass visits, in
    /// order: all of `0..workers`, but *starting* at a rotor that
    /// advances by one per call. A fixed registry-order scan would let
    /// a chatty early worker's `recv_timeout` slice systematically
    /// delay the inbox drains of later workers (each pass spends up to
    /// `POLL_SLICE` per pollable worker before reaching the next);
    /// rotating the start index makes every worker first-in-line
    /// equally often. Results themselves are absorbed
    /// order-independently (Virtual mode sorts by delay before
    /// applying the deadline), so rotation changes *latency
    /// fairness*, never outcomes.
    fn poll_order(&mut self) -> Vec<usize> {
        let n = self.workers.len();
        if n == 0 {
            return Vec::new();
        }
        let start = self.poll_rotor % n;
        self.poll_rotor = self.poll_rotor.wrapping_add(1);
        (0..n).map(|i| (start + i) % n).collect()
    }

    fn poll_round(
        &mut self,
        ctx: &mut Collect,
        verifier: Option<&Verifier>,
        on_result: &mut dyn FnMut(u64, ResultMsg),
    ) -> usize {
        let mut pollable = 0;
        for wi in self.poll_order() {
            while let Some(r) = self.workers[wi].inbox.pop_front() {
                self.accept_frame(wi, r, ctx, verifier, on_result);
            }
            if !self.workers[wi].alive || self.workers[wi].in_flight.is_empty() {
                continue;
            }
            pollable += 1;
            match self.workers[wi].conn.recv_timeout(Some(POLL_SLICE)) {
                Ok(Some(Msg::Result(r))) => {
                    self.accept_frame(wi, r, ctx, verifier, on_result)
                }
                Ok(Some(Msg::HeartbeatAck { .. })) => {}
                // a rateless frame here is a straggler from an earlier
                // rateless request on the same stream: stale, not a
                // protocol violation
                Ok(Some(Msg::RatelessResult(_))) => {}
                Ok(Some(_)) => {
                    // protocol violation: only workers speak here
                    self.kill_worker(wi, ctx);
                }
                Ok(None) => {}
                // a checksum-damaged frame is a channel fault, not a
                // worker fault: the connection resynced past it, but the
                // lost frame may have carried a result — requeue the
                // worker's unresolved slots (it keeps them in flight; if
                // the damaged frame was something else, the eventual
                // honest results absorb and the requeued duplicates are
                // dropped by the settled guard)
                Err(WireError::BadChecksum { .. }) => {
                    ctx.corrupt += 1;
                    let held = self.workers[wi].in_flight.clone();
                    for slot in held {
                        if !ctx.settled[slot as usize] && !ctx.requeue.contains(&slot)
                        {
                            ctx.requeue.push(slot);
                        }
                    }
                }
                Err(_) => self.kill_worker(wi, ctx),
            }
        }
        pollable
    }

    /// Classify one result frame from worker `wi`:
    /// * stale (another request) — dropped quietly;
    /// * corrupt slot (outside the packet set, or an unsettled slot the
    ///   sender was never dispatched) — counted, and the sender is
    ///   evicted as broken (its in-flight work requeues);
    /// * duplicate (slot already settled) — absorbed exactly once, the
    ///   extra frame is dropped without touching the accounting;
    /// * failed Freivalds check (tampered or miscomputed payload) — the
    ///   sender is struck (quarantined past
    ///   [`ClusterConfig::max_verify_failures`]) and the slot requeues;
    /// * otherwise — the slot settles, the worker's books update, and
    ///   the frame is handed to the caller.
    fn accept_frame(
        &mut self,
        wi: usize,
        r: ResultMsg,
        ctx: &mut Collect,
        verifier: Option<&Verifier>,
        on_result: &mut dyn FnMut(u64, ResultMsg),
    ) {
        if r.request_id != ctx.request_id {
            return; // straggler from an earlier request: drop
        }
        let slot = r.slot as usize;
        if slot < ctx.n_slots && ctx.settled[slot] {
            return; // duplicate (an earlier attempt already landed)
        }
        // a result only settles a slot the sender actually holds: a
        // frame naming a slot outside the packet set — or one this
        // worker was never dispatched (it would absorb a foreign
        // payload into the wrong packet, and could underflow
        // `outstanding` for a never-dispatched slot) — marks the
        // sender broken
        let held = self.workers[wi].in_flight.iter().position(|&s| s == r.slot);
        let Some(pos) = held else {
            ctx.corrupt += 1;
            self.kill_worker(wi, ctx);
            return;
        };
        // Freivalds gate: the worker definitively answered this slot
        // (drop it from in-flight either way), but a payload that is
        // not W_A·W_B never settles the slot — it requeues, and the
        // sender accumulates a strike
        if let Some(v) = verifier {
            if !v.check(slot, &r.payload) {
                ctx.verify_failures += 1;
                self.workers[wi].in_flight.swap_remove(pos);
                self.workers[wi].verify_failures += 1;
                if !ctx.requeue.contains(&r.slot) {
                    ctx.requeue.push(r.slot);
                }
                if self.workers[wi].verify_failures > self.cfg.max_verify_failures {
                    self.quarantine(wi, ctx);
                }
                return;
            }
        }
        ctx.settled[slot] = true;
        ctx.outstanding -= 1;
        let w = &mut self.workers[wi];
        w.in_flight.swap_remove(pos);
        w.jobs_done += 1;
        w.note_result_delay(r.delay);
        on_result(w.id, r);
    }

    /// Mark worker `wi` dead and requeue its unresolved in-flight slots.
    fn kill_worker(&mut self, wi: usize, ctx: &mut Collect) {
        self.workers[wi].alive = false;
        let stranded = std::mem::take(&mut self.workers[wi].in_flight);
        for slot in stranded {
            if !ctx.settled[slot as usize] {
                ctx.requeue.push(slot);
            }
        }
    }

    /// Evict worker `wi` *and* bar its agent name from rejoin: the
    /// Byzantine response. Lifted only by [`Self::reset_quarantine`].
    fn quarantine(&mut self, wi: usize, ctx: &mut Collect) {
        self.workers[wi].quarantined = true;
        self.kill_worker(wi, ctx);
    }

    /// One rateless poll pass: drain every worker's rateless inbox, then
    /// read one frame from each live worker. Unlike the fixed-rate
    /// [`Self::poll_round`], *every* live worker is polled — a stream
    /// context lives on each of them, and a Redo reply may come from a
    /// worker other than the stream's owner. Returns whether any frame
    /// was classified (the stall-clock signal).
    fn rateless_poll(
        &mut self,
        rc: &mut RatelessCollect,
        plan: &RatelessPlan,
        verifier: Option<&RatelessVerifier>,
        budgets: &[u32],
    ) -> bool {
        let mut progressed = false;
        for wi in 0..self.workers.len() {
            while let Some(r) = self.workers[wi].rateless_inbox.pop_front() {
                progressed |=
                    self.accept_rateless(wi, r, rc, plan, verifier, budgets);
            }
            if !self.workers[wi].alive {
                continue;
            }
            match self.workers[wi].conn.recv_timeout(Some(POLL_SLICE)) {
                Ok(Some(Msg::RatelessResult(r))) => {
                    progressed |=
                        self.accept_rateless(wi, r, rc, plan, verifier, budgets);
                }
                Ok(Some(Msg::HeartbeatAck { .. })) => {}
                // a fixed-rate result here is a straggler from an earlier
                // request: buffer it for the fixed-rate classifier, which
                // drops it once provably stale
                Ok(Some(Msg::Result(r))) => self.workers[wi].inbox.push_back(r),
                Ok(Some(_)) => self.workers[wi].alive = false,
                Ok(None) => {}
                Err(WireError::BadChecksum { .. }) => rc.corrupt += 1,
                Err(_) => self.workers[wi].alive = false,
            }
        }
        progressed
    }

    /// Classify one rateless result frame from worker `wi`. Returns
    /// whether the frame belonged to this request (progress for the
    /// stall clock), regardless of whether it was ultimately stored.
    fn accept_rateless(
        &mut self,
        wi: usize,
        r: RatelessResultMsg,
        rc: &mut RatelessCollect,
        plan: &RatelessPlan,
        verifier: Option<&RatelessVerifier>,
        budgets: &[u32],
    ) -> bool {
        if r.request_id != rc.request_id {
            return false; // straggler from an earlier request: drop
        }
        let s = r.stream as usize;
        if s >= budgets.len() || r.seq >= budgets[s] {
            // outside the dispatched stream/budget space: a broken sender
            rc.corrupt += 1;
            self.workers[wi].alive = false;
            return false;
        }
        // end of stream: the owner sends nothing more on its own, so any
        // still-missing packet of this stream must come via Redo
        if !r.more && !rc.eos[s] {
            rc.eos[s] = true;
            for sl in &mut rc.slots[s] {
                if sl.payload.is_none() && !sl.absorbed && !sl.written_off {
                    sl.redo_now = true;
                }
            }
        }
        let k = r.seq as usize;
        {
            let sl = &rc.slots[s][k];
            if sl.payload.is_some() || sl.absorbed || sl.written_off {
                return true; // duplicate (a redo raced the original)
            }
        }
        // Freivalds gate: a payload that is not the packet's coefficient
        // combination never lands — it is flagged for regeneration and
        // the sender accumulates a strike
        if let Some(v) = verifier {
            let pkt = plan.packet(rc.request_id, r.stream, r.seq);
            let JobRecipe::Stacked { terms } = &pkt.recipe else {
                // every rateless coder emits stacked recipes today; if
                // that ever changes, treat the packet as corrupt and
                // regenerate it rather than panicking the serve loop
                rc.corrupt += 1;
                rc.slots[s][k].redo_now = true;
                return true;
            };
            if !v.check(terms, &r.payload) {
                rc.verify_failures += 1;
                rc.slots[s][k].redo_now = true;
                self.workers[wi].verify_failures += 1;
                if self.workers[wi].verify_failures > self.cfg.max_verify_failures {
                    self.workers[wi].quarantined = true;
                    self.workers[wi].alive = false;
                }
                return true; // the lie still resets the stall clock
            }
        }
        let sl = &mut rc.slots[s][k];
        sl.payload = Some(r.payload);
        sl.src = self.workers[wi].id;
        sl.compute_secs = r.compute_secs;
        sl.delay = r.delay;
        sl.redo_now = false;
        rc.outstanding -= 1;
        let w = &mut self.workers[wi];
        w.jobs_done += 1;
        w.note_result_delay(r.delay);
        true
    }

    /// Send a [`Msg::Redo`] for every packet flagged `redo_now`, to the
    /// least-loaded live worker (any worker holding the request context
    /// can regenerate any `(stream, seq)`). A packet whose retry budget
    /// is exhausted — or that no live worker can take — is written off.
    /// Returns how many redo sends went out.
    fn redo_flagged(&mut self, rc: &mut RatelessCollect) -> usize {
        let mut sent = 0usize;
        for s in 0..rc.slots.len() {
            for k in 0..rc.slots[s].len() {
                {
                    let sl = &rc.slots[s][k];
                    if !sl.redo_now
                        || sl.payload.is_some()
                        || sl.absorbed
                        || sl.written_off
                    {
                        continue;
                    }
                }
                if rc.slots[s][k].redos as usize >= self.cfg.max_job_retries {
                    let sl = &mut rc.slots[s][k];
                    sl.written_off = true;
                    sl.redo_now = false;
                    rc.outstanding -= 1;
                    continue;
                }
                let attempt = rc.slots[s][k].redos + 1;
                let msg = Msg::Redo {
                    request_id: rc.request_id,
                    stream: s as u64,
                    seq: k as u32,
                    attempt,
                };
                loop {
                    let Some(wi) = self.pick_worker() else {
                        let sl = &mut rc.slots[s][k];
                        sl.written_off = true;
                        sl.redo_now = false;
                        rc.outstanding -= 1;
                        break;
                    };
                    match self.workers[wi].conn.send(&msg) {
                        Ok(()) => {
                            let sl = &mut rc.slots[s][k];
                            sl.redos = attempt;
                            sl.redo_now = false;
                            sent += 1;
                            break;
                        }
                        Err(_) => self.workers[wi].alive = false,
                    }
                }
            }
        }
        sent
    }

    /// Best-effort [`Msg::Drain`]: stop every live worker's stream for
    /// this request and drop their contexts.
    fn drain_rateless(&mut self, request_id: u64) {
        for w in &mut self.workers {
            if w.alive && w.conn.send(&Msg::Drain { request_id }).is_err() {
                w.alive = false;
            }
        }
    }

    /// `Virtual`-mode stall recovery: requeue every unresolved in-flight
    /// slot without killing anyone — the holder may simply have had its
    /// result frame dropped on a lossy channel. Duplicate absorption
    /// keeps an over-requeue harmless; the per-slot retry budget keeps
    /// it finite.
    fn requeue_stalled(&mut self, ctx: &mut Collect) {
        for w in &self.workers {
            for &slot in &w.in_flight {
                if !ctx.settled[slot as usize] && !ctx.requeue.contains(&slot) {
                    ctx.requeue.push(slot);
                }
            }
        }
    }
}

/// Virtual-mode planning for one rateless request: merge the per-stream
/// cumulative arrival schedules, keep the events the deadline admits,
/// and replay them through a coefficient-only decode to find the exact
/// packet set needed. Returns each stream's budget (the needed prefix
/// length — arrivals are cumulative, so the needed set of a stream is
/// always a contiguous `0..budget` prefix) and the absorb schedule in
/// deterministic `(t, stream, seq)` order.
fn rateless_schedule(
    plan: &RatelessPlan,
    request_id: u64,
    delays: &[Vec<f64>],
    t_max: f64,
) -> (Vec<u32>, Vec<(f64, usize, u32)>) {
    let mut events: Vec<(f64, usize, u32)> = Vec::new();
    for (s, sched) in delays.iter().enumerate() {
        for (k, &t) in sched.iter().enumerate() {
            if t > t_max {
                break; // cumulative ⇒ every later packet is later still
            }
            events.push((t, s, k as u32));
        }
    }
    events.sort_by(|a, b| {
        a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
    });
    // coefficient-only replay: stop at the first event that completes
    // the decode — everything after it is work nobody needs to do
    let mut st = DecodeState::new(plan.space.clone());
    let mut taken: Vec<(f64, usize, u32)> = Vec::new();
    for &(t, s, k) in &events {
        taken.push((t, s, k));
        st.add_packet(&plan.packet(request_id, s as u64, k), None);
        if st.is_complete() {
            break;
        }
    }
    let mut budgets = vec![0u32; delays.len()];
    for &(_, s, k) in &taken {
        budgets[s] = budgets[s].max(k + 1);
    }
    (budgets, taken)
}

/// Build the wire message for one (re-)dispatch of `slot`. Payloads are
/// `Arc` handles out of the job table, so this never copies a matrix.
/// `injection` is the holding worker's straggle-injection multiplier
/// (1.0 = unscaled) — applied to the slot's base injected delay so the
/// scaled value flows through pacing, the reported delay, and decode.
#[allow(clippy::too_many_arguments)]
fn job_msg(
    request_id: u64,
    slot: u32,
    attempt: u32,
    job: &(Arc<Matrix>, Arc<Matrix>),
    delays: Option<&[f64]>,
    t_max: f64,
    pace: f64,
    injection: f64,
) -> Msg {
    let injected = delays.map(|d| d[slot as usize] * injection);
    let sleep_secs = match injected {
        Some(d) if pace > 0.0 => d.min(t_max * SLEEP_CAP_FACTOR) * pace,
        _ => 0.0,
    };
    Msg::Job(JobMsg {
        request_id,
        slot,
        attempt,
        injected_delay: injected,
        sleep_secs,
        wa: Arc::clone(&job.0),
        wb: Arc::clone(&job.1),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::transport::{LoopbackDialer, LoopbackTransport};
    use crate::cluster::worker::{
        run_worker, spawn_loopback_workers, WorkerConfig, WorkerStats,
    };
    use crate::coding::CodeKind;
    use crate::coordinator::Coordinator;
    use crate::runtime::NativeEngine;
    use std::thread::JoinHandle;

    // MDS keeps full-decode assertions seed-independent: any ≥ 9
    // received packets recover all 9 sub-products.
    fn small_plan(workers: usize, seed: u64) -> Plan {
        let mut rng = Pcg64::seed_from(seed);
        let part = Partitioning::rxc(3, 3, 4, 5, 4);
        let a = Matrix::randn(12, 5, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(5, 12, 0.0, 1.0, &mut rng);
        let spec = CodeSpec::stacked(CodeKind::Mds);
        Plan::build(&part, spec, 3, workers, &a, &b, &mut rng).unwrap()
    }

    fn start_cluster(
        threads: usize,
        cfg: ClusterConfig,
    ) -> (ClusterServer, LoopbackDialer, Vec<JoinHandle<anyhow::Result<WorkerStats>>>)
    {
        let (mut transport, dialer) = LoopbackTransport::new();
        let wcfg = WorkerConfig { name: "t".to_string(), ..Default::default() };
        let handles = spawn_loopback_workers(&dialer, threads, &wcfg);
        let mut server = ClusterServer::new(cfg);
        let n = server
            .accept_workers(&mut transport, threads, Duration::from_secs(10))
            .unwrap();
        assert_eq!(n, threads);
        (server, dialer, handles)
    }

    fn finish(
        mut server: ClusterServer,
        handles: Vec<JoinHandle<anyhow::Result<WorkerStats>>>,
    ) {
        server.shutdown();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    /// Satellite (PR 8): the poll pass must not visit workers in fixed
    /// registry order every tick — the start index rotates, so each
    /// worker is first-in-line for inbox drains equally often.
    #[test]
    fn poll_order_rotates_its_starting_worker_every_tick() {
        let (mut server, _dialer, handles) =
            start_cluster(3, ClusterConfig::default());
        assert_eq!(server.poll_order(), vec![0, 1, 2]);
        assert_eq!(server.poll_order(), vec![1, 2, 0]);
        assert_eq!(server.poll_order(), vec![2, 0, 1]);
        // a full cycle returns to registry order
        assert_eq!(server.poll_order(), vec![0, 1, 2]);
        // every pass still visits every worker exactly once
        let mut seen = server.poll_order();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2]);
        finish(server, handles);
    }

    #[test]
    fn virtual_mode_is_deterministic_and_thread_count_independent() {
        let plan = small_plan(18, 7);
        let mut drng = Pcg64::seed_from(13);
        let delays: Vec<f64> = (0..18)
            .map(|_| LatencyModel::exp(1.0).sample_scaled(0.5, &mut drng))
            .collect();
        let t_max = 0.8;
        let run = |threads: usize| {
            let (mut server, _dialer, handles) =
                start_cluster(threads, ClusterConfig::default());
            let out = server.serve_plan(&plan, t_max, Some(&delays)).unwrap();
            finish(server, handles);
            out
        };
        let o1 = run(3);
        let o2 = run(5);
        assert_eq!(o1.outcome.received, o2.outcome.received);
        assert_eq!(o1.outcome.recovered, o2.outcome.recovered);
        assert_eq!(o1.late, o2.late);
        // bit-identical decode regardless of worker thread count
        assert_eq!(o1.outcome.c_hat.data(), o2.outcome.c_hat.data());
        assert_eq!(o1.outcome.loss.to_bits(), o2.outcome.loss.to_bits());

        // and it matches the virtual-time honest coordinator on the same
        // arrivals bit for bit (same serial engine, same absorb order)
        let coord = Coordinator::new(NativeEngine::serial());
        let honest = coord.run(&plan, &delays, t_max).unwrap();
        assert_eq!(honest.received, o1.outcome.received);
        assert_eq!(honest.recovered, o1.outcome.recovered);
        assert_eq!(honest.c_hat.data(), o1.outcome.c_hat.data());
    }

    #[test]
    fn late_results_are_counted_not_decoded() {
        let plan = small_plan(12, 3);
        // half the workers miss the virtual deadline by construction
        let delays: Vec<f64> =
            (0..12).map(|w| if w % 2 == 0 { 0.1 } else { 9.0 }).collect();
        let (mut server, _dialer, handles) =
            start_cluster(3, ClusterConfig::default());
        let out = server.serve_plan(&plan, 1.0, Some(&delays)).unwrap();
        finish(server, handles);
        assert_eq!(out.dispatched, 12);
        assert_eq!(out.outcome.received, 6);
        assert_eq!(out.late, 6);
        assert_eq!(out.missing(), 0);
        assert!(out.outcome.normalized_loss <= 1.0 + 1e-12);
    }

    fn coding_config(latency: Option<LatencyModel>, workers: usize) -> CodingConfig {
        let part = Partitioning::rxc(3, 3, 4, 5, 4);
        let pair = crate::partition::default_pair_classes(3);
        let cm = ClassMap::from_levels(
            &part,
            vec![0, 1, 2],
            vec![0, 1, 2],
            &pair,
        );
        CodingConfig {
            part,
            spec: CodeSpec::stacked(CodeKind::Mds),
            cm,
            workers,
            latency,
        }
    }

    #[test]
    fn request_stream_reuses_cached_encodings() {
        let coding = coding_config(Some(LatencyModel::exp(1.0)), 14);
        let (mut server, _dialer, handles) =
            start_cluster(3, ClusterConfig::default());
        let mut rng = Pcg64::seed_from(31);
        let mut mats = Pcg64::seed_from(32);
        let a0 = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
        let a1 = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
        // the DNN-training shape: same A, fresh B every request
        let stream = [(0u64, &a0), (0, &a0), (1, &a1), (0, &a0)];
        let mut hits = Vec::new();
        for &(a_id, a) in &stream {
            let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
            let req =
                MatmulRequest { a_id, a: a.clone(), b, t_max: 50.0, score: true };
            let out = server.serve_request(&coding, &req, &mut rng).unwrap();
            hits.push(out.cache_hit.unwrap());
            // the deadline is generous: cached and fresh encodings alike
            // must fully decode — a corrupted cached W_A could not
            assert_eq!(out.outcome.recovered, 9);
            assert!(out.outcome.normalized_loss < 1e-9);
        }
        assert_eq!(hits, vec![false, true, false, true]);
        let stats = server.cache_stats();
        assert_eq!(stats.hits, 2);
        assert_eq!(stats.misses, 2);
        finish(server, handles);
    }

    #[test]
    fn unscored_requests_skip_the_reference_product() {
        // production shape: decode and assemble without ever computing
        // the exact A·B locally — loss fields come back NaN
        let coding = coding_config(Some(LatencyModel::exp(1.0)), 14);
        let (mut server, _dialer, handles) =
            start_cluster(2, ClusterConfig::default());
        let mut rng = Pcg64::seed_from(41);
        let mut mats = Pcg64::seed_from(42);
        let a = Matrix::randn(12, 5, 0.0, 1.0, &mut mats);
        let b = Matrix::randn(5, 12, 0.0, 1.0, &mut mats);
        let req = MatmulRequest { a_id: 0, a, b, t_max: 50.0, score: false };
        let out = server.serve_request(&coding, &req, &mut rng).unwrap();
        assert_eq!(out.outcome.recovered, 9);
        assert!(out.outcome.loss.is_nan());
        assert!(out.outcome.normalized_loss.is_nan());
        finish(server, handles);
    }

    #[test]
    fn dispatch_fails_over_when_a_worker_dies() {
        let (mut transport, dialer) = LoopbackTransport::new();
        let wcfg = WorkerConfig { name: "live".to_string(), ..Default::default() };
        let handles = spawn_loopback_workers(&dialer, 1, &wcfg);
        // a worker that registers and immediately vanishes
        let mut ghost = dialer.dial("ghost").unwrap();
        ghost.send(&Msg::Hello { agent: "ghost".to_string() }).unwrap();
        let mut server = ClusterServer::new(ClusterConfig::default());
        let n = server
            .accept_workers(&mut transport, 2, Duration::from_secs(10))
            .unwrap();
        assert_eq!(n, 2);
        drop(ghost);

        let plan = small_plan(10, 5);
        let delays = vec![0.1; 10];
        let out = server.serve_plan(&plan, 1.0, Some(&delays)).unwrap();
        // every job must have failed over to the live worker
        assert_eq!(out.dispatched, 10);
        assert_eq!(out.outcome.received, 10);
        assert_eq!(out.outcome.recovered, 9);
        assert!(out.outcome.normalized_loss < 1e-9);
        assert_eq!(server.live_workers(), 1);
        finish(server, handles);
    }

    #[test]
    fn jobs_stranded_on_a_mid_request_death_are_redispatched() {
        // A worker that accepts jobs and then vanishes must not cost
        // the request any work: its in-flight slots requeue onto the
        // survivor (well before the 60 s collect timeout) and the MDS
        // plan still fully decodes.
        let (mut transport, dialer) = LoopbackTransport::new();
        let wcfg = WorkerConfig { name: "live".to_string(), ..Default::default() };
        let handles = spawn_loopback_workers(&dialer, 1, &wcfg);
        let ghost_conn = dialer.dial("ghost").unwrap();
        let ghost = std::thread::spawn(move || {
            let mut conn = ghost_conn;
            conn.send(&Msg::Hello { agent: "ghost".to_string() }).unwrap();
            assert!(matches!(conn.recv().unwrap(), Msg::Welcome { .. }));
            // accept exactly one job, then die without replying
            loop {
                match conn.recv().unwrap() {
                    Msg::Job(_) => break,
                    _ => continue,
                }
            }
        });
        let mut server = ClusterServer::new(ClusterConfig::default());
        let n = server
            .accept_workers(&mut transport, 2, Duration::from_secs(10))
            .unwrap();
        assert_eq!(n, 2);

        let plan = small_plan(12, 6);
        let delays = vec![0.1; 12];
        let t0 = Instant::now();
        let out = server.serve_plan(&plan, 1.0, Some(&delays)).unwrap();
        ghost.join().unwrap();
        // far below the 60 s collect_timeout: no spin on stranded jobs
        assert!(t0.elapsed() < Duration::from_secs(10), "{:?}", t0.elapsed());
        // every slot the ghost was holding was re-dispatched and landed
        assert!(out.retries > 0, "ghost jobs must be re-dispatched: {out:?}");
        assert_eq!(out.missing(), 0, "no work may be lost: {out:?}");
        assert_eq!(out.outcome.received, 12);
        assert_eq!(out.outcome.recovered, 9);
        assert!(out.outcome.normalized_loss < 1e-9);
        assert_eq!(
            out.outcome.received + out.late + out.missing(),
            out.dispatched
        );
        assert_eq!(server.live_workers(), 1);
        finish(server, handles);
    }

    #[test]
    fn retry_budget_bounds_redispatch_and_writes_off_cleanly() {
        // With re-dispatch disabled (max_job_retries = 0) the old
        // write-off semantics apply: stranded jobs surface as missing,
        // accounting stays balanced, and the request still returns
        // promptly.
        let (mut transport, dialer) = LoopbackTransport::new();
        let wcfg = WorkerConfig { name: "live".to_string(), ..Default::default() };
        let handles = spawn_loopback_workers(&dialer, 1, &wcfg);
        let ghost_conn = dialer.dial("ghost").unwrap();
        let ghost = std::thread::spawn(move || {
            let mut conn = ghost_conn;
            conn.send(&Msg::Hello { agent: "ghost".to_string() }).unwrap();
            assert!(matches!(conn.recv().unwrap(), Msg::Welcome { .. }));
            loop {
                match conn.recv().unwrap() {
                    Msg::Job(_) => break,
                    _ => continue,
                }
            }
        });
        let cfg = ClusterConfig { max_job_retries: 0, ..ClusterConfig::default() };
        let mut server = ClusterServer::new(cfg);
        let n = server
            .accept_workers(&mut transport, 2, Duration::from_secs(10))
            .unwrap();
        assert_eq!(n, 2);

        let plan = small_plan(12, 6);
        let delays = vec![0.1; 12];
        let t0 = Instant::now();
        let out = server.serve_plan(&plan, 1.0, Some(&delays)).unwrap();
        ghost.join().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(10), "{:?}", t0.elapsed());
        assert_eq!(out.retries, 0);
        assert!(out.missing() > 0, "ghost jobs must be written off: {out:?}");
        assert_eq!(
            out.outcome.received + out.late + out.missing(),
            out.dispatched
        );
        finish(server, handles);
    }

    #[test]
    fn heartbeat_evicts_silent_workers_and_service_continues() {
        let (mut transport, dialer) = LoopbackTransport::new();
        let wcfg = WorkerConfig { name: "live".to_string(), ..Default::default() };
        let handles = spawn_loopback_workers(&dialer, 1, &wcfg);
        // a worker that registers but never answers anything again (its
        // connection stays open, so only the heartbeat can catch it)
        let mut silent = dialer.dial("silent").unwrap();
        silent.send(&Msg::Hello { agent: "silent".to_string() }).unwrap();
        let cfg = ClusterConfig {
            heartbeat_timeout: Duration::from_millis(50),
            ..Default::default()
        };
        let mut server = ClusterServer::new(cfg);
        let n = server
            .accept_workers(&mut transport, 2, Duration::from_secs(10))
            .unwrap();
        assert_eq!(n, 2);

        let silent_id = server
            .worker_info()
            .iter()
            .find(|w| w.name == "silent")
            .unwrap()
            .id;
        let hb = server.heartbeat();
        assert_eq!(hb.evicted, vec![silent_id]);
        assert_eq!(hb.buffered_results, 0);
        assert_eq!(server.live_workers(), 1);

        // the stream keeps serving on the survivor
        let plan = small_plan(8, 9);
        let delays = vec![0.2; 8];
        let out = server.serve_plan(&plan, 1.0, Some(&delays)).unwrap();
        assert_eq!(out.outcome.received, 8);
        assert!(out.outcome.normalized_loss <= 1.0 + 1e-12);
        // keep the silent connection alive until the end of the test
        let _ = silent.send(&Msg::HeartbeatAck { nonce: 0 });
        finish(server, handles);
    }

    #[test]
    fn serving_with_no_workers_is_an_error() {
        let mut server = ClusterServer::new(ClusterConfig::default());
        let plan = small_plan(4, 2);
        assert!(server.serve_plan(&plan, 1.0, None).is_err());
    }

    #[test]
    fn serve_jobs_observer_sees_every_accepted_absorption_in_order() {
        let plan = small_plan(12, 17);
        // half the results miss the virtual deadline: the observer must
        // see exactly the six accepted ones, in (delay, slot) order
        let delays: Vec<f64> =
            (0..12).map(|w| if w % 2 == 0 { 0.1 * (w + 1) as f64 } else { 9.0 }).collect();
        let (mut server, _dialer, handles) =
            start_cluster(3, ClusterConfig::default());
        let jobs: Vec<(Arc<Matrix>, Arc<Matrix>)> = plan
            .packets
            .iter()
            .map(|p| {
                let (wa, wb) = crate::coordinator::build_job_matrices(
                    &plan.part,
                    &plan.a_blocks,
                    &plan.b_blocks,
                    &p.recipe,
                );
                (Arc::new(wa), Arc::new(wb))
            })
            .collect();
        let mut steps: Vec<DecodeStep> = Vec::new();
        let mut obs = |s: DecodeStep| steps.push(s);
        let served = server
            .serve_jobs(
                &plan.space,
                &plan.packets,
                jobs,
                Some(&delays),
                1.5,
                Some(&mut obs),
            )
            .unwrap();
        finish(server, handles);
        assert_eq!(served.received, 6);
        assert_eq!(served.late, 6);
        assert_eq!(steps.len(), 6);
        for (i, s) in steps.iter().enumerate() {
            assert_eq!(s.received, i + 1);
            assert!(s.delay <= 1.5);
        }
        // delays are absorbed in non-decreasing order
        for w in steps.windows(2) {
            assert!(w[0].delay <= w[1].delay);
        }
        // newly-determined counts accumulate to the final recovery
        let total_newly: usize = steps.iter().map(|s| s.newly.len()).sum();
        assert_eq!(total_newly, served.st.num_recovered());
        assert_eq!(steps.last().unwrap().recovered, served.st.num_recovered());
    }

    #[test]
    fn heartbeat_buffers_in_flight_results_instead_of_dropping() {
        // Regression for the result-drop bug: a heartbeat that reads a
        // result frame while waiting for acks must route it into the
        // worker's inbox, where the next serve poll absorbs it with
        // full accounting — not consume and discard it.
        let (mut transport, dialer) = LoopbackTransport::new();
        let mut agent = dialer.dial("agent").unwrap();
        agent.send(&Msg::Hello { agent: "agent".to_string() }).unwrap();
        let cfg = ClusterConfig {
            heartbeat_timeout: Duration::from_millis(100),
            collect_timeout: Duration::from_secs(5),
            ..Default::default()
        };
        let mut server = ClusterServer::new(cfg);
        assert_eq!(
            server.accept_workers(&mut transport, 1, Duration::from_secs(10)).unwrap(),
            1
        );
        assert!(matches!(agent.recv().unwrap(), Msg::Welcome { .. }));

        // the honest payload for the one job of the upcoming request
        // (id 1), already in flight when the heartbeat runs
        let plan = small_plan(1, 21);
        let (wa, wb) = crate::coordinator::build_job_matrices(
            &plan.part,
            &plan.a_blocks,
            &plan.b_blocks,
            &plan.packets[0].recipe,
        );
        agent
            .send(&Msg::Result(ResultMsg {
                request_id: 1,
                slot: 0,
                attempt: 0,
                delay: 0.1,
                compute_secs: 0.0,
                payload: matmul(&wa, &wb),
            }))
            .unwrap();
        let hb = server.heartbeat();
        // the frame proves liveness (no eviction) and is buffered
        assert!(hb.evicted.is_empty(), "{hb:?}");
        assert_eq!(hb.buffered_results, 1);

        // the buffered frame satisfies the request even though the
        // agent never answers the job send itself
        let out = server.serve_plan(&plan, 1.0, Some(&[0.1])).unwrap();
        assert_eq!(out.dispatched, 1);
        assert_eq!(out.outcome.received, 1);
        assert_eq!(out.missing(), 0);
        drop(agent);
    }

    #[test]
    fn evicted_worker_rejoins_with_its_id_and_serves_again() {
        let (mut transport, dialer) = LoopbackTransport::new();
        let wcfg = WorkerConfig { name: "live".to_string(), ..Default::default() };
        let handles = spawn_loopback_workers(&dialer, 1, &wcfg);
        // an agent that registers but never answers: evicted by heartbeat
        let mut silent = dialer.dial("flaky").unwrap();
        silent.send(&Msg::Hello { agent: "flaky".to_string() }).unwrap();
        let cfg = ClusterConfig {
            heartbeat_timeout: Duration::from_millis(50),
            ..Default::default()
        };
        let mut server = ClusterServer::new(cfg);
        assert_eq!(
            server.accept_workers(&mut transport, 2, Duration::from_secs(10)).unwrap(),
            2
        );
        let flaky_id = server
            .worker_info()
            .iter()
            .find(|w| w.name == "flaky")
            .unwrap()
            .id;
        let hb = server.heartbeat();
        assert_eq!(hb.evicted, vec![flaky_id]);
        assert_eq!(server.live_workers(), 1);

        // the same agent rejoins under its name: the dead slot revives
        // in place instead of growing the registry
        let dialer2 = dialer.clone();
        let rejoin = std::thread::spawn(move || {
            let mut conn = dialer2.dial("flaky").unwrap();
            let cfg = WorkerConfig { name: "flaky".to_string(), ..Default::default() };
            run_worker(&mut conn, &NativeEngine::serial(), &cfg).unwrap()
        });
        assert_eq!(
            server.accept_workers(&mut transport, 1, Duration::from_secs(10)).unwrap(),
            1
        );
        assert_eq!(server.live_workers(), 2);
        let info = server.worker_info();
        assert_eq!(info.len(), 2, "rejoin must not duplicate the slot");
        let flaky = info.iter().find(|w| w.name == "flaky").unwrap();
        assert_eq!(flaky.id, flaky_id);
        assert!(flaky.alive);

        // … and it is eligible for (and receives) dispatched work
        let plan = small_plan(8, 11);
        let delays = vec![0.2; 8];
        let out = server.serve_plan(&plan, 1.0, Some(&delays)).unwrap();
        assert_eq!(out.outcome.received, 8);
        assert_eq!(out.missing(), 0);
        let flaky_after = server
            .worker_info()
            .into_iter()
            .find(|w| w.name == "flaky")
            .unwrap();
        assert!(flaky_after.jobs_done > 0, "rejoined worker must get work");
        drop(silent);
        server.shutdown();
        for h in handles {
            h.join().unwrap().unwrap();
        }
        let stats = rejoin.join().unwrap();
        assert!(stats.clean_shutdown);
        assert_eq!(stats.worker_id, flaky_id);
    }

    #[test]
    fn duplicate_results_are_absorbed_exactly_once() {
        // Two results for the same (request, slot) under different
        // attempts: the coordinator must settle the slot on the first
        // and drop the second without touching the accounting.
        let (mut transport, dialer) = LoopbackTransport::new();
        let agent_conn = dialer.dial("dup").unwrap();
        let agent = std::thread::spawn(move || {
            let mut conn = agent_conn;
            conn.send(&Msg::Hello { agent: "dup".to_string() }).unwrap();
            assert!(matches!(conn.recv().unwrap(), Msg::Welcome { .. }));
            let mut served = 0;
            while served < 2 {
                match conn.recv().unwrap() {
                    Msg::Job(job) => {
                        let payload = matmul(&job.wa, &job.wb);
                        let reply = |attempt: u32| {
                            Msg::Result(ResultMsg {
                                request_id: job.request_id,
                                slot: job.slot,
                                attempt,
                                delay: job.injected_delay.unwrap_or(0.1),
                                compute_secs: 0.0,
                                payload: payload.clone(),
                            })
                        };
                        conn.send(&reply(job.attempt)).unwrap();
                        if job.slot == 0 {
                            conn.send(&reply(job.attempt + 1)).unwrap();
                        }
                        served += 1;
                    }
                    Msg::Shutdown => return,
                    _ => {}
                }
            }
            // drain to the orderly goodbye
            loop {
                match conn.recv() {
                    Ok(Msg::Shutdown) | Err(_) => break,
                    Ok(_) => {}
                }
            }
        });
        let mut server = ClusterServer::new(ClusterConfig::default());
        assert_eq!(
            server.accept_workers(&mut transport, 1, Duration::from_secs(10)).unwrap(),
            1
        );
        let plan = small_plan(2, 23);
        let delays = vec![0.1; 2];
        let out = server.serve_plan(&plan, 1.0, Some(&delays)).unwrap();
        assert_eq!(out.dispatched, 2);
        assert_eq!(
            out.outcome.received, 2,
            "a duplicate must not double-count: {out:?}"
        );
        assert_eq!(out.late, 0);
        assert_eq!(out.missing(), 0);
        server.shutdown();
        agent.join().unwrap();
    }

    #[test]
    fn corrupt_slot_results_are_counted_and_the_work_requeued() {
        // A worker naming a slot outside the packet set is broken: the
        // frame is counted in `corrupt`, the sender evicted, and its
        // jobs re-dispatched — the books always balance.
        let (mut transport, dialer) = LoopbackTransport::new();
        let wcfg = WorkerConfig { name: "live".to_string(), ..Default::default() };
        let handles = spawn_loopback_workers(&dialer, 1, &wcfg);
        let broken_conn = dialer.dial("broken").unwrap();
        let broken = std::thread::spawn(move || {
            let mut conn = broken_conn;
            conn.send(&Msg::Hello { agent: "broken".to_string() }).unwrap();
            assert!(matches!(conn.recv().unwrap(), Msg::Welcome { .. }));
            loop {
                match conn.recv() {
                    Ok(Msg::Job(job)) => {
                        let r = Msg::Result(ResultMsg {
                            request_id: job.request_id,
                            slot: 999, // far outside the packet set
                            attempt: job.attempt,
                            delay: 0.1,
                            compute_secs: 0.0,
                            payload: matmul(&job.wa, &job.wb),
                        });
                        if conn.send(&r).is_err() {
                            break;
                        }
                    }
                    Ok(Msg::Shutdown) | Err(_) => break,
                    Ok(_) => {}
                }
            }
        });
        let mut server = ClusterServer::new(ClusterConfig::default());
        assert_eq!(
            server.accept_workers(&mut transport, 2, Duration::from_secs(10)).unwrap(),
            2
        );
        let plan = small_plan(10, 27);
        let delays = vec![0.1; 10];
        let out = server.serve_plan(&plan, 1.0, Some(&delays)).unwrap();
        assert!(out.corrupt >= 1, "corrupt frames must be counted: {out:?}");
        assert_eq!(server.live_workers(), 1, "the broken worker is evicted");
        assert!(out.retries > 0, "its jobs must be re-dispatched: {out:?}");
        assert_eq!(out.outcome.received, 10);
        assert_eq!(out.outcome.recovered, 9);
        assert_eq!(
            out.outcome.received + out.late + out.missing(),
            out.dispatched
        );
        server.shutdown();
        let _ = broken.join();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn lying_worker_is_struck_quarantined_and_barred_from_rejoin() {
        // A Byzantine worker computes the product and then perturbs it:
        // the frame is wire-perfect (valid CRC), so only the Freivalds
        // gate can catch it. Every lie strikes; past the budget the
        // worker is quarantined and its name refused at re-Hello until
        // the operator resets it.
        let (mut transport, dialer) = LoopbackTransport::new();
        let wcfg = WorkerConfig { name: "honest".to_string(), ..Default::default() };
        let handles = spawn_loopback_workers(&dialer, 1, &wcfg);
        let liar_conn = dialer.dial("liar").unwrap();
        let liar = std::thread::spawn(move || {
            let mut conn = liar_conn;
            conn.send(&Msg::Hello { agent: "liar".to_string() }).unwrap();
            assert!(matches!(conn.recv().unwrap(), Msg::Welcome { .. }));
            loop {
                match conn.recv() {
                    Ok(Msg::Job(job)) => {
                        let honest = matmul(&job.wa, &job.wb);
                        let mut data = honest.data().to_vec();
                        data[0] += 1.0 + 0.5 * honest.max_abs();
                        let forged =
                            Matrix::from_vec(honest.rows(), honest.cols(), data);
                        let r = Msg::Result(ResultMsg {
                            request_id: job.request_id,
                            slot: job.slot,
                            attempt: job.attempt,
                            delay: job.injected_delay.unwrap_or(0.1),
                            compute_secs: 0.0,
                            payload: forged,
                        });
                        if conn.send(&r).is_err() {
                            break;
                        }
                    }
                    Ok(Msg::Shutdown) | Err(_) => break,
                    Ok(_) => {}
                }
            }
        });
        let cfg = ClusterConfig {
            max_verify_failures: 0, // the first lie quarantines
            max_job_retries: 5,
            ..ClusterConfig::default()
        };
        let mut server = ClusterServer::new(cfg);
        assert_eq!(
            server.accept_workers(&mut transport, 2, Duration::from_secs(10)).unwrap(),
            2
        );
        let liar_id =
            server.worker_info().iter().find(|w| w.name == "liar").unwrap().id;

        let plan = small_plan(10, 33);
        let delays = vec![0.1; 10];
        let out = server.serve_plan(&plan, 1.0, Some(&delays)).unwrap();
        assert!(out.verify_failures >= 1, "the lie must be caught: {out:?}");
        assert_eq!(server.quarantined_workers(), vec![liar_id]);
        assert_eq!(server.live_workers(), 1);
        // the forged slots were requeued onto the honest worker
        assert_eq!(out.outcome.received, 10);
        assert_eq!(out.outcome.recovered, 9);
        assert_eq!(out.missing(), 0, "{out:?}");
        let info = server.worker_info();
        let liar_info = info.iter().find(|w| w.name == "liar").unwrap();
        assert!(liar_info.quarantined);
        assert!(liar_info.verify_failures >= 1);

        // a quarantined name is refused at the Hello handshake
        let mut retry = dialer.dial("liar").unwrap();
        retry.send(&Msg::Hello { agent: "liar".to_string() }).unwrap();
        assert_eq!(
            server
                .accept_workers(&mut transport, 1, Duration::from_millis(300))
                .unwrap(),
            0,
            "quarantined agent must not rejoin"
        );
        drop(retry);

        // operator reset lifts the bar; the agent rejoins and serves
        assert!(server.reset_quarantine(liar_id));
        assert!(!server.reset_quarantine(liar_id), "already reset");
        let dialer2 = dialer.clone();
        let reformed = std::thread::spawn(move || {
            let mut conn = dialer2.dial("liar").unwrap();
            let cfg = WorkerConfig { name: "liar".to_string(), ..Default::default() };
            run_worker(&mut conn, &NativeEngine::serial(), &cfg).unwrap()
        });
        assert_eq!(
            server.accept_workers(&mut transport, 1, Duration::from_secs(10)).unwrap(),
            1
        );
        assert_eq!(server.live_workers(), 2);
        let out2 = server.serve_plan(&plan, 1.0, Some(&delays)).unwrap();
        assert_eq!(out2.verify_failures, 0);
        assert_eq!(out2.missing(), 0);
        server.shutdown();
        let _ = liar.join();
        let stats = reformed.join().unwrap();
        assert!(stats.clean_shutdown);
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn honest_runs_are_bit_identical_with_verification_on_and_off() {
        // the Freivalds probes come from their own RNG stream keyed by
        // (verify_seed, request_id), so toggling verification must not
        // move a single bit of an honest run's outcome
        let plan = small_plan(14, 35);
        let mut drng = Pcg64::seed_from(36);
        let delays: Vec<f64> = (0..14)
            .map(|_| LatencyModel::exp(1.0).sample_scaled(0.5, &mut drng))
            .collect();
        let run = |verify: bool| {
            let cfg = ClusterConfig { verify, ..ClusterConfig::default() };
            let (mut server, _dialer, handles) = start_cluster(3, cfg);
            let out = server.serve_plan(&plan, 0.8, Some(&delays)).unwrap();
            finish(server, handles);
            out
        };
        let on = run(true);
        let off = run(false);
        assert_eq!(on.verify_failures, 0);
        assert_eq!(on.outcome.received, off.outcome.received);
        assert_eq!(on.late, off.late);
        assert_eq!(on.outcome.c_hat.data(), off.outcome.c_hat.data());
        assert_eq!(on.outcome.loss.to_bits(), off.outcome.loss.to_bits());
    }

    #[test]
    fn corrupt_frames_are_tolerated_and_the_work_recovered() {
        // A checksum-damaged frame is a channel fault: the connection
        // resyncs past it, the sender is NOT killed, and the work still
        // lands (here via the sender's own follow-up honest frame).
        use crate::cluster::wire::{self, HEADER_LEN};
        let (mut transport, dialer) = LoopbackTransport::new();
        let wcfg = WorkerConfig { name: "clean".to_string(), ..Default::default() };
        let handles = spawn_loopback_workers(&dialer, 1, &wcfg);
        let noisy_conn = dialer.dial("noisy").unwrap();
        let noisy = std::thread::spawn(move || {
            let mut conn = noisy_conn;
            conn.send(&Msg::Hello { agent: "noisy".to_string() }).unwrap();
            assert!(matches!(conn.recv().unwrap(), Msg::Welcome { .. }));
            let mut first = true;
            loop {
                match conn.recv() {
                    Ok(Msg::Job(job)) => {
                        let r = Msg::Result(ResultMsg {
                            request_id: job.request_id,
                            slot: job.slot,
                            attempt: job.attempt,
                            delay: job.injected_delay.unwrap_or(0.1),
                            compute_secs: 0.0,
                            payload: matmul(&job.wa, &job.wb),
                        });
                        if first {
                            // the channel damages the first delivery in
                            // flight; the worker then resends it intact
                            first = false;
                            let mut frame = wire::encode(&r).unwrap();
                            frame[HEADER_LEN] ^= 0x01;
                            if conn.send_frame(&frame).is_err() {
                                break;
                            }
                        }
                        if conn.send(&r).is_err() {
                            break;
                        }
                    }
                    Ok(Msg::Shutdown) | Err(_) => break,
                    Ok(_) => {}
                }
            }
        });
        let mut server = ClusterServer::new(ClusterConfig::default());
        assert_eq!(
            server.accept_workers(&mut transport, 2, Duration::from_secs(10)).unwrap(),
            2
        );
        let plan = small_plan(10, 37);
        let delays = vec![0.1; 10];
        let out = server.serve_plan(&plan, 1.0, Some(&delays)).unwrap();
        assert!(out.corrupt >= 1, "the damaged frame must be counted: {out:?}");
        assert_eq!(out.verify_failures, 0);
        assert_eq!(
            server.live_workers(),
            2,
            "a noisy channel is not a dead worker"
        );
        assert_eq!(out.outcome.received, 10);
        assert_eq!(out.outcome.recovered, 9);
        assert_eq!(out.missing(), 0, "{out:?}");
        server.shutdown();
        let _ = noisy.join();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    fn rateless_setup(seed: u64) -> (RatelessPlan, Matrix) {
        let mut rng = Pcg64::seed_from(seed);
        let part = Partitioning::rxc(3, 3, 4, 5, 4);
        let a = Matrix::randn(12, 5, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(5, 12, 0.0, 1.0, &mut rng);
        let c = matmul(&a, &b);
        let plan = RatelessPlan::build(
            &part,
            crate::coding::RatelessSpec::paper_default(),
            3,
            &a,
            &b,
        )
        .unwrap();
        (plan, c)
    }

    fn rateless_chat(plan: &RatelessPlan, served: &ServedDecode) -> Matrix {
        assemble_outcome(&plan.part, &plan.cm, &served.st, served.received).c_hat
    }

    #[test]
    fn rateless_virtual_is_deterministic_and_decodes_exactly() {
        let (plan, c_true) = rateless_setup(51);
        // four streams, linearly slower bases, all cumulative
        let schedules: Vec<Vec<f64>> = (0..4)
            .map(|s| {
                let base = 0.1 * (s + 1) as f64;
                (0..40).map(|k| base * (k + 1) as f64).collect()
            })
            .collect();
        let run = |verify: bool| {
            let cfg = ClusterConfig { verify, ..ClusterConfig::default() };
            let (mut server, _dialer, handles) = start_cluster(4, cfg);
            let served = server
                .serve_rateless(&plan, 6.0, Some(schedules.as_slice()), None)
                .unwrap();
            finish(server, handles);
            served
        };
        let a1 = run(true);
        let a2 = run(true);
        let off = run(false);
        assert!(a1.st.is_complete(), "generous deadline must decode fully");
        assert_eq!(a1.received, a1.dispatched, "{a1:?}",);
        assert_eq!(a1.late, 0);
        assert_eq!(a1.retries, 0, "honest run needs no redo");
        assert_eq!(a1.verify_failures, 0);
        // every absorbed packet is credited to exactly one worker
        let credited: usize = a1.worker_packets.iter().map(|&(_, n)| n).sum();
        assert_eq!(credited, a1.received);
        // absorption order follows the injected schedule
        for w in a1.timings.windows(2) {
            assert!(w[0].delay <= w[1].delay);
        }
        let c1 = rateless_chat(&plan, &a1);
        assert!(c1.allclose(&c_true, 1e-9));
        // rerun and verify-off are bit-identical
        for other in [&a2, &off] {
            assert_eq!(a1.received, other.received);
            assert_eq!(c1.data(), rateless_chat(&plan, other).data());
        }
    }

    #[test]
    fn rateless_straggler_stream_earns_partial_credit() {
        let (plan, c_true) = rateless_setup(53);
        // three fast workers with only two in-deadline packets each: the
        // decode cannot complete without the straggler's stream
        let mut schedules: Vec<Vec<f64>> = (0..3)
            .map(|s| vec![0.1 + 0.01 * s as f64, 0.2 + 0.01 * s as f64])
            .collect();
        schedules.push((0..120).map(|k| (k + 1) as f64).collect());
        let (mut server, _dialer, handles) =
            start_cluster(4, ClusterConfig::default());
        let mut steps = 0usize;
        let mut obs = |step: DecodeStep| {
            steps += 1;
            assert_eq!(step.received, steps);
        };
        let served = server
            .serve_rateless(&plan, 1e6, Some(schedules.as_slice()), Some(&mut obs))
            .unwrap();
        finish(server, handles);
        assert!(served.st.is_complete());
        assert_eq!(steps, served.received);
        assert!(
            served.partial_packets > 0,
            "the straggler must contribute decoded packets: {:?}",
            served.worker_packets
        );
        // the straggler's stream carries most of the work here
        let straggler_credit =
            served.worker_packets.iter().map(|&(_, n)| n).max().unwrap();
        assert!(straggler_credit > 2, "{:?}", served.worker_packets);
        assert!(rateless_chat(&plan, &served).allclose(&c_true, 1e-9));
    }

    #[test]
    fn rateless_wall_mode_completes_and_drains() {
        let (plan, c_true) = rateless_setup(55);
        let cfg = ClusterConfig {
            deadline: DeadlineMode::Wall,
            time_scale: 1.0,
            ..ClusterConfig::default()
        };
        let (mut server, _dialer, handles) = start_cluster(3, cfg);
        let served = server.serve_rateless(&plan, 10.0, None, None).unwrap();
        finish(server, handles);
        assert!(
            served.st.is_complete(),
            "only {} packets arrived",
            served.received
        );
        assert!(rateless_chat(&plan, &served).allclose(&c_true, 1e-9));
        assert_eq!(served.dispatched, served.received + served.late);
        assert!(served.partial_packets <= served.received);
    }

    #[test]
    fn dropped_result_frames_recover_via_stall_requeue() {
        // A worker whose first result frame vanishes entirely (lossy
        // channel): nothing tells the coordinator the slot is dead, so
        // the stall timer must respin it onto the fleet instead of
        // sitting out the full collect timeout.
        let (mut transport, dialer) = LoopbackTransport::new();
        let wcfg = WorkerConfig { name: "ok".to_string(), ..Default::default() };
        let handles = spawn_loopback_workers(&dialer, 1, &wcfg);
        let lossy_conn = dialer.dial("lossy").unwrap();
        let lossy = std::thread::spawn(move || {
            let mut conn = lossy_conn;
            conn.send(&Msg::Hello { agent: "lossy".to_string() }).unwrap();
            assert!(matches!(conn.recv().unwrap(), Msg::Welcome { .. }));
            let mut dropped = false;
            loop {
                match conn.recv() {
                    Ok(Msg::Job(job)) => {
                        if !dropped {
                            dropped = true; // the channel ate this result
                            continue;
                        }
                        let r = Msg::Result(ResultMsg {
                            request_id: job.request_id,
                            slot: job.slot,
                            attempt: job.attempt,
                            delay: job.injected_delay.unwrap_or(0.1),
                            compute_secs: 0.0,
                            payload: matmul(&job.wa, &job.wb),
                        });
                        if conn.send(&r).is_err() {
                            break;
                        }
                    }
                    Ok(Msg::Shutdown) | Err(_) => break,
                    Ok(_) => {}
                }
            }
        });
        let cfg = ClusterConfig {
            stall_timeout: Duration::from_millis(100),
            ..ClusterConfig::default()
        };
        let mut server = ClusterServer::new(cfg);
        assert_eq!(
            server.accept_workers(&mut transport, 2, Duration::from_secs(10)).unwrap(),
            2
        );
        let plan = small_plan(10, 39);
        let delays = vec![0.1; 10];
        let t0 = Instant::now();
        let out = server.serve_plan(&plan, 1.0, Some(&delays)).unwrap();
        assert!(out.retries > 0, "the eaten slot must be respun: {out:?}");
        assert_eq!(out.outcome.received, 10);
        assert_eq!(out.missing(), 0, "{out:?}");
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "stall recovery must beat the 60 s collect timeout: {:?}",
            t0.elapsed()
        );
        server.shutdown();
        let _ = lossy.join();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }
}
