//! The networked coordinator/worker runtime: the paper's protocol
//! (Fig. 2) across process and socket boundaries.
//!
//! The virtual-time simulator ([`crate::sim`]) and the threaded service
//! ([`crate::coordinator::run_service`]) model stragglers; this
//! subsystem *has* them: workers are separate agents behind a
//! transport, results arrive when they arrive, connections drop, and
//! the coordinator decodes whatever made it by the deadline.
//!
//! Layers:
//! * [`wire`] — length-prefixed binary frames (versioned header, f64
//!   matrix payloads bit-exact on the wire);
//! * [`transport`] — [`Transport`]/[`Connection`] over TCP
//!   ([`TcpTransport`]) or deterministic in-process channels
//!   ([`LoopbackTransport`]), both carrying identical bytes;
//! * [`worker`] — the worker agent loop computing coded sub-products
//!   through any [`crate::runtime::ExecEngine`];
//! * [`server`] — the coordinator: worker registry with
//!   heartbeat/eviction, round-robin dispatch with failover, per-request
//!   deadlines, progressive decode, scoring;
//! * [`cache`] — the encoded-block cache reusing the `B`-independent
//!   half of plan preparation across a request stream (the DNN-training
//!   shape: same weights `A`, fresh activations `B`).
//!
//! Entry points: `uepmm serve` / `uepmm worker` (see `main.rs`) for the
//! TCP deployment, [`ClusterServer`] + [`spawn_loopback_workers`] for
//! embedded/loopback use — or wrap either form in
//! [`crate::api::ClusterBackend`] to drive it through the unified
//! [`crate::api::Session`] API (progress stream, session-owned encode
//! cache, typed errors).

pub mod cache;
pub mod server;
pub mod transport;
pub mod wire;
pub mod worker;

pub use cache::{CacheKey, CacheStats, EncodedBlockCache};
pub use server::{
    ClusterConfig, ClusterOutcome, ClusterServer, CodingConfig, DeadlineMode,
    DecodeStep, MatmulRequest, ServedDecode, WorkerInfo,
};
pub use transport::{
    loopback_pair, Connection, LoopbackConn, LoopbackDialer, LoopbackTransport,
    TcpConn, TcpTransport, Transport,
};
pub use wire::{JobMsg, Msg, ResultMsg, WireError};
pub use worker::{run_worker, spawn_loopback_workers, WorkerConfig, WorkerStats};
