//! The networked coordinator/worker runtime: the paper's protocol
//! (Fig. 2) across process and socket boundaries.
//!
//! The virtual-time simulator ([`crate::sim`]) and the in-process
//! backends of [`crate::api`] model stragglers; this subsystem *has*
//! them: workers are separate agents behind a transport, results arrive
//! when they arrive, connections drop, and the coordinator decodes
//! whatever made it by the deadline.
//!
//! Layers:
//! * [`wire`] — length-prefixed binary frames (versioned header, f64
//!   matrix payloads bit-exact on the wire);
//! * [`transport`] — [`Transport`]/[`Connection`] over TCP
//!   ([`TcpTransport`]) or deterministic in-process channels
//!   ([`LoopbackTransport`]), both carrying identical bytes;
//! * [`worker`] — the worker agent loop computing coded sub-products
//!   through any [`crate::runtime::ExecEngine`];
//! * [`server`] — the coordinator: worker registry with
//!   heartbeat/eviction and rejoin, least-outstanding dispatch with
//!   failover and bounded re-dispatch, per-request deadlines,
//!   progressive decode, scoring;
//! * [`cache`] — the encoded-block cache reusing the `B`-independent
//!   half of plan preparation across a request stream (the DNN-training
//!   shape: same weights `A`, fresh activations `B`);
//! * [`chaos`] — seeded fault injection ([`ChaosConn`] /
//!   [`ChaosTransport`] over any transport, driven by a [`FaultPlan`])
//!   that makes every fault mode below reproducible in tests and soaks;
//! * [`service`] — the multi-tenant serve plane ([`ServePlane`] /
//!   [`FleetEngine`]): many concurrent client sessions multiplexed onto
//!   one shared fleet behind a single front door, with deficit-round-
//!   robin fairness, admission control, and sharded decode.
//!
//! # Fault model
//!
//! The paper's straggler model assumes honest-but-slow workers; its own
//! premise — poor channel conditions — also implies corrupted frames
//! and wrong answers. The runtime distinguishes three fault classes:
//!
//! | Fault | Example | Detected by | Recovery | Cost |
//! |---|---|---|---|---|
//! | **Crash / hang** | worker killed, socket reset, silent stall | send/recv failure, missed heartbeats ([`ClusterConfig::evict_after`]), `Virtual`-mode stall timer | eviction; unresolved slots re-dispatch onto survivors (bounded by [`ClusterConfig::max_job_retries`]); agent may rejoin | latency; slots written off as `missing` once the retry budget is spent |
//! | **Corrupt frame** | bit flips on a lossy link | CRC32 on every frame ([`WireError::BadChecksum`]); the connection resyncs past the damaged frame | the frame is counted `corrupt`, the *sender keeps its slots* (channel fault ≠ worker fault), and affected slots requeue | one round trip per damaged frame |
//! | **Byzantine payload** | tampered or miscomputed sub-product with a valid checksum | Freivalds verification of every arriving result ([`crate::coordinator::Verifier`], O(n²) per packet, seeded ⇒ bit-reproducible) | the result is rejected and the slot requeued; after [`ClusterConfig::max_verify_failures`] strikes the worker is **quarantined** — evicted and barred from re-`Hello` until [`ClusterServer::reset_quarantine`] | at most `max_verify_failures + 1` wasted slot-attempts per liar, plus the O(n²) verify per result |
//!
//! What is *not* recovered: work written off after the retry budget
//! (surfaces as `missing`), and — by design — nothing is silently
//! accepted: a result is either verified in, counted late, or requeued.
//!
//! # Recovery semantics
//!
//! The paper treats stragglers as erasures to be coded around, never as
//! work to be thrown away. The runtime honors that end to end:
//!
//! * **No dropped results.** A [`Msg::Result`] frame read out of turn —
//!   by [`ClusterServer::heartbeat`] while it waits for acks, or by a
//!   poll that outlived its request — is buffered in the owning
//!   worker's inbox (current request) or dropped only once it is
//!   provably stale (earlier request id). A run with interleaved
//!   [`ClusterServer::heartbeat`] calls therefore decodes
//!   bit-identically to one without.
//! * **Bounded re-dispatch.** Every dispatched payload stays in the
//!   request's job table until its result lands. When a worker dies —
//!   send failure, receive failure, protocol violation, or a corrupt
//!   result slot — its unresolved jobs requeue onto surviving workers,
//!   at most [`ClusterConfig::max_job_retries`] re-sends per slot
//!   (then the slot is written off and surfaces as `missing`). In
//!   `Wall` mode nothing is re-sent after the deadline: a re-dispatch
//!   could not land in time.
//! * **Idempotent results.** A slot settles on its first accepted
//!   result; duplicates (a re-dispatched job whose original holder
//!   delivered after all) are absorbed exactly once.
//! * **Rejoin.** A previously evicted agent that re-`Hello`s under its
//!   name revives its registry slot in place — same worker id,
//!   cumulative `jobs_done` — and is immediately eligible for new and
//!   requeued work.
//! * **Informed dispatch.** Jobs go to the live worker with the fewest
//!   in-flight jobs, ties broken by the lowest EWMA straggle score
//!   (then registry order, keeping selection deterministic), so slow
//!   workers shed load instead of accumulating it.
//!
//! # Rateless streams (wire v5)
//!
//! The fixed-rate protocol above ships `n` pre-drawn jobs and waits;
//! the rateless family ([`crate::coding::CodeKind::Rateless`]) has no
//! `n`. Wire v5 adds a second, fountain-shaped data plane:
//!
//! * **Multi-packet jobs.** One [`Msg::RatelessJob`] per worker opens a
//!   *stream*: the worker derives packet `seq = 0, 1, 2, …` itself —
//!   coefficients are seeded per `(request_id, stream, seq)`, so the
//!   coordinator reconstructs every row without the rows ever crossing
//!   the wire — and keeps emitting until told to stop.
//! * **Per-packet result frames.** Each [`Msg::RatelessResult`] carries
//!   its `seq` and a `more` flag (the worker's own claim that further
//!   packets follow). Frames are data plane: CRC32-checked, Freivalds-
//!   verified, chaos-injectable ([`chaos`]) exactly like fixed-rate
//!   results; a dropped or damaged frame costs that packet, never the
//!   stream — in `Virtual` mode the stall timer flags the gap and a
//!   [`Msg::Redo`] (control plane) re-requests from the flagged `seq`.
//! * **Drain on completion.** The moment the decoder reaches full rank
//!   the coordinator broadcasts [`Msg::Drain`] (control plane) and
//!   absorbs stragglers' in-flight frames instead of discarding them:
//!   a slow worker's partial stream still contributes every packet it
//!   landed.
//!
//! **Partial credit** is the accounting contract that makes the last
//! point auditable: [`ServedDecode::worker_packets`] reports, per
//! stream, how many of its packets the decoder actually absorbed, and
//! [`ServedDecode::partial_packets`] is the minimum credit across
//! contributing streams — `> 0` means *no* worker was cut out of the
//! decode, i.e. straggler work was recovered rather than raced to
//! death. `uepmm serve --code rateless` prints both per request and a
//! stream-wide `partial_packets=` summary that the CI rateless smoke
//! asserts against a 10× straggler.
//!
//! # Multi-tenant client plane (wire v6)
//!
//! Wire v6 adds client-facing frames — `OpenSession`, `Submit`,
//! `ProgressFrame`, `ClientResult`, `Reject`, `CloseSession` — on the
//! same CRC32 framing, so one listener serves both planes and the first
//! frame of a connection picks its role (`Hello` ⇒ worker lane,
//! `OpenSession` ⇒ admission control). See [`service`] for the frame
//! table, session lifecycle, and determinism contract.
//!
//! Entry points: `uepmm serve` / `uepmm worker` (see `main.rs`) for the
//! single-stream TCP deployment, `uepmm serve --service` +
//! `uepmm client` for the multi-tenant plane, [`ClusterServer`] +
//! [`spawn_loopback_workers`] for embedded/loopback use — or wrap
//! either form in [`crate::api::ClusterBackend`] (local over a
//! transport, or remote via [`crate::api::ClusterBackend::connect`]) to
//! drive it through the unified [`crate::api::Session`] API (progress
//! stream, session-owned encode cache, typed errors).

pub mod cache;
pub mod chaos;
pub mod server;
pub mod service;
pub mod transport;
pub mod wire;
pub mod worker;

pub use cache::{CacheKey, CacheStats, EncodedBlockCache};
pub use chaos::{ChaosConn, ChaosTransport, FaultPlan};
pub use server::{
    ClusterConfig, ClusterOutcome, ClusterServer, CodingConfig, DeadlineMode,
    DecodeStep, HeartbeatReport, JobTiming, MatmulRequest, ServedDecode,
    WorkerInfo,
};
pub use transport::{
    loopback_pair, Connection, LoopbackConn, LoopbackDialer, LoopbackTransport,
    TcpConn, TcpTransport, Transport,
};
pub use service::{
    DrrScheduler, FleetEngine, ServePlane, ServiceConfig, ServiceReport,
};
pub use wire::{
    ClientResultMsg, JobMsg, Msg, ProgressMsg, RatelessJobMsg,
    RatelessResultMsg, ResultMsg, SubmitMsg, WireError,
};
pub use worker::{
    run_worker, spawn_chaos_loopback_worker, spawn_loopback_workers, WorkerConfig,
    WorkerStats,
};
