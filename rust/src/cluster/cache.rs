//! The encoded-block cache: reuse the `A`-side of plan preparation
//! across a request stream.
//!
//! The DNN-training workload (paper §VII) multiplies the *same* weight
//! matrix `A` against a fresh activation matrix `B` on every request.
//! Splitting `A`, drawing the coded packet set, and materializing every
//! worker's left factor `W_A` are all `B`-independent, so the cluster
//! server caches that work ([`crate::coordinator::EncodedA`]) keyed by
//! `(matrix id, partitioning, code spec, class map, worker count)` and only the
//! `B`-side (split + `W_B`) is rebuilt per request. Hit/miss/eviction
//! counters are surfaced through [`CacheStats`] in the server's
//! per-request stats.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::coding::CodeSpec;
use crate::coordinator::EncodedA;
use crate::partition::{ClassMap, Paradigm, Partitioning};

/// Cache identity of one encoding. Two requests share an entry only if
/// they multiply the same logical `A` (caller-assigned `matrix_id`,
/// namespaced by the owning tenant — ids are assigned independently
/// per session, so tenant 1's matrix #0 and tenant 2's matrix #0 are
/// different matrices that must never collide) under the same
/// partition geometry, the same fully-specified code (including the
/// window polynomial), the same importance-class assignment (the
/// window draw in `generate_packets` depends on it), and the same
/// worker count.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CacheKey {
    /// Owning tenant/session: the namespace for `matrix_id`.
    pub tenant: u64,
    pub matrix_id: u64,
    paradigm: u8,
    n: usize,
    p: usize,
    m: usize,
    u: usize,
    h: usize,
    q: usize,
    /// Full code spec rendered to text (captures kind, style, and the
    /// window polynomial's probabilities).
    code: String,
    /// The class structure the packets were drawn under: sub-product
    /// classes plus factor-block levels (rank-one NOW packets combine
    /// blocks by level).
    classes: String,
    workers: usize,
}

impl CacheKey {
    pub fn new(
        tenant: u64,
        matrix_id: u64,
        part: &Partitioning,
        spec: &CodeSpec,
        cm: &ClassMap,
        workers: usize,
    ) -> CacheKey {
        CacheKey {
            tenant,
            matrix_id,
            paradigm: match part.paradigm {
                Paradigm::RowTimesCol => 0,
                Paradigm::ColTimesRow => 1,
            },
            n: part.n,
            p: part.p,
            m: part.m,
            u: part.u,
            h: part.h,
            q: part.q,
            code: format!("{spec:?}"),
            classes: format!(
                "{:?}|{:?}|{:?}",
                cm.class_of, cm.a_level, cm.b_level
            ),
            workers,
        }
    }
}

/// Monotone hit/miss/eviction counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// An LRU cache of encoded `A`-sides. Capacity 0 disables caching (every
/// lookup is a miss and nothing is stored).
///
/// Recency is a monotone tick stamped on every access, so the hot path
/// (a hit on a repeated-`A` stream) is one hash lookup plus a counter
/// store — the earlier `VecDeque` re-ordering made every hit an O(n)
/// scan. Eviction scans for the minimum tick, which is O(n) only on the
/// rare capacity overflow.
pub struct EncodedBlockCache {
    /// Entry plus the tick of its most recent use. `BTreeMap` keeps
    /// iteration (eviction scans, debugging dumps) in key order — no
    /// per-process hash-seed nondeterminism anywhere near the serve
    /// path (no-unordered-iteration).
    map: BTreeMap<CacheKey, (Arc<EncodedA>, u64)>,
    /// Monotone access counter (the recency clock).
    tick: u64,
    capacity: usize,
    stats: CacheStats,
    /// Per-tenant (hits, misses): the multi-tenant accounting behind
    /// [`EncodedBlockCache::tenant_stats`].
    per_tenant: BTreeMap<u64, (u64, u64)>,
}

impl EncodedBlockCache {
    pub fn new(capacity: usize) -> Self {
        EncodedBlockCache {
            map: BTreeMap::new(),
            tick: 0,
            capacity,
            stats: CacheStats::default(),
            per_tenant: BTreeMap::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Per-tenant `(tenant, hits, misses)` rows, sorted by tenant id so
    /// the report is deterministic. Surfaced through
    /// [`crate::api::Maintenance::cache_tenants`].
    pub fn tenant_stats(&self) -> Vec<(u64, u64, u64)> {
        let mut rows: Vec<(u64, u64, u64)> = self
            .per_tenant
            .iter()
            .map(|(&t, &(h, m))| (t, h, m))
            .collect();
        rows.sort_unstable_by_key(|r| r.0);
        rows
    }

    pub fn clear(&mut self) {
        self.map.clear();
    }

    /// Fetch the encoding for `key`, building (and storing) it on a
    /// miss. Returns the entry and whether it was a hit.
    pub fn get_or_insert_with(
        &mut self,
        key: CacheKey,
        build: impl FnOnce() -> anyhow::Result<EncodedA>,
    ) -> anyhow::Result<(Arc<EncodedA>, bool)> {
        self.tick += 1;
        let tenant = self.per_tenant.entry(key.tenant).or_insert((0, 0));
        if let Some((entry, used)) = self.map.get_mut(&key) {
            self.stats.hits += 1;
            tenant.0 += 1;
            *used = self.tick;
            return Ok((Arc::clone(entry), true));
        }
        self.stats.misses += 1;
        tenant.1 += 1;
        let entry = Arc::new(build()?);
        if self.capacity == 0 {
            return Ok((entry, false));
        }
        while self.map.len() >= self.capacity {
            // evict the least recently used entry (minimum tick)
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (_, used))| *used)
                .map(|(k, _)| k.clone());
            match oldest {
                Some(k) => {
                    self.map.remove(&k);
                    self.stats.evictions += 1;
                }
                None => break,
            }
        }
        self.map.insert(key, (Arc::clone(&entry), self.tick));
        Ok((entry, false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodeKind, WindowPolynomial};
    use crate::linalg::Matrix;
    use crate::partition::ClassMap;
    use crate::rng::Pcg64;

    fn setup() -> (Partitioning, ClassMap, Matrix) {
        let part = Partitioning::rxc(3, 3, 2, 3, 2);
        let mut rng = Pcg64::seed_from(5);
        let a = Matrix::randn(6, 3, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(3, 6, 0.0, 1.0, &mut rng);
        let cm = ClassMap::from_matrices(&part, &a, &b, 3);
        (part, cm, a)
    }

    fn encode(
        part: &Partitioning,
        cm: &ClassMap,
        a: &Matrix,
        seed: u64,
    ) -> EncodedA {
        let mut rng = Pcg64::seed_from(seed);
        EncodedA::encode(
            part,
            CodeSpec::stacked(CodeKind::Mds),
            cm,
            6,
            a,
            &mut rng,
        )
        .unwrap()
    }

    #[test]
    fn hit_miss_accounting_and_reuse() {
        let (part, cm, a) = setup();
        let spec = CodeSpec::stacked(CodeKind::Mds);
        let mut cache = EncodedBlockCache::new(4);
        let k0 = CacheKey::new(0, 0, &part, &spec, &cm, 6);

        let (e0, hit) =
            cache.get_or_insert_with(k0.clone(), || Ok(encode(&part, &cm, &a, 1))).unwrap();
        assert!(!hit);
        let (e1, hit) = cache
            .get_or_insert_with(k0.clone(), || panic!("must not rebuild on hit"))
            .unwrap();
        assert!(hit);
        // the hit returns the *same* encoding (packets identical)
        assert_eq!(e0.packets, e1.packets);
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, evictions: 0 });

        // a different matrix id is a different entry
        let k1 = CacheKey::new(0, 1, &part, &spec, &cm, 6);
        let (_, hit) =
            cache.get_or_insert_with(k1, || Ok(encode(&part, &cm, &a, 2))).unwrap();
        assert!(!hit);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 2, evictions: 0 });
    }

    #[test]
    fn key_distinguishes_code_geometry_classes_and_workers() {
        let (part, cm, _) = setup();
        let mds = CodeSpec::stacked(CodeKind::Mds);
        let ew = CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3()));
        let key = |part: &Partitioning, spec: &CodeSpec, cm: &ClassMap, w: usize| {
            CacheKey::new(0, 0, part, spec, cm, w)
        };
        assert_ne!(key(&part, &mds, &cm, 6), key(&part, &ew, &cm, 6));
        assert_ne!(key(&part, &mds, &cm, 6), key(&part, &mds, &cm, 9));
        let other = Partitioning::rxc(3, 3, 2, 4, 2);
        assert_ne!(key(&part, &mds, &cm, 6), key(&other, &mds, &cm, 6));
        // different window polynomials must not collide even though the
        // code kind label is the same
        let gamma = WindowPolynomial::new(&[0.5, 0.3, 0.2]);
        let ew2 = CodeSpec::stacked(CodeKind::EwUep(gamma));
        assert_ne!(key(&part, &ew, &cm, 6), key(&part, &ew2, &cm, 6));
        // and neither may two class maps: the packet draw depends on the
        // class assignment, so reusing across maps would be incoherent
        let pair = crate::partition::default_pair_classes(3);
        let cm2 = ClassMap::from_levels(
            &part,
            vec![2, 1, 0],
            vec![2, 1, 0],
            &pair,
        );
        assert_ne!(key(&part, &ew, &cm, 6), key(&part, &ew, &cm2, 6));
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let (part, cm, a) = setup();
        let spec = CodeSpec::stacked(CodeKind::Mds);
        let mut cache = EncodedBlockCache::new(2);
        let key = |id| CacheKey::new(0, id, &part, &spec, &cm, 6);
        for id in 0..2 {
            cache
                .get_or_insert_with(key(id), || Ok(encode(&part, &cm, &a, id)))
                .unwrap();
        }
        // touch id 0 so id 1 is the LRU entry
        let (_, hit) = cache
            .get_or_insert_with(key(0), || panic!("0 is cached"))
            .unwrap();
        assert!(hit);
        cache.get_or_insert_with(key(2), || Ok(encode(&part, &cm, &a, 2))).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        // id 1 was evicted; id 0 survived
        let (_, hit) = cache
            .get_or_insert_with(key(0), || panic!("0 must have survived"))
            .unwrap();
        assert!(hit);
        let (_, hit) =
            cache.get_or_insert_with(key(1), || Ok(encode(&part, &cm, &a, 1))).unwrap();
        assert!(!hit);
    }

    /// Regression (multi-tenant serve plane): matrix ids are assigned
    /// *per session*, so two tenants both calling their first matrix
    /// id 0 — with different actual matrices — must land on different
    /// cache entries. Before keys carried the tenant, tenant 2 would
    /// have been served tenant 1's encoding.
    #[test]
    fn tenants_with_the_same_matrix_id_never_collide() {
        let (part, cm, a) = setup();
        let spec = CodeSpec::stacked(CodeKind::Mds);
        let mut cache = EncodedBlockCache::new(4);
        let k_t1 = CacheKey::new(1, 0, &part, &spec, &cm, 6);
        let k_t2 = CacheKey::new(2, 0, &part, &spec, &cm, 6);
        assert_ne!(k_t1, k_t2);

        let (e1, hit) = cache
            .get_or_insert_with(k_t1.clone(), || Ok(encode(&part, &cm, &a, 1)))
            .unwrap();
        assert!(!hit);
        // tenant 2, same id, *different* encoding seed (standing in for
        // a different matrix): must miss and build its own entry
        let (e2, hit) = cache
            .get_or_insert_with(k_t2, || Ok(encode(&part, &cm, &a, 2)))
            .unwrap();
        assert!(!hit, "cross-tenant collision: tenant 2 got tenant 1's entry");
        assert_ne!(e1.packets, e2.packets);
        assert_eq!(cache.len(), 2);

        // tenant 1 still hits its own entry
        let (e1b, hit) = cache
            .get_or_insert_with(k_t1, || panic!("tenant 1's entry was lost"))
            .unwrap();
        assert!(hit);
        assert_eq!(e1.packets, e1b.packets);

        // and the per-tenant accounting saw all of it
        assert_eq!(cache.tenant_stats(), vec![(1, 1, 1), (2, 0, 1)]);
    }

    #[test]
    fn zero_capacity_disables_storage() {
        let (part, cm, a) = setup();
        let spec = CodeSpec::stacked(CodeKind::Mds);
        let mut cache = EncodedBlockCache::new(0);
        let key = CacheKey::new(0, 0, &part, &spec, &cm, 6);
        for _ in 0..3 {
            let (_, hit) = cache
                .get_or_insert_with(key.clone(), || Ok(encode(&part, &cm, &a, 1)))
                .unwrap();
            assert!(!hit);
        }
        assert!(cache.is_empty());
        assert_eq!(cache.stats().misses, 3);
    }
}
