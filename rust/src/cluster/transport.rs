//! Transports: how coordinator and workers exchange [`Msg`] frames.
//!
//! Two implementations of the same pair of abstractions:
//!
//! * [`TcpTransport`] / [`TcpConn`] — real sockets over `std::net`, the
//!   deployment path (`uepmm serve` + `uepmm worker` processes). Here
//!   straggling is a property of the transport and the host: scheduling,
//!   the network stack, and worker load all show up as arrival jitter.
//! * [`LoopbackTransport`] / [`LoopbackConn`] — in-process channels that
//!   carry the *same encoded frames*, so every cluster test runs the
//!   production byte format seeded and toolchain-only. Stragglers are
//!   injected deterministically through per-job delays sampled from a
//!   seeded [`crate::latency::LatencyModel`] (see
//!   [`super::server::ClusterServer`]) instead of wall-clock races, which
//!   is what makes loopback runs bit-identical across repetitions.
//!
//! A [`Connection`] is one bidirectional framed message stream; a
//! [`Transport`] accepts incoming connections on the coordinator side.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::wire::{self, Msg, WireError};

/// Floor for socket read timeouts: `set_read_timeout(Some(ZERO))` is an
/// error on every platform, and sub-millisecond timeouts burn CPU.
const MIN_IO_WAIT: Duration = Duration::from_millis(1);

/// Normalize "the peer went away" I/O errors to [`WireError::Closed`] so
/// callers can tell an orderly departure from a real fault.
fn io_to_wire(e: std::io::Error) -> WireError {
    use std::io::ErrorKind::*;
    match e.kind() {
        BrokenPipe | ConnectionReset | ConnectionAborted | UnexpectedEof
        | NotConnected => WireError::Closed,
        _ => WireError::Io(e),
    }
}

/// One bidirectional framed message stream between two cluster agents.
///
/// Known limitation: `send` blocks until the frame is handed to the
/// transport. A TCP worker that stops draining its socket while its OS
/// receive buffer is full can therefore stall the sender — at the
/// current demo/test scales frames are far smaller than socket buffers,
/// but very large jobs would want a write deadline (std `TcpStream` has
/// no portable write timeout; this is the documented integration point
/// for a nonblocking-writer upgrade).
pub trait Connection: Send {
    /// Send one message (blocking until the frame is written out).
    fn send(&mut self, msg: &Msg) -> Result<(), WireError>;

    /// Send one pre-encoded frame verbatim. This is the byte-level
    /// escape hatch the chaos layer uses to put *deliberately damaged*
    /// frames on the wire ([`super::chaos::ChaosConn`]); normal callers
    /// should use [`Connection::send`].
    fn send_frame(&mut self, frame: &[u8]) -> Result<(), WireError>;

    /// Send one frame whose bytes live in several buffers (e.g. the
    /// split job encoding `prefix | shared body | trailer` from
    /// [`wire::job_prefix`]) — the serve plane's zero-copy dispatch
    /// path. The default concatenates and delegates to
    /// [`Connection::send_frame`] (loopback channels carry whole-frame
    /// messages); [`TcpConn`] overrides it with a true vectored write,
    /// so the shared megabyte body is never copied per dispatch.
    fn send_vectored(&mut self, parts: &[&[u8]]) -> Result<(), WireError> {
        let total = parts.iter().map(|p| p.len()).sum();
        let mut frame = Vec::with_capacity(total);
        for p in parts {
            frame.extend_from_slice(p);
        }
        self.send_frame(&frame)
    }

    /// Receive the next message. `timeout = None` blocks until a message
    /// arrives or the peer closes; `Some(d)` returns `Ok(None)` if no
    /// complete frame arrived within `d`.
    fn recv_timeout(&mut self, timeout: Option<Duration>)
        -> Result<Option<Msg>, WireError>;

    /// Peer label for logs.
    fn peer(&self) -> &str;

    /// Block until the next message (a closed peer is an error here).
    fn recv(&mut self) -> Result<Msg, WireError> {
        match self.recv_timeout(None)? {
            Some(m) => Ok(m),
            None => Err(WireError::Closed),
        }
    }
}

/// Coordinator-side listener: yields worker connections as they dial in.
pub trait Transport {
    /// Wait up to `timeout` for one incoming connection.
    fn accept_timeout(
        &mut self,
        timeout: Duration,
    ) -> Result<Option<Box<dyn Connection>>, WireError>;

    /// The address workers should dial (e.g. `127.0.0.1:7077`).
    fn local_addr(&self) -> String;
}

// ------------------------------------------------------------------ TCP

/// A framed connection over a TCP socket, with an internal receive
/// buffer so a timeout mid-frame never loses bytes or framing sync.
pub struct TcpConn {
    stream: TcpStream,
    buf: Vec<u8>,
    peer: String,
    /// The timeout currently programmed on the socket (avoids a syscall
    /// per poll when the wait does not change).
    current_timeout: Option<Duration>,
}

impl TcpConn {
    /// Dial a coordinator at `addr`.
    pub fn connect(addr: &str) -> Result<TcpConn, WireError> {
        let stream = TcpStream::connect(addr)?;
        Self::from_stream(stream)
    }

    /// Wrap an accepted or connected stream.
    pub fn from_stream(stream: TcpStream) -> Result<TcpConn, WireError> {
        stream.set_nodelay(true)?;
        stream.set_nonblocking(false)?;
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "tcp-peer".to_string());
        Ok(TcpConn { stream, buf: Vec::new(), peer, current_timeout: None })
    }

    fn set_io_timeout(&mut self, t: Option<Duration>) -> Result<(), WireError> {
        if self.current_timeout != t {
            self.stream.set_read_timeout(t)?;
            self.current_timeout = t;
        }
        Ok(())
    }
}

impl Connection for TcpConn {
    fn send(&mut self, msg: &Msg) -> Result<(), WireError> {
        // encode is fallible: a payload that does not fit the wire
        // format surfaces as `Oversize` here instead of truncating
        let frame = wire::encode(msg)?;
        self.send_frame(&frame)
    }

    fn send_frame(&mut self, frame: &[u8]) -> Result<(), WireError> {
        self.stream.write_all(frame).map_err(io_to_wire)?;
        Ok(())
    }

    fn send_vectored(&mut self, parts: &[&[u8]]) -> Result<(), WireError> {
        use std::io::IoSlice;
        // write_vectored may accept only a prefix of the buffers; loop
        // with an advancing cursor (part index + offset) until all
        // bytes are out — the manual analogue of write_all, across
        // buffers, without ever concatenating them
        let mut part = 0;
        let mut off = 0;
        while part < parts.len() {
            if parts[part].len() == off {
                part += 1;
                off = 0;
                continue;
            }
            let mut slices = Vec::with_capacity(parts.len() - part);
            slices.push(IoSlice::new(&parts[part][off..]));
            slices.extend(parts[part + 1..].iter().map(|p| IoSlice::new(p)));
            match self.stream.write_vectored(&slices) {
                Ok(0) => {
                    return Err(WireError::Io(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "vectored write made no progress",
                    )))
                }
                Ok(mut n) => {
                    while part < parts.len() && n > 0 {
                        let left = parts[part].len() - off;
                        if n >= left {
                            n -= left;
                            part += 1;
                            off = 0;
                        } else {
                            off += n;
                            n = 0;
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(io_to_wire(e)),
            }
        }
        Ok(())
    }

    fn recv_timeout(
        &mut self,
        timeout: Option<Duration>,
    ) -> Result<Option<Msg>, WireError> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut chunk = [0u8; 64 * 1024];
        loop {
            match wire::try_decode(&self.buf) {
                Ok(Some((msg, used))) => {
                    self.buf.drain(..used);
                    return Ok(Some(msg));
                }
                Ok(None) => {}
                Err(e @ WireError::BadChecksum { .. }) => {
                    // a corrupt frame, but its extent is known from the
                    // validated header: drain exactly that frame so the
                    // stream stays in sync, surface the error once, and
                    // the next call resumes at the following frame —
                    // one damaged frame must not kill the connection
                    let total = wire::frame_len(&self.buf)
                        .unwrap_or(self.buf.len())
                        .min(self.buf.len());
                    self.buf.drain(..total);
                    return Err(e);
                }
                Err(e) => return Err(e),
            }
            match deadline {
                None => self.set_io_timeout(None)?,
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Ok(None);
                    }
                    self.set_io_timeout(Some((d - now).max(MIN_IO_WAIT)))?;
                }
            }
            match self.stream.read(&mut chunk) {
                Ok(0) => return Err(WireError::Closed),
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if deadline.map(|d| Instant::now() >= d).unwrap_or(false) {
                        return Ok(None);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(io_to_wire(e)),
            }
        }
    }

    fn peer(&self) -> &str {
        &self.peer
    }
}

/// TCP listener on the coordinator side.
pub struct TcpTransport {
    listener: TcpListener,
    addr: String,
}

impl TcpTransport {
    /// Bind `addr` (use port 0 for an ephemeral port; the bound address
    /// is reported by [`Transport::local_addr`]).
    pub fn bind(addr: &str) -> Result<TcpTransport, WireError> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| addr.to_string());
        Ok(TcpTransport { listener, addr })
    }
}

impl Transport for TcpTransport {
    fn accept_timeout(
        &mut self,
        timeout: Duration,
    ) -> Result<Option<Box<dyn Connection>>, WireError> {
        let deadline = Instant::now() + timeout;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    return Ok(Some(Box::new(TcpConn::from_stream(stream)?)))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Ok(None);
                    }
                    std::thread::sleep(MIN_IO_WAIT);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(WireError::Io(e)),
            }
        }
    }

    fn local_addr(&self) -> String {
        self.addr.clone()
    }
}

// ------------------------------------------------------------- loopback

/// In-process framed connection: encoded frames over a channel pair.
pub struct LoopbackConn {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
    peer: String,
}

/// Create a connected pair of loopback endpoints.
pub fn loopback_pair(a: &str, b: &str) -> (LoopbackConn, LoopbackConn) {
    let (tx_ab, rx_ab) = mpsc::channel();
    let (tx_ba, rx_ba) = mpsc::channel();
    (
        LoopbackConn { tx: tx_ab, rx: rx_ba, peer: b.to_string() },
        LoopbackConn { tx: tx_ba, rx: rx_ab, peer: a.to_string() },
    )
}

impl LoopbackConn {
    fn decode_one(bytes: Vec<u8>) -> Result<Msg, WireError> {
        let (msg, used) = wire::decode_frame(&bytes)?;
        if used != bytes.len() {
            return Err(WireError::Malformed("loopback frame with trailing bytes"));
        }
        Ok(msg)
    }
}

impl Connection for LoopbackConn {
    fn send(&mut self, msg: &Msg) -> Result<(), WireError> {
        self.tx.send(wire::encode(msg)?).map_err(|_| WireError::Closed)
    }

    fn send_frame(&mut self, frame: &[u8]) -> Result<(), WireError> {
        self.tx.send(frame.to_vec()).map_err(|_| WireError::Closed)
    }

    fn recv_timeout(
        &mut self,
        timeout: Option<Duration>,
    ) -> Result<Option<Msg>, WireError> {
        let bytes = match timeout {
            None => self.rx.recv().map_err(|_| WireError::Closed)?,
            Some(d) => match self.rx.recv_timeout(d) {
                Ok(b) => b,
                Err(mpsc::RecvTimeoutError::Timeout) => return Ok(None),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(WireError::Closed)
                }
            },
        };
        Ok(Some(Self::decode_one(bytes)?))
    }

    fn peer(&self) -> &str {
        &self.peer
    }
}

/// Coordinator side of the loopback transport: a queue of dialed-in
/// connections.
pub struct LoopbackTransport {
    rx: mpsc::Receiver<LoopbackConn>,
}

/// Worker-side handle for dialing a [`LoopbackTransport`]. Clone one per
/// worker thread.
#[derive(Clone)]
pub struct LoopbackDialer {
    tx: mpsc::Sender<LoopbackConn>,
}

impl LoopbackTransport {
    /// A fresh transport plus the dialer workers use to connect to it.
    pub fn new() -> (LoopbackTransport, LoopbackDialer) {
        let (tx, rx) = mpsc::channel();
        (LoopbackTransport { rx }, LoopbackDialer { tx })
    }
}

impl Default for LoopbackTransport {
    fn default() -> Self {
        Self::new().0
    }
}

impl LoopbackDialer {
    /// Open a connection to the transport's coordinator.
    pub fn dial(&self, name: &str) -> Result<LoopbackConn, WireError> {
        let (client, server) = loopback_pair("coordinator", name);
        self.tx.send(server).map_err(|_| WireError::Closed)?;
        Ok(client)
    }
}

impl Transport for LoopbackTransport {
    fn accept_timeout(
        &mut self,
        timeout: Duration,
    ) -> Result<Option<Box<dyn Connection>>, WireError> {
        match self.rx.recv_timeout(timeout) {
            Ok(conn) => Ok(Some(Box::new(conn))),
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(WireError::Closed),
        }
    }

    fn local_addr(&self) -> String {
        "loopback".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_round_trip_and_timeout() {
        let (mut a, mut b) = loopback_pair("a", "b");
        assert!(a.recv_timeout(Some(Duration::from_millis(1))).unwrap().is_none());
        a.send(&Msg::Heartbeat { nonce: 9 }).unwrap();
        match b.recv().unwrap() {
            Msg::Heartbeat { nonce } => assert_eq!(nonce, 9),
            other => panic!("unexpected {other:?}"),
        }
        b.send(&Msg::HeartbeatAck { nonce: 9 }).unwrap();
        assert!(matches!(a.recv().unwrap(), Msg::HeartbeatAck { nonce: 9 }));
    }

    #[test]
    fn loopback_detects_closed_peer() {
        let (mut a, b) = loopback_pair("a", "b");
        drop(b);
        assert!(matches!(a.send(&Msg::Shutdown), Err(WireError::Closed)));
        assert!(matches!(a.recv_timeout(None), Err(WireError::Closed)));
    }

    #[test]
    fn loopback_transport_accepts_dialed_connections() {
        let (mut t, dialer) = LoopbackTransport::new();
        assert!(t.accept_timeout(Duration::from_millis(1)).unwrap().is_none());
        let mut client = dialer.dial("w0").unwrap();
        let mut server = t.accept_timeout(Duration::from_millis(100)).unwrap().unwrap();
        client.send(&Msg::Hello { agent: "w0".to_string() }).unwrap();
        match server.recv().unwrap() {
            Msg::Hello { agent } => assert_eq!(agent, "w0"),
            other => panic!("unexpected {other:?}"),
        }
        server.send(&Msg::Welcome { worker_id: 1 }).unwrap();
        assert!(matches!(client.recv().unwrap(), Msg::Welcome { worker_id: 1 }));
    }

    #[test]
    fn tcp_round_trip_on_localhost() {
        let mut transport = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = transport.local_addr();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpConn::connect(&addr).unwrap();
            conn.send(&Msg::Hello { agent: "tcp-w".to_string() }).unwrap();
            // echo protocol: expect a welcome back
            match conn.recv().unwrap() {
                Msg::Welcome { worker_id } => worker_id,
                other => panic!("unexpected {other:?}"),
            }
        });
        let mut server =
            transport.accept_timeout(Duration::from_secs(5)).unwrap().unwrap();
        match server.recv().unwrap() {
            Msg::Hello { agent } => assert_eq!(agent, "tcp-w"),
            other => panic!("unexpected {other:?}"),
        }
        server.send(&Msg::Welcome { worker_id: 17 }).unwrap();
        assert_eq!(handle.join().unwrap(), 17);
    }

    #[test]
    fn tcp_recv_timeout_returns_none_without_traffic() {
        let mut transport = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = transport.local_addr();
        let _client = TcpConn::connect(&addr).unwrap();
        let mut server =
            transport.accept_timeout(Duration::from_secs(5)).unwrap().unwrap();
        let t0 = Instant::now();
        let got = server.recv_timeout(Some(Duration::from_millis(20))).unwrap();
        assert!(got.is_none());
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    /// One corrupted frame must surface as `BadChecksum` and then leave
    /// the connection usable: the next (intact) frame decodes normally.
    #[test]
    fn tcp_connection_survives_a_corrupt_frame() {
        let mut transport = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = transport.local_addr();
        let mut bad = wire::encode(&Msg::Heartbeat { nonce: 1 }).unwrap();
        bad[wire::HEADER_LEN] ^= 0xFF; // flip a payload bit in flight
        let good = wire::encode(&Msg::Heartbeat { nonce: 2 }).unwrap();
        let handle = std::thread::spawn(move || {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(&bad).unwrap();
            s.write_all(&good).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(100));
        });
        let mut server =
            transport.accept_timeout(Duration::from_secs(5)).unwrap().unwrap();
        // the damaged frame surfaces exactly once…
        let err = loop {
            match server.recv_timeout(Some(Duration::from_millis(10))) {
                Ok(None) => continue, // still reading
                Ok(Some(m)) => panic!("corrupt frame decoded: {m:?}"),
                Err(e) => break e,
            }
        };
        assert!(matches!(err, WireError::BadChecksum { .. }), "{err}");
        // …and the parse loop stays alive: the next frame is intact
        let mut got = None;
        for _ in 0..200 {
            if let Some(m) =
                server.recv_timeout(Some(Duration::from_millis(5))).unwrap()
            {
                got = Some(m);
                break;
            }
        }
        assert!(matches!(got, Some(Msg::Heartbeat { nonce: 2 })), "{got:?}");
        handle.join().unwrap();
    }

    /// Same resync contract on the loopback transport: a corrupt frame
    /// surfaces once, the following frame decodes.
    #[test]
    fn loopback_connection_survives_a_corrupt_frame() {
        let (mut a, mut b) = loopback_pair("a", "b");
        let mut bad = wire::encode(&Msg::Heartbeat { nonce: 7 }).unwrap();
        bad[wire::HEADER_LEN + 3] ^= 0x20;
        a.send_frame(&bad).unwrap();
        a.send(&Msg::Heartbeat { nonce: 8 }).unwrap();
        match b.recv() {
            Err(WireError::BadChecksum { .. }) => {}
            other => panic!("expected BadChecksum, got {other:?}"),
        }
        assert!(matches!(b.recv().unwrap(), Msg::Heartbeat { nonce: 8 }));
    }

    /// A split job frame sent as three vectored buffers must arrive as
    /// one intact frame — bit-identical to the whole-buffer encoding —
    /// over both transports.
    #[test]
    fn vectored_send_delivers_the_split_job_frame_intact() {
        use crate::linalg::Matrix;
        use std::sync::Arc;
        let wa = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let wb = Matrix::from_vec(2, 1, vec![0.5, -0.5]);
        let body = wire::job_body(&wa, &wb).unwrap();
        let prefix =
            wire::job_prefix(9, 4, 1, Some(0.125), 0.001, body.len()).unwrap();
        let trailer = wire::job_trailer(&prefix, &body);
        let want = Msg::Job(wire::JobMsg {
            request_id: 9,
            slot: 4,
            attempt: 1,
            injected_delay: Some(0.125),
            sleep_secs: 0.001,
            wa: Arc::new(wa),
            wb: Arc::new(wb),
        });

        // loopback: default (concatenating) path
        let (mut a, mut b) = loopback_pair("a", "b");
        a.send_vectored(&[&prefix, &body, &trailer]).unwrap();
        assert_eq!(b.recv().unwrap(), want);

        // TCP: true vectored write
        let mut transport = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = transport.local_addr();
        let (p2, b2, t2) = (prefix.clone(), body.clone(), trailer);
        let handle = std::thread::spawn(move || {
            let mut conn = TcpConn::connect(&addr).unwrap();
            conn.send_vectored(&[&p2, &b2, &t2]).unwrap();
            std::thread::sleep(Duration::from_millis(50));
        });
        let mut server =
            transport.accept_timeout(Duration::from_secs(5)).unwrap().unwrap();
        let mut got = None;
        for _ in 0..200 {
            if let Some(m) =
                server.recv_timeout(Some(Duration::from_millis(5))).unwrap()
            {
                got = Some(m);
                break;
            }
        }
        assert_eq!(got.as_ref(), Some(&want));
        handle.join().unwrap();
    }

    #[test]
    fn tcp_split_frames_reassemble() {
        // a frame delivered in two TCP segments must decode once complete
        let mut transport = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = transport.local_addr();
        let frame = wire::encode(&Msg::Welcome { worker_id: 3 }).unwrap();
        let (first, rest) = frame.split_at(5);
        let (first, rest) = (first.to_vec(), rest.to_vec());
        let handle = std::thread::spawn(move || {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(&first).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(10));
            s.write_all(&rest).unwrap();
            s.flush().unwrap();
            // keep the socket open until the reader is done
            std::thread::sleep(Duration::from_millis(50));
        });
        let mut server =
            transport.accept_timeout(Duration::from_secs(5)).unwrap().unwrap();
        // first poll may time out while only the partial frame arrived;
        // the buffered bytes must survive into the next poll
        let mut got = None;
        for _ in 0..100 {
            if let Some(m) =
                server.recv_timeout(Some(Duration::from_millis(5))).unwrap()
            {
                got = Some(m);
                break;
            }
        }
        assert!(matches!(got, Some(Msg::Welcome { worker_id: 3 })));
        handle.join().unwrap();
    }
}
