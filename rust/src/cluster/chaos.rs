//! Deterministic fault injection for the cluster wire: wrap any
//! [`Connection`] (or a whole [`Transport`]) in a chaos layer driven by
//! a seeded [`FaultPlan`], and every fault mode — dropped, bit-flipped,
//! duplicated, delayed, reordered frames, a scripted hang, and
//! Byzantine payload tampering — becomes reproducible in-process and
//! over TCP.
//!
//! Design rules:
//!
//! * **Send-side injection.** Faults hit frames as they leave the
//!   wrapped peer (the usual deployment: a worker on a bad link). The
//!   receive path passes through untouched, so one chaotic worker never
//!   perturbs what the coordinator hears from the others.
//! * **Data plane only.** [`Msg::Job`] and [`Msg::Result`] frames are
//!   faultable; the control plane (`Hello`/`Welcome`/`Heartbeat`/
//!   `HeartbeatAck`/`Shutdown`) is exempt, so a chaos run exercises the
//!   *result-integrity* machinery rather than degenerating into
//!   registration flakes.
//! * **Corruption is detectable by construction.** Bit flips land at
//!   byte indices `>= HEADER_LEN` (payload or CRC trailer), so a
//!   damaged frame always surfaces as
//!   [`super::wire::WireError::BadChecksum`] — never as a desynced
//!   header that would force the peer to kill the connection.
//! * **Tampering is *not* wire-detectable.** The lying-worker mode
//!   perturbs a [`Msg::Result`] payload *before* encoding, so the frame
//!   carries a valid checksum and only Freivalds verification
//!   ([`crate::coordinator::Verifier`]) can catch it.
//! * **Determinism.** Each connection draws from its own
//!   [`crate::rng::Pcg64`] stream seeded from the plan, and fault rolls
//!   are consumed in a fixed per-frame order — same plan, same traffic,
//!   same faults.

use std::str::FromStr;
use std::time::Duration;

use crate::linalg::Matrix;
use crate::rng::Pcg64;

use super::transport::{Connection, Transport};
use super::wire::{self, Msg, RatelessResultMsg, ResultMsg, WireError, HEADER_LEN};

/// Seeded per-frame fault probabilities and scripted faults. Parse one
/// from a `key=value,...` spec (the `uepmm worker --chaos` syntax):
///
/// ```
/// use uepmm::cluster::FaultPlan;
/// let plan: FaultPlan = "drop=0.05,corrupt=0.1,seed=7".parse().unwrap();
/// assert_eq!(plan.drop, 0.05);
/// assert_eq!(plan.corrupt, 0.1);
/// assert_eq!(plan.seed, 7);
/// ```
///
/// Keys: `drop`, `corrupt`, `dup`, `delay`, `reorder`, `tamper`
/// (probabilities in `[0, 1]`), `delay-ms` (pause length), `seed`, and
/// `hang` (swallow every data frame after the N-th).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// RNG seed for every probabilistic roll.
    pub seed: u64,
    /// Probability a data frame is silently dropped.
    pub drop: f64,
    /// Probability a data frame gets one bit flipped in its payload or
    /// checksum trailer (detected at the receiver as `BadChecksum`).
    pub corrupt: f64,
    /// Probability a data frame is sent twice.
    pub duplicate: f64,
    /// Probability the sender pauses [`FaultPlan::delay_ms`] before a
    /// data frame goes out.
    pub delay: f64,
    /// Pause length for delay faults.
    pub delay_ms: u64,
    /// Probability a data frame is held back and sent *after* the next
    /// one (pairwise reorder).
    pub reorder: f64,
    /// Probability a [`Msg::Result`] payload is perturbed before
    /// encoding — the Byzantine worker. The frame is wire-perfect;
    /// only Freivalds verification catches it.
    pub tamper: f64,
    /// Scripted hang: swallow every data frame after this many have
    /// been offered for sending (`None` = never hang).
    pub hang_after: Option<u64>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            drop: 0.0,
            corrupt: 0.0,
            duplicate: 0.0,
            delay: 0.0,
            delay_ms: 20,
            reorder: 0.0,
            tamper: 0.0,
            hang_after: None,
        }
    }
}

impl FromStr for FaultPlan {
    type Err = String;

    fn from_str(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for item in s.split(',').filter(|t| !t.trim().is_empty()) {
            let (key, value) = item
                .split_once('=')
                .ok_or_else(|| format!("chaos spec item '{item}' is not key=value"))?;
            let key = key.trim();
            let value = value.trim();
            let prob = |slot: &mut f64| -> Result<(), String> {
                let p: f64 = value
                    .parse()
                    .map_err(|_| format!("chaos {key}: '{value}' is not a number"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("chaos {key}: {p} is not in [0, 1]"));
                }
                *slot = p;
                Ok(())
            };
            match key {
                "drop" => prob(&mut plan.drop)?,
                "corrupt" => prob(&mut plan.corrupt)?,
                "dup" => prob(&mut plan.duplicate)?,
                "delay" => prob(&mut plan.delay)?,
                "reorder" => prob(&mut plan.reorder)?,
                "tamper" => prob(&mut plan.tamper)?,
                "delay-ms" => {
                    plan.delay_ms = value.parse().map_err(|_| {
                        format!("chaos delay-ms: '{value}' is not an integer")
                    })?;
                }
                "seed" => {
                    plan.seed = value.parse().map_err(|_| {
                        format!("chaos seed: '{value}' is not an integer")
                    })?;
                }
                "hang" => {
                    plan.hang_after = Some(value.parse().map_err(|_| {
                        format!("chaos hang: '{value}' is not an integer")
                    })?);
                }
                other => {
                    return Err(format!(
                        "unknown chaos key '{other}' (expected drop, corrupt, dup, \
                         delay, delay-ms, reorder, tamper, seed, hang)"
                    ))
                }
            }
        }
        Ok(plan)
    }
}

/// Only the data plane is faultable (see module docs). The rateless
/// frames (`RatelessJob`/`RatelessResult`) are data; `Drain`/`Redo` are
/// stream control and stay exempt like the heartbeat plane.
fn is_data(msg: &Msg) -> bool {
    matches!(
        msg,
        Msg::Job(_) | Msg::Result(_) | Msg::RatelessJob(_) | Msg::RatelessResult(_)
    )
}

/// One Byzantine perturbation: bump a random entry by more than the
/// payload's own magnitude, so the lie is numerically unmissable for a
/// verifier yet wire-perfect.
fn perturb(payload: &Matrix, rng: &mut Pcg64) -> Matrix {
    let mut data = payload.data().to_vec();
    let idx = rng.next_bounded(data.len() as u64) as usize;
    data[idx] += 1.0 + 0.5 * payload.max_abs();
    Matrix::from_vec(payload.rows(), payload.cols(), data)
}

/// A [`Connection`] whose *sends* pass through a seeded fault layer.
pub struct ChaosConn {
    inner: Box<dyn Connection>,
    plan: FaultPlan,
    rng: Pcg64,
    /// A frame held back by a reorder fault, sent after the next one.
    held: Option<Vec<u8>>,
    /// Data frames offered for sending so far (the hang counter).
    faulted: u64,
}

impl ChaosConn {
    /// Wrap `inner`, seeding the fault RNG from the plan.
    pub fn new(inner: Box<dyn Connection>, plan: &FaultPlan) -> ChaosConn {
        ChaosConn {
            inner,
            plan: plan.clone(),
            rng: Pcg64::seed_from(plan.seed),
            held: None,
            faulted: 0,
        }
    }

    /// Wrap `inner` on an explicit RNG stream — a fleet of chaotic
    /// workers from one plan gets independent fault sequences.
    pub fn with_stream(
        inner: Box<dyn Connection>,
        plan: &FaultPlan,
        stream: u64,
    ) -> ChaosConn {
        ChaosConn {
            inner,
            plan: plan.clone(),
            rng: Pcg64::with_stream(plan.seed, stream),
            held: None,
            faulted: 0,
        }
    }

    fn flush_held(&mut self) -> Result<(), WireError> {
        if let Some(frame) = self.held.take() {
            self.inner.send_frame(&frame)?;
        }
        Ok(())
    }

    /// Put one encoded data frame on the wire through the fault layer.
    /// Roll order is fixed (drop, corrupt, delay, dup, reorder) so a
    /// given seed produces the same fault sequence for the same traffic.
    fn put(&mut self, mut frame: Vec<u8>) -> Result<(), WireError> {
        if self.rng.bernoulli(self.plan.drop) {
            return Ok(()); // vanished in flight
        }
        if self.rng.bernoulli(self.plan.corrupt) {
            // flip one bit past the header: always a checksum miss at
            // the receiver, never a desynced parse
            let span = (frame.len() - HEADER_LEN) as u64;
            let idx = HEADER_LEN + self.rng.next_bounded(span) as usize;
            frame[idx] ^= 1 << self.rng.next_bounded(8);
        }
        if self.rng.bernoulli(self.plan.delay) {
            std::thread::sleep(Duration::from_millis(self.plan.delay_ms));
        }
        let dup = self.rng.bernoulli(self.plan.duplicate);
        if self.held.is_none() && self.rng.bernoulli(self.plan.reorder) {
            self.held = Some(frame);
            return Ok(()); // goes out after the next frame
        }
        self.inner.send_frame(&frame)?;
        if dup {
            self.inner.send_frame(&frame)?;
        }
        self.flush_held()
    }
}

impl Connection for ChaosConn {
    fn send(&mut self, msg: &Msg) -> Result<(), WireError> {
        if !is_data(msg) {
            // control plane: anything reordered before it goes first
            self.flush_held()?;
            return self.inner.send(msg);
        }
        if let Some(n) = self.plan.hang_after {
            if self.faulted >= n {
                return Ok(()); // scripted hang: swallow silently
            }
        }
        self.faulted += 1;
        // Byzantine tamper happens before encoding: the frame checksums
        // clean and only result verification can catch it
        let tampered;
        let msg = match msg {
            Msg::Result(r) if self.rng.bernoulli(self.plan.tamper) => {
                tampered = Msg::Result(ResultMsg {
                    payload: perturb(&r.payload, &mut self.rng),
                    ..r.clone()
                });
                &tampered
            }
            Msg::RatelessResult(r) if self.rng.bernoulli(self.plan.tamper) => {
                tampered = Msg::RatelessResult(RatelessResultMsg {
                    payload: perturb(&r.payload, &mut self.rng),
                    ..r.clone()
                });
                &tampered
            }
            _ => msg,
        };
        let frame = wire::encode(msg)?;
        self.put(frame)
    }

    fn send_frame(&mut self, frame: &[u8]) -> Result<(), WireError> {
        // pre-encoded frames bypass injection (the escape hatch is for
        // tests that build their own damage)
        self.inner.send_frame(frame)
    }

    fn recv_timeout(
        &mut self,
        timeout: Option<Duration>,
    ) -> Result<Option<Msg>, WireError> {
        self.inner.recv_timeout(timeout)
    }

    fn peer(&self) -> &str {
        self.inner.peer()
    }
}

/// A [`Transport`] that wraps every accepted connection in a
/// [`ChaosConn`], each on its own RNG stream — coordinator-side chaos
/// for soak tests that damage *outbound* job frames too.
pub struct ChaosTransport {
    inner: Box<dyn Transport>,
    plan: FaultPlan,
    accepted: u64,
}

impl ChaosTransport {
    pub fn new(inner: Box<dyn Transport>, plan: &FaultPlan) -> ChaosTransport {
        ChaosTransport { inner, plan: plan.clone(), accepted: 0 }
    }
}

impl Transport for ChaosTransport {
    fn accept_timeout(
        &mut self,
        timeout: Duration,
    ) -> Result<Option<Box<dyn Connection>>, WireError> {
        match self.inner.accept_timeout(timeout)? {
            Some(conn) => {
                let stream = self.accepted;
                self.accepted += 1;
                Ok(Some(Box::new(ChaosConn::with_stream(
                    conn,
                    &self.plan,
                    stream,
                ))))
            }
            None => Ok(None),
        }
    }

    fn local_addr(&self) -> String {
        self.inner.local_addr()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::transport::loopback_pair;
    use crate::linalg::matmul;

    fn result_msg(slot: u32) -> Msg {
        let mut rng = Pcg64::seed_from(slot as u64 + 100);
        let a = Matrix::randn(4, 3, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(3, 4, 0.0, 1.0, &mut rng);
        Msg::Result(ResultMsg {
            request_id: 1,
            slot,
            attempt: 0,
            delay: 0.1,
            compute_secs: 0.0,
            payload: matmul(&a, &b),
        })
    }

    fn rateless_msg(seq: u32) -> Msg {
        let mut rng = Pcg64::seed_from(seq as u64 + 200);
        Msg::RatelessResult(RatelessResultMsg {
            request_id: 1,
            stream: 0,
            seq,
            attempt: 0,
            delay: 0.1,
            compute_secs: 0.0,
            more: true,
            payload: Matrix::randn(4, 4, 0.0, 1.0, &mut rng),
        })
    }

    fn chaos_pair(plan: FaultPlan) -> (ChaosConn, Box<dyn Connection>) {
        let (a, b) = loopback_pair("chaos", "peer");
        (ChaosConn::new(Box::new(a), &plan), Box::new(b))
    }

    const WAIT: Duration = Duration::from_millis(50);

    #[test]
    fn spec_parsing_round_trips_and_rejects_garbage() {
        let plan: FaultPlan =
            "drop=0.1,corrupt=0.2,dup=0.3,delay=0.4,delay-ms=5,reorder=0.5,\
             tamper=1,seed=9,hang=3"
                .parse()
                .unwrap();
        assert_eq!(plan.drop, 0.1);
        assert_eq!(plan.corrupt, 0.2);
        assert_eq!(plan.duplicate, 0.3);
        assert_eq!(plan.delay, 0.4);
        assert_eq!(plan.delay_ms, 5);
        assert_eq!(plan.reorder, 0.5);
        assert_eq!(plan.tamper, 1.0);
        assert_eq!(plan.seed, 9);
        assert_eq!(plan.hang_after, Some(3));
        assert_eq!("".parse::<FaultPlan>().unwrap(), FaultPlan::default());
        assert!("drop=1.5".parse::<FaultPlan>().is_err(), "out-of-range prob");
        assert!("drop".parse::<FaultPlan>().is_err(), "missing value");
        assert!("explode=1".parse::<FaultPlan>().is_err(), "unknown key");
        assert!("seed=x".parse::<FaultPlan>().is_err(), "non-integer seed");
    }

    #[test]
    fn tampered_results_decode_cleanly_but_payloads_differ() {
        let plan = FaultPlan { tamper: 1.0, seed: 3, ..FaultPlan::default() };
        let (mut chaos, mut peer) = chaos_pair(plan);
        let sent = result_msg(0);
        chaos.send(&sent).unwrap();
        // the frame is wire-perfect — it decodes without any error …
        let got = peer.recv_timeout(Some(WAIT)).unwrap().unwrap();
        let (Msg::Result(s), Msg::Result(g)) = (&sent, &got) else {
            panic!("expected results");
        };
        assert_eq!(g.slot, s.slot);
        // … but the payload is a lie
        assert_ne!(g.payload.data(), s.payload.data());
    }

    #[test]
    fn corrupted_frames_surface_as_bad_checksum() {
        let plan = FaultPlan { corrupt: 1.0, seed: 4, ..FaultPlan::default() };
        let (mut chaos, mut peer) = chaos_pair(plan);
        chaos.send(&result_msg(0)).unwrap();
        assert!(matches!(
            peer.recv_timeout(Some(WAIT)),
            Err(WireError::BadChecksum { .. })
        ));
        // the connection survives: an intact follow-up still lands
        let clean = FaultPlan::default();
        let mut honest = ChaosConn { plan: clean, ..chaos };
        honest.send(&result_msg(1)).unwrap();
        let got = honest_recv(&mut peer);
        assert!(matches!(got, Msg::Result(r) if r.slot == 1));
    }

    fn honest_recv(peer: &mut Box<dyn Connection>) -> Msg {
        peer.recv_timeout(Some(WAIT)).unwrap().unwrap()
    }

    #[test]
    fn dropped_frames_never_arrive() {
        let plan = FaultPlan { drop: 1.0, seed: 5, ..FaultPlan::default() };
        let (mut chaos, mut peer) = chaos_pair(plan);
        chaos.send(&result_msg(0)).unwrap();
        assert!(peer.recv_timeout(Some(WAIT)).unwrap().is_none());
    }

    #[test]
    fn duplicated_frames_arrive_twice() {
        let plan = FaultPlan { duplicate: 1.0, seed: 6, ..FaultPlan::default() };
        let (mut chaos, mut peer) = chaos_pair(plan);
        chaos.send(&result_msg(0)).unwrap();
        for _ in 0..2 {
            assert!(matches!(honest_recv(&mut peer), Msg::Result(r) if r.slot == 0));
        }
        assert!(peer.recv_timeout(Some(WAIT)).unwrap().is_none());
    }

    #[test]
    fn reordering_swaps_adjacent_data_frames() {
        let plan = FaultPlan { reorder: 1.0, seed: 7, ..FaultPlan::default() };
        let (mut chaos, mut peer) = chaos_pair(plan);
        chaos.send(&result_msg(0)).unwrap(); // held
        chaos.send(&result_msg(1)).unwrap(); // goes first, flushes 0
        let first = honest_recv(&mut peer);
        let second = honest_recv(&mut peer);
        assert!(matches!(first, Msg::Result(r) if r.slot == 1));
        assert!(matches!(second, Msg::Result(r) if r.slot == 0));
    }

    #[test]
    fn control_plane_is_exempt_and_flushes_held_frames() {
        let plan =
            FaultPlan { drop: 1.0, reorder: 1.0, seed: 8, ..FaultPlan::default() };
        let (mut chaos, mut peer) = chaos_pair(plan);
        // data frames all drop under drop=1 …
        chaos.send(&result_msg(0)).unwrap();
        assert!(peer.recv_timeout(Some(WAIT)).unwrap().is_none());
        // … but the control plane always gets through
        chaos.send(&Msg::HeartbeatAck { nonce: 7 }).unwrap();
        assert!(matches!(
            honest_recv(&mut peer),
            Msg::HeartbeatAck { nonce: 7 }
        ));
    }

    #[test]
    fn scripted_hang_swallows_data_after_the_count() {
        let plan = FaultPlan { hang_after: Some(1), ..FaultPlan::default() };
        let (mut chaos, mut peer) = chaos_pair(plan);
        chaos.send(&result_msg(0)).unwrap(); // the one allowed frame
        chaos.send(&result_msg(1)).unwrap(); // hung
        chaos.send(&result_msg(2)).unwrap(); // hung
        assert!(matches!(honest_recv(&mut peer), Msg::Result(r) if r.slot == 0));
        assert!(peer.recv_timeout(Some(WAIT)).unwrap().is_none());
        // control still flows while the data plane hangs
        chaos.send(&Msg::HeartbeatAck { nonce: 1 }).unwrap();
        assert!(matches!(honest_recv(&mut peer), Msg::HeartbeatAck { nonce: 1 }));
    }

    #[test]
    fn rateless_result_frames_are_data_plane_but_drain_is_control() {
        // tamper perturbs the packet payload yet the frame stays
        // wire-perfect — only Freivalds can catch it
        let plan = FaultPlan { tamper: 1.0, seed: 12, ..FaultPlan::default() };
        let (mut chaos, mut peer) = chaos_pair(plan);
        let sent = rateless_msg(0);
        chaos.send(&sent).unwrap();
        let got = honest_recv(&mut peer);
        let (Msg::RatelessResult(s), Msg::RatelessResult(g)) = (&sent, &got)
        else {
            panic!("expected rateless results");
        };
        assert_eq!(g.seq, s.seq);
        assert_ne!(g.payload.data(), s.payload.data());
        // drop swallows packet frames; Drain (stream control) still flows
        let plan = FaultPlan { drop: 1.0, seed: 13, ..FaultPlan::default() };
        let (mut chaos, mut peer) = chaos_pair(plan);
        chaos.send(&rateless_msg(1)).unwrap();
        assert!(peer.recv_timeout(Some(WAIT)).unwrap().is_none());
        chaos.send(&Msg::Drain { request_id: 1 }).unwrap();
        assert!(matches!(honest_recv(&mut peer), Msg::Drain { request_id: 1 }));
    }

    #[test]
    fn same_seed_same_faults() {
        // a mixed plan applied twice to the same traffic produces the
        // same arrivals (count and content)
        let plan: FaultPlan =
            "drop=0.3,corrupt=0.3,dup=0.3,tamper=0.3,seed=11".parse().unwrap();
        let observe = || {
            let (mut chaos, mut peer) = chaos_pair(plan.clone());
            let mut log: Vec<String> = Vec::new();
            for slot in 0..20 {
                chaos.send(&result_msg(slot)).unwrap();
                loop {
                    match peer.recv_timeout(Some(Duration::from_millis(5))) {
                        Ok(Some(Msg::Result(r))) => log.push(format!(
                            "slot={} sum={:.12e}",
                            r.slot,
                            r.payload.data().iter().sum::<f64>()
                        )),
                        Ok(Some(_)) => log.push("other".to_string()),
                        Ok(None) => break,
                        Err(e) => log.push(format!("err={e}")),
                    }
                }
            }
            log
        };
        assert_eq!(observe(), observe());
    }
}
