//! The cluster wire protocol: a dependency-free, length-prefixed binary
//! framing with a versioned header, used verbatim over TCP sockets and
//! over in-process loopback channels (so loopback tests exercise the
//! exact byte format the network sees).
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//!      0     4  magic  "UEPW"
//!      4     2  protocol version (currently 6)
//!      6     1  message type tag
//!      7     1  reserved (0)
//!      8     4  payload length in bytes
//!     12     n  payload (per-type encoding below)
//!   12+n     4  CRC32 of header + payload (v4 integrity trailer)
//! ```
//!
//! Matrix payloads are `rows: u32, cols: u32, rows·cols × f64` — raw
//! little-endian bit patterns, so values survive the wire bit-identically
//! (JSON is reserved for configuration; bulk data never goes through
//! text). Strings are `len: u32 + UTF-8 bytes`; optional floats are a
//! one-byte presence tag followed by the value when present.

use std::sync::Arc;

use crate::linalg::Matrix;

/// Frame magic: distinguishes the protocol from stray TCP traffic.
pub const MAGIC: [u8; 4] = *b"UEPW";
/// Protocol version carried in every frame header. Version 2 added the
/// `attempt` counter to job and result frames (re-dispatch of jobs
/// stranded on dead workers); version 3 added `compute_secs` timing
/// telemetry to result frames (worker-measured wall compute time,
/// feeding the coordinator's latency estimators); version 4 added the
/// CRC32 integrity trailer after every payload, so channel corruption
/// is detected ([`WireError::BadChecksum`]) instead of silently
/// poisoning the decode; version 5 added the rateless multi-packet
/// frames — [`RatelessJobMsg`] (one job, a whole packet stream),
/// [`RatelessResultMsg`] (`seq` + `more` per packet), `Drain` (stop a
/// stream on decode completion) and `Redo` (regenerate one lost
/// packet); version 6 added the multi-tenant client plane — session
/// handshake (`OpenSession`/`CloseSession`), request submission
/// ([`SubmitMsg`]: partitioning, coefficient rows, coded factor
/// blocks and optional scoring gram), streamed progress
/// ([`ProgressMsg`]), the final decode report ([`ClientResultMsg`])
/// and admission-control `Reject{retry_after}` frames.
pub const VERSION: u16 = 6;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 12;
/// Size of the CRC32 trailer appended after every payload (v4).
pub const TRAILER_LEN: usize = 4;
/// Hard ceiling on a single frame's payload (guards against a corrupt
/// or hostile length field allocating unbounded memory).
pub const MAX_PAYLOAD: usize = 1 << 28;

/// Message type tags (byte 6 of the header).
const TAG_HELLO: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_JOB: u8 = 3;
const TAG_RESULT: u8 = 4;
const TAG_HEARTBEAT: u8 = 5;
const TAG_HEARTBEAT_ACK: u8 = 6;
const TAG_SHUTDOWN: u8 = 7;
const TAG_RATELESS_JOB: u8 = 8;
const TAG_RATELESS_RESULT: u8 = 9;
const TAG_DRAIN: u8 = 10;
const TAG_REDO: u8 = 11;
const TAG_OPEN_SESSION: u8 = 12;
const TAG_SUBMIT: u8 = 13;
const TAG_PROGRESS: u8 = 14;
const TAG_CLIENT_RESULT: u8 = 15;
const TAG_REJECT: u8 = 16;
const TAG_CLOSE_SESSION: u8 = 17;

/// Is `tag` one of the known message type tags? Checked before the CRC
/// so an unknown type reports [`WireError::UnknownType`] rather than the
/// (also true, but less specific) checksum mismatch.
fn tag_known(tag: u8) -> bool {
    (TAG_HELLO..=TAG_CLOSE_SESSION).contains(&tag)
}

// ---------------------------------------------------------------- crc32

/// Table for the reflected CRC-32 (IEEE 802.3 polynomial 0xEDB88320) —
/// hand-rolled and built at compile time; no dependency needed.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// Incremental CRC-32 (IEEE): feed byte slices in any split and
/// [`Crc32::finalize`] yields exactly what [`crc32`] computes over
/// their concatenation. This is what lets the vectored-send hot path
/// seal a frame whose header, prefix and shared payload body live in
/// *separate* buffers without first copying them together.
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finalize(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

/// CRC-32 (IEEE) of `bytes` — the checksum carried in every v4 frame
/// trailer, computed over header + payload.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finalize()
}

/// A coded job dispatched to one worker: the two factor matrices it must
/// multiply, plus straggle bookkeeping. `injected_delay` is the virtual
/// completion time pre-sampled by the coordinator (deterministic seeded
/// runs); when absent the worker models its own latency or reports real
/// elapsed time. `sleep_secs` is how long the worker should pace the
/// reply in wall time (0 = reply immediately).
#[derive(Clone, Debug, PartialEq)]
pub struct JobMsg {
    pub request_id: u64,
    /// Packet slot in the request's job set (indexes `plan.packets`).
    pub slot: u32,
    /// Zero-based dispatch attempt for this slot: `0` for the first
    /// send, `n` for the `n`-th re-dispatch after the previous holder
    /// died. Workers echo it back in the result so the coordinator can
    /// attribute duplicates.
    pub attempt: u32,
    pub injected_delay: Option<f64>,
    pub sleep_secs: f64,
    /// Shared left factor: on the coordinator this is usually a handle
    /// into the encoded-block cache, so building a `JobMsg` never
    /// deep-copies `W_A` (the wire codec serializes straight from the
    /// shared buffer).
    pub wa: Arc<Matrix>,
    /// Shared right factor: the coordinator's job table retains a handle
    /// to every dispatched payload until its result lands, so a
    /// re-dispatch onto a surviving worker resends the same buffer
    /// instead of rebuilding (or deep-copying) it.
    pub wb: Arc<Matrix>,
}

/// A computed sub-product streaming back to the coordinator. `delay` is
/// the worker's virtual completion time (injected, self-sampled, or
/// measured), which the coordinator checks against the request deadline.
/// `attempt` echoes the job's dispatch attempt: two results for the same
/// `(request_id, slot)` under different attempts are duplicates, and the
/// coordinator absorbs exactly one.
#[derive(Clone, Debug, PartialEq)]
pub struct ResultMsg {
    pub request_id: u64,
    pub slot: u32,
    pub attempt: u32,
    pub delay: f64,
    /// Wall seconds the worker spent on the matmul itself (protocol v3
    /// timing telemetry): the straggle-free compute floor, which lets
    /// the coordinator's latency estimators separate "slow because
    /// straggling" from "slow because the job is big".
    pub compute_secs: f64,
    pub payload: Matrix,
}

/// One rateless job (protocol v5): instead of a single `(W_A, W_B)`
/// pair, the worker receives everything needed to *derive* an entire
/// packet stream — the raw factor blocks, the unknown→(a, b) factor
/// table, the per-unknown class vector, and the robust-Soliton/window
/// parameters. Coefficients never cross the wire: both ends run the
/// same [`crate::coding::RatelessCoder`] seeded per
/// `(request_id, stream, seq)`.
#[derive(Clone, Debug, PartialEq)]
pub struct RatelessJobMsg {
    pub request_id: u64,
    /// Packet-stream selector (the worker's slot in the request). Any
    /// worker holding this job context can regenerate any stream's
    /// packets — that is what makes `Redo` cheap.
    pub stream: u64,
    /// How many packets to generate and send (`seq = 0..budget`).
    /// `0` = context only: hold the job for `Redo` requests.
    pub budget: u32,
    /// Robust-Soliton failure parameter δ.
    pub delta: f64,
    /// Robust-Soliton spike constant c.
    pub c: f64,
    /// Window-sampling weights Γ (already resized to the class count).
    pub gamma: Vec<f64>,
    /// Class of each unknown — the worker rebuilds the expanding
    /// windows from this.
    pub class_of: Vec<u32>,
    /// `factors[u] = (a_idx, b_idx)`: which factor blocks unknown `u`
    /// multiplies.
    pub factors: Vec<(u32, u32)>,
    /// Injected cumulative virtual arrival time per `seq`
    /// (deterministic runs). Empty = the worker self-paces from its own
    /// straggle model or measured time.
    pub delays: Vec<f64>,
    /// Request deadline (virtual seconds) — caps wall sleeping.
    pub t_max: f64,
    /// Virtual→wall pacing factor for sleeps.
    pub pace: f64,
    /// The raw split blocks of `A` (shared handles, serialized from the
    /// shared buffers).
    pub a_blocks: Vec<Arc<Matrix>>,
    /// The raw split blocks of `B`.
    pub b_blocks: Vec<Arc<Matrix>>,
}

/// One packet of a rateless result stream (protocol v5).
#[derive(Clone, Debug, PartialEq)]
pub struct RatelessResultMsg {
    pub request_id: u64,
    /// Which packet stream this payload belongs to (usually the sending
    /// worker's own slot; a `Redo` reply carries the original stream).
    pub stream: u64,
    /// Packet sequence number within the stream.
    pub seq: u32,
    /// `0` for the in-order stream, `n` for the `n`-th regeneration.
    pub attempt: u32,
    /// Virtual completion time of this packet.
    pub delay: f64,
    /// Worker-measured wall compute seconds for this packet.
    pub compute_secs: f64,
    /// More packets follow in this stream? `false` on the last budgeted
    /// packet, so the coordinator can immediately re-request anything
    /// missing instead of waiting out a stall timeout.
    pub more: bool,
    pub payload: Matrix,
}

/// One complete matmul request submitted by a remote client to the
/// multi-tenant serve plane (protocol v6). The client ships everything
/// the plane needs to dispatch, verify and decode *without* the plane
/// ever re-deriving the code: the partitioning (all-public dims, so it
/// reconstructs literally), the dense coefficient row of every packet
/// (expanded client-side from the seeded generator — the plane never
/// needs the generator), the coded factor blocks per slot, and an
/// optional scoring gram. `C_true` deliberately never crosses the
/// wire: approximation losses are computed plane-side from the gram
/// alone (Remark 2's loss identities need only `WᵀW` and the total
/// energy).
#[derive(Clone, Debug, PartialEq)]
pub struct SubmitMsg {
    /// Session id assigned by the plane's `OpenSession` ack.
    pub session: u64,
    /// Client-chosen request sequence number, echoed in every
    /// `ProgressFrame`/`ClientResult`/`Reject` for this request.
    pub request: u64,
    /// Deadline in virtual seconds.
    pub t_max: f64,
    /// Partitioning paradigm: 0 = row×column, 1 = column×row.
    pub paradigm: u8,
    /// The six `Partitioning` dimension fields `n, p, m, u, h, q`.
    pub dims: [u32; 6],
    /// Total unknowns (real + virtual) — every coefficient row is this
    /// long.
    pub n_total: u32,
    /// Number of UEP classes.
    pub n_classes: u32,
    /// Class of each *real* unknown.
    pub class_of: Vec<u32>,
    /// Dense coefficient row of each packet over the unknown space
    /// (`rows[slot].len() == n_total`).
    pub rows: Vec<Vec<f64>>,
    /// Coded left factor per slot (shared handles; serialized straight
    /// from the encode cache's buffers).
    pub wa: Vec<Arc<Matrix>>,
    /// Coded right factor per slot.
    pub wb: Vec<Arc<Matrix>>,
    /// Injected per-slot virtual delays (deterministic runs). Empty =
    /// workers pace themselves.
    pub delays: Vec<f64>,
    /// Gram matrix `G[u][v] = <X_u, X_v>` of the true sub-products, for
    /// plane-side loss scoring. `None` = client did not request scoring.
    pub gram: Option<Matrix>,
    /// Total signal energy (the all-unrecovered loss), normalizing the
    /// reported losses.
    pub energy: f64,
}

/// Plane → client: one decode-progress refinement for a request
/// (protocol v6) — the serve-plane twin of
/// [`crate::api::ProgressEvent`].
#[derive(Clone, Debug, PartialEq)]
pub struct ProgressMsg {
    pub session: u64,
    pub request: u64,
    /// Virtual arrival time of the packet that caused this refinement.
    pub elapsed: f64,
    /// Packets absorbed so far.
    pub received: u32,
    /// Unknowns recovered so far.
    pub recovered: u32,
    /// Unknowns newly recovered by this packet.
    pub newly: u32,
    /// Dispatch attempt of the packet.
    pub attempt: u32,
    /// Absolute approximation loss after this refinement (NaN when the
    /// request carries no gram).
    pub loss: f64,
    /// Loss normalized by total energy (NaN without a gram).
    pub normalized_loss: f64,
}

/// Plane → client: the final decode report for one request (protocol
/// v6) — everything [`crate::api::RunReport`] needs that the client
/// cannot know locally.
#[derive(Clone, Debug, PartialEq)]
pub struct ClientResultMsg {
    pub session: u64,
    pub request: u64,
    /// Packets absorbed by the deadline.
    pub received: u32,
    /// Unknowns recovered.
    pub recovered: u32,
    /// Unknowns recovered per UEP class.
    pub per_class: Vec<u32>,
    /// The assembled approximation (zero-filled where unrecovered).
    pub c_hat: Matrix,
    /// Absolute loss (NaN without a gram).
    pub loss: f64,
    /// Energy-normalized loss (NaN without a gram).
    pub normalized_loss: f64,
    /// Results that arrived after `t_max` (still absorbed, flagged late).
    pub late: u32,
    /// Job frames dispatched (including re-dispatches).
    pub dispatched: u32,
    /// Re-dispatches after worker death / verification failure.
    pub retries: u32,
    /// Corrupt frames survived on this request's results.
    pub corrupt: u32,
    /// Freivalds rejections on this request's results.
    pub verify_failures: u32,
    /// Plane-measured wall time serving the request, in milliseconds.
    pub wall_ms: u64,
}

/// Every message that crosses a cluster connection.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Worker → coordinator: register under a human-readable name.
    Hello { agent: String },
    /// Coordinator → worker: registration accepted, id assigned.
    Welcome { worker_id: u64 },
    /// Coordinator → worker: compute one coded sub-product.
    Job(JobMsg),
    /// Worker → coordinator: the computed payload.
    Result(ResultMsg),
    /// Coordinator → worker: liveness probe.
    Heartbeat { nonce: u64 },
    /// Worker → coordinator: liveness reply (echoes the nonce).
    HeartbeatAck { nonce: u64 },
    /// Coordinator → worker: drain and exit cleanly.
    Shutdown,
    /// Coordinator → worker: derive and stream a rateless packet
    /// sequence (v5).
    RatelessJob(RatelessJobMsg),
    /// Worker → coordinator: one packet of a rateless stream (v5).
    RatelessResult(RatelessResultMsg),
    /// Coordinator → worker: the request decoded — stop streaming
    /// packets for it and drop the job context (v5).
    Drain { request_id: u64 },
    /// Coordinator → worker: regenerate one specific packet of one
    /// stream (lost/corrupt frame healing; v5).
    Redo { request_id: u64, stream: u64, seq: u32, attempt: u32 },
    /// Client → plane: open a session (`session` = 0, `client` = a
    /// human-readable tenant name). Plane → client: the ack, echoing
    /// the *assigned* session id (v6).
    OpenSession { session: u64, client: String },
    /// Client → plane: submit one matmul request into the session (v6).
    Submit(SubmitMsg),
    /// Plane → client: one decode-progress refinement (v6).
    ProgressFrame(ProgressMsg),
    /// Plane → client: the final decode report for one request (v6).
    ClientResult(ClientResultMsg),
    /// Plane → client: admission control — the session (`request` = 0)
    /// or one request was refused; retry after `retry_after` seconds
    /// (v6).
    Reject { session: u64, request: u64, retry_after: f64, reason: String },
    /// Client → plane: drain and close the session; the plane echoes
    /// the same frame back once every in-flight request has been
    /// answered (v6).
    CloseSession { session: u64 },
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => TAG_HELLO,
            Msg::Welcome { .. } => TAG_WELCOME,
            Msg::Job(_) => TAG_JOB,
            Msg::Result(_) => TAG_RESULT,
            Msg::Heartbeat { .. } => TAG_HEARTBEAT,
            Msg::HeartbeatAck { .. } => TAG_HEARTBEAT_ACK,
            Msg::Shutdown => TAG_SHUTDOWN,
            Msg::RatelessJob(_) => TAG_RATELESS_JOB,
            Msg::RatelessResult(_) => TAG_RATELESS_RESULT,
            Msg::Drain { .. } => TAG_DRAIN,
            Msg::Redo { .. } => TAG_REDO,
            Msg::OpenSession { .. } => TAG_OPEN_SESSION,
            Msg::Submit(_) => TAG_SUBMIT,
            Msg::ProgressFrame(_) => TAG_PROGRESS,
            Msg::ClientResult(_) => TAG_CLIENT_RESULT,
            Msg::Reject { .. } => TAG_REJECT,
            Msg::CloseSession { .. } => TAG_CLOSE_SESSION,
        }
    }

    /// Short name for logs and protocol errors.
    pub fn name(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "hello",
            Msg::Welcome { .. } => "welcome",
            Msg::Job(_) => "job",
            Msg::Result(_) => "result",
            Msg::Heartbeat { .. } => "heartbeat",
            Msg::HeartbeatAck { .. } => "heartbeat-ack",
            Msg::Shutdown => "shutdown",
            Msg::RatelessJob(_) => "rateless-job",
            Msg::RatelessResult(_) => "rateless-result",
            Msg::Drain { .. } => "drain",
            Msg::Redo { .. } => "redo",
            Msg::OpenSession { .. } => "open-session",
            Msg::Submit(_) => "submit",
            Msg::ProgressFrame(_) => "progress",
            Msg::ClientResult(_) => "client-result",
            Msg::Reject { .. } => "reject",
            Msg::CloseSession { .. } => "close-session",
        }
    }
}

/// Everything that can go wrong on the wire.
#[derive(Debug)]
pub enum WireError {
    Io(std::io::Error),
    BadMagic([u8; 4]),
    BadVersion(u16),
    UnknownType(u8),
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    Oversized { len: usize, max: usize },
    /// Encode-side: a length or dimension does not fit its wire-format
    /// field. Casting (`as u32`) would silently truncate and produce a
    /// structurally valid frame describing the *wrong* data, so the
    /// encoder refuses instead.
    Oversize { what: &'static str, value: usize, max: usize },
    /// The frame's CRC32 trailer does not match its bytes: the frame was
    /// corrupted in flight. The header survived its own field checks, so
    /// the frame's extent is known — transports drain the bad frame and
    /// keep the connection parse loop alive (see
    /// [`frame_len`]).
    BadChecksum { got: u32, want: u32 },
    /// The buffer ends before the frame does.
    Truncated { need: usize, have: usize },
    /// Structurally invalid payload (bad lengths, trailing bytes, …).
    Malformed(&'static str),
    /// The peer closed the connection.
    Closed,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            WireError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (speak {VERSION})")
            }
            WireError::UnknownType(t) => write!(f, "unknown message type {t}"),
            WireError::Oversized { len, max } => {
                write!(f, "frame payload of {len} bytes exceeds cap {max}")
            }
            WireError::Oversize { what, value, max } => {
                write!(f, "{what} of {value} does not fit the wire format (max {max})")
            }
            WireError::BadChecksum { got, want } => {
                write!(f, "frame checksum mismatch: got {got:#010x}, want {want:#010x}")
            }
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            WireError::Malformed(what) => write!(f, "malformed payload: {what}"),
            WireError::Closed => write!(f, "connection closed by peer"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

// ---------------------------------------------------------------- encode

/// Checked conversion into a `u32` wire field. The unchecked `as u32`
/// cast this replaces would silently truncate a ≥ 4 GiB length or a
/// ≥ 2³² dimension into a small number that decodes "successfully" into
/// garbage; refusing at encode time keeps the fault at its source.
pub(crate) fn wire_u32(what: &'static str, value: usize) -> Result<u32, WireError> {
    u32::try_from(value).map_err(|_| WireError::Oversize {
        what,
        value,
        max: u32::MAX as usize,
    })
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) -> Result<(), WireError> {
    put_u32(out, wire_u32("string length", s.len())?);
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

fn put_opt_f64(out: &mut Vec<u8>, v: Option<f64>) {
    match v {
        Some(x) => {
            out.push(1);
            put_f64(out, x);
        }
        None => out.push(0),
    }
}

fn put_matrix(out: &mut Vec<u8>, m: &Matrix) -> Result<(), WireError> {
    put_u32(out, wire_u32("matrix rows", m.rows())?);
    put_u32(out, wire_u32("matrix cols", m.cols())?);
    out.reserve(m.data().len() * 8);
    for &x in m.data() {
        out.extend_from_slice(&x.to_le_bytes());
    }
    Ok(())
}

fn put_f64s(out: &mut Vec<u8>, xs: &[f64]) -> Result<(), WireError> {
    put_u32(out, wire_u32("f64 vector length", xs.len())?);
    for &x in xs {
        put_f64(out, x);
    }
    Ok(())
}

fn put_u32s(out: &mut Vec<u8>, xs: &[u32]) -> Result<(), WireError> {
    put_u32(out, wire_u32("u32 vector length", xs.len())?);
    for &x in xs {
        put_u32(out, x);
    }
    Ok(())
}

fn put_pairs(out: &mut Vec<u8>, xs: &[(u32, u32)]) -> Result<(), WireError> {
    put_u32(out, wire_u32("pair vector length", xs.len())?);
    for &(a, b) in xs {
        put_u32(out, a);
        put_u32(out, b);
    }
    Ok(())
}

fn put_matrices(out: &mut Vec<u8>, ms: &[Arc<Matrix>]) -> Result<(), WireError> {
    put_u32(out, wire_u32("matrix vector length", ms.len())?);
    for m in ms {
        put_matrix(out, m)?;
    }
    Ok(())
}

fn put_opt_matrix(out: &mut Vec<u8>, m: Option<&Matrix>) -> Result<(), WireError> {
    match m {
        Some(m) => {
            out.push(1);
            put_matrix(out, m)?;
        }
        None => out.push(0),
    }
    Ok(())
}

fn put_f64_rows(out: &mut Vec<u8>, rows: &[Vec<f64>]) -> Result<(), WireError> {
    put_u32(out, wire_u32("row vector length", rows.len())?);
    for r in rows {
        put_f64s(out, r)?;
    }
    Ok(())
}

/// Wire size of a matrix payload (shape header + elements).
fn matrix_wire_len(m: &Matrix) -> usize {
    8 + m.data().len() * 8
}

/// Wire size of a length-prefixed matrix vector.
fn matrices_wire_len(ms: &[Arc<Matrix>]) -> usize {
    4 + ms.iter().map(|m| matrix_wire_len(m)).sum::<usize>()
}

/// Serialize one message as a complete frame (header + payload).
/// Job/result frames carry megabytes at paper scale and encoding sits
/// inside the request's deadline budget, so the payload buffer is sized
/// exactly upfront — no doubling reallocations on the dispatch path.
/// Lengths and dimensions that do not fit their wire fields (and
/// payloads past [`MAX_PAYLOAD`]) report [`WireError::Oversize`] /
/// [`WireError::Oversized`] instead of truncating.
pub fn encode(msg: &Msg) -> Result<Vec<u8>, WireError> {
    let capacity = match msg {
        Msg::Hello { agent } => 4 + agent.len(),
        // 8 request_id + 4 slot + 4 attempt + 9 option tag+f64 + 8 sleep
        Msg::Job(j) => 33 + matrix_wire_len(&j.wa) + matrix_wire_len(&j.wb),
        // 8 request_id + 4 slot + 4 attempt + 8 delay + 8 compute_secs
        Msg::Result(r) => 32 + matrix_wire_len(&r.payload),
        // 8 request + 8 stream + 4 budget + 8 delta + 8 c + 8 t_max +
        // 8 pace + length-prefixed vectors
        Msg::RatelessJob(j) => {
            52 + (4 + j.gamma.len() * 8)
                + (4 + j.class_of.len() * 4)
                + (4 + j.factors.len() * 8)
                + (4 + j.delays.len() * 8)
                + matrices_wire_len(&j.a_blocks)
                + matrices_wire_len(&j.b_blocks)
        }
        // 8 request + 8 stream + 4 seq + 4 attempt + 8 delay +
        // 8 compute_secs + 1 more flag
        Msg::RatelessResult(r) => 41 + matrix_wire_len(&r.payload),
        // 8 request + 8 stream + 4 seq + 4 attempt
        Msg::Redo { .. } => 24,
        Msg::OpenSession { client, .. } => 12 + client.len(),
        // 8 session + 8 request + 8 t_max + 1 paradigm + 24 dims +
        // 4 n_total + 4 n_classes + 8 energy + length-prefixed vectors
        Msg::Submit(s) => {
            65 + (4 + s.class_of.len() * 4)
                + (4 + s.rows.iter().map(|r| 4 + r.len() * 8).sum::<usize>())
                + matrices_wire_len(&s.wa)
                + matrices_wire_len(&s.wb)
                + (4 + s.delays.len() * 8)
                + (1 + s.gram.as_ref().map_or(0, matrix_wire_len))
        }
        // 8 session + 8 request + 8 elapsed + 4·4 counters + 2·8 losses
        Msg::ProgressFrame(_) => 56,
        // 8 session + 8 request + 2·4 counts + per_class + c_hat +
        // 2·8 losses + 5·4 counters + 8 wall_ms
        Msg::ClientResult(r) => {
            68 + (4 + r.per_class.len() * 4) + matrix_wire_len(&r.c_hat)
        }
        // 8 session + 8 request + 8 retry_after + reason
        Msg::Reject { reason, .. } => 28 + reason.len(),
        _ => 8,
    };
    let mut payload = Vec::with_capacity(capacity);
    match msg {
        Msg::Hello { agent } => put_str(&mut payload, agent)?,
        Msg::Welcome { worker_id } => put_u64(&mut payload, *worker_id),
        Msg::Job(j) => {
            put_u64(&mut payload, j.request_id);
            put_u32(&mut payload, j.slot);
            put_u32(&mut payload, j.attempt);
            put_opt_f64(&mut payload, j.injected_delay);
            put_f64(&mut payload, j.sleep_secs);
            put_matrix(&mut payload, &j.wa)?;
            put_matrix(&mut payload, &j.wb)?;
        }
        Msg::Result(r) => {
            put_u64(&mut payload, r.request_id);
            put_u32(&mut payload, r.slot);
            put_u32(&mut payload, r.attempt);
            put_f64(&mut payload, r.delay);
            put_f64(&mut payload, r.compute_secs);
            put_matrix(&mut payload, &r.payload)?;
        }
        Msg::Heartbeat { nonce } | Msg::HeartbeatAck { nonce } => {
            put_u64(&mut payload, *nonce)
        }
        Msg::Shutdown => {}
        Msg::RatelessJob(j) => {
            put_u64(&mut payload, j.request_id);
            put_u64(&mut payload, j.stream);
            put_u32(&mut payload, j.budget);
            put_f64(&mut payload, j.delta);
            put_f64(&mut payload, j.c);
            put_f64s(&mut payload, &j.gamma)?;
            put_u32s(&mut payload, &j.class_of)?;
            put_pairs(&mut payload, &j.factors)?;
            put_f64s(&mut payload, &j.delays)?;
            put_f64(&mut payload, j.t_max);
            put_f64(&mut payload, j.pace);
            put_matrices(&mut payload, &j.a_blocks)?;
            put_matrices(&mut payload, &j.b_blocks)?;
        }
        Msg::RatelessResult(r) => {
            put_u64(&mut payload, r.request_id);
            put_u64(&mut payload, r.stream);
            put_u32(&mut payload, r.seq);
            put_u32(&mut payload, r.attempt);
            put_f64(&mut payload, r.delay);
            put_f64(&mut payload, r.compute_secs);
            payload.push(r.more as u8);
            put_matrix(&mut payload, &r.payload)?;
        }
        Msg::Drain { request_id } => put_u64(&mut payload, *request_id),
        Msg::Redo { request_id, stream, seq, attempt } => {
            put_u64(&mut payload, *request_id);
            put_u64(&mut payload, *stream);
            put_u32(&mut payload, *seq);
            put_u32(&mut payload, *attempt);
        }
        Msg::OpenSession { session, client } => {
            put_u64(&mut payload, *session);
            put_str(&mut payload, client)?;
        }
        Msg::Submit(s) => {
            put_u64(&mut payload, s.session);
            put_u64(&mut payload, s.request);
            put_f64(&mut payload, s.t_max);
            payload.push(s.paradigm);
            for &d in &s.dims {
                put_u32(&mut payload, d);
            }
            put_u32(&mut payload, s.n_total);
            put_u32(&mut payload, s.n_classes);
            put_u32s(&mut payload, &s.class_of)?;
            put_f64_rows(&mut payload, &s.rows)?;
            put_matrices(&mut payload, &s.wa)?;
            put_matrices(&mut payload, &s.wb)?;
            put_f64s(&mut payload, &s.delays)?;
            put_opt_matrix(&mut payload, s.gram.as_ref())?;
            put_f64(&mut payload, s.energy);
        }
        Msg::ProgressFrame(p) => {
            put_u64(&mut payload, p.session);
            put_u64(&mut payload, p.request);
            put_f64(&mut payload, p.elapsed);
            put_u32(&mut payload, p.received);
            put_u32(&mut payload, p.recovered);
            put_u32(&mut payload, p.newly);
            put_u32(&mut payload, p.attempt);
            put_f64(&mut payload, p.loss);
            put_f64(&mut payload, p.normalized_loss);
        }
        Msg::ClientResult(r) => {
            put_u64(&mut payload, r.session);
            put_u64(&mut payload, r.request);
            put_u32(&mut payload, r.received);
            put_u32(&mut payload, r.recovered);
            put_u32s(&mut payload, &r.per_class)?;
            put_matrix(&mut payload, &r.c_hat)?;
            put_f64(&mut payload, r.loss);
            put_f64(&mut payload, r.normalized_loss);
            put_u32(&mut payload, r.late);
            put_u32(&mut payload, r.dispatched);
            put_u32(&mut payload, r.retries);
            put_u32(&mut payload, r.corrupt);
            put_u32(&mut payload, r.verify_failures);
            put_u64(&mut payload, r.wall_ms);
        }
        Msg::Reject { session, request, retry_after, reason } => {
            put_u64(&mut payload, *session);
            put_u64(&mut payload, *request);
            put_f64(&mut payload, *retry_after);
            put_str(&mut payload, reason)?;
        }
        Msg::CloseSession { session } => put_u64(&mut payload, *session),
    }
    if payload.len() > MAX_PAYLOAD {
        return Err(WireError::Oversized { len: payload.len(), max: MAX_PAYLOAD });
    }
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(msg.tag());
    out.push(0); // reserved
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    // v4 integrity trailer: CRC32 over everything written so far
    // (header + payload), so any in-flight bit flip is detected
    let crc = crc32(&out);
    put_u32(&mut out, crc);
    Ok(out)
}

// ------------------------------------------------- split job encoding
//
// The serve plane dispatches the *same* job payload body (the coded
// `W_A`/`W_B` pair) many times: to the first holder, to re-dispatch
// targets, across retries. Only the tiny per-dispatch prefix
// (request id, slot, attempt, pacing) changes. Splitting the frame
// into `prefix | shared body | trailer` lets the body bytes be
// serialized once per slot and every dispatch go out as a vectored
// write of three buffers — zero copies of the megabyte part.

/// Serialize the shared payload *body* of a job frame — the two coded
/// factor matrices — exactly as [`encode`] would embed them.
pub fn job_body(wa: &Matrix, wb: &Matrix) -> Result<Vec<u8>, WireError> {
    let mut out = Vec::with_capacity(matrix_wire_len(wa) + matrix_wire_len(wb));
    put_matrix(&mut out, wa)?;
    put_matrix(&mut out, wb)?;
    Ok(out)
}

/// Serialize the frame header plus the per-dispatch payload prefix of
/// a job frame whose body ([`job_body`]) is `body_len` bytes long.
/// `job_prefix(..) ++ body ++ job_trailer(prefix, body)` is
/// bit-identical to `encode(&Msg::Job(..))` (asserted by test).
pub fn job_prefix(
    request_id: u64,
    slot: u32,
    attempt: u32,
    injected_delay: Option<f64>,
    sleep_secs: f64,
    body_len: usize,
) -> Result<Vec<u8>, WireError> {
    // 8 request_id + 4 slot + 4 attempt + option tag(+f64) + 8 sleep
    let fields = 25 + if injected_delay.is_some() { 8 } else { 0 };
    let payload_len = fields + body_len;
    if payload_len > MAX_PAYLOAD {
        return Err(WireError::Oversized { len: payload_len, max: MAX_PAYLOAD });
    }
    let mut out = Vec::with_capacity(HEADER_LEN + fields);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(TAG_JOB);
    out.push(0); // reserved
    put_u32(&mut out, wire_u32("job payload length", payload_len)?);
    put_u64(&mut out, request_id);
    put_u32(&mut out, slot);
    put_u32(&mut out, attempt);
    put_opt_f64(&mut out, injected_delay);
    put_f64(&mut out, sleep_secs);
    Ok(out)
}

/// The CRC32 trailer sealing a split job frame: the checksum of
/// `prefix ++ body`, computed incrementally so the two buffers are
/// never concatenated.
pub fn job_trailer(prefix: &[u8], body: &[u8]) -> [u8; 4] {
    let mut crc = Crc32::new();
    crc.update(prefix);
    crc.update(body);
    crc.finalize().to_le_bytes()
}

// ---------------------------------------------------------------- decode

/// Bounds-checked little-endian reader over a payload slice.
struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Rd { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).ok_or(WireError::Malformed("length overflow"))?;
        if end > self.buf.len() {
            return Err(WireError::Truncated { need: end, have: self.buf.len() });
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn opt_f64(&mut self) -> Result<Option<f64>, WireError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.f64()?)),
            _ => Err(WireError::Malformed("bad option tag")),
        }
    }

    fn string(&mut self) -> Result<String, WireError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Malformed("non-UTF-8 string"))
    }

    fn bool(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(WireError::Malformed("bad bool tag")),
        }
    }

    fn f64s(&mut self) -> Result<Vec<f64>, WireError> {
        let len = self.u32()? as usize;
        let bytes = len
            .checked_mul(8)
            .ok_or(WireError::Malformed("f64 vector length overflow"))?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u32s(&mut self) -> Result<Vec<u32>, WireError> {
        let len = self.u32()? as usize;
        let bytes = len
            .checked_mul(4)
            .ok_or(WireError::Malformed("u32 vector length overflow"))?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn pairs(&mut self) -> Result<Vec<(u32, u32)>, WireError> {
        let len = self.u32()? as usize;
        let bytes = len
            .checked_mul(8)
            .ok_or(WireError::Malformed("pair vector length overflow"))?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| {
                (
                    u32::from_le_bytes(c[..4].try_into().unwrap()),
                    u32::from_le_bytes(c[4..].try_into().unwrap()),
                )
            })
            .collect())
    }

    fn matrices(&mut self) -> Result<Vec<Arc<Matrix>>, WireError> {
        let len = self.u32()? as usize;
        // one matrix is ≥ 8 bytes of shape header: cheap sanity bound
        // before reserving
        if len > self.buf.len().saturating_sub(self.pos) / 8 {
            return Err(WireError::Malformed("matrix vector longer than payload"));
        }
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(Arc::new(self.matrix()?));
        }
        Ok(out)
    }

    fn f64_rows(&mut self) -> Result<Vec<Vec<f64>>, WireError> {
        let len = self.u32()? as usize;
        // one row is ≥ 4 bytes of length prefix: cheap sanity bound
        // before reserving
        if len > self.buf.len().saturating_sub(self.pos) / 4 {
            return Err(WireError::Malformed("row vector longer than payload"));
        }
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.f64s()?);
        }
        Ok(out)
    }

    fn opt_matrix(&mut self) -> Result<Option<Matrix>, WireError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.matrix()?)),
            _ => Err(WireError::Malformed("bad option tag")),
        }
    }

    fn matrix(&mut self) -> Result<Matrix, WireError> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let n = rows
            .checked_mul(cols)
            .ok_or(WireError::Malformed("matrix shape overflow"))?;
        // size sanity before allocating: the elements must fit in what is
        // actually present
        let bytes = n
            .checked_mul(8)
            .ok_or(WireError::Malformed("matrix shape overflow"))?;
        let raw = self.take(bytes)?;
        let mut data = Vec::with_capacity(n);
        for chunk in raw.chunks_exact(8) {
            data.push(f64::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(Matrix::from_vec(rows, cols, data))
    }

    fn finish(self) -> Result<(), WireError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(WireError::Malformed("trailing bytes after payload"))
        }
    }
}

/// Length a complete frame would occupy at the front of `buf`, from its
/// header alone: `Some(header + payload + trailer)` once the 12 header
/// bytes are present and carry valid magic/version, `None` otherwise.
/// This is what lets a transport *resync* after
/// [`WireError::BadChecksum`]: the header's own fields were already
/// validated, so the corrupt frame's extent is trustworthy — drain that
/// many bytes and the next frame parses normally.
pub fn frame_len(buf: &[u8]) -> Option<usize> {
    if buf.len() < HEADER_LEN
        || buf[..4] != MAGIC
        || u16::from_le_bytes([buf[4], buf[5]]) != VERSION
    {
        return None;
    }
    let len = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize;
    if len > MAX_PAYLOAD {
        return None;
    }
    Some(HEADER_LEN + len + TRAILER_LEN)
}

/// Decode one complete frame from the front of `buf`. Returns the message
/// and the number of bytes consumed. An incomplete frame reports
/// [`WireError::Truncated`]; corrupt headers report their specific error;
/// a CRC mismatch reports [`WireError::BadChecksum`] (checked before the
/// payload is parsed, so corrupted bytes never reach the decoder).
pub fn decode_frame(buf: &[u8]) -> Result<(Msg, usize), WireError> {
    if buf.len() < HEADER_LEN {
        return Err(WireError::Truncated { need: HEADER_LEN, have: buf.len() });
    }
    let magic = [buf[0], buf[1], buf[2], buf[3]];
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = u16::from_le_bytes([buf[4], buf[5]]);
    if version != VERSION {
        return Err(WireError::BadVersion(version));
    }
    let tag = buf[6];
    if !tag_known(tag) {
        return Err(WireError::UnknownType(tag));
    }
    let len = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize;
    if len > MAX_PAYLOAD {
        return Err(WireError::Oversized { len, max: MAX_PAYLOAD });
    }
    let total = HEADER_LEN + len + TRAILER_LEN;
    if buf.len() < total {
        return Err(WireError::Truncated { need: total, have: buf.len() });
    }
    let body_end = HEADER_LEN + len;
    let want = u32::from_le_bytes([
        buf[body_end],
        buf[body_end + 1],
        buf[body_end + 2],
        buf[body_end + 3],
    ]);
    let got = crc32(&buf[..body_end]);
    if got != want {
        return Err(WireError::BadChecksum { got, want });
    }
    let mut rd = Rd::new(&buf[HEADER_LEN..body_end]);
    let msg = match tag {
        TAG_HELLO => Msg::Hello { agent: rd.string()? },
        TAG_WELCOME => Msg::Welcome { worker_id: rd.u64()? },
        TAG_JOB => Msg::Job(JobMsg {
            request_id: rd.u64()?,
            slot: rd.u32()?,
            attempt: rd.u32()?,
            injected_delay: rd.opt_f64()?,
            sleep_secs: rd.f64()?,
            wa: Arc::new(rd.matrix()?),
            wb: Arc::new(rd.matrix()?),
        }),
        TAG_RESULT => Msg::Result(ResultMsg {
            request_id: rd.u64()?,
            slot: rd.u32()?,
            attempt: rd.u32()?,
            delay: rd.f64()?,
            compute_secs: rd.f64()?,
            payload: rd.matrix()?,
        }),
        TAG_HEARTBEAT => Msg::Heartbeat { nonce: rd.u64()? },
        TAG_HEARTBEAT_ACK => Msg::HeartbeatAck { nonce: rd.u64()? },
        TAG_SHUTDOWN => Msg::Shutdown,
        TAG_RATELESS_JOB => Msg::RatelessJob(RatelessJobMsg {
            request_id: rd.u64()?,
            stream: rd.u64()?,
            budget: rd.u32()?,
            delta: rd.f64()?,
            c: rd.f64()?,
            gamma: rd.f64s()?,
            class_of: rd.u32s()?,
            factors: rd.pairs()?,
            delays: rd.f64s()?,
            t_max: rd.f64()?,
            pace: rd.f64()?,
            a_blocks: rd.matrices()?,
            b_blocks: rd.matrices()?,
        }),
        TAG_RATELESS_RESULT => Msg::RatelessResult(RatelessResultMsg {
            request_id: rd.u64()?,
            stream: rd.u64()?,
            seq: rd.u32()?,
            attempt: rd.u32()?,
            delay: rd.f64()?,
            compute_secs: rd.f64()?,
            more: rd.bool()?,
            payload: rd.matrix()?,
        }),
        TAG_DRAIN => Msg::Drain { request_id: rd.u64()? },
        TAG_REDO => Msg::Redo {
            request_id: rd.u64()?,
            stream: rd.u64()?,
            seq: rd.u32()?,
            attempt: rd.u32()?,
        },
        TAG_OPEN_SESSION => Msg::OpenSession {
            session: rd.u64()?,
            client: rd.string()?,
        },
        TAG_SUBMIT => Msg::Submit(SubmitMsg {
            session: rd.u64()?,
            request: rd.u64()?,
            t_max: rd.f64()?,
            paradigm: rd.u8()?,
            dims: {
                let mut dims = [0u32; 6];
                for d in &mut dims {
                    *d = rd.u32()?;
                }
                dims
            },
            n_total: rd.u32()?,
            n_classes: rd.u32()?,
            class_of: rd.u32s()?,
            rows: rd.f64_rows()?,
            wa: rd.matrices()?,
            wb: rd.matrices()?,
            delays: rd.f64s()?,
            gram: rd.opt_matrix()?,
            energy: rd.f64()?,
        }),
        TAG_PROGRESS => Msg::ProgressFrame(ProgressMsg {
            session: rd.u64()?,
            request: rd.u64()?,
            elapsed: rd.f64()?,
            received: rd.u32()?,
            recovered: rd.u32()?,
            newly: rd.u32()?,
            attempt: rd.u32()?,
            loss: rd.f64()?,
            normalized_loss: rd.f64()?,
        }),
        TAG_CLIENT_RESULT => Msg::ClientResult(ClientResultMsg {
            session: rd.u64()?,
            request: rd.u64()?,
            received: rd.u32()?,
            recovered: rd.u32()?,
            per_class: rd.u32s()?,
            c_hat: rd.matrix()?,
            loss: rd.f64()?,
            normalized_loss: rd.f64()?,
            late: rd.u32()?,
            dispatched: rd.u32()?,
            retries: rd.u32()?,
            corrupt: rd.u32()?,
            verify_failures: rd.u32()?,
            wall_ms: rd.u64()?,
        }),
        TAG_REJECT => Msg::Reject {
            session: rd.u64()?,
            request: rd.u64()?,
            retry_after: rd.f64()?,
            reason: rd.string()?,
        },
        TAG_CLOSE_SESSION => Msg::CloseSession { session: rd.u64()? },
        other => return Err(WireError::UnknownType(other)),
    };
    rd.finish()?;
    Ok((msg, total))
}

/// Streaming variant of [`decode_frame`]: `Ok(None)` when the buffer
/// simply does not hold a complete frame yet (keep reading), `Err` for
/// anything unrecoverable.
pub fn try_decode(buf: &[u8]) -> Result<Option<(Msg, usize)>, WireError> {
    match decode_frame(buf) {
        Ok(hit) => Ok(Some(hit)),
        Err(WireError::Truncated { .. }) => Ok(None),
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn sample_matrix(seed: u64, r: usize, c: usize) -> Matrix {
        let mut rng = Pcg64::seed_from(seed);
        Matrix::randn(r, c, 0.0, 1.0, &mut rng)
    }

    fn all_messages() -> Vec<Msg> {
        vec![
            Msg::Hello { agent: "worker-α".to_string() },
            Msg::Welcome { worker_id: 42 },
            Msg::Job(JobMsg {
                request_id: 7,
                slot: 3,
                attempt: 0,
                injected_delay: Some(0.25),
                sleep_secs: 0.001,
                wa: Arc::new(sample_matrix(1, 4, 6)),
                wb: Arc::new(sample_matrix(2, 6, 5)),
            }),
            Msg::Job(JobMsg {
                request_id: 8,
                slot: 0,
                attempt: 2,
                injected_delay: None,
                sleep_secs: 0.0,
                wa: Arc::new(sample_matrix(3, 1, 1)),
                wb: Arc::new(sample_matrix(4, 1, 1)),
            }),
            Msg::Result(ResultMsg {
                request_id: 7,
                slot: 3,
                attempt: 1,
                delay: 1.75,
                compute_secs: 0.004,
                payload: sample_matrix(5, 4, 5),
            }),
            Msg::Heartbeat { nonce: u64::MAX },
            Msg::HeartbeatAck { nonce: 0 },
            Msg::Shutdown,
            Msg::RatelessJob(RatelessJobMsg {
                request_id: 9,
                stream: 2,
                budget: 17,
                delta: 0.05,
                c: 0.1,
                gamma: vec![0.4, 0.35, 0.25],
                class_of: vec![0, 0, 1, 1, 2, 2],
                factors: vec![(0, 0), (0, 1), (1, 0), (1, 1), (2, 0), (2, 1)],
                delays: vec![0.25, 0.5, 0.75],
                t_max: 2.0,
                pace: 0.001,
                a_blocks: vec![
                    Arc::new(sample_matrix(11, 2, 3)),
                    Arc::new(sample_matrix(12, 2, 3)),
                    Arc::new(sample_matrix(13, 2, 3)),
                ],
                b_blocks: vec![
                    Arc::new(sample_matrix(14, 3, 2)),
                    Arc::new(sample_matrix(15, 3, 2)),
                ],
            }),
            Msg::RatelessJob(RatelessJobMsg {
                request_id: 10,
                stream: 0,
                budget: 0,
                delta: 0.5,
                c: 0.9,
                gamma: vec![1.0],
                class_of: vec![0],
                factors: vec![(0, 0)],
                delays: Vec::new(),
                t_max: 1.0,
                pace: 0.0,
                a_blocks: vec![Arc::new(sample_matrix(16, 1, 1))],
                b_blocks: vec![Arc::new(sample_matrix(17, 1, 1))],
            }),
            Msg::RatelessResult(RatelessResultMsg {
                request_id: 9,
                stream: 2,
                seq: 5,
                attempt: 1,
                delay: 0.625,
                compute_secs: 0.002,
                more: true,
                payload: sample_matrix(18, 2, 2),
            }),
            Msg::RatelessResult(RatelessResultMsg {
                request_id: 9,
                stream: 2,
                seq: 16,
                attempt: 0,
                delay: 2.0,
                compute_secs: 0.001,
                more: false,
                payload: sample_matrix(19, 2, 2),
            }),
            Msg::Drain { request_id: 9 },
            Msg::Redo { request_id: 9, stream: 1, seq: 3, attempt: 2 },
            Msg::OpenSession { session: 0, client: "tenant-β".to_string() },
            Msg::OpenSession { session: 11, client: String::new() },
            Msg::Submit(SubmitMsg {
                session: 11,
                request: 1,
                t_max: 1.5,
                paradigm: 0,
                dims: [2, 3, 1, 6, 2, 4],
                n_total: 8,
                n_classes: 2,
                class_of: vec![0, 0, 0, 1, 1, 1],
                rows: vec![
                    vec![1.0, 0.0, 0.5, 0.0, 0.0, 0.0, 0.25, 0.0],
                    vec![0.0; 8],
                ],
                wa: vec![
                    Arc::new(sample_matrix(21, 2, 3)),
                    Arc::new(sample_matrix(22, 2, 3)),
                ],
                wb: vec![
                    Arc::new(sample_matrix(23, 3, 2)),
                    Arc::new(sample_matrix(24, 3, 2)),
                ],
                delays: vec![0.125, 0.75],
                gram: Some(sample_matrix(25, 6, 6)),
                energy: 12.5,
            }),
            Msg::Submit(SubmitMsg {
                session: 12,
                request: 2,
                t_max: 0.5,
                paradigm: 1,
                dims: [1, 1, 4, 2, 1, 3],
                n_total: 2,
                n_classes: 1,
                class_of: vec![0, 0],
                rows: vec![vec![1.0, 1.0]],
                wa: vec![Arc::new(sample_matrix(26, 1, 1))],
                wb: vec![Arc::new(sample_matrix(27, 1, 1))],
                delays: Vec::new(),
                gram: None,
                energy: 0.0,
            }),
            Msg::ProgressFrame(ProgressMsg {
                session: 11,
                request: 1,
                elapsed: 0.375,
                received: 5,
                recovered: 4,
                newly: 2,
                attempt: 1,
                loss: 0.25,
                normalized_loss: 0.02,
            }),
            Msg::ClientResult(ClientResultMsg {
                session: 11,
                request: 1,
                received: 6,
                recovered: 6,
                per_class: vec![3, 3],
                c_hat: sample_matrix(28, 4, 4),
                loss: 0.0,
                normalized_loss: 0.0,
                late: 1,
                dispatched: 7,
                retries: 1,
                corrupt: 0,
                verify_failures: 0,
                wall_ms: 42,
            }),
            Msg::Reject {
                session: 11,
                request: 0,
                retry_after: 0.25,
                reason: "sessions saturated".to_string(),
            },
            Msg::CloseSession { session: 11 },
        ]
    }

    #[test]
    fn every_message_round_trips_bit_identically() {
        for msg in all_messages() {
            let bytes = encode(&msg).unwrap();
            let (back, used) = decode_frame(&bytes).unwrap();
            assert_eq!(used, bytes.len(), "{}", msg.name());
            assert_eq!(back, msg, "{}", msg.name());
        }
    }

    #[test]
    fn frames_concatenate_and_split_cleanly() {
        let msgs = all_messages();
        let mut stream = Vec::new();
        for m in &msgs {
            stream.extend_from_slice(&encode(m).unwrap());
        }
        let mut at = 0;
        for want in &msgs {
            let (got, used) = decode_frame(&stream[at..]).unwrap();
            assert_eq!(&got, want);
            at += used;
        }
        assert_eq!(at, stream.len());
    }

    #[test]
    fn truncated_frames_report_truncated_and_try_decode_waits() {
        let full = encode(&Msg::Result(ResultMsg {
            request_id: 1,
            slot: 0,
            attempt: 0,
            delay: 0.5,
            compute_secs: 0.0,
            payload: sample_matrix(6, 3, 3),
        }))
        .unwrap();
        for cut in [0, 1, HEADER_LEN - 1, HEADER_LEN, full.len() - 1] {
            match decode_frame(&full[..cut]) {
                Err(WireError::Truncated { need, have }) => {
                    assert_eq!(have, cut);
                    assert!(need > cut);
                }
                other => panic!("cut={cut}: expected Truncated, got {other:?}"),
            }
            assert!(try_decode(&full[..cut]).unwrap().is_none(), "cut={cut}");
        }
        assert!(try_decode(&full).unwrap().is_some());
    }

    #[test]
    fn encode_side_casts_are_checked_not_truncating() {
        // Anything that fits a u32 passes through exactly…
        assert_eq!(wire_u32("len", 0).unwrap(), 0);
        assert_eq!(wire_u32("len", u32::MAX as usize).unwrap(), u32::MAX);
        // …and anything larger refuses instead of silently truncating.
        // (The old `as u32` cast would have mapped 1 << 33 to 0 and
        // produced a structurally valid frame describing no data.)
        #[cfg(target_pointer_width = "64")]
        {
            let big = (u32::MAX as usize) + 1;
            match wire_u32("matrix rows", big) {
                Err(WireError::Oversize { what, value, max }) => {
                    assert_eq!(what, "matrix rows");
                    assert_eq!(value, big);
                    assert_eq!(max, u32::MAX as usize);
                }
                other => panic!("expected Oversize, got {other:?}"),
            }
            let err = wire_u32("string length", 1usize << 33).unwrap_err();
            assert!(err.to_string().contains("does not fit the wire format"));
        }
    }

    #[test]
    fn oversized_length_field_is_rejected_before_allocation() {
        let mut frame = encode(&Msg::Shutdown).unwrap();
        let huge = (MAX_PAYLOAD as u32) + 1;
        frame[8..12].copy_from_slice(&huge.to_le_bytes());
        match decode_frame(&frame) {
            Err(WireError::Oversized { len, max }) => {
                assert_eq!(len, MAX_PAYLOAD + 1);
                assert_eq!(max, MAX_PAYLOAD);
            }
            other => panic!("expected Oversized, got {other:?}"),
        }
        // try_decode must surface it too (it is not recoverable by waiting)
        assert!(try_decode(&frame).is_err());
    }

    #[test]
    fn bad_magic_version_and_type_are_rejected() {
        let good = encode(&Msg::Heartbeat { nonce: 5 }).unwrap();

        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(decode_frame(&bad), Err(WireError::BadMagic(_))));

        let mut bad = good.clone();
        bad[4..6].copy_from_slice(&99u16.to_le_bytes());
        assert!(matches!(decode_frame(&bad), Err(WireError::BadVersion(99))));

        let mut bad = good.clone();
        bad[6] = 200;
        assert!(matches!(decode_frame(&bad), Err(WireError::UnknownType(200))));
    }

    /// Re-seal a hand-patched frame: recompute the CRC trailer over the
    /// (modified) header + payload so structural tests reach the parser
    /// instead of stopping at `BadChecksum`.
    fn reseal(frame: &mut Vec<u8>) {
        let body_end = frame.len() - TRAILER_LEN;
        let crc = crc32(&frame[..body_end]);
        frame[body_end..].copy_from_slice(&crc.to_le_bytes());
    }

    #[test]
    fn trailing_bytes_inside_payload_are_malformed() {
        // declare a payload one byte longer than the heartbeat body (the
        // junk byte goes before the trailer, which is then re-sealed so
        // the structural check — not the checksum — is what trips)
        let mut frame = encode(&Msg::Heartbeat { nonce: 1 }).unwrap();
        let body_end = frame.len() - TRAILER_LEN;
        frame.insert(body_end, 0xEE);
        let len = 9u32; // 8-byte nonce + 1 junk byte
        frame[8..12].copy_from_slice(&len.to_le_bytes());
        reseal(&mut frame);
        assert!(matches!(decode_frame(&frame), Err(WireError::Malformed(_))));
    }

    #[test]
    fn crc32_matches_the_reference_vector() {
        // the canonical IEEE CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn incremental_crc_matches_one_shot_for_every_split() {
        let data = b"UEP window polynomials over straggler channels";
        let want = crc32(data);
        for cut in 0..=data.len() {
            let mut c = Crc32::new();
            c.update(&data[..cut]);
            c.update(&data[cut..]);
            assert_eq!(c.finalize(), want, "cut={cut}");
        }
        // three-way split too (the prefix|body|... shape the hot path uses)
        let mut c = Crc32::new();
        c.update(&data[..7]);
        c.update(&data[7..20]);
        c.update(&data[20..]);
        assert_eq!(c.finalize(), want);
    }

    #[test]
    fn split_job_frame_is_bit_identical_to_encode() {
        for j in [
            JobMsg {
                request_id: 7,
                slot: 3,
                attempt: 2,
                injected_delay: Some(0.25),
                sleep_secs: 0.001,
                wa: Arc::new(sample_matrix(31, 4, 6)),
                wb: Arc::new(sample_matrix(32, 6, 5)),
            },
            JobMsg {
                request_id: 8,
                slot: 0,
                attempt: 0,
                injected_delay: None,
                sleep_secs: 0.0,
                wa: Arc::new(sample_matrix(33, 1, 1)),
                wb: Arc::new(sample_matrix(34, 1, 1)),
            },
        ] {
            let whole = encode(&Msg::Job(j.clone())).unwrap();
            let body = job_body(&j.wa, &j.wb).unwrap();
            let prefix = job_prefix(
                j.request_id,
                j.slot,
                j.attempt,
                j.injected_delay,
                j.sleep_secs,
                body.len(),
            )
            .unwrap();
            let trailer = job_trailer(&prefix, &body);
            let mut split = prefix;
            split.extend_from_slice(&body);
            split.extend_from_slice(&trailer);
            assert_eq!(split, whole);
        }
    }

    #[test]
    fn every_corrupted_byte_is_caught_by_the_checksum() {
        let frame = encode(&Msg::Result(ResultMsg {
            request_id: 3,
            slot: 1,
            attempt: 0,
            delay: 0.25,
            compute_secs: 0.001,
            payload: sample_matrix(8, 3, 4),
        }))
        .unwrap();
        // flip one bit in every payload byte (and the reserved header
        // byte): each single corruption must surface as BadChecksum
        let mut positions: Vec<usize> = (HEADER_LEN..frame.len() - TRAILER_LEN).collect();
        positions.push(7); // reserved byte: parsed by nothing, covered by CRC
        for pos in positions {
            let mut bad = frame.clone();
            bad[pos] ^= 0x10;
            match decode_frame(&bad) {
                Err(WireError::BadChecksum { got, want }) => {
                    assert_ne!(got, want, "pos={pos}")
                }
                other => panic!("pos={pos}: expected BadChecksum, got {other:?}"),
            }
            // not recoverable by waiting for more bytes
            assert!(try_decode(&bad).is_err(), "pos={pos}");
        }
        // a corrupted trailer itself is also a checksum mismatch
        let mut bad = frame.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        assert!(matches!(decode_frame(&bad), Err(WireError::BadChecksum { .. })));
    }

    #[test]
    fn frame_len_reports_the_corrupt_frames_extent() {
        let frame = encode(&Msg::Heartbeat { nonce: 9 }).unwrap();
        assert_eq!(frame_len(&frame), Some(frame.len()));
        // corrupt payload: frame_len still knows the extent (that is the
        // resync contract — the header's own fields were validated)
        let mut bad = frame.clone();
        bad[HEADER_LEN] ^= 0xFF;
        assert_eq!(frame_len(&bad), Some(frame.len()));
        // headerless / foreign bytes: no extent
        assert_eq!(frame_len(&frame[..HEADER_LEN - 1]), None);
        let mut alien = frame;
        alien[0] = b'X';
        assert_eq!(frame_len(&alien), None);
    }

    /// Satellite: `decode_frame` must never panic on adversarial bytes —
    /// arbitrary truncations and bit flips of every frame type,
    /// including the v4 checksum trailer.
    #[test]
    fn decode_never_panics_on_truncations_or_bit_flips() {
        use crate::util::prop::{gen, prop_check, PropConfig};
        let frames: Vec<Vec<u8>> =
            all_messages().iter().map(|m| encode(m).unwrap()).collect();
        prop_check(
            "decode_frame survives adversarial bytes",
            PropConfig { cases: 256, ..PropConfig::default() },
            |rng, case| {
                let frame = &frames[case % frames.len()];
                let mut bytes = frame.clone();
                if rng.bernoulli(0.5) {
                    // random truncation: must report Truncated (or parse
                    // an earlier complete frame — impossible here, one
                    // frame only), never panic
                    let cut = gen::usize_in(rng, 0, bytes.len());
                    bytes.truncate(cut);
                    if cut < frame.len() {
                        match decode_frame(&bytes) {
                            Err(_) => {}
                            Ok(_) => {
                                return Err(format!("truncated to {cut} decoded"))
                            }
                        }
                    }
                } else {
                    // 1–4 random bit flips anywhere in the frame
                    // (header, payload, or trailer): decode must return
                    // an error or a changed message — never panic, never
                    // hand back the original bytes' message
                    let flips = gen::usize_in(rng, 1, 4);
                    for _ in 0..flips {
                        let pos = gen::usize_in(rng, 0, bytes.len() - 1);
                        let bit = gen::usize_in(rng, 0, 7);
                        bytes[pos] ^= 1 << bit;
                    }
                    if bytes != *frame {
                        let _ = decode_frame(&bytes); // must not panic
                    }
                }
                Ok(())
            },
        );
    }

    /// Deepened fuzz over the v5 rateless and v6 service frames: random
    /// byte patches inside the payload with the CRC trailer *re-sealed*,
    /// so corruption reaches the structural parser (length prefixes,
    /// counts, dims, enum tags) instead of stopping at `BadChecksum`.
    /// The parser must never panic, and any frame it does accept must be
    /// consumed exactly to its declared extent.
    #[test]
    fn resealed_structural_corruption_never_panics_v5_v6_parsers() {
        use crate::util::prop::{gen, prop_check, PropConfig};
        let frames: Vec<Vec<u8>> = all_messages()
            .iter()
            .filter(|m| {
                matches!(
                    m,
                    Msg::RatelessJob(_)
                        | Msg::RatelessResult(_)
                        | Msg::Drain { .. }
                        | Msg::Redo { .. }
                        | Msg::OpenSession { .. }
                        | Msg::Submit(_)
                        | Msg::ProgressFrame(_)
                        | Msg::ClientResult(_)
                        | Msg::Reject { .. }
                        | Msg::CloseSession { .. }
                )
            })
            .map(|m| encode(m).unwrap())
            .collect();
        prop_check(
            "v5/v6 parsers survive resealed structural corruption",
            PropConfig { cases: 512, ..PropConfig::default() },
            |rng, case| {
                let frame = &frames[case % frames.len()];
                let mut bytes = frame.clone();
                let lo = HEADER_LEN;
                let hi = bytes.len() - TRAILER_LEN;
                if hi <= lo {
                    return Ok(()); // no payload to corrupt
                }
                for _ in 0..gen::usize_in(rng, 1, 8) {
                    let pos = gen::usize_in(rng, lo, hi - 1);
                    bytes[pos] = (rng.next_u64() & 0xFF) as u8;
                }
                reseal(&mut bytes);
                if bytes == *frame {
                    return Ok(()); // patched back to itself
                }
                match decode_frame(&bytes) {
                    Err(_) => Ok(()),
                    // a structurally-valid reinterpretation is fine, but
                    // it must account for every payload byte (the
                    // trailing-bytes check) — a partial consume would let
                    // an attacker smuggle bytes past the framing
                    Ok((_, used)) if used == bytes.len() => Ok(()),
                    Ok((_, used)) => Err(format!(
                        "partial consume: {used} of {} bytes",
                        bytes.len()
                    )),
                }
            },
        );
    }

    /// Stream-resync fuzz: in a stream of mixed v1–v6 frames, corrupt
    /// one byte of one frame's payload/trailer. A reader that skips the
    /// corrupt frame's reported extent ([`frame_len`] — valid because
    /// the header itself still parses) must recover *every* other frame
    /// bit-exactly, before and after the damage.
    #[test]
    fn corrupt_frame_in_a_stream_resyncs_to_every_later_frame() {
        use crate::util::prop::{gen, prop_check, PropConfig};
        let msgs = all_messages();
        prop_check(
            "stream resync after mid-stream payload corruption",
            PropConfig { cases: 128, ..PropConfig::default() },
            |rng, _case| {
                let n = gen::usize_in(rng, 4, 8);
                let picks: Vec<usize> =
                    (0..n).map(|_| gen::usize_in(rng, 0, msgs.len() - 1)).collect();
                let frames: Vec<Vec<u8>> =
                    picks.iter().map(|&i| encode(&msgs[i]).unwrap()).collect();
                let offsets: Vec<usize> = frames
                    .iter()
                    .scan(0usize, |at, f| {
                        let o = *at;
                        *at += f.len();
                        Some(o)
                    })
                    .collect();
                let mut stream: Vec<u8> = frames.concat();
                // one byte anywhere past the victim's header: a single
                // flip can never collide CRC-32, so the victim always
                // trips BadChecksum while its header extent stays valid
                let victim = gen::usize_in(rng, 0, n - 2);
                let pos = offsets[victim]
                    + gen::usize_in(rng, HEADER_LEN, frames[victim].len() - 1);
                stream[pos] ^= 0x20;

                let mut at = 0;
                let mut got: Vec<Msg> = Vec::new();
                let mut skipped = 0usize;
                while at < stream.len() {
                    match decode_frame(&stream[at..]) {
                        Ok((m, used)) => {
                            got.push(m);
                            at += used;
                        }
                        Err(WireError::Truncated { .. }) => {
                            return Err(format!("stream truncated at {at}"))
                        }
                        Err(_) => match frame_len(&stream[at..]) {
                            Some(len) => {
                                skipped += 1;
                                at += len;
                            }
                            None => return Err(format!("lost framing at {at}")),
                        },
                    }
                }
                if skipped != 1 {
                    return Err(format!("skipped {skipped} frames, expected 1"));
                }
                let expected: Vec<&Msg> = picks
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| i != victim)
                    .map(|(_, &p)| &msgs[p])
                    .collect();
                if got.len() != expected.len() {
                    return Err(format!(
                        "recovered {} frames, expected {}",
                        got.len(),
                        expected.len()
                    ));
                }
                for (g, w) in got.iter().zip(&expected) {
                    if g != *w {
                        return Err(format!("recovered frame diverged: {}", g.name()));
                    }
                }
                Ok(())
            },
        );
    }

    /// Header damage (the magic itself) leaves no extent to skip —
    /// [`frame_len`] returns `None` — so a reader must fall back to a
    /// byte-by-byte scan for the next magic. The scan re-locks on the
    /// next genuine frame: nothing inside the damaged heartbeat frame
    /// can masquerade as one.
    #[test]
    fn header_damage_resyncs_by_scanning_to_the_next_magic() {
        let mut stream = encode(&Msg::Heartbeat { nonce: 5 }).unwrap();
        let tail = Msg::Welcome { worker_id: 77 };
        let tail_at = stream.len();
        stream.extend_from_slice(&encode(&tail).unwrap());
        stream[0] = b'X'; // kill the first frame's magic

        let mut at = 0;
        let mut got = None;
        while at < stream.len() {
            match decode_frame(&stream[at..]) {
                Ok((m, used)) => {
                    assert!(got.is_none(), "decoded more than one frame");
                    got = Some((at, m));
                    at += used;
                }
                Err(WireError::Truncated { .. }) => break,
                Err(_) => at += frame_len(&stream[at..]).unwrap_or(1),
            }
        }
        let (lock_at, msg) = got.expect("scan never re-locked");
        assert_eq!(lock_at, tail_at, "re-locked inside the damaged frame");
        assert_eq!(msg, tail);
    }

    #[test]
    fn matrix_payload_preserves_exact_bits() {
        let m = Matrix::from_vec(
            2,
            2,
            vec![f64::MIN_POSITIVE, -0.0, 1.0 / 3.0, f64::MAX],
        );
        let msg =
            Msg::Result(ResultMsg {
            request_id: 0,
            slot: 0,
            attempt: 0,
            delay: 0.0,
            compute_secs: 0.0,
            payload: m,
        });
        let (back, _) = decode_frame(&encode(&msg).unwrap()).unwrap();
        if let Msg::Result(r) = back {
            if let Msg::Result(orig) = &msg {
                for (a, b) in r.payload.data().iter().zip(orig.payload.data()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        } else {
            panic!("wrong variant");
        }
    }
}
