//! PJRT-backed execution engine: loads AOT HLO-text artifacts
//! (`python/compile/aot.py` output) and executes them on the CPU PJRT
//! client through the `xla` crate.
//!
//! Interchange is HLO *text* — the image's xla_extension 0.5.1 rejects
//! serialized protos from jax ≥ 0.5 (64-bit instruction ids); the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` crate (and its xla_extension native library) is not part of
//! the hermetic vendor set, so the real engine is gated behind the
//! `pjrt` cargo feature. Without it this module compiles a stub with the
//! same surface whose constructor fails with a clear message — callers
//! (CLI `--engine pjrt`, benches, integration tests) already branch on
//! artifact/engine availability, so the default build stays green.

#[cfg(feature = "pjrt")]
mod backend {
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::path::Path;

    use anyhow::{anyhow, bail, Context, Result};

    use crate::linalg::Matrix;
    use crate::runtime::{ExecEngine, Manifest};

    /// A compiled artifact ready to execute.
    pub struct PjrtExecutable {
        exe: xla::PjRtLoadedExecutable,
        /// Output is a tuple (jax lowering uses `return_tuple=True`).
        pub tuple_arity: usize,
    }

    impl PjrtExecutable {
        /// Execute with f32 row-major inputs; returns flat f32 outputs.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let literals = inputs
                .iter()
                .map(|(data, shape)| {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| anyhow!("reshape literal: {e:?}"))
                })
                .collect::<Result<Vec<_>>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("pjrt execute: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let parts = lit
                .to_tuple()
                .map_err(|e| anyhow!("untuple: {e:?}"))?;
            parts
                .into_iter()
                .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("read output: {e:?}")))
                .collect()
        }
    }

    /// Execution engine backed by the PJRT CPU client and an artifact
    /// manifest. Executables are compiled lazily per artifact and cached.
    ///
    /// The PJRT handles are not `Send`, so the engine is confined to the
    /// thread that created it (the coordinator's execution thread).
    pub struct PjrtEngine {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: RefCell<HashMap<String, std::rc::Rc<PjrtExecutable>>>,
    }

    impl PjrtEngine {
        /// Create from an artifact directory containing `manifest.json`.
        pub fn from_artifacts(dir: impl AsRef<Path>) -> Result<Self> {
            let manifest = Manifest::load(&dir)?;
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
            Ok(PjrtEngine { client, manifest, cache: RefCell::new(HashMap::new()) })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch from cache) an artifact by name.
        pub fn executable(&self, name: &str) -> Result<std::rc::Rc<PjrtExecutable>> {
            if let Some(exe) = self.cache.borrow().get(name) {
                return Ok(exe.clone());
            }
            let entry = self
                .manifest
                .by_name(name)
                .with_context(|| format!("artifact '{name}' not in manifest"))?
                .clone();
            let path = self.manifest.hlo_path(&entry);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("load hlo text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            let wrapped = std::rc::Rc::new(PjrtExecutable {
                exe,
                tuple_arity: entry.outputs.len().max(1),
            });
            self.cache.borrow_mut().insert(name.to_string(), wrapped.clone());
            Ok(wrapped)
        }

        /// Execute a named artifact on `Matrix` inputs (f64 → f32 → f64).
        pub fn run(&self, name: &str, inputs: &[&Matrix]) -> Result<Vec<Matrix>> {
            let entry = self
                .manifest
                .by_name(name)
                .with_context(|| format!("artifact '{name}' not in manifest"))?
                .clone();
            if entry.inputs.len() != inputs.len() {
                bail!(
                    "artifact '{name}' expects {} inputs, got {}",
                    entry.inputs.len(),
                    inputs.len()
                );
            }
            let exe = self.executable(name)?;
            let f32_inputs: Vec<(Vec<f32>, Vec<usize>)> = inputs
                .iter()
                .zip(entry.inputs.iter())
                .map(|(m, spec)| {
                    anyhow::ensure!(
                        spec.shape == [m.rows(), m.cols()],
                        "artifact '{name}': input shape {:?} ≠ expected {:?}",
                        m.shape(),
                        spec.shape
                    );
                    Ok((m.to_f32(), spec.shape.clone()))
                })
                .collect::<Result<Vec<_>>>()?;
            let refs: Vec<(&[f32], &[usize])> = f32_inputs
                .iter()
                .map(|(d, s)| (d.as_slice(), s.as_slice()))
                .collect();
            let outs = exe.run_f32(&refs)?;
            outs.into_iter()
                .zip(entry.outputs.iter())
                .map(|(data, spec)| {
                    anyhow::ensure!(
                        data.len() == spec.num_elements(),
                        "artifact '{name}': output size mismatch"
                    );
                    let (r, c) = match spec.shape.len() {
                        2 => (spec.shape[0], spec.shape[1]),
                        1 => (1, spec.shape[0]),
                        0 => (1, 1),
                        _ => bail!("artifact '{name}': >2-D outputs map to flat rows"),
                    };
                    Ok(Matrix::from_f32(r, c, &data))
                })
                .collect()
        }
    }

    impl ExecEngine for PjrtEngine {
        fn matmul(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
            let (m, k) = a.shape();
            let n = b.cols();
            let entry = self
                .manifest
                .find_matmul(m, k, n)
                .with_context(|| {
                    format!("no matmul artifact for {m}x{k}x{n} — re-run `make artifacts`")
                })?
                .clone();
            let mut outs = self.run(&entry.name, &[a, b])?;
            anyhow::ensure!(!outs.is_empty(), "matmul artifact returned nothing");
            Ok(outs.remove(0))
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use std::path::Path;

    use anyhow::{bail, Result};

    use crate::linalg::Matrix;
    use crate::runtime::{ExecEngine, Manifest};

    const UNAVAILABLE: &str = "uepmm was built without the `pjrt` feature; \
         rebuild with `--features pjrt` where the xla crate / xla_extension \
         native library is available";

    /// Stub compiled when the `pjrt` feature is off. The constructor
    /// still validates the manifest (so path/contract errors surface the
    /// same way) but always fails with a clear message.
    pub struct PjrtExecutable {
        pub tuple_arity: usize,
    }

    impl PjrtExecutable {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            bail!(UNAVAILABLE)
        }
    }

    pub struct PjrtEngine {
        manifest: Manifest,
    }

    impl PjrtEngine {
        pub fn from_artifacts(dir: impl AsRef<Path>) -> Result<Self> {
            let _manifest = Manifest::load(&dir)?;
            bail!(UNAVAILABLE)
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            "pjrt-stub".to_string()
        }

        pub fn executable(&self, _name: &str) -> Result<std::rc::Rc<PjrtExecutable>> {
            bail!(UNAVAILABLE)
        }

        pub fn run(&self, _name: &str, _inputs: &[&Matrix]) -> Result<Vec<Matrix>> {
            bail!(UNAVAILABLE)
        }
    }

    impl ExecEngine for PjrtEngine {
        fn matmul(&self, _a: &Matrix, _b: &Matrix) -> Result<Matrix> {
            bail!(UNAVAILABLE)
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}

pub use backend::{PjrtEngine, PjrtExecutable};
