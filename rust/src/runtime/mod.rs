//! Execution engines: where worker sub-products actually get computed.
//!
//! * [`NativeEngine`] — the pure-Rust blocked/parallel matmul from
//!   [`crate::linalg`]; always available, used for large Monte-Carlo
//!   sweeps.
//! * [`PjrtEngine`] — loads the AOT-compiled HLO artifacts produced by
//!   `python/compile/aot.py` (JAX/Pallas, lowered **once** at build time)
//!   and executes them on the PJRT CPU client via the `xla` crate. This
//!   is the production path: Python never runs at request time.
//!
//! Both engines satisfy [`ExecEngine`], so the coordinator, experiments,
//! and benches are engine-agnostic.

mod manifest;
mod pjrt;

pub use manifest::{ArtifactEntry, Manifest, TensorSpec};
pub use pjrt::{PjrtEngine, PjrtExecutable};

use crate::linalg::{matmul_with, Matrix, MatmulOpts};

/// Anything that can multiply two matrices on behalf of a worker.
pub trait ExecEngine {
    /// Compute `A·B`.
    fn matmul(&self, a: &Matrix, b: &Matrix) -> anyhow::Result<Matrix>;

    /// Engine name for logs/metrics.
    fn name(&self) -> &'static str;
}

/// Pure-Rust execution engine (blocked + thread-parallel matmul).
#[derive(Clone, Debug)]
pub struct NativeEngine {
    pub opts: MatmulOpts,
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine { opts: MatmulOpts::default() }
    }
}

impl NativeEngine {
    /// Single-threaded variant (used inside already-parallel sweeps).
    pub fn serial() -> Self {
        NativeEngine { opts: MatmulOpts { threads: 1, ..MatmulOpts::default() } }
    }
}

impl ExecEngine for NativeEngine {
    fn matmul(&self, a: &Matrix, b: &Matrix) -> anyhow::Result<Matrix> {
        Ok(matmul_with(a, b, self.opts))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn native_engine_matches_linalg() {
        let mut rng = Pcg64::seed_from(1);
        let a = Matrix::randn(20, 30, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(30, 10, 0.0, 1.0, &mut rng);
        let eng = NativeEngine::default();
        let c = eng.matmul(&a, &b).unwrap();
        assert!(c.allclose(&crate::linalg::matmul(&a, &b), 1e-12));
        assert_eq!(eng.name(), "native");
    }
}
