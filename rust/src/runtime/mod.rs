//! Execution engines: where worker sub-products actually get computed.
//!
//! * [`NativeEngine`] — the pure-Rust blocked/parallel matmul from
//!   [`crate::linalg`]; always available, used for large Monte-Carlo
//!   sweeps.
//! * [`PjrtEngine`] — loads the AOT-compiled HLO artifacts produced by
//!   `python/compile/aot.py` (JAX/Pallas, lowered **once** at build time)
//!   and executes them on the PJRT CPU client via the `xla` crate. This
//!   is the production path: Python never runs at request time.
//!
//! Both engines satisfy [`ExecEngine`], so the coordinator, experiments,
//! and benches are engine-agnostic.

mod manifest;
mod pjrt;

pub use manifest::{ArtifactEntry, Manifest, TensorSpec};
pub use pjrt::{PjrtEngine, PjrtExecutable};

use crate::linalg::{matmul_with, Matrix, MatmulOpts};

/// Anything that can multiply two matrices on behalf of a worker.
pub trait ExecEngine {
    /// Compute `A·B`.
    fn matmul(&self, a: &Matrix, b: &Matrix) -> anyhow::Result<Matrix>;

    /// Engine name for logs/metrics.
    fn name(&self) -> &'static str;
}

impl<E: ExecEngine + ?Sized> ExecEngine for &E {
    fn matmul(&self, a: &Matrix, b: &Matrix) -> anyhow::Result<Matrix> {
        (**self).matmul(a, b)
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }
}

impl<E: ExecEngine + ?Sized> ExecEngine for Box<E> {
    fn matmul(&self, a: &Matrix, b: &Matrix) -> anyhow::Result<Matrix> {
        (**self).matmul(a, b)
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }
}

/// Resolve an engine by CLI name: `native` (always available) or `pjrt`
/// with its artifact directory (compiles to an error message without the
/// `pjrt` feature). Used by `uepmm worker --engine …`.
pub fn engine_by_name(
    name: &str,
    artifacts: &str,
) -> anyhow::Result<Box<dyn ExecEngine>> {
    match name {
        "native" => Ok(Box::new(NativeEngine::default())),
        "pjrt" => Ok(Box::new(PjrtEngine::from_artifacts(artifacts)?)),
        other => anyhow::bail!("unknown engine '{other}' (native|pjrt)"),
    }
}

/// Pure-Rust execution engine (blocked + thread-parallel matmul).
#[derive(Clone, Debug)]
pub struct NativeEngine {
    pub opts: MatmulOpts,
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine { opts: MatmulOpts::default() }
    }
}

impl NativeEngine {
    /// Single-threaded variant (used inside already-parallel sweeps).
    pub fn serial() -> Self {
        NativeEngine { opts: MatmulOpts { threads: 1, ..MatmulOpts::default() } }
    }
}

impl ExecEngine for NativeEngine {
    fn matmul(&self, a: &Matrix, b: &Matrix) -> anyhow::Result<Matrix> {
        Ok(matmul_with(a, b, self.opts))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn engine_smoke<E: ExecEngine>(eng: E) {
        let mut rng = Pcg64::seed_from(2);
        let a = Matrix::randn(4, 6, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(6, 3, 0.0, 1.0, &mut rng);
        let c = eng.matmul(&a, &b).unwrap();
        assert!(c.allclose(&crate::linalg::matmul(&a, &b), 1e-12));
    }

    #[test]
    fn engines_compose_through_refs_and_boxes() {
        let eng = NativeEngine::serial();
        engine_smoke(&eng);
        let boxed: Box<dyn ExecEngine> = Box::new(eng);
        assert_eq!(boxed.name(), "native");
        engine_smoke(boxed);
    }

    #[test]
    fn engine_by_name_resolves_native_and_rejects_unknown() {
        let eng = engine_by_name("native", "unused").unwrap();
        assert_eq!(eng.name(), "native");
        assert!(engine_by_name("gpu3000", "unused").is_err());
    }

    #[test]
    fn native_engine_matches_linalg() {
        let mut rng = Pcg64::seed_from(1);
        let a = Matrix::randn(20, 30, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(30, 10, 0.0, 1.0, &mut rng);
        let eng = NativeEngine::default();
        let c = eng.matmul(&a, &b).unwrap();
        assert!(c.allclose(&crate::linalg::matmul(&a, &b), 1e-12));
        assert_eq!(eng.name(), "native");
    }
}
