//! The `artifacts/manifest.json` contract between `python/compile/aot.py`
//! (writer) and the Rust runtime (reader).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("tensor spec missing shape")?
            .iter()
            .map(|v| v.as_usize().context("non-integer dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("f32")
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }

    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled HLO module.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    /// Path of the HLO text file, relative to the manifest directory.
    pub path: String,
    /// Logical kind: `matmul`, `uep_encode`, `worker_product`,
    /// `mlp_step`, …
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest.json parse error")?;
        let version = j.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let entries = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing artifacts array")?
            .iter()
            .map(|e| {
                let name = e
                    .get("name")
                    .and_then(Json::as_str)
                    .context("artifact missing name")?
                    .to_string();
                let path = e
                    .get("path")
                    .and_then(Json::as_str)
                    .context("artifact missing path")?
                    .to_string();
                let kind = e
                    .get("kind")
                    .and_then(Json::as_str)
                    .unwrap_or("generic")
                    .to_string();
                let inputs = e
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = e
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                Ok(ArtifactEntry { name, path, kind, inputs, outputs })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir, entries })
    }

    /// Find an entry by name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Find a `matmul` artifact matching `(m, k, n)`.
    pub fn find_matmul(&self, m: usize, k: usize, n: usize) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| {
            e.kind == "matmul"
                && e.inputs.len() == 2
                && e.inputs[0].shape == [m, k]
                && e.inputs[1].shape == [k, n]
        })
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "block_matmul_4x6x5", "path": "block_matmul_4x6x5.hlo.txt",
         "kind": "matmul",
         "inputs": [{"shape": [4,6], "dtype": "f32"}, {"shape": [6,5], "dtype": "f32"}],
         "outputs": [{"shape": [4,5], "dtype": "f32"}]},
        {"name": "mlp_step", "path": "mlp_step.hlo.txt", "kind": "mlp_step",
         "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.by_name("block_matmul_4x6x5").unwrap();
        assert_eq!(e.kind, "matmul");
        assert_eq!(e.inputs[0].shape, vec![4, 6]);
        assert_eq!(e.outputs[0].num_elements(), 20);
        assert_eq!(
            m.hlo_path(e),
            PathBuf::from("/tmp/a/block_matmul_4x6x5.hlo.txt")
        );
    }

    #[test]
    fn matmul_lookup() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert!(m.find_matmul(4, 6, 5).is_some());
        assert!(m.find_matmul(4, 6, 7).is_none());
        assert!(m.find_matmul(6, 4, 5).is_none());
    }

    #[test]
    fn rejects_bad_version() {
        assert!(Manifest::parse(r#"{"version": 2, "artifacts": []}"#, ".".into()).is_err());
        assert!(Manifest::parse(r#"{"artifacts": []}"#, ".".into()).is_err());
    }
}
