//! Experiment configuration presets: the paper's §VI synthetic setup
//! (Fig. 9–11, Table III) and the §VII DNN encoding parameters
//! (Table VII), shared by the experiment harness, the examples, and the
//! benches.

use crate::analysis::TheoremLoss;
use crate::coding::WindowPolynomial;
use crate::latency::LatencyModel;
use crate::linalg::Matrix;
use crate::partition::{default_pair_classes, ClassMap, Paradigm, Partitioning};
use crate::rng::Pcg64;

/// A fully specified synthetic matrix-approximation experiment
/// (Assumption 1 matrices with per-level variances).
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub part: Partitioning,
    /// Importance level of each A factor block (B uses the same).
    pub a_levels: Vec<usize>,
    pub b_levels: Vec<usize>,
    /// Standard deviation of block entries per level.
    pub level_sds: Vec<f64>,
    /// Window selection polynomial (Table III).
    pub gamma: WindowPolynomial,
    pub workers: usize,
    pub latency: LatencyModel,
    pub t_max: f64,
}

impl SyntheticSpec {
    /// Fig. 9 r×c: `N=P=3, U=Q=300, H=900`, levels (high, med, low) with
    /// variances (10, 1, 0.1), `W=30`, `Exp(λ=1)`.
    pub fn fig9_rxc() -> Self {
        SyntheticSpec {
            part: Partitioning::rxc(3, 3, 300, 900, 300),
            a_levels: vec![0, 1, 2],
            b_levels: vec![0, 1, 2],
            level_sds: vec![10f64.sqrt(), 1.0, 0.1f64.sqrt()],
            gamma: WindowPolynomial::paper_table3(),
            workers: 30,
            latency: LatencyModel::exp(1.0),
            t_max: 2.0,
        }
    }

    /// Fig. 9 c×r: `U=Q=900, H=100, M=9`, blocks 1–3 high, 4–6 medium,
    /// 7–9 low (same per-worker compute as the r×c case).
    pub fn fig9_cxr() -> Self {
        SyntheticSpec {
            part: Partitioning::cxr(9, 900, 100, 900),
            a_levels: vec![0, 0, 0, 1, 1, 1, 2, 2, 2],
            b_levels: vec![0, 0, 0, 1, 1, 1, 2, 2, 2],
            level_sds: vec![10f64.sqrt(), 1.0, 0.1f64.sqrt()],
            gamma: WindowPolynomial::paper_table3(),
            workers: 30,
            latency: LatencyModel::exp(1.0),
            t_max: 2.0,
        }
    }

    /// Same geometry scaled down (fast CI / quick runs).
    pub fn scaled(&self, factor: usize) -> Self {
        let mut s = self.clone();
        let f = factor.max(1);
        s.part.u = (s.part.u / f).max(1);
        s.part.h = (s.part.h / f).max(1);
        s.part.q = (s.part.q / f).max(1);
        s
    }

    /// Same spec with `blocks×blocks` factor blocks per side
    /// (`K = blocks²` sub-products): importance levels are spread evenly
    /// across the blocks and per-block dims rescaled so the total
    /// operand shapes stay put. r×c only — the c×r paradigm ties its
    /// block count to `M`.
    pub fn with_blocks(&self, blocks: usize) -> Self {
        assert!(blocks >= 1, "need at least one block per side");
        assert!(
            matches!(self.part.paradigm, Paradigm::RowTimesCol),
            "with_blocks applies to the r×c paradigm"
        );
        let mut s = self.clone();
        let levels = self.level_sds.len();
        let total_u = self.part.n * self.part.u;
        let total_q = self.part.p * self.part.q;
        s.part.n = blocks;
        s.part.p = blocks;
        s.part.u = (total_u / blocks).max(1);
        s.part.q = (total_q / blocks).max(1);
        s.a_levels = (0..blocks).map(|i| i * levels / blocks).collect();
        s.b_levels = s.a_levels.clone();
        s
    }

    /// The paper's Ω fairness scaling (Remark 1).
    pub fn omega(&self) -> f64 {
        self.part.num_products() as f64 / self.workers as f64
    }

    /// Class map with the pinned levels.
    pub fn class_map(&self) -> ClassMap {
        let pair = default_pair_classes(self.level_sds.len());
        ClassMap::from_levels(&self.part, self.a_levels.clone(), self.b_levels.clone(), &pair)
    }

    /// Sample `A` alone with i.i.d. `N(0, σ²_level)` blocks (Assumption 1).
    pub fn sample_a(&self, rng: &mut Pcg64) -> Matrix {
        let a_blocks: Vec<Matrix> = self
            .a_levels
            .iter()
            .map(|&lv| {
                Matrix::randn(self.part.u, self.part.h, 0.0, self.level_sds[lv], rng)
            })
            .collect();
        let refs_a: Vec<&Matrix> = a_blocks.iter().collect();
        match self.part.paradigm {
            Paradigm::RowTimesCol => Matrix::vconcat(&refs_a),
            Paradigm::ColTimesRow => Matrix::hconcat(&refs_a),
        }
    }

    /// Sample `B` alone — the per-request side of a cluster stream that
    /// reuses a cached `A` (fresh activations against fixed weights).
    pub fn sample_b(&self, rng: &mut Pcg64) -> Matrix {
        let b_blocks: Vec<Matrix> = self
            .b_levels
            .iter()
            .map(|&lv| {
                Matrix::randn(self.part.h, self.part.q, 0.0, self.level_sds[lv], rng)
            })
            .collect();
        let refs_b: Vec<&Matrix> = b_blocks.iter().collect();
        match self.part.paradigm {
            Paradigm::RowTimesCol => Matrix::hconcat(&refs_b),
            Paradigm::ColTimesRow => Matrix::vconcat(&refs_b),
        }
    }

    /// Sample `(A, B)` with i.i.d. `N(0, σ²_level)` blocks (Assumption 1).
    /// Consumes the RNG in the same order as [`Self::sample_a`] followed
    /// by [`Self::sample_b`].
    pub fn sample_matrices(&self, rng: &mut Pcg64) -> (Matrix, Matrix) {
        let a = self.sample_a(rng);
        let b = self.sample_b(rng);
        (a, b)
    }

    /// Per-class mean variance products `σ²_{l,A}·σ²_{l,B}` for the
    /// Theorem 2/3 formulas (merged classes average their grid cells).
    pub fn class_sigma2(&self) -> Vec<f64> {
        let cm = self.class_map();
        let var = |lv: usize| self.level_sds[lv] * self.level_sds[lv];
        cm.members
            .iter()
            .map(|members| {
                let sum: f64 = members
                    .iter()
                    .map(|&u| {
                        let (ai, bi) = self.part.factors_of(u);
                        var(self.a_levels[ai]) * var(self.b_levels[bi])
                    })
                    .sum();
                sum / members.len() as f64
            })
            .collect()
    }

    /// The Theorem 2 (r×c) / Theorem 3 (c×r, with the `M` bound factor)
    /// loss formula for this spec.
    pub fn theorem(&self) -> TheoremLoss {
        let cm = self.class_map();
        let gamma = self.gamma.resized(cm.n_classes).probs().to_vec();
        TheoremLoss::for_plan(
            &self.part,
            &cm,
            self.class_sigma2(),
            gamma,
            self.workers,
            self.latency.clone(),
            self.omega(),
        )
    }
}

/// Table VII: the encoding parameter sets of the DNN experiments.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EncodingRow {
    Uncoded,
    Uep,
    TwoBlockRep,
}

impl EncodingRow {
    /// `(W, Ω)` per Table VII (9 sub-products).
    pub fn params(&self) -> (usize, f64) {
        match self {
            EncodingRow::Uncoded => (9, 9.0 / 9.0),
            EncodingRow::Uep => (15, 9.0 / 15.0),
            EncodingRow::TwoBlockRep => (18, 9.0 / 18.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_specs_have_equal_worker_compute() {
        // fairness: per sub-product multiply-adds match across paradigms
        let rxc = SyntheticSpec::fig9_rxc();
        let cxr = SyntheticSpec::fig9_cxr();
        let flops_rxc = rxc.part.u * rxc.part.h * rxc.part.q;
        let flops_cxr = cxr.part.u * cxr.part.h * cxr.part.q;
        assert_eq!(flops_rxc, flops_cxr);
        assert_eq!(rxc.part.num_products(), 9);
        assert_eq!(cxr.part.num_products(), 9);
        assert!((rxc.omega() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn class_structure_matches_paper() {
        for spec in [SyntheticSpec::fig9_rxc(), SyntheticSpec::fig9_cxr()] {
            let cm = spec.class_map();
            assert_eq!(cm.n_classes, 3);
            assert_eq!(cm.class_sizes(), vec![3, 3, 3]);
        }
        // r×c merged class variance products: {100,10,10} → 40, {1,1,1} → 1
        let s2 = SyntheticSpec::fig9_rxc().class_sigma2();
        assert!((s2[0] - 40.0).abs() < 1e-9);
        assert!((s2[1] - 1.0).abs() < 1e-9);
        // c×r classes are homogeneous: 100, 1, 0.01
        let s2 = SyntheticSpec::fig9_cxr().class_sigma2();
        assert!((s2[0] - 100.0).abs() < 1e-9);
        assert!((s2[2] - 0.01).abs() < 1e-9);
    }

    #[test]
    fn sampled_matrices_have_level_norm_ordering() {
        let spec = SyntheticSpec::fig9_rxc().scaled(6);
        let mut rng = Pcg64::seed_from(1);
        let (a, b) = spec.sample_matrices(&mut rng);
        let cm_est = ClassMap::from_matrices(&spec.part, &a, &b, 3);
        // norm-based classification must recover the pinned levels
        assert_eq!(cm_est.a_level, spec.a_levels);
        assert_eq!(cm_est.b_level, spec.b_levels);
    }

    #[test]
    fn with_blocks_rescales_geometry_and_levels() {
        let base = SyntheticSpec::fig9_rxc().scaled(10);
        let spec = base.with_blocks(6);
        assert_eq!(spec.part.num_products(), 36);
        assert_eq!(spec.part.a_shape(), base.part.a_shape());
        assert_eq!(spec.part.b_shape(), base.part.b_shape());
        assert_eq!(spec.a_levels, vec![0, 0, 1, 1, 2, 2]);
        assert_eq!(spec.class_map().class_of.len(), 36);
    }

    #[test]
    fn table_vii_rows() {
        assert_eq!(EncodingRow::Uncoded.params(), (9, 1.0));
        assert_eq!(EncodingRow::Uep.params(), (15, 0.6));
        assert_eq!(EncodingRow::TwoBlockRep.params(), (18, 0.5));
    }
}
