//! Terminal ASCII plotting so experiment binaries can show the *shape*
//! of each reproduced figure directly in the console (the CSV written
//! alongside holds the exact numbers).

use crate::util::fmt_g;

/// A named data series.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
}

impl Series {
    pub fn new(name: &str, xs: Vec<f64>, ys: Vec<f64>) -> Self {
        assert_eq!(xs.len(), ys.len());
        Series { name: name.to_string(), xs, ys }
    }
}

const MARKS: &[char] = &['*', '+', 'o', 'x', '#', '@', '%', '&'];

/// Render multiple series on one ASCII canvas with axes and a legend.
pub fn render(title: &str, series: &[Series], width: usize, height: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!("── {title} ──\n"));
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.xs.iter().cloned().zip(s.ys.iter().cloned()))
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if pts.is_empty() {
        out.push_str("(no data)\n");
        return out;
    }
    let (mut x0, mut x1) = min_max(pts.iter().map(|p| p.0));
    let (mut y0, mut y1) = min_max(pts.iter().map(|p| p.1));
    if x1 - x0 < 1e-12 {
        x0 -= 0.5;
        x1 += 0.5;
    }
    if y1 - y0 < 1e-12 {
        y0 -= 0.5;
        y1 += 0.5;
    }
    let mut canvas = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for (&x, &y) in s.xs.iter().zip(s.ys.iter()) {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            canvas[row][cx.min(width - 1)] = mark;
        }
    }
    for (i, row) in canvas.iter().enumerate() {
        let label = if i == 0 {
            format!("{:>9} ", fmt_g(y1))
        } else if i == height - 1 {
            format!("{:>9} ", fmt_g(y0))
        } else {
            " ".repeat(10)
        };
        out.push_str(&label);
        out.push('|');
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&" ".repeat(10));
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!(
        "{}{}{:>width$}\n",
        " ".repeat(11),
        fmt_g(x0),
        fmt_g(x1),
        width = width - fmt_g(x0).len()
    ));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", MARKS[si % MARKS.len()], s.name));
    }
    out
}

fn min_max(iter: impl Iterator<Item = f64>) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for v in iter {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    (lo, hi)
}

/// Render an aligned text table (for Table II/III/VII-style outputs).
pub fn text_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, cell) in r.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: Vec<String>, widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(header.iter().map(|s| s.to_string()).collect(), &widths));
    let mut sep = String::from("|");
    for w in &widths {
        sep.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    sep.push('\n');
    out.push_str(&sep);
    for r in rows {
        out.push_str(&fmt_row(r.clone(), &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_series_marks_and_legend() {
        let s1 = Series::new("a", vec![0.0, 1.0, 2.0], vec![0.0, 1.0, 4.0]);
        let s2 = Series::new("b", vec![0.0, 1.0, 2.0], vec![4.0, 1.0, 0.0]);
        let out = render("test", &[s1, s2], 40, 10);
        assert!(out.contains('*'));
        assert!(out.contains('+'));
        assert!(out.contains("a\n"));
        assert!(out.contains("b\n"));
    }

    #[test]
    fn render_handles_empty() {
        let out = render("empty", &[], 20, 5);
        assert!(out.contains("no data"));
    }

    #[test]
    fn render_handles_constant_series() {
        let s = Series::new("c", vec![1.0, 1.0], vec![2.0, 2.0]);
        let out = render("const", &[s], 20, 5);
        assert!(out.contains('*'));
    }

    #[test]
    fn table_alignment() {
        let t = text_table(
            &["scheme", "loss"],
            &[
                vec!["now".into(), "0.5".into()],
                vec!["ew-uep".into(), "0.25".into()],
            ],
        );
        assert!(t.contains("| scheme"));
        assert!(t.contains("| ew-uep"));
    }
}
