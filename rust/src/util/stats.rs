//! Summary statistics, histograms, and Gaussian maximum-likelihood
//! fitting — used by the Fig. 5 / Table II reproduction (layer-wise
//! Gaussian fits of gradients/weights/inputs) and by the bench harness.

/// Streaming mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Unbiased sample variance.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        (self.sample_variance() / self.n as f64).sqrt()
    }
}

/// Quantile of a sample via linear interpolation. `q` in `[0, 1]`.
/// Sorts a copy; use [`quantile_sorted`] when data is already sorted.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    quantile_sorted(&v, q)
}

/// Quantile of an ascending-sorted sample.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median absolute deviation (robust spread), scaled to be consistent
/// with the standard deviation for Gaussian data.
pub fn mad(xs: &[f64]) -> f64 {
    let med = quantile(xs, 0.5);
    let devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    1.4826 * quantile(&devs, 0.5)
}

/// Result of a Gaussian MLE fit over the *dense* (non-zero) portion of a
/// sample, plus the sparsity ratio — the exact quantities in the paper's
/// Fig. 5 and Table II.
#[derive(Clone, Copy, Debug)]
pub struct GaussianFit {
    /// Fraction of entries whose magnitude was at or below the threshold.
    pub sparsity: f64,
    /// MLE mean of the remaining entries.
    pub mean: f64,
    /// MLE variance of the remaining entries.
    pub variance: f64,
    /// Number of dense entries the fit used.
    pub dense_count: usize,
}

/// Fit the dense portion of `xs` (entries with `|x| > threshold`) with a
/// Gaussian; reports the sparsity fraction alongside.
pub fn gaussian_fit_dense(xs: &[f64], threshold: f64) -> GaussianFit {
    let mut r = Running::new();
    let mut zeros = 0usize;
    for &x in xs {
        if x.abs() <= threshold {
            zeros += 1;
        } else {
            r.push(x);
        }
    }
    GaussianFit {
        sparsity: zeros as f64 / xs.len().max(1) as f64,
        mean: if r.count() == 0 { 0.0 } else { r.mean() },
        variance: if r.count() == 0 { 0.0 } else { r.variance() },
        dense_count: r.count() as usize,
    }
}

/// An equi-width histogram over `[lo, hi]`.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], total: 0, underflow: 0, overflow: 0 }
    }

    pub fn push(&mut self, x: f64) {
        self.total += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let bins = self.counts.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * bins as f64) as usize;
            self.counts[idx.min(bins - 1)] += 1;
        }
    }

    pub fn from_slice(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Self {
        let mut h = Histogram::new(lo, hi, bins);
        for &x in xs {
            h.push(x);
        }
        h
    }

    /// Normalized density per bin (integrates to ≤ 1 over [lo, hi]).
    pub fn density(&self) -> Vec<f64> {
        let width = (self.hi - self.lo) / self.counts.len() as f64;
        let n = self.total.max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / (n * width)).collect()
    }

    /// Bin center coordinates.
    pub fn centers(&self) -> Vec<f64> {
        let width = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + width * (i as f64 + 0.5))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Normal, Pcg64, Sample};

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let mut r = Running::new();
        r.extend(&xs);
        assert_eq!(r.count(), 4);
        assert!((r.mean() - 3.75).abs() < 1e-12);
        let direct_var =
            xs.iter().map(|x| (x - 3.75f64).powi(2)).sum::<f64>() / 4.0;
        assert!((r.variance() - direct_var).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 8.0);
    }

    #[test]
    fn quantiles() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn mad_of_gaussian_approximates_sd() {
        let mut rng = Pcg64::seed_from(1);
        let d = Normal::new(0.0, 3.0);
        let xs: Vec<f64> = (0..50_000).map(|_| d.sample(&mut rng)).collect();
        let m = mad(&xs);
        assert!((m - 3.0).abs() < 0.1, "mad {m}");
    }

    #[test]
    fn gaussian_fit_recovers_parameters_and_sparsity() {
        let mut rng = Pcg64::seed_from(2);
        let d = Normal::new(0.5, 2.0);
        let mut xs: Vec<f64> = (0..40_000).map(|_| d.sample(&mut rng)).collect();
        // zero half of the entries, as a sparsified gradient would be
        for i in 0..xs.len() {
            if i % 2 == 0 {
                xs[i] = 0.0;
            }
        }
        let fit = gaussian_fit_dense(&xs, 1e-9);
        assert!((fit.sparsity - 0.5).abs() < 0.01);
        assert!((fit.mean - 0.5).abs() < 0.05);
        assert!((fit.variance - 4.0).abs() < 0.15);
    }

    #[test]
    fn histogram_counts_and_density() {
        let xs = [0.1, 0.2, 0.6, 0.9, -1.0, 2.0];
        let h = Histogram::from_slice(&xs, 0.0, 1.0, 2);
        assert_eq!(h.counts, vec![2, 2]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        let d = h.density();
        // 2 of 6 samples in a bin of width 0.5 → density 2/(6*0.5)
        assert!((d[0] - 2.0 / 3.0).abs() < 1e-12);
    }
}
