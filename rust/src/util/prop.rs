//! A small property-based testing harness (proptest is not available in
//! the offline vendor set). Generates seeded random cases, runs a
//! predicate, and on failure reports the failing seed so the case can be
//! replayed deterministically.

use crate::rng::Pcg64;

/// Configuration for a property check.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; case `i` uses stream `i` of this seed.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `property(rng, case_index)`; panics with the failing seed/case on
/// the first `Err`. Use `prop_check(..)` in `#[test]` functions.
pub fn prop_check<F>(name: &str, cfg: PropConfig, mut property: F)
where
    F: FnMut(&mut Pcg64, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Pcg64::with_stream(cfg.seed, case as u64 + 1);
        if let Err(msg) = property(&mut rng, case) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed}): {msg}",
                seed = cfg.seed
            );
        }
    }
}

/// Generators for common random test inputs.
pub mod gen {
    use crate::rng::{Normal, Pcg64};

    /// Uniform integer in `[lo, hi]`.
    pub fn usize_in(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        lo + rng.next_bounded((hi - lo + 1) as u64) as usize
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_in(rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * rng.next_f64()
    }

    /// Vector of standard normals.
    pub fn normal_vec(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        (0..n).map(|_| Normal::standard(rng)).collect()
    }

    /// A probability vector of length `n` (strictly positive entries).
    pub fn simplex(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| rng.next_f64() + 1e-3).collect();
        let s: f64 = v.iter().sum();
        for x in v.iter_mut() {
            *x /= s;
        }
        v
    }

    /// Partition `total` into `parts` positive integers.
    pub fn composition(rng: &mut Pcg64, total: usize, parts: usize) -> Vec<usize> {
        assert!(total >= parts && parts > 0);
        let mut v = vec![1usize; parts];
        for _ in 0..(total - parts) {
            let i = rng.next_bounded(parts as u64) as usize;
            v[i] += 1;
        }
        v
    }

    /// Fisher–Yates shuffle in place (uniform over permutations).
    pub fn shuffle<T>(rng: &mut Pcg64, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = rng.next_bounded(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_check_passes_trivial() {
        prop_check("trivial", PropConfig::default(), |rng, _| {
            let x = rng.next_f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn prop_check_reports_failure() {
        prop_check("fails", PropConfig { cases: 10, seed: 1 }, |_, case| {
            if case < 3 {
                Ok(())
            } else {
                Err("boom".to_string())
            }
        });
    }

    #[test]
    fn simplex_sums_to_one() {
        let mut rng = Pcg64::seed_from(5);
        for _ in 0..20 {
            let v = gen::simplex(&mut rng, 5);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
            assert!(v.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn composition_sums() {
        let mut rng = Pcg64::seed_from(6);
        for _ in 0..20 {
            let v = gen::composition(&mut rng, 30, 4);
            assert_eq!(v.iter().sum::<usize>(), 30);
            assert!(v.iter().all(|&x| x >= 1));
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg64::seed_from(7);
        let mut xs: Vec<usize> = (0..50).collect();
        gen::shuffle(&mut rng, &mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, sorted, "50 elements left in place — shuffle broken");
    }
}
