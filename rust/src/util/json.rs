//! A minimal JSON implementation (RFC 8259 subset) used for the artifact
//! manifest written by `python/compile/aot.py`, experiment configuration
//! files, and machine-readable result dumps. No external crates.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` for deterministic ordering.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access: `j.get("key")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut vec = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(vec));
        }
        loop {
            vec.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(vec)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self.peek().map_or(false, |b| b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while self.peek().map_or(false, |b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while self.peek().map_or(false, |b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basics() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"k":"v","n":null},"t":true}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        let j2 = Json::parse(&printed).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(Json::parse("[1,2").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }
}
