//! A fixed-size thread pool with a scoped `parallel_for` — the crate's
//! replacement for rayon/tokio (not available offline). Workers in the
//! straggler simulator and the Monte-Carlo harness run on this pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple work-queue thread pool.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` worker threads.
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("uepmm-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool thread")
            })
            .collect();
        ThreadPool { tx: Some(tx), handles, size }
    }

    /// Pool sized to the number of available CPUs (capped at `cap`).
    pub fn with_cpus(cap: usize) -> Self {
        ThreadPool::new(available_parallelism().min(cap).max(1))
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Number of logical CPUs.
pub fn available_parallelism() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(i)` for `i in 0..n` across up to `threads` scoped threads and
/// collect results in order. Uses `std::thread::scope`, so `f` may borrow
/// from the caller.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_scratch(n, threads, || (), move |i, _scratch| f(i))
}

/// [`parallel_map`] with per-thread scratch: every worker thread calls
/// `init()` exactly once and threads the resulting value mutably through
/// all items it processes. This is the Monte-Carlo fan-out primitive —
/// decode states, order buffers, and masks live in the scratch and are
/// reused across trials instead of being reallocated per trial. Results
/// come back in index order, so the output is independent of the thread
/// count and of which thread ran which item.
pub fn parallel_map_scratch<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut S) -> T + Sync,
{
    let threads = threads.min(n).max(1);
    if threads <= 1 || n <= 1 {
        let mut scratch = init();
        return (0..n).map(|i| f(i, &mut scratch)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots: Vec<Mutex<&mut Option<T>>> =
        out.iter_mut().map(Mutex::new).collect();
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut scratch = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(i, &mut scratch);
                    **slots[i].lock().unwrap() = Some(v);
                }
            });
        }
    });
    out.into_iter()
        .map(|v| v.expect("parallel_map_scratch slot unfilled"))
        .collect()
}

/// `parallel_for` over disjoint chunks of a mutable slice.
pub fn parallel_for_chunks<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0);
    let chunks: Vec<(usize, &mut [T])> =
        data.chunks_mut(chunk).enumerate().collect();
    let n = chunks.len();
    let work: Vec<Mutex<Option<(usize, &mut [T])>>> =
        chunks.into_iter().map(|c| Mutex::new(Some(c))).collect();
    let next = AtomicUsize::new(0);
    let threads = threads.min(n).max(1);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let (idx, slice) = work[i].lock().unwrap().take().unwrap();
                f(idx, slice);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop waits for completion.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(100, 8, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_borrows() {
        let data: Vec<u64> = (0..50).collect();
        let out = parallel_map(50, 4, |i| data[i] + 1);
        assert_eq!(out[49], 50);
    }

    #[test]
    fn parallel_chunks_cover_slice() {
        let mut data = vec![0u32; 1000];
        parallel_for_chunks(&mut data, 13, 4, |idx, chunk| {
            for v in chunk.iter_mut() {
                *v = idx as u32 + 1;
            }
        });
        assert!(data.iter().all(|&v| v > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[999], (999 / 13 + 1) as u32);
    }

    #[test]
    fn parallel_map_single_thread_fallback() {
        let out = parallel_map(5, 1, |i| i);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn parallel_map_scratch_reuses_per_thread_state() {
        let inits = Arc::new(AtomicU64::new(0));
        let threads = 4;
        let out = parallel_map_scratch(
            64,
            threads,
            {
                let inits = Arc::clone(&inits);
                move || {
                    inits.fetch_add(1, Ordering::SeqCst);
                    Vec::<u64>::new()
                }
            },
            |i, scratch: &mut Vec<u64>| {
                // the scratch grows monotonically within a thread: reuse
                scratch.push(i as u64);
                i * 2
            },
        );
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
        }
        let n_inits = inits.load(Ordering::SeqCst);
        assert!(n_inits >= 1 && n_inits <= threads as u64, "{n_inits} inits");
    }
}
