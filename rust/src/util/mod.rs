//! General-purpose substrates built from scratch for the offline
//! environment (no serde / clap / tokio / criterion / proptest available):
//! JSON, CLI parsing, a thread pool, summary statistics, a small
//! property-testing harness, and tabular/CSV/ASCII-plot reporting.

pub mod cli;
pub mod csv;
pub mod json;
pub mod plot;
pub mod pool;
pub mod prop;
pub mod stats;

/// Format a float compactly for tables (trims trailing zeros).
pub fn fmt_g(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if (1e-4..1e7).contains(&a) {
        let s = format!("{x:.6}");
        let s = s.trim_end_matches('0').trim_end_matches('.').to_string();
        if s.is_empty() { "0".into() } else { s }
    } else {
        format!("{x:.4e}")
    }
}

/// `linspace(a, b, n)` — `n` evenly spaced points including both ends.
pub fn linspace(a: f64, b: f64, n: usize) -> Vec<f64> {
    assert!(n >= 2);
    let step = (b - a) / (n - 1) as f64;
    (0..n).map(|i| a + step * i as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_g_trims() {
        assert_eq!(fmt_g(1.5), "1.5");
        assert_eq!(fmt_g(2.0), "2");
        assert_eq!(fmt_g(0.0), "0");
        assert!(fmt_g(1.0e-9).contains('e'));
    }

    #[test]
    fn linspace_endpoints() {
        let xs = linspace(0.0, 1.0, 5);
        assert_eq!(xs.len(), 5);
        assert_eq!(xs[0], 0.0);
        assert!((xs[4] - 1.0).abs() < 1e-12);
        assert!((xs[2] - 0.5).abs() < 1e-12);
    }
}
