//! CSV writing/reading for experiment outputs. Every experiment harness
//! emits its figure/table data as CSV under `results/` so the numbers in
//! EXPERIMENTS.md can be regenerated and diffed.

use std::fs;
use std::io::Write;
use std::path::Path;

use crate::util::fmt_g;

/// An in-memory CSV table with a header row.
#[derive(Clone, Debug, Default)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> Self {
        CsvTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of raw strings. Must match the header width.
    pub fn push_raw(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "csv row width mismatch");
        self.rows.push(row);
    }

    /// Append a row of floats (formatted compactly).
    pub fn push_f64(&mut self, row: &[f64]) {
        self.push_raw(row.iter().map(|&x| fmt_g(x)).collect());
    }

    /// Append a row that starts with a label followed by floats.
    pub fn push_labeled(&mut self, label: &str, row: &[f64]) {
        let mut v = vec![label.to_string()];
        v.extend(row.iter().map(|&x| fmt_g(x)));
        self.push_raw(v);
    }

    /// Serialize with proper quoting.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        s.push_str(&join_csv(&self.header));
        s.push('\n');
        for r in &self.rows {
            s.push_str(&join_csv(r));
            s.push('\n');
        }
        s
    }

    /// Write to a file, creating parent directories.
    pub fn write(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }

    /// Parse CSV text (quoted fields supported).
    pub fn parse(text: &str) -> Option<CsvTable> {
        let mut lines = text.lines();
        let header = split_csv(lines.next()?);
        let mut rows = Vec::new();
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            rows.push(split_csv(line));
        }
        Some(CsvTable { header, rows })
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// A whole column parsed as f64 (non-numeric cells become NaN).
    pub fn col_f64(&self, name: &str) -> Option<Vec<f64>> {
        let idx = self.col(name)?;
        Some(
            self.rows
                .iter()
                .map(|r| r[idx].parse().unwrap_or(f64::NAN))
                .collect(),
        )
    }
}

fn needs_quoting(s: &str) -> bool {
    s.contains(',') || s.contains('"') || s.contains('\n')
}

fn join_csv(fields: &[String]) -> String {
    fields
        .iter()
        .map(|f| {
            if needs_quoting(f) {
                format!("\"{}\"", f.replace('"', "\"\""))
            } else {
                f.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

fn split_csv(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_with_quotes() {
        let mut t = CsvTable::new(&["name", "x"]);
        t.push_raw(vec!["hello, world".into(), "1.5".into()]);
        t.push_raw(vec!["quote\"d".into(), "2".into()]);
        let s = t.to_string();
        let t2 = CsvTable::parse(&s).unwrap();
        assert_eq!(t.header, t2.header);
        assert_eq!(t.rows, t2.rows);
    }

    #[test]
    fn float_rows_and_columns() {
        let mut t = CsvTable::new(&["t", "loss"]);
        t.push_f64(&[0.5, 0.25]);
        t.push_f64(&[1.0, 0.125]);
        let loss = t.col_f64("loss").unwrap();
        assert_eq!(loss, vec![0.25, 0.125]);
        assert_eq!(t.col("t"), Some(0));
        assert_eq!(t.col("missing"), None);
    }

    #[test]
    fn labeled_rows() {
        let mut t = CsvTable::new(&["scheme", "v"]);
        t.push_labeled("now-uep", &[0.75]);
        assert_eq!(t.rows[0][0], "now-uep");
    }
}
