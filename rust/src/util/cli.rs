//! Declarative command-line parsing (clap is not available offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with typed accessors and defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// A parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    /// Option values by name (flags map to "true").
    pub options: BTreeMap<String, String>,
}

/// Error from argument parsing or typed access.
#[derive(Debug)]
pub enum CliError {
    UnknownOption(String),
    MissingValue(String),
    InvalidValue(String, String),
    MissingRequired(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownOption(n) => write!(f, "unknown option --{n}"),
            CliError::MissingValue(n) => write!(f, "option --{n} requires a value"),
            CliError::InvalidValue(n, v) => write!(f, "invalid value for --{n}: {v}"),
            CliError::MissingRequired(n) => write!(f, "missing required option --{n}"),
        }
    }
}

impl std::error::Error for CliError {}

/// A command parser: name, description, declared options.
pub struct Command {
    pub name: String,
    pub about: String,
    opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Command { name: name.to_string(), about: about.to_string(), opts: Vec::new() }
    }

    /// Declare a `--key value` option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a required `--key value` option.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    /// Declare a boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some("false".to_string()),
            is_flag: true,
        });
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = writeln!(s, "\noptions:");
        for o in &self.opts {
            let meta = if o.is_flag { "" } else { " <value>" };
            let def = match (&o.default, o.is_flag) {
                (Some(d), false) => format!(" [default: {d}]"),
                _ => String::new(),
            };
            let _ = writeln!(s, "  --{}{:<14} {}{}", o.name, meta, o.help, def);
        }
        s
    }

    /// Parse raw arguments against the declared options.
    pub fn parse(&self, raw: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.options.insert(o.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError::UnknownOption(key.clone()))?;
                let value = if spec.is_flag {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    raw.get(i)
                        .cloned()
                        .ok_or_else(|| CliError::MissingValue(key.clone()))?
                };
                args.options.insert(key, value);
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if o.default.is_none() && !args.options.contains_key(o.name) {
                return Err(CliError::MissingRequired(o.name.to_string()));
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn get_str(&self, name: &str) -> &str {
        self.options.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    /// Parse an option through any `FromStr` type (e.g.
    /// `args.get::<LatencyModel>("latency")` for `--latency exp:1.0`).
    /// The type's own parse error rides along in the message, so rich
    /// diagnostics (like `LatencyModel`'s) reach the user.
    pub fn get<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        let s = self.get_str(name);
        s.parse().map_err(|e| {
            CliError::InvalidValue(name.to_string(), format!("{s} ({e})"))
        })
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        let s = self.get_str(name);
        s.parse()
            .map_err(|_| CliError::InvalidValue(name.to_string(), s.to_string()))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        let s = self.get_str(name);
        s.parse()
            .map_err(|_| CliError::InvalidValue(name.to_string(), s.to_string()))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        let s = self.get_str(name);
        s.parse()
            .map_err(|_| CliError::InvalidValue(name.to_string(), s.to_string()))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get_str(name), "true" | "1" | "yes")
    }

    /// Comma-separated list of floats, e.g. `--tmax 0.25,0.5,1,2`.
    pub fn get_f64_list(&self, name: &str) -> Result<Vec<f64>, CliError> {
        let s = self.get_str(name);
        s.split(',')
            .filter(|t| !t.trim().is_empty())
            .map(|t| {
                t.trim().parse().map_err(|_| {
                    CliError::InvalidValue(name.to_string(), s.to_string())
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("test", "a test command")
            .opt("workers", "30", "number of workers")
            .opt("lambda", "1.0", "latency rate")
            .flag("verbose", "print more")
            .req("out", "output path")
    }

    fn to_vec(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd().parse(&to_vec(&["--out", "x.csv", "--workers", "15"])).unwrap();
        assert_eq!(a.get_usize("workers").unwrap(), 15);
        assert_eq!(a.get_f64("lambda").unwrap(), 1.0);
        assert_eq!(a.get_str("out"), "x.csv");
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = cmd().parse(&to_vec(&["--out=y", "--verbose", "pos1"])).unwrap();
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_required_rejected() {
        assert!(matches!(
            cmd().parse(&to_vec(&["--workers", "3"])),
            Err(CliError::MissingRequired(_))
        ));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            cmd().parse(&to_vec(&["--out", "x", "--nope", "1"])),
            Err(CliError::UnknownOption(_))
        ));
    }

    #[test]
    fn float_lists() {
        let a = cmd().parse(&to_vec(&["--out", "x"])).unwrap();
        assert!(a.get_f64_list("lambda").unwrap() == vec![1.0]);
        let c = Command::new("c", "").opt("tmax", "0.25,0.5,1,2", "");
        let a = c.parse(&[]).unwrap();
        assert_eq!(a.get_f64_list("tmax").unwrap(), vec![0.25, 0.5, 1.0, 2.0]);
    }

    #[test]
    fn generic_get_parses_fromstr_types() {
        let a = cmd().parse(&to_vec(&["--out", "x", "--lambda", "2.5"])).unwrap();
        assert_eq!(a.get::<f64>("lambda").unwrap(), 2.5);
        assert_eq!(a.get::<usize>("workers").unwrap(), 30);
        let m: crate::latency::LatencyModel = {
            let c = Command::new("c", "").opt("latency", "exp:1.0", "");
            c.parse(&[]).unwrap().get("latency").unwrap()
        };
        assert_eq!(m, crate::latency::LatencyModel::exp(1.0));
        assert!(matches!(
            a.get::<usize>("out"),
            Err(CliError::InvalidValue(_, _))
        ));
    }

    #[test]
    fn help_mentions_options() {
        let h = cmd().help();
        assert!(h.contains("--workers"));
        assert!(h.contains("default: 30"));
    }
}
