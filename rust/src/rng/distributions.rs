//! Continuous distributions used across the paper's experiments:
//! Gaussian matrix entries (Assumption 1), exponential worker latencies
//! (§VI–VII), plus Pareto for heavy-tailed straggler ablations.

use super::{Pcg64, Sample};

/// Uniform distribution on `[lo, hi)`.
#[derive(Clone, Copy, Debug)]
pub struct Uniform {
    pub lo: f64,
    pub hi: f64,
}

impl Uniform {
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(hi > lo);
        Uniform { lo, hi }
    }
}

impl Sample for Uniform {
    type Output = f64;
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }
}

/// Gaussian distribution `N(mean, sd²)`, sampled via Box–Muller with a
/// cached second variate.
#[derive(Clone, Copy, Debug)]
pub struct Normal {
    pub mean: f64,
    pub sd: f64,
}

impl Normal {
    pub fn new(mean: f64, sd: f64) -> Self {
        assert!(sd >= 0.0, "negative standard deviation");
        Normal { mean, sd }
    }

    /// From a variance rather than a standard deviation.
    pub fn from_variance(mean: f64, var: f64) -> Self {
        Normal::new(mean, var.sqrt())
    }

    /// Standard normal sample (mean 0, sd 1).
    #[inline]
    pub fn standard(rng: &mut Pcg64) -> f64 {
        // Box–Muller; we deliberately do not cache the second variate so
        // the sampler stays stateless (reproducibility across call sites).
        let u1 = loop {
            let u = rng.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = rng.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

impl Sample for Normal {
    type Output = f64;
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.mean + self.sd * Normal::standard(rng)
    }
}

/// Exponential distribution with rate `lambda` (mean `1/lambda`) — the
/// paper's worker-latency model, sampled by CDF inversion.
#[derive(Clone, Copy, Debug)]
pub struct Exponential {
    pub lambda: f64,
}

impl Exponential {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0, "rate must be positive");
        Exponential { lambda }
    }

    /// CDF `F(t) = 1 - exp(-λ t)` for `t ≥ 0`.
    pub fn cdf(&self, t: f64) -> f64 {
        if t <= 0.0 {
            0.0
        } else {
            1.0 - (-self.lambda * t).exp()
        }
    }
}

impl Sample for Exponential {
    type Output = f64;
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        let u = loop {
            let u = rng.next_f64();
            if u < 1.0 {
                break u;
            }
        };
        -(1.0 - u).ln() / self.lambda
    }
}

/// Pareto (type I) distribution: heavy-tailed latency ablation.
#[derive(Clone, Copy, Debug)]
pub struct Pareto {
    /// Scale (minimum value), > 0.
    pub x_min: f64,
    /// Tail index, > 0; smaller = heavier tail.
    pub alpha: f64,
}

impl Pareto {
    pub fn new(x_min: f64, alpha: f64) -> Self {
        assert!(x_min > 0.0 && alpha > 0.0);
        Pareto { x_min, alpha }
    }

    pub fn cdf(&self, t: f64) -> f64 {
        if t <= self.x_min {
            0.0
        } else {
            1.0 - (self.x_min / t).powf(self.alpha)
        }
    }
}

impl Sample for Pareto {
    type Output = f64;
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        let u = loop {
            let u = rng.next_f64();
            if u < 1.0 {
                break u;
            }
        };
        self.x_min / (1.0 - u).powf(1.0 / self.alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(samples: &[f64]) -> (f64, f64) {
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed_from(42);
        let d = Normal::new(3.0, 2.0);
        let xs: Vec<f64> = (0..200_000).map(|_| d.sample(&mut rng)).collect();
        let (m, v) = moments(&xs);
        assert!((m - 3.0).abs() < 0.02, "mean {m}");
        assert!((v - 4.0).abs() < 0.08, "var {v}");
    }

    #[test]
    fn exponential_moments_and_cdf() {
        let mut rng = Pcg64::seed_from(43);
        let d = Exponential::new(2.0);
        let xs: Vec<f64> = (0..200_000).map(|_| d.sample(&mut rng)).collect();
        let (m, v) = moments(&xs);
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
        assert!((v - 0.25).abs() < 0.02, "var {v}");
        // empirical CDF vs analytic at a few points
        for t in [0.1, 0.5, 1.0] {
            let emp = xs.iter().filter(|&&x| x <= t).count() as f64 / xs.len() as f64;
            assert!((emp - d.cdf(t)).abs() < 0.01);
        }
    }

    #[test]
    fn pareto_support_and_median() {
        let mut rng = Pcg64::seed_from(44);
        let d = Pareto::new(1.0, 2.0);
        let xs: Vec<f64> = (0..100_000).map(|_| d.sample(&mut rng)).collect();
        assert!(xs.iter().all(|&x| x >= 1.0));
        let median_analytic = 1.0 * 2f64.powf(1.0 / 2.0);
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let emp_median = sorted[xs.len() / 2];
        assert!((emp_median - median_analytic).abs() < 0.03);
    }

    #[test]
    fn uniform_bounds() {
        let mut rng = Pcg64::seed_from(45);
        let d = Uniform::new(-2.0, 5.0);
        for _ in 0..10_000 {
            let x = d.sample(&mut rng);
            assert!((-2.0..5.0).contains(&x));
        }
    }
}
