//! PCG-XSH-RR 64/32 generator (O'Neill 2014), extended to 64-bit output by
//! drawing two 32-bit values. Small state, excellent statistical quality,
//! trivially seedable and splittable — exactly what reproducible
//! simulations need.

/// A 64-bit-state permuted congruential generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed, using a fixed default stream.
    pub fn seed_from(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Create a generator with an explicit stream selector; different
    /// streams from the same seed are independent sequences.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        rng.step();
        rng.state = rng.state.wrapping_add(seed);
        rng.step();
        rng
    }

    /// Derive an independent child generator (for parallel trials).
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg64::with_stream(seed, stream)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.step();
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire rejection).
    #[inline]
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        // Rejection sampling on the top of the range to remove modulo bias.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Random boolean with probability `p` of `true`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::seed_from(123);
        let mut b = Pcg64::seed_from(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed_from(1);
        let mut b = Pcg64::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_decorrelated() {
        let mut root = Pcg64::seed_from(9);
        let mut a = root.split();
        let mut b = root.split();
        // crude correlation check on signs
        let n = 10_000;
        let mut agree = 0;
        for _ in 0..n {
            if (a.next_f64() < 0.5) == (b.next_f64() < 0.5) {
                agree += 1;
            }
        }
        let frac = agree as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.02, "agreement {frac}");
    }

    #[test]
    fn f64_in_unit_interval_and_uniform() {
        let mut rng = Pcg64::seed_from(77);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.005);
    }

    #[test]
    fn bounded_is_unbiased_small_bound() {
        let mut rng = Pcg64::seed_from(5);
        let mut counts = [0usize; 3];
        let n = 90_000;
        for _ in 0..n {
            counts[rng.next_bounded(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 / n as f64 - 1.0 / 3.0).abs() < 0.01);
        }
    }
}
