//! Deterministic pseudo-random number generation and sampling.
//!
//! The offline build environment vendors no `rand` crate, so the whole
//! stack runs on this self-contained PCG implementation. Every stochastic
//! component in the library (matrix sampling, window selection, RLC
//! coefficients, worker latencies, Monte-Carlo trials) takes an explicit
//! `&mut Pcg64` so that simulations are exactly reproducible from a seed
//! and parallel trials can use [`Pcg64::split`] streams.

mod distributions;
mod pcg;

pub use distributions::{Exponential, Normal, Pareto, Uniform};
pub use pcg::Pcg64;

/// Types that can sample a value from an RNG.
pub trait Sample {
    type Output;
    fn sample(&self, rng: &mut Pcg64) -> Self::Output;
}

/// Fill a slice with i.i.d. standard normal values.
pub fn fill_standard_normal(rng: &mut Pcg64, out: &mut [f64]) {
    let dist = Normal::new(0.0, 1.0);
    for v in out.iter_mut() {
        *v = dist.sample(rng);
    }
}

/// Sample an index from a (not necessarily normalized) discrete
/// distribution given by `weights`. Panics if all weights are zero.
pub fn sample_discrete(rng: &mut Pcg64, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "sample_discrete: all weights zero");
    let mut u = rng.next_f64() * total;
    for (i, w) in weights.iter().enumerate() {
        if u < *w {
            return i;
        }
        u -= w;
    }
    weights.len() - 1
}

/// Fisher–Yates shuffle.
pub fn shuffle<T>(rng: &mut Pcg64, xs: &mut [T]) {
    for i in (1..xs.len()).rev() {
        let j = rng.next_bounded((i + 1) as u64) as usize;
        xs.swap(i, j);
    }
}

/// A random permutation of `0..n`.
pub fn permutation(rng: &mut Pcg64, n: usize) -> Vec<usize> {
    let mut p: Vec<usize> = (0..n).collect();
    shuffle(rng, &mut p);
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discrete_sampling_matches_weights() {
        let mut rng = Pcg64::seed_from(7);
        let w = [0.5, 0.3, 0.2];
        let mut counts = [0usize; 3];
        let n = 200_000;
        for _ in 0..n {
            counts[sample_discrete(&mut rng, &w)] += 1;
        }
        for (c, expect) in counts.iter().zip(w.iter()) {
            let freq = *c as f64 / n as f64;
            assert!((freq - expect).abs() < 0.01, "freq {freq} vs {expect}");
        }
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut rng = Pcg64::seed_from(3);
        let p = permutation(&mut rng, 100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn shuffle_preserves_multiset() {
        let mut rng = Pcg64::seed_from(11);
        let mut xs: Vec<u32> = (0..50).map(|i| i % 7).collect();
        let mut sorted_before = xs.clone();
        sorted_before.sort_unstable();
        shuffle(&mut rng, &mut xs);
        xs.sort_unstable();
        assert_eq!(xs, sorted_before);
    }
}
