//! Worker-latency models (paper §II eq. 8, Remark 1), order-statistic
//! analytics (§III-A eqs. 13–14), and online estimators that fit a model
//! back from observed completion times ([`estimator`]).
//!
//! Worker completion times are i.i.d. `T_w ~ F`. For fair comparisons
//! across coding schemes with different worker counts, the paper scales
//! time as `F(Ω·t)` with `Ω = (#sub-products)/W` — total service capacity
//! stays constant as `W` changes.

pub mod estimator;

pub use estimator::{FleetEstimator, LatencyEstimator, OnlineStats};

use crate::rng::{Exponential, Pareto, Pcg64, Sample};

/// An i.i.d. worker completion-time distribution.
#[derive(Clone, Debug, PartialEq)]
pub enum LatencyModel {
    /// `F(t) = 1 − e^{−λt}` — the paper's model throughout.
    Exponential { lambda: f64 },
    /// `F(t) = 1 − e^{−λ(t−s)}` for `t ≥ s`: constant setup + exp tail
    /// (the classical coded-computation model of Lee et al.).
    ShiftedExponential { shift: f64, lambda: f64 },
    /// Every worker finishes at exactly `t` (the "no stragglers" red
    /// curve in Figs. 1/13–15).
    Deterministic { t: f64 },
    /// Heavy-tailed stragglers (ablation).
    Pareto { x_min: f64, alpha: f64 },
}

impl LatencyModel {
    /// The paper's default: `Exponential { lambda }`.
    pub fn exp(lambda: f64) -> Self {
        LatencyModel::Exponential { lambda }
    }

    /// CDF `F(t)` (unscaled).
    pub fn cdf(&self, t: f64) -> f64 {
        match self {
            LatencyModel::Exponential { lambda } => Exponential::new(*lambda).cdf(t),
            LatencyModel::ShiftedExponential { shift, lambda } => {
                if t <= *shift {
                    0.0
                } else {
                    1.0 - (-(lambda) * (t - shift)).exp()
                }
            }
            LatencyModel::Deterministic { t: t0 } => {
                if t >= *t0 {
                    1.0
                } else {
                    0.0
                }
            }
            LatencyModel::Pareto { x_min, alpha } => Pareto::new(*x_min, *alpha).cdf(t),
        }
    }

    /// CDF under the paper's Ω scaling: `P[T ≤ t] = F(Ω·t)`.
    pub fn cdf_scaled(&self, t: f64, omega: f64) -> f64 {
        self.cdf(omega * t)
    }

    /// Sample an unscaled completion time.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match self {
            LatencyModel::Exponential { lambda } => Exponential::new(*lambda).sample(rng),
            LatencyModel::ShiftedExponential { shift, lambda } => {
                shift + Exponential::new(*lambda).sample(rng)
            }
            LatencyModel::Deterministic { t } => *t,
            LatencyModel::Pareto { x_min, alpha } => Pareto::new(*x_min, *alpha).sample(rng),
        }
    }

    /// Sample a completion time under Ω scaling (`T' = T/Ω`).
    pub fn sample_scaled(&self, omega: f64, rng: &mut Pcg64) -> f64 {
        assert!(omega > 0.0);
        self.sample(rng) / omega
    }

    /// Mean of the unscaled distribution.
    pub fn mean(&self) -> f64 {
        match self {
            LatencyModel::Exponential { lambda } => 1.0 / lambda,
            LatencyModel::ShiftedExponential { shift, lambda } => shift + 1.0 / lambda,
            LatencyModel::Deterministic { t } => *t,
            LatencyModel::Pareto { x_min, alpha } => {
                if *alpha <= 1.0 {
                    f64::INFINITY
                } else {
                    alpha * x_min / (alpha - 1.0)
                }
            }
        }
    }
}

/// Text form used by CLI flags and config files (`--latency exp:1.0`),
/// the inverse of [`LatencyModel`]'s `FromStr`:
/// `exp:λ`, `det:t`, `sexp:shift:λ`, `pareto:xmin:α`.
impl std::fmt::Display for LatencyModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LatencyModel::Exponential { lambda } => write!(f, "exp:{lambda}"),
            LatencyModel::ShiftedExponential { shift, lambda } => {
                write!(f, "sexp:{shift}:{lambda}")
            }
            LatencyModel::Deterministic { t } => write!(f, "det:{t}"),
            LatencyModel::Pareto { x_min, alpha } => write!(f, "pareto:{x_min}:{alpha}"),
        }
    }
}

/// Parse the colon-separated spec format, e.g. `exp:1.0`, `det:0.5`,
/// `sexp:0.2:1.0` (shift, rate), `pareto:1.0:2.5` (x_min, tail index).
/// Long spellings `exponential`, `deterministic`, `shifted-exp` are
/// accepted too; parameters must be finite and positive (the shift may
/// be zero).
impl std::str::FromStr for LatencyModel {
    type Err = String;

    fn from_str(s: &str) -> Result<LatencyModel, String> {
        let parts: Vec<&str> = s.split(':').map(str::trim).collect();
        let num = |v: &str, what: &str| -> Result<f64, String> {
            let x: f64 = v
                .parse()
                .map_err(|_| format!("latency model '{s}': bad {what} '{v}'"))?;
            if !x.is_finite() {
                return Err(format!("latency model '{s}': {what} must be finite"));
            }
            Ok(x)
        };
        let positive = |x: f64, what: &str| -> Result<f64, String> {
            if x > 0.0 {
                Ok(x)
            } else {
                Err(format!("latency model '{s}': {what} must be > 0"))
            }
        };
        match parts.as_slice() {
            ["exp" | "exponential", l] => {
                Ok(LatencyModel::Exponential { lambda: positive(num(l, "rate")?, "rate")? })
            }
            ["det" | "deterministic", t] => {
                Ok(LatencyModel::Deterministic { t: positive(num(t, "time")?, "time")? })
            }
            ["sexp" | "shifted-exp", sh, l] => {
                let shift = num(sh, "shift")?;
                if shift < 0.0 {
                    return Err(format!("latency model '{s}': shift must be ≥ 0"));
                }
                Ok(LatencyModel::ShiftedExponential {
                    shift,
                    lambda: positive(num(l, "rate")?, "rate")?,
                })
            }
            ["pareto", xm, a] => Ok(LatencyModel::Pareto {
                x_min: positive(num(xm, "x_min")?, "x_min")?,
                alpha: positive(num(a, "alpha")?, "alpha")?,
            }),
            _ => Err(format!(
                "unknown latency model '{s}' (expected exp:λ, det:t, \
                 sexp:shift:λ, or pareto:xmin:α)"
            )),
        }
    }
}

/// The paper's Ω (Remark 1 / Table VII): sub-products per worker.
pub fn omega(num_subproducts: usize, workers: usize) -> f64 {
    num_subproducts as f64 / workers as f64
}

/// Expected value of the `k`-th order statistic (k-th fastest of `w`)
/// for `Exp(λ)`: `(H_w − H_{w−k})/λ` with `H` the harmonic numbers.
/// This is the expected time for `k` of `w` workers to finish — the
/// quantity behind eqs. (13)–(14).
pub fn exp_order_statistic_mean(w: usize, k: usize, lambda: f64) -> f64 {
    assert!(k >= 1 && k <= w);
    let h = |n: usize| (1..=n).map(|i| 1.0 / i as f64).sum::<f64>();
    (h(w) - h(w - k)) / lambda
}

/// Lower bound (14) on the expected completion time of `δ`-replication:
/// `(1/μ)·log((1+δ)/δ) + O(1)`.
pub fn replication_time_lower_bound(delta: f64, mu: f64) -> f64 {
    ((1.0 + delta) / delta).ln() / mu
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_sanity() {
        let m = LatencyModel::exp(2.0);
        assert_eq!(m.cdf(0.0), 0.0);
        assert!((m.cdf(0.5) - (1.0 - (-1.0f64).exp())).abs() < 1e-12);
        assert!(m.cdf(100.0) > 0.999);
    }

    #[test]
    fn omega_scaling_makes_workers_slower_when_w_grows() {
        // Ω = 9/15 < 1 ⇒ scaled time T/Ω > T: each of the 15 workers is
        // slower so total capacity matches the 9-worker uncoded setup.
        let om = omega(9, 15);
        assert!((om - 0.6).abs() < 1e-12);
        let mut rng = Pcg64::seed_from(1);
        let m = LatencyModel::exp(1.0);
        let n = 100_000;
        let mean_scaled: f64 =
            (0..n).map(|_| m.sample_scaled(om, &mut rng)).sum::<f64>() / n as f64;
        assert!((mean_scaled - 1.0 / om).abs() < 0.03);
    }

    #[test]
    fn scaled_cdf_matches_scaled_samples() {
        let mut rng = Pcg64::seed_from(2);
        let m = LatencyModel::exp(0.5);
        let om = 9.0 / 18.0;
        let t = 1.5;
        let n = 200_000;
        let emp = (0..n)
            .filter(|_| m.sample_scaled(om, &mut rng) <= t)
            .count() as f64
            / n as f64;
        assert!((emp - m.cdf_scaled(t, om)).abs() < 0.01);
    }

    #[test]
    fn shifted_exponential() {
        let m = LatencyModel::ShiftedExponential { shift: 1.0, lambda: 2.0 };
        assert_eq!(m.cdf(0.5), 0.0);
        assert!(m.cdf(1.5) > 0.0);
        assert!((m.mean() - 1.5).abs() < 1e-12);
        let mut rng = Pcg64::seed_from(3);
        for _ in 0..100 {
            assert!(m.sample(&mut rng) >= 1.0);
        }
    }

    #[test]
    fn deterministic_no_stragglers() {
        let m = LatencyModel::Deterministic { t: 0.7 };
        let mut rng = Pcg64::seed_from(4);
        assert_eq!(m.sample(&mut rng), 0.7);
        assert_eq!(m.cdf(0.69), 0.0);
        assert_eq!(m.cdf(0.7), 1.0);
    }

    #[test]
    fn order_statistic_mean_matches_monte_carlo() {
        let (w, k, lambda) = (10, 7, 1.0);
        let analytic = exp_order_statistic_mean(w, k, lambda);
        let mut rng = Pcg64::seed_from(5);
        let m = LatencyModel::exp(lambda);
        let trials = 20_000;
        let mut sum = 0.0;
        for _ in 0..trials {
            let mut ts: Vec<f64> = (0..w).map(|_| m.sample(&mut rng)).collect();
            ts.sort_by(|a, b| a.total_cmp(b));
            sum += ts[k - 1];
        }
        let mc = sum / trials as f64;
        assert!((analytic - mc).abs() < 0.02, "{analytic} vs {mc}");
    }

    #[test]
    fn latency_models_parse_from_cli_specs() {
        assert_eq!(
            "exp:1.5".parse::<LatencyModel>().unwrap(),
            LatencyModel::Exponential { lambda: 1.5 }
        );
        assert_eq!(
            "exponential:0.5".parse::<LatencyModel>().unwrap(),
            LatencyModel::exp(0.5)
        );
        assert_eq!(
            "det:0.7".parse::<LatencyModel>().unwrap(),
            LatencyModel::Deterministic { t: 0.7 }
        );
        assert_eq!(
            "sexp:0.2:2.0".parse::<LatencyModel>().unwrap(),
            LatencyModel::ShiftedExponential { shift: 0.2, lambda: 2.0 }
        );
        assert_eq!(
            "sexp:0:1".parse::<LatencyModel>().unwrap(),
            LatencyModel::ShiftedExponential { shift: 0.0, lambda: 1.0 }
        );
        assert_eq!(
            "pareto:1.0:2.5".parse::<LatencyModel>().unwrap(),
            LatencyModel::Pareto { x_min: 1.0, alpha: 2.5 }
        );
        // whitespace around fields is tolerated
        assert_eq!(
            " pareto : 1.0 : 2.5 ".trim().parse::<LatencyModel>().unwrap(),
            LatencyModel::Pareto { x_min: 1.0, alpha: 2.5 }
        );
    }

    #[test]
    fn bad_latency_specs_are_rejected_with_context() {
        for bad in [
            "",
            "exp",
            "exp:",
            "exp:zero",
            "exp:-1",
            "exp:0",
            "exp:inf",
            "det:0",
            "sexp:-0.1:1",
            "pareto:1.0",
            "pareto:1:2:3",
            "gauss:1.0",
        ] {
            let err = bad.parse::<LatencyModel>().unwrap_err();
            assert!(!err.is_empty(), "{bad}");
        }
    }

    #[test]
    fn display_round_trips_through_parse() {
        for model in [
            LatencyModel::exp(1.0),
            LatencyModel::Deterministic { t: 0.25 },
            LatencyModel::ShiftedExponential { shift: 0.5, lambda: 3.0 },
            LatencyModel::Pareto { x_min: 1.0, alpha: 2.5 },
        ] {
            let text = model.to_string();
            assert_eq!(text.parse::<LatencyModel>().unwrap(), model, "{text}");
        }
    }

    #[test]
    fn replication_bound_decreases_with_delta() {
        let a = replication_time_lower_bound(1.0, 1.0);
        let b = replication_time_lower_bound(3.0, 1.0);
        assert!(a > b);
        assert!((a - 2.0f64.ln()).abs() < 1e-12);
    }
}
