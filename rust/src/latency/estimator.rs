//! Online latency estimation: fit a [`LatencyModel`] (and per-worker
//! scale offsets) from observed job round-trip times.
//!
//! The planning formulas (Theorems 2/3, [`crate::analysis::TheoremLoss`])
//! and the window-polynomial optimizer
//! ([`crate::analysis::optimize_gamma`]) take a latency model as an
//! *input*; until now that model was always assumed. The estimators here
//! close the loop: every served request reports per-job completion times
//! ([`crate::api::RunReport::timings`]), the estimator folds them into
//! running moments, and [`LatencyEstimator::fit`] produces the
//! maximum-moment-match model of the observed fleet — which the
//! [`crate::api::Replanner`] then feeds back into `optimize_gamma`.
//!
//! Everything here is deterministic: fits are pure functions of the
//! observed sample stream, so a `Virtual`-time run replans
//! bit-identically across repetitions and thread counts.

use std::collections::BTreeMap;

use super::LatencyModel;

/// Numerically stable running moments (Welford) plus extremes.
#[derive(Clone, Debug)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for OnlineStats {
    fn default() -> Self {
        OnlineStats::new()
    }
}

impl OnlineStats {
    pub fn new() -> OnlineStats {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: 0.0 }
    }

    /// Fold one observation in. Non-finite or negative values are
    /// ignored (a completion time is a duration).
    pub fn push(&mut self, x: f64) {
        if !x.is_finite() || x < 0.0 {
            return;
        }
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Unbiased sample variance (0 for fewer than two observations).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fits a [`LatencyModel`] to observed completion times by the method of
/// moments, with a deterministic family-selection rule.
///
/// Observed delays are in *scaled* time (what workers report under the
/// paper's Ω capacity scaling: `T' = T/Ω`); the estimator multiplies by
/// `omega` internally so the fitted model lives in the same unscaled
/// units as the assumed model it replaces — `fit()` composes directly
/// with [`LatencyModel::cdf_scaled`] and
/// [`crate::analysis::TheoremLoss`].
#[derive(Clone, Debug)]
pub struct LatencyEstimator {
    omega: f64,
    stats: OnlineStats,
}

impl LatencyEstimator {
    /// `omega` is the Ω the observed delays were scaled by (use 1.0 for
    /// raw unscaled observations).
    pub fn new(omega: f64) -> LatencyEstimator {
        assert!(omega > 0.0, "omega must be positive");
        LatencyEstimator { omega, stats: OnlineStats::new() }
    }

    /// Fold one observed (scaled) completion time in.
    pub fn observe(&mut self, scaled_delay: f64) {
        self.stats.push(scaled_delay * self.omega);
    }

    /// Observations folded in so far.
    pub fn count(&self) -> u64 {
        self.stats.count()
    }

    /// Running stats over the *unscaled* observations.
    pub fn stats(&self) -> &OnlineStats {
        &self.stats
    }

    /// Method-of-moments fit over the observed sample, `None` until at
    /// least two observations have landed. Family selection, in order:
    ///
    /// * coefficient of variation `cv < 0.05` → [`LatencyModel::Deterministic`]
    ///   at the mean (no spread ⇒ no stragglers);
    /// * sample support bounded away from zero (`min > 0.2·mean`) — only
    ///   then are the shifted families honest, since both put zero mass
    ///   below their onset:
    ///   * `cv² > 1.5` → [`LatencyModel::Pareto`]
    ///     (heavier-than-exponential tail; `α` from `cv² = 1/(α(α−2))`,
    ///     `x_min` from the mean),
    ///   * else → [`LatencyModel::ShiftedExponential`] (constant setup +
    ///     exp tail: `shift = min`, `λ = 1/(mean−min)`);
    /// * otherwise → [`LatencyModel::Exponential`] with `λ = 1/mean`
    ///   (the paper's model — and the right mean-matching default for
    ///   zero-supported heterogeneous mixtures, which must *not* be
    ///   mistaken for a distribution that forbids early arrivals).
    pub fn fit(&self) -> Option<LatencyModel> {
        let s = &self.stats;
        if s.count() < 2 {
            return None;
        }
        let mean = s.mean();
        if !(mean > 0.0) {
            return None;
        }
        let sd = s.variance().sqrt();
        let cv = sd / mean;
        if cv < 0.05 {
            return Some(LatencyModel::Deterministic { t: mean });
        }
        let cv2 = cv * cv;
        if s.min() > 0.2 * mean && mean > s.min() {
            if cv2 > 1.5 {
                // Pareto(x_min, α): mean = αx/(α−1), var/mean² =
                // 1/(α(α−2)) ⇒ α = 1 + sqrt(1 + 1/cv²), always > 2
                let alpha = 1.0 + (1.0 + 1.0 / cv2).sqrt();
                let x_min = mean * (alpha - 1.0) / alpha;
                if alpha.is_finite() && x_min > 0.0 {
                    return Some(LatencyModel::Pareto { x_min, alpha });
                }
            }
            return Some(LatencyModel::ShiftedExponential {
                shift: s.min(),
                lambda: 1.0 / (mean - s.min()),
            });
        }
        Some(LatencyModel::Exponential { lambda: 1.0 / mean })
    }
}

/// Per-worker telemetry on top of a fleet-wide [`LatencyEstimator`]:
/// running moments per worker id, exposed as multiplicative *scale
/// offsets* against the fleet mean (1.0 = average, 3.0 = three times
/// slower). `BTreeMap` keeps iteration order — and therefore any
/// decision derived from a snapshot — deterministic.
#[derive(Clone, Debug)]
pub struct FleetEstimator {
    fleet: LatencyEstimator,
    per_worker: BTreeMap<u64, OnlineStats>,
    /// Latest EWMA straggle score per worker, as reported by cluster
    /// registry snapshots ([`crate::api::Maintenance::straggle`]) — an
    /// alternative scale source when per-job attribution is unavailable.
    ewma: BTreeMap<u64, f64>,
}

impl FleetEstimator {
    pub fn new(omega: f64) -> FleetEstimator {
        FleetEstimator {
            fleet: LatencyEstimator::new(omega),
            per_worker: BTreeMap::new(),
            ewma: BTreeMap::new(),
        }
    }

    /// Fold in one observed (scaled) completion time attributed to
    /// `worker`.
    pub fn observe(&mut self, worker: u64, scaled_delay: f64) {
        self.fleet.observe(scaled_delay);
        self.per_worker.entry(worker).or_default().push(scaled_delay);
    }

    /// Absorb a registry EWMA snapshot (`(worker id, straggle score)`).
    pub fn absorb_straggle(&mut self, snapshot: &[(u64, Option<f64>)]) {
        for &(id, s) in snapshot {
            if let Some(s) = s {
                self.ewma.insert(id, s);
            }
        }
    }

    /// The fleet-wide estimator (fit the common [`LatencyModel`] here).
    pub fn fleet(&self) -> &LatencyEstimator {
        &self.fleet
    }

    pub fn observations(&self) -> u64 {
        self.fleet.count()
    }

    /// Scale offset of `worker` against the fleet mean: per-job moments
    /// when available, the EWMA snapshot otherwise, `None` when the
    /// worker (or the fleet) has no history.
    pub fn scale_of(&self, worker: u64) -> Option<f64> {
        if let Some(st) = self.per_worker.get(&worker) {
            let fleet_mean = self.fleet.stats().mean() / self.fleet.omega;
            if st.count() > 0 && fleet_mean > 0.0 {
                return Some(st.mean() / fleet_mean);
            }
        }
        let s = *self.ewma.get(&worker)?;
        let n = self.ewma.len();
        let mean: f64 = self.ewma.values().sum::<f64>() / n as f64;
        (mean > 0.0).then(|| s / mean)
    }

    /// All known scale offsets, sorted by worker id.
    pub fn scales(&self) -> Vec<(u64, f64)> {
        let mut ids: Vec<u64> =
            self.per_worker.keys().chain(self.ewma.keys()).copied().collect();
        ids.sort_unstable();
        ids.dedup();
        ids.into_iter()
            .filter_map(|id| self.scale_of(id).map(|s| (id, s)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn feed(est: &mut LatencyEstimator, model: &LatencyModel, omega: f64, n: usize, seed: u64) {
        let mut rng = Pcg64::seed_from(seed);
        for _ in 0..n {
            est.observe(model.sample_scaled(omega, &mut rng));
        }
    }

    #[test]
    fn online_stats_match_closed_form() {
        let mut s = OnlineStats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        // garbage is ignored, not absorbed
        s.push(f64::NAN);
        s.push(-1.0);
        assert_eq!(s.count(), 4);
    }

    #[test]
    fn recovers_exponential_rate_under_omega_scaling() {
        let omega = 0.3;
        let truth = LatencyModel::exp(0.4);
        let mut est = LatencyEstimator::new(omega);
        feed(&mut est, &truth, omega, 4000, 1);
        match est.fit().unwrap() {
            LatencyModel::Exponential { lambda } => {
                assert!((lambda - 0.4).abs() < 0.03, "fitted λ = {lambda}")
            }
            other => panic!("expected exponential, fitted {other:?}"),
        }
    }

    #[test]
    fn recovers_deterministic_and_shifted_families() {
        let mut est = LatencyEstimator::new(1.0);
        feed(&mut est, &LatencyModel::Deterministic { t: 0.7 }, 1.0, 50, 2);
        assert_eq!(est.fit().unwrap(), LatencyModel::Deterministic { t: 0.7 });

        let truth = LatencyModel::ShiftedExponential { shift: 2.0, lambda: 2.0 };
        let mut est = LatencyEstimator::new(1.0);
        feed(&mut est, &truth, 1.0, 4000, 3);
        match est.fit().unwrap() {
            LatencyModel::ShiftedExponential { shift, lambda } => {
                assert!((shift - 2.0).abs() < 0.05, "shift {shift}");
                assert!((lambda - 2.0).abs() < 0.2, "λ {lambda}");
            }
            other => panic!("expected shifted-exp, fitted {other:?}"),
        }
    }

    #[test]
    fn heavy_tails_fit_pareto() {
        // α = 2.05 has cv² = 1/(α(α−2)) ≈ 9.8, far above the 1.5
        // family boundary even though sample cv² of a heavy tail
        // converges from below
        let truth = LatencyModel::Pareto { x_min: 1.0, alpha: 2.05 };
        let mut est = LatencyEstimator::new(1.0);
        feed(&mut est, &truth, 1.0, 200_000, 4);
        match est.fit().unwrap() {
            LatencyModel::Pareto { x_min, alpha } => {
                // moment fits on heavy tails are noisy; the point is the
                // family and the right ballpark
                assert!((alpha - 2.05).abs() < 0.5, "α {alpha}");
                assert!((x_min - 1.0).abs() < 0.3, "x_min {x_min}");
            }
            other => panic!("expected pareto, fitted {other:?}"),
        }
    }

    #[test]
    fn zero_supported_heterogeneous_mixtures_stay_exponential() {
        // A fast/slow fleet mixture has a huge cv² but support down to
        // zero: fitting a Pareto (zero mass below x_min) would predict
        // no arrivals before the deadline at all. The support guard must
        // route this to the mean-matching exponential instead.
        let mut est = LatencyEstimator::new(1.0);
        let mut rng = Pcg64::seed_from(11);
        let fast = LatencyModel::exp(1.0);
        let slow = LatencyModel::exp(0.05); // mean 20: extreme stragglers
        for i in 0..6000 {
            let m = if i % 3 == 0 { &slow } else { &fast };
            est.observe(m.sample(&mut rng));
        }
        let true_mean = (2.0 * 1.0 + 20.0) / 3.0;
        match est.fit().unwrap() {
            LatencyModel::Exponential { lambda } => {
                assert!(
                    (1.0 / lambda - true_mean).abs() < 0.8,
                    "mean-matched λ {lambda}"
                )
            }
            other => panic!("mixture must fit exponential, got {other:?}"),
        }
    }

    #[test]
    fn fit_is_deterministic_in_the_sample_stream() {
        let truth = LatencyModel::exp(1.0);
        let mut a = LatencyEstimator::new(0.5);
        let mut b = LatencyEstimator::new(0.5);
        feed(&mut a, &truth, 0.5, 500, 9);
        feed(&mut b, &truth, 0.5, 500, 9);
        assert_eq!(a.fit(), b.fit());
    }

    #[test]
    fn too_few_samples_fit_nothing() {
        let mut est = LatencyEstimator::new(1.0);
        assert_eq!(est.fit(), None);
        est.observe(1.0);
        assert_eq!(est.fit(), None);
        est.observe(2.0);
        assert!(est.fit().is_some());
    }

    #[test]
    fn fleet_scales_identify_the_straggler() {
        let mut fleet = FleetEstimator::new(1.0);
        let mut rng = Pcg64::seed_from(7);
        let fast = LatencyModel::exp(2.0); // mean 0.5
        let slow = LatencyModel::exp(0.5); // mean 2.0
        for _ in 0..2000 {
            fleet.observe(1, fast.sample(&mut rng));
            fleet.observe(2, fast.sample(&mut rng));
            fleet.observe(3, slow.sample(&mut rng));
        }
        let s1 = fleet.scale_of(1).unwrap();
        let s3 = fleet.scale_of(3).unwrap();
        assert!(s1 < 0.7, "fast worker scale {s1}");
        assert!(s3 > 1.6, "slow worker scale {s3}");
        assert_eq!(fleet.scales().len(), 3);
        assert_eq!(fleet.scale_of(99), None);
    }

    #[test]
    fn ewma_snapshots_back_fill_scales() {
        let mut fleet = FleetEstimator::new(1.0);
        fleet.absorb_straggle(&[(1, Some(0.5)), (2, Some(1.5)), (3, None)]);
        assert!((fleet.scale_of(1).unwrap() - 0.5).abs() < 1e-12);
        assert!((fleet.scale_of(2).unwrap() - 1.5).abs() < 1e-12);
        assert_eq!(fleet.scale_of(3), None);
    }

    /// Feed a hand-crafted sample stream with closed-form moments.
    fn fit_of(samples: &[f64]) -> LatencyModel {
        let mut est = LatencyEstimator::new(1.0);
        for &x in samples {
            est.observe(x);
        }
        est.fit().unwrap()
    }

    /// Every branch of the family-selection rule, pinned with exact
    /// parameter values computed by hand from the documented formulas —
    /// a change to any boundary constant or moment-match formula must
    /// fail here, not just shift a convergence tolerance.
    #[test]
    fn family_rule_branches_pin_exact_parameters() {
        // cv = 0 -> Deterministic at the mean, exactly.
        assert_eq!(
            fit_of(&[2.0, 2.0, 2.0]),
            LatencyModel::Deterministic { t: 2.0 }
        );

        // [1, 3]: mean 2, sample var 2, cv² = 0.5 ≤ 1.5, min 1 > 0.4
        // -> ShiftedExponential { shift = min = 1, λ = 1/(mean−min) = 1 }.
        match fit_of(&[1.0, 3.0]) {
            LatencyModel::ShiftedExponential { shift, lambda } => {
                assert!((shift - 1.0).abs() < 1e-12, "shift {shift}");
                assert!((lambda - 1.0).abs() < 1e-12, "λ {lambda}");
            }
            other => panic!("expected shifted-exp, fitted {other:?}"),
        }

        // [1, 1, 1, 9]: mean 3, sample var 16, cv² = 16/9 > 1.5,
        // min 1 > 0.6 -> Pareto with α = 1 + √(1 + 9/16) = 9/4 and
        // x_min = mean·(α−1)/α = 3·(5/4)/(9/4) = 5/3, both exact.
        match fit_of(&[1.0, 1.0, 1.0, 9.0]) {
            LatencyModel::Pareto { x_min, alpha } => {
                assert!((alpha - 2.25).abs() < 1e-12, "α {alpha}");
                assert!((x_min - 5.0 / 3.0).abs() < 1e-12, "x_min {x_min}");
            }
            other => panic!("expected pareto, fitted {other:?}"),
        }

        // [0.1, 10]: min = 0.1 ≤ 0.2·mean = 1.01, so the shifted
        // families are dishonest regardless of cv -> Exponential with
        // λ = 1/mean = 1/5.05.
        match fit_of(&[0.1, 10.0]) {
            LatencyModel::Exponential { lambda } => {
                assert!((lambda - 1.0 / 5.05).abs() < 1e-12, "λ {lambda}");
            }
            other => panic!("expected exponential, fitted {other:?}"),
        }
    }

    /// Scale offsets converge to per-worker-mean / fleet-mean even when
    /// each worker draws from a *different* latency family — the
    /// planner consumes scales, not families, so mixed fleets must
    /// still rank correctly.
    #[test]
    fn fleet_scales_converge_on_mixed_families() {
        let mut fleet = FleetEstimator::new(1.0);
        let mut rng = Pcg64::seed_from(23);
        let exp = LatencyModel::exp(2.0); // mean 0.5
        let sexp = LatencyModel::ShiftedExponential { shift: 1.0, lambda: 2.0 }; // mean 1.5
        let par = LatencyModel::Pareto { x_min: 2.0, alpha: 3.0 }; // mean 3.0
        for _ in 0..30_000 {
            fleet.observe(1, exp.sample(&mut rng));
            fleet.observe(2, sexp.sample(&mut rng));
            fleet.observe(3, par.sample(&mut rng));
        }
        let fleet_mean = (0.5 + 1.5 + 3.0) / 3.0;
        for (id, true_mean) in [(1u64, 0.5), (2, 1.5), (3, 3.0)] {
            let s = fleet.scale_of(id).unwrap();
            let expect = true_mean / fleet_mean;
            assert!(
                (s - expect).abs() < 0.12 * expect,
                "worker {id}: scale {s}, expected ≈{expect}"
            );
        }
        // ranking is what hetero-assign dispatch consumes
        let scales = fleet.scales();
        assert_eq!(scales.len(), 3);
        assert!(scales[0].1 < scales[1].1 && scales[1].1 < scales[2].1);
    }
}
