//! The parameter server (paper Fig. 2): builds the coded job set,
//! dispatches to workers, collects results until the deadline `T_max`,
//! decodes progressively, and assembles the approximation `Ĉ`.
//!
//! **Entry point note:** new code should drive these paths through the
//! unified client API ([`crate::api::Session`] +
//! [`crate::api::Backend`]), which adds caching, batched submission,
//! anytime progress, and typed errors on top. What remains here is the
//! plan machinery ([`Plan`], [`EncodedA`], job building, scoring) and
//! the *reference* virtual-time path every backend is checked against.
//!
//! Three execution paths, one protocol:
//! * [`Coordinator::run`] — *virtual-time honest* path: every worker
//!   payload is actually computed through the [`ExecEngine`] (PJRT
//!   artifacts or native matmul), arrival times come from the straggler
//!   simulator, and `Ĉ` is decoded from the payloads. The reference
//!   semantics every other path is checked against.
//! * [`crate::api::PooledBackend`] — *in-process threaded* path: worker
//!   agents run on threads and stream results back over the cluster
//!   loopback transport with seeded injected delays, driven through a
//!   [`crate::api::Session`]. Deterministic: same plan + seed ⇒
//!   bit-identical outcome.
//! * [`crate::cluster`] — *networked* path: `uepmm serve` coordinates
//!   `uepmm worker` processes over TCP with the same wire protocol the
//!   loopback path uses; straggling is a property of the transport and
//!   the worker hosts, deadlines are wall-clock, and partial failures
//!   (dead workers, dropped connections) are survived rather than
//!   simulated.

mod assignment;
mod plan;
mod service;

pub use assignment::Assignment;
pub use plan::{
    build_job_a, build_job_b, build_job_matrices, EncodedA, Plan, RatelessPlan,
    RatelessVerifier, Verifier,
};
#[allow(deprecated)]
pub use service::run_service;
pub use service::{ServiceConfig, ServiceOutcome};

use crate::coding::DecodeState;
use crate::linalg::Matrix;
use crate::partition::{ClassMap, Partitioning};
use crate::runtime::ExecEngine;

/// Result of one coordinated approximate multiplication.
#[derive(Clone, Debug)]
pub struct Outcome {
    /// Packets received by the deadline.
    pub received: usize,
    /// Real sub-products recovered.
    pub recovered: usize,
    /// Per-class recovered counts.
    pub per_class_recovered: Vec<usize>,
    /// The assembled approximation.
    pub c_hat: Matrix,
    /// `‖C − Ĉ‖²_F` against the true product.
    pub loss: f64,
    /// Loss normalized by `‖C‖²_F`.
    pub normalized_loss: f64,
}

/// The parameter server, generic over the execution engine.
pub struct Coordinator<E: ExecEngine> {
    engine: E,
}

impl<E: ExecEngine> Coordinator<E> {
    pub fn new(engine: E) -> Self {
        Coordinator { engine }
    }

    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// Run one coded multiplication to the deadline `t_max` with the
    /// given per-worker arrival times (virtual time). Every payload the
    /// deadline admits is computed honestly through the engine.
    pub fn run(&self, plan: &Plan, arrivals: &[f64], t_max: f64) -> anyhow::Result<Outcome> {
        assert_eq!(arrivals.len(), plan.packets.len(), "one arrival per worker");
        let mut order: Vec<usize> = (0..arrivals.len()).collect();
        order.sort_by(|&a, &b| arrivals[a].total_cmp(&arrivals[b]));
        let mut st = DecodeState::new(plan.space.clone());
        let mut received = 0;
        for &w in &order {
            if arrivals[w] > t_max {
                break;
            }
            let packet = &plan.packets[w];
            let (wa, wb) = build_job_matrices(
                &plan.part,
                &plan.a_blocks,
                &plan.b_blocks,
                &packet.recipe,
            );
            let payload = self.engine.matmul(&wa, &wb)?;
            st.add_packet(packet, Some(payload));
            received += 1;
        }
        self.finish(plan, st, received)
    }

    /// Decode + assemble + score.
    fn finish(
        &self,
        plan: &Plan,
        st: DecodeState,
        received: usize,
    ) -> anyhow::Result<Outcome> {
        Ok(score_outcome(&plan.part, &plan.cm, &plan.c_true, &st, received))
    }
}

/// Decode and assemble `Ĉ` without a reference product: the production
/// tail, where the true `A·B` is exactly what nobody computed. The loss
/// fields come back as NaN — use [`score_outcome`] when a reference is
/// available.
pub fn assemble_outcome(
    part: &Partitioning,
    cm: &ClassMap,
    st: &DecodeState,
    received: usize,
) -> Outcome {
    let values = if received > 0 {
        st.recover_values()
    } else {
        vec![None; part.num_products()]
    };
    let mask = st.recovered_mask();
    let mut per_class = vec![0usize; cm.n_classes];
    for (u, &rec) in mask.iter().enumerate() {
        if rec {
            per_class[cm.class_of[u]] += 1;
        }
    }
    let c_hat = part.assemble(&values);
    Outcome {
        received,
        recovered: mask.iter().filter(|&&b| b).count(),
        per_class_recovered: per_class,
        c_hat,
        loss: f64::NAN,
        normalized_loss: f64::NAN,
    }
}

/// Decode, assemble `Ĉ`, and score it against the true product: the
/// common tail of every *evaluation* path (virtual-time, threaded
/// loopback, and scored cluster requests).
pub fn score_outcome(
    part: &Partitioning,
    cm: &ClassMap,
    c_true: &Matrix,
    st: &DecodeState,
    received: usize,
) -> Outcome {
    let mut out = assemble_outcome(part, cm, st, received);
    out.loss = c_true.frob_sq_diff(&out.c_hat);
    let energy = c_true.frob_sq();
    out.normalized_loss = if energy > 0.0 { out.loss / energy } else { 0.0 };
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodeKind, CodeSpec, EncodeStyle, WindowPolynomial};
    use crate::partition::Partitioning;
    use crate::rng::Pcg64;
    use crate::runtime::NativeEngine;

    fn make_plan(spec: CodeSpec, workers: usize, seed: u64) -> (Plan, Pcg64) {
        let mut rng = Pcg64::seed_from(seed);
        let part = Partitioning::rxc(3, 3, 6, 8, 6);
        // heavy/medium/light row blocks — real norm-based classification
        let sds = [10f64.sqrt(), 1.0, 0.1f64.sqrt()];
        let blocks_a: Vec<Matrix> =
            sds.iter().map(|&s| Matrix::randn(6, 8, 0.0, s, &mut rng)).collect();
        let a = Matrix::vconcat(&blocks_a.iter().collect::<Vec<_>>());
        let blocks_b: Vec<Matrix> =
            sds.iter().map(|&s| Matrix::randn(8, 6, 0.0, s, &mut rng)).collect();
        let b = Matrix::hconcat(&blocks_b.iter().collect::<Vec<_>>());
        let plan = Plan::build(&part, spec, 3, workers, &a, &b, &mut rng).unwrap();
        (plan, rng)
    }

    #[test]
    fn full_arrivals_give_exact_product() {
        for spec in [
            CodeSpec::stacked(CodeKind::Uncoded),
            CodeSpec::stacked(CodeKind::Mds),
            CodeSpec::stacked(CodeKind::NowUep(WindowPolynomial::paper_table3())),
            CodeSpec::new(
                CodeKind::EwUep(WindowPolynomial::paper_table3()),
                EncodeStyle::RankOne,
            ),
        ] {
            let label = spec.label();
            let (plan, _) = make_plan(spec, 40, 3);
            let arrivals = vec![0.1; 40];
            let coord = Coordinator::new(NativeEngine::default());
            let out = coord.run(&plan, &arrivals, 1.0).unwrap();
            assert_eq!(out.received, 40);
            assert_eq!(out.recovered, 9, "{label}");
            assert!(out.normalized_loss < 1e-12, "{label}: {}", out.normalized_loss);
        }
    }

    #[test]
    fn zero_deadline_recovers_nothing() {
        let (plan, _) =
            make_plan(CodeSpec::stacked(CodeKind::Mds), 12, 4);
        let arrivals: Vec<f64> = (0..12).map(|i| 0.5 + i as f64).collect();
        let coord = Coordinator::new(NativeEngine::default());
        let out = coord.run(&plan, &arrivals, 0.1).unwrap();
        assert_eq!(out.received, 0);
        assert_eq!(out.recovered, 0);
        assert!((out.normalized_loss - 1.0).abs() < 1e-12);
    }

    #[test]
    fn partial_deadline_partial_loss_and_class_priority() {
        // With NOW-UEP and only the first few arrivals, whatever is
        // recovered must be exact (loss = energy of missing blocks).
        let spec = CodeSpec::stacked(CodeKind::NowUep(WindowPolynomial::paper_table3()));
        let (plan, mut rng) = make_plan(spec, 15, 5);
        let arrivals: Vec<f64> = (0..15).map(|_| rng.next_f64()).collect();
        let coord = Coordinator::new(NativeEngine::default());
        let out = coord.run(&plan, &arrivals, 0.5).unwrap();
        assert!(out.received < 15);
        assert!(out.normalized_loss <= 1.0 + 1e-12);
        // recovered blocks contribute zero residual: check against the
        // gram identity
        let gram = plan.part.gram(&plan.true_products());
        let mask_loss = {
            let values = out.per_class_recovered.iter().sum::<usize>();
            assert_eq!(values, out.recovered);
            // reconstruct mask from c_hat: block exact or zero
            let mut mask = vec![false; 9];
            for u in 0..9 {
                let (n, p) = plan.part.factors_of(u);
                let blk = out.c_hat.block(n * 6, p * 6, 6, 6);
                if blk.frob_sq() > 0.0 {
                    mask[u] = true;
                }
            }
            plan.part.loss_from_gram(&gram, &mask)
        };
        assert!(
            (out.loss - mask_loss).abs() < 1e-6 * (1.0 + out.loss),
            "honest loss {} vs gram loss {}",
            out.loss,
            mask_loss
        );
    }

    #[test]
    fn coordinator_matches_fast_sweep_path() {
        // The coefficient-only fast path must agree with the honest
        // engine path on which unknowns decode and the resulting loss.
        let spec = CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3()));
        let (plan, mut rng) = make_plan(spec.clone(), 20, 6);
        let arrivals: Vec<f64> = (0..20).map(|_| rng.next_f64() * 2.0).collect();
        let t_max = 0.8;
        let coord = Coordinator::new(NativeEngine::default());
        let honest = coord.run(&plan, &arrivals, t_max).unwrap();
        let gram = plan.part.gram(&plan.true_products());
        let trace = crate::sim::loss_trace_packets(
            &plan.part,
            &spec,
            &gram,
            &plan.packets,
            &arrivals,
        );
        let fast_loss = crate::sim::sweep::loss_at(&trace, t_max);
        assert!(
            (honest.loss - fast_loss).abs() <= 1e-6 * (1.0 + honest.loss),
            "honest {} vs fast {}",
            honest.loss,
            fast_loss
        );
    }
}
