//! Heterogeneity-aware work assignment (ROADMAP item 4).
//!
//! The fleet estimator fits a per-worker **scale offset** (1.0 = fleet
//! mean, higher = slower). Least-outstanding dispatch ignores those
//! fits except as a tie-break, so a 3× straggler still receives ~1/w of
//! the jobs and the deadline eats its share. [`Assignment`] plans the
//! slot→worker map *up front* from the scales instead, with two goals:
//!
//! * **Unequal load** — worker job counts are (inversely) proportional
//!   to their scales, via the d'Hondt highest-averages method: slots
//!   are handed out one at a time, each to the worker minimizing
//!   `(assigned + 1) · scale`. A worker twice as slow ends up with
//!   about half the slots.
//! * **Criticality order** — slots are handed out most-critical first
//!   (ascending packet window, then slot index; window-major packet
//!   generation makes this the natural slot order), so the fastest
//!   workers take the most-protected windows and a straggler's slots
//!   are the ones the Γ design already tolerates losing.
//!
//! The method is deterministic (ties break on the lower worker id) and
//! degenerates exactly to least-outstanding round-robin when every
//! scale is equal — turning [`ClusterConfig::hetero_assign`] on for a
//! homogeneous fleet changes nothing, which the golden-trace tests pin.
//!
//! [`ClusterConfig::hetero_assign`]: crate::cluster::ClusterConfig::hetero_assign

use std::collections::BTreeMap;

/// A planned slot→worker map for one request's packet set.
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    /// `(slot, worker id)` in dispatch order: most-critical slot first,
    /// each paired with the worker the divider method chose for it.
    dispatch: Vec<(u32, u64)>,
    /// Worker id per slot, indexed by slot.
    slot_worker: Vec<u64>,
    /// Planned job counts per worker id (present for every worker that
    /// was offered to the planner, including those assigned nothing).
    counts: BTreeMap<u64, usize>,
}

impl Assignment {
    /// Plan `slot_windows.len()` slots over the given `(worker id,
    /// scale)` fleet. `slot_windows[s]` is the packet window of slot
    /// `s` (lower = more critical). Entries with a non-finite or
    /// non-positive scale are dropped; returns `None` when no usable
    /// worker remains (callers then fall back to least-outstanding).
    pub fn plan(slot_windows: &[usize], scales: &[(u64, f64)]) -> Option<Assignment> {
        // ids sorted ascending so equal-scale ties resolve to the lower
        // id regardless of the caller's ordering
        let mut fleet: Vec<(u64, f64)> = scales
            .iter()
            .copied()
            .filter(|&(_, s)| s.is_finite() && s > 0.0)
            .collect();
        if fleet.is_empty() {
            return None;
        }
        fleet.sort_by(|a, b| a.0.cmp(&b.0));
        fleet.dedup_by_key(|e| e.0);

        // slots in criticality order: window ascending, slot ascending
        let mut order: Vec<u32> = (0..slot_windows.len() as u32).collect();
        order.sort_by(|&a, &b| {
            slot_windows[a as usize]
                .cmp(&slot_windows[b as usize])
                .then(a.cmp(&b))
        });

        let mut assigned = vec![0usize; fleet.len()];
        let mut dispatch = Vec::with_capacity(order.len());
        let mut slot_worker = vec![0u64; slot_windows.len()];
        for slot in order {
            // d'Hondt divider: next slot to the worker minimizing
            // (assigned + 1) * scale; ties to the lower id (fleet is
            // id-sorted, so strict `<` keeps the earlier winner)
            let mut best = 0usize;
            let mut best_key = (assigned[0] as f64 + 1.0) * fleet[0].1;
            for (wi, &(_, scale)) in fleet.iter().enumerate().skip(1) {
                let key = (assigned[wi] as f64 + 1.0) * scale;
                if key.total_cmp(&best_key) == std::cmp::Ordering::Less {
                    best = wi;
                    best_key = key;
                }
            }
            assigned[best] += 1;
            dispatch.push((slot, fleet[best].0));
            slot_worker[slot as usize] = fleet[best].0;
        }
        let counts = fleet
            .iter()
            .zip(&assigned)
            .map(|(&(id, _), &n)| (id, n))
            .collect();
        Some(Assignment { dispatch, slot_worker, counts })
    }

    /// `(slot, worker id)` pairs in dispatch order (most-critical slot
    /// first). The divider method interleaves workers by construction,
    /// so sending in this order keeps every queue shallow.
    pub fn dispatch_order(&self) -> &[(u32, u64)] {
        &self.dispatch
    }

    /// Planned worker id for a slot.
    pub fn worker_of(&self, slot: usize) -> u64 {
        self.slot_worker[slot]
    }

    /// Planned job counts per worker id (id-ordered; workers planned
    /// zero slots are present with a 0).
    pub fn counts(&self) -> &BTreeMap<u64, usize> {
        &self.counts
    }

    /// Number of slots planned.
    pub fn len(&self) -> usize {
        self.slot_worker.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slot_worker.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conserves_and_orders_by_criticality() {
        // EW-style windows: 2 slots of window 0, 3 of window 1, rest 2
        let windows = [1, 0, 2, 0, 1, 2, 1, 2, 2];
        let a = Assignment::plan(&windows, &[(7, 1.0), (3, 2.0)]).unwrap();
        assert_eq!(a.len(), windows.len());
        assert_eq!(a.counts().values().sum::<usize>(), windows.len());
        // dispatch order is window-ascending
        let seq: Vec<usize> =
            a.dispatch_order().iter().map(|&(s, _)| windows[s as usize]).collect();
        let mut sorted = seq.clone();
        sorted.sort_unstable();
        assert_eq!(seq, sorted);
        // the first (most critical) slot goes to the faster worker
        assert_eq!(a.dispatch_order()[0], (1, 7));
        // 2× slower worker gets about half the slots: 6 vs 3
        assert_eq!(a.counts()[&7], 6);
        assert_eq!(a.counts()[&3], 3);
    }

    #[test]
    fn equal_scales_round_robin_by_id() {
        let windows = vec![0usize; 8];
        let a = Assignment::plan(&windows, &[(2, 1.0), (1, 1.0), (3, 1.0)]).unwrap();
        for (i, &(slot, w)) in a.dispatch_order().iter().enumerate() {
            assert_eq!(slot as usize, i);
            assert_eq!(w, [1, 2, 3][i % 3]);
        }
    }

    #[test]
    fn rejects_unusable_scales() {
        assert!(Assignment::plan(&[0, 0], &[]).is_none());
        assert!(Assignment::plan(&[0, 0], &[(1, 0.0), (2, f64::NAN)]).is_none());
        // one usable worker takes everything
        let a =
            Assignment::plan(&[0, 0], &[(1, 0.0), (2, 0.5), (3, -1.0)]).unwrap();
        assert_eq!(a.counts()[&2], 2);
    }
}
