//! Wall-clock threaded coordinator: the deployment-shaped path.
//!
//! Workers run as jobs on a thread pool; each computes its coded product
//! through a (thread-safe) execution engine, sleeps out its injected
//! straggler delay, and streams the result to the PS over a channel. The
//! PS decodes arrivals until the wall-clock deadline, then returns
//! whatever approximation it has — exactly the paper's protocol, but
//! with real threads and real time instead of the virtual-time
//! simulator.
//!
//! Delays are scaled by `time_scale` so experiments with `T_max ≈ 1`
//! finish in tens of milliseconds of wall time.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coding::DecodeState;
use crate::latency::LatencyModel;
use crate::linalg::{matmul_with, Matrix, MatmulOpts};
use crate::rng::Pcg64;
use crate::util::pool::ThreadPool;

use super::{build_job_matrices, Outcome, Plan};

/// Configuration of a threaded service run.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub latency: LatencyModel,
    /// Ω capacity scaling (Remark 1).
    pub omega: f64,
    /// Virtual deadline `T_max` (same units as the latency model).
    pub t_max: f64,
    /// Wall seconds per virtual time unit (e.g. 0.02 → T_max=1 is 20ms).
    pub time_scale: f64,
    /// Worker threads.
    pub threads: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            latency: LatencyModel::exp(1.0),
            omega: 1.0,
            t_max: 1.0,
            time_scale: 0.02,
            threads: 8,
        }
    }
}

/// Outcome of a service run, with wall-clock accounting.
#[derive(Clone, Debug)]
pub struct ServiceOutcome {
    pub outcome: Outcome,
    /// Worker results that arrived after the deadline (discarded).
    pub late: usize,
    /// Wall time the PS actually waited.
    pub wall: Duration,
}

/// Run the plan as a real threaded service (native engine compute inside
/// the worker threads; the PJRT engine is thread-confined, so the
/// service path keeps compute native — the honest PJRT path is
/// [`super::Coordinator::run`]).
pub fn run_service(plan: &Plan, cfg: &ServiceConfig, rng: &mut Pcg64) -> Result<ServiceOutcome> {
    let (tx, rx) = mpsc::channel::<(usize, f64, Matrix)>();
    let pool = ThreadPool::new(cfg.threads.max(1));
    let start = Instant::now();
    // Pre-sample delays so the run is reproducible from the seed.
    let delays: Vec<f64> = (0..plan.packets.len())
        .map(|_| cfg.latency.sample_scaled(cfg.omega, rng))
        .collect();
    for (w, packet) in plan.packets.iter().enumerate() {
        let tx = tx.clone();
        let delay = delays[w];
        let (wa, wb) = build_job_matrices(
            &plan.part,
            &plan.a_blocks,
            &plan.b_blocks,
            &packet.recipe,
        );
        let scale = cfg.time_scale;
        pool.execute(move || {
            // compute first (a real worker), then model the residual
            // straggle as sleep up to the sampled completion time
            let payload = matmul_with(
                &wa,
                &wb,
                MatmulOpts { threads: 1, ..MatmulOpts::default() },
            );
            let target = Duration::from_secs_f64(delay * scale);
            let elapsed = start.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
            let _ = tx.send((w, delay, payload));
        });
    }
    drop(tx);

    let deadline = Duration::from_secs_f64(cfg.t_max * cfg.time_scale);
    let mut st = DecodeState::new(plan.space.clone());
    let mut received = 0usize;
    let mut late = 0usize;
    loop {
        let elapsed = start.elapsed();
        if elapsed >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - elapsed) {
            Ok((w, delay, payload)) => {
                // enforce the *virtual* deadline too: a worker whose
                // sampled completion exceeds T_max is late even if the
                // wall clock raced ahead
                if delay <= cfg.t_max {
                    st.add_packet(&plan.packets[w], Some(payload));
                    received += 1;
                } else {
                    late += 1;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => break,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    let wall = start.elapsed();
    // drain (count) late arrivals without blocking the deadline path
    drop(rx);
    drop(pool);

    let values = if received > 0 {
        st.recover_values()
    } else {
        vec![None; plan.part.num_products()]
    };
    let mask = st.recovered_mask();
    let mut per_class = vec![0usize; plan.cm.n_classes];
    for (u, &rec) in mask.iter().enumerate() {
        if rec {
            per_class[plan.cm.class_of[u]] += 1;
        }
    }
    let c_hat = plan.part.assemble(&values);
    let loss = plan.c_true.frob_sq_diff(&c_hat);
    let energy = plan.c_true.frob_sq();
    Ok(ServiceOutcome {
        outcome: Outcome {
            received,
            recovered: mask.iter().filter(|&&b| b).count(),
            per_class_recovered: per_class,
            c_hat,
            loss,
            normalized_loss: if energy > 0.0 { loss / energy } else { 0.0 },
        },
        late,
        wall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodeKind, CodeSpec, WindowPolynomial};
    use crate::partition::Partitioning;

    fn small_plan(workers: usize, seed: u64) -> Plan {
        let mut rng = Pcg64::seed_from(seed);
        let part = Partitioning::rxc(3, 3, 4, 5, 4);
        let a = Matrix::randn(12, 5, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(5, 12, 0.0, 1.0, &mut rng);
        let spec = CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3()));
        Plan::build(&part, spec, 3, workers, &a, &b, &mut rng).unwrap()
    }

    #[test]
    fn service_with_generous_deadline_fully_decodes() {
        let plan = small_plan(25, 1);
        let cfg = ServiceConfig {
            latency: LatencyModel::Deterministic { t: 0.01 },
            omega: 1.0,
            t_max: 10.0,
            time_scale: 0.01,
            threads: 4,
        };
        let mut rng = Pcg64::seed_from(2);
        let out = run_service(&plan, &cfg, &mut rng).unwrap();
        assert_eq!(out.outcome.recovered, 9);
        assert!(out.outcome.normalized_loss < 1e-12);
        assert_eq!(out.late, 0);
    }

    #[test]
    fn service_with_tight_deadline_drops_stragglers() {
        let plan = small_plan(20, 3);
        let cfg = ServiceConfig {
            latency: LatencyModel::exp(1.0),
            omega: 9.0 / 20.0,
            t_max: 0.3,
            time_scale: 0.005,
            threads: 4,
        };
        let mut rng = Pcg64::seed_from(4);
        let out = run_service(&plan, &cfg, &mut rng).unwrap();
        // with mean scaled latency 1/Ω ≈ 2.2 and deadline 0.3, most
        // workers miss it
        assert!(out.outcome.received < 20);
        assert!(out.outcome.normalized_loss <= 1.0 + 1e-12);
    }
}
