//! The in-process threaded service: a thin adapter over the cluster
//! loopback runtime. **Deprecated shim** — the unified client API
//! ([`crate::api::Session`] with [`crate::api::PooledBackend`]) serves
//! the same path with caching, batching, and anytime progress; this
//! one-call form stays for callers that already hold a [`Plan`].
//!
//! Worker agents run on threads behind a
//! [`LoopbackTransport`], each computing its coded product through a
//! serial native engine and streaming the result back over the cluster
//! wire protocol. The PS pre-samples every worker's virtual completion
//! time from the seeded latency model, injects it into the job, and
//! accepts exactly the results whose delay meets the virtual deadline —
//! so a run is a pure function of `(plan, config, seed)`: bit-identical
//! across repetitions and across thread counts. Injected delays are
//! paced in wall time by `time_scale` (capped just past the deadline),
//! which keeps demos lifelike and tests fast.
//!
//! This used to be a hand-rolled thread-pool + channel loop; it now
//! delegates to [`crate::cluster::ClusterServer`] in
//! [`DeadlineMode::Virtual`], so the threaded path and the networked
//! path exercise the same dispatch/collect/decode machinery.

use std::time::Duration;

use anyhow::Result;

use crate::cluster::{
    spawn_loopback_workers, ClusterConfig, ClusterServer, DeadlineMode,
    LoopbackTransport, WorkerConfig,
};
use crate::latency::LatencyModel;
use crate::rng::Pcg64;
use crate::util::pool::available_parallelism;

use super::{Outcome, Plan};

/// Configuration of a threaded service run.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub latency: LatencyModel,
    /// Ω capacity scaling (Remark 1).
    pub omega: f64,
    /// Virtual deadline `T_max` (same units as the latency model).
    pub t_max: f64,
    /// Wall seconds per virtual time unit (e.g. 0.02 → T_max=1 is 20ms).
    pub time_scale: f64,
    /// Worker threads.
    pub threads: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            latency: LatencyModel::exp(1.0),
            omega: 1.0,
            t_max: 1.0,
            time_scale: 0.02,
            threads: available_parallelism(),
        }
    }
}

/// Outcome of a service run, with wall-clock accounting.
#[derive(Clone, Debug)]
pub struct ServiceOutcome {
    pub outcome: Outcome,
    /// Worker results whose virtual completion missed the deadline
    /// (computed, streamed back, discarded).
    pub late: usize,
    /// Wall time the PS actually waited.
    pub wall: Duration,
}

/// Run the plan as a threaded loopback cluster (native engine compute
/// inside the worker threads; the PJRT engine is thread-confined, so the
/// service path keeps compute native — the honest PJRT path is
/// [`super::Coordinator::run`]).
#[deprecated(
    since = "0.2.0",
    note = "drive a PooledBackend through uepmm::api::Session instead; this \
            shim stays for plan-level callers and will not grow features"
)]
pub fn run_service(plan: &Plan, cfg: &ServiceConfig, rng: &mut Pcg64) -> Result<ServiceOutcome> {
    // Pre-sample delays so the run is reproducible from the seed.
    let delays: Vec<f64> = (0..plan.packets.len())
        .map(|_| cfg.latency.sample_scaled(cfg.omega, rng))
        .collect();
    let threads = cfg.threads.max(1);
    let (mut transport, dialer) = LoopbackTransport::new();
    let wcfg = WorkerConfig {
        name: "svc".to_string(),
        latency: None,
        omega: cfg.omega,
        time_scale: cfg.time_scale,
        seed: 0,
    };
    let handles = spawn_loopback_workers(&dialer, threads, &wcfg);
    drop(dialer);
    let mut server = ClusterServer::new(ClusterConfig {
        deadline: DeadlineMode::Virtual,
        time_scale: cfg.time_scale,
        ..ClusterConfig::default()
    });
    let joined =
        server.accept_workers(&mut transport, threads, Duration::from_secs(30))?;
    anyhow::ensure!(joined == threads, "only {joined}/{threads} workers joined");
    let served = server.serve_plan(plan, cfg.t_max, Some(&delays));
    server.shutdown();
    for h in handles {
        match h.join() {
            Ok(r) => {
                r?;
            }
            Err(_) => anyhow::bail!("service worker thread panicked"),
        }
    }
    let out = served?;
    Ok(ServiceOutcome { outcome: out.outcome, late: out.late, wall: out.wall })
}

#[cfg(test)]
#[allow(deprecated)] // the shim's own contract tests keep exercising it
mod tests {
    use super::*;
    use crate::coding::{CodeKind, CodeSpec, WindowPolynomial};
    use crate::linalg::Matrix;
    use crate::partition::Partitioning;

    fn small_plan(workers: usize, seed: u64) -> Plan {
        let mut rng = Pcg64::seed_from(seed);
        let part = Partitioning::rxc(3, 3, 4, 5, 4);
        let a = Matrix::randn(12, 5, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(5, 12, 0.0, 1.0, &mut rng);
        let spec = CodeSpec::stacked(CodeKind::EwUep(WindowPolynomial::paper_table3()));
        Plan::build(&part, spec, 3, workers, &a, &b, &mut rng).unwrap()
    }

    #[test]
    fn service_with_generous_deadline_fully_decodes() {
        let plan = small_plan(25, 1);
        let cfg = ServiceConfig {
            latency: LatencyModel::Deterministic { t: 0.01 },
            omega: 1.0,
            t_max: 10.0,
            time_scale: 0.01,
            threads: 4,
        };
        let mut rng = Pcg64::seed_from(2);
        let out = run_service(&plan, &cfg, &mut rng).unwrap();
        assert_eq!(out.outcome.recovered, 9);
        assert!(out.outcome.normalized_loss < 1e-12);
        assert_eq!(out.late, 0);
    }

    #[test]
    fn service_with_tight_deadline_drops_stragglers() {
        let plan = small_plan(20, 3);
        let cfg = ServiceConfig {
            latency: LatencyModel::exp(1.0),
            omega: 9.0 / 20.0,
            t_max: 0.3,
            time_scale: 0.005,
            threads: 4,
        };
        let mut rng = Pcg64::seed_from(4);
        let out = run_service(&plan, &cfg, &mut rng).unwrap();
        // with mean scaled latency 1/Ω ≈ 2.2 and deadline 0.3, most
        // workers miss it
        assert!(out.outcome.received < 20);
        assert!(out.outcome.normalized_loss <= 1.0 + 1e-12);
    }

    #[test]
    fn service_is_bit_identical_across_runs_and_thread_counts() {
        let plan = small_plan(16, 6);
        let run = |threads: usize| {
            let cfg = ServiceConfig {
                latency: LatencyModel::exp(1.0),
                omega: 9.0 / 16.0,
                t_max: 0.9,
                time_scale: 0.002,
                threads,
            };
            let mut rng = Pcg64::seed_from(11);
            run_service(&plan, &cfg, &mut rng).unwrap()
        };
        let a = run(4);
        let b = run(4);
        let c = run(2);
        for other in [&b, &c] {
            assert_eq!(a.outcome.received, other.outcome.received);
            assert_eq!(a.outcome.recovered, other.outcome.recovered);
            assert_eq!(a.late, other.late);
            assert_eq!(a.outcome.c_hat.data(), other.outcome.c_hat.data());
            assert_eq!(a.outcome.loss.to_bits(), other.outcome.loss.to_bits());
        }
    }

    #[test]
    fn service_matches_direct_cluster_serve_plan() {
        // run_service is a thin adapter: replaying its delay sampling and
        // driving the cluster server directly must reproduce it exactly.
        let plan = small_plan(14, 8);
        let cfg = ServiceConfig {
            latency: LatencyModel::exp(1.0),
            omega: 9.0 / 14.0,
            t_max: 1.1,
            time_scale: 0.002,
            threads: 3,
        };
        let mut rng = Pcg64::seed_from(21);
        let service = run_service(&plan, &cfg, &mut rng).unwrap();

        let mut rng = Pcg64::seed_from(21);
        let delays: Vec<f64> = (0..plan.packets.len())
            .map(|_| cfg.latency.sample_scaled(cfg.omega, &mut rng))
            .collect();
        let (mut transport, dialer) = LoopbackTransport::new();
        let handles = spawn_loopback_workers(
            &dialer,
            cfg.threads,
            &WorkerConfig {
                omega: cfg.omega,
                time_scale: cfg.time_scale,
                ..WorkerConfig::default()
            },
        );
        let mut server = ClusterServer::new(ClusterConfig {
            deadline: DeadlineMode::Virtual,
            time_scale: cfg.time_scale,
            ..ClusterConfig::default()
        });
        server
            .accept_workers(&mut transport, cfg.threads, Duration::from_secs(10))
            .unwrap();
        let direct = server.serve_plan(&plan, cfg.t_max, Some(&delays)).unwrap();
        server.shutdown();
        for h in handles {
            h.join().unwrap().unwrap();
        }

        assert_eq!(service.outcome.received, direct.outcome.received);
        assert_eq!(service.late, direct.late);
        assert_eq!(service.outcome.c_hat.data(), direct.outcome.c_hat.data());
    }
}
