//! A [`Plan`] is everything the PS prepares *before* dispatch: the block
//! split of `A` and `B`, the norm-based importance classification, the
//! coded packet set, and the reference product for loss evaluation.
//!
//! Plan preparation is deliberately separable into three stages so the
//! cluster runtime can cache the expensive `A`-side work across a
//! request stream (the DNN-training shape: same weights `A`, fresh
//! activations `B` every request):
//!
//! 1. **encode** — split `A`, draw the coded packet set, and materialize
//!    every worker's left factor `W_A` ([`EncodedA::encode`]);
//! 2. **bind** — split the per-request `B` and build the right factors
//!    `W_B` ([`build_job_b`]);
//! 3. **dispatch** — hand `(W_A, W_B)` pairs to whatever executes them
//!    (virtual-time [`super::Coordinator::run`], any
//!    [`crate::api::Backend`], or a [`crate::cluster::ClusterServer`]).

use std::sync::Arc;

use anyhow::Result;

use crate::coding::{
    CodeSpec, EncodeStyle, JobRecipe, Packet, RatelessCoder, RatelessSpec,
    StackTerm, UnknownSpace,
};
use crate::linalg::{matmul, Matrix};
use crate::partition::{ClassMap, Partitioning};
use crate::rng::Pcg64;

/// A prepared coded-multiplication job set.
#[derive(Clone, Debug)]
pub struct Plan {
    pub part: Partitioning,
    pub cm: ClassMap,
    pub spec: CodeSpec,
    pub space: UnknownSpace,
    pub packets: Vec<Packet>,
    pub a_blocks: Vec<Matrix>,
    pub b_blocks: Vec<Matrix>,
    /// The true product (reference for loss; computed once at build).
    pub c_true: Matrix,
}

impl Plan {
    /// Build a plan: split, classify into `s_levels` by Frobenius norm,
    /// and generate one coded packet per worker.
    pub fn build(
        part: &Partitioning,
        spec: CodeSpec,
        s_levels: usize,
        workers: usize,
        a: &Matrix,
        b: &Matrix,
        rng: &mut Pcg64,
    ) -> Result<Plan> {
        let cm = ClassMap::from_matrices(part, a, b, s_levels);
        Self::build_with_classes(part, spec, cm, workers, a, b, rng)
    }

    /// Build with an explicit class map (synthetic experiments pin the
    /// levels instead of estimating them from norms).
    pub fn build_with_classes(
        part: &Partitioning,
        spec: CodeSpec,
        cm: ClassMap,
        workers: usize,
        a: &Matrix,
        b: &Matrix,
        rng: &mut Pcg64,
    ) -> Result<Plan> {
        anyhow::ensure!(workers >= 1, "need at least one worker");
        let a_blocks = part.split_a(a);
        let b_blocks = part.split_b(b);
        let packets = spec.generate_packets(part, &cm, workers, rng);
        let space = UnknownSpace::for_code(part, spec.style);
        let c_true = matmul(a, b);
        Ok(Plan {
            part: part.clone(),
            cm,
            spec,
            space,
            packets,
            a_blocks,
            b_blocks,
            c_true,
        })
    }

    pub fn workers(&self) -> usize {
        self.packets.len()
    }

    /// The true sub-products (computed on demand, e.g. for Gram-based
    /// fast sweeps).
    pub fn true_products(&self) -> Vec<Matrix> {
        (0..self.part.num_products())
            .map(|i| {
                let (ai, bi) = self.part.factors_of(i);
                matmul(&self.a_blocks[ai], &self.b_blocks[bi])
            })
            .collect()
    }

    /// Total worker compute (in units of one plain sub-product) — the
    /// quantity behind the paper's Ω fairness scaling.
    pub fn total_work_factor(&self) -> usize {
        self.packets.iter().map(|p| p.recipe.work_factor()).sum()
    }
}

/// The cachable, `B`-independent half of a coded job set: the packet
/// (coefficient) draw, the decode space, and every worker's
/// materialized left factor `W_A`. Keyed by
/// `(matrix id, partitioning, code spec, class map, workers)` in
/// [`crate::cluster::EncodedBlockCache`], one `EncodedA` serves an
/// entire stream of requests that reuse the same `A`.
#[derive(Clone, Debug)]
pub struct EncodedA {
    pub part: Partitioning,
    pub space: UnknownSpace,
    pub packets: Vec<Packet>,
    /// `wa[w]` is worker `w`'s left factor, prebuilt from the split of
    /// `A` and `packets[w].recipe`. Shared so dispatching a cached
    /// encoding clones a handle, not the matrix. The raw `A` blocks are
    /// deliberately *not* retained: once every `W_A` exists they are
    /// dead weight, and cache entries are long-lived.
    pub wa: Vec<Arc<Matrix>>,
}

impl EncodedA {
    /// Run the `A`-side of plan preparation: split, draw one coded packet
    /// per worker, and materialize every `W_A`.
    pub fn encode(
        part: &Partitioning,
        spec: CodeSpec,
        cm: &ClassMap,
        workers: usize,
        a: &Matrix,
        rng: &mut Pcg64,
    ) -> Result<EncodedA> {
        anyhow::ensure!(workers >= 1, "need at least one worker");
        let a_blocks = part.split_a(a);
        let packets = spec.generate_packets(part, cm, workers, rng);
        let space = UnknownSpace::for_code(part, spec.style);
        let wa = packets
            .iter()
            .map(|p| Arc::new(build_job_a(part, &a_blocks, &p.recipe)))
            .collect();
        Ok(EncodedA { part: part.clone(), space, packets, wa })
    }

    pub fn workers(&self) -> usize {
        self.packets.len()
    }

    /// Bind this encoding to one request's `B` blocks: worker `w`'s right
    /// factor.
    pub fn job_b(&self, b_blocks: &[Matrix], w: usize) -> Matrix {
        build_job_b(&self.part, b_blocks, &self.packets[w].recipe)
    }
}

/// Materialize the left factor a worker multiplies, per the packet
/// recipe (paper eq. 5–6):
/// * `Stacked`: `W_A = [c₁·A_{n₁}, …] (U×kH)`.
/// * `RankOne`: `W_A = Σ αᵢ·A_i (U×H)`.
///
/// Depends only on `A` and the packet — this is the half the encoded
/// block cache reuses across requests.
pub fn build_job_a(
    part: &Partitioning,
    a_blocks: &[Matrix],
    recipe: &JobRecipe,
) -> Matrix {
    match recipe {
        JobRecipe::Stacked { terms } => {
            assert!(!terms.is_empty(), "empty stacked job");
            let scaled_a: Vec<Matrix> = terms
                .iter()
                .map(|t| {
                    let (ai, _) = part.factors_of(t.unknown);
                    let mut m = a_blocks[ai].clone();
                    m.scale(t.coeff);
                    m
                })
                .collect();
            Matrix::hconcat(&scaled_a.iter().collect::<Vec<_>>())
        }
        JobRecipe::RankOne { a_coeffs, .. } => {
            assert!(!a_coeffs.is_empty());
            let (u, h) = a_blocks[0].shape();
            let mut wa = Matrix::zeros(u, h);
            for &(i, alpha) in a_coeffs {
                wa.axpy(alpha, &a_blocks[i]);
            }
            wa
        }
    }
}

/// Materialize the right factor a worker multiplies, per the packet
/// recipe (paper eq. 5–6):
/// * `Stacked`: `W_B = [B_{p₁}; …] (kH×Q)`.
/// * `RankOne`: `W_B = Σ βⱼ·B_j (H×Q)`.
pub fn build_job_b(
    part: &Partitioning,
    b_blocks: &[Matrix],
    recipe: &JobRecipe,
) -> Matrix {
    match recipe {
        JobRecipe::Stacked { terms } => {
            assert!(!terms.is_empty(), "empty stacked job");
            let b_parts: Vec<&Matrix> = terms
                .iter()
                .map(|t| {
                    let (_, bi) = part.factors_of(t.unknown);
                    &b_blocks[bi]
                })
                .collect();
            Matrix::vconcat(&b_parts)
        }
        JobRecipe::RankOne { b_coeffs, .. } => {
            assert!(!b_coeffs.is_empty());
            let (h, q) = b_blocks[0].shape();
            let mut wb = Matrix::zeros(h, q);
            for &(j, beta) in b_coeffs {
                wb.axpy(beta, &b_blocks[j]);
            }
            wb
        }
    }
}

/// Materialize both factor matrices of one job (see [`build_job_a`] and
/// [`build_job_b`]).
pub fn build_job_matrices(
    part: &Partitioning,
    a_blocks: &[Matrix],
    b_blocks: &[Matrix],
    recipe: &JobRecipe,
) -> (Matrix, Matrix) {
    (build_job_a(part, a_blocks, recipe), build_job_b(part, b_blocks, recipe))
}

/// The rateless counterpart of [`Plan`]: instead of a fixed packet set
/// it holds the deterministic [`RatelessCoder`] from which *any*
/// `(request, stream, seq)` packet can be derived — by the PS when it
/// absorbs a result, or by a worker when it generates one. No
/// coefficients ever cross the wire.
///
/// Blocks are kept behind `Arc` because a single plan is shared between
/// the dispatch path (ships the blocks to workers inside a
/// `RatelessJob` frame) and the verify path (precomputes Freivalds
/// references from the same blocks).
#[derive(Clone, Debug)]
pub struct RatelessPlan {
    pub part: Partitioning,
    pub cm: ClassMap,
    pub spec: RatelessSpec,
    pub space: UnknownSpace,
    pub coder: RatelessCoder,
    pub a_blocks: Vec<Arc<Matrix>>,
    pub b_blocks: Vec<Arc<Matrix>>,
}

impl RatelessPlan {
    /// Split, classify into `s_levels` by Frobenius norm, and build the
    /// deterministic coder.
    pub fn build(
        part: &Partitioning,
        spec: RatelessSpec,
        s_levels: usize,
        a: &Matrix,
        b: &Matrix,
    ) -> Result<RatelessPlan> {
        let cm = ClassMap::from_matrices(part, a, b, s_levels);
        Self::build_with_classes(part, spec, cm, a, b)
    }

    /// Build with an explicit class map (synthetic experiments pin the
    /// levels instead of estimating them from norms).
    pub fn build_with_classes(
        part: &Partitioning,
        spec: RatelessSpec,
        cm: ClassMap,
        a: &Matrix,
        b: &Matrix,
    ) -> Result<RatelessPlan> {
        anyhow::ensure!(
            cm.class_of.len() == part.num_products(),
            "class map covers {} unknowns, partitioning has {}",
            cm.class_of.len(),
            part.num_products()
        );
        let coder = RatelessCoder::from_class_map(&spec, &cm);
        let space = UnknownSpace::for_code(part, EncodeStyle::Stacked);
        let a_blocks = part.split_a(a).into_iter().map(Arc::new).collect();
        let b_blocks = part.split_b(b).into_iter().map(Arc::new).collect();
        Ok(RatelessPlan { part: part.clone(), cm, spec, space, coder, a_blocks, b_blocks })
    }

    /// Number of real unknowns (sub-products of `C`).
    pub fn num_unknowns(&self) -> usize {
        self.part.num_products()
    }

    /// The class index of each unknown, in wire form (`RatelessJob`
    /// ships this so workers rebuild the identical coder).
    pub fn class_of(&self) -> Vec<u32> {
        self.cm.class_of.iter().map(|&c| c as u32).collect()
    }

    /// The `(a block, b block)` factor pair of each unknown, in wire
    /// form (ships alongside [`Self::class_of`]).
    pub fn factors(&self) -> Vec<(u32, u32)> {
        (0..self.part.num_products())
            .map(|u| {
                let (ai, bi) = self.part.factors_of(u);
                (ai as u32, bi as u32)
            })
            .collect()
    }

    /// Derive the packet for `(request_id, stream, seq)` — identical to
    /// what the worker holding that stream generates.
    pub fn packet(&self, request_id: u64, stream: u64, seq: u32) -> Packet {
        self.coder.packet(request_id, stream, seq)
    }

    /// The honest payload of a packet: `W_A · W_B` materialized from the
    /// plan's own blocks (loopback backends and tests use this instead
    /// of round-tripping matrices through a worker).
    pub fn payload(&self, pkt: &Packet) -> Matrix {
        let JobRecipe::Stacked { terms } = &pkt.recipe else {
            panic!("rateless packets are always stacked");
        };
        let scaled: Vec<Matrix> = terms
            .iter()
            .map(|t| {
                let (ai, _) = self.part.factors_of(t.unknown);
                let mut m = (*self.a_blocks[ai]).clone();
                m.scale(t.coeff);
                m
            })
            .collect();
        let wa = Matrix::hconcat(&scaled.iter().collect::<Vec<_>>());
        let b_parts: Vec<&Matrix> = terms
            .iter()
            .map(|t| {
                let (_, bi) = self.part.factors_of(t.unknown);
                &*self.b_blocks[bi]
            })
            .collect();
        matmul(&wa, &Matrix::vconcat(&b_parts))
    }

    /// The true sub-products (reference for loss traces in experiments).
    pub fn true_products(&self) -> Vec<Matrix> {
        (0..self.part.num_products())
            .map(|u| {
                let (ai, bi) = self.part.factors_of(u);
                matmul(&self.a_blocks[ai], &self.b_blocks[bi])
            })
            .collect()
    }
}

/// Freivalds verifier for a rateless stream. Fixed-rate [`Verifier`]
/// precomputes one reference per *slot*; a rateless stream has no slot
/// bound, so this one precomputes one reference per *unknown*:
/// `z_u = A_{a(u)} · (B_{b(u)} · r)` for a single Gaussian probe `r`.
/// Any packet's reference is then the coefficient combination
/// `Σ_j c_j · z_{u_j}` — O(U·d) per check regardless of how many
/// packets the stream ends up carrying.
///
/// As with [`Verifier`], the probe RNG is supplied by the caller on a
/// stream disjoint from delay sampling, so toggling verification never
/// shifts any other draw.
#[derive(Clone, Debug)]
pub struct RatelessVerifier {
    probe: Matrix,
    z: Vec<Matrix>,
}

impl RatelessVerifier {
    /// Draw the probe and precompute one reference column per unknown.
    pub fn new(plan: &RatelessPlan, rng: &mut Pcg64) -> RatelessVerifier {
        let q = plan.b_blocks[0].cols();
        let probe = Matrix::randn(q, 1, 0.0, 1.0, rng);
        let z = (0..plan.num_unknowns())
            .map(|u| {
                let (ai, bi) = plan.part.factors_of(u);
                matmul(&plan.a_blocks[ai], &matmul(&plan.b_blocks[bi], &probe))
            })
            .collect();
        RatelessVerifier { probe, z }
    }

    /// Check one arriving payload against the packet's coefficient
    /// terms. Returns `false` for wrong shapes, out-of-range unknowns,
    /// or a product that misses the combined reference beyond relative
    /// tolerance.
    pub fn check(&self, terms: &[StackTerm], payload: &Matrix) -> bool {
        let Some(first) = self.z.first() else { return false };
        if payload.rows() != first.rows() || payload.cols() != self.probe.rows() {
            return false;
        }
        let mut v = Matrix::zeros(first.rows(), 1);
        for t in terms {
            match self.z.get(t.unknown) {
                Some(z) => v.axpy(t.coeff, z),
                None => return false,
            }
        }
        let pr = matmul(payload, &self.probe);
        let scale = v.max_abs().max(pr.max_abs()).max(1.0);
        pr.sub(&v).max_abs() <= 1e-6 * scale
    }
}

/// Freivalds verifier for one request's job set: a cheap probabilistic
/// check that an arriving sub-product really is `W_A · W_B`.
///
/// At build time it draws one Gaussian probe vector `r` per slot and
/// precomputes the reference `v = W_A · (W_B · r)` — two matrix-vector
/// products, O(n²) per slot. Checking a payload is a single
/// matrix-vector product `payload · r` compared against `v`, again
/// O(n²), versus the O(n³) of recomputing `W_A · W_B` outright. A
/// tampered payload passes only if its error lies in the probe's null
/// space — probability 0 for a Gaussian probe under real perturbations.
///
/// The probe RNG is supplied by the caller (the cluster server seeds it
/// from `(verify_seed, request_id)` on a stream disjoint from delay
/// sampling), so enabling or disabling verification never shifts any
/// other random draw and honest-run outcomes stay bit-identical.
#[derive(Clone, Debug)]
pub struct Verifier {
    probes: Vec<Matrix>,
    refs: Vec<Matrix>,
}

impl Verifier {
    /// Draw one probe per job and precompute the references.
    pub fn new(jobs: &[(Arc<Matrix>, Arc<Matrix>)], rng: &mut Pcg64) -> Verifier {
        let mut probes = Vec::with_capacity(jobs.len());
        let mut refs = Vec::with_capacity(jobs.len());
        for (wa, wb) in jobs {
            let r = Matrix::randn(wb.cols(), 1, 0.0, 1.0, rng);
            let v = matmul(wa, &matmul(wb, &r));
            probes.push(r);
            refs.push(v);
        }
        Verifier { probes, refs }
    }

    /// Number of slots this verifier covers.
    pub fn slots(&self) -> usize {
        self.probes.len()
    }

    /// Check one arriving payload against slot `slot`'s probe. Returns
    /// `false` for wrong shapes or a product that misses the reference
    /// beyond relative tolerance.
    pub fn check(&self, slot: usize, payload: &Matrix) -> bool {
        let (r, v) = match (self.probes.get(slot), self.refs.get(slot)) {
            (Some(r), Some(v)) => (r, v),
            _ => return false,
        };
        if payload.rows() != v.rows() || payload.cols() != r.rows() {
            return false;
        }
        let pr = matmul(payload, r);
        let scale = v.max_abs().max(pr.max_abs()).max(1.0);
        pr.sub(v).max_abs() <= 1e-6 * scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodeKind, StackTerm};

    #[test]
    fn stacked_job_product_equals_combination() {
        let mut rng = Pcg64::seed_from(1);
        let part = Partitioning::rxc(2, 2, 3, 4, 3);
        let a = Matrix::randn(6, 4, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(4, 6, 0.0, 1.0, &mut rng);
        let a_blocks = part.split_a(&a);
        let b_blocks = part.split_b(&b);
        let prods = part.true_products(&a, &b);
        let recipe = JobRecipe::Stacked {
            terms: vec![
                StackTerm { unknown: 0, coeff: 2.0 },
                StackTerm { unknown: 3, coeff: -1.5 },
            ],
        };
        let (wa, wb) = build_job_matrices(&part, &a_blocks, &b_blocks, &recipe);
        assert_eq!(wa.shape(), (3, 8));
        assert_eq!(wb.shape(), (8, 3));
        let got = matmul(&wa, &wb);
        let mut want = prods[0].clone();
        want.scale(2.0);
        want.axpy(-1.5, &prods[3]);
        assert!(got.allclose(&want, 1e-10));
    }

    #[test]
    fn rank_one_job_product_equals_khatri_rao_combination() {
        let mut rng = Pcg64::seed_from(2);
        let part = Partitioning::cxr(3, 4, 3, 5);
        let a = Matrix::randn(4, 9, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(9, 5, 0.0, 1.0, &mut rng);
        let a_blocks = part.split_a(&a);
        let b_blocks = part.split_b(&b);
        let recipe = JobRecipe::RankOne {
            a_coeffs: vec![(0, 1.0), (2, 0.5)],
            b_coeffs: vec![(1, -1.0), (2, 2.0)],
        };
        let (wa, wb) = build_job_matrices(&part, &a_blocks, &b_blocks, &recipe);
        let got = matmul(&wa, &wb);
        // expand: Σ_{i,j} αβ A_i B_j
        let mut want = Matrix::zeros(4, 5);
        for &(i, al) in &[(0usize, 1.0), (2usize, 0.5)] {
            for &(j, be) in &[(1usize, -1.0), (2usize, 2.0)] {
                want.axpy(al * be, &matmul(&a_blocks[i], &b_blocks[j]));
            }
        }
        assert!(got.allclose(&want, 1e-10));
    }

    #[test]
    fn job_factor_halves_compose_to_the_full_job() {
        let mut rng = Pcg64::seed_from(11);
        let part = Partitioning::rxc(3, 3, 2, 3, 2);
        let a = Matrix::randn(6, 3, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(3, 6, 0.0, 1.0, &mut rng);
        let a_blocks = part.split_a(&a);
        let b_blocks = part.split_b(&b);
        let spec = CodeSpec::stacked(CodeKind::Mds);
        let cm = crate::partition::ClassMap::from_matrices(&part, &a, &b, 3);
        for p in spec.generate_packets(&part, &cm, 6, &mut rng) {
            let (wa, wb) = build_job_matrices(&part, &a_blocks, &b_blocks, &p.recipe);
            let ha = build_job_a(&part, &a_blocks, &p.recipe);
            let hb = build_job_b(&part, &b_blocks, &p.recipe);
            assert!(wa.allclose(&ha, 0.0), "W_A halves must be identical");
            assert!(wb.allclose(&hb, 0.0), "W_B halves must be identical");
        }
    }

    #[test]
    fn encoded_a_matches_plan_construction() {
        // Same seed through EncodedA::encode and Plan::build_with_classes
        // must give the same packets and the same worker jobs: the cache
        // path is a pure refactoring of plan construction.
        let part = Partitioning::rxc(3, 3, 2, 3, 2);
        let mut rng = Pcg64::seed_from(21);
        let a = Matrix::randn(6, 3, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(3, 6, 0.0, 1.0, &mut rng);
        let cm = crate::partition::ClassMap::from_matrices(&part, &a, &b, 3);
        let spec = CodeSpec::stacked(CodeKind::Mds);

        let mut r1 = Pcg64::seed_from(77);
        let enc =
            EncodedA::encode(&part, spec.clone(), &cm, 8, &a, &mut r1).unwrap();
        let mut r2 = Pcg64::seed_from(77);
        let plan =
            Plan::build_with_classes(&part, spec, cm, 8, &a, &b, &mut r2).unwrap();

        assert_eq!(enc.packets, plan.packets);
        assert_eq!(enc.workers(), 8);
        let b_blocks = part.split_b(&b);
        for w in 0..8 {
            let (wa, wb) = build_job_matrices(
                &part,
                &plan.a_blocks,
                &plan.b_blocks,
                &plan.packets[w].recipe,
            );
            assert!(enc.wa[w].allclose(&wa, 0.0));
            assert!(enc.job_b(&b_blocks, w).allclose(&wb, 0.0));
        }
    }

    #[test]
    fn verifier_accepts_honest_products_and_rejects_tampered_ones() {
        let mut rng = Pcg64::seed_from(31);
        let part = Partitioning::rxc(3, 3, 4, 5, 4);
        let a = Matrix::randn(12, 5, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(5, 12, 0.0, 1.0, &mut rng);
        let a_blocks = part.split_a(&a);
        let b_blocks = part.split_b(&b);
        let spec = CodeSpec::stacked(CodeKind::Mds);
        let cm = crate::partition::ClassMap::from_matrices(&part, &a, &b, 3);
        let jobs: Vec<(Arc<Matrix>, Arc<Matrix>)> = spec
            .generate_packets(&part, &cm, 10, &mut rng)
            .iter()
            .map(|p| {
                let (wa, wb) =
                    build_job_matrices(&part, &a_blocks, &b_blocks, &p.recipe);
                (Arc::new(wa), Arc::new(wb))
            })
            .collect();
        let mut vrng = Pcg64::with_stream(99, 1);
        let v = Verifier::new(&jobs, &mut vrng);
        assert_eq!(v.slots(), 10);
        for (s, (wa, wb)) in jobs.iter().enumerate() {
            let honest = matmul(wa, wb);
            assert!(v.check(s, &honest), "honest payload rejected at slot {s}");
            // Byzantine worker: perturb one entry well above float noise
            let mut data = honest.data().to_vec();
            data[0] += 1.0 + 0.5 * honest.max_abs();
            let forged = Matrix::from_vec(honest.rows(), honest.cols(), data);
            assert!(!v.check(s, &forged), "forged payload accepted at slot {s}");
        }
    }

    #[test]
    fn verifier_rejects_wrong_shapes_and_unknown_slots() {
        let mut rng = Pcg64::seed_from(32);
        let wa = Matrix::randn(4, 3, 0.0, 1.0, &mut rng);
        let wb = Matrix::randn(3, 5, 0.0, 1.0, &mut rng);
        let jobs = vec![(Arc::new(wa.clone()), Arc::new(wb.clone()))];
        let v = Verifier::new(&jobs, &mut Pcg64::seed_from(7));
        assert!(v.check(0, &matmul(&wa, &wb)));
        assert!(!v.check(0, &Matrix::zeros(5, 5)), "wrong shape must fail");
        assert!(!v.check(1, &matmul(&wa, &wb)), "out-of-range slot must fail");
    }

    #[test]
    fn rateless_plan_payload_matches_coefficient_combination() {
        let mut rng = Pcg64::seed_from(41);
        let part = Partitioning::rxc(3, 3, 2, 3, 2);
        let a = Matrix::randn(6, 3, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(3, 6, 0.0, 1.0, &mut rng);
        let plan =
            RatelessPlan::build(&part, RatelessSpec::paper_default(), 3, &a, &b)
                .unwrap();
        assert_eq!(plan.num_unknowns(), 9);
        assert_eq!(plan.factors().len(), 9);
        assert_eq!(plan.class_of().len(), 9);
        let prods = plan.true_products();
        for (stream, seq) in [(0u64, 0u32), (2, 5), (7, 31)] {
            let pkt = plan.packet(123, stream, seq);
            let JobRecipe::Stacked { terms } = &pkt.recipe else {
                panic!("not stacked")
            };
            let mut want = Matrix::zeros(prods[0].rows(), prods[0].cols());
            for t in terms {
                want.axpy(t.coeff, &prods[t.unknown]);
            }
            assert!(plan.payload(&pkt).allclose(&want, 1e-10));
        }
    }

    #[test]
    fn rateless_verifier_accepts_honest_and_rejects_forged_packets() {
        let mut rng = Pcg64::seed_from(42);
        let part = Partitioning::rxc(3, 3, 4, 5, 4);
        let a = Matrix::randn(12, 5, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(5, 12, 0.0, 1.0, &mut rng);
        let plan =
            RatelessPlan::build(&part, RatelessSpec::paper_default(), 3, &a, &b)
                .unwrap();
        let v = RatelessVerifier::new(&plan, &mut Pcg64::with_stream(99, 1));
        for seq in 0..8u32 {
            let pkt = plan.packet(5, 1, seq);
            let JobRecipe::Stacked { terms } = &pkt.recipe else {
                panic!("not stacked")
            };
            let honest = plan.payload(&pkt);
            assert!(v.check(terms, &honest), "honest packet rejected at {seq}");
            let mut data = honest.data().to_vec();
            data[0] += 1.0 + 0.5 * honest.max_abs();
            let forged = Matrix::from_vec(honest.rows(), honest.cols(), data);
            assert!(!v.check(terms, &forged), "forged packet accepted at {seq}");
            // a packet's payload never verifies against different terms
            let other = plan.packet(5, 1, seq + 100);
            let JobRecipe::Stacked { terms: ot } = &other.recipe else {
                panic!("not stacked")
            };
            if ot != terms {
                assert!(!v.check(ot, &honest), "cross-packet check passed");
            }
        }
        assert!(!v.check(
            &[StackTerm { unknown: 999, coeff: 1.0 }],
            &plan.payload(&plan.packet(5, 1, 0))
        ));
    }

    #[test]
    fn plan_build_classifies_and_generates() {
        let mut rng = Pcg64::seed_from(3);
        let part = Partitioning::rxc(3, 3, 2, 3, 2);
        let a = Matrix::randn(6, 3, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(3, 6, 0.0, 1.0, &mut rng);
        let spec = CodeSpec::stacked(CodeKind::Mds);
        let plan = Plan::build(&part, spec, 3, 12, &a, &b, &mut rng).unwrap();
        assert_eq!(plan.workers(), 12);
        assert_eq!(plan.cm.n_classes, 3);
        assert_eq!(plan.true_products().len(), 9);
        assert_eq!(plan.total_work_factor(), 12 * 9); // dense MDS jobs
        assert!(plan.c_true.allclose(&matmul(&a, &b), 1e-12));
    }
}
