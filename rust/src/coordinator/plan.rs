//! A [`Plan`] is everything the PS prepares *before* dispatch: the block
//! split of `A` and `B`, the norm-based importance classification, the
//! coded packet set, and the reference product for loss evaluation.

use anyhow::Result;

use crate::coding::{CodeSpec, JobRecipe, Packet, UnknownSpace};
use crate::linalg::{matmul, Matrix};
use crate::partition::{ClassMap, Partitioning};
use crate::rng::Pcg64;

/// A prepared coded-multiplication job set.
#[derive(Clone, Debug)]
pub struct Plan {
    pub part: Partitioning,
    pub cm: ClassMap,
    pub spec: CodeSpec,
    pub space: UnknownSpace,
    pub packets: Vec<Packet>,
    pub a_blocks: Vec<Matrix>,
    pub b_blocks: Vec<Matrix>,
    /// The true product (reference for loss; computed once at build).
    pub c_true: Matrix,
}

impl Plan {
    /// Build a plan: split, classify into `s_levels` by Frobenius norm,
    /// and generate one coded packet per worker.
    pub fn build(
        part: &Partitioning,
        spec: CodeSpec,
        s_levels: usize,
        workers: usize,
        a: &Matrix,
        b: &Matrix,
        rng: &mut Pcg64,
    ) -> Result<Plan> {
        let cm = ClassMap::from_matrices(part, a, b, s_levels);
        Self::build_with_classes(part, spec, cm, workers, a, b, rng)
    }

    /// Build with an explicit class map (synthetic experiments pin the
    /// levels instead of estimating them from norms).
    pub fn build_with_classes(
        part: &Partitioning,
        spec: CodeSpec,
        cm: ClassMap,
        workers: usize,
        a: &Matrix,
        b: &Matrix,
        rng: &mut Pcg64,
    ) -> Result<Plan> {
        anyhow::ensure!(workers >= 1, "need at least one worker");
        let a_blocks = part.split_a(a);
        let b_blocks = part.split_b(b);
        let packets = spec.generate_packets(part, &cm, workers, rng);
        let space = UnknownSpace::for_code(part, spec.style);
        let c_true = matmul(a, b);
        Ok(Plan {
            part: part.clone(),
            cm,
            spec,
            space,
            packets,
            a_blocks,
            b_blocks,
            c_true,
        })
    }

    pub fn workers(&self) -> usize {
        self.packets.len()
    }

    /// The true sub-products (computed on demand, e.g. for Gram-based
    /// fast sweeps).
    pub fn true_products(&self) -> Vec<Matrix> {
        (0..self.part.num_products())
            .map(|i| {
                let (ai, bi) = self.part.factors_of(i);
                matmul(&self.a_blocks[ai], &self.b_blocks[bi])
            })
            .collect()
    }

    /// Total worker compute (in units of one plain sub-product) — the
    /// quantity behind the paper's Ω fairness scaling.
    pub fn total_work_factor(&self) -> usize {
        self.packets.iter().map(|p| p.recipe.work_factor()).sum()
    }
}

/// Materialize the two factor matrices a worker multiplies, per the
/// packet recipe (paper eq. 5–6):
/// * `Stacked`: `W_A = [c₁·A_{n₁}, …] (U×kH)`, `W_B = [B_{p₁}; …] (kH×Q)`.
/// * `RankOne`: `W_A = Σ αᵢ·A_i (U×H)`, `W_B = Σ βⱼ·B_j (H×Q)`.
pub fn build_job_matrices(
    part: &Partitioning,
    a_blocks: &[Matrix],
    b_blocks: &[Matrix],
    recipe: &JobRecipe,
) -> (Matrix, Matrix) {
    match recipe {
        JobRecipe::Stacked { terms } => {
            assert!(!terms.is_empty(), "empty stacked job");
            let scaled_a: Vec<Matrix> = terms
                .iter()
                .map(|t| {
                    let (ai, _) = part.factors_of(t.unknown);
                    let mut m = a_blocks[ai].clone();
                    m.scale(t.coeff);
                    m
                })
                .collect();
            let b_parts: Vec<&Matrix> = terms
                .iter()
                .map(|t| {
                    let (_, bi) = part.factors_of(t.unknown);
                    &b_blocks[bi]
                })
                .collect();
            let wa = Matrix::hconcat(&scaled_a.iter().collect::<Vec<_>>());
            let wb = Matrix::vconcat(&b_parts);
            (wa, wb)
        }
        JobRecipe::RankOne { a_coeffs, b_coeffs } => {
            assert!(!a_coeffs.is_empty() && !b_coeffs.is_empty());
            let (u, h) = a_blocks[0].shape();
            let (_, q) = b_blocks[0].shape();
            let mut wa = Matrix::zeros(u, h);
            for &(i, alpha) in a_coeffs {
                wa.axpy(alpha, &a_blocks[i]);
            }
            let mut wb = Matrix::zeros(h, q);
            for &(j, beta) in b_coeffs {
                wb.axpy(beta, &b_blocks[j]);
            }
            (wa, wb)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodeKind, StackTerm};

    #[test]
    fn stacked_job_product_equals_combination() {
        let mut rng = Pcg64::seed_from(1);
        let part = Partitioning::rxc(2, 2, 3, 4, 3);
        let a = Matrix::randn(6, 4, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(4, 6, 0.0, 1.0, &mut rng);
        let a_blocks = part.split_a(&a);
        let b_blocks = part.split_b(&b);
        let prods = part.true_products(&a, &b);
        let recipe = JobRecipe::Stacked {
            terms: vec![
                StackTerm { unknown: 0, coeff: 2.0 },
                StackTerm { unknown: 3, coeff: -1.5 },
            ],
        };
        let (wa, wb) = build_job_matrices(&part, &a_blocks, &b_blocks, &recipe);
        assert_eq!(wa.shape(), (3, 8));
        assert_eq!(wb.shape(), (8, 3));
        let got = matmul(&wa, &wb);
        let mut want = prods[0].clone();
        want.scale(2.0);
        want.axpy(-1.5, &prods[3]);
        assert!(got.allclose(&want, 1e-10));
    }

    #[test]
    fn rank_one_job_product_equals_khatri_rao_combination() {
        let mut rng = Pcg64::seed_from(2);
        let part = Partitioning::cxr(3, 4, 3, 5);
        let a = Matrix::randn(4, 9, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(9, 5, 0.0, 1.0, &mut rng);
        let a_blocks = part.split_a(&a);
        let b_blocks = part.split_b(&b);
        let recipe = JobRecipe::RankOne {
            a_coeffs: vec![(0, 1.0), (2, 0.5)],
            b_coeffs: vec![(1, -1.0), (2, 2.0)],
        };
        let (wa, wb) = build_job_matrices(&part, &a_blocks, &b_blocks, &recipe);
        let got = matmul(&wa, &wb);
        // expand: Σ_{i,j} αβ A_i B_j
        let mut want = Matrix::zeros(4, 5);
        for &(i, al) in &[(0usize, 1.0), (2usize, 0.5)] {
            for &(j, be) in &[(1usize, -1.0), (2usize, 2.0)] {
                want.axpy(al * be, &matmul(&a_blocks[i], &b_blocks[j]));
            }
        }
        assert!(got.allclose(&want, 1e-10));
    }

    #[test]
    fn plan_build_classifies_and_generates() {
        let mut rng = Pcg64::seed_from(3);
        let part = Partitioning::rxc(3, 3, 2, 3, 2);
        let a = Matrix::randn(6, 3, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(3, 6, 0.0, 1.0, &mut rng);
        let spec = CodeSpec::stacked(CodeKind::Mds);
        let plan = Plan::build(&part, spec, 3, 12, &a, &b, &mut rng).unwrap();
        assert_eq!(plan.workers(), 12);
        assert_eq!(plan.cm.n_classes, 3);
        assert_eq!(plan.true_products().len(), 9);
        assert_eq!(plan.total_work_factor(), 12 * 9); // dense MDS jobs
        assert!(plan.c_true.allclose(&matmul(&a, &b), 1e-12));
    }
}
