//! Importance classification: factor blocks → `S` norm levels, and the
//! pair table mapping factor-level pairs to the `L` classes of `C`
//! sub-products (paper §IV-A and the §VI worked example).

use super::Partitioning;
use crate::linalg::Matrix;

/// Classify values into `s` importance levels by descending magnitude:
/// index 0 = most important. Groups are as equal-sized as possible
/// (paper §VII-C: "divided into three groups of (roughly) equal size").
///
/// The sort is total (`f64::total_cmp`), so non-finite norms cannot
/// panic the production classification path: a NaN norm — e.g. a block
/// containing NaN entries from an upstream numerical blow-up — orders
/// above `+∞` and lands in the most-protected level, which is the
/// conservative choice for data we cannot reason about.
pub fn classify_by_norm(norms: &[f64], s: usize) -> Vec<usize> {
    assert!(s >= 1 && s <= norms.len(), "need 1 ≤ S ≤ #blocks");
    let mut order: Vec<usize> = (0..norms.len()).collect();
    order.sort_by(|&i, &j| norms[j].total_cmp(&norms[i]));
    let mut classes = vec![0usize; norms.len()];
    let n = norms.len();
    for (rank, &idx) in order.iter().enumerate() {
        // split ranks into s contiguous groups of near-equal size
        classes[idx] = rank * s / n;
    }
    classes
}

/// Default pair table: maps an (unordered) pair of factor levels
/// `(s_a, s_b)` with `s_a, s_b ∈ [S]` to a class in `[L]` with `L = S`,
/// reproducing the paper's §VI example for `S = 3`:
/// `{hh, hm, mh} → 0`, `{mm, hl, lh} → 1`, `{ml, lm, ll} → 2`.
///
/// General rule: the pair score `σ = s_a + s_b ∈ [0, 2S-2]` is banded
/// symmetrically into `S` classes — scores below the middle pair up from
/// the top, the middle score `S-1` sits in the middle class, and scores
/// above pair up from the bottom. For `S = 3` this is exactly the paper's
/// merge: bands `{0,1} {2} {3,4}`.
pub fn default_pair_classes(s: usize) -> PairTable {
    let band = |score: usize| -> usize {
        let mid = s - 1;
        if score < mid {
            score / 2
        } else if score == mid {
            mid / 2
        } else {
            (s - 1) - (2 * s - 2 - score) / 2
        }
    };
    let table = (0..s)
        .map(|sa| (0..s).map(|sb| band(sa + sb)).collect())
        .collect();
    PairTable { s, table }
}

/// Mapping from factor-level pairs to sub-product classes.
#[derive(Clone, Debug)]
pub struct PairTable {
    pub s: usize,
    /// `table[s_a][s_b]` = class of a product of an `s_a`-level A block
    /// with an `s_b`-level B block.
    pub table: Vec<Vec<usize>>,
}

impl PairTable {
    pub fn class_of(&self, sa: usize, sb: usize) -> usize {
        self.table[sa][sb]
    }

    pub fn num_classes(&self) -> usize {
        *self.table.iter().flatten().max().unwrap() + 1
    }
}

/// The complete importance structure of one coded multiplication:
/// factor-block levels, sub-product classes, and members per class.
#[derive(Clone, Debug)]
pub struct ClassMap {
    /// Number of sub-product classes `L` (most important = 0).
    pub n_classes: usize,
    /// Class of each sub-product (unknown), length `num_products()`.
    pub class_of: Vec<usize>,
    /// Unknown indices per class (each non-empty).
    pub members: Vec<Vec<usize>>,
    /// Importance level of each A factor block.
    pub a_level: Vec<usize>,
    /// Importance level of each B factor block.
    pub b_level: Vec<usize>,
    /// Number of factor levels `S`.
    pub s_levels: usize,
}

impl ClassMap {
    /// Build from explicit factor levels and a pair table. Classes with no
    /// members are compacted away (the paper's c×r case can produce fewer
    /// than `S(S+1)/2` classes).
    pub fn from_levels(
        part: &Partitioning,
        a_level: Vec<usize>,
        b_level: Vec<usize>,
        pair: &PairTable,
    ) -> Self {
        assert_eq!(a_level.len(), part.num_a_blocks());
        assert_eq!(b_level.len(), part.num_b_blocks());
        let k = part.num_products();
        let raw: Vec<usize> = (0..k)
            .map(|i| {
                let (ai, bi) = part.factors_of(i);
                pair.class_of(a_level[ai], b_level[bi])
            })
            .collect();
        // compact to consecutive class ids preserving order
        let mut present: Vec<usize> = raw.clone();
        present.sort_unstable();
        present.dedup();
        let remap = |c: usize| present.binary_search(&c).unwrap();
        let class_of: Vec<usize> = raw.iter().map(|&c| remap(c)).collect();
        let n_classes = present.len();
        let mut members = vec![Vec::new(); n_classes];
        for (i, &c) in class_of.iter().enumerate() {
            members[c].push(i);
        }
        ClassMap { n_classes, class_of, members, a_level, b_level, s_levels: pair.s }
    }

    /// Build by classifying the actual factor blocks of `(A, B)` by
    /// Frobenius norm into `s` levels (the production path: the PS sorts
    /// row/column blocks by magnitude, §VII-C).
    pub fn from_matrices(
        part: &Partitioning,
        a: &Matrix,
        b: &Matrix,
        s: usize,
    ) -> Self {
        let a_norms: Vec<f64> =
            part.split_a(a).iter().map(|m| m.frob_sq()).collect();
        let b_norms: Vec<f64> =
            part.split_b(b).iter().map(|m| m.frob_sq()).collect();
        ClassMap::from_norms(part, &a_norms, &b_norms, s)
    }

    /// [`Self::from_matrices`] from already-computed per-block Frobenius
    /// norms — the one home of the norm-classification recipe, shared by
    /// callers that need the norms for other purposes too (the adaptive
    /// session's σ² estimate and re-banding).
    pub fn from_norms(
        part: &Partitioning,
        a_norms: &[f64],
        b_norms: &[f64],
        s: usize,
    ) -> Self {
        let a_level = classify_by_norm(a_norms, s);
        let b_level = classify_by_norm(b_norms, s);
        let pair = default_pair_classes(s);
        ClassMap::from_levels(part, a_level, b_level, &pair)
    }

    /// `k_l`: number of sub-products in each class.
    pub fn class_sizes(&self) -> Vec<usize> {
        self.members.iter().map(|m| m.len()).collect()
    }

    /// Unknowns whose class is `≤ l` (the EW window `l`).
    pub fn window_leq(&self, l: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .members
            .iter()
            .take(l + 1)
            .flatten()
            .copied()
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn classify_splits_evenly_and_orders() {
        let norms = [10.0, 1.0, 0.1, 5.0, 0.5, 0.05];
        let c = classify_by_norm(&norms, 3);
        // descending order: 10, 5, 1, 0.5, 0.1, 0.05 → levels 0,0,1,1,2,2
        assert_eq!(c, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn classify_single_class() {
        let c = classify_by_norm(&[3.0, 2.0, 1.0], 1);
        assert_eq!(c, vec![0, 0, 0]);
    }

    #[test]
    fn classify_survives_nan_norms_ranking_them_most_important() {
        // Regression: the old partial_cmp(..).unwrap() sort panicked on
        // any NaN norm. The total order must classify without panicking
        // and put the NaN block in level 0 (above +∞).
        let c = classify_by_norm(&[1.0, f64::NAN, 2.0, f64::INFINITY, 0.5, 3.0], 3);
        assert_eq!(c.len(), 6);
        assert_eq!(c[1], 0, "NaN ranks most important: {c:?}");
        assert_eq!(c[3], 0, "+∞ ranks directly below NaN: {c:?}");
        assert_eq!(c[4], 2, "the smallest finite norm ranks last: {c:?}");
        // every level is populated with near-equal sizes
        for lvl in 0..3 {
            assert_eq!(c.iter().filter(|&&x| x == lvl).count(), 2, "{c:?}");
        }
    }

    #[test]
    fn pair_table_matches_paper_example() {
        // S = 3: {hh,hm,mh}→0, {mm,hl,lh}→1, {ml,lm,ll}→2
        let t = default_pair_classes(3);
        assert_eq!(t.class_of(0, 0), 0);
        assert_eq!(t.class_of(0, 1), 0);
        assert_eq!(t.class_of(1, 0), 0);
        assert_eq!(t.class_of(1, 1), 1);
        assert_eq!(t.class_of(0, 2), 1);
        assert_eq!(t.class_of(2, 0), 1);
        assert_eq!(t.class_of(1, 2), 2);
        assert_eq!(t.class_of(2, 1), 2);
        assert_eq!(t.class_of(2, 2), 2);
        assert_eq!(t.num_classes(), 3);
    }

    #[test]
    fn paper_rxc_synthetic_classes() {
        // §VI: N=P=3, one block per level on each side → k=(3,3,3).
        let part = Partitioning::rxc(3, 3, 2, 2, 2);
        let pair = default_pair_classes(3);
        let cm = ClassMap::from_levels(&part, vec![0, 1, 2], vec![0, 1, 2], &pair);
        assert_eq!(cm.n_classes, 3);
        assert_eq!(cm.class_sizes(), vec![3, 3, 3]);
        // class 0 = {(0,0),(0,1),(1,0)} = unknowns {0,1,3}
        assert_eq!(cm.members[0], vec![0, 1, 3]);
    }

    #[test]
    fn paper_cxr_synthetic_classes() {
        // §VI: M=9, blocks 0-2 high, 3-5 medium, 6-8 low → k=(3,3,3).
        let part = Partitioning::cxr(9, 2, 2, 2);
        let lv = vec![0, 0, 0, 1, 1, 1, 2, 2, 2];
        let pair = default_pair_classes(3);
        let cm = ClassMap::from_levels(&part, lv.clone(), lv, &pair);
        assert_eq!(cm.n_classes, 3);
        assert_eq!(cm.class_sizes(), vec![3, 3, 3]);
        assert_eq!(cm.members[2], vec![6, 7, 8]);
    }

    #[test]
    fn cxr_compacts_missing_classes() {
        // alternating levels: pairs are (0,0) and (2,2) only → 2 classes
        let part = Partitioning::cxr(4, 2, 2, 2);
        let lv = vec![0, 2, 0, 2];
        let pair = default_pair_classes(3);
        let cm = ClassMap::from_levels(&part, lv.clone(), lv, &pair);
        assert_eq!(cm.n_classes, 2);
        assert_eq!(cm.class_sizes(), vec![2, 2]);
    }

    #[test]
    fn from_matrices_orders_by_actual_norm() {
        let mut rng = Pcg64::seed_from(5);
        let part = Partitioning::rxc(3, 3, 4, 6, 4);
        // build A with row blocks of wildly different scales, shuffled
        let scales_a = [0.1, 10.0, 1.0];
        let blocks_a: Vec<Matrix> = scales_a
            .iter()
            .map(|&s| Matrix::randn(4, 6, 0.0, s, &mut rng))
            .collect();
        let a = Matrix::vconcat(&blocks_a.iter().collect::<Vec<_>>());
        let scales_b = [1.0, 0.1, 10.0];
        let blocks_b: Vec<Matrix> = scales_b
            .iter()
            .map(|&s| Matrix::randn(6, 4, 0.0, s, &mut rng))
            .collect();
        let b = Matrix::hconcat(&blocks_b.iter().collect::<Vec<_>>());
        let cm = ClassMap::from_matrices(&part, &a, &b, 3);
        assert_eq!(cm.a_level, vec![2, 0, 1]);
        assert_eq!(cm.b_level, vec![1, 2, 0]);
        // highest-importance product = A_1·B_2 = unknown 1*3+2 = 5
        assert_eq!(cm.class_of[5], 0);
    }

    #[test]
    fn ew_windows_are_nested() {
        let part = Partitioning::rxc(3, 3, 1, 1, 1);
        let pair = default_pair_classes(3);
        let cm = ClassMap::from_levels(&part, vec![0, 1, 2], vec![0, 1, 2], &pair);
        let w0 = cm.window_leq(0);
        let w1 = cm.window_leq(1);
        let w2 = cm.window_leq(2);
        assert!(w0.iter().all(|i| w1.contains(i)));
        assert!(w1.iter().all(|i| w2.contains(i)));
        assert_eq!(w2.len(), 9);
    }
}
