//! The two block-partitioning paradigms and the `Ĉ` assembly logic.

use crate::linalg::{matmul, Matrix};

/// Which partitioning paradigm (paper Figs. 3 and 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Paradigm {
    /// Row-times-column (eq. 3): `C_np = A_n B_p` tiles `C`.
    RowTimesCol,
    /// Column-times-row (eq. 4): `C = Σ_m A_m B_m` (outer-product form).
    ColTimesRow,
}

impl Paradigm {
    pub fn short(&self) -> &'static str {
        match self {
            Paradigm::RowTimesCol => "rxc",
            Paradigm::ColTimesRow => "cxr",
        }
    }
}

/// A concrete partitioning of a product `C = A·B`.
///
/// For `RowTimesCol`, `A: (N·U)×H`, `B: H×(P·Q)` and there are `N·P`
/// sub-products of shape `U×Q` (unknown index `n·P + p`).
/// For `ColTimesRow`, `A: U×(M·H)`, `B: (M·H)×Q` and there are `M`
/// sub-products, each of the full shape `U×Q`.
#[derive(Clone, Debug)]
pub struct Partitioning {
    pub paradigm: Paradigm,
    /// Row blocks of A (r×c) — 1 for c×r.
    pub n: usize,
    /// Column blocks of B (r×c) — 1 for c×r.
    pub p: usize,
    /// Column/row blocks of A/B (c×r) — 1 for r×c.
    pub m: usize,
    /// Sub-block rows of each A block.
    pub u: usize,
    /// Shared inner dimension of each sub-product.
    pub h: usize,
    /// Sub-block columns of each B block.
    pub q: usize,
}

impl Partitioning {
    /// Row-times-column with `n`/`p` row/column blocks of size `u×h` / `h×q`.
    pub fn rxc(n: usize, p: usize, u: usize, h: usize, q: usize) -> Self {
        Partitioning { paradigm: Paradigm::RowTimesCol, n, p, m: 1, u, h, q }
    }

    /// Column-times-row with `m` column/row blocks of size `u×h` / `h×q`.
    pub fn cxr(m: usize, u: usize, h: usize, q: usize) -> Self {
        Partitioning { paradigm: Paradigm::ColTimesRow, n: 1, p: 1, m, u, h, q }
    }

    /// Total number of sub-products (unknowns): `N·P` or `M`.
    pub fn num_products(&self) -> usize {
        match self.paradigm {
            Paradigm::RowTimesCol => self.n * self.p,
            Paradigm::ColTimesRow => self.m,
        }
    }

    /// Shape of `A`: rows × cols.
    pub fn a_shape(&self) -> (usize, usize) {
        match self.paradigm {
            Paradigm::RowTimesCol => (self.n * self.u, self.h),
            Paradigm::ColTimesRow => (self.u, self.m * self.h),
        }
    }

    /// Shape of `B`.
    pub fn b_shape(&self) -> (usize, usize) {
        match self.paradigm {
            Paradigm::RowTimesCol => (self.h, self.p * self.q),
            Paradigm::ColTimesRow => (self.m * self.h, self.q),
        }
    }

    /// Shape of `C`.
    pub fn c_shape(&self) -> (usize, usize) {
        match self.paradigm {
            Paradigm::RowTimesCol => (self.n * self.u, self.p * self.q),
            Paradigm::ColTimesRow => (self.u, self.q),
        }
    }

    /// Number of factor blocks on the A side (`N` or `M`).
    pub fn num_a_blocks(&self) -> usize {
        match self.paradigm {
            Paradigm::RowTimesCol => self.n,
            Paradigm::ColTimesRow => self.m,
        }
    }

    /// Number of factor blocks on the B side (`P` or `M`).
    pub fn num_b_blocks(&self) -> usize {
        match self.paradigm {
            Paradigm::RowTimesCol => self.p,
            Paradigm::ColTimesRow => self.m,
        }
    }

    /// Split `A` into its factor blocks (each `U×H`).
    pub fn split_a(&self, a: &Matrix) -> Vec<Matrix> {
        assert_eq!(a.shape(), self.a_shape(), "A shape mismatch");
        match self.paradigm {
            Paradigm::RowTimesCol => a.split_rows(self.n),
            Paradigm::ColTimesRow => a.split_cols(self.m),
        }
    }

    /// Split `B` into its factor blocks (each `H×Q`).
    pub fn split_b(&self, b: &Matrix) -> Vec<Matrix> {
        assert_eq!(b.shape(), self.b_shape(), "B shape mismatch");
        match self.paradigm {
            Paradigm::RowTimesCol => b.split_cols(self.p),
            Paradigm::ColTimesRow => b.split_rows(self.m),
        }
    }

    /// Factor-block indices `(a_idx, b_idx)` of sub-product `idx`.
    pub fn factors_of(&self, idx: usize) -> (usize, usize) {
        match self.paradigm {
            Paradigm::RowTimesCol => (idx / self.p, idx % self.p),
            Paradigm::ColTimesRow => (idx, idx),
        }
    }

    /// Unknown index of the pair `(a_idx, b_idx)`; `None` if that pair is
    /// not a sub-product of `C` (off-diagonal pairs in c×r).
    pub fn product_of(&self, a_idx: usize, b_idx: usize) -> Option<usize> {
        match self.paradigm {
            Paradigm::RowTimesCol => Some(a_idx * self.p + b_idx),
            Paradigm::ColTimesRow => (a_idx == b_idx).then_some(a_idx),
        }
    }

    /// Compute all true sub-products `C_i` (reference path; the
    /// coordinator normally delegates the per-worker products to an
    /// execution engine).
    pub fn true_products(&self, a: &Matrix, b: &Matrix) -> Vec<Matrix> {
        let a_blocks = self.split_a(a);
        let b_blocks = self.split_b(b);
        (0..self.num_products())
            .map(|i| {
                let (ai, bi) = self.factors_of(i);
                matmul(&a_blocks[ai], &b_blocks[bi])
            })
            .collect()
    }

    /// Assemble `Ĉ` from recovered sub-products; missing blocks are zero
    /// (the paper's decoder, §IV-B).
    pub fn assemble(&self, recovered: &[Option<Matrix>]) -> Matrix {
        assert_eq!(recovered.len(), self.num_products());
        let (cr, cc) = self.c_shape();
        let mut c = Matrix::zeros(cr, cc);
        match self.paradigm {
            Paradigm::RowTimesCol => {
                for (idx, blk) in recovered.iter().enumerate() {
                    if let Some(blk) = blk {
                        let (n, p) = self.factors_of(idx);
                        assert_eq!(blk.shape(), (self.u, self.q));
                        c.set_block(n * self.u, p * self.q, blk);
                    }
                }
            }
            Paradigm::ColTimesRow => {
                for blk in recovered.iter().flatten() {
                    assert_eq!(blk.shape(), (self.u, self.q));
                    c.axpy(1.0, blk);
                }
            }
        }
        c
    }

    /// `‖C‖²_F`-weighted residual loss for a recovery subset: the exact
    /// loss `‖C − Ĉ‖²_F` computed from the sub-product Gram matrix
    /// `G_ij = ⟨C_i, C_j⟩_F` (cheap path for Monte-Carlo sweeps; for r×c
    /// `G` is diagonal because distinct sub-products occupy disjoint
    /// blocks of `C`).
    pub fn loss_from_gram(&self, gram: &Matrix, recovered: &[bool]) -> f64 {
        let k = self.num_products();
        assert_eq!(gram.shape(), (k, k));
        assert_eq!(recovered.len(), k);
        match self.paradigm {
            Paradigm::RowTimesCol => (0..k)
                .filter(|&i| !recovered[i])
                .map(|i| gram[(i, i)])
                .sum(),
            Paradigm::ColTimesRow => {
                let mut loss = 0.0;
                for i in 0..k {
                    if recovered[i] {
                        continue;
                    }
                    for j in 0..k {
                        if !recovered[j] {
                            loss += gram[(i, j)];
                        }
                    }
                }
                loss
            }
        }
    }

    /// Decrease of [`Self::loss_from_gram`] caused by unknown `u` flipping
    /// from missing to recovered — the incremental-sweep update: O(1) for
    /// r×c (diagonal Gram), O(K) for c×r, instead of an O(K²) recompute.
    /// `recovered` must already have `recovered[u] == true`.
    pub fn loss_delta_on_recover(&self, gram: &Matrix, recovered: &[bool], u: usize) -> f64 {
        debug_assert!(recovered[u], "mark the unknown recovered before the delta");
        match self.paradigm {
            Paradigm::RowTimesCol => gram[(u, u)],
            Paradigm::ColTimesRow => {
                // removing u from the unrecovered set U drops G_uu plus
                // both cross strips: Σ_{j∈U\{u}} (G_uj + G_ju) = 2·Σ G_uj
                let k = self.num_products();
                let mut delta = gram[(u, u)];
                for j in 0..k {
                    if !recovered[j] {
                        delta += 2.0 * gram[(u, j)];
                    }
                }
                delta
            }
        }
    }

    /// Gram matrix `G_ij = ⟨C_i, C_j⟩_F` of the true sub-products.
    pub fn gram(&self, products: &[Matrix]) -> Matrix {
        let k = products.len();
        let mut g = Matrix::zeros(k, k);
        for i in 0..k {
            for j in i..k {
                let dot: f64 = products[i]
                    .data()
                    .iter()
                    .zip(products[j].data().iter())
                    .map(|(x, y)| x * y)
                    .sum();
                g[(i, j)] = dot;
                g[(j, i)] = dot;
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn rxc_assembles_full_product() {
        let mut rng = Pcg64::seed_from(1);
        let part = Partitioning::rxc(3, 3, 4, 5, 6);
        let a = Matrix::randn(12, 5, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(5, 18, 0.0, 1.0, &mut rng);
        let prods = part.true_products(&a, &b);
        assert_eq!(prods.len(), 9);
        let c = part.assemble(&prods.iter().cloned().map(Some).collect::<Vec<_>>());
        assert!(c.allclose(&matmul(&a, &b), 1e-10));
    }

    #[test]
    fn cxr_assembles_full_product() {
        let mut rng = Pcg64::seed_from(2);
        let part = Partitioning::cxr(9, 7, 3, 8);
        let a = Matrix::randn(7, 27, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(27, 8, 0.0, 1.0, &mut rng);
        let prods = part.true_products(&a, &b);
        assert_eq!(prods.len(), 9);
        let c = part.assemble(&prods.iter().cloned().map(Some).collect::<Vec<_>>());
        assert!(c.allclose(&matmul(&a, &b), 1e-9));
    }

    #[test]
    fn missing_blocks_zeroed_rxc() {
        let mut rng = Pcg64::seed_from(3);
        let part = Partitioning::rxc(2, 2, 3, 4, 5);
        let a = Matrix::randn(6, 4, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(4, 10, 0.0, 1.0, &mut rng);
        let prods = part.true_products(&a, &b);
        let mut rec: Vec<Option<Matrix>> = prods.iter().cloned().map(Some).collect();
        rec[3] = None; // drop C_11
        let c = part.assemble(&rec);
        // the C_11 block must be zero
        let blk = c.block(3, 5, 3, 5);
        assert_eq!(blk.frob_sq(), 0.0);
        // the rest must match
        assert!(c.block(0, 0, 3, 5).allclose(&prods[0], 1e-12));
    }

    #[test]
    fn gram_loss_matches_direct_loss() {
        let mut rng = Pcg64::seed_from(4);
        for part in [Partitioning::rxc(3, 3, 4, 6, 5), Partitioning::cxr(6, 8, 4, 7)] {
            let (ar, ac) = part.a_shape();
            let (br, bc) = part.b_shape();
            let a = Matrix::randn(ar, ac, 0.0, 1.0, &mut rng);
            let b = Matrix::randn(br, bc, 0.0, 1.0, &mut rng);
            let prods = part.true_products(&a, &b);
            let gram = part.gram(&prods);
            let c_true = matmul(&a, &b);
            // random recovery subset
            let rec: Vec<bool> =
                (0..part.num_products()).map(|_| rng.bernoulli(0.5)).collect();
            let rec_mats: Vec<Option<Matrix>> = prods
                .iter()
                .zip(rec.iter())
                .map(|(p, &r)| if r { Some(p.clone()) } else { None })
                .collect();
            let c_hat = part.assemble(&rec_mats);
            let direct = c_true.frob_sq_diff(&c_hat);
            let fast = part.loss_from_gram(&gram, &rec);
            assert!(
                (direct - fast).abs() <= 1e-8 * (1.0 + direct.abs()),
                "{}: {direct} vs {fast}",
                part.paradigm.short()
            );
        }
    }

    #[test]
    fn loss_delta_tracks_full_recompute() {
        // Recover unknowns one by one in random order: the running sum of
        // deltas must agree with a fresh loss_from_gram at every step.
        let mut rng = Pcg64::seed_from(5);
        for part in [Partitioning::rxc(3, 3, 4, 6, 5), Partitioning::cxr(6, 8, 4, 7)] {
            let (ar, ac) = part.a_shape();
            let (br, bc) = part.b_shape();
            let a = Matrix::randn(ar, ac, 0.0, 1.0, &mut rng);
            let b = Matrix::randn(br, bc, 0.0, 1.0, &mut rng);
            let gram = part.gram(&part.true_products(&a, &b));
            let k = part.num_products();
            let mut order: Vec<usize> = (0..k).collect();
            crate::util::prop::gen::shuffle(&mut rng, &mut order);
            let mut mask = vec![false; k];
            let mut running = part.loss_from_gram(&gram, &mask);
            for &u in &order {
                mask[u] = true;
                running -= part.loss_delta_on_recover(&gram, &mask, u);
                let full = part.loss_from_gram(&gram, &mask);
                assert!(
                    (running - full).abs() <= 1e-9 * (1.0 + full.abs()),
                    "{}: running {running} vs full {full}",
                    part.paradigm.short()
                );
            }
            assert!(running.abs() < 1e-9);
        }
    }

    #[test]
    fn factor_maps_are_consistent() {
        let part = Partitioning::rxc(3, 4, 1, 1, 1);
        for idx in 0..12 {
            let (a, b) = part.factors_of(idx);
            assert_eq!(part.product_of(a, b), Some(idx));
        }
        let part = Partitioning::cxr(5, 1, 1, 1);
        assert_eq!(part.product_of(2, 2), Some(2));
        assert_eq!(part.product_of(2, 3), None);
    }
}
