//! Matrix partitioning (paper §II-A) and importance classification
//! (paper §IV-A).
//!
//! Two paradigms:
//! * **r×c** (row-times-column, eq. 3): `A` split into `N` row blocks,
//!   `B` into `P` column blocks; the `N·P` sub-products `C_np = A_n·B_p`
//!   tile `C`.
//! * **c×r** (column-times-row, eq. 4): `A` split into `M` column blocks,
//!   `B` into `M` row blocks; `C = Σ_m A_m·B_m` is a sum of `M` full-size
//!   terms.
//!
//! Sub-blocks are classified into `S` importance levels by Frobenius norm
//! (larger norm ⇒ more important ⇒ stronger protection), and each
//! sub-product inherits a class from the pair of factor classes via a
//! *pair table* (the paper's §VI example merges the `S(S+1)/2` pair levels
//! into `L` classes).

mod classify;
mod paradigm;

pub use classify::{classify_by_norm, default_pair_classes, ClassMap};
pub use paradigm::{Paradigm, Partitioning};
