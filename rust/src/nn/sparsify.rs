//! Threshold sparsification, paper eq. (34): entries with `|x| ≤ τ` are
//! zeroed. τ starts near machine precision and grows with the epoch, and
//! deeper layers get larger τ — this is what creates the norm variation
//! across sub-blocks that UEP coding exploits (§VII-B).

use crate::linalg::Matrix;

/// Apply `R(x) = x·1(|x| > τ)` in place; returns the number of zeroed
/// entries.
pub fn sparsify(m: &mut Matrix, tau: f64) -> usize {
    let mut zeroed = 0;
    for v in m.data_mut() {
        if v.abs() <= tau && *v != 0.0 {
            *v = 0.0;
            zeroed += 1;
        }
    }
    zeroed
}

/// Fraction of exactly-zero entries.
pub fn sparsity_of(m: &Matrix) -> f64 {
    let zeros = m.data().iter().filter(|&&v| v == 0.0).count();
    zeros as f64 / m.data().len().max(1) as f64
}

/// The τ schedule of §VII-B: per-layer base thresholds (deeper layers
/// sparser) growing geometrically with the epoch.
#[derive(Clone, Debug)]
pub struct TauSchedule {
    /// Base τ for gradients at epoch 0, per layer (index = depth).
    pub grad_base: Vec<f64>,
    /// Base τ for weights/inputs at epoch 0, per layer.
    pub weight_base: Vec<f64>,
    /// Multiplicative growth per epoch ("increased as training
    /// progresses").
    pub growth: f64,
}

impl TauSchedule {
    /// The paper's §VII-B choice: τ_grad = 1e-5, τ_weight/input = 1e-4,
    /// with deeper layers 2× sparser per depth step.
    pub fn paper(layers: usize) -> Self {
        TauSchedule {
            grad_base: (0..layers).map(|d| 1e-5 * 2f64.powi(d as i32)).collect(),
            weight_base: (0..layers).map(|d| 1e-4 * 2f64.powi(d as i32)).collect(),
            growth: 1.5,
        }
    }

    /// No sparsification (ablation).
    pub fn off(layers: usize) -> Self {
        TauSchedule {
            grad_base: vec![0.0; layers],
            weight_base: vec![0.0; layers],
            growth: 1.0,
        }
    }

    pub fn grad_tau(&self, layer: usize, epoch: usize) -> f64 {
        self.grad_base[layer] * self.growth.powi(epoch as i32)
    }

    pub fn weight_tau(&self, layer: usize, epoch: usize) -> f64 {
        self.weight_base[layer] * self.growth.powi(epoch as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn sparsify_zeroes_below_threshold() {
        let mut m = Matrix::from_vec(1, 4, vec![0.5, -0.001, 0.002, -2.0]);
        let z = sparsify(&mut m, 0.01);
        assert_eq!(z, 2);
        assert_eq!(m.data(), &[0.5, 0.0, 0.0, -2.0]);
        assert!((sparsity_of(&m) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn gaussian_matrix_sparsity_tracks_threshold() {
        let mut rng = Pcg64::seed_from(1);
        let mut m = Matrix::randn(200, 200, 0.0, 1.0, &mut rng);
        // P(|N(0,1)| ≤ 0.6745) = 0.5
        sparsify(&mut m, 0.6745);
        assert!((sparsity_of(&m) - 0.5).abs() < 0.02);
    }

    #[test]
    fn schedule_grows_with_epoch_and_depth() {
        let s = TauSchedule::paper(3);
        assert!(s.grad_tau(0, 0) < s.grad_tau(1, 0));
        assert!(s.grad_tau(0, 0) < s.grad_tau(0, 2));
        assert_eq!(TauSchedule::off(3).grad_tau(2, 5), 0.0);
    }
}
