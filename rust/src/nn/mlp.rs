//! The MNIST MLP of paper §VII-A (Fig. 12, Table VI): 784-100-200-10,
//! with the back-propagation matmuls routed through the distributed
//! coded engine. Mirrors `python/compile/model.py` exactly.

use crate::linalg::Matrix;
use crate::rng::Pcg64;

use super::dense::{relu, relu_backward, Dense};
use super::distributed::DistributedMatmul;
use super::loss::softmax_xent;
use super::sparsify::{sparsify, TauSchedule};

/// A multi-layer perceptron with ReLU hidden activations.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<Dense>,
}

/// Gradients of one training step.
pub struct MlpGrads {
    pub dv: Vec<Matrix>,
    pub db: Vec<Vec<f64>>,
}

impl Mlp {
    pub fn new(dims: &[usize], rng: &mut Pcg64) -> Self {
        assert!(dims.len() >= 2);
        Mlp {
            layers: (0..dims.len() - 1)
                .map(|i| Dense::init(dims[i], dims[i + 1], rng))
                .collect(),
        }
    }

    /// The paper's MNIST model (Table VI).
    pub fn mnist(rng: &mut Pcg64) -> Self {
        Mlp::new(&[784, 100, 200, 10], rng)
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Forward pass; returns `(logits, activations)` where
    /// `activations[i]` is `X_i`, the input of dense layer `i`.
    pub fn forward(&self, x: &Matrix) -> (Matrix, Vec<Matrix>) {
        let n = self.layers.len();
        let mut acts = Vec::with_capacity(n + 1);
        acts.push(x.clone());
        let mut h = x.clone();
        for (i, layer) in self.layers.iter().enumerate() {
            h = layer.forward(&h);
            if i + 1 < n {
                relu(&mut h);
            }
            acts.push(h.clone());
        }
        let logits = acts.last().unwrap().clone();
        (logits, acts)
    }

    /// Inference logits only.
    pub fn logits(&self, x: &Matrix) -> Matrix {
        self.forward(x).0
    }

    /// Manual back-propagation (paper eqs. 32–33) with sparsification
    /// (eq. 34) applied to the factors of every distributed product.
    ///
    /// * eq. (33): `V_i* = X_iᵀ · G_{i+1}` — through `engine`.
    /// * eq. (32): `G_i = G_{i+1} · V_iᵀ` — through `engine`, then masked
    ///   by the ReLU derivative.
    pub fn backward(
        &self,
        acts: &[Matrix],
        grad_logits: Matrix,
        engine: &mut DistributedMatmul,
        tau: &TauSchedule,
        epoch: usize,
    ) -> MlpGrads {
        let n = self.layers.len();
        let mut dv: Vec<Option<Matrix>> = vec![None; n];
        let mut db: Vec<Option<Vec<f64>>> = vec![None; n];
        let mut g = grad_logits; // G_{i+1}
        for i in (0..n).rev() {
            // sparsify the gradient factor (transient)
            sparsify(&mut g, tau.grad_tau(i, epoch));
            // sparsified copies of the weight/input factors (eq. 34 is
            // applied to the matrices being multiplied, §VII-B)
            let mut x_t = acts[i].transpose();
            sparsify(&mut x_t, tau.weight_tau(i, epoch));
            // eq. (33)
            dv[i] = Some(engine.multiply(&x_t, &g));
            db[i] = Some(Dense::bias_grad(&g));
            if i > 0 {
                let mut v_t = self.layers[i].v.transpose();
                sparsify(&mut v_t, tau.weight_tau(i, epoch));
                // eq. (32)
                let mut g_prev = engine.multiply(&g, &v_t);
                relu_backward(&mut g_prev, &acts[i]);
                g = g_prev;
            }
        }
        MlpGrads {
            dv: dv.into_iter().map(Option::unwrap).collect(),
            db: db.into_iter().map(Option::unwrap).collect(),
        }
    }

    /// One SGD training step; returns the batch loss.
    pub fn train_step(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        lr: f64,
        engine: &mut DistributedMatmul,
        tau: &TauSchedule,
        epoch: usize,
    ) -> f64 {
        let (logits, acts) = self.forward(x);
        let (loss, grad_logits) = softmax_xent(&logits, y);
        let grads = self.backward(&acts, grad_logits, engine, tau, epoch);
        for (i, layer) in self.layers.iter_mut().enumerate() {
            layer.apply_grads(&grads.dv[i], &grads.db[i], lr);
        }
        loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::distributed::MatmulStrategy;
    use crate::linalg::matmul;

    fn tiny() -> (Mlp, Matrix, Matrix) {
        let mut rng = Pcg64::seed_from(1);
        let mlp = Mlp::new(&[6, 5, 4, 3], &mut rng);
        let x = Matrix::randn(4, 6, 0.0, 1.0, &mut rng);
        let mut y = Matrix::zeros(4, 3);
        for r in 0..4 {
            y[(r, r % 3)] = 1.0;
        }
        (mlp, x, y)
    }

    /// The backward pass with Exact strategy and no sparsification must
    /// match finite differences of the loss wrt every weight sample.
    #[test]
    fn backward_matches_finite_difference() {
        let (mlp, x, y) = tiny();
        let tau = TauSchedule::off(3);
        let mut engine =
            DistributedMatmul::new(MatmulStrategy::Exact, Pcg64::seed_from(2));
        let (logits, acts) = mlp.forward(&x);
        let (_, g) = softmax_xent(&logits, &y);
        let grads = mlp.backward(&acts, g, &mut engine, &tau, 0);
        let loss_of = |m: &Mlp| {
            let (lg, _) = m.forward(&x);
            softmax_xent(&lg, &y).0
        };
        let eps = 1e-6;
        for li in 0..3 {
            for &(r, c) in &[(0usize, 0usize), (1, 2)] {
                let mut m2 = mlp.clone();
                m2.layers[li].v[(r, c)] += eps;
                let num = (loss_of(&m2) - loss_of(&mlp)) / eps;
                let ana = grads.dv[li][(r, c)];
                assert!(
                    (num - ana).abs() < 1e-4,
                    "layer {li} ({r},{c}): fd {num} vs analytic {ana}"
                );
            }
        }
    }

    #[test]
    fn training_reduces_loss_on_fixed_batch() {
        let (mut mlp, x, y) = tiny();
        let tau = TauSchedule::off(3);
        let mut engine =
            DistributedMatmul::new(MatmulStrategy::Exact, Pcg64::seed_from(3));
        let first = mlp.train_step(&x, &y, 0.5, &mut engine, &tau, 0);
        let mut last = first;
        for _ in 0..50 {
            last = mlp.train_step(&x, &y, 0.5, &mut engine, &tau, 0);
        }
        assert!(last < first * 0.5, "loss {first} -> {last}");
    }

    #[test]
    fn forward_matches_manual_composition() {
        let (mlp, x, _) = tiny();
        let (logits, acts) = mlp.forward(&x);
        // manual: layer 0
        let mut h = matmul(&x, &mlp.layers[0].v);
        for r in 0..h.rows() {
            for c in 0..h.cols() {
                h[(r, c)] += mlp.layers[0].b[c];
                if h[(r, c)] < 0.0 {
                    h[(r, c)] = 0.0;
                }
            }
        }
        assert!(acts[1].allclose(&h, 1e-12));
        assert_eq!(logits.shape(), (4, 3));
        assert_eq!(acts.len(), 4);
    }

    #[test]
    fn mnist_shapes_match_table_vi() {
        let mut rng = Pcg64::seed_from(4);
        let m = Mlp::mnist(&mut rng);
        assert_eq!(m.layers[0].v.shape(), (784, 100));
        assert_eq!(m.layers[1].v.shape(), (100, 200));
        assert_eq!(m.layers[2].v.shape(), (200, 10));
    }
}
