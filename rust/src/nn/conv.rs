//! Convolution substrate for the CIFAR experiment (paper Table V):
//! Conv2D (im2col + matmul), MaxPool2D, and the image tensor plumbing.
//! The paper computes convolutional layers centrally ("without
//! stragglers", §VII-C); only the dense layers are coded — but training
//! still needs full conv forward/backward, so it is built here.

use crate::linalg::{matmul, Matrix};
use crate::rng::{Normal, Pcg64, Sample};

/// A batch of images, NCHW, flattened row-major.
#[derive(Clone, Debug)]
pub struct ImageBatch {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<f64>,
}

impl ImageBatch {
    pub fn zeros(n: usize, c: usize, h: usize, w: usize) -> Self {
        ImageBatch { n, c, h, w, data: vec![0.0; n * c * h * w] }
    }

    #[inline]
    pub fn idx(&self, n: usize, c: usize, y: usize, x: usize) -> usize {
        ((n * self.c + c) * self.h + y) * self.w + x
    }

    pub fn at(&self, n: usize, c: usize, y: usize, x: usize) -> f64 {
        self.data[self.idx(n, c, y, x)]
    }

    /// Flatten to a `(N, C·H·W)` matrix (the Flatten layer).
    pub fn to_matrix(&self) -> Matrix {
        Matrix::from_vec(self.n, self.c * self.h * self.w, self.data.clone())
    }

    pub fn from_matrix(m: &Matrix, c: usize, h: usize, w: usize) -> Self {
        assert_eq!(m.cols(), c * h * w);
        ImageBatch { n: m.rows(), c, h, w, data: m.data().to_vec() }
    }
}

/// im2col: extract all `kh×kw` patches (stride 1) into a
/// `(N·OH·OW, C·kh·kw)` matrix; `pad` adds zero padding ("same" = k/2).
pub fn im2col(x: &ImageBatch, kh: usize, kw: usize, pad: usize) -> Matrix {
    let oh = x.h + 2 * pad - kh + 1;
    let ow = x.w + 2 * pad - kw + 1;
    let mut out = Matrix::zeros(x.n * oh * ow, x.c * kh * kw);
    for n in 0..x.n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (n * oh + oy) * ow + ox;
                let dst = out.row_mut(row);
                let mut col = 0;
                for c in 0..x.c {
                    for dy in 0..kh {
                        for dx in 0..kw {
                            let sy = oy + dy;
                            let sx = ox + dx;
                            let v = if sy < pad
                                || sx < pad
                                || sy - pad >= x.h
                                || sx - pad >= x.w
                            {
                                0.0
                            } else {
                                x.at(n, c, sy - pad, sx - pad)
                            };
                            dst[col] = v;
                            col += 1;
                        }
                    }
                }
            }
        }
    }
    out
}

/// col2im: scatter-add the patch matrix back to image space (the adjoint
/// of [`im2col`]) — used for the conv input gradient.
pub fn col2im(
    cols: &Matrix,
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    pad: usize,
) -> ImageBatch {
    let oh = h + 2 * pad - kh + 1;
    let ow = w + 2 * pad - kw + 1;
    assert_eq!(cols.rows(), n * oh * ow);
    assert_eq!(cols.cols(), c * kh * kw);
    let mut img = ImageBatch::zeros(n, c, h, w);
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (ni * oh + oy) * ow + ox;
                let src = cols.row(row);
                let mut col = 0;
                for ci in 0..c {
                    for dy in 0..kh {
                        for dx in 0..kw {
                            let sy = oy + dy;
                            let sx = ox + dx;
                            if sy >= pad && sx >= pad && sy - pad < h && sx - pad < w {
                                let idx = img.idx(ni, ci, sy - pad, sx - pad);
                                img.data[idx] += src[col];
                            }
                            col += 1;
                        }
                    }
                }
            }
        }
    }
    img
}

/// 2-D convolution, stride 1, ReLU fused by the caller.
#[derive(Clone, Debug)]
pub struct Conv2D {
    /// `(C_in·kh·kw, C_out)` weight matrix (im2col layout).
    pub w: Matrix,
    pub b: Vec<f64>,
    pub c_in: usize,
    pub c_out: usize,
    pub k: usize,
    /// Zero padding ("same" = k/2, "valid" = 0 — Table V uses both).
    pub pad: usize,
}

/// Cache from the forward pass needed by backward.
pub struct ConvCache {
    cols: Matrix,
    in_shape: (usize, usize, usize, usize),
    out_pre_relu: Matrix,
}

impl Conv2D {
    pub fn init(c_in: usize, c_out: usize, k: usize, pad: usize, rng: &mut Pcg64) -> Self {
        let fan_in = c_in * k * k;
        let dist = Normal::new(0.0, (2.0 / fan_in as f64).sqrt());
        Conv2D {
            w: Matrix::from_fn(fan_in, c_out, |_, _| dist.sample(rng)),
            b: vec![0.0; c_out],
            c_in,
            c_out,
            k,
            pad,
        }
    }

    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (h + 2 * self.pad - self.k + 1, w + 2 * self.pad - self.k + 1)
    }

    /// Forward with ReLU; returns output batch + cache.
    pub fn forward(&self, x: &ImageBatch) -> (ImageBatch, ConvCache) {
        assert_eq!(x.c, self.c_in);
        let (oh, ow) = self.out_hw(x.h, x.w);
        let cols = im2col(x, self.k, self.k, self.pad);
        let mut out = matmul(&cols, &self.w); // (N·OH·OW, C_out)
        for r in 0..out.rows() {
            for (v, bias) in out.row_mut(r).iter_mut().zip(self.b.iter()) {
                *v += bias;
            }
        }
        let pre = out.clone();
        for v in out.data_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        // reshape (N·OH·OW, C_out) -> NCHW
        let mut img = ImageBatch::zeros(x.n, self.c_out, oh, ow);
        for n in 0..x.n {
            for y in 0..oh {
                for xx in 0..ow {
                    let row = (n * oh + y) * ow + xx;
                    for c in 0..self.c_out {
                        let idx = img.idx(n, c, y, xx);
                        img.data[idx] = out[(row, c)];
                    }
                }
            }
        }
        (img, ConvCache { cols, in_shape: (x.n, x.c, x.h, x.w), out_pre_relu: pre })
    }

    /// Backward: given dL/d(output NCHW), returns (dW, db, dX).
    pub fn backward(&self, g: &ImageBatch, cache: &ConvCache) -> (Matrix, Vec<f64>, ImageBatch) {
        let (n, c_in, h, w) = cache.in_shape;
        let (oh, ow) = self.out_hw(h, w);
        assert_eq!((g.n, g.c, g.h, g.w), (n, self.c_out, oh, ow));
        // NCHW grad -> (N·OH·OW, C_out), masked by ReLU
        let mut gm = Matrix::zeros(n * oh * ow, self.c_out);
        for ni in 0..n {
            for y in 0..oh {
                for x in 0..ow {
                    let row = (ni * oh + y) * ow + x;
                    for c in 0..self.c_out {
                        let v = if cache.out_pre_relu[(row, c)] > 0.0 {
                            g.at(ni, c, y, x)
                        } else {
                            0.0
                        };
                        gm[(row, c)] = v;
                    }
                }
            }
        }
        let dw = matmul(&cache.cols.transpose(), &gm);
        let mut db = vec![0.0; self.c_out];
        for r in 0..gm.rows() {
            for (acc, &v) in db.iter_mut().zip(gm.row(r)) {
                *acc += v;
            }
        }
        let dcols = matmul(&gm, &self.w.transpose());
        let dx = col2im(&dcols, n, c_in, h, w, self.k, self.k, self.pad);
        (dw, db, dx)
    }

    pub fn apply_grads(&mut self, dw: &Matrix, db: &[f64], lr: f64) {
        self.w.axpy(-lr, dw);
        for (b, g) in self.b.iter_mut().zip(db.iter()) {
            *b -= lr * g;
        }
    }
}

/// 2×2 max pooling, stride 2.
#[derive(Clone, Copy, Debug, Default)]
pub struct MaxPool2D;

pub struct PoolCache {
    argmax: Vec<usize>,
    in_shape: (usize, usize, usize, usize),
}

impl MaxPool2D {
    pub fn forward(&self, x: &ImageBatch) -> (ImageBatch, PoolCache) {
        let (oh, ow) = (x.h / 2, x.w / 2);
        let mut out = ImageBatch::zeros(x.n, x.c, oh, ow);
        let mut argmax = vec![0usize; x.n * x.c * oh * ow];
        let mut oi = 0;
        for n in 0..x.n {
            for c in 0..x.c {
                for y in 0..oh {
                    for xx in 0..ow {
                        let mut best = f64::NEG_INFINITY;
                        let mut best_idx = 0;
                        for dy in 0..2 {
                            for dx in 0..2 {
                                let idx = x.idx(n, c, 2 * y + dy, 2 * xx + dx);
                                if x.data[idx] > best {
                                    best = x.data[idx];
                                    best_idx = idx;
                                }
                            }
                        }
                        let out_idx = out.idx(n, c, y, xx);
                        out.data[out_idx] = best;
                        argmax[oi] = best_idx;
                        oi += 1;
                    }
                }
            }
        }
        (out, PoolCache { argmax, in_shape: (x.n, x.c, x.h, x.w) })
    }

    pub fn backward(&self, g: &ImageBatch, cache: &PoolCache) -> ImageBatch {
        let (n, c, h, w) = cache.in_shape;
        let mut dx = ImageBatch::zeros(n, c, h, w);
        for (oi, &src) in cache.argmax.iter().enumerate() {
            dx.data[src] += g.data[oi];
        }
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_batch(n: usize, c: usize, h: usize, w: usize, rng: &mut Pcg64) -> ImageBatch {
        let mut b = ImageBatch::zeros(n, c, h, w);
        for v in b.data.iter_mut() {
            *v = Normal::standard(rng);
        }
        b
    }

    #[test]
    fn im2col_col2im_are_adjoint() {
        // <im2col(x), y> == <x, col2im(y)> — the defining adjoint identity.
        let mut rng = Pcg64::seed_from(1);
        let x = rand_batch(2, 3, 5, 5, &mut rng);
        let cols = im2col(&x, 3, 3, 1);
        let y = Matrix::randn(cols.rows(), cols.cols(), 0.0, 1.0, &mut rng);
        let lhs: f64 = cols.data().iter().zip(y.data()).map(|(a, b)| a * b).sum();
        let back = col2im(&y, 2, 3, 5, 5, 3, 3, 1);
        let rhs: f64 = x.data.iter().zip(back.data.iter()).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-8 * (1.0 + lhs.abs()));
    }

    #[test]
    fn conv_shapes_same_and_valid() {
        let mut rng = Pcg64::seed_from(2);
        let x = rand_batch(1, 3, 8, 8, &mut rng);
        let same = Conv2D::init(3, 4, 3, 1, &mut rng);
        let (o1, _) = same.forward(&x);
        assert_eq!((o1.c, o1.h, o1.w), (4, 8, 8));
        let valid = Conv2D::init(3, 4, 3, 0, &mut rng);
        let (o2, _) = valid.forward(&x);
        assert_eq!((o2.c, o2.h, o2.w), (4, 6, 6));
    }

    #[test]
    fn conv_gradient_matches_finite_difference() {
        let mut rng = Pcg64::seed_from(3);
        let x = rand_batch(1, 2, 4, 4, &mut rng);
        let conv = Conv2D::init(2, 3, 3, 1, &mut rng);
        let loss_of = |c: &Conv2D, xb: &ImageBatch| -> f64 {
            let (o, _) = c.forward(xb);
            o.data.iter().sum()
        };
        let (o, cache) = conv.forward(&x);
        let g = ImageBatch { data: vec![1.0; o.data.len()], ..o.clone() };
        let (dw, db, dx) = conv.backward(&g, &cache);
        let eps = 1e-6;
        for &(r, c) in &[(0usize, 0usize), (5, 2), (17, 1)] {
            let mut c2 = conv.clone();
            c2.w[(r, c)] += eps;
            let num = (loss_of(&c2, &x) - loss_of(&conv, &x)) / eps;
            assert!((num - dw[(r, c)]).abs() < 1e-4, "dW({r},{c}): {num} vs {}", dw[(r, c)]);
        }
        {
            let mut c2 = conv.clone();
            c2.b[1] += eps;
            let num = (loss_of(&c2, &x) - loss_of(&conv, &x)) / eps;
            assert!((num - db[1]).abs() < 1e-4);
        }
        for idx in [0usize, 7, 20] {
            let mut x2 = x.clone();
            x2.data[idx] += eps;
            let num = (loss_of(&conv, &x2) - loss_of(&conv, &x)) / eps;
            assert!((num - dx.data[idx]).abs() < 1e-4, "dX[{idx}]");
        }
    }

    #[test]
    fn maxpool_forward_and_backward() {
        let mut x = ImageBatch::zeros(1, 1, 4, 4);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = i as f64;
        }
        let pool = MaxPool2D;
        let (o, cache) = pool.forward(&x);
        assert_eq!((o.h, o.w), (2, 2));
        assert_eq!(o.data, vec![5.0, 7.0, 13.0, 15.0]);
        let g = ImageBatch { data: vec![1.0, 2.0, 3.0, 4.0], ..o.clone() };
        let dx = pool.backward(&g, &cache);
        assert_eq!(dx.data[5], 1.0);
        assert_eq!(dx.data[15], 4.0);
        assert_eq!(dx.data.iter().sum::<f64>(), 10.0);
    }
}
