//! DNN training substrate (paper §VII): dense and convolutional layers
//! with *manual* back-propagation written exactly as the paper's
//! eqs. (32)–(33), threshold sparsification (eq. 34), SGD, and the hook
//! that routes the two back-propagation matmuls of every dense layer
//! through the UEP-coded distributed multiplication engine.
//!
//! The layer math mirrors `python/compile/model.py` one-to-one; the
//! `mlp_step` AOT artifact is the compiled reference for the centralized
//! (no-straggler) path and the integration tests check the two against
//! each other.

mod cnn;
mod conv;
mod dense;
mod distributed;
mod loss;
mod mlp;
mod sparsify;
mod train;

pub use cnn::{Cnn, CnnArch};
pub use conv::{col2im, im2col, Conv2D, ImageBatch, MaxPool2D};
pub use dense::{relu, relu_backward, Dense};
pub use distributed::{
    ClusterMatmulCfg, CodedMatmulCfg, DistributedMatmul, MatmulStrategy,
    StraggleDrift,
};
pub use loss::{accuracy, softmax_xent};
pub use mlp::{Mlp, MlpGrads};
pub use sparsify::{sparsify, sparsity_of, TauSchedule};
pub use train::{evaluate, train_mlp, EpochPoint, TrainConfig, TrainRecord};
