//! Dense (fully connected) layer with the paper's back-propagation
//! factorization: forward `O = X·V + b` (eq. 31), backward
//! `G_i = G_{i+1}·V_iᵀ` (eq. 32) and `V_i* = X_iᵀ·G_{i+1}` (eq. 33).
//! The two backward matmuls are the products the PS distributes.

use crate::linalg::{matmul, Matrix};
use crate::rng::{Normal, Pcg64, Sample};

/// A dense layer `x ↦ x·V + b`.
#[derive(Clone, Debug)]
pub struct Dense {
    pub v: Matrix,
    pub b: Vec<f64>,
}

impl Dense {
    /// He-style initialization.
    pub fn init(fan_in: usize, fan_out: usize, rng: &mut Pcg64) -> Self {
        let sd = (2.0 / fan_in as f64).sqrt();
        let dist = Normal::new(0.0, sd);
        Dense {
            v: Matrix::from_fn(fan_in, fan_out, |_, _| dist.sample(rng)),
            b: vec![0.0; fan_out],
        }
    }

    pub fn fan_in(&self) -> usize {
        self.v.rows()
    }

    pub fn fan_out(&self) -> usize {
        self.v.cols()
    }

    /// Forward: `X·V + b` (eq. 31).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        let mut o = matmul(x, &self.v);
        for r in 0..o.rows() {
            let row = o.row_mut(r);
            for (val, bias) in row.iter_mut().zip(self.b.iter()) {
                *val += bias;
            }
        }
        o
    }

    /// Bias gradient: column sums of the output gradient.
    pub fn bias_grad(g_out: &Matrix) -> Vec<f64> {
        let mut db = vec![0.0; g_out.cols()];
        for r in 0..g_out.rows() {
            for (acc, &v) in db.iter_mut().zip(g_out.row(r).iter()) {
                *acc += v;
            }
        }
        db
    }

    /// SGD update.
    pub fn apply_grads(&mut self, dv: &Matrix, db: &[f64], lr: f64) {
        self.v.axpy(-lr, dv);
        for (b, g) in self.b.iter_mut().zip(db.iter()) {
            *b -= lr * g;
        }
    }
}

/// ReLU forward, in place.
pub fn relu(x: &mut Matrix) {
    for v in x.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// ReLU backward: zero the gradient where the activation input was ≤ 0.
pub fn relu_backward(g: &mut Matrix, pre_activation_output: &Matrix) {
    assert_eq!(g.shape(), pre_activation_output.shape());
    for (gv, &av) in g.data_mut().iter_mut().zip(pre_activation_output.data()) {
        if av <= 0.0 {
            *gv = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_adds_bias() {
        let mut d = Dense {
            v: Matrix::eye(2),
            b: vec![1.0, -1.0],
        };
        let x = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        let o = d.forward(&x);
        assert_eq!(o.data(), &[4.0, 3.0]);
        d.apply_grads(&Matrix::zeros(2, 2), &[1.0, 0.0], 0.5);
        assert_eq!(d.b, vec![0.5, -1.0]);
    }

    #[test]
    fn bias_grad_sums_rows() {
        let g = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(Dense::bias_grad(&g), vec![4.0, 6.0]);
    }

    #[test]
    fn relu_and_backward() {
        let mut x = Matrix::from_vec(1, 4, vec![-1.0, 0.0, 2.0, -3.0]);
        let pre = x.clone();
        relu(&mut x);
        assert_eq!(x.data(), &[0.0, 0.0, 2.0, 0.0]);
        let mut g = Matrix::from_vec(1, 4, vec![1.0; 4]);
        relu_backward(&mut g, &pre);
        assert_eq!(g.data(), &[0.0, 0.0, 1.0, 0.0]);
    }

    /// Finite-difference check of the dense backward formulas (32)/(33).
    #[test]
    fn gradients_match_finite_difference() {
        let mut rng = Pcg64::seed_from(5);
        let d = Dense::init(4, 3, &mut rng);
        let x = Matrix::randn(2, 4, 0.0, 1.0, &mut rng);
        // scalar objective: sum of outputs
        let f = |layer: &Dense| layer.forward(&x).data().iter().sum::<f64>();
        // analytic: dL/dV = Xᵀ · G with G = ones
        let g = Matrix::from_fn(2, 3, |_, _| 1.0);
        let dv = matmul(&x.transpose(), &g);
        let eps = 1e-6;
        for (r, c) in [(0, 0), (1, 2), (3, 1)] {
            let mut dp = d.clone();
            dp.v[(r, c)] += eps;
            let num = (f(&dp) - f(&d)) / eps;
            assert!((num - dv[(r, c)]).abs() < 1e-4, "({r},{c}): {num} vs {}", dv[(r, c)]);
        }
        // input gradient: dL/dX = G · Vᵀ (eq. 32)
        let dx = matmul(&g, &d.v.transpose());
        let fx = |xm: &Matrix| d.forward(xm).data().iter().sum::<f64>();
        for (r, c) in [(0, 0), (1, 3)] {
            let mut xp = x.clone();
            xp[(r, c)] += eps;
            let num = (fx(&xp) - fx(&x)) / eps;
            assert!((num - dx[(r, c)]).abs() < 1e-4);
        }
    }
}
