//! The distributed approximate matmul hook: where the paper's system
//! meets the training loop. Every call runs one coded multiplication
//! round through the unified client API — partition, classify by norm,
//! encode, sample worker arrivals, decode what beat the deadline,
//! assemble with zeros elsewhere — and returns the approximation `Ĉ`
//! the optimizer actually consumes.
//!
//! The round is served by an [`InProcessBackend`] in
//! [`Compute::Selective`] mode: the decode runs coefficient-only and
//! then exactly the *recovered* sub-products are computed, so training
//! never pays for materializing `W_A`/`W_B` or for sub-products the
//! deadline discarded. Caching is off — the weights matrix changes
//! every step, so no two requests could share an encoding anyway.
//!
//! Operand dimensions rarely divide the block counts, so operands are
//! zero-padded up to the next multiple (zero rows/columns contribute
//! nothing to the product) and the result is cropped back.

use crate::api::{
    Compute, InProcessBackend, OmegaMode, Request, Session,
};
use crate::coding::CodeSpec;
use crate::latency::LatencyModel;
use crate::linalg::{matmul, Matrix};
use crate::partition::{Paradigm, Partitioning};
use crate::rng::Pcg64;

/// How a training-loop matmul is executed.
#[derive(Clone, Debug)]
pub enum MatmulStrategy {
    /// Centralized, no stragglers (the red reference curve).
    Exact,
    /// Distributed with coding and a deadline.
    Coded(CodedMatmulCfg),
}

/// Configuration of one coded multiplication round (Table VII).
#[derive(Clone, Debug)]
pub struct CodedMatmulCfg {
    pub paradigm: Paradigm,
    /// Row/col blocks per side for r×c (N = P = `blocks`), or the number
    /// of inner blocks M for c×r (`blocks`² blocks? no — M = `blocks`²
    /// is *not* implied; M = `blocks_cxr`). For the paper's setup:
    /// r×c: blocks = 3 (9 sub-products); c×r: blocks = 9.
    pub blocks: usize,
    pub spec: CodeSpec,
    pub workers: usize,
    pub latency: LatencyModel,
    /// Ω = #sub-products / workers (Remark 1), recomputed per call from
    /// the actual sub-product count when `auto_omega` is set.
    pub auto_omega: bool,
    pub t_max: f64,
    /// Importance levels S for norm classification.
    pub s_levels: usize,
}

impl CodedMatmulCfg {
    pub fn num_products(&self) -> usize {
        match self.paradigm {
            Paradigm::RowTimesCol => self.blocks * self.blocks,
            Paradigm::ColTimesRow => self.blocks,
        }
    }
}

/// Stateful distributed matmul executor (owns the RNG stream so training
/// runs are reproducible).
pub struct DistributedMatmul {
    pub strategy: MatmulStrategy,
    pub rng: Pcg64,
    /// Cumulative stats: products attempted / recovered.
    pub total_products: usize,
    pub total_recovered: usize,
}

impl DistributedMatmul {
    pub fn new(strategy: MatmulStrategy, rng: Pcg64) -> Self {
        DistributedMatmul { strategy, rng, total_products: 0, total_recovered: 0 }
    }

    /// Compute (an approximation of) `A·B`.
    pub fn multiply(&mut self, a: &Matrix, b: &Matrix) -> Matrix {
        match &self.strategy {
            MatmulStrategy::Exact => matmul(a, b),
            MatmulStrategy::Coded(cfg) => {
                let cfg = cfg.clone();
                self.multiply_coded(a, b, &cfg)
            }
        }
    }

    /// Fraction of sub-products recovered so far (diagnostics).
    pub fn recovery_rate(&self) -> f64 {
        if self.total_products == 0 {
            1.0
        } else {
            self.total_recovered as f64 / self.total_products as f64
        }
    }

    fn multiply_coded(&mut self, a: &Matrix, b: &Matrix, cfg: &CodedMatmulCfg) -> Matrix {
        assert_eq!(a.cols(), b.rows());
        let (orig_m, orig_n) = (a.rows(), b.cols());
        // --- pad to block-divisible shapes --------------------------------
        let (a_pad, b_pad, part) = match cfg.paradigm {
            Paradigm::RowTimesCol => {
                let nb = cfg.blocks;
                let m_pad = round_up(a.rows(), nb);
                let n_pad = round_up(b.cols(), nb);
                let a_pad = pad_to(a, m_pad, a.cols());
                let b_pad = pad_to(b, b.rows(), n_pad);
                let part =
                    Partitioning::rxc(nb, nb, m_pad / nb, a.cols(), n_pad / nb);
                (a_pad, b_pad, part)
            }
            Paradigm::ColTimesRow => {
                let mb = cfg.blocks;
                let k_pad = round_up(a.cols(), mb);
                let a_pad = pad_to(a, a.rows(), k_pad);
                let b_pad = pad_to(b, k_pad, b.cols());
                let part = Partitioning::cxr(mb, a.rows(), k_pad / mb, b.cols());
                (a_pad, b_pad, part)
            }
        };
        // --- classify, encode, decode, assemble: one API round ------------
        let num_products = part.num_products();
        let mut session = Session::builder()
            .partitioning(part)
            .code(cfg.spec.clone())
            .auto_classes(cfg.s_levels)
            .workers(cfg.workers)
            .latency(cfg.latency.clone())
            .omega(if cfg.auto_omega {
                OmegaMode::Auto
            } else {
                OmegaMode::Fixed(1.0)
            })
            .deadline(cfg.t_max)
            .compute(Compute::Selective)
            .cache_capacity(0)
            .seed(self.rng.next_u64())
            .backend(InProcessBackend::serial())
            .build()
            .expect("coded-matmul session config is validated by construction");
        let report = session
            .run(Request::new(0, a_pad, b_pad))
            .expect("in-process selective round cannot fail");
        self.total_products += num_products;
        self.total_recovered += report.outcome.recovered;
        report.outcome.c_hat.block(0, 0, orig_m, orig_n)
    }
}

fn round_up(x: usize, to: usize) -> usize {
    x.div_ceil(to) * to
}

fn pad_to(m: &Matrix, rows: usize, cols: usize) -> Matrix {
    if m.shape() == (rows, cols) {
        return m.clone();
    }
    let mut out = Matrix::zeros(rows, cols);
    out.set_block(0, 0, m);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodeKind, EncodeStyle, WindowPolynomial};

    fn cfg(paradigm: Paradigm, blocks: usize, t_max: f64) -> CodedMatmulCfg {
        CodedMatmulCfg {
            paradigm,
            blocks,
            spec: CodeSpec::new(
                CodeKind::EwUep(WindowPolynomial::paper_table3()),
                EncodeStyle::Stacked,
            ),
            workers: 15,
            latency: LatencyModel::exp(0.5),
            auto_omega: true,
            t_max,
            s_levels: 3,
        }
    }

    #[test]
    fn generous_deadline_gives_exact_product() {
        let mut rng = Pcg64::seed_from(1);
        // Table VI shape: (64×100)·(100×784) — indivisible by 3, padded.
        let a = Matrix::randn(64, 100, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(100, 784, 0.0, 1.0, &mut rng);
        for paradigm in [Paradigm::RowTimesCol, Paradigm::ColTimesRow] {
            let blocks = if paradigm == Paradigm::RowTimesCol { 3 } else { 9 };
            let mut dm = DistributedMatmul::new(
                MatmulStrategy::Coded(cfg(paradigm, blocks, 1e6)),
                Pcg64::seed_from(2),
            );
            let got = dm.multiply(&a, &b);
            assert_eq!(got.shape(), (64, 784));
            assert!(got.allclose(&matmul(&a, &b), 1e-9), "{paradigm:?}");
            assert!((dm.recovery_rate() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_deadline_gives_zero_matrix() {
        let mut rng = Pcg64::seed_from(3);
        let a = Matrix::randn(10, 9, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(9, 10, 0.0, 1.0, &mut rng);
        let mut dm = DistributedMatmul::new(
            MatmulStrategy::Coded(cfg(Paradigm::ColTimesRow, 9, 0.0)),
            Pcg64::seed_from(4),
        );
        let got = dm.multiply(&a, &b);
        assert_eq!(got.frob_sq(), 0.0);
        assert_eq!(dm.recovery_rate(), 0.0);
    }

    #[test]
    fn partial_deadline_recovers_blocks_exactly() {
        // Whatever the coded path recovers must match the true product on
        // those blocks (r×c: block-exact or zero).
        let mut rng = Pcg64::seed_from(5);
        let a = Matrix::randn(12, 8, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(8, 12, 0.0, 1.0, &mut rng);
        let mut dm = DistributedMatmul::new(
            MatmulStrategy::Coded(cfg(Paradigm::RowTimesCol, 3, 1.2)),
            Pcg64::seed_from(6),
        );
        let got = dm.multiply(&a, &b);
        let truth = matmul(&a, &b);
        for bi in 0..3 {
            for bj in 0..3 {
                let gb = got.block(bi * 4, bj * 4, 4, 4);
                let tb = truth.block(bi * 4, bj * 4, 4, 4);
                let zero = gb.frob_sq() == 0.0;
                assert!(
                    zero || gb.allclose(&tb, 1e-9),
                    "block ({bi},{bj}) neither zero nor exact"
                );
            }
        }
    }

    #[test]
    fn exact_strategy_is_exact() {
        let mut rng = Pcg64::seed_from(7);
        let a = Matrix::randn(5, 6, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(6, 4, 0.0, 1.0, &mut rng);
        let mut dm = DistributedMatmul::new(MatmulStrategy::Exact, Pcg64::seed_from(8));
        assert!(dm.multiply(&a, &b).allclose(&matmul(&a, &b), 1e-12));
    }
}
