//! The distributed approximate matmul hook: where the paper's system
//! meets the training loop. Every call runs one coded multiplication
//! round through the unified client API — partition, classify by norm,
//! encode, sample worker arrivals, decode what beat the deadline,
//! assemble with zeros elsewhere — and returns the approximation `Ĉ`
//! the optimizer actually consumes.
//!
//! The round is served by an [`InProcessBackend`] in
//! [`Compute::Selective`] mode: the decode runs coefficient-only and
//! then exactly the *recovered* sub-products are computed, so training
//! never pays for materializing `W_A`/`W_B` or for sub-products the
//! deadline discarded. Caching is off — the weights matrix changes
//! every step, so no two requests could share an encoding anyway.
//!
//! Operand dimensions rarely divide the block counts, so operands are
//! zero-padded up to the next multiple (zero rows/columns contribute
//! nothing to the product) and the result is cropped back.

use crate::api::{
    Backend, Compute, InProcessBackend, OmegaMode, ReplanPolicy, Request,
    Session, SharedBackend,
};
use crate::coding::CodeSpec;
use crate::latency::LatencyModel;
use crate::linalg::{matmul, Matrix};
use crate::partition::{Paradigm, Partitioning};
use crate::rng::Pcg64;

/// How a training-loop matmul is executed.
#[derive(Clone, Debug)]
pub enum MatmulStrategy {
    /// Centralized, no stragglers (the red reference curve).
    Exact,
    /// Distributed with coding and a deadline, simulated in process.
    Coded(CodedMatmulCfg),
    /// Distributed through a real [`crate::api::ClusterBackend`] fleet
    /// (loopback threads or TCP workers), with coding, a deadline, and
    /// optionally adaptive replanning + heterogeneity-aware assignment.
    Cluster(ClusterMatmulCfg),
}

/// Configuration of one coded multiplication round (Table VII).
#[derive(Clone, Debug)]
pub struct CodedMatmulCfg {
    pub paradigm: Paradigm,
    /// Row/col blocks per side for r×c (N = P = `blocks`), or the number
    /// of inner blocks M for c×r (`blocks`² blocks? no — M = `blocks`²
    /// is *not* implied; M = `blocks_cxr`). For the paper's setup:
    /// r×c: blocks = 3 (9 sub-products); c×r: blocks = 9.
    pub blocks: usize,
    pub spec: CodeSpec,
    pub workers: usize,
    pub latency: LatencyModel,
    /// Ω = #sub-products / workers (Remark 1), recomputed per call from
    /// the actual sub-product count when `auto_omega` is set.
    pub auto_omega: bool,
    pub t_max: f64,
    /// Importance levels S for norm classification.
    pub s_levels: usize,
}

impl CodedMatmulCfg {
    pub fn num_products(&self) -> usize {
        match self.paradigm {
            Paradigm::RowTimesCol => self.blocks * self.blocks,
            Paradigm::ColTimesRow => self.blocks,
        }
    }
}

/// A deterministic straggle schedule for cluster training runs: every
/// `rounds_per_phase` cluster rounds the fleet's injected-delay
/// multipliers advance to the next entry of `phases` (wrapping), via
/// [`crate::api::Backend::inject_straggle`]. Entries are
/// `(worker registry id, multiplier)` — loopback fleets number workers
/// `1..=threads`. An empty `phases` list injects nothing.
#[derive(Clone, Debug)]
pub struct StraggleDrift {
    /// Cluster rounds served per phase before advancing (min 1).
    pub rounds_per_phase: usize,
    /// The cycle of per-worker multiplier maps.
    pub phases: Vec<Vec<(u64, f64)>>,
}

/// Configuration of the cluster-served training matmul.
///
/// One training step multiplies several distinct shapes (forward and
/// backward per layer); each padded shape gets its own persistent
/// [`Session`] — so replanner/estimator state accumulates across steps
/// instead of resetting per call — and all sessions ride the one
/// [`SharedBackend`] fleet. Injected per-slot delays come from a
/// dedicated seeded stream (`delay_seed`), so the decode is virtual-time
/// deterministic regardless of fleet size or wall-clock races.
#[derive(Clone, Debug)]
pub struct ClusterMatmulCfg {
    /// The coding/deadline setup, shared with the in-process path.
    pub coded: CodedMatmulCfg,
    /// The shared fleet handle every per-shape session clones.
    pub backend: SharedBackend,
    /// Straggle-adaptive replanning (UEP codes only); on the replanner
    /// cadence the fitted per-worker scales are also pushed down to the
    /// backend, where [`crate::cluster::ClusterConfig::hetero_assign`]
    /// plans unequal work from them.
    pub adaptive: Option<ReplanPolicy>,
    /// Seed of the injected-delay stream (disjoint from the session
    /// RNGs).
    pub delay_seed: u64,
    /// Optional drifting heterogeneity injected into the fleet.
    pub drift: Option<StraggleDrift>,
}

/// Per-shape session cache key: the padded `(m, k, n)` of the operand
/// pair (a `Vec` keyed by value — a training loop touches a handful of
/// shapes, and iteration order never affects results).
type ShapeKey = (usize, usize, usize);

/// Stateful distributed matmul executor (owns the RNG stream so training
/// runs are reproducible).
pub struct DistributedMatmul {
    pub strategy: MatmulStrategy,
    pub rng: Pcg64,
    /// Cumulative stats: products attempted / recovered.
    pub total_products: usize,
    pub total_recovered: usize,
    /// Cumulative *virtual* compute time of cluster rounds: per round,
    /// the slowest absorbed result's reported delay capped at `T_max`
    /// (a round that produced nothing in time still waited out the
    /// deadline). Always 0.0 for the exact and in-process strategies.
    pub total_virtual_time: f64,
    /// Persistent per-padded-shape sessions (cluster strategy only).
    sessions: Vec<(ShapeKey, Session)>,
    /// Injected-delay stream for cluster rounds.
    delay_rng: Pcg64,
    /// Cluster rounds served (drives [`StraggleDrift`] phases).
    rounds: usize,
    /// Last drift phase installed on the backend.
    last_phase: Option<usize>,
}

impl DistributedMatmul {
    pub fn new(strategy: MatmulStrategy, rng: Pcg64) -> Self {
        let delay_rng = match &strategy {
            MatmulStrategy::Cluster(cfg) => Pcg64::seed_from(cfg.delay_seed),
            _ => Pcg64::seed_from(0),
        };
        DistributedMatmul {
            strategy,
            rng,
            total_products: 0,
            total_recovered: 0,
            total_virtual_time: 0.0,
            sessions: Vec::new(),
            delay_rng,
            rounds: 0,
            last_phase: None,
        }
    }

    /// Compute (an approximation of) `A·B`.
    pub fn multiply(&mut self, a: &Matrix, b: &Matrix) -> Matrix {
        match &self.strategy {
            MatmulStrategy::Exact => matmul(a, b),
            MatmulStrategy::Coded(cfg) => {
                let cfg = cfg.clone();
                self.multiply_coded(a, b, &cfg)
            }
            MatmulStrategy::Cluster(cfg) => {
                let cfg = cfg.clone();
                self.multiply_cluster(a, b, &cfg)
            }
        }
    }

    /// Fraction of sub-products recovered so far (diagnostics).
    pub fn recovery_rate(&self) -> f64 {
        if self.total_products == 0 {
            1.0
        } else {
            self.total_recovered as f64 / self.total_products as f64
        }
    }

    fn multiply_coded(&mut self, a: &Matrix, b: &Matrix, cfg: &CodedMatmulCfg) -> Matrix {
        assert_eq!(a.cols(), b.rows());
        let (orig_m, orig_n) = (a.rows(), b.cols());
        let (a_pad, b_pad, part) = pad_and_partition(a, b, cfg);
        // --- classify, encode, decode, assemble: one API round ------------
        let num_products = part.num_products();
        let mut session = Session::builder()
            .partitioning(part)
            .code(cfg.spec.clone())
            .auto_classes(cfg.s_levels)
            .workers(cfg.workers)
            .latency(cfg.latency.clone())
            .omega(if cfg.auto_omega {
                OmegaMode::Auto
            } else {
                OmegaMode::Fixed(1.0)
            })
            .deadline(cfg.t_max)
            .compute(Compute::Selective)
            .cache_capacity(0)
            .seed(self.rng.next_u64())
            .backend(InProcessBackend::serial())
            .build()
            .expect("coded-matmul session config is validated by construction");
        let report = session
            .run(Request::new(0, a_pad, b_pad))
            .expect("in-process selective round cannot fail");
        self.total_products += num_products;
        self.total_recovered += report.outcome.recovered;
        report.outcome.c_hat.block(0, 0, orig_m, orig_n)
    }

    /// One training matmul served by the shared cluster fleet. Virtual
    /// time accounting: the round costs the slowest absorbed result's
    /// delay, capped at (and defaulting to) `T_max`.
    fn multiply_cluster(
        &mut self,
        a: &Matrix,
        b: &Matrix,
        cfg: &ClusterMatmulCfg,
    ) -> Matrix {
        assert_eq!(a.cols(), b.rows());
        let (orig_m, orig_n) = (a.rows(), b.cols());
        let (a_pad, b_pad, part) = pad_and_partition(a, b, &cfg.coded);
        let key: ShapeKey = (a_pad.rows(), a_pad.cols(), b_pad.cols());
        let num_products = part.num_products();

        // drifting heterogeneity: install this round's phase before
        // dispatch (a no-op between phase boundaries)
        if let Some(drift) = &cfg.drift {
            if !drift.phases.is_empty() {
                let phase = (self.rounds / drift.rounds_per_phase.max(1))
                    % drift.phases.len();
                if self.last_phase != Some(phase) {
                    let mut handle = cfg.backend.clone();
                    handle
                        .inject_straggle(&drift.phases[phase])
                        .expect("straggle injection is infallible locally");
                    self.last_phase = Some(phase);
                }
            }
        }

        // per-shape persistent session: replanner and estimator state
        // survive across training steps instead of resetting per call
        if !self.sessions.iter().any(|(k, _)| *k == key) {
            let mut builder = Session::builder()
                .partitioning(part)
                .code(cfg.coded.spec.clone())
                .auto_classes(cfg.coded.s_levels)
                .workers(cfg.coded.workers)
                .latency(cfg.coded.latency.clone())
                .omega(if cfg.coded.auto_omega {
                    OmegaMode::Auto
                } else {
                    OmegaMode::Fixed(1.0)
                })
                .deadline(cfg.coded.t_max)
                .cache_capacity(0)
                .seed(self.rng.next_u64())
                .backend(cfg.backend.clone());
            if let Some(policy) = cfg.adaptive.clone() {
                builder = builder.adaptive(policy);
            }
            let session = builder
                .build()
                .expect("cluster-matmul session config is validated by construction");
            self.sessions.push((key, session));
        }

        // injected per-slot delays from the dedicated stream: the decode
        // is virtual-time deterministic, and the server's per-worker
        // injection multipliers are what make workers actually unequal
        let omega = if cfg.coded.auto_omega {
            num_products as f64 / cfg.coded.workers as f64
        } else {
            1.0
        };
        let base: Vec<f64> = (0..cfg.coded.workers)
            .map(|_| cfg.coded.latency.sample_scaled(omega, &mut self.delay_rng))
            .collect();

        let session = self
            .sessions
            .iter_mut()
            .find(|(k, _)| *k == key)
            .map(|(_, s)| s)
            .expect("session inserted above");
        let report = session
            .run(Request::new(0, a_pad, b_pad).delays(base))
            .expect("cluster round failed (fleet unreachable or all workers dead)");

        self.rounds += 1;
        self.total_products += num_products;
        self.total_recovered += report.outcome.recovered;
        let slowest = report
            .timings
            .iter()
            .map(|t| t.delay)
            .fold(f64::NEG_INFINITY, f64::max);
        self.total_virtual_time += if slowest.is_finite() {
            slowest.min(cfg.coded.t_max)
        } else {
            cfg.coded.t_max
        };
        report.outcome.c_hat.block(0, 0, orig_m, orig_n)
    }
}

/// Zero-pad the operands up to block-divisible shapes and build the
/// matching partitioning (zero rows/columns contribute nothing to the
/// product; the caller crops the result back).
fn pad_and_partition(
    a: &Matrix,
    b: &Matrix,
    cfg: &CodedMatmulCfg,
) -> (Matrix, Matrix, Partitioning) {
    match cfg.paradigm {
        Paradigm::RowTimesCol => {
            let nb = cfg.blocks;
            let m_pad = round_up(a.rows(), nb);
            let n_pad = round_up(b.cols(), nb);
            let a_pad = pad_to(a, m_pad, a.cols());
            let b_pad = pad_to(b, b.rows(), n_pad);
            let part = Partitioning::rxc(nb, nb, m_pad / nb, a.cols(), n_pad / nb);
            (a_pad, b_pad, part)
        }
        Paradigm::ColTimesRow => {
            let mb = cfg.blocks;
            let k_pad = round_up(a.cols(), mb);
            let a_pad = pad_to(a, a.rows(), k_pad);
            let b_pad = pad_to(b, k_pad, b.cols());
            let part = Partitioning::cxr(mb, a.rows(), k_pad / mb, b.cols());
            (a_pad, b_pad, part)
        }
    }
}

fn round_up(x: usize, to: usize) -> usize {
    x.div_ceil(to) * to
}

fn pad_to(m: &Matrix, rows: usize, cols: usize) -> Matrix {
    if m.shape() == (rows, cols) {
        return m.clone();
    }
    let mut out = Matrix::zeros(rows, cols);
    out.set_block(0, 0, m);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodeKind, EncodeStyle, WindowPolynomial};

    fn cfg(paradigm: Paradigm, blocks: usize, t_max: f64) -> CodedMatmulCfg {
        CodedMatmulCfg {
            paradigm,
            blocks,
            spec: CodeSpec::new(
                CodeKind::EwUep(WindowPolynomial::paper_table3()),
                EncodeStyle::Stacked,
            ),
            workers: 15,
            latency: LatencyModel::exp(0.5),
            auto_omega: true,
            t_max,
            s_levels: 3,
        }
    }

    #[test]
    fn generous_deadline_gives_exact_product() {
        let mut rng = Pcg64::seed_from(1);
        // Table VI shape: (64×100)·(100×784) — indivisible by 3, padded.
        let a = Matrix::randn(64, 100, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(100, 784, 0.0, 1.0, &mut rng);
        for paradigm in [Paradigm::RowTimesCol, Paradigm::ColTimesRow] {
            let blocks = if paradigm == Paradigm::RowTimesCol { 3 } else { 9 };
            let mut dm = DistributedMatmul::new(
                MatmulStrategy::Coded(cfg(paradigm, blocks, 1e6)),
                Pcg64::seed_from(2),
            );
            let got = dm.multiply(&a, &b);
            assert_eq!(got.shape(), (64, 784));
            assert!(got.allclose(&matmul(&a, &b), 1e-9), "{paradigm:?}");
            assert!((dm.recovery_rate() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_deadline_gives_zero_matrix() {
        let mut rng = Pcg64::seed_from(3);
        let a = Matrix::randn(10, 9, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(9, 10, 0.0, 1.0, &mut rng);
        let mut dm = DistributedMatmul::new(
            MatmulStrategy::Coded(cfg(Paradigm::ColTimesRow, 9, 0.0)),
            Pcg64::seed_from(4),
        );
        let got = dm.multiply(&a, &b);
        assert_eq!(got.frob_sq(), 0.0);
        assert_eq!(dm.recovery_rate(), 0.0);
    }

    #[test]
    fn partial_deadline_recovers_blocks_exactly() {
        // Whatever the coded path recovers must match the true product on
        // those blocks (r×c: block-exact or zero).
        let mut rng = Pcg64::seed_from(5);
        let a = Matrix::randn(12, 8, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(8, 12, 0.0, 1.0, &mut rng);
        let mut dm = DistributedMatmul::new(
            MatmulStrategy::Coded(cfg(Paradigm::RowTimesCol, 3, 1.2)),
            Pcg64::seed_from(6),
        );
        let got = dm.multiply(&a, &b);
        let truth = matmul(&a, &b);
        for bi in 0..3 {
            for bj in 0..3 {
                let gb = got.block(bi * 4, bj * 4, 4, 4);
                let tb = truth.block(bi * 4, bj * 4, 4, 4);
                let zero = gb.frob_sq() == 0.0;
                assert!(
                    zero || gb.allclose(&tb, 1e-9),
                    "block ({bi},{bj}) neither zero nor exact"
                );
            }
        }
    }

    #[test]
    fn exact_strategy_is_exact() {
        let mut rng = Pcg64::seed_from(7);
        let a = Matrix::randn(5, 6, 0.0, 1.0, &mut rng);
        let b = Matrix::randn(6, 4, 0.0, 1.0, &mut rng);
        let mut dm = DistributedMatmul::new(MatmulStrategy::Exact, Pcg64::seed_from(8));
        assert!(dm.multiply(&a, &b).allclose(&matmul(&a, &b), 1e-12));
    }
}
