//! The CIFAR CNN of paper Table V (two conv layers + max-pool + three
//! dense layers), with a size knob so the Fig. 1 reproduction can run
//! scaled-down by default. Convolutions are computed centrally (the
//! paper trains them without stragglers, §VII-C); the dense layers'
//! back-propagation matmuls go through the coded distributed engine.

use crate::linalg::Matrix;
use crate::rng::Pcg64;

use super::conv::{Conv2D, ImageBatch, MaxPool2D};
use super::dense::{relu, relu_backward, Dense};
use super::distributed::DistributedMatmul;
use super::loss::softmax_xent;
use super::sparsify::{sparsify, TauSchedule};

/// Architecture parameters (paper Table V: side=32, channels=32,
/// dense=(512, 256), classes=10).
#[derive(Clone, Copy, Debug)]
pub struct CnnArch {
    pub side: usize,
    pub in_channels: usize,
    pub conv_channels: usize,
    pub dense1: usize,
    pub dense2: usize,
    pub classes: usize,
}

impl CnnArch {
    /// Paper scale (Table V).
    pub fn paper() -> Self {
        CnnArch {
            side: 32,
            in_channels: 3,
            conv_channels: 32,
            dense1: 512,
            dense2: 256,
            classes: 10,
        }
    }

    /// Scaled-down default used by `uepmm exp fig1` without `--full`.
    pub fn small() -> Self {
        CnnArch {
            side: 16,
            in_channels: 3,
            conv_channels: 8,
            dense1: 64,
            dense2: 32,
            classes: 10,
        }
    }

    /// Flattened feature size after conv1(same) → conv2(valid) → pool.
    pub fn flat_dim(&self) -> usize {
        let after_valid = self.side - 2;
        let pooled = after_valid / 2;
        self.conv_channels * pooled * pooled
    }
}

/// The CNN model.
pub struct Cnn {
    pub arch: CnnArch,
    pub conv1: Conv2D,
    pub conv2: Conv2D,
    pub pool: MaxPool2D,
    pub fc: [Dense; 3],
}

impl Cnn {
    pub fn init(arch: CnnArch, rng: &mut Pcg64) -> Self {
        Cnn {
            arch,
            conv1: Conv2D::init(arch.in_channels, arch.conv_channels, 3, 1, rng),
            conv2: Conv2D::init(arch.conv_channels, arch.conv_channels, 3, 0, rng),
            pool: MaxPool2D,
            fc: [
                Dense::init(arch.flat_dim(), arch.dense1, rng),
                Dense::init(arch.dense1, arch.dense2, rng),
                Dense::init(arch.dense2, arch.classes, rng),
            ],
        }
    }

    /// Forward to logits (rows = batch).
    pub fn logits(&self, x: &Matrix) -> Matrix {
        let img = ImageBatch::from_matrix(x, self.arch.in_channels, self.arch.side, self.arch.side);
        let (c1, _) = self.conv1.forward(&img);
        let (c2, _) = self.conv2.forward(&c1);
        let (p, _) = self.pool.forward(&c2);
        let mut h = p.to_matrix();
        for (i, fc) in self.fc.iter().enumerate() {
            h = fc.forward(&h);
            if i + 1 < self.fc.len() {
                relu(&mut h);
            }
        }
        h
    }

    /// One SGD step; dense back-propagation matmuls run through `engine`.
    pub fn train_step(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        lr: f64,
        engine: &mut DistributedMatmul,
        tau: &TauSchedule,
        epoch: usize,
        // when false, the last layer's eq. (33) stays uncoded — its
        // factors are not sparse enough to benefit (paper §VII-C)
        code_last_layer: bool,
    ) -> f64 {
        let arch = self.arch;
        let img = ImageBatch::from_matrix(x, arch.in_channels, arch.side, arch.side);
        let (c1, cache1) = self.conv1.forward(&img);
        let (c2, cache2) = self.conv2.forward(&c1);
        let (pooled, cache_p) = self.pool.forward(&c2);
        let flat = pooled.to_matrix();
        // dense forward, keeping X_i
        let mut acts = vec![flat.clone()];
        let mut h = flat;
        for (i, fc) in self.fc.iter().enumerate() {
            h = fc.forward(&h);
            if i + 1 < self.fc.len() {
                relu(&mut h);
            }
            acts.push(h.clone());
        }
        let (loss, mut g) = softmax_xent(&h, y);
        // dense backward (eqs. 32–33) with coded matmuls
        let n_fc = self.fc.len();
        let mut dv = Vec::with_capacity(n_fc);
        let mut db = Vec::with_capacity(n_fc);
        for i in (0..n_fc).rev() {
            sparsify(&mut g, tau.grad_tau(i, epoch));
            let mut x_t = acts[i].transpose();
            sparsify(&mut x_t, tau.weight_tau(i, epoch));
            // the paper computes the LAST layer's eq. (33) uncoded — its
            // factors are not sparse enough to benefit (§VII-C)
            let dvi = if i + 1 == n_fc && !code_last_layer {
                crate::linalg::matmul(&x_t, &g)
            } else {
                engine.multiply(&x_t, &g)
            };
            dv.push(dvi);
            db.push(Dense::bias_grad(&g));
            if i > 0 {
                let mut v_t = self.fc[i].v.transpose();
                sparsify(&mut v_t, tau.weight_tau(i, epoch));
                let mut g_prev = engine.multiply(&g, &v_t);
                relu_backward(&mut g_prev, &acts[i]);
                g = g_prev;
            }
        }
        dv.reverse();
        db.reverse();
        // gradient into the conv stack: dL/dflat = G_1 · V_1ᵀ (central)
        let mut g_flat = crate::linalg::matmul(&g, &self.fc[0].v.transpose());
        relu_backward(&mut g_flat, &acts[0]);
        // NOTE: acts[0] is post-pool (no ReLU applied after pool), so the
        // mask above is a no-op unless pooling output hit exact zeros;
        // conv ReLUs are handled inside Conv2D::backward.
        let (oh, ow) = {
            let after_valid = arch.side - 2;
            (after_valid / 2, after_valid / 2)
        };
        let g_pool = ImageBatch::from_matrix(&g_flat, arch.conv_channels, oh, ow);
        let g_c2 = self.pool.backward(&g_pool, &cache_p);
        let (dw2, db2, g_c1) = self.conv2.backward(&g_c2, &cache2);
        let (dw1, db1, _) = self.conv1.backward(&g_c1, &cache1);
        // updates
        for (i, fc) in self.fc.iter_mut().enumerate() {
            fc.apply_grads(&dv[i], &db[i], lr);
        }
        self.conv2.apply_grads(&dw2, &db2, lr);
        self.conv1.apply_grads(&dw1, &db1, lr);
        loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::distributed::MatmulStrategy;
    use super::super::loss::accuracy;
    use crate::data::synthetic_cifar;

    #[test]
    fn flat_dim_matches_paper_arch() {
        // Table V: 32×32 → conv same → conv valid (30) → pool (15) →
        // 32·15·15 = 7200.
        assert_eq!(CnnArch::paper().flat_dim(), 7200);
    }

    #[test]
    fn cnn_learns_synthetic_textures() {
        let mut rng = Pcg64::seed_from(1);
        let arch = CnnArch {
            side: 12,
            in_channels: 3,
            conv_channels: 4,
            dense1: 32,
            dense2: 16,
            classes: 10,
        };
        let train = synthetic_cifar(200, 12, 3, &mut rng);
        let test = synthetic_cifar(80, 12, 5, &mut rng);
        let mut cnn = Cnn::init(arch, &mut rng);
        let mut engine = DistributedMatmul::new(MatmulStrategy::Exact, Pcg64::seed_from(2));
        let tau = TauSchedule::off(3);
        let (tx, ty) = test.all();
        let before = accuracy(&cnn.logits(&tx), &ty);
        for epoch in 0..12 {
            for step in 0..12 {
                let idx: Vec<usize> = (0..16).map(|i| (step * 16 + i) % train.len()).collect();
                let (x, y) = train.batch(&idx);
                cnn.train_step(&x, &y, 0.1, &mut engine, &tau, epoch, false);
            }
        }
        let after = accuracy(&cnn.logits(&tx), &ty);
        assert!(after > 0.6, "accuracy {before} -> {after}");
    }
}
