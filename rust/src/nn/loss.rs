//! Softmax + categorical cross-entropy (Table IV) and accuracy.

use crate::linalg::Matrix;

/// Mean softmax cross-entropy loss and its logits gradient
/// `(softmax(logits) − y)/batch` — the `G_{I+1}` seeding eq. (32).
pub fn softmax_xent(logits: &Matrix, y_onehot: &Matrix) -> (f64, Matrix) {
    assert_eq!(logits.shape(), y_onehot.shape());
    let batch = logits.rows();
    let classes = logits.cols();
    let mut grad = Matrix::zeros(batch, classes);
    let mut loss = 0.0;
    for r in 0..batch {
        let row = logits.row(r);
        let max = row.iter().fold(f64::NEG_INFINITY, |m, &x| m.max(x));
        let exps: Vec<f64> = row.iter().map(|&x| (x - max).exp()).collect();
        let z: f64 = exps.iter().sum();
        let g = grad.row_mut(r);
        for c in 0..classes {
            let p = exps[c] / z;
            let y = y_onehot[(r, c)];
            g[c] = (p - y) / batch as f64;
            if y > 0.0 {
                loss -= y * (p.max(1e-300)).ln();
            }
        }
    }
    (loss / batch as f64, grad)
}

/// Classification accuracy of logits against one-hot labels.
pub fn accuracy(logits: &Matrix, y_onehot: &Matrix) -> f64 {
    let batch = logits.rows();
    let mut correct = 0usize;
    for r in 0..batch {
        let pred = argmax(logits.row(r));
        let truth = argmax(y_onehot.row(r));
        if pred == truth {
            correct += 1;
        }
    }
    correct as f64 / batch as f64
}

fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_loss_is_log_classes() {
        let logits = Matrix::zeros(4, 10);
        let mut y = Matrix::zeros(4, 10);
        for r in 0..4 {
            y[(r, r)] = 1.0;
        }
        let (loss, grad) = softmax_xent(&logits, &y);
        assert!((loss - (10f64).ln()).abs() < 1e-9);
        // gradient rows sum to zero
        for r in 0..4 {
            let s: f64 = grad.row(r).iter().sum();
            assert!(s.abs() < 1e-12);
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut logits = Matrix::from_vec(2, 3, vec![0.3, -0.7, 1.1, 0.0, 0.5, -0.2]);
        let mut y = Matrix::zeros(2, 3);
        y[(0, 2)] = 1.0;
        y[(1, 0)] = 1.0;
        let (_, grad) = softmax_xent(&logits, &y);
        let eps = 1e-6;
        for (r, c) in [(0, 0), (0, 2), (1, 1)] {
            let base = softmax_xent(&logits, &y).0;
            logits[(r, c)] += eps;
            let bumped = softmax_xent(&logits, &y).0;
            logits[(r, c)] -= eps;
            let num = (bumped - base) / eps;
            assert!((num - grad[(r, c)]).abs() < 1e-4, "({r},{c}): {num} vs {}", grad[(r, c)]);
        }
    }

    #[test]
    fn accuracy_counts_argmax_matches() {
        let logits = Matrix::from_vec(2, 2, vec![0.9, 0.1, 0.2, 0.8]);
        let mut y = Matrix::zeros(2, 2);
        y[(0, 0)] = 1.0;
        y[(1, 0)] = 1.0; // second sample mislabeled vs prediction
        assert!((accuracy(&logits, &y) - 0.5).abs() < 1e-12);
    }
}
