//! Training loop for the MLP experiments (paper §VII-C, Figs. 13–15):
//! SGD over mini-batches with the back-propagation matmuls routed
//! through a [`DistributedMatmul`] strategy, logging accuracy per
//! evaluation interval.

use crate::data::Dataset;
use crate::rng::Pcg64;

use super::distributed::{DistributedMatmul, MatmulStrategy};
use super::loss::accuracy;
use super::mlp::Mlp;
use super::sparsify::TauSchedule;

/// Training configuration (paper Table IV defaults).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub lr: f64,
    pub epochs: usize,
    pub batch: usize,
    pub strategy: MatmulStrategy,
    pub tau: TauSchedule,
    pub seed: u64,
    /// Evaluate every `eval_every` mini-batch iterations.
    pub eval_every: usize,
    /// Cap on iterations per epoch (0 = full dataset) — the scaled-down
    /// default keeps the 20-config Fig. 13–15 sweep tractable.
    pub max_iters_per_epoch: usize,
}

impl TrainConfig {
    pub fn paper_defaults(strategy: MatmulStrategy, layers: usize) -> Self {
        TrainConfig {
            lr: 0.01,
            epochs: 3,
            batch: 64,
            strategy,
            tau: TauSchedule::paper(layers),
            seed: 7,
            eval_every: 50,
            max_iters_per_epoch: 0,
        }
    }
}

/// One evaluation point along training.
#[derive(Clone, Copy, Debug)]
pub struct EpochPoint {
    pub epoch: usize,
    pub iter: usize,
    pub train_loss: f64,
    pub test_acc: f64,
    /// Cumulative virtual compute time when this point was taken
    /// (cluster strategy; 0.0 elsewhere). The x-axis of
    /// wall-clock-to-accuracy comparisons.
    pub virtual_time: f64,
}

/// Full record of a training run.
#[derive(Clone, Debug)]
pub struct TrainRecord {
    pub points: Vec<EpochPoint>,
    pub final_test_acc: f64,
    /// Fraction of distributed sub-products recovered across the run.
    pub recovery_rate: f64,
    /// Total virtual compute time of the run (cluster strategy; 0.0
    /// elsewhere).
    pub virtual_time: f64,
}

/// Train an MLP on a dataset under the given straggler strategy.
pub fn train_mlp(
    mlp: &mut Mlp,
    train: &Dataset,
    test: &Dataset,
    cfg: &TrainConfig,
) -> TrainRecord {
    let mut rng = Pcg64::seed_from(cfg.seed);
    let mut engine = DistributedMatmul::new(cfg.strategy.clone(), rng.split());
    let mut points = Vec::new();
    let mut iter = 0usize;
    let (test_x, test_y) = test.all();
    for epoch in 0..cfg.epochs {
        let mut order = crate::rng::permutation(&mut rng, train.len());
        let full_iters = train.len() / cfg.batch;
        let iters = if cfg.max_iters_per_epoch == 0 {
            full_iters
        } else {
            full_iters.min(cfg.max_iters_per_epoch)
        };
        order.truncate(iters * cfg.batch);
        let mut running_loss = 0.0;
        let mut since_eval = 0usize;
        for step in 0..iters {
            let idx = &order[step * cfg.batch..(step + 1) * cfg.batch];
            let (x, y) = train.batch(idx);
            let loss = mlp.train_step(&x, &y, cfg.lr, &mut engine, &cfg.tau, epoch);
            running_loss += loss;
            since_eval += 1;
            iter += 1;
            if iter % cfg.eval_every == 0 || step + 1 == iters {
                let acc = accuracy(&mlp.logits(&test_x), &test_y);
                points.push(EpochPoint {
                    epoch,
                    iter,
                    train_loss: running_loss / since_eval as f64,
                    test_acc: acc,
                    virtual_time: engine.total_virtual_time,
                });
                running_loss = 0.0;
                since_eval = 0;
            }
        }
    }
    let final_acc = accuracy(&mlp.logits(&test_x), &test_y);
    TrainRecord {
        points,
        final_test_acc: final_acc,
        recovery_rate: engine.recovery_rate(),
        virtual_time: engine.total_virtual_time,
    }
}

/// Evaluate accuracy of a model over a dataset in batches.
pub fn evaluate(mlp: &Mlp, data: &Dataset, batch: usize) -> f64 {
    let mut correct = 0.0;
    let mut total = 0.0;
    let n = data.len();
    let mut i = 0;
    while i < n {
        let hi = (i + batch).min(n);
        let idx: Vec<usize> = (i..hi).collect();
        let (x, y) = data.batch(&idx);
        correct += accuracy(&mlp.logits(&x), &y) * idx.len() as f64;
        total += idx.len() as f64;
        i = hi;
    }
    correct / total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic_digits;

    #[test]
    fn exact_training_learns_synthetic_digits() {
        let mut rng = Pcg64::seed_from(1);
        let train = synthetic_digits(600, 11, &mut rng);
        let test = synthetic_digits(200, 13, &mut rng);
        let mut mlp = Mlp::new(&[784, 64, 32, 10], &mut rng);
        let cfg = TrainConfig {
            lr: 0.1,
            epochs: 4,
            batch: 32,
            strategy: MatmulStrategy::Exact,
            tau: TauSchedule::off(3),
            seed: 5,
            eval_every: 10,
            max_iters_per_epoch: 0,
        };
        let rec = train_mlp(&mut mlp, &train, &test, &cfg);
        assert!(!rec.points.is_empty());
        assert!(
            rec.final_test_acc > 0.62,
            "accuracy too low: {}",
            rec.final_test_acc
        );
        assert_eq!(rec.recovery_rate, 1.0);
        // loss should broadly decrease
        let first = rec.points.first().unwrap().train_loss;
        let last = rec.points.last().unwrap().train_loss;
        assert!(last < first, "loss {first} -> {last}");
    }
}
