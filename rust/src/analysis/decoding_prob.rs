//! Per-class decoding probabilities of the NOW and EW UEP strategies as
//! a function of the number of received packets `N` — [19, eqs. 5–9] as
//! used by the paper's eq. (20). Real Gaussian coefficients realize the
//! infinite-field-size assumption, so these are exact for the `Stacked`
//! encoding (and validated against Monte-Carlo rank experiments in the
//! tests).

use super::combinatorics::{binomial_pmf, compositions, multinomial_pmf};

/// NOW-UEP: class `l` decodes iff at least `k_l` of the `n` received
/// packets chose window `l`; the count is `Binomial(n, Γ_l)` (the
/// multinomial marginal), so
/// `P_{d,l}(n) = Σ_{j ≥ k_l} C(n,j) Γ_l^j (1−Γ_l)^{n−j}`.
pub fn now_decode_prob(n: usize, gamma: &[f64], k: &[usize], l: usize) -> f64 {
    assert_eq!(gamma.len(), k.len());
    assert!(l < k.len());
    (k[l]..=n).map(|j| binomial_pmf(n, j, gamma[l])).sum()
}

/// EW prefix solvability: with window counts `counts` (packets per
/// window), the joint system on levels `0..=j` is generically solvable
/// iff every suffix of levels `s..=j` has at least as many covering
/// packets as unknowns: `Σ_{m=s..j} counts_m ≥ Σ_{m=s..j} k_m` for all
/// `s ≤ j` (packets of window `m` cover levels `0..=m`, so only windows
/// `≥ s` touch levels `≥ s`).
pub fn ew_prefix_solvable(counts: &[usize], k: &[usize], j: usize) -> bool {
    debug_assert!(j < k.len());
    let mut packets = 0usize;
    let mut unknowns = 0usize;
    for s in (0..=j).rev() {
        packets += counts[s];
        unknowns += k[s];
        if packets < unknowns {
            return false;
        }
    }
    true
}

/// EW decodable-level set for a window-count vector: level `i` decodes
/// iff some prefix `0..=j` with `j ≥ i` is solvable.
pub fn ew_decodable_levels(counts: &[usize], k: &[usize]) -> Vec<bool> {
    let l = k.len();
    let solvable: Vec<bool> = (0..l).map(|j| ew_prefix_solvable(counts, k, j)).collect();
    // decodable(i) = any solvable(j) for j ≥ i
    let mut dec = vec![false; l];
    let mut any = false;
    for i in (0..l).rev() {
        any = any || solvable[i];
        dec[i] = any;
    }
    dec
}

/// EW-UEP: exact decoding probability of level `l` with `n` received
/// packets, by enumeration over the multinomial window-count vectors
/// ([19, eqs. 6–9]).
pub fn ew_decode_prob(n: usize, gamma: &[f64], k: &[usize], l: usize) -> f64 {
    assert_eq!(gamma.len(), k.len());
    assert!(l < k.len());
    let mut p = 0.0;
    for counts in compositions(n, k.len()) {
        if ew_decodable_levels(&counts, k)[l] {
            p += multinomial_pmf(&counts, gamma);
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{rank, Matrix};
    use crate::rng::{Normal, Pcg64};
    use crate::util::prop::{gen, prop_check, PropConfig};

    const GAMMA: [f64; 3] = [0.40, 0.35, 0.25];
    const K: [usize; 3] = [3, 3, 3];

    #[test]
    fn now_monotone_in_n_and_ordered_by_gamma() {
        let mut prev = [0.0; 3];
        for n in 0..=30 {
            for l in 0..3 {
                let p = now_decode_prob(n, &GAMMA, &K, l);
                assert!((0.0..=1.0 + 1e-12).contains(&p));
                assert!(p + 1e-12 >= prev[l], "class {l} not monotone at n={n}");
                prev[l] = p;
            }
            // higher window probability ⇒ better protection (k equal)
            assert!(prev[0] + 1e-12 >= prev[1]);
            assert!(prev[1] + 1e-12 >= prev[2]);
        }
        // by n = 30 the first class is nearly always decodable (Fig. 8)
        assert!(prev[0] > 0.999);
    }

    #[test]
    fn now_zero_below_threshold() {
        for l in 0..3 {
            for n in 0..K[l] {
                assert_eq!(now_decode_prob(n, &GAMMA, &K, l), 0.0);
            }
        }
    }

    #[test]
    fn ew_class0_dominates_now_class0() {
        // EW always includes class 0 in every packet, so its class-0
        // decoding probability is at least NOW's for every n.
        for n in 0..=30 {
            let ew = ew_decode_prob(n, &GAMMA, &K, 0);
            let now = now_decode_prob(n, &GAMMA, &K, 0);
            assert!(ew + 1e-12 >= now, "n={n}: EW {ew} < NOW {now}");
        }
        // and strictly better somewhere
        assert!(ew_decode_prob(6, &GAMMA, &K, 0) > now_decode_prob(6, &GAMMA, &K, 0));
    }

    #[test]
    fn ew_levels_are_ordered() {
        // With nested windows, a more important level always has a ≥
        // decoding probability.
        for n in 0..=25 {
            let p: Vec<f64> = (0..3).map(|l| ew_decode_prob(n, &GAMMA, &K, l)).collect();
            assert!(p[0] + 1e-12 >= p[1] && p[1] + 1e-12 >= p[2], "n={n}: {p:?}");
        }
    }

    #[test]
    fn ew_prefix_solvable_cases() {
        // k = (3,3,3): 3 window-0 packets solve prefix 0
        assert!(ew_prefix_solvable(&[3, 0, 0], &K, 0));
        assert!(!ew_prefix_solvable(&[2, 5, 0], &K, 0));
        // 6 packets in windows 0..1 with ≥3 in window ≥1 solve prefix 1
        assert!(ew_prefix_solvable(&[3, 3, 0], &K, 1));
        assert!(ew_prefix_solvable(&[0, 6, 0], &K, 1));
        // suffix violation: 5 window-0, 1 window-1 (level-1 unknowns only
        // covered by the single window-1 packet)
        assert!(!ew_prefix_solvable(&[5, 1, 0], &K, 1));
        // full decode needs 9 with every suffix covered
        assert!(ew_prefix_solvable(&[3, 3, 3], &K, 2));
        assert!(!ew_prefix_solvable(&[4, 3, 2], &K, 2));
    }

    /// Monte-Carlo validation of the Hall-type predicate: build the
    /// actual random nested-support coefficient matrix and compare
    /// generic solvability (rank of suffix systems) with the predicate.
    #[test]
    fn ew_predicate_matches_random_rank() {
        prop_check("EW Hall ≡ rank", PropConfig { cases: 60, seed: 21 }, |rng, _| {
            let l = gen::usize_in(rng, 1, 3);
            let k: Vec<usize> = (0..l).map(|_| gen::usize_in(rng, 1, 3)).collect();
            let total_k: usize = k.iter().sum();
            let n = gen::usize_in(rng, 0, total_k + 3);
            // random window counts
            let mut counts = vec![0usize; l];
            for _ in 0..n {
                counts[rng.next_bounded(l as u64) as usize] += 1;
            }
            for j in 0..l {
                // build system on levels 0..=j using packets with window ≤ j
                let unknowns: usize = k[..=j].iter().sum();
                let mut rows: Vec<Vec<f64>> = Vec::new();
                for (w, &cnt) in counts.iter().enumerate().take(j + 1) {
                    let covered: usize = k[..=w].iter().sum();
                    for _ in 0..cnt {
                        let mut row = vec![0.0; unknowns];
                        for slot in row.iter_mut().take(covered) {
                            *slot = Normal::standard(rng);
                        }
                        rows.push(row);
                    }
                }
                let solvable_rank = if rows.is_empty() {
                    unknowns == 0
                } else {
                    let m = Matrix::from_fn(rows.len(), unknowns, |r, c| rows[r][c]);
                    rank(&m) == unknowns
                };
                let predicted = ew_prefix_solvable(&counts, &k, j);
                if solvable_rank != predicted {
                    return Err(format!(
                        "counts={counts:?} k={k:?} j={j}: rank says {solvable_rank}, predicate {predicted}"
                    ));
                }
            }
            Ok(())
        });
    }

    /// NOW probability formula vs direct Monte-Carlo packet simulation.
    #[test]
    fn now_formula_matches_monte_carlo() {
        let mut rng = Pcg64::seed_from(3);
        let n = 10;
        let trials = 60_000;
        let mut hits = [0usize; 3];
        for _ in 0..trials {
            let mut counts = [0usize; 3];
            for _ in 0..n {
                counts[crate::rng::sample_discrete(&mut rng, &GAMMA)] += 1;
            }
            for l in 0..3 {
                if counts[l] >= K[l] {
                    hits[l] += 1;
                }
            }
        }
        for l in 0..3 {
            let emp = hits[l] as f64 / trials as f64;
            let ana = now_decode_prob(n, &GAMMA, &K, l);
            assert!((emp - ana).abs() < 0.01, "class {l}: {emp} vs {ana}");
        }
    }
}
